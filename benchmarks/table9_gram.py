"""Paper Table 9: the rate-limiting Sigma statistic
sum_d (1/gamma_d) x_d x_d^T at N=250,000, K=500.

The paper measured 1 CPU core (17.1s) vs 512/2048 GPU cores (0.73/0.34s).
Here, three measurement families:

  1. the original XLA-CPU wall time for the jnp path plus the derived
     TPU v5e single-chip roofline bounds for the Pallas kernel;
  2. dense-vs-triangle SYRK: the dense ``weighted_gram`` block grid vs
     ``syrk_tri``'s lower-triangle block grid, wall-clocked on whatever
     backend this host provides (interpret-mode Pallas on CPU, compiled
     on TPU) — the triangle grid runs nb(nb+1)/2 of nb^2 block-steps,
     so the ratio approaches 0.5 (+ mirror overhead) as K grows;
  3. fused-vs-split statistics: one ``fused_stats`` pass vs
     ``fused_estep`` + gram (two X streams), on both the Pallas and the
     XLA-ref path.

Everything is appended to ``BENCH_gram.json`` so the speedups are
tracked across PRs (scripts/bench_smoke.py runs a tiny version in CI).
"""
from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops

from .common import append_json, emit

PEAK_FLOPS = 197e12
HBM_BW = 819e9

BENCH_JSON = os.environ.get("BENCH_GRAM_JSON", "BENCH_gram.json")


def _time(f, *args, repeats: int = 5, **kw):
    """Best wall-clock of ``repeats`` post-warmup calls (seconds)."""
    out = f(*args, **kw)
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(f(*args, **kw))
        best = min(best, time.perf_counter() - t0)
    return best


def _time_pair(fa, fb, repeats: int = 5):
    """Best wall-clock for two thunks with INTERLEAVED trials, so slow
    machine drift (noisy CI neighbors) hits both alike and their ratio
    stays meaningful."""
    jax.block_until_ready(fa())
    jax.block_until_ready(fb())
    best_a = best_b = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fa())
        best_a = min(best_a, time.perf_counter() - t0)
        t0 = time.perf_counter()
        jax.block_until_ready(fb())
        best_b = min(best_b, time.perf_counter() - t0)
    return best_a, best_b


def _kernel_backend() -> str:
    """Compiled Pallas on TPU; interpreter elsewhere (same block grid,
    so grid-size ratios — the quantity under test — carry over)."""
    return "pallas" if jax.default_backend() == "tpu" else "interpret"


def bench_tri_syrk(n: int, ks, *, block_n: int = 512, block_k: int = 128,
                   repeats: int = 5):
    """Dense-grid vs triangle-grid SYRK wall-clock at each K."""
    rng = np.random.default_rng(0)
    backend = _kernel_backend()
    rows = []
    for k in ks:
        X = jnp.asarray(rng.normal(size=(n, k)).astype(np.float32))
        w = jnp.asarray(rng.uniform(0.1, 2.0, size=(n,)).astype(np.float32))
        kw = dict(backend=backend, block_n=block_n, block_k=block_k)
        t_dense, t_tri = _time_pair(
            lambda: ops.weighted_gram(X, w, **kw),
            lambda: ops.syrk_tri(X, w, **kw), repeats=repeats)
        # exact parity check rides along with the timing
        err = float(jnp.max(jnp.abs(
            ops.syrk_tri(X, w, **kw) - ops.weighted_gram(X, w, **kw))))
        rows.append({"name": f"syrk_k{k}", "n": n, "k": k,
                     "backend": backend,
                     "seconds": t_tri, "dense_seconds": t_dense,
                     "tri_over_dense": round(t_tri / t_dense, 4),
                     "max_abs_err": err})
    return rows


def bench_fused_stats(n: int, k: int, *, block_n: int = 512,
                      block_k: int = 128):
    """One-pass fused_stats vs the split estep + gram (two X streams)."""
    rng = np.random.default_rng(1)
    X = jnp.asarray(rng.normal(size=(n, k)).astype(np.float32))
    y = jnp.asarray(rng.choice([-1.0, 1.0], size=n).astype(np.float32))
    wv = jnp.asarray(rng.normal(size=k).astype(np.float32))
    rows = []
    for backend in (_kernel_backend(), "ref"):
        kkw = {} if backend == "ref" else {"block_n": block_n}
        gkw = {} if backend == "ref" else {"block_n": block_n,
                                           "block_k": block_k}

        def split(X, y, wv):
            m, g, b = ops.fused_estep(X, y, y, wv, backend=backend, **kkw)
            S = ops.syrk_tri(X, 1.0 / g, backend=backend, **gkw)
            return m, g, b, S

        t_split, t_fused = _time_pair(
            lambda: split(X, y, wv),
            lambda: ops.fused_stats(X, y, y, wv, backend=backend, **kkw))
        rows.append({"name": f"stats_{backend}_k{k}", "n": n, "k": k,
                     "backend": backend, "seconds": t_fused,
                     "split_seconds": t_split,
                     "fused_over_split": round(t_fused / t_split, 4)})
    return rows


def run(n: int = 250_000, k: int = 500, full: bool = False,
        bench_n: int = 1024):
    # Kernel-grid comparisons FIRST: on quota-throttled CI runners a
    # long prior burn degrades later wall-clocks, and these ratios are
    # the numbers tracked across PRs. (Smaller N is fine — the grid
    # ratio under test is N-independent.)
    ks = (512, 1024, 2048) if full else (512, 1024)
    tri_rows = bench_tri_syrk(bench_n, ks, repeats=9)
    fused_rows = bench_fused_stats(bench_n, 512)

    rng = np.random.default_rng(0)
    X = rng.normal(size=(n, k)).astype(np.float32)
    w = rng.uniform(0.1, 2.0, size=(n,)).astype(np.float32)
    Xj, wj = jnp.asarray(X), jnp.asarray(w)

    f = jax.jit(lambda a, b: ops.weighted_gram(a, b, backend="ref"))
    f(Xj, wj).block_until_ready()
    t0 = time.time()
    f(Xj, wj).block_until_ready()
    cpu_s = time.time() - t0

    flops = 2.0 * n * k * k + n * k
    bytes_moved = 4.0 * (n * k + n + k * k)      # one X pass + w + out (f32)
    bf16_bytes = 2.0 * n * k + 4.0 * (n + k * k)
    rows = [
        {"name": "xla_cpu_1core", "seconds": cpu_s,
         "gflops": round(flops / cpu_s / 1e9, 1)},
        {"name": "tpu_v5e_compute_bound", "seconds": flops / PEAK_FLOPS,
         "derivation": "2NK^2/peak"},
        {"name": "tpu_v5e_compute_bound_tri",
         "seconds": flops / 2.0 / PEAK_FLOPS,
         "derivation": "NK^2/peak (triangle-blocked SYRK)"},
        {"name": "tpu_v5e_memory_bound_f32", "seconds": bytes_moved / HBM_BW,
         "derivation": "one-pass X stream"},
        {"name": "tpu_v5e_memory_bound_bf16",
         "seconds": bf16_bytes / HBM_BW,
         "derivation": "bf16 X stream (beyond-paper)"},
        {"name": "tpu_v5e_iter_split_vs_fused",
         "seconds": bytes_moved / HBM_BW,
         "derivation": "fused_stats: 1 X stream/iter vs 2 for split"},
    ]
    # paper reference points for the same statistic
    rows.append({"name": "paper_1_cpu_core", "seconds": 17.1,
                 "source": "Table 9"})
    rows.append({"name": "paper_2048_gpu_cores", "seconds": 0.34,
                 "source": "Table 9"})
    rows += tri_rows + fused_rows

    emit(rows, "table9_gram")
    append_json(rows, BENCH_JSON)
    return rows
