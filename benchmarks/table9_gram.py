"""Paper Table 9: the rate-limiting Sigma statistic
sum_d (1/gamma_d) x_d x_d^T at N=250,000, K=500.

The paper measured 1 CPU core (17.1s) vs 512/2048 GPU cores (0.73/0.34s).
Here: measured XLA-CPU wall time for the jnp path, plus the *derived* TPU
v5e single-chip roofline time for the Pallas kernel (compute- and
memory-bound bounds from the exact tile arithmetic — the kernel itself is
validated in interpret mode in tests/test_kernels_pallas.py)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops

from .common import emit

PEAK_FLOPS = 197e12
HBM_BW = 819e9


def run(n: int = 250_000, k: int = 500, full: bool = False):
    rng = np.random.default_rng(0)
    X = rng.normal(size=(n, k)).astype(np.float32)
    w = rng.uniform(0.1, 2.0, size=(n,)).astype(np.float32)
    Xj, wj = jnp.asarray(X), jnp.asarray(w)

    f = jax.jit(lambda a, b: ops.weighted_gram(a, b, backend="ref"))
    f(Xj, wj).block_until_ready()
    t0 = time.time()
    f(Xj, wj).block_until_ready()
    cpu_s = time.time() - t0

    flops = 2.0 * n * k * k + n * k
    bytes_moved = 4.0 * (n * k + n + k * k)      # one X pass + w + out (f32)
    bf16_bytes = 2.0 * n * k + 4.0 * (n + k * k)
    rows = [
        {"name": "xla_cpu_1core", "seconds": cpu_s,
         "gflops": round(flops / cpu_s / 1e9, 1)},
        {"name": "tpu_v5e_compute_bound", "seconds": flops / PEAK_FLOPS,
         "derivation": "2NK^2/peak"},
        {"name": "tpu_v5e_memory_bound_f32", "seconds": bytes_moved / HBM_BW,
         "derivation": "one-pass X stream"},
        {"name": "tpu_v5e_memory_bound_bf16",
         "seconds": bf16_bytes / HBM_BW,
         "derivation": "bf16 X stream (beyond-paper)"},
    ]
    # paper reference points for the same statistic
    rows.append({"name": "paper_1_cpu_core", "seconds": 17.1,
                 "source": "Table 9"})
    rows.append({"name": "paper_2048_gpu_cores", "seconds": 0.34,
                 "source": "Table 9"})
    emit(rows, "table9_gram")
    return rows
