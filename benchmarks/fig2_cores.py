"""Paper Fig. 2: training speed vs number of workers (dna dataset).

This container has ONE physical core, so wall-clock over forced host
devices cannot show parallel speedup (all 'devices' share the core).
Instead each P runs in a subprocess and reports the *per-device* compiled
cost of one EM iteration (exact loop-aware HLO analysis): FLOPs/device
must fall as 1/P (the paper's linear-scaling regime) while the reduction
payload stays constant — the same accounting the §Roofline cells use.
Wall-clock is reported as a secondary sanity column with this caveat."""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

from .common import emit

_SCRIPT = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={n_dev}"
import json, time
import numpy as np, jax
from repro import compat
from repro.core import PEMSVM, SVMConfig, lam_from_C
from repro.data import make_dna_like
from repro.launch.hlo_cost import analyze

n_dev = {n_dev}
X, y = make_dna_like({n}, {k})
lam = lam_from_C(1e-5) * {n} / 2_500_000
mesh = None
if n_dev > 1:
    mesh = compat.make_mesh((n_dev,), ("data",),
                         axis_types=("auto",))
svm = PEMSVM(SVMConfig(lam=lam, max_iters=6, min_iters=6, tol=0.0),
             mesh=mesh)
data, prior, state = svm._prepare(
    np.concatenate([X, np.ones((len(X), 1), np.float32)], 1), y)
step = svm._build_step(False)
key = jax.random.PRNGKey(0)
import jax.numpy as jnp
lowered = step.lower(data, state, key) if hasattr(step, "lower") else \
    jax.jit(step).lower(data, state, key)
cost = analyze(lowered.compile().as_text())
t0 = time.time()
res = svm.fit(X, y)
wall = (time.time() - t0) / res.n_iters
print(json.dumps({{"n_dev": n_dev, "flops_per_dev": cost["flops"],
                   "coll_bytes": cost["collective_bytes"],
                   "wall_s_per_iter": wall, "acc": svm.score(X, y)}}))
"""


def run(n: int = 40_000, k: int = 400, devices=(1, 2, 4, 8, 16),
        full=False):
    rows = []
    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    for n_dev in devices:
        env = dict(os.environ)
        env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
        code = textwrap.dedent(_SCRIPT.format(n_dev=n_dev, n=n, k=k))
        p = subprocess.run([sys.executable, "-c", code], env=env,
                           capture_output=True, text=True, timeout=900)
        assert p.returncode == 0, p.stderr[-2000:]
        r = json.loads(p.stdout.strip().splitlines()[-1])
        rows.append({"name": f"P={n_dev}",
                     "seconds": r["flops_per_dev"] / 197e12,
                     "flops_per_dev": f"{r['flops_per_dev']:.4g}",
                     "coll_bytes": f"{r['coll_bytes']:.4g}",
                     "wall_1core_caveat": round(r["wall_s_per_iter"], 3),
                     "acc": round(r["acc"], 4)})
    base = float(rows[0]["flops_per_dev"])
    for r, n_dev in zip(rows, devices):
        r["flop_speedup"] = round(base / float(r["flops_per_dev"]), 2)
        r["parallel_efficiency"] = round(
            base / float(r["flops_per_dev"]) / n_dev, 3)
    emit(rows, "fig2_cores")
    return rows
