"""Single-stream Gibbs: fused augmentation epilogues vs the pre-fusion
split paths (ISSUE 4 acceptance benchmark) -> ``BENCH_mc.json``.

Before the epilogue family, one MC-CLS (or SVR, either mode) iteration
streamed X three times:

  split:  margin = X w          (stream 1)
          draws on host         (gamma_mc_rowwise / double mixture)
          b      = X^T coef     (stream 2)
          S      = syrk_tri     (stream 3, tri-blocked: NK^2 FLOPs)
  fused:  one pallas_call       (stream 1 of 1; dense S: 2NK^2 FLOPs,
          epilogue on the margin tile, pre-drawn (nu, u) noise as O(N)
          operands)

In the memory-bound regime (K below the ~3300 roofline crossover,
DESIGN.md §Perf) stream count IS iteration time, so the fusion is a
bound-level ~3x. Per (combo, K) the benchmark records measured
wall-clock for both paths AND the analytic v5e roofline terms (same
constants as ``benchmarks/roofline.py``), with the X-stream counts
spelled out.

Gates (asserted, any backend):
  * roofline memory-time for fused >= 2x lower than split at every K
    (it is ~3x: 1 X stream vs 3);
  * measured wall-clock ratio fused/split < 1.0 — even in interpret
    mode (fewer grid steps + no extra XLA passes);
  * MC draw parity: the fused path's gamma (and SVR gamma/omega) are
    BITWISE equal to the ``gamma_mc_rowwise`` / split-key oracle on the
    dispatch (ref) path, and flip-free-close on the kernel path;
  * EM-SVR whole-fit parity <= 1e-4 across the loop / scan / stream
    drivers AND a hand-rolled pre-fusion split-statistic fit.
"""
from __future__ import annotations

import os
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import PEMSVM, SVMConfig, augment, stats
from repro.kernels import ops

from .common import append_json, emit

BENCH_JSON = os.environ.get("BENCH_MC_JSON", "BENCH_mc.json")

PEAK_FLOPS = 197e12     # v5e, matches benchmarks/roofline.py
HBM_BW = 819e9


def _roofline(n: int, k: int) -> dict[str, dict[str, float]]:
    """Analytic per-iteration roofline terms for split vs fused.

    Both paths run the same O(NK) margin/b work; Sigma is NK^2 FLOPs
    tri-blocked (split) vs 2NK^2 dense (fused) — the triangle trick
    does not compose with single-pass streaming. Bytes: split streams
    X for margin, b and Sigma (3 passes); fused streams it once plus
    the O(N) row operands (targets, draws' noise). CLS and SVR share
    these terms: SVR's second mixture only adds O(N) row work/bytes,
    noise next to the O(NK) X stream already in ``small``."""
    small = 4.0 * (8 * n + 2 * k)          # row vectors + w/b
    flops_linear = 4.0 * n * k             # margin + b matmuls
    out = {}
    for name, (flops, byts, streams) in {
        "split": (flops_linear + n * k * k, 3 * 4.0 * n * k + small, 3),
        "fused": (flops_linear + 2.0 * n * k * k, 4.0 * n * k + small, 1),
    }.items():
        compute_s, memory_s = flops / PEAK_FLOPS, byts / HBM_BW
        out[name] = {"compute_s": compute_s, "memory_s": memory_s,
                     "bound_s": max(compute_s, memory_s),
                     "x_streams": streams}
    return out


def _time_best(fn, repeats: int = 3) -> float:
    fn()                                    # warm the jit caches
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _statistic_rows(n: int, ks, backend: str, failures: list) -> list:
    rng = np.random.default_rng(0)
    key = jax.random.PRNGKey(1)
    k_lo, k_hi = jax.random.split(key)
    rows = []
    for k in ks:
        X = jnp.asarray(rng.normal(size=(n, k)).astype(np.float32))
        y = jnp.asarray(rng.choice([-1.0, 1.0], n).astype(np.float32))
        ys = jnp.asarray(
            np.asarray(X) @ rng.normal(size=k).astype(np.float32))
        # knee-free SVR targets for the PARITY gate: |res +- eps_ins|
        # >= 0.1 at w = 0 bounds the IG mean mu <= 10, so in-kernel vs
        # oracle draws cannot hit the accept-reject flip channel or the
        # transform's mu-amplified cancellation (tests/test_mc_fused.py
        # documents both) — the gate stays deterministic across jax
        # versions. Timing uses the realistic (w, ys) below.
        ys_gate = jnp.asarray(
            (np.sign(rng.normal(size=n)) *
             (0.3 + np.abs(rng.normal(size=n)))).astype(np.float32))
        w = jnp.asarray(rng.normal(size=k).astype(np.float32))
        w0 = jnp.zeros((k,), jnp.float32)
        zeros = jnp.zeros((n,), jnp.float32)
        eps, eps_ins = 1e-2, 0.2

        def split_mc_cls(wv=w):
            margin = X @ wv
            gamma = augment.gamma_mc_rowwise(key, y - margin, eps, 0)
            b = X.T @ (y / gamma + y)
            S = ops.syrk_tri(X, 1.0 / gamma, backend=backend)
            return [np.asarray(o) for o in (margin, gamma, b, S)]

        def fused_mc_cls(wv=w):
            noise = augment.draw_ig_noise(key, n, 0)
            return [np.asarray(o) for o in ops.fused_stats(
                X, y, y, wv, None, noise, epilogue="mc_hinge", eps=eps,
                backend=backend)]

        def split_svr(mode, wv=w, t=ys):
            pred = X @ wv
            res = t - pred
            gamma = augment.update_gamma(mode, k_lo, res - eps_ins, eps,
                                         row0=0)
            omega = augment.update_gamma(mode, k_hi, res + eps_ins, eps,
                                         row0=0)
            S = ops.syrk_tri(X, 1.0 / gamma + 1.0 / omega,
                             backend=backend)
            b = X.T @ ((t - eps_ins) / gamma + (t + eps_ins) / omega)
            return [np.asarray(o) for o in (pred, gamma, omega, b, S)]

        def fused_svr(mode, wv=w, t=ys):
            noise = None
            if mode == "MC":
                noise = (*augment.draw_ig_noise(k_lo, n, 0),
                         *augment.draw_ig_noise(k_hi, n, 0))
            return [np.asarray(o) for o in ops.fused_stats(
                X, t, zeros, wv, None, noise,
                epilogue=("em_svr" if mode == "EM" else "mc_svr"),
                eps=eps, eps_ins=eps_ins, backend=backend)]

        combos = {
            "MC-CLS": (split_mc_cls, fused_mc_cls,
                       lambda: (split_mc_cls(w0), fused_mc_cls(w0))),
            "EM-SVR": (lambda: split_svr("EM"), lambda: fused_svr("EM"),
                       lambda: (split_svr("EM", w0, ys_gate),
                                fused_svr("EM", w0, ys_gate))),
            "MC-SVR": (lambda: split_svr("MC"), lambda: fused_svr("MC"),
                       lambda: (split_svr("MC", w0, ys_gate),
                                fused_svr("MC", w0, ys_gate))),
        }
        for combo, (split_fn, fused_fn, gate_fn) in combos.items():
            svr = combo.endswith("SVR")
            # parity gate at w = 0 / knee-free targets: fused statistic
            # == split statistic (the split path uses the rowwise
            # oracle draws, so MC agreement IS draw parity at the
            # statistic level, flip-free by construction — see ys_gate)
            want, got = gate_fn()
            names = (("margin", "gamma", "omega", "b", "S") if svr
                     else ("margin", "gamma", "b", "S"))
            for a, b_, part in zip(got, want, names):
                err = np.abs(a - b_).max() / max(1.0, np.abs(b_).max())
                if err > 2e-3:
                    failures.append(
                        f"K={k} {combo} {part} parity {err:.2e}")
            secs = {"split": _time_best(split_fn),
                    "fused": _time_best(fused_fn)}
            roof = _roofline(n, k)
            sp, fu = roof["split"], roof["fused"]
            mem_ratio = sp["memory_s"] / fu["memory_s"]
            if mem_ratio < 2.0:
                failures.append(
                    f"K={k} {combo}: roofline memory ratio {mem_ratio:.2f}"
                    " < 2")
            if secs["fused"] >= secs["split"]:
                failures.append(
                    f"K={k} {combo}: fused measured {secs['fused']:.4f}s"
                    f" not below split {secs['split']:.4f}s")
            rows.append({
                "name": f"statistic_{combo}_K{k}", "n": n, "k": k,
                "combo": combo, "backend": backend,
                "seconds_split": secs["split"],
                "seconds_fused": secs["fused"],
                "measured_ratio_fused_over_split": round(
                    secs["fused"] / secs["split"], 4),
                "x_streams": {"split": 3, "fused": 1},
                "roofline": {kk: {p: round(q, 9) if p != "x_streams"
                                  else q for p, q in vv.items()}
                             for kk, vv in roof.items()},
                "roofline_memory_speedup": round(mem_ratio, 3),
                "roofline_bound_speedup": round(
                    sp["bound_s"] / fu["bound_s"], 3),
            })
    return rows


def _bitwise_draw_row(n: int, k: int, failures: list) -> dict:
    """Acceptance gate: the fused dispatch path's MC draws are BITWISE
    the rowwise / split-key oracle's (ref backend — the production CPU
    route; the in-kernel transform is flip-free-close, see
    tests/test_mc_fused.py)."""
    rng = np.random.default_rng(3)
    X = jnp.asarray(rng.normal(size=(n, k)).astype(np.float32))
    y = jnp.asarray(rng.choice([-1.0, 1.0], n).astype(np.float32))
    ys = jnp.asarray(np.asarray(X) @ rng.normal(size=k).astype(np.float32))
    w = jnp.asarray(rng.normal(size=k).astype(np.float32))
    key = jax.random.PRNGKey(9)
    eps, eps_ins, row0 = 1e-6, 0.2, 17

    margin = X @ w
    g_want = augment.gamma_mc_rowwise(key, y - margin, eps, row0)
    noise = augment.draw_ig_noise(key, n, row0)
    out = ops.fused_stats(X, y, y, w, None, noise, epilogue="mc_hinge",
                          eps=eps, backend="ref")
    cls_ok = bool(np.array_equal(np.asarray(out[1]), np.asarray(g_want)))

    k_lo, k_hi = jax.random.split(key)
    res = ys - margin
    gs = augment.gamma_mc_rowwise(k_lo, res - eps_ins, eps, row0)
    osb = augment.gamma_mc_rowwise(k_hi, res + eps_ins, eps, row0)
    n4 = (*augment.draw_ig_noise(k_lo, n, row0),
          *augment.draw_ig_noise(k_hi, n, row0))
    out = ops.fused_stats(X, ys, jnp.zeros((n,), jnp.float32), w, None,
                          n4, epilogue="mc_svr", eps=eps,
                          eps_ins=eps_ins, backend="ref")
    svr_ok = bool(np.array_equal(np.asarray(out[1]), np.asarray(gs))
                  and np.array_equal(np.asarray(out[2]), np.asarray(osb)))
    if not cls_ok:
        failures.append("MC-CLS fused draws not bitwise vs oracle")
    if not svr_ok:
        failures.append("MC-SVR fused draws not bitwise vs split-key "
                        "oracle")
    return {"name": "bitwise_draw_parity", "n": n, "k": k,
            "cls_bitwise": cls_ok, "svr_bitwise": svr_ok}


def _em_svr_fit_row(n: int, k: int, failures: list) -> dict:
    """Acceptance gate: EM-SVR whole-fit parity <= 1e-4 across the
    loop / scan / stream drivers and a hand-rolled pre-fusion
    split-statistic fit (margin pass + b pass + SYRK pass)."""
    rng = np.random.default_rng(4)
    X = rng.normal(size=(n, k)).astype(np.float32)
    y = (X @ rng.normal(size=k)).astype(np.float32)
    kw = dict(task="SVR", eps=1e-2, eps_ins=0.3, max_iters=20,
              min_iters=20)
    fits = {}
    secs = {}
    for driver in ("loop", "scan", "stream"):
        cfg = SVMConfig(driver=driver, chunk_rows=max(64, n // 8), **kw)
        model = PEMSVM(cfg)
        t0 = time.perf_counter()
        fits[driver] = model.fit(X, y).weights
        secs[driver] = time.perf_counter() - t0

    # pre-fusion split-statistic oracle fit (bias feature appended, the
    # solver's LIN convention)
    Xb = jnp.asarray(np.concatenate(
        [X, np.ones((n, 1), np.float32)], 1))
    yd = jnp.asarray(y)
    w = jnp.zeros((k + 1,), jnp.float32)
    for _ in range(20):
        pred = Xb @ w
        res = yd - pred
        gamma = jnp.maximum(jnp.abs(res - 0.3), 1e-2)
        omega = jnp.maximum(jnp.abs(res + 0.3), 1e-2)
        S = ops.syrk_tri(Xb, 1.0 / gamma + 1.0 / omega, backend="ref")
        b = Xb.T @ ((yd - 0.3) / gamma + (yd + 0.3) / omega)
        _, w = stats.posterior_params(S, b, 1.0, jitter=1e-7)
    fits["split_oracle"] = np.asarray(w)

    ref_w = fits["loop"]
    rels = {}
    for name, wgt in fits.items():
        rel = float(np.abs(wgt - ref_w).max() / np.abs(ref_w).max())
        rels[name] = rel
        if rel > 1e-4:
            failures.append(f"EM-SVR {name} vs loop rel {rel:.2e} > 1e-4")
    return {"name": "em_svr_whole_fit_parity", "n": n, "k": k,
            "iters": 20, "rel_err_vs_loop": rels,
            "seconds": secs["scan"], "seconds_by_driver": secs}


def run(full: bool = False, backend: str | None = None):
    # Statistic-level comparison runs the REAL kernel body (interpret
    # off TPU) so grid structure and launch counts are exercised; the
    # draw/fit gates use the dispatch default (ref -> XLA on CPU).
    kernel_backend = backend or (
        "pallas" if jax.default_backend() == "tpu" else "interpret")
    n = 16384 if full else 2048
    failures: list[str] = []
    rows = _statistic_rows(n, (256, 512, 1024), kernel_backend, failures)
    rows.append(_bitwise_draw_row(1024, 32, failures))
    rows.append(_em_svr_fit_row(1024 if not full else 8192, 16, failures))
    emit(rows, "mc_fused")
    append_json(rows, BENCH_JSON)
    assert not failures, "; ".join(failures)
    return rows


if __name__ == "__main__":
    run()
