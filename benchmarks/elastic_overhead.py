"""Checkpointing tax on the fit hot path (ISSUE 6 acceptance
benchmark) -> ``BENCH_elastic.json``.

The reliability pitch only holds if snapshots are ~free: a resume
point is O(K^2) statistics plus scalars (never O(N) data), and saves
are committed by a background writer thread overlapped with the next
iteration's device work. This benchmark measures that claim:

  * fit wall-clock vs the same fit with no fault policy, stream and
    loop drivers, at two cadences: ``ckpt_every=3`` (a production-ish
    cadence; the <= 5% GATE, asserted with a noise allowance for
    shared CI machines) and ``ckpt_every=1`` (a snapshot EVERY
    iteration — the recorded stress row; the residual cost there is
    the writer thread competing for cores, not hot-path blocking);
  * resume latency — restore + first-iteration cost when continuing a
    killed fit, the downtime a preemption actually costs.
"""
from __future__ import annotations

import os
import tempfile

import numpy as np

from repro.core import PEMSVM, SVMConfig
from repro.runtime import faults
from repro.runtime.policy import FaultPolicy

from .common import append_json, emit, time_fit

BENCH_JSON = os.environ.get("BENCH_ELASTIC_JSON", "BENCH_elastic.json")

# Generous on CI: the gate documents the contract, the JSON history
# tracks the real number. Local/quiet-machine runs sit well under 5%.
OVERHEAD_GATE = float(os.environ.get("ELASTIC_OVERHEAD_GATE", "0.05"))
NOISE_ALLOWANCE = 0.05          # shared-runner wall-clock jitter


def _data(full: bool):
    # The snapshot cost is FIXED (~ms: one host sync + an async O(K^2)
    # write) while the iteration cost scales with N*K^2 — the gate is
    # only meaningful where an iteration is not itself ~ms-sized, so
    # the default stays large enough for device work to dominate.
    n, k = (200_000, 128) if full else (65_536, 96)
    rng = np.random.default_rng(0)
    X = rng.normal(size=(n, k)).astype(np.float32)
    y = np.where(X @ rng.normal(size=k) > 0, 1.0, -1.0)
    return X, y


def run(full: bool = False) -> None:
    X, y = _data(full)
    iters = 12
    rows = []
    worst = 0.0

    for name, extra in (
            ("stream", dict(driver="stream", chunk_rows=2048)),
            ("loop", dict(driver="loop"))):
        kw = dict(algorithm="EM", eps=1e-2, max_iters=iters,
                  min_iters=iters, **extra)
        base_svm = PEMSVM(SVMConfig(**kw))
        _, warm = time_fit(base_svm.fit, X, y)          # compile
        _, base = time_fit(base_svm.fit, X, y, repeats=3)

        for every, gated in ((3, True), (1, False)):
            with tempfile.TemporaryDirectory() as d:
                pol = FaultPolicy(ckpt_dir=d, ckpt_every=every, keep_k=2)
                svm = PEMSVM(SVMConfig(**kw, fault=pol))
                _, ckpt = time_fit(svm.fit, X, y, repeats=3)

                # resume latency: kill mid-fit, time the
                # restore-and-finish run — the downtime a preemption
                # actually costs
                try:
                    svm.fit(X, y, fault_hook=faults.kill_at_iteration(
                        iters // 2))
                except faults.SimulatedPreemption:
                    pass
                res, resumed = time_fit(
                    PEMSVM(SVMConfig(**kw, fault=pol)).fit, X, y,
                    resume_from=d)

            overhead = ckpt / base - 1.0
            if gated:
                worst = max(worst, overhead)
            rows.append({
                "name": f"{name}_ckpt_every_{every}",
                "seconds": ckpt,
                "base_seconds": round(base, 4),
                "overhead_pct": round(100 * overhead, 2),
                "gated": gated,
                "resume_seconds": round(resumed, 4),
                "resumed_at": res.resumed_at,
                "n_iters": iters,
                "n": X.shape[0],
            })

    emit(rows, "elastic_overhead")
    append_json(rows, BENCH_JSON)
    assert worst <= OVERHEAD_GATE + NOISE_ALLOWANCE, (
        f"per-iteration checkpointing cost {100 * worst:.1f}% "
        f"(gate {100 * OVERHEAD_GATE:.0f}% + "
        f"{100 * NOISE_ALLOWANCE:.0f}% noise allowance) — the async "
        "writer is blocking the hot path")


if __name__ == "__main__":
    run()
