"""Paper Fig. 3/4: single-threaded training time vs N (linear) and vs K
(quadratic) on alpha-shaped data. Fits the scaling exponents and reports
them — the paper's claims are slope 1 in N and slope 2 in K."""
from __future__ import annotations

import time

import numpy as np

from repro.core import PEMSVM, SVMConfig
from repro.data import make_alpha_like

from .common import emit


def _iter_time(n, k, iters=6):
    X, y = make_alpha_like(n, k)
    svm = PEMSVM(SVMConfig(lam=1.0, max_iters=iters, min_iters=iters,
                           tol=0.0))
    t0 = time.time()
    svm.fit(X, y)
    return (time.time() - t0) / iters


def _form_fit(xs, ts, power):
    """Fit t = a + b * x^power (a = fixed dispatch overhead on this
    1-core host); return (a, b, R^2) — the paper's claims are about the
    *asymptotic* term, so the fit quality of the predicted functional
    form is the verdict."""
    X = np.stack([np.ones_like(xs, dtype=float),
                  np.asarray(xs, float) ** power], 1)
    coef, *_ = np.linalg.lstsq(X, np.asarray(ts), rcond=None)
    pred = X @ coef
    ss_res = float(np.sum((ts - pred) ** 2))
    ss_tot = float(np.sum((ts - np.mean(ts)) ** 2))
    return coef[0], coef[1], 1.0 - ss_res / max(ss_tot, 1e-12)


def run(full: bool = False):
    rows = []
    ns = [32_000, 64_000, 128_000, 256_000]
    ts = np.array([_iter_time(n, 200) for n in ns])
    for n, t in zip(ns, ts):
        rows.append({"name": f"fig3_N={n}", "seconds": float(t)})
    a, b, r2 = _form_fit(ns, ts, 1.0)          # paper: linear in N
    rows.append({"name": "fig3_linear_in_N_fit", "seconds": 0.0,
                 "overhead_s": round(float(a), 3), "r2": round(r2, 4)})

    ks = [128, 256, 512, 1024]
    ts = np.array([_iter_time(10_000, k) for k in ks])
    for k, t in zip(ks, ts):
        rows.append({"name": f"fig4_K={k}", "seconds": float(t)})
    a, b, r2 = _form_fit(ks, ts, 2.0)          # paper: quadratic in K
    rows.append({"name": "fig4_quadratic_in_K_fit", "seconds": 0.0,
                 "overhead_s": round(float(a), 3), "r2": round(r2, 4)})
    emit(rows, "fig34_scaling")
    return rows
