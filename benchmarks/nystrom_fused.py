"""Fused Nystrom featurize-and-accumulate vs the two materializing
baselines (ISSUE 3 acceptance benchmark) -> ``BENCH_nystrom.json``.

Three ways to produce one phi-space EM statistic (margin, gamma, b, S):

  * host_phi    — float64 NumPy featurization materializes the (N, m)
                  phi ONCE per fit; every iteration then streams phi.
                  The pre-PR-3 path: accurate, but phi must be resident
                  (no out-of-core) and the host does O(N m) f64 work.
  * device_phi  — featurize on device (``ops.nystrom_phi``), write phi
                  to HBM, re-read it through ``fused_stats``: 2 kernel
                  launches and a 2·N·M-byte phi round-trip per
                  iteration.
  * fused       — ``ops.nystrom_fused_stats``: one launch, one X
                  stream, phi lives only in VMEM.

Per (N, D, m) the benchmark records measured wall-clock for all three
AND the analytic v5e roofline bound (same constants as
``benchmarks/roofline.py``): fused and device_phi run identical FLOPs,
so the fused win is pure HBM traffic — visible in the roofline terms on
any host, and in wall-clock only where HBM is the actual bottleneck
(the TPU backend; the CPU interpreter copies arrays in cache). The
roofline advantage is asserted at every m; the wall-clock advantage is
asserted on TPU only.

Gates (asserted, any backend):
  * fused ≡ device_phi ≡ host_phi statistic parity at every m;
  * out-of-core acceptance: ``NystromSVM(driver="stream")`` fit from a
    libsvm FILE matches the host-phi resident baseline to <= 1e-4
    weight rel-err (EM) with device input residency bounded by
    (prefetch + 2) RAW D-wide chunks — m-independent and far below the
    (N, m) phi residency every baseline pays.
"""
from __future__ import annotations

import os
import tempfile
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import NystromSVM, PEMSVM, SVMConfig
from repro.core.nystrom import nystrom_features
from repro.data import save_libsvm
from repro.kernels import ops

from .common import append_json, emit

BENCH_JSON = os.environ.get("BENCH_NYSTROM_JSON", "BENCH_nystrom.json")

PEAK_FLOPS = 197e12     # v5e, matches benchmarks/roofline.py
HBM_BW = 819e9


def _roofline(n: int, d: int, m: int) -> dict[str, dict[str, float]]:
    """Analytic per-iteration roofline terms for the three paths.

    FLOPs (identical featurize+stats math): cross 2NmD + project 2NmM
    + margin/b 4NM + Sigma 2NM^2, M = m + 1. Bytes: every path streams
    its input once; device_phi adds the 2NM phi round-trip; host_phi
    streams the resident phi (no featurize FLOPs on device, but phi
    must exist — its residency is reported separately).

    fused and device_phi run IDENTICAL FLOPs, so the fusion win is pure
    HBM traffic: memory_s is strictly smaller at every m, and bound_s
    strictly smaller wherever device_phi is memory-bound (m up to
    ~M/2 = ridge-point FLOP/byte on v5e; above that both paths sit on
    the compute roof and the fusion buys launch count + phi residency,
    not bound time — DESIGN.md §Perf/Nystrom)."""
    M = m + 1
    feat_flops = 2.0 * n * m * d + 2.0 * n * m * M
    stat_flops = 4.0 * n * M + 2.0 * n * M * M
    x_bytes = 4.0 * n * d
    phi_bytes = 4.0 * n * M
    small = 4.0 * (m * d + m * M + 2 * n + M + M * M)
    out = {}
    for name, (flops, byts) in {
        "fused": (feat_flops + stat_flops, x_bytes + small),
        "device_phi": (feat_flops + stat_flops,
                       x_bytes + 2 * phi_bytes + small),
        "host_phi": (stat_flops, phi_bytes + small),
    }.items():
        compute_s, memory_s = flops / PEAK_FLOPS, byts / HBM_BW
        out[name] = {"compute_s": compute_s, "memory_s": memory_s,
                     "bound_s": max(compute_s, memory_s)}
    return out


def _time_best(fn, repeats: int = 3) -> float:
    fn()                                    # warm the jit caches
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _statistic_rows(n: int, d: int, ms, backend: str | None,
                    failures: list) -> list[dict]:
    rng = np.random.default_rng(0)
    X = rng.normal(size=(n, d)).astype(np.float32)
    y = rng.choice([-1.0, 1.0], n).astype(np.float32)
    Xd, yd = jnp.asarray(X), jnp.asarray(y)
    mask = jnp.ones(n, jnp.float32)
    rows = []
    for m in ms:
        L = jnp.asarray(X[rng.choice(n, m, replace=False)])
        proj = jnp.asarray(
            (0.1 * rng.normal(size=(m, m))).astype(np.float32))
        wv = jnp.asarray(rng.normal(size=m + 1).astype(np.float32))
        kw = dict(sigma=2.0, kind="rbf", add_bias=True, eps=1e-2)

        def fused():
            return [np.asarray(o) for o in ops.nystrom_fused_stats(
                Xd, L, proj, yd, yd, wv, mask, backend=backend, **kw)]

        def device_phi():
            phi = ops.nystrom_phi(Xd, L, proj, mask, sigma=2.0,
                                  add_bias=True, backend=backend)
            return [np.asarray(o) for o in ops.fused_stats(
                phi, yd, yd, wv, mask, eps=1e-2, backend=backend)]

        # host_phi featurizes ONCE per fit (f64, outside the per-
        # iteration timing) and then streams the resident phi through
        # the statistic every iteration — time only the recurring part,
        # matching the roofline leg; the one-time cost is recorded.
        t0 = time.perf_counter()
        phi_host = jnp.asarray(np.concatenate(
            [nystrom_features(X, np.asarray(L), sigma=2.0),
             np.ones((n, 1), np.float32)], 1))
        host_featurize_s = time.perf_counter() - t0

        def host_phi():
            return [np.asarray(o) for o in ops.fused_stats(
                phi_host, yd, yd, wv, mask, eps=1e-2, backend=backend)]

        # accuracy parity gate: all three produce the same statistic
        # (host_phi featurizes in f64 with its own projection, so it is
        # checked at fit level in the out-of-core section instead)
        ref_out = fused()
        for name, fn in (("device_phi", device_phi),):
            for a, b, part in zip(fn(), ref_out,
                                  ("margin", "gamma", "b", "S")):
                err = (np.abs(a - b).max()
                       / max(1.0, np.abs(b).max()))
                if err > 2e-3:
                    failures.append(
                        f"m={m} {name} {part} parity {err:.2e}")

        secs = {"fused": _time_best(fused),
                "device_phi": _time_best(device_phi),
                "host_phi": _time_best(host_phi)}
        roof = _roofline(n, d, m)
        # The fusion's claim is structural: identical FLOPs, strictly
        # fewer HBM bytes. Asserted per the roofline: memory time
        # strictly drops at EVERY m; the bound strictly drops wherever
        # device_phi is memory-bound; never rises.
        f, dp = roof["fused"], roof["device_phi"]
        if not f["memory_s"] < dp["memory_s"]:
            failures.append(f"m={m}: fused memory_s not below device_phi")
        if f["bound_s"] > dp["bound_s"]:
            failures.append(f"m={m}: fused bound_s above device_phi")
        if (dp["memory_s"] > dp["compute_s"]
                and not f["bound_s"] < dp["bound_s"]):
            failures.append(
                f"m={m}: memory-bound but fused bound_s not below")
        if jax.default_backend() == "tpu" and (
                secs["fused"] >= secs["device_phi"]):
            failures.append(
                f"m={m}: fused measured {secs['fused']:.4f}s not below "
                f"device_phi {secs['device_phi']:.4f}s on TPU")
        rows.append({
            "name": f"statistic_m{m}", "n": n, "d": d, "m": m,
            "backend": backend or ops.default_backend(),
            "seconds_fused": secs["fused"],
            "seconds_device_phi": secs["device_phi"],
            "seconds_host_phi": secs["host_phi"],
            "host_phi_onetime_featurize_s": host_featurize_s,
            "measured_speedup_vs_device_phi": round(
                secs["device_phi"] / secs["fused"], 3),
            "roofline": {k: {kk: round(vv, 9) for kk, vv in v.items()}
                         for k, v in roof.items()},
            "roofline_memory_speedup_vs_device_phi": round(
                dp["memory_s"] / f["memory_s"], 3),
            "roofline_bound_speedup_vs_device_phi": round(
                dp["bound_s"] / f["bound_s"], 3),
            "kernel_launches": {"fused": 1, "device_phi": 2},
            "phi_roundtrip_bytes_saved": int(8.0 * n * (m + 1)),
        })
    return rows


def _out_of_core_row(n: int, d: int, m: int, chunk_rows: int,
                     prefetch: int, failures: list) -> dict:
    rng = np.random.default_rng(1)
    X = rng.normal(size=(n, d)).astype(np.float32)
    wt = rng.normal(size=d)
    y = np.where(np.tanh(X @ wt) + 0.3 * rng.normal(size=n) > 0,
                 1.0, -1.0).astype(np.float32)
    kw = dict(formulation="KRN", lam=1.0, sigma=3.0, eps=1e-2,
              max_iters=15, min_iters=15)

    with tempfile.TemporaryDirectory() as tmp:
        path = os.path.join(tmp, "bench.libsvm")
        save_libsvm(path, X, y)
        ny = NystromSVM(SVMConfig(driver="stream", chunk_rows=chunk_rows,
                                  prefetch=prefetch, **kw), n_landmarks=m)
        t0 = time.perf_counter()
        r_stream = ny.fit_libsvm(path, n_features=d)
        t_stream = time.perf_counter() - t0

    # host-phi resident baseline on the SAME landmarks (f64 featurize)
    t0 = time.perf_counter()
    phi = nystrom_features(X, ny._landmarks, sigma=3.0)
    import dataclasses
    base = PEMSVM(dataclasses.replace(ny.svm.config, phi_spec=None,
                                      add_bias=True, driver="scan"))
    r_host = base.fit(phi, y)
    t_host = time.perf_counter() - t0

    rel = float(np.abs(r_stream.weights - r_host.weights).max()
                / np.abs(r_host.weights).max())
    raw_chunk_bytes = chunk_rows * d * 4 + 2 * chunk_rows * 4
    bound = (prefetch + 2) * raw_chunk_bytes
    phi_resident_bytes = n * (m + 1) * 4
    parity_ok = bool(rel <= 1e-4)
    residency_ok = (0 < r_stream.peak_input_bytes <= bound
                    and r_stream.peak_input_bytes < phi_resident_bytes)
    if not parity_ok:
        failures.append(f"stream-vs-host-phi rel {rel:.2e} > 1e-4")
    if not residency_ok:
        failures.append(
            f"peak {r_stream.peak_input_bytes} outside (0, {bound}] "
            f"or >= phi residency {phi_resident_bytes}")
    return {
        "name": "stream_fit_libsvm", "n": n, "d": d, "m": m,
        "chunk_rows": chunk_rows, "prefetch": prefetch,
        "iters": 15, "seconds": t_stream,
        "host_phi_resident_seconds": t_host,
        "weights_rel_err": rel, "parity_ok": parity_ok,
        "peak_input_bytes": int(r_stream.peak_input_bytes),
        "peak_bound_bytes": bound,
        "phi_resident_bytes": phi_resident_bytes,
        "peak_over_phi_resident": round(
            r_stream.peak_input_bytes / phi_resident_bytes, 4),
        "residency_ok": residency_ok,
    }


def run(full: bool = False, backend: str | None = None):
    # Kernel-level comparison runs the REAL kernel body (interpret off
    # TPU) so grid structure and launch counts are exercised; the fit
    # gate uses the default backend (ref -> XLA on CPU hosts).
    kernel_backend = backend or (
        "pallas" if jax.default_backend() == "tpu" else "interpret")
    n, d = (16384, 128) if full else (2048, 64)
    failures: list[str] = []
    rows = _statistic_rows(n, d, (256, 512, 1024), kernel_backend,
                           failures)
    rows.append(_out_of_core_row(8192 if full else 4096, 24, 64,
                                 chunk_rows=256, prefetch=2,
                                 failures=failures))
    emit(rows, "nystrom_fused")
    append_json(rows, BENCH_JSON)
    assert not failures, "; ".join(failures)
    return rows


if __name__ == "__main__":
    run()
