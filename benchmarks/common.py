"""Shared benchmark utilities: timing, CSV emission, JSON history
append, scaled-down dataset sizes (full paper sizes via --full;
CPU-friendly defaults otherwise)."""
from __future__ import annotations

import json
import os
import time


def time_fit(fn, *args, repeats: int = 1, **kw):
    """Returns (result_of_last_call, best_seconds)."""
    best = float("inf")
    out = None
    for _ in range(repeats):
        t0 = time.time()
        out = fn(*args, **kw)
        best = min(best, time.time() - t0)
    return out, best


def emit(rows: list[dict], name: str):
    """Print `name,us_per_call,derived` CSV rows per the harness contract,
    then a human-readable table."""
    for r in rows:
        us = r.get("us_per_call", r.get("seconds", 0.0) * 1e6)
        derived = ";".join(f"{k}={v}" for k, v in r.items()
                           if k not in ("name", "us_per_call", "seconds"))
        print(f"{name}/{r['name']},{us:.1f},{derived}")


def append_json(rows: list[dict], path: str):
    """Append one timestamped record to a cross-PR benchmark history
    file (a JSON list; unreadable/corrupt histories restart empty)."""
    import jax

    payload = []
    if os.path.exists(path):
        try:
            with open(path) as f:
                payload = json.load(f)
        except (json.JSONDecodeError, OSError):
            payload = []
    payload.append({"timestamp": time.time(),
                    "jax_backend": jax.default_backend(), "rows": rows})
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
