"""Paper Table 7: KRN-EM-CLS on an N=1800 subset (news20 protocol, C=1).

The synthetic stand-in is a radially-structured problem where the linear
formulation fails — demonstrating the kernel extension's accuracy, with
training time independent of K (paper Sec 4.3)."""
from __future__ import annotations

import numpy as np

from repro.core import PEMSVM, SVMConfig, lam_from_C
from repro.core.nystrom import NystromSVM
from repro.data import make_circles

from .common import emit, time_fit


def run(n: int = 1800, full: bool = False):
    X, y = make_circles(n)
    rows = []

    krn = PEMSVM(SVMConfig.from_options(
        "KRN-EM-CLS", lam=lam_from_C(1.0), sigma=0.7, max_iters=60))
    res, secs = time_fit(krn.fit, X, y)
    rows.append({"name": "KRN-EM-CLS", "seconds": secs,
                 "acc": round(krn.score(X, y), 4), "iters": res.n_iters})

    krn_mc = PEMSVM(SVMConfig.from_options(
        "KRN-MC-CLS", lam=lam_from_C(1.0), sigma=0.7, max_iters=60))
    _, secs = time_fit(krn_mc.fit, X, y)
    rows.append({"name": "KRN-MC-CLS", "seconds": secs,
                 "acc": round(krn_mc.score(X, y), 4)})

    lin = PEMSVM(SVMConfig(lam=lam_from_C(1.0), max_iters=60))
    _, secs = time_fit(lin.fit, X, y)
    rows.append({"name": "LIN-EM-CLS(control)", "seconds": secs,
                 "acc": round(lin.score(X, y), 4)})

    # Beyond-paper: the paper's own open question (Sec 4.3) — PSVM-style
    # sqrt(N) Nystrom approximation composed with the sampling SVM. Run
    # at 5x the exact-KRN N to show the cubic-in-N blocker is gone.
    Xb, yb = make_circles(5 * n)
    nys = NystromSVM(SVMConfig.from_options(
        "KRN-EM-CLS", lam=lam_from_C(1.0), sigma=0.7, max_iters=60))
    _, secs = time_fit(nys.fit, Xb, yb)
    rows.append({"name": f"KRN-EM-CLS+nystrom(N={5*n})", "seconds": secs,
                 "acc": round(nys.score(Xb, yb), 4)})

    emit(rows, "table7_krn")
    return rows
