"""Paper Table 8: Crammer-Singer on mnist8m (C=0.04, LIN-MC-MLT — the
paper's own pick: 'For the Crammer and Singer implementation, MC converged
much faster than EM'). Baseline: one-vs-rest DCD (LL-CS stand-in)."""
from __future__ import annotations

import numpy as np

from repro.baselines import DCDSVM
from repro.core import PEMSVM, SVMConfig, lam_from_C
from repro.data import make_mnist8m_like

from .common import emit, time_fit


def run(n: int = 20_000, k: int = 196, m: int = 10, full: bool = False):
    if full:
        n, k = 200_000, 784
    X, labels = make_mnist8m_like(n, k, m)
    n_te = n // 5
    Xte, lte = X[-n_te:], labels[-n_te:]
    Xtr, ltr = X[:-n_te], labels[:-n_te]

    rows = []
    svm = PEMSVM(SVMConfig.from_options(
        "LIN-MC-MLT", num_classes=m, lam=lam_from_C(0.04), max_iters=40,
        min_iters=25, burnin=8))
    res, secs = time_fit(svm.fit, Xtr, ltr)
    rows.append({"name": "LIN-MC-MLT", "seconds": secs,
                 "acc": round(svm.score(Xte, lte), 4), "iters": res.n_iters})

    t0 = __import__("time").time()
    preds = []
    for c in range(m):
        yc = np.where(ltr == c, 1.0, -1.0)
        d = DCDSVM(C=0.04, n_epochs=3).fit(Xtr, yc)
        preds.append(d.decision_function(Xte))
    secs = __import__("time").time() - t0
    acc = float(np.mean(np.argmax(np.stack(preds, 1), 1) == lte))
    rows.append({"name": "OvR-DCD", "seconds": secs, "acc": round(acc, 4)})

    emit(rows, "table8_mlt")
    return rows
