"""Paper Fig. 5/6: convergence of the objective and test accuracy for EM
vs MC on the dna subset (C=1e-5). Validates the paper's claims:
  * EM converges within 40-60 iterations,
  * MC's averaged-sample objective decreases smoothly and its final
    accuracy is competitive (paper: slightly higher after 100 iters)."""
from __future__ import annotations

import numpy as np

from repro.core import PEMSVM, SVMConfig, lam_from_C
from repro.data import make_dna_like

from .common import emit


def run(n: int = 40_000, k: int = 400, iters: int = 100,
        full: bool = False):
    lam = lam_from_C(1e-5) * n / 2_500_000   # N-scaled paper C (table5)
    X, y = make_dna_like(n, k)
    n_te = n // 5
    Xte, yte = X[-n_te:], y[-n_te:]
    Xtr, ytr = X[:-n_te], y[:-n_te]
    rows = []
    curves = {}
    for algo in ["EM", "MC"]:
        svm = PEMSVM(SVMConfig(algorithm=algo, lam=lam,
                               max_iters=iters, tol=1e-3, burnin=10))
        res = svm.fit(Xtr, ytr)
        objs = np.asarray(res.objective)
        # iterations until the paper's stopping rule is met
        diffs = np.abs(np.diff(objs))
        conv = int(np.argmax(diffs <= 1e-3 * len(Xtr)) + 1) \
            if (diffs <= 1e-3 * len(Xtr)).any() else iters
        curves[algo] = objs
        rows.append({"name": f"{algo}", "seconds": 0.0,
                     "iters_run": res.n_iters,
                     "iters_to_converge": conv,
                     "final_obj": round(float(objs[-1]), 1),
                     "test_acc": round(svm.score(Xte, yte), 4)})
    emit(rows, "fig56_convergence")
    # dump curves for plotting / EXPERIMENTS.md
    for algo, objs in curves.items():
        sampled = {i: round(float(objs[i]), 1)
                   for i in range(0, len(objs), max(1, len(objs) // 10))}
        print(f"curve,{algo},{sampled}")
    return rows
