"""Fleet-controller tax and recovery latency (ISSUE 8 acceptance
benchmark) -> ``BENCH_fleet.json``.

The supervisor must be ~free when nothing fails, and cheap to invoke
when something does:

  * controller overhead — the same undisturbed fit run bare vs under
    :class:`FleetController` (supervision thread polling the shared
    checkpoint directory for progress). Gated at <= 5% (+ a noise
    allowance for shared CI machines): the monitor only ever lists a
    directory, so the hot path must not feel it;
  * recovery latency — a SIGKILL-style preemption mid-fit, then the
    relaunch: time from the relaunch's start to its FIRST checkpoint
    commit (``AttemptRecord.first_commit_s`` — restore + re-warm +
    one checkpoint cadence, the span during which a second failure
    would lose ground), plus the end-to-end disturbed wall clock.
    Gated by an absolute ceiling (env-tunable for slower runners).
"""
from __future__ import annotations

import os
import shutil
import tempfile
import time

import numpy as np

from repro.core import PEMSVM, SVMConfig
from repro.runtime import faults
from repro.runtime.controller import FleetController, FleetPolicy
from repro.runtime.faults import FleetSchedule
from repro.runtime.policy import FaultPolicy

from .common import append_json, emit

BENCH_JSON = os.environ.get("BENCH_FLEET_JSON", "BENCH_fleet.json")

# Generous on CI: the gate documents the contract, the JSON history
# tracks the real number.
OVERHEAD_GATE = float(os.environ.get("FLEET_OVERHEAD_GATE", "0.05"))
NOISE_ALLOWANCE = 0.05          # shared-runner wall-clock jitter
RECOVERY_GATE_S = float(os.environ.get("FLEET_RECOVERY_GATE_S", "30"))


def _data(full: bool):
    # Iterations must dominate the supervisor's directory polls for the
    # overhead gate to measure the controller rather than the noise.
    n, k = (200_000, 128) if full else (65_536, 64)
    rng = np.random.default_rng(0)
    X = rng.normal(size=(n, k)).astype(np.float32)
    y = np.where(X @ rng.normal(size=k) > 0, 1.0, -1.0)
    return X, y


def _best_of(fn, reps: int = 3, reset=None):
    """Best-of-N with a per-rep reset (clearing the checkpoint dir so a
    repeated run never turns into a resume). Best-of also amortizes the
    one-time jit compile out of the measurement."""
    best, out = float("inf"), None
    for _ in range(reps):
        if reset is not None:
            reset()
        t0 = time.time()
        out = fn()
        best = min(best, time.time() - t0)
    return out, best


def run(full: bool = False) -> None:
    X, y = _data(full)
    iters = 12
    kw = dict(algorithm="EM", eps=1e-2, driver="loop", max_iters=iters,
              min_iters=iters)
    rows = []

    with tempfile.TemporaryDirectory() as root:
        d = os.path.join(root, "ckpt")
        pol = FaultPolicy(ckpt_dir=d, ckpt_every=3, keep_k=2)
        cfg = SVMConfig(**kw, fault=pol)

        def reset():
            shutil.rmtree(d, ignore_errors=True)
            os.makedirs(d)

        # --- bare fit (checkpointing on, no supervisor) ---------------
        _, base = _best_of(lambda: PEMSVM(cfg).fit(X, y), reset=reset)

        # --- the same fit under the controller, nothing failing -------
        def make_host(level):
            def host(ctx):
                return PEMSVM(cfg).fit(X, y, resume_from=ctx.resume_from,
                                       fault_hook=ctx.fault_hook)
            return host

        def fleet_fit():
            return FleetController(
                make_host, d,
                policy=FleetPolicy(max_attempts=3, poll_s=0.02)).run()

        fr, ctl = _best_of(fleet_fit, reset=reset)
        assert fr.n_relaunches == 0 and not fr.recovered
        overhead = ctl / base - 1.0
        rows.append({
            "name": "controller_overhead",
            "seconds": ctl,
            "base_seconds": round(base, 4),
            "overhead_pct": round(100 * overhead, 2),
            "gated": True,
            "n_iters": iters,
            "n": X.shape[0],
        })

        # --- disturbed run: SIGKILL mid-fit, supervised relaunch ------
        reset()
        t0 = time.time()
        fr = FleetController(
            make_host, d,
            policy=FleetPolicy(max_attempts=3, backoff_s=1e-3,
                               poll_s=0.02),
            schedule=FleetSchedule({
                0: lambda cancel: faults.kill_at_iteration(iters // 2),
            })).run()
        disturbed = time.time() - t0
        relaunch = fr.attempts[1]
        first_commit = relaunch.first_commit_s
        rows.append({
            "name": "recovery_after_kill",
            "seconds": disturbed,
            "base_seconds": round(base, 4),
            "first_commit_s": (None if first_commit is None
                               else round(first_commit, 4)),
            "resumed_at": fr.result.resumed_at,
            "n_relaunches": fr.n_relaunches,
            "disturbed_over_base_pct": round(
                100 * (disturbed / base - 1.0), 2),
            "gated": True,
            "n_iters": iters,
        })
        assert fr.recovered and fr.result.resumed_at is not None
        assert np.isfinite(fr.result.weights).all()

    emit(rows, "fleet_recovery")
    append_json(rows, BENCH_JSON)
    assert overhead <= OVERHEAD_GATE + NOISE_ALLOWANCE, (
        f"fleet supervision cost {100 * overhead:.1f}% on an undisturbed "
        f"fit (gate {100 * OVERHEAD_GATE:.0f}% + "
        f"{100 * NOISE_ALLOWANCE:.0f}% noise allowance) — the progress "
        "monitor is interfering with the hot path")
    assert first_commit is not None and first_commit <= RECOVERY_GATE_S, (
        f"relaunch took {first_commit}s to its first checkpoint commit "
        f"(gate {RECOVERY_GATE_S}s) — restore or re-warm has regressed")


if __name__ == "__main__":
    run()
