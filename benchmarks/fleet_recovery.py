"""Fleet-controller tax and recovery latency (ISSUE 8 acceptance
benchmark) -> ``BENCH_fleet.json``.

The supervisor must be ~free when nothing fails, and cheap to invoke
when something does:

  * controller overhead — the same undisturbed fit run bare vs under
    :class:`FleetController` (supervision thread polling the shared
    checkpoint directory for progress). Gated at <= 5% (+ a noise
    allowance for shared CI machines): the monitor only ever lists a
    directory, so the hot path must not feel it;
  * recovery latency — a SIGKILL-style preemption mid-fit, then the
    relaunch: time from the relaunch's start to its FIRST checkpoint
    commit (``AttemptRecord.first_commit_s`` — restore + re-warm +
    one checkpoint cadence, the span during which a second failure
    would lose ground), plus the end-to-end disturbed wall clock.
    Gated by an absolute ceiling (env-tunable for slower runners);
  * lease takeover (ISSUE 9) — leader A freezes mid-supervision with a
    NON-cooperative zombie worker; standby B's lease expiry takeover
    (term+1) to B's FIRST checkpoint commit is the measured latency
    (ttl wait + resume + re-warm + one cadence). Gated by
    ttl + an absolute ceiling, plus the ZERO-LOST-COMMIT gate: the
    zombie's late commit must be rejected at the rename boundary and
    contribute no committed record after the takeover.
"""
from __future__ import annotations

import os
import shutil
import tempfile
import threading
import time

import numpy as np

from repro.checkpoint import Checkpointer, FencedCommitError
from repro.core import PEMSVM, SVMConfig
from repro.runtime import faults
from repro.runtime.controller import (FleetController, FleetError,
                                      FleetPolicy)
from repro.runtime.faults import FleetSchedule
from repro.runtime.lease import LeasePolicy
from repro.runtime.policy import FaultPolicy

from .common import append_json, emit

BENCH_JSON = os.environ.get("BENCH_FLEET_JSON", "BENCH_fleet.json")

# Generous on CI: the gate documents the contract, the JSON history
# tracks the real number.
OVERHEAD_GATE = float(os.environ.get("FLEET_OVERHEAD_GATE", "0.05"))
NOISE_ALLOWANCE = 0.05          # shared-runner wall-clock jitter
RECOVERY_GATE_S = float(os.environ.get("FLEET_RECOVERY_GATE_S", "30"))
TAKEOVER_GATE_S = float(os.environ.get("FLEET_TAKEOVER_GATE_S", "30"))
LEASE_TTL_S = 1.0               # benchmark election's expiry horizon


def _data(full: bool):
    # Iterations must dominate the supervisor's directory polls for the
    # overhead gate to measure the controller rather than the noise.
    n, k = (200_000, 128) if full else (65_536, 64)
    rng = np.random.default_rng(0)
    X = rng.normal(size=(n, k)).astype(np.float32)
    y = np.where(X @ rng.normal(size=k) > 0, 1.0, -1.0)
    return X, y


def _best_of(fn, reps: int = 3, reset=None):
    """Best-of-N with a per-rep reset (clearing the checkpoint dir so a
    repeated run never turns into a resume). Best-of also amortizes the
    one-time jit compile out of the measurement."""
    best, out = float("inf"), None
    for _ in range(reps):
        if reset is not None:
            reset()
        t0 = time.time()
        out = fn()
        best = min(best, time.time() - t0)
    return out, best


def run(full: bool = False) -> None:
    X, y = _data(full)
    iters = 12
    kw = dict(algorithm="EM", eps=1e-2, driver="loop", max_iters=iters,
              min_iters=iters)
    rows = []

    with tempfile.TemporaryDirectory() as root:
        d = os.path.join(root, "ckpt")
        pol = FaultPolicy(ckpt_dir=d, ckpt_every=3, keep_k=2)
        cfg = SVMConfig(**kw, fault=pol)

        def reset():
            shutil.rmtree(d, ignore_errors=True)
            os.makedirs(d)

        # --- bare fit (checkpointing on, no supervisor) ---------------
        _, base = _best_of(lambda: PEMSVM(cfg).fit(X, y), reset=reset)

        # --- the same fit under the controller, nothing failing -------
        def make_host(level):
            def host(ctx):
                return PEMSVM(cfg).fit(X, y, resume_from=ctx.resume_from,
                                       fault_hook=ctx.fault_hook)
            return host

        def fleet_fit():
            return FleetController(
                make_host, d,
                policy=FleetPolicy(max_attempts=3, poll_s=0.02)).run()

        fr, ctl = _best_of(fleet_fit, reset=reset)
        assert fr.n_relaunches == 0 and not fr.recovered
        overhead = ctl / base - 1.0
        rows.append({
            "name": "controller_overhead",
            "seconds": ctl,
            "base_seconds": round(base, 4),
            "overhead_pct": round(100 * overhead, 2),
            "gated": True,
            "n_iters": iters,
            "n": X.shape[0],
        })

        # --- disturbed run: SIGKILL mid-fit, supervised relaunch ------
        reset()
        t0 = time.time()
        fr = FleetController(
            make_host, d,
            policy=FleetPolicy(max_attempts=3, backoff_s=1e-3,
                               poll_s=0.02),
            schedule=FleetSchedule({
                0: lambda cancel: faults.kill_at_iteration(iters // 2),
            })).run()
        disturbed = time.time() - t0
        relaunch = fr.attempts[1]
        first_commit = relaunch.first_commit_s
        rows.append({
            "name": "recovery_after_kill",
            "seconds": disturbed,
            "base_seconds": round(base, 4),
            "first_commit_s": (None if first_commit is None
                               else round(first_commit, 4)),
            "resumed_at": fr.result.resumed_at,
            "n_relaunches": fr.n_relaunches,
            "disturbed_over_base_pct": round(
                100 * (disturbed / base - 1.0), 2),
            "gated": True,
            "n_iters": iters,
        })
        assert fr.recovered and fr.result.resumed_at is not None
        assert np.isfinite(fr.result.weights).all()

        # --- lease takeover: frozen leader, fenced zombie commit ------
        reset()
        frozen = threading.Event()
        release = threading.Event()
        zombie: dict = {}

        def make_rogue(level):
            def host(ctx):
                # Ignores ctx.fault_hook/cancel: a genuine zombie. Its
                # writer IS epoch-fenced, so the post-takeover commit
                # must die at the rename boundary.
                try:
                    return PEMSVM(cfg).fit(
                        X, y, resume_from=ctx.resume_from,
                        fault_hook=faults.hold_at_iteration(
                            iters // 2, release=release,
                            max_seconds=600.0),
                        epoch=ctx.epoch)
                except Exception as e:  # noqa: BLE001 — recorded
                    zombie["error"] = e
                    raise
            return host

        def make_fenced(level):
            def host(ctx):
                return PEMSVM(cfg).fit(X, y, resume_from=ctx.resume_from,
                                       fault_hook=ctx.fault_hook,
                                       epoch=ctx.epoch)
            return host

        lease = LeasePolicy(ttl_s=LEASE_TTL_S, renew_every_s=0.2,
                            poll_s=0.05)
        A = FleetController(
            make_rogue, d,
            policy=FleetPolicy(max_attempts=2, poll_s=0.02,
                               kill_grace_s=0.3),
            lease=lease, owner="bench-A",
            sleep=faults.freezable_sleep(frozen, max_seconds=600.0))
        B = FleetController(
            make_fenced, d,
            policy=FleetPolicy(max_attempts=2, poll_s=0.02),
            lease=lease, owner="bench-B")
        out: dict = {}

        def run_a():
            try:
                out["A"] = A.run()
            except FleetError as e:     # LeadershipLost expected
                out["A"] = e

        ta = threading.Thread(target=run_a)
        ta.start()
        watcher = Checkpointer(d, keep_k=0)
        hold_step = (iters // 2) * 1_000_000
        deadline = time.time() + 600.0
        while (watcher.latest_record() or (0, 0))[1] < hold_step:
            assert time.time() < deadline, "leader's worker never held"
            time.sleep(0.02)
        t_freeze = time.time()
        frozen.set()                    # the leader goes dark
        tb = threading.Thread(
            target=lambda: out.__setitem__("B", B.run()))
        tb.start()
        while (watcher.latest_record() or (0, 0))[0] < 2:
            assert time.time() < deadline, "takeover never committed"
            time.sleep(0.01)
        takeover_s = time.time() - t_freeze
        tb.join(timeout=600.0)
        fr_b = out["B"]
        records_at_takeover = watcher.all_records()
        release.set()                   # zombie wakes, tries to commit
        while "error" not in zombie:
            assert time.time() < deadline, "zombie never hit the fence"
            time.sleep(0.02)
        lost = [r for r in watcher.all_records()
                if r not in records_at_takeover]
        frozen.clear()                  # deposed leader stands down
        ta.join(timeout=600.0)
        rows.append({
            "name": "lease_takeover",
            "seconds": takeover_s,
            "ttl_s": LEASE_TTL_S,
            "takeover_term": fr_b.term,
            "resumed_at": fr_b.result.resumed_at,
            "first_commit_s": (None if fr_b.attempts[0].first_commit_s
                               is None
                               else round(fr_b.attempts[0].first_commit_s,
                                          4)),
            "fenced_commit_rejected": isinstance(zombie.get("error"),
                                                 FencedCommitError),
            "lost_commits": len(lost),
            "gated": True,
            "n_iters": iters,
        })

    emit(rows, "fleet_recovery")
    append_json(rows, BENCH_JSON)
    assert overhead <= OVERHEAD_GATE + NOISE_ALLOWANCE, (
        f"fleet supervision cost {100 * overhead:.1f}% on an undisturbed "
        f"fit (gate {100 * OVERHEAD_GATE:.0f}% + "
        f"{100 * NOISE_ALLOWANCE:.0f}% noise allowance) — the progress "
        "monitor is interfering with the hot path")
    assert first_commit is not None and first_commit <= RECOVERY_GATE_S, (
        f"relaunch took {first_commit}s to its first checkpoint commit "
        f"(gate {RECOVERY_GATE_S}s) — restore or re-warm has regressed")
    assert takeover_s <= LEASE_TTL_S + TAKEOVER_GATE_S, (
        f"lease takeover to first commit took {takeover_s:.2f}s (gate "
        f"ttl {LEASE_TTL_S}s + {TAKEOVER_GATE_S}s) — election or "
        "resume has regressed")
    assert fr_b.term == 2 and fr_b.result.resumed_at is not None
    assert isinstance(zombie.get("error"), FencedCommitError), (
        f"zombie worker ended with {zombie.get('error')!r} instead of a "
        "fenced commit — the rename-boundary rejection has regressed")
    assert not lost, (
        f"zero-lost-commit gate: {lost} landed after the takeover — a "
        "fenced writer's commit became visible")


if __name__ == "__main__":
    run()
