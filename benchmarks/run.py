"""Benchmark harness: one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV. ``--full`` uses paper-size
datasets (hours on CPU); default sizes finish in minutes."""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default="",
                    help="comma-separated benchmark names")
    args, _ = ap.parse_known_args()

    from . import (elastic_overhead, fig2_cores, fig34_scaling,
                   fig56_convergence, fleet_recovery, kshard_fused,
                   mc_fused, nystrom_fused, rng_fused, roofline,
                   serve_latency, stream_vs_resident, table5_dna,
                   table6_svr, table7_krn, table8_mlt, table9_gram)
    benches = {
        "table5_dna": table5_dna.run,
        "table6_svr": table6_svr.run,
        "table7_krn": table7_krn.run,
        "table8_mlt": table8_mlt.run,
        "table9_gram": table9_gram.run,
        "fig2_cores": fig2_cores.run,
        "fig34_scaling": fig34_scaling.run,
        "fig56_convergence": fig56_convergence.run,
        "roofline": roofline.run,
        "stream_vs_resident": stream_vs_resident.run,
        "nystrom_fused": nystrom_fused.run,
        "mc_fused": mc_fused.run,
        "rng_fused": rng_fused.run,
        "kshard_fused": kshard_fused.run,
        "elastic_overhead": elastic_overhead.run,
        "fleet_recovery": fleet_recovery.run,
        "serve_latency": serve_latency.run,
    }
    only = [x for x in args.only.split(",") if x]
    failed = []
    for name, fn in benches.items():
        if only and name not in only:
            continue
        print(f"# --- {name} ---", flush=True)
        t0 = time.time()
        try:
            fn(full=args.full)
        except Exception:  # noqa: BLE001
            failed.append(name)
            traceback.print_exc()
        print(f"# {name} done in {time.time() - t0:.1f}s", flush=True)
    if failed:
        print(f"# FAILED: {failed}")
        sys.exit(1)


if __name__ == "__main__":
    main()
