"""Out-of-core streaming fit vs resident fit: wall-clock, parity, and
peak device input residency (ISSUE 2 acceptance benchmark).

Compares ``driver="stream"`` (chunked sufficient-statistics
accumulation with a prefetching loader, chunk_rows < N/8) against the
resident ``driver="scan"`` oracle on every LIN combo:

  * rel-err of the final weights must be <= 1e-4 (asserted, recorded);
  * peak device-resident input bytes must be bounded by the chunk size
    — (prefetch+2) blocks — and sit far below the resident dataset
    (asserted, recorded);
  * wall-clock per fit for the streaming tax at CPU/TPU speeds.

Per-combo chain lengths/clamps are chosen inside the regime where the
iteration map does not chaotically amplify fp32 reassociation noise
(DESIGN.md §Perf/Streaming): EM runs long at eps=1e-2; MC runs shorter
chains (the IG sampler's accept-reject branch is discontinuous, so
near-hinge rows can flip on lsb-level residual differences — same
dynamic-range analysis as the bf16-reduce eps >= 1e-3 rule).

Results append to ``BENCH_stream.json``.
"""
from __future__ import annotations

import os
import time

import numpy as np

from repro.core import PEMSVM, SVMConfig

from .common import append_json, emit

BENCH_JSON = os.environ.get("BENCH_STREAM_JSON", "BENCH_stream.json")

# (options, config overrides, iterations) — see module docstring for why
# MC chains are shorter.
COMBOS = [
    ("LIN-EM-CLS", {}, 30),
    ("LIN-EM-SVR", dict(eps_ins=0.3), 30),
    ("LIN-EM-MLT", dict(num_classes=3), 16),
    ("LIN-MC-CLS", dict(burnin=4), 8),
    ("LIN-MC-SVR", dict(eps_ins=0.3, burnin=4), 8),
    # MLT MC forks fastest (M IG-draw layers per iteration, each with a
    # discontinuous accept-reject): 2 iterations still exercises a full
    # draw-and-average chain while staying inside the 1e-4 window.
    ("LIN-MC-MLT", dict(num_classes=3, burnin=0, eps=1e-1), 2),
]


def _problem(task: str, n: int, k: int, m: int = 3):
    rng = np.random.default_rng(0)
    X = rng.normal(size=(n, k)).astype(np.float32)
    w_true = rng.normal(size=k)
    if task == "SVR":
        y = (X @ w_true).astype(np.float32)
    elif task == "MLT":
        y = np.argmax(X @ rng.normal(size=(m, k)).T, 1).astype(np.int32)
    else:
        y = np.where(X @ w_true + 0.3 * rng.normal(size=n) > 0, 1.0, -1.0)
    return X, y


def _fit_timed(model: PEMSVM, X, y):
    model.fit(X, y)  # warm the jit caches out of the measurement
    t0 = time.perf_counter()
    res = model.fit(X, y)
    return res, time.perf_counter() - t0


def run(full: bool = False, n: int | None = None, k: int | None = None,
        chunk_rows: int | None = None, prefetch: int = 2):
    n = n or (65536 if full else 1024)
    k = k or (128 if full else 16)
    chunk_rows = chunk_rows or max(1, n // 16)   # < N/8 by construction
    assert chunk_rows < n / 8
    rows = []
    failures = []
    for options, kw, iters in COMBOS:
        task = options.split("-")[-1]
        X, y = _problem(task, n, k)
        base = {"eps": 1e-2, **kw,
                "max_iters": iters, "min_iters": iters}
        resident = PEMSVM(SVMConfig.from_options(options, **base))
        stream = PEMSVM(SVMConfig.from_options(
            options, driver="stream", chunk_rows=chunk_rows,
            prefetch=prefetch, **base))
        r_res, t_res = _fit_timed(resident, X, y)
        r_str, t_str = _fit_timed(stream, X, y)

        rel_err = float(np.abs(r_str.weights - r_res.weights).max()
                        / max(1e-12, np.abs(r_res.weights).max()))
        k_eff = X.shape[1] + 1                      # + absorbed bias
        resident_bytes = int(n * k_eff * 4 + 2 * n * 4)
        chunk_bytes = int(chunk_rows * k_eff * 4 + 2 * chunk_rows * 4)
        # prefetch queued + worker in-hand + consumer (ChunkPrefetcher)
        bound_bytes = (prefetch + 2) * chunk_bytes
        parity_ok = rel_err <= 1e-4
        # The acceptance bound: residency tracks the chunk size — the
        # (prefetch+2) in-flight blocks — never the dataset.
        residency_ok = (0 < r_str.peak_input_bytes <= bound_bytes
                        and r_str.peak_input_bytes < resident_bytes)
        if not parity_ok:
            failures.append(f"{options}: rel_err {rel_err:.2e} > 1e-4")
        if not residency_ok:
            failures.append(
                f"{options}: peak {r_str.peak_input_bytes} outside "
                f"(0, {bound_bytes}] or >= resident {resident_bytes}")
        rows.append({
            "name": options, "n": n, "k": k, "chunk_rows": chunk_rows,
            "iters": iters, "seconds": t_str,
            "resident_seconds": t_res,
            "stream_over_resident": round(t_str / t_res, 3),
            "weights_rel_err": rel_err, "parity_ok": parity_ok,
            "peak_input_bytes": r_str.peak_input_bytes,
            "peak_bound_bytes": bound_bytes,
            "resident_input_bytes": resident_bytes,
            "peak_over_resident": round(
                r_str.peak_input_bytes / resident_bytes, 4),
            "residency_ok": residency_ok,
        })

    emit(rows, "stream_vs_resident")
    append_json(rows, BENCH_JSON)
    assert not failures, "; ".join(failures)
    return rows


if __name__ == "__main__":
    run()
