"""In-kernel counter RNG vs materialized noise operands, and multichain
scaling (ISSUE 10 acceptance benchmark) -> ``BENCH_rng.json``.

Two claims are measured and gated:

  1. OPERAND ELIMINATION: rng='fused' replaces the ``n_noise`` (N,) f32
     noise operands of the MC epilogues with one (4,) uint32 seed — the
     kernel input traffic drops by exactly ``4 * N * n_noise - 16``
     bytes, and the host pre-draw pass (its own O(N * n_noise) write +
     read) disappears entirely.  In the memory-bound regime the
     roofline memory-time drops by the same ratio.
  2. MULTICHAIN IS NEARLY FREE: C chains are C counter planes over ONE
     X stream, so the incremental cost of a chain is the O(N) epilogue
     math + the O(K^2) statistic — never another X pass.  The roofline
     memory-time of the C-chain statistic is far below C x the
     single-chain one, and measured wall-clock beats running C
     independent single-chain statistics.

Gates (asserted, any backend):
  * analytic operand-byte reduction == 4 * N * n_noise - 16 per MC
    epilogue, and roofline memory-time strictly lower for fused;
  * BITWISE parity: seed-mode outputs == operand-mode outputs on the
    statistic (ref + kernel backends), and an rng='fused' whole fit ==
    the rng='fused_predraw' oracle fit;
  * C-chain roofline memory-time < 0.5 * C x single-chain at C = 8
    (the "nearly free" bound-level claim);
  * measured: the C-chain statistic beats C independent single-chain
    calls (< 0.9 * C x single) — the shared X stream is real time, not
    just a model, even compute-bound on CPU.
"""
from __future__ import annotations

import os
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import PEMSVM, SVMConfig
from repro.kernels import epilogues, ops
from repro.kernels import rng as rng_mod

from .common import append_json, emit

BENCH_JSON = os.environ.get("BENCH_RNG_JSON", "BENCH_rng.json")

PEAK_FLOPS = 197e12     # v5e, matches benchmarks/roofline.py
HBM_BW = 819e9


def _roofline(n: int, k: int, n_noise: int, chains: int) -> dict:
    """Analytic per-call roofline terms for the fused MC statistic.

    Input bytes: the X stream (4nk), ~3 row operands (targets, beta,
    mask), w (4k * C), plus the noise source — ``4 n n_noise`` under
    predraw operands, 16 bytes of seed under the counter.  Outputs:
    margin + draws ((1 + n_noise/2) * 4n * C), b (4k * C), Sigma
    (4k^2 * C).  FLOPs: the margin/b matmuls (4nk * C) + the dense
    Sigma SYRK (2nk^2 * C) + the cipher (~100 int ops per draw pair,
    counted at 50 * n * n_noise * C when in-kernel)."""
    noise_bytes = {"operands": 4.0 * n * n_noise, "seed": 16.0}
    out = {}
    for name, nb in noise_bytes.items():
        in_bytes = 4.0 * n * k + 3 * 4.0 * n + 4.0 * k * chains + nb
        out_bytes = ((1 + n_noise // 2) * 4.0 * n * chains
                     + 4.0 * k * chains + 4.0 * k * k * chains)
        flops = (4.0 * n * k * chains + 2.0 * n * k * k * chains
                 + (50.0 * n * n_noise * chains if name == "seed" else 0))
        byts = in_bytes + out_bytes
        out[name] = {"compute_s": flops / PEAK_FLOPS,
                     "memory_s": byts / HBM_BW,
                     "bound_s": max(flops / PEAK_FLOPS, byts / HBM_BW),
                     "in_bytes": in_bytes}
    return out


def _time_best(fn, repeats: int = 3) -> float:
    fn()                                    # warm the jit caches
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _operand_rows(n: int, ks, backend: str, failures: list) -> list:
    """Per (epilogue, K): seed-vs-operand byte accounting, roofline,
    measured wall-clock (predraw timing INCLUDES the host pre-draw —
    that is what rng='fused_predraw' pays every iteration), and the
    bitwise-parity gate."""
    rng = np.random.default_rng(0)
    key = jax.random.PRNGKey(1)
    rows = []
    for k in ks:
        X = jnp.asarray(rng.normal(size=(n, k)).astype(np.float32))
        y = jnp.asarray(rng.choice([-1.0, 1.0], n).astype(np.float32))
        w = jnp.asarray(rng.normal(size=k).astype(np.float32))
        zeros = jnp.zeros((n,), jnp.float32)
        seed = rng_mod.pack_seed(key, 0, 0)
        for epilogue in ("mc_hinge", "mc_svr"):
            n_noise = epilogues.noise_arity(epilogue)
            tgt = y if epilogue == "mc_hinge" else jnp.asarray(
                (np.asarray(X) @ rng.normal(size=k)).astype(np.float32))
            beta = y if epilogue == "mc_hinge" else zeros
            kw = dict(epilogue=epilogue, eps=1e-6, eps_ins=0.2,
                      backend=backend)

            def fused():
                return [np.asarray(o) for o in ops.fused_stats(
                    X, tgt, beta, w, None, None, seed=seed, **kw)]

            def predraw():
                noise = rng_mod.draw_fused_noise(key, n, 0, 0, n_noise)
                return [np.asarray(o) for o in ops.fused_stats(
                    X, tgt, beta, w, None, noise, **kw)]

            for a, b in zip(fused(), predraw()):
                if not np.array_equal(a, b):
                    failures.append(
                        f"K={k} {epilogue}: seed vs operands NOT bitwise")
                    break
            roof = _roofline(n, k, n_noise, 1)
            saved = (roof["operands"]["in_bytes"]
                     - roof["seed"]["in_bytes"])
            if saved != 4.0 * n * n_noise - 16:
                failures.append(
                    f"K={k} {epilogue}: operand bytes saved {saved}")
            mem_ratio = (roof["operands"]["memory_s"]
                         / roof["seed"]["memory_s"])
            if mem_ratio <= 1.0:
                failures.append(
                    f"K={k} {epilogue}: roofline memory ratio "
                    f"{mem_ratio:.3f} not > 1")
            secs = {"seed": _time_best(fused),
                    "predraw": _time_best(predraw)}
            rows.append({
                "name": f"operand_elim_{epilogue}_K{k}", "n": n, "k": k,
                "epilogue": epilogue, "backend": backend,
                "noise_operand_bytes": 4 * n * n_noise,
                "seed_bytes": 16,
                "seconds_seed": secs["seed"],
                "seconds_predraw": secs["predraw"],
                "measured_ratio_seed_over_predraw": round(
                    secs["seed"] / secs["predraw"], 4),
                "roofline_memory_ratio": round(mem_ratio, 4),
                "bitwise": True,
            })
    return rows


def _chain_rows(n: int, k: int, backend: str, failures: list,
                cs=(1, 2, 4, 8)) -> list:
    """Multichain statistic scaling: C counter planes over one X
    stream, measured against C independent single-chain calls and the
    roofline's memory-time model."""
    rng = np.random.default_rng(2)
    X = jnp.asarray(rng.normal(size=(n, k)).astype(np.float32))
    y = jnp.asarray(rng.choice([-1.0, 1.0], n).astype(np.float32))
    key = jax.random.PRNGKey(3)
    rows = []
    base = None
    for c in cs:
        W = jnp.asarray(rng.normal(size=(k, c)).astype(np.float32))
        seed = rng_mod.pack_seed(key, 0, 0)

        def multi(W=W):
            return [np.asarray(o) for o in ops.fused_stats(
                X, y, y, W, None, None, seed=seed, epilogue="mc_hinge",
                eps=1e-6, backend=backend)]

        def singles(W=W, c=c):
            out = []
            for i in range(c):
                out.append([np.asarray(o) for o in ops.fused_stats(
                    X, y, y, W[:, i], None, None,
                    seed=rng_mod.pack_seed(key, 0, i),
                    epilogue="mc_hinge", eps=1e-6, backend=backend)])
            return out

        secs = {"multi": _time_best(multi), "singles": _time_best(singles)}
        roof_c = _roofline(n, k, 2, c)["seed"]["memory_s"]
        roof_1 = _roofline(n, k, 2, 1)["seed"]["memory_s"]
        if base is None:
            base = secs["multi"]
        if c >= 4:
            if roof_c / roof_1 >= 0.5 * c:
                failures.append(
                    f"C={c}: roofline memory {roof_c / roof_1:.2f}x not "
                    f"< 0.5 * {c}")
            if secs["multi"] >= 0.9 * secs["singles"]:
                failures.append(
                    f"C={c}: multichain {secs['multi']:.4f}s not < 0.9 x "
                    f"{c} singles {secs['singles']:.4f}s")
        rows.append({
            "name": f"chain_scaling_C{c}", "n": n, "k": k, "chains": c,
            "backend": backend,
            "seconds_multichain": secs["multi"],
            "seconds_c_singles": secs["singles"],
            "measured_vs_c_singles": round(
                secs["multi"] / secs["singles"], 4),
            "measured_vs_c1": round(secs["multi"] / base, 4),
            "roofline_memory_vs_c1": round(roof_c / roof_1, 4),
        })
    return rows


def _fit_rows(n: int, k: int, failures: list) -> list:
    """Whole-fit gates: rng='fused' == rng='fused_predraw' bitwise, and
    a C-chain fit vs C independent chain0-staggered fits (the ensemble
    the multichain mode replaces), dispatch backend."""
    rng = np.random.default_rng(4)
    X = rng.normal(size=(n, k)).astype(np.float32)
    y = np.where(X @ rng.normal(size=k) > 0, 1.0, -1.0).astype(np.float32)
    kw = dict(algorithm="MC", burnin=4, max_iters=12, min_iters=12)

    t0 = time.perf_counter()
    fused = PEMSVM(SVMConfig(**kw, rng="fused")).fit(X, y)
    sec_fused = time.perf_counter() - t0
    oracle = PEMSVM(SVMConfig(**kw, rng="fused_predraw")).fit(X, y)
    bitwise = bool(np.array_equal(fused.weights, oracle.weights))
    if not bitwise:
        failures.append("whole fit: rng='fused' != 'fused_predraw'")

    C = 4
    t0 = time.perf_counter()
    multi = PEMSVM(SVMConfig(**kw, rng="fused", n_chains=C)).fit(X, y)
    sec_multi = time.perf_counter() - t0
    t0 = time.perf_counter()
    for c in range(C):
        PEMSVM(SVMConfig(**kw, rng="fused", chain0=c)).fit(X, y)
    sec_serial = time.perf_counter() - t0
    if sec_multi >= 0.9 * sec_serial:
        failures.append(
            f"fit: {C}-chain {sec_multi:.3f}s not < 0.9 x serial "
            f"{sec_serial:.3f}s")
    assert multi.chain_weights.shape == (C, k + 1)
    return [{"name": "whole_fit_parity", "n": n, "k": k,
             "bitwise_fused_vs_predraw": bitwise,
             "seconds": sec_fused},
            {"name": f"whole_fit_chains_C{C}", "n": n, "k": k,
             "chains": C, "seconds_multichain_fit": sec_multi,
             "seconds_serial_fits": sec_serial,
             "measured_vs_serial": round(sec_multi / sec_serial, 4)}]


def run(full: bool = False, backend: str | None = None):
    # Statistic rows exercise the real kernel body (interpret off TPU);
    # fit rows use the dispatch default (ref -> XLA on CPU).
    kernel_backend = backend or (
        "pallas" if jax.default_backend() == "tpu" else "interpret")
    n = 16384 if full else 2048
    failures: list[str] = []
    rows = _operand_rows(n, (64, 256), kernel_backend, failures)
    rows += _chain_rows(n, 128, kernel_backend, failures)
    rows += _fit_rows(2048 if not full else 8192, 16, failures)
    emit(rows, "rng_fused")
    append_json(rows, BENCH_JSON)
    assert not failures, "; ".join(failures)
    return rows


if __name__ == "__main__":
    run()
