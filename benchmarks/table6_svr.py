"""Paper Table 6: SVR on the year dataset (normalized targets, eps=0.3,
C=0.01). Reference accuracy: closed-form ridge regression (the LL-Primal
stand-in for RMSE parity)."""
from __future__ import annotations

import numpy as np

from repro.core import PEMSVM, SVMConfig, lam_from_C
from repro.data import make_year_like

from .common import emit, time_fit


def run(n: int = 50_000, k: int = 90, full: bool = False):
    if full:
        n = 250_000
    X, y = make_year_like(n, k)
    n_te = n // 5
    Xte, yte = X[-n_te:], y[-n_te:]
    Xtr, ytr = X[:-n_te], y[:-n_te]

    rows = []
    svm = PEMSVM(SVMConfig.from_options(
        "LIN-EM-SVR", lam=lam_from_C(0.01), eps_ins=0.3, max_iters=100))
    res, secs = time_fit(svm.fit, Xtr, ytr)
    rows.append({"name": "LIN-EM-SVR", "seconds": secs,
                 "rmse": round(svm.rmse(Xte, yte), 4),
                 "iters": res.n_iters})

    t0 = __import__("time").time()
    Xb = np.concatenate([Xtr, np.ones((len(Xtr), 1), np.float32)], 1)
    w = np.linalg.solve(Xb.T @ Xb + 1e-3 * np.eye(k + 1), Xb.T @ ytr)
    secs = __import__("time").time() - t0
    pred = np.concatenate([Xte, np.ones((len(Xte), 1), np.float32)], 1) @ w
    rows.append({"name": "ridge-ref", "seconds": secs,
                 "rmse": round(float(np.sqrt(np.mean((pred - yte) ** 2))), 4)})

    emit(rows, "table6_svr")
    return rows
