"""Column-tiled fused statistics: the single-stream 2-D (data x model)
``k_shard_axis`` path vs the pre-fusion split path (ISSUE 5 acceptance
benchmark) -> ``BENCH_kshard.json``.

Before the column-windowed kernels, one k_shard iteration ran a SPLIT
E-step plus a separate column-block matmul:

  split EM:  (margin, gamma, b) = fused_estep   (X stream 1)
             S_blk = (X * 1/gamma)^T Xcols      (X stream 2, + the
                                                 sliced Xcols bytes)
  split MC:  margin = X w                       (stream 1)
             draws on host (gamma_mc_rowwise)
             b = X^T coef                       (stream 2)
             S_blk matmul                       (stream 3, + Xcols)
  windowed:  one fused kernel, col_window       (stream 1 of 1; the
             column block accumulates from the in-VMEM X tile)

In the memory-bound regime (K below the roofline crossover, DESIGN.md
§Perf) stream count IS iteration time, so the windowing is a
bound-level ~2x (EM) / ~3x (MC). Per (mode, K) the benchmark records
measured wall-clock for both paths AND the analytic v5e roofline
terms, with the X-stream counts spelled out.

Gates (asserted, any backend):
  * roofline memory-time for windowed >= 2x lower than split at every
    (mode, K) — the ISSUE 5 acceptance bar;
  * measured wall-clock windowed < split;
  * parity: the windowed statistic == the full statistic's column
    slice, and a 2-shard window assembly rebuilds the full Sigma;
  * MC draw parity: windowed gammas BITWISE equal the rowwise oracle
    (dispatch path — margin stays full-width under windowing);
  * EM whole-fit parity <= 1e-4: a hand-rolled fit whose Sigma is
    assembled from 2 windowed blocks per iteration vs the standard
    PEMSVM fit.
"""
from __future__ import annotations

import os
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import PEMSVM, SVMConfig, augment, stats
from repro.kernels import ops

from .common import append_json, emit

BENCH_JSON = os.environ.get("BENCH_KSHARD_JSON", "BENCH_kshard.json")

PEAK_FLOPS = 197e12     # v5e, matches benchmarks/roofline.py
HBM_BW = 819e9


def _roofline(n: int, k: int, blk: int, mode: str) -> dict:
    """Analytic per-iteration roofline terms for the k_shard statistic.

    Both paths run identical FLOPs (margin/b O(nk) + the dense
    (k, blk) block 2*n*k*blk). Bytes: the split path streams X once
    per pass (2 passes EM — fused_estep then the block matmul — and 3
    MC) and additionally reads the materialized (n, blk) Xcols slice
    in the block pass; the windowed kernel streams X ONCE and slices
    columns in VMEM. Row vectors and the (k, blk) output are charged
    to both sides."""
    small = 4.0 * (8 * n + k * blk + 2 * k)
    flops = 4.0 * n * k + 2.0 * n * k * blk
    streams = {"split": 2 if mode == "EM" else 3, "windowed": 1}
    out = {}
    for name, n_streams in streams.items():
        byts = n_streams * 4.0 * n * k + small
        if name == "split":
            byts += 4.0 * n * blk          # the materialized Xcols read
        compute_s, memory_s = flops / PEAK_FLOPS, byts / HBM_BW
        out[name] = {"compute_s": compute_s, "memory_s": memory_s,
                     "bound_s": max(compute_s, memory_s),
                     "x_streams": n_streams}
    return out


def _time_best_pair(fn_a, fn_b, repeats: int = 5) -> tuple[float, float]:
    """Interleaved best-of-N for two competitors, so a CPU-quota dip or
    scheduler stall hits both paths rather than biasing one (the
    container's wall-clocks are noisy — .claude/skills/verify)."""
    fn_a(), fn_b()                          # warm the jit caches
    best_a = best_b = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn_a()
        best_a = min(best_a, time.perf_counter() - t0)
        t0 = time.perf_counter()
        fn_b()
        best_b = min(best_b, time.perf_counter() - t0)
    return best_a, best_b


def _statistic_rows(n: int, ks, backend: str, failures: list) -> list:
    rng = np.random.default_rng(0)
    key = jax.random.PRNGKey(1)
    rows = []
    for k in ks:
        blk = k // 2                       # the 2-way model-axis window
        start = jnp.int32(blk)             # shard 1's block
        X = jnp.asarray(rng.normal(size=(n, k)).astype(np.float32))
        y = jnp.asarray(rng.choice([-1.0, 1.0], n).astype(np.float32))
        w = jnp.asarray(rng.normal(size=k).astype(np.float32))
        # Parity gates run at w = 0: the hinge residual is then exactly
        # y = +-1, far from the knee, so the in-kernel IG transform
        # cannot hit the accept-reject flip channel vs the host oracle
        # (the same knee-free construction as benchmarks/mc_fused.py —
        # the gate stays deterministic across backends/jax versions).
        # Timing uses the realistic random w.
        w0 = jnp.zeros((k,), jnp.float32)
        eps = 1e-2

        def split_em(wv=w):
            gamma_b = ops.fused_estep(X, y, y, wv, eps=eps,
                                      backend=backend)
            margin, gamma, b = gamma_b
            Xcols = jax.lax.dynamic_slice_in_dim(X, start, blk, axis=1)
            S_blk = (X * (1.0 / gamma)[:, None]).T @ Xcols
            return [np.asarray(o) for o in (margin, gamma, b, S_blk)]

        def windowed_em(wv=w):
            return [np.asarray(o) for o in ops.fused_stats(
                X, y, y, wv, None, None, epilogue="em_hinge", eps=eps,
                col_window=(start, blk), backend=backend)]

        def split_mc(wv=w):
            margin = X @ wv
            gamma = augment.gamma_mc_rowwise(key, y - margin, eps, 0)
            b = X.T @ (y / gamma + y)
            Xcols = jax.lax.dynamic_slice_in_dim(X, start, blk, axis=1)
            S_blk = (X * (1.0 / gamma)[:, None]).T @ Xcols
            return [np.asarray(o) for o in (margin, gamma, b, S_blk)]

        def windowed_mc(wv=w):
            noise = augment.draw_ig_noise(key, n, 0)
            return [np.asarray(o) for o in ops.fused_stats(
                X, y, y, wv, None, noise, epilogue="mc_hinge", eps=eps,
                col_window=(start, blk), backend=backend)]

        for mode, split_fn, win_fn in (("EM", split_em, windowed_em),
                                       ("MC", split_mc, windowed_mc)):
            # parity gate at w0 (knee-free, see above): windowed
            # statistic == split statistic (the split path uses the
            # rowwise oracle draws, so MC agreement IS draw parity at
            # the statistic level)
            want, got = split_fn(w0), win_fn(w0)
            for a, b_, part in zip(got, want,
                                   ("margin", "gamma", "b", "S_blk")):
                err = np.abs(a - b_).max() / max(1.0, np.abs(b_).max())
                if err > 2e-3:
                    failures.append(
                        f"K={k} {mode} {part} parity {err:.2e}")
            t_split, t_win = _time_best_pair(split_fn, win_fn)
            secs = {"split": t_split, "windowed": t_win}
            roof = _roofline(n, k, blk, mode)
            sp, wi = roof["split"], roof["windowed"]
            mem_ratio = sp["memory_s"] / wi["memory_s"]
            if mem_ratio < 2.0:
                failures.append(
                    f"K={k} {mode}: roofline memory ratio "
                    f"{mem_ratio:.2f} < 2")
            # The analytic roofline >= 2x above is THE acceptance gate;
            # the measured check keeps a 10% noise allowance so a
            # scheduler stall on a loaded machine cannot fail a correct
            # build (measured margins are 0.57-0.88 when quiet).
            if secs["windowed"] >= 1.1 * secs["split"]:
                failures.append(
                    f"K={k} {mode}: windowed measured "
                    f"{secs['windowed']:.4f}s not below split "
                    f"{secs['split']:.4f}s (+10% allowance)")
            rows.append({
                "name": f"kshard_statistic_{mode}_K{k}", "n": n, "k": k,
                "col_blk": blk, "mode": mode, "backend": backend,
                "seconds_split": secs["split"],
                "seconds_windowed": secs["windowed"],
                "measured_ratio_windowed_over_split": round(
                    secs["windowed"] / secs["split"], 4),
                "x_streams": {"split": sp["x_streams"], "windowed": 1},
                "roofline": {kk: {p: round(q, 9) for p, q in vv.items()}
                             for kk, vv in roof.items()},
                "roofline_memory_speedup": round(mem_ratio, 3),
                "roofline_bound_speedup": round(
                    sp["bound_s"] / wi["bound_s"], 3),
            })
    return rows


def _window_assembly_row(n: int, k: int, failures: list) -> dict:
    """Gate: 2 windowed blocks assemble the full Sigma (the all-gather
    identity, single-process) and windowed MC draws are BITWISE the
    rowwise oracle's."""
    rng = np.random.default_rng(3)
    X = jnp.asarray(rng.normal(size=(n, k)).astype(np.float32))
    y = jnp.asarray(rng.choice([-1.0, 1.0], n).astype(np.float32))
    w = jnp.asarray(rng.normal(size=k).astype(np.float32))
    key, eps, row0 = jax.random.PRNGKey(9), 1e-6, 17
    blk = k // 2

    full = ops.fused_stats(X, y, y, w, None, None, epilogue="em_hinge",
                           eps=eps, backend="ref")
    blocks = [np.asarray(ops.fused_stats(
        X, y, y, w, None, None, epilogue="em_hinge", eps=eps,
        col_window=(jnp.int32(p * blk), blk), backend="ref")[-1])
        for p in range(2)]
    S = np.concatenate(blocks, axis=1)
    asm_err = float(np.abs(S - np.asarray(full[-1])).max()
                    / np.abs(np.asarray(full[-1])).max())
    if asm_err > 1e-6:
        failures.append(f"window assembly != full Sigma ({asm_err:.2e})")

    margin = X @ w
    g_want = augment.gamma_mc_rowwise(key, y - margin, eps, row0)
    noise = augment.draw_ig_noise(key, n, row0)
    out = ops.fused_stats(X, y, y, w, None, noise,
                          col_window=(jnp.int32(blk), blk),
                          epilogue="mc_hinge", eps=eps, backend="ref")
    bitwise = bool(np.array_equal(np.asarray(out[1]), np.asarray(g_want)))
    if not bitwise:
        failures.append("windowed MC draws not bitwise vs oracle")
    return {"name": "window_assembly_and_draw_parity", "n": n, "k": k,
            "assembly_rel_err": asm_err, "mc_draws_bitwise": bitwise}


def _em_fit_row(n: int, k: int, failures: list) -> dict:
    """Gate: EM whole-fit parity <= 1e-4 — a hand-rolled fit whose
    Sigma is assembled from 2 windowed blocks per iteration (the
    single-process image of the 2-D mesh statistic) vs PEMSVM."""
    rng = np.random.default_rng(4)
    X = rng.normal(size=(n, k)).astype(np.float32)
    y = np.where(X @ rng.normal(size=k) > 0, 1.0, -1.0).astype(np.float32)
    iters = 20
    model = PEMSVM(SVMConfig(eps=1e-2, max_iters=iters, min_iters=iters,
                             add_bias=False))
    ref_w = model.fit(X, y).weights

    Xd, yd = jnp.asarray(X), jnp.asarray(y)
    blk = k // 2
    w = jnp.zeros((k,), jnp.float32)
    for _ in range(iters):
        parts = [ops.fused_stats(Xd, yd, yd, w, None, None,
                                 epilogue="em_hinge", eps=1e-2,
                                 col_window=(jnp.int32(p * blk), blk),
                                 backend="ref")
                 for p in range(2)]
        S = jnp.concatenate([p[-1] for p in parts], axis=1)
        b = parts[0][-2]
        _, w = stats.posterior_params(S, b, 1.0, jitter=1e-7)
    rel = float(np.abs(np.asarray(w) - ref_w).max() / np.abs(ref_w).max())
    if rel > 1e-4:
        failures.append(f"EM windowed-assembly fit rel {rel:.2e} > 1e-4")
    return {"name": "em_windowed_fit_parity", "n": n, "k": k,
            "iters": iters, "rel_err_vs_pemsvm": rel}


def run(full: bool = False, backend: str | None = None):
    # Statistic-level comparison runs the REAL kernel body (interpret
    # off TPU); the draw/fit gates use the dispatch default (ref).
    kernel_backend = backend or (
        "pallas" if jax.default_backend() == "tpu" else "interpret")
    n = 16384 if full else 2048
    failures: list[str] = []
    rows = _statistic_rows(n, (256, 512), kernel_backend, failures)
    rows.append(_window_assembly_row(1024, 32, failures))
    rows.append(_em_fit_row(1024 if not full else 8192, 16, failures))
    emit(rows, "kshard_fused")
    append_json(rows, BENCH_JSON)
    assert not failures, "; ".join(failures)
    return rows


if __name__ == "__main__":
    run()
