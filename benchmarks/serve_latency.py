"""Serving benchmark -> BENCH_serve.json: p50/p99 request latency and
row throughput of the continuous-batching SVM serve loop, per model
family, plus the acceptance gates:

  * bitwise parity — bucketed served scores == the decision_function
    oracle, bit for bit, for {CLS, SVR, MLT} x {linear, Nystrom}
    (the fixed-tile score cell's bucket-invariance contract);
  * phi residency — the fused score path never materializes the
    full-batch phi / cross-Gram matrix (jaxpr walk,
    ``serving.phi_never_materialized``);
  * uncertainty calibration — served std matches the host
    Sigma-quadratic-form oracle on the MC-posterior head;
  * multi-tenant paging — N tenants over a 4-slot pager keep serving
    bit-identically while evicting.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import PEMSVM, SVMConfig
from repro.core.nystrom import NystromSVM
from repro.serving import (ServeLoop, SVMScorer, WeightPager,
                           phi_never_materialized)

from .common import append_json, emit

COMBOS = [("CLS", "linear"), ("SVR", "linear"), ("MLT", "linear"),
          ("CLS", "nystrom"), ("SVR", "nystrom"), ("MLT", "nystrom")]


def _problem(task: str, n: int, d: int, m: int = 3, seed: int = 0):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n, d)).astype(np.float32)
    w = rng.normal(size=d)
    if task == "SVR":
        y = (X @ w).astype(np.float32)
    elif task == "MLT":
        y = np.argmax(X @ rng.normal(size=(m, d)).T, 1).astype(np.int32)
    else:
        y = np.where(X @ w > 0, 1.0, -1.0).astype(np.float32)
    return X, y


def _fit(task: str, family: str, n: int, d: int):
    X, y = _problem(task, n, d)
    if family == "linear":
        model = PEMSVM(SVMConfig(task=task, num_classes=3, max_iters=20,
                                 min_iters=5))
    else:
        model = NystromSVM(
            SVMConfig(formulation="KRN", task=task, num_classes=3,
                      sigma=3.0, lam=0.1, max_iters=20, min_iters=5),
            n_landmarks=48)
    model.fit(X, y)
    return model, X, y


def _drive(loop: ServeLoop, name: str, X: np.ndarray, n_requests: int,
           rows_per_req: int, seed: int = 1) -> float:
    """Fire a ragged request stream through the synchronous drain the
    way the threaded loop would coalesce it; returns wall seconds."""
    rng = np.random.default_rng(seed)
    t0 = time.perf_counter()
    futs = []
    for i in range(n_requests):
        n = int(rng.integers(1, rows_per_req + 1))
        j = int(rng.integers(0, X.shape[0] - n + 1))
        futs.append(loop.submit(name, X[j:j + n]))
        if (i + 1) % 8 == 0:        # continuous batching: drain every 8
            loop.step()
    loop.step()
    for f in futs:
        f.result(timeout=30)
    return time.perf_counter() - t0


def run(full: bool = False):
    n, d = (20_000, 64) if full else (2_000, 24)
    rows, failures = [], []

    for task, family in COMBOS:
        model, X, y = _fit(task, family, n, d)
        servable = model.export_servable(name=f"{task}-{family}")
        pager = WeightPager()
        pager.register(servable)
        loop = ServeLoop(pager)

        # warm the bucket ladder out of the measurement
        sc = pager.scorer(servable.name)
        for b in (128, 256, 512, 1024):
            sc.score(X[:b])

        n_req = 200 if full else 60
        secs = _drive(loop, servable.name, X, n_req, rows_per_req=96)
        q = loop.latency_quantiles()
        rows.append({"name": f"{task}-{family}", "seconds": secs,
                     "p50_ms": round(q["p50_ms"], 3),
                     "p99_ms": round(q["p99_ms"], 3),
                     "rows_per_s": round(loop.n_rows / secs, 1),
                     "n_requests": loop.n_requests,
                     "n_batches": loop.n_batches,
                     "traces": sc.traces})

        # --- gate: bitwise parity vs the decision_function oracle ----
        oracle = model.decision_function(X[:700])
        served = sc.score(X[:700])
        flat = served[:, 0] if task != "MLT" else served[:, :3]
        bitwise = bool(np.array_equal(flat, oracle))
        single = sc.score(X[41:42])   # 1-row request, same bits
        one_ok = bool(np.array_equal(
            single[:, :3] if task == "MLT" else single[:, 0],
            oracle[41:42]))
        if not (bitwise and one_ok):
            failures.append(f"{task}-{family} served != oracle bitwise")

        # --- gate: phi never materialized on the fused path ----------
        resident = bool(phi_never_materialized(sc, 1024))
        if not resident:
            failures.append(f"{task}-{family} materializes phi")
        rows.append({"name": f"{task}-{family}-gates", "seconds": 0.0,
                     "bitwise_parity": bitwise and one_ok,
                     "phi_resident_vmem_only": resident})

    # --- gate: uncertainty head vs host Sigma oracle ------------------
    model, X, y = _fit("CLS", "nystrom", n // 2, d)
    sc = SVMScorer(model.export_servable(posterior_from=(X, y)))
    margin, std = sc.score_with_std(X[:256])
    phi = model._phi(X, add_bias=True).astype(np.float64)
    w = np.asarray(model.svm._weights, np.float64)
    cfg = model.svm.config
    gamma = np.maximum(np.abs(1.0 - y.astype(np.float64) * (phi @ w)),
                       cfg.eps)
    S = (phi * (1.0 / gamma)[:, None]).T @ phi
    P = S + cfg.lam * np.eye(S.shape[0])
    P = 0.5 * (P + P.T) + cfg.jitter * (np.trace(P) / S.shape[0]) \
        * np.eye(S.shape[0])
    sol = np.linalg.solve(P, phi[:256].T)
    std_oracle = np.sqrt(np.sum(phi[:256].T * sol, axis=0))
    rel = float(np.max(np.abs(std - std_oracle)
                       / np.maximum(std_oracle, 1e-12)))
    if rel > 5e-2:
        failures.append(f"uncertainty vs Sigma oracle rel {rel:.2e}")
    rows.append({"name": "uncertainty-gate", "seconds": 0.0,
                 "std_rel_err": round(rel, 6),
                 "margin_bitwise": bool(np.array_equal(
                     margin, model.decision_function(X[:256])))})

    # --- gate: multi-tenant paging stays bit-identical ----------------
    base, X, y = _fit("CLS", "linear", n // 2, d)
    pager = WeightPager(max_resident=4)
    oracle = base.decision_function(X[:300])
    for t in range(10):
        pager.register(base.export_servable(name=f"tenant{t}"))
    paging_ok = True
    for t in list(range(10)) + [0, 7, 3]:   # re-touch evicted tenants
        out = pager.scorer(f"tenant{t}").score(X[:300])[:, 0]
        paging_ok &= bool(np.array_equal(out, oracle))
    if not paging_ok:
        failures.append("tenant paging changed served bits")
    rows.append({"name": "paging-gate", "seconds": 0.0,
                 "tenants": 10, "resident_slots": 4,
                 "evictions": pager.evictions,
                 "resident_bytes": pager.resident_bytes,
                 "bitwise_across_paging": paging_ok})

    emit(rows, "serve_latency")
    append_json(rows, "BENCH_serve.json")
    if failures:
        raise AssertionError(f"serve gates failed: {failures}")
    return rows
