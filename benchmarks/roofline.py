"""§Roofline report generator: reads runs/dryrun/*.json (written by
repro.launch.dryrun) and emits the per-(arch x shape x mesh) table with
the three roofline terms, the dominant bottleneck, MODEL_FLOPS/HLO ratio,
and a one-line what-would-move-it note."""
from __future__ import annotations

import glob
import json
import os

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_ADVICE = {
    "compute_s": ("cut replicated/wasted FLOPs: head-divisible TP layout, "
                  "causal block skipping, lower remat factor"),
    "memory_s": ("stream less HBM: bf16 activations everywhere, larger "
                 "fusion tiles, fewer elementwise round-trips"),
    "collective_s": ("shrink reduction payloads: triangle/bf16-compressed "
                     "reduce, overlap collectives with compute, "
                     "reduce-scatter instead of all-reduce"),
}


def load(run_dir: str = "runs/dryrun") -> list[dict]:
    recs = []
    for p in sorted(glob.glob(os.path.join(run_dir, "*.json"))):
        with open(p) as f:
            recs.append(json.load(f))
    return recs


def table(recs: list[dict]) -> str:
    hdr = ("| arch | shape | mesh | compute_s | memory_s | collective_s | "
           "dominant | fits HBM | model/HLO flops | note |")
    sep = "|" + "---|" * 10
    lines = [hdr, sep]
    for r in recs:
        if r.get("skipped"):
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — | — | "
                f"skipped | — | — | {r['reason'][:60]} |")
            continue
        if not r.get("ok"):
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — | — | "
                f"ERROR | — | — | {r.get('error', '')[:60]} |")
            continue
        t = r["terms"]
        dom = t["dominant"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {t['compute_s']:.3g} | {t['memory_s']:.3g} "
            f"| {t['collective_s']:.3g} | {dom.replace('_s','')} "
            f"| {'Y' if r['memory']['fits_16gb_hbm'] else 'N'} "
            f"| {r['useful_flops_ratio']:.3f} | {_ADVICE[dom][:58]} |")
    return "\n".join(lines)


def run(run_dir: str = "runs/dryrun", full: bool = False):
    recs = load(run_dir)
    if not recs:
        print(f"roofline,no_records,dir={run_dir}")
        return []
    print(table(recs))
    ok = [r for r in recs if r.get("ok") and not r.get("skipped")]
    for r in ok:
        dom = r["terms"]["dominant"]
        print(f"roofline/{r['arch']}_{r['shape']}_{r['mesh']},0.0,"
              f"dominant={dom};ratio={r['useful_flops_ratio']:.3f}")
    return recs
