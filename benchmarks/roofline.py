"""§Roofline report generator: reads runs/dryrun/*.json (written by
repro.launch.dryrun) and emits the per-(arch x shape x mesh) table with
the three roofline terms, the dominant bottleneck, MODEL_FLOPS/HLO ratio,
and a one-line what-would-move-it note.

Also emits the SVM iteration-statistic roofline (DESIGN.md §Perf):
dense SYRK vs triangle-blocked SYRK vs one-sweep fused_stats, so the
kernel choice (FLOP-halving vs HBM-halving) can be read off per (N, K)."""
from __future__ import annotations

import glob
import json
import os

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_ADVICE = {
    "compute_s": ("cut replicated/wasted FLOPs: head-divisible TP layout, "
                  "causal block skipping, lower remat factor"),
    "memory_s": ("stream less HBM: bf16 activations everywhere, larger "
                 "fusion tiles, fewer elementwise round-trips"),
    "collective_s": ("shrink reduction payloads: triangle/bf16-compressed "
                     "reduce, overlap collectives with compute, "
                     "reduce-scatter instead of all-reduce"),
}


def load(run_dir: str = "runs/dryrun") -> list[dict]:
    recs = []
    for p in sorted(glob.glob(os.path.join(run_dir, "*.json"))):
        with open(p) as f:
            recs.append(json.load(f))
    return recs


def table(recs: list[dict]) -> str:
    hdr = ("| arch | shape | mesh | compute_s | memory_s | collective_s | "
           "dominant | fits HBM | model/HLO flops | note |")
    sep = "|" + "---|" * 10
    lines = [hdr, sep]
    for r in recs:
        if r.get("skipped"):
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — | — | "
                f"skipped | — | — | {r['reason'][:60]} |")
            continue
        if not r.get("ok"):
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — | — | "
                f"ERROR | — | — | {r.get('error', '')[:60]} |")
            continue
        t = r["terms"]
        dom = t["dominant"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {t['compute_s']:.3g} | {t['memory_s']:.3g} "
            f"| {t['collective_s']:.3g} | {dom.replace('_s','')} "
            f"| {'Y' if r['memory']['fits_16gb_hbm'] else 'N'} "
            f"| {r['useful_flops_ratio']:.3f} | {_ADVICE[dom][:58]} |")
    return "\n".join(lines)


def gram_rooflines(shapes=((250_000, 500), (1_000_000, 1024))) -> list[dict]:
    """Analytic roofline terms for the three Sigma-statistic kernels.

    Per EM iteration (bytes in f32):
      dense  weighted_gram:  2NK^2 flops, X streamed once for Sigma and
                             once for the estep  -> 2 X streams/iter.
      syrk_tri:              NK^2 flops (lower-triangle block grid),
                             same 2 X streams/iter.
      fused_stats:           2NK^2 flops but ONE X stream/iter.
    Whichever bound dominates picks the kernel: compute-bound -> tri,
    memory-bound -> fused (DESIGN.md §Perf)."""
    out = []
    for n, k in shapes:
        x_bytes = 4.0 * n * k
        small = 4.0 * (2 * n + k + k * k)      # margins/gammas/b/Sigma
        variants = {
            "dense_split": (2.0 * n * k * k, 2 * x_bytes + small),
            "tri_split": (1.0 * n * k * k, 2 * x_bytes + small),
            "fused": (2.0 * n * k * k, x_bytes + small),
            "tri_fused_lower_bound": (1.0 * n * k * k, x_bytes + small),
        }
        for name, (flops, byts) in variants.items():
            compute_s = flops / PEAK_FLOPS
            memory_s = byts / HBM_BW
            out.append({
                "name": name, "n": n, "k": k,
                "compute_s": compute_s, "memory_s": memory_s,
                "bound_s": max(compute_s, memory_s),
                "dominant": ("compute" if compute_s >= memory_s
                             else "memory")})
    return out


def gram_table(rows: list[dict]) -> str:
    lines = ["| kernel | N | K | compute_s | memory_s | bound_s | "
             "dominant |", "|" + "---|" * 7]
    for r in rows:
        lines.append(
            f"| {r['name']} | {r['n']} | {r['k']} | {r['compute_s']:.3g} "
            f"| {r['memory_s']:.3g} | {r['bound_s']:.3g} "
            f"| {r['dominant']} |")
    return "\n".join(lines)


def run(run_dir: str = "runs/dryrun", full: bool = False):
    grows = gram_rooflines()
    print(gram_table(grows))
    for r in grows:
        print(f"roofline/gram_{r['name']}_n{r['n']}_k{r['k']},"
              f"{r['bound_s'] * 1e6:.2f},dominant={r['dominant']}")
    recs = load(run_dir)
    if not recs:
        print(f"roofline,no_records,dir={run_dir}")
        return grows
    print(table(recs))
    ok = [r for r in recs if r.get("ok") and not r.get("skipped")]
    for r in ok:
        dom = r["terms"]["dominant"]
        print(f"roofline/{r['arch']}_{r['shape']}_{r['mesh']},0.0,"
              f"dominant={dom};ratio={r['useful_flops_ratio']:.3f}")
    return recs
