"""Paper Table 5: LIN-EM-CLS on the dna dataset vs baseline solvers.

Scaled-down default (N=60k of the paper's 2.5M/25M rows — CPU container);
the protocol is the paper's: C=1e-5, objective-change stopping rule,
accuracy parity check. Baselines are the reimplemented LL-Dual (DCD) and
Pegasos. The paper's headline — parallel scaling to hundreds of cores —
is measured in fig2_cores.py; the 256/512-chip versions are the
pemsvm dry-run cells (EXPERIMENTS.md §Dry-run)."""
from __future__ import annotations

from repro.baselines import DCDSVM, PegasosSVM
from repro.core import PEMSVM, SVMConfig, lam_from_C
from repro.data import make_dna_like

from .common import emit, time_fit


def run(n: int = 60_000, k: int = 800, full: bool = False):
    if full:
        n, k = 2_500_000, 800
    # Paper protocol: C=1e-5 at N=2.5M. The regularizer 0.5*lam*||w||^2
    # competes with a sum over N examples, so lam scales with N when the
    # dataset is scaled down (lam_paper * n/n_paper) — otherwise the
    # reduced problem is over-regularized to chance accuracy.
    lam = lam_from_C(1e-5) * n / 2_500_000
    C_dual = 2.0 / lam
    X, y = make_dna_like(n, k)
    n_te = min(10_000, n // 5)
    Xte, yte = X[-n_te:], y[-n_te:]
    Xtr, ytr = X[:-n_te], y[:-n_te]

    rows = []
    svm = PEMSVM(SVMConfig(lam=lam, max_iters=100))
    res, secs = time_fit(svm.fit, Xtr, ytr)
    rows.append({"name": "LIN-EM-CLS", "seconds": secs,
                 "acc": round(svm.score(Xte, yte), 4),
                 "iters": res.n_iters, "converged": res.converged})

    # Pegasos's lambda is per-example (obj: lam/2||w||^2 + mean hinge);
    # the paper's is per-sum — divide by 2N for the equivalent problem.
    peg = PegasosSVM(lam=lam / (2 * len(Xtr)), n_steps=8_000,
                     batch_size=512)
    _, secs = time_fit(peg.fit, Xtr, ytr)
    rows.append({"name": "Pegasos", "seconds": secs,
                 "acc": round(peg.score(Xte, yte), 4)})

    dcd = DCDSVM(C=C_dual, n_epochs=3)
    _, secs = time_fit(dcd.fit, Xtr, ytr)
    rows.append({"name": "LL-Dual(DCD)", "seconds": secs,
                 "acc": round(dcd.score(Xte, yte), 4)})

    emit(rows, "table5_dna")
    return rows
