#!/usr/bin/env python
"""CPU counter-RNG smoke for CI (mirrors scripts/mc_smoke.py): the
in-kernel noise generator, the seed-operand kernel path and the
multichain ensemble, gated on bitwise parity.

Gates:

  * BITWISE generator parity: the kernel-tile generator
    (``tile_noise``) emits exactly the host oracle's
    (``draw_fused_noise``) stream per chain plane, and chunk slices
    are literal slices of the full stream;
  * BITWISE kernel parity: ``ops.fused_stats`` under the (4,) counter
    seed == the same call fed the materialized noise operands, for
    both MC epilogues;
  * BITWISE whole-fit parity: an rng='fused' MC fit == the
    rng='fused_predraw' oracle fit (CLS and SVR, stream driver);
  * multichain surface: a 3-chain fit's weights are the float64 chain
    mean, chain_std the ddof-1 spread, chains pairwise distinct.
"""
from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main() -> int:
    import numpy as np

    import jax
    import jax.numpy as jnp

    from repro.core import PEMSVM, SVMConfig
    from repro.kernels import ops
    from repro.kernels import rng as rng_mod

    rng = np.random.default_rng(0)
    N, K = 1024, 16
    X = rng.normal(size=(N, K)).astype(np.float32)
    w_true = rng.normal(size=K)
    y = np.where(X @ w_true + 0.3 * rng.normal(size=N) > 0,
                 1.0, -1.0).astype(np.float32)
    ys = (X @ w_true).astype(np.float32)
    key = jax.random.PRNGKey(7)

    # --- gate 1: generator parity (tile == oracle, slices == full) ---
    seed = np.asarray(rng_mod.pack_seed(key, 100, 2))
    tile = rng_mod.tile_noise(seed, 28, (64, 3), 2)
    gen_ok = True
    for c in range(3):
        want = rng_mod.draw_fused_noise(key, 64, 128, 2 + c, 2)
        gen_ok &= all(np.array_equal(np.asarray(t)[:, c], np.asarray(w))
                      for t, w in zip(tile, want))
    full = rng_mod.draw_fused_noise(key, 300, 0, 0, 4)
    part = rng_mod.draw_fused_noise(key, 100, 150, 0, 4)
    gen_ok &= all(np.array_equal(np.asarray(f)[150:250], np.asarray(p))
                  for f, p in zip(full, part))
    print(f"generator parity: tile/slice bitwise={gen_ok}")
    if not gen_ok:
        print("GENERATOR PARITY FAIL")
        return 1

    # --- gate 2: seed vs operand kernel parity, bitwise --------------
    Xd, yd = jnp.asarray(X), jnp.asarray(y)
    w = jnp.asarray(rng.normal(size=K).astype(np.float32))
    for epilogue, tgt, beta, n_noise in (
            ("mc_hinge", yd, yd, 2),
            ("mc_svr", jnp.asarray(ys), jnp.zeros(N), 4)):
        kw = dict(epilogue=epilogue, eps=1e-6, eps_ins=0.2,
                  backend="ref")
        got = ops.fused_stats(Xd, tgt, beta, w, None, None,
                              seed=rng_mod.pack_seed(key, 5, 0), **kw)
        want = ops.fused_stats(
            Xd, tgt, beta, w, None,
            rng_mod.draw_fused_noise(key, N, 5, 0, n_noise), **kw)
        ok = all(np.array_equal(np.asarray(a), np.asarray(b))
                 for a, b in zip(got, want))
        print(f"kernel parity {epilogue}: bitwise={ok}")
        if not ok:
            print("KERNEL PARITY FAIL")
            return 1

    # --- gate 3: whole-fit fused == predraw oracle, bitwise ----------
    for task, tgt in (("CLS", y), ("SVR", ys)):
        kw = dict(algorithm="MC", task=task, eps=1e-2, eps_ins=0.3,
                  burnin=4, max_iters=12, min_iters=12, driver="stream",
                  chunk_rows=256)
        a = PEMSVM(SVMConfig(**kw, rng="fused")).fit(X, tgt)
        b = PEMSVM(SVMConfig(**kw, rng="fused_predraw")).fit(X, tgt)
        ok = np.array_equal(a.weights, b.weights)
        print(f"whole-fit parity {task}: bitwise={ok}")
        if not ok:
            print("WHOLE-FIT PARITY FAIL")
            return 1

    # --- gate 4: multichain ensemble surface -------------------------
    res = PEMSVM(SVMConfig(algorithm="MC", burnin=4, max_iters=12,
                           min_iters=12, rng="fused", n_chains=3)
                 ).fit(X, y)
    cw = res.chain_weights.astype(np.float64)
    ok = (res.chain_weights.shape == (3, K + 1)
          and np.array_equal(res.weights,
                             cw.mean(axis=0).astype(np.float32))
          and np.array_equal(res.chain_std,
                             cw.std(axis=0, ddof=1).astype(np.float32))
          and not np.array_equal(res.chain_weights[0],
                                 res.chain_weights[1]))
    print(f"multichain ensemble: ok={ok}")
    if not ok:
        print("MULTICHAIN FAIL")
        return 1

    print("rng smoke complete")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
