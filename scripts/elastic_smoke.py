#!/usr/bin/env python
"""CPU elasticity smoke for CI: kill a fit, resume it, demand the
same bits (DESIGN.md §Reliability).

Three gates, strongest first:

  * kill/resume parity — an EM and an MC streaming fit are preempted
    by the deterministic fault injectors (between iterations AND
    mid-pass between chunks) and resumed from the last committed
    snapshot; the resumed weights must equal the uninterrupted fit's
    BITWISE (the snapshot carries the PRNG carry key and, mid-pass,
    the iteration subkey);
  * elastic restore — the stream-written checkpoint must resume into
    ``driver="scan"`` within the whole-fit reassociation band (1e-3);
  * budget extension — resuming a finished 5-iteration fit with
    max_iters=10 must land bitwise on the one-shot 10-iteration fit.
"""
from __future__ import annotations

import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main() -> int:
    import numpy as np

    from repro.core import PEMSVM, SVMConfig
    from repro.runtime import faults
    from repro.runtime.policy import FaultPolicy

    rng = np.random.default_rng(0)
    N, K = 400, 12
    X = rng.normal(size=(N, K)).astype(np.float32)
    y = np.where(X @ rng.normal(size=K) + 0.2 * rng.normal(size=N) > 0,
                 1.0, -1.0)
    ok = True

    # --- 1. kill between iterations / mid-pass between chunks -> bitwise
    for algo, cadence in (("EM", dict(ckpt_every=2)),
                          ("MC", dict(ckpt_every=100, ckpt_chunks=3))):
        kw = dict(algorithm=algo, driver="stream", chunk_rows=64,
                  max_iters=10, min_iters=10, burnin=3)
        ref = PEMSVM(SVMConfig(**kw)).fit(X, y)
        with tempfile.TemporaryDirectory() as d:
            cfg = SVMConfig(**kw, fault=FaultPolicy(ckpt_dir=d, **cadence))
            try:
                if algo == "EM":
                    PEMSVM(cfg).fit(X, y,
                                    fault_hook=faults.kill_at_iteration(6))
                else:
                    # 7 chunks/pass after padding; die inside pass 3
                    PEMSVM(cfg).fit(X, y,
                                    fault_hook=faults.kill_at_iteration(4))
                print(f"{algo}: kill did not fire")
                return 1
            except faults.SimulatedPreemption:
                pass
            res = PEMSVM(cfg).fit(X, y, resume_from=d)
        bitwise = np.array_equal(ref.weights, res.weights)
        print(f"{algo} stream kill/resume: bitwise={bitwise} "
              f"resumed_at={res.resumed_at} ckpts={res.n_checkpoints}")
        ok &= bitwise

    # --- 2. stream-written checkpoint restores into the scan driver
    # eps=1e-2 keeps the iteration map out of the 1/gamma^2
    # noise-amplifying regime so the band is gateable on CI
    # (same rationale as stream_smoke).
    kw = dict(algorithm="EM", max_iters=10, min_iters=10, eps=1e-2)
    ref = PEMSVM(SVMConfig(**kw, driver="scan", scan_chunk=4)).fit(X, y)
    with tempfile.TemporaryDirectory() as d:
        pol = FaultPolicy(ckpt_dir=d, ckpt_every=3)
        try:
            PEMSVM(SVMConfig(**kw, driver="stream", chunk_rows=64,
                             fault=pol)).fit(
                X, y, fault_hook=faults.kill_at_iteration(6))
        except faults.SimulatedPreemption:
            pass
        res = PEMSVM(SVMConfig(**kw, driver="scan", scan_chunk=4,
                               fault=pol)).fit(X, y, resume_from=d)
    rel = (np.abs(ref.weights - res.weights).max()
           / np.abs(ref.weights).max())
    print(f"stream->scan elastic resume: rel={rel:.3e}")
    ok &= rel < 1e-3

    # --- 3. budget extension is bitwise vs the one-shot fit
    kw = dict(algorithm="EM", driver="loop", min_iters=1, tol=1e-12)
    with tempfile.TemporaryDirectory() as d:
        pol = FaultPolicy(ckpt_dir=d, ckpt_every=5)
        PEMSVM(SVMConfig(**kw, max_iters=5, fault=pol)).fit(X, y)
        r2 = PEMSVM(SVMConfig(**kw, max_iters=10, fault=pol)).fit(
            X, y, resume_from=d)
    ref = PEMSVM(SVMConfig(**kw, max_iters=10)).fit(X, y)
    extend_ok = (r2.resumed_at == 5
                 and np.array_equal(ref.weights, r2.weights))
    print(f"extend budget 5->10: bitwise={extend_ok}")
    ok &= extend_ok

    if not ok:
        print("ELASTIC SMOKE FAIL")
        return 1
    print("elastic smoke complete")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
