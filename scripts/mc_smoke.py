#!/usr/bin/env python
"""CPU single-stream-Gibbs smoke for CI (mirrors the stream/krn smoke
pattern): the fused MC and SVR epilogue paths, gated on draw parity and
stream-vs-resident fit parity.

Gates:

  * BITWISE draw parity: the fused MC-CLS statistic's gamma draws (and
    SVR's gamma/omega double mixture) equal the ``gamma_mc_rowwise`` /
    split-key oracles bit for bit on the dispatch path — the property
    that makes MC chains chunking- and sharding-invariant;
  * MC-CLS stream-vs-resident whole-fit parity on a short chain
    (<= 2e-4 rel-err — the IG accept-reject branch is the documented
    fp32 fork channel, so MC is gated looser than EM);
  * EM-SVR stream-vs-resident whole-fit parity (<= 1e-4 rel-err —
    deterministic, so tight even on noisy CI machines).
"""
from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main() -> int:
    import numpy as np

    import jax
    import jax.numpy as jnp

    from repro.core import PEMSVM, SVMConfig, augment
    from repro.kernels import ops

    # Same problem family/size as tests/test_streaming.py's whole-fit
    # parity matrix — chosen inside the non-chaotic window where the
    # MC fork channel stays within the 2e-4 band on short chains.
    rng = np.random.default_rng(0)
    N, K = 1024, 16
    X = rng.normal(size=(N, K)).astype(np.float32)
    w_true = rng.normal(size=K)
    y = np.where(X @ w_true + 0.3 * rng.normal(size=N) > 0,
                 1.0, -1.0).astype(np.float32)
    ys = (X @ w_true).astype(np.float32)

    # --- gate 1: bitwise draw parity on the fused statistic ----------
    w = jnp.asarray(rng.normal(size=K).astype(np.float32))
    key = jax.random.PRNGKey(7)
    Xd, yd = jnp.asarray(X), jnp.asarray(y)
    margin = Xd @ w
    g_want = augment.gamma_mc_rowwise(key, yd - margin, 1e-6, 5)
    noise = augment.draw_ig_noise(key, N, 5)
    out = ops.fused_stats(Xd, yd, yd, w, None, noise,
                          epilogue="mc_hinge", eps=1e-6, backend="ref")
    cls_ok = np.array_equal(np.asarray(out[1]), np.asarray(g_want))

    k_lo, k_hi = jax.random.split(key)
    res = jnp.asarray(ys) - margin
    gs = augment.gamma_mc_rowwise(k_lo, res - 0.2, 1e-6, 5)
    os_ = augment.gamma_mc_rowwise(k_hi, res + 0.2, 1e-6, 5)
    n4 = (*augment.draw_ig_noise(k_lo, N, 5),
          *augment.draw_ig_noise(k_hi, N, 5))
    out = ops.fused_stats(Xd, jnp.asarray(ys), jnp.zeros(N), w, None,
                          n4, epilogue="mc_svr", eps=1e-6, eps_ins=0.2,
                          backend="ref")
    svr_ok = (np.array_equal(np.asarray(out[1]), np.asarray(gs))
              and np.array_equal(np.asarray(out[2]), np.asarray(os_)))
    print(f"draw parity: cls bitwise={cls_ok} svr bitwise={svr_ok}")
    if not (cls_ok and svr_ok):
        print("MC DRAW PARITY FAIL")
        return 1

    # --- gate 2: MC-CLS stream vs resident (short chain) -------------
    kw = dict(algorithm="MC", eps=1e-2, burnin=8, max_iters=16,
              min_iters=16)
    resident = PEMSVM(SVMConfig(**kw)).fit(X, y)
    streamed = PEMSVM(SVMConfig(driver="stream", chunk_rows=100,
                                **kw)).fit(X, y)
    rel_mc = (np.abs(streamed.weights - resident.weights).max()
              / np.abs(resident.weights).max())
    print(f"MC-CLS stream-vs-resident rel-err: {rel_mc:.3e}")
    if rel_mc > 2e-4:
        print("MC STREAM PARITY FAIL")
        return 1

    # --- gate 3: EM-SVR stream vs resident (deterministic) -----------
    kw = dict(task="SVR", eps=1e-2, eps_ins=0.3, max_iters=20,
              min_iters=20)
    resident = PEMSVM(SVMConfig(**kw)).fit(X, ys)
    streamed = PEMSVM(SVMConfig(driver="stream", chunk_rows=100,
                                **kw)).fit(X, ys)
    rel_svr = (np.abs(streamed.weights - resident.weights).max()
               / np.abs(resident.weights).max())
    print(f"EM-SVR stream-vs-resident rel-err: {rel_svr:.3e}")
    if rel_svr > 1e-4:
        print("SVR STREAM PARITY FAIL")
        return 1

    print("mc smoke complete")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
