#!/usr/bin/env python
"""CPU 2-D (data x model) k_shard smoke for CI (mirrors the stream/krn/
mc smoke pattern): the column-windowed single-stream statistic on a
real multi-device mesh, gated on parity with the replicated path.

Forces 2 emulated CPU devices (the env var must be set before jax
initializes, hence at module top) and builds a (1, 2) (data, model)
mesh, so the windowed kernels run under real shard_map axis indices.

Gates:

  * EM-CLS k_shard whole-fit parity vs the single-device fit
    (<= 1e-3 rel — deterministic; the data axis has ONE shard, so the
    only fp channel is the windowed-matmul split);
  * MC-CLS chain identity: iteration one EXACT (the rowwise-keyed
    draws are layout-invariant), short-chain trace within the
    documented fp32 band;
  * k_shard x phi_spec (Nystrom) EM whole-fit parity <= 1e-4 — the
    composition this PR unlocks (was NotImplementedError);
  * SVMConfig.pad_features route: an indivisible width fits and
    predictions match the unpadded fit.
"""
from __future__ import annotations

import os
import sys

os.environ.setdefault("XLA_FLAGS", "")
if "--xla_force_host_platform_device_count" not in os.environ["XLA_FLAGS"]:
    os.environ["XLA_FLAGS"] += " --xla_force_host_platform_device_count=2"

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main() -> int:
    import numpy as np

    from repro import compat
    from repro.core import PEMSVM, SVMConfig
    from repro.core.nystrom import NystromSVM

    mesh = compat.make_mesh((1, 2), ("data", "model"),
                            axis_types=("auto",) * 2)
    rng = np.random.default_rng(0)
    N, K = 1024, 23                    # +bias -> 24, model axis 2 | 24
    w_true = rng.normal(size=K)
    X = rng.normal(size=(N, K)).astype(np.float32)
    y = np.where(X @ w_true + 0.3 * rng.normal(size=N) > 0, 1.0, -1.0)
    ok = True

    # --- gate 1: EM-CLS k_shard whole-fit parity ----------------------
    em = dict(max_iters=20, min_iters=20, eps=1e-2)
    r1 = PEMSVM(SVMConfig(**em)).fit(X, y)
    rk = PEMSVM(SVMConfig(k_shard_axis="model", **em), mesh=mesh,
                data_axes=("data",)).fit(X, y)
    rel = np.abs(rk.weights - r1.weights).max() / np.abs(r1.weights).max()
    print(f"EM-CLS k_shard rel err: {rel:.2e} (gate 1e-3)")
    ok &= rel < 1e-3

    # --- gate 2: MC-CLS chain identity --------------------------------
    mc = dict(algorithm="MC", max_iters=12, min_iters=12, eps=1e-2,
              burnin=6)
    m1 = PEMSVM(SVMConfig(**mc)).fit(X, y)
    mk = PEMSVM(SVMConfig(k_shard_axis="model", **mc), mesh=mesh,
                data_axes=("data",)).fit(X, y)
    tr = np.abs(np.array(mk.objective) - np.array(m1.objective)) / (
        np.abs(np.array(m1.objective)))
    print(f"MC-CLS k_shard trace rel: iter1={tr[0]:.2e} max={tr.max():.2e}"
          " (gates 1e-6 / 2e-3)")
    ok &= tr[0] < 1e-6 and tr.max() < 2e-3

    # --- gate 3: k_shard x phi_spec (Nystrom) EM parity ---------------
    def kcfg(**kw):
        return SVMConfig(formulation="KRN", sigma=5.0, lam=0.1,
                         eps=1e-2, max_iters=15, min_iters=15, **kw)

    n1 = NystromSVM(kcfg(), n_landmarks=31)       # phi width 32 -> | 2
    rn1 = n1.fit(X, y)
    nk = NystromSVM(kcfg(k_shard_axis="model"), n_landmarks=31,
                    mesh=mesh, data_axes=("data",))
    rnk = nk.fit(X, y)
    rel = np.abs(rnk.weights - rn1.weights).max() / np.abs(
        rn1.weights).max()
    print(f"KRN(Nystrom) k_shard rel err: {rel:.2e} (gate 1e-4), "
          f"scores {n1.score(X, y):.3f}/{nk.score(X, y):.3f}")
    ok &= rel < 1e-4

    # --- gate 4: pad_features route ------------------------------------
    base = PEMSVM(SVMConfig(add_bias=False, **em)).fit(X, y)
    pk = PEMSVM(SVMConfig(add_bias=False, k_shard_axis="model",
                          pad_features=2, **em),
                mesh=mesh, data_axes=("data",))
    rp = pk.fit(X, y)
    rel = np.abs(rp.weights[:K] - base.weights).max() / np.abs(
        base.weights).max()
    print(f"pad_features k_shard rel err: {rel:.2e} (gate 1e-3), "
          f"padded width {rp.weights.shape[0]}")
    ok &= rel < 1e-3 and rp.weights.shape == (24,)

    if not ok:
        print("KSHARD SMOKE FAIL")
        return 1
    print("KSHARD SMOKE OK")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
