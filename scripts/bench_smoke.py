#!/usr/bin/env python
"""Perf smoke check for CI: run a tiny Table-9 gram benchmark.

No thresholds — the check is that the benchmark *completes* and writes
``BENCH_gram.json`` (the speedup numbers are tracked across PRs as an
artifact, not gated; CI machines are too noisy for wall-clock gates).
Exits nonzero if the triangle kernel loses exact-ish parity with the
dense kernel, which IS deterministic and gateable.
"""
from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


def main() -> int:
    from benchmarks import table9_gram

    rows = table9_gram.run(n=20_000, k=256, bench_n=1024)
    syrk_rows = [r for r in rows if r["name"].startswith("syrk_")]
    assert syrk_rows, "benchmark produced no syrk comparison rows"
    for r in syrk_rows:
        if r["max_abs_err"] > 1e-2:
            print(f"PARITY FAIL: {r}")
            return 1
        print(f"ok {r['name']}: tri/dense = {r['tri_over_dense']}")
    if not os.path.exists(table9_gram.BENCH_JSON):
        print("BENCH_gram.json was not written")
        return 1
    print("bench smoke complete")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
