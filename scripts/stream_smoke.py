#!/usr/bin/env python
"""CPU streaming smoke for CI: tiny libsvm file -> ``driver="stream"``
fit -> weight parity against the resident scan driver.

Writes a small classification dataset (with comment/blank lines, to
exercise the hardened parser) to a tmpdir in libsvm format, fits it
out-of-core with chunk_rows < N/8, and gates on:

  * final-weight parity with the resident fit (<= 1e-4 rel-err — the
    deterministic EM path, so this IS gateable on noisy CI machines);
  * peak device input residency <= (prefetch+2) chunks.
"""
from __future__ import annotations

import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main() -> int:
    import numpy as np

    from repro.core import PEMSVM, SVMConfig
    from repro.data import save_libsvm

    rng = np.random.default_rng(0)
    N, K = 800, 12
    X = rng.normal(size=(N, K)).astype(np.float32)
    X *= rng.random(size=(N, K)) > 0.3          # sparsity, like real libsvm
    y = np.where(X @ rng.normal(size=K) + 0.2 * rng.normal(size=N) > 0,
                 1.0, -1.0)

    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "smoke.libsvm")
        save_libsvm(path, X, y)
        lines = open(path).read().splitlines()
        with open(path, "w") as f:
            f.write("# stream_smoke dataset\n\n")
            for i, ln in enumerate(lines):
                f.write(ln + ("  # sv" if i % 13 == 0 else "") + "\n")

        kw = dict(eps=1e-2, max_iters=20, min_iters=20)
        resident = PEMSVM(SVMConfig(**kw)).fit(X, y)
        chunk_rows = 64                          # < N/8 = 100
        model = PEMSVM(SVMConfig(driver="stream", chunk_rows=chunk_rows,
                                 prefetch=2, **kw))
        streamed = model.fit_libsvm(path, n_features=K)

    rel = (np.abs(streamed.weights - resident.weights).max()
           / np.abs(resident.weights).max())
    # (prefetch + 2) chunks: queued + worker in-hand + consumer
    bound = 4 * (chunk_rows * (K + 1) * 4 + 2 * chunk_rows * 4)
    print(f"weights rel-err: {rel:.3e}   "
          f"peak input bytes: {streamed.peak_input_bytes} (bound {bound})")
    if rel > 1e-4:
        print("STREAM PARITY FAIL")
        return 1
    if not 0 < streamed.peak_input_bytes <= bound:
        print("STREAM RESIDENCY FAIL")
        return 1
    print("stream smoke complete")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
