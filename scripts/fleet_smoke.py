#!/usr/bin/env python
"""CPU fleet-controller smoke for CI: run a fit fleet through a
deterministic chaos schedule and demand the undisturbed bits
(DESIGN.md §Reliability).

Three gates, strongest first:

  * chaos recovery — a streaming MC fit supervised by
    ``FleetController`` is preempted (SIGKILL-style) on attempt 0 and
    evicted (SIGTERM-style) on attempt 1; the completing attempt's
    weights must equal the uninterrupted fit's BITWISE (the flaky-
    loader leg of the schedule is pinned in tests/test_fleet.py);
  * windowed statistics — hard expiry is EXACT: a donor dragging
    generations beyond the horizon changes nothing (bitwise), and a
    killed windowed fit resumes bit-identically (the ring rides the
    checkpoint);
  * real process supervision — a ``SubprocessHost`` that crashes on
    attempt 0 is classified retryable and the relaunch completes.
"""
from __future__ import annotations

import os
import sys
import tempfile
import textwrap

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main() -> int:
    import numpy as np

    from repro.core import PEMSVM, SVMConfig
    from repro.runtime import faults
    from repro.runtime.controller import (FleetController, FleetPolicy,
                                          SubprocessHost)
    from repro.runtime.faults import FleetSchedule
    from repro.runtime.policy import FaultPolicy

    rng = np.random.default_rng(0)
    N, K = 400, 12
    X = rng.normal(size=(N, K)).astype(np.float32)
    y = np.where(X @ rng.normal(size=K) + 0.2 * rng.normal(size=N) > 0,
                 1.0, -1.0)
    ok = True

    # --- 1. chaos schedule -> bitwise recovery --------------------------
    kw = dict(algorithm="MC", driver="stream", chunk_rows=64,
              max_iters=10, min_iters=10, burnin=3)
    ref = PEMSVM(SVMConfig(**kw)).fit(X, y)
    with tempfile.TemporaryDirectory() as d:
        pol = FaultPolicy(ckpt_dir=d, ckpt_every=2, loader_retries=3,
                          loader_backoff=1e-3)
        cfg = SVMConfig(**kw, fault=pol)

        def make_host(level):
            def host(ctx, svm=PEMSVM(cfg)):
                return svm.fit(X, y, resume_from=ctx.resume_from,
                               fault_hook=ctx.fault_hook)
            return host

        fr = FleetController(
            make_host, d,
            policy=FleetPolicy(max_attempts=5, backoff_s=1e-3),
            schedule=FleetSchedule({
                0: lambda cancel: faults.kill_at_iteration(4),
                1: lambda cancel: faults.terminate_at_iteration(7),
            })).run()
    bitwise = np.array_equal(ref.weights, fr.result.weights)
    outcomes = [a.outcome for a in fr.attempts]
    print(f"chaos fleet: bitwise={bitwise} outcomes={outcomes} "
          f"resumed_at={fr.result.resumed_at}")
    ok &= bitwise and outcomes == ["retryable", "retryable", "completed"]

    # --- 2. windowed statistics: exact expiry + resume-exact ring -------
    import dataclasses

    kw = dict(algorithm="EM", driver="stream", chunk_rows=64,
              max_iters=6, min_iters=6, window=2)
    g1 = PEMSVM(SVMConfig(**kw)).fit(X, y)
    g2 = PEMSVM(SVMConfig(**kw)).fit(X, -y, warm_start=g1)
    g3a = PEMSVM(SVMConfig(**kw)).fit(X, y, warm_start=g2)
    fat = dataclasses.replace(g2,
                              stats_window=g2.stats_window
                              + g1.stats_window)
    g3b = PEMSVM(SVMConfig(**kw)).fit(X, y, warm_start=fat)
    expiry = np.array_equal(g3a.weights, g3b.weights)
    folds = not np.allclose(
        g3a.weights, PEMSVM(SVMConfig(**kw)).fit(X, y).weights)
    with tempfile.TemporaryDirectory() as d:
        polw = FaultPolicy(ckpt_dir=d, ckpt_every=2)
        cfgw = SVMConfig(**kw, fault=polw)
        refw = PEMSVM(SVMConfig(**kw)).fit(X, -y, warm_start=g1)
        try:
            PEMSVM(cfgw).fit(X, -y, warm_start=g1,
                             fault_hook=faults.kill_at_iteration(3))
            print("window kill did not fire")
            return 1
        except faults.SimulatedPreemption:
            pass
        resw = PEMSVM(cfgw).fit(X, -y, resume_from=d)
    resume_exact = np.array_equal(refw.weights, resw.weights)
    print(f"window: hard_expiry_exact={expiry} folds={folds} "
          f"kill_resume_bitwise={resume_exact}")
    ok &= expiry and folds and resume_exact

    # --- 3. SubprocessHost: crash -> retry -> complete ------------------
    code = textwrap.dedent("""
        import os, sys
        sys.exit(3 if os.environ["FLEET_ATTEMPT"] == "0" else 0)
    """)
    with tempfile.TemporaryDirectory() as d:
        fr = FleetController(
            lambda level: SubprocessHost(code, load_result=lambda: "ok"),
            d, policy=FleetPolicy(max_attempts=3, backoff_s=1e-3)).run()
    sub_ok = (fr.result == "ok"
              and [a.outcome for a in fr.attempts]
              == ["retryable", "completed"])
    print(f"subprocess host: recovered={sub_ok}")
    ok &= sub_ok

    if not ok:
        print("FLEET SMOKE FAIL")
        return 1
    print("fleet smoke complete")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
