#!/usr/bin/env python
"""CPU fleet-controller smoke for CI: run a fit fleet through a
deterministic chaos schedule and demand the undisturbed bits
(DESIGN.md §Reliability).

Four gates, strongest first:

  * chaos recovery — a streaming MC fit supervised by
    ``FleetController`` is preempted (SIGKILL-style) on attempt 0 and
    evicted (SIGTERM-style) on attempt 1; the completing attempt's
    weights must equal the uninterrupted fit's BITWISE (the flaky-
    loader leg of the schedule is pinned in tests/test_fleet.py);
  * split-brain takeover — two controllers co-supervise one checkpoint
    directory; the leader freezes mid-supervision with a
    non-cooperative zombie worker, the standby takes over at term+1,
    the zombie's late commit is REJECTED at the rename boundary
    (epoch fencing), and the recovered model is bitwise the
    undisturbed fit;
  * windowed statistics — hard expiry is EXACT: a donor dragging
    generations beyond the horizon changes nothing (bitwise), and a
    killed windowed fit resumes bit-identically (the ring rides the
    checkpoint);
  * real process supervision — a ``SubprocessHost`` that crashes on
    attempt 0 is classified retryable and the relaunch completes.
"""
from __future__ import annotations

import os
import sys
import tempfile
import textwrap

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main() -> int:
    import numpy as np

    from repro.core import PEMSVM, SVMConfig
    from repro.runtime import faults
    from repro.runtime.controller import (FleetController, FleetPolicy,
                                          SubprocessHost)
    from repro.runtime.faults import FleetSchedule
    from repro.runtime.policy import FaultPolicy

    rng = np.random.default_rng(0)
    N, K = 400, 12
    X = rng.normal(size=(N, K)).astype(np.float32)
    y = np.where(X @ rng.normal(size=K) + 0.2 * rng.normal(size=N) > 0,
                 1.0, -1.0)
    ok = True

    # --- 1. chaos schedule -> bitwise recovery --------------------------
    kw = dict(algorithm="MC", driver="stream", chunk_rows=64,
              max_iters=10, min_iters=10, burnin=3)
    ref = PEMSVM(SVMConfig(**kw)).fit(X, y)
    with tempfile.TemporaryDirectory() as d:
        pol = FaultPolicy(ckpt_dir=d, ckpt_every=2, loader_retries=3,
                          loader_backoff=1e-3)
        cfg = SVMConfig(**kw, fault=pol)

        def make_host(level):
            def host(ctx, svm=PEMSVM(cfg)):
                return svm.fit(X, y, resume_from=ctx.resume_from,
                               fault_hook=ctx.fault_hook)
            return host

        fr = FleetController(
            make_host, d,
            policy=FleetPolicy(max_attempts=5, backoff_s=1e-3),
            schedule=FleetSchedule({
                0: lambda cancel: faults.kill_at_iteration(4),
                1: lambda cancel: faults.terminate_at_iteration(7),
            })).run()
    bitwise = np.array_equal(ref.weights, fr.result.weights)
    outcomes = [a.outcome for a in fr.attempts]
    print(f"chaos fleet: bitwise={bitwise} outcomes={outcomes} "
          f"resumed_at={fr.result.resumed_at}")
    ok &= bitwise and outcomes == ["retryable", "retryable", "completed"]

    # --- 2. split-brain: frozen leader, takeover, fenced zombie ---------
    import threading
    import time

    from repro.checkpoint import Checkpointer, FencedCommitError
    from repro.runtime.controller import FleetError
    from repro.runtime.lease import LeasePolicy

    kw2 = dict(algorithm="EM", driver="loop", max_iters=10, min_iters=10)
    ref2 = PEMSVM(SVMConfig(**kw2)).fit(X, y)
    with tempfile.TemporaryDirectory() as d:
        cfg2 = SVMConfig(**kw2, fault=FaultPolicy(ckpt_dir=d,
                                                  ckpt_every=1))
        frozen, release = threading.Event(), threading.Event()
        zombie: dict = {}

        def make_rogue(level):
            def host(ctx):
                try:   # ignores cancel: a genuine zombie worker
                    return PEMSVM(cfg2).fit(
                        X, y, resume_from=ctx.resume_from,
                        fault_hook=faults.hold_at_iteration(
                            5, release=release, max_seconds=300.0),
                        epoch=ctx.epoch)
                except Exception as e:  # noqa: BLE001 — recorded
                    zombie["error"] = e
                    raise
            return host

        def make_fenced(level):
            def host(ctx):
                return PEMSVM(cfg2).fit(X, y, resume_from=ctx.resume_from,
                                        fault_hook=ctx.fault_hook,
                                        epoch=ctx.epoch)
            return host

        lease = LeasePolicy(ttl_s=0.6, renew_every_s=0.1, poll_s=0.05)
        A = FleetController(
            make_rogue, d,
            policy=FleetPolicy(max_attempts=2, poll_s=0.02,
                               kill_grace_s=0.3),
            lease=lease, owner="smoke-A",
            sleep=faults.freezable_sleep(frozen, max_seconds=300.0))
        B = FleetController(
            make_fenced, d,
            policy=FleetPolicy(max_attempts=2, poll_s=0.02),
            lease=lease, owner="smoke-B")
        out: dict = {}

        def run_a():
            try:
                out["A"] = A.run()
            except FleetError as e:     # LeadershipLost expected
                out["A"] = e

        ta = threading.Thread(target=run_a)
        ta.start()
        watcher = Checkpointer(d, keep_k=0)
        deadline = time.time() + 300.0
        while (watcher.latest_record() or (0, 0))[1] < 5_000_000:
            if time.time() > deadline:
                print("leader's worker never held")
                return 1
            time.sleep(0.02)
        frozen.set()
        tb = threading.Thread(
            target=lambda: out.__setitem__("B", B.run()))
        tb.start()
        tb.join(timeout=300.0)
        fr_b = out["B"]
        records = watcher.all_records()
        release.set()
        while "error" not in zombie:
            if time.time() > deadline:
                print("zombie never hit the fence")
                return 1
            time.sleep(0.02)
        frozen.clear()
        ta.join(timeout=300.0)
        lost = [r for r in watcher.all_records() if r not in records]
    bitwise2 = np.array_equal(ref2.weights, fr_b.result.weights)
    fenced = isinstance(zombie["error"], FencedCommitError)
    print(f"split-brain: takeover_term={fr_b.term} bitwise={bitwise2} "
          f"zombie_fenced={fenced} lost_commits={len(lost)}")
    ok &= fr_b.term == 2 and bitwise2 and fenced and not lost

    # --- 3. windowed statistics: exact expiry + resume-exact ring -------
    import dataclasses

    kw = dict(algorithm="EM", driver="stream", chunk_rows=64,
              max_iters=6, min_iters=6, window=2)
    g1 = PEMSVM(SVMConfig(**kw)).fit(X, y)
    g2 = PEMSVM(SVMConfig(**kw)).fit(X, -y, warm_start=g1)
    g3a = PEMSVM(SVMConfig(**kw)).fit(X, y, warm_start=g2)
    fat = dataclasses.replace(g2,
                              stats_window=g2.stats_window
                              + g1.stats_window)
    g3b = PEMSVM(SVMConfig(**kw)).fit(X, y, warm_start=fat)
    expiry = np.array_equal(g3a.weights, g3b.weights)
    folds = not np.allclose(
        g3a.weights, PEMSVM(SVMConfig(**kw)).fit(X, y).weights)
    with tempfile.TemporaryDirectory() as d:
        polw = FaultPolicy(ckpt_dir=d, ckpt_every=2)
        cfgw = SVMConfig(**kw, fault=polw)
        refw = PEMSVM(SVMConfig(**kw)).fit(X, -y, warm_start=g1)
        try:
            PEMSVM(cfgw).fit(X, -y, warm_start=g1,
                             fault_hook=faults.kill_at_iteration(3))
            print("window kill did not fire")
            return 1
        except faults.SimulatedPreemption:
            pass
        resw = PEMSVM(cfgw).fit(X, -y, resume_from=d)
    resume_exact = np.array_equal(refw.weights, resw.weights)
    print(f"window: hard_expiry_exact={expiry} folds={folds} "
          f"kill_resume_bitwise={resume_exact}")
    ok &= expiry and folds and resume_exact

    # --- 4. SubprocessHost: crash -> retry -> complete ------------------
    code = textwrap.dedent("""
        import os, sys
        sys.exit(3 if os.environ["FLEET_ATTEMPT"] == "0" else 0)
    """)
    with tempfile.TemporaryDirectory() as d:
        fr = FleetController(
            lambda level: SubprocessHost(code, load_result=lambda: "ok"),
            d, policy=FleetPolicy(max_attempts=3, backoff_s=1e-3)).run()
    sub_ok = (fr.result == "ok"
              and [a.outcome for a in fr.attempts]
              == ["retryable", "completed"])
    print(f"subprocess host: recovered={sub_ok}")
    ok &= sub_ok

    if not ok:
        print("FLEET SMOKE FAIL")
        return 1
    print("fleet smoke complete")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
