#!/usr/bin/env python
"""CPU serving smoke for CI: the fused featurize-and-score path must
serve the same bits as ``decision_function``, compile once per bucket,
and keep phi out of HBM (DESIGN.md §Serving).

Gates:

  * bitwise parity — continuous-batched, bucket-padded served scores
    equal the decision_function oracle bit for bit, for a linear and a
    Nystrom model, including 1-row requests coalesced with large ones;
  * no-retrace — repeat requests at a seen bucket add ZERO compilations
    (trace counter), and a second same-config tenant reuses the cell;
  * phi residency — the traced jaxpr of the Nystrom score cell has no
    full-batch (bucket, m) intermediate;
  * uncertainty — MC-posterior serving returns margin bitwise plus a
    positive finite std from the same single dispatch.
"""
from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main() -> int:
    import numpy as np

    from repro.core import PEMSVM, SVMConfig
    from repro.core.nystrom import NystromSVM
    from repro.serving import ServeLoop, WeightPager, phi_never_materialized

    rng = np.random.default_rng(0)
    X = rng.normal(size=(900, 16)).astype(np.float32)
    w = rng.normal(size=16)
    y = np.where(X @ w > 0, 1.0, -1.0).astype(np.float32)

    lin = PEMSVM(SVMConfig(max_iters=20, min_iters=5))
    lin.fit(X, y)
    ny = NystromSVM(SVMConfig(formulation="KRN", sigma=3.0, lam=0.1,
                              max_iters=20, min_iters=5), n_landmarks=32)
    ny.fit(X, y)

    failures = []
    pager = WeightPager()
    for name, model in (("lin", lin), ("ny", ny)):
        pager.register(model.export_servable(name=name))
    loop = ServeLoop(pager)

    # --- gate: coalesced ragged requests == oracle, bitwise ----------
    for name, model in (("lin", lin), ("ny", ny)):
        futs = [loop.submit(name, X[j:j + n])
                for j, n in ((0, 1), (1, 77), (78, 130), (208, 292))]
        loop.step()
        served = np.concatenate([f.result(timeout=30)[:, 0] for f in futs])
        oracle = model.decision_function(X[:500])
        if not np.array_equal(served, oracle):
            failures.append(f"{name}: served bits != decision_function")
        print(f"bitwise parity [{name}]: "
              f"{np.array_equal(served, oracle)}")

    # --- gate: zero retrace at seen buckets, cell shared -------------
    sc = pager.scorer("ny")
    sc.score(X[:90])                    # warm the 128 bucket
    t0 = sc.traces
    for n in (90, 17, 128, 1, 64):      # all land in the 128 bucket
        sc.score(X[:n])
    retraces = sc.traces - t0
    ny2 = NystromSVM(SVMConfig(formulation="KRN", sigma=3.0, lam=0.1,
                               max_iters=10, min_iters=5), n_landmarks=32)
    ny2.fit(X, y)
    shared = pager.scorer("ny").traces
    pager.register(ny2.export_servable(name="ny2"))
    pager.scorer("ny2").score(X[:50])
    shared_ok = pager.scorer("ny2").traces == shared
    print(f"no-retrace at seen bucket: {retraces == 0} "
          f"(new traces={retraces}); second tenant reuses cell: "
          f"{shared_ok}")
    if retraces:
        failures.append(f"{retraces} retraces at a seen bucket")
    if not shared_ok:
        failures.append("same-config tenant recompiled the cell")

    # --- gate: phi stays in VMEM -------------------------------------
    resident = phi_never_materialized(sc, 512)
    print(f"phi never materialized at bucket 512: {resident}")
    if not resident:
        failures.append("full-batch phi found in the traced jaxpr")

    # --- gate: posterior head serves margin bitwise + finite std -----
    from repro.serving import SVMScorer
    scp = SVMScorer(lin.export_servable(posterior_from=(X, y)))
    margin, std = scp.score_with_std(X[:200])
    m_ok = np.array_equal(margin, lin.decision_function(X[:200]))
    s_ok = bool(np.all(np.isfinite(std)) and np.all(std > 0))
    print(f"posterior margin bitwise: {m_ok}; std finite>0: {s_ok}")
    if not (m_ok and s_ok):
        failures.append("posterior serving head broken")

    if failures:
        print(f"FAILED: {failures}")
        return 1
    print("serve smoke OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
