#!/usr/bin/env python
"""CPU nonlinear-streaming smoke for CI: tiny libsvm file ->
``NystromSVM(driver="stream")`` fit -> parity against the host-phi
resident baseline (mirrors scripts/stream_smoke.py for the KRN path).

Writes a small rbf-separable dataset to a tmpdir in libsvm format, fits
it out-of-core — reservoir-sampled landmarks, then raw D-wide chunks
streamed through the fused featurize-and-accumulate statistic — and
gates on:

  * final-weight parity with the float64 host-featurized resident fit
    on the SAME landmarks (<= 1e-4 rel-err — deterministic EM, so this
    IS gateable on noisy CI machines);
  * peak device input residency <= (prefetch+2) RAW chunks (D-wide,
    not m-wide: the (N, m) phi matrix must never exist).
"""
from __future__ import annotations

import dataclasses
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main() -> int:
    import numpy as np

    from repro.core import NystromSVM, PEMSVM, SVMConfig
    from repro.core.nystrom import nystrom_features
    from repro.data import save_libsvm

    rng = np.random.default_rng(0)
    N, D, m = 900, 10, 48
    X = rng.normal(size=(N, D)).astype(np.float32)
    wt = rng.normal(size=D)
    y = np.where(np.tanh(X @ wt) + 0.2 * rng.normal(size=N) > 0,
                 1.0, -1.0).astype(np.float32)

    chunk_rows, prefetch = 96, 2                 # < N/8 = 112
    cfg = SVMConfig(formulation="KRN", driver="stream",
                    chunk_rows=chunk_rows, prefetch=prefetch,
                    lam=1.0, sigma=3.0, eps=1e-2,
                    max_iters=15, min_iters=15)
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "krn_smoke.libsvm")
        save_libsvm(path, X, y)
        model = NystromSVM(cfg, n_landmarks=m)
        streamed = model.fit_libsvm(path, n_features=D)

    phi = nystrom_features(X, model._landmarks, sigma=3.0)
    base = PEMSVM(dataclasses.replace(model.svm.config, phi_spec=None,
                                      add_bias=True, driver="scan"))
    resident = base.fit(phi, y)

    rel = (np.abs(streamed.weights - resident.weights).max()
           / np.abs(resident.weights).max())
    # (prefetch + 2) RAW chunks: queued + worker in-hand + consumer
    bound = (prefetch + 2) * (chunk_rows * D * 4 + 2 * chunk_rows * 4)
    phi_bytes = N * (m + 1) * 4
    print(f"weights rel-err: {rel:.3e}   peak input bytes: "
          f"{streamed.peak_input_bytes} (bound {bound}, "
          f"phi residency would be {phi_bytes})")
    if rel > 1e-4:
        print("KRN STREAM PARITY FAIL")
        return 1
    if not 0 < streamed.peak_input_bytes <= bound:
        print("KRN STREAM RESIDENCY FAIL")
        return 1
    if streamed.peak_input_bytes >= phi_bytes:
        print("KRN STREAM RESIDENCY FAIL (not below phi residency)")
        return 1
    print("krn smoke complete")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
