"""§Perf hillclimb harness: run a cell under a series of option variants,
recording the roofline-term deltas per iteration. Used for the three
chosen cells; each variant is one hypothesis -> change -> re-lower ->
measure cycle logged into EXPERIMENTS.md §Perf.

    PYTHONPATH=src python scripts/hillclimb.py --cell yi-34b:train_4k \
        --variant baseline "" --variant skip_blocks skip_masked_blocks=1
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys


def run_variant(arch, shape, opts, out, multi=False, timeout=2400):
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape, "--out", out]
    if multi:
        cmd.append("--multi-pod")
    for o in opts:
        if o:
            cmd += ["--opt", o]
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.abspath("src"), env.get("PYTHONPATH", "")])
    p = subprocess.run(cmd, capture_output=True, text=True, env=env,
                       timeout=timeout)
    try:
        return json.loads(p.stdout[p.stdout.index("{"):])
    except Exception:  # noqa: BLE001
        return {"ok": False, "error": (p.stderr or p.stdout)[-800:]}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True)       # arch:shape
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="runs/hillclimb")
    ap.add_argument("--variant", nargs=2, action="append", required=True,
                    metavar=("NAME", "OPTS"))      # OPTS: comma-joined k=v
    args = ap.parse_args()
    arch, shape = args.cell.split(":")
    os.makedirs(args.out, exist_ok=True)

    rows = []
    for name, optstr in args.variant:
        opts = [o for o in optstr.split(",") if o]
        rec = run_variant(arch, shape, opts, args.out, args.multi_pod)
        if not rec.get("ok"):
            print(f"{name:28s} FAILED: {rec.get('error', '')[:100]}")
            continue
        t = rec["terms"]
        rows.append((name, t, rec))
        print(f"{name:28s} compute={t['compute_s']:.4g} "
              f"memory={t['memory_s']:.4g} coll={t['collective_s']:.4g} "
              f"dom={t['dominant']:12s} "
              f"fit={'Y' if rec['memory']['fits_16gb_hbm'] else 'N'} "
              f"temp={rec['memory']['temp_bytes']/1e9:.1f}GB "
              f"ratio={rec['useful_flops_ratio']:.3f}", flush=True)
    if len(rows) > 1:
        base = rows[0][1]
        print("\ndeltas vs", rows[0][0])
        for name, t, _ in rows[1:]:
            for k in ("compute_s", "memory_s", "collective_s"):
                d = (t[k] - base[k]) / max(base[k], 1e-12) * 100
                print(f"  {name:26s} {k:13s} {d:+7.1f}%")


if __name__ == "__main__":
    main()
