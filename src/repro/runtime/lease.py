"""Lease-based leader election over the shared checkpoint directory
(DESIGN.md §Reliability).

Several controllers co-supervising one fleet must agree on exactly one
supervisor, with takeover when the leader dies — and the takeover must
compose with checkpoint EPOCH FENCING so a deposed leader's workers
cannot corrupt the recovery line. The two mechanisms share ONE
monotonic counter, the checkpoint directory's ``FENCE`` file
(``repro.checkpoint.advance_fence``):

  * a lease TERM is minted by advancing the fence (``term = fence+1``),
    so acquiring leadership immediately fences out every attempt epoch
    the previous leader ever granted — its in-flight workers find
    their commits rejected at the rename boundary before the new
    leader launches anything;
  * the leader mints each attempt's epoch the same way, so epochs and
    terms interleave on one total order and ``(epoch, step)`` snapshot
    ordering resolves the newest line unambiguously.

The lease itself is a crash-safe file (``LEASE``) in the checkpoint
directory:

    acquire   O_EXCL create — the filesystem arbitrates a dueling
              startup; exactly one creator wins, losers go standby
    renew     atomic replace (tmp + fsync + rename + dir fsync) with a
              fresh wall-clock stamp; BEFORE writing, the leader checks
              its OWN deadline — a leader that wakes from a long pause
              (GC, partition) past its ttl declares the lease lost
              without touching the file, so it can never clobber a
              usurper's lease (the standard check-your-own-clock
              fencing discipline)
    takeover  allowed only once ``stamp + ttl_s`` has passed (or the
              lease file is torn/corrupt — an unreadable lease cannot
              be renewed by anyone, so it is breakable); writes
              ``term = fence+1`` then verifies it won by re-reading
    release   unlink, only while still the owner

Wall-clock expiry is the single-host simulation of a heartbeat
session; the injectable ``clock`` keeps chaos tests deterministic.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Callable

from repro.checkpoint import advance_fence, read_fence
from repro.checkpoint.checkpointer import _fsync_path

LEASE_FILE = "LEASE"


class LeaseLost(RuntimeError):
    """The caller no longer holds the lease: its own deadline passed
    (missed renewals — GC pause, partition) or another controller's
    term is on disk. The holder must stop supervising immediately; its
    workers' commits are already fenced out by the usurper's term."""


@dataclasses.dataclass(frozen=True)
class LeasePolicy:
    """Election knobs. ``ttl_s`` is the takeover latency floor: a dead
    leader is only safe to replace once its last renewal has aged out.
    Renewals should land several times per ttl (default ttl/3) so one
    slow poll does not read as death."""

    ttl_s: float = 2.0
    renew_every_s: float | None = None   # default ttl_s / 3
    poll_s: float = 0.05                 # standby watch interval
    standby_timeout_s: float | None = None  # give up standing by (None
    #                                       = stand by forever)

    def __post_init__(self):
        assert self.ttl_s > 0.0, self.ttl_s
        assert (self.renew_every_s is None
                or 0.0 < self.renew_every_s < self.ttl_s)
        assert self.poll_s > 0.0, self.poll_s

    @property
    def renew_s(self) -> float:
        return (self.renew_every_s if self.renew_every_s is not None
                else self.ttl_s / 3.0)


@dataclasses.dataclass(frozen=True)
class LeaseState:
    term: int
    owner: str
    stamp: float                 # wall-clock seconds at grant/renewal
    ttl_s: float

    def expired(self, now: float | None = None) -> bool:
        return (time.time() if now is None else now) \
            > self.stamp + self.ttl_s


class LeaseManager:
    """One controller's handle on the election. Not thread-safe: a
    controller renews from its single supervision loop."""

    def __init__(self, directory: str, owner: str, *,
                 policy: LeasePolicy | None = None,
                 clock: Callable[[], float] = time.time):
        self.dir = str(directory)
        self.owner = str(owner)
        self.policy = policy or LeasePolicy()
        self.clock = clock
        self.path = os.path.join(self.dir, LEASE_FILE)
        self.state: LeaseState | None = None   # held lease, if any

    # ------------------------------------------------------------ file io
    def read(self) -> LeaseState | None:
        """The lease on disk, or None if absent OR unreadable. A torn
        lease write (injected chaos; a crash mid-write from a
        fsync-less older version) parses as None — no owner could renew
        it either, so takeover treats it as immediately breakable."""
        try:
            with open(self.path) as f:
                d = json.load(f)
            return LeaseState(term=int(d["term"]), owner=str(d["owner"]),
                              stamp=float(d["stamp"]),
                              ttl_s=float(d["ttl_s"]))
        except FileNotFoundError:
            return None
        except (OSError, ValueError, KeyError, json.JSONDecodeError):
            return None

    def _payload(self, st: LeaseState) -> str:
        return json.dumps({"term": st.term, "owner": st.owner,
                           "stamp": st.stamp, "ttl_s": st.ttl_s})

    def _write_excl(self, st: LeaseState) -> bool:
        """O_EXCL create — the dueling-startup arbiter. Returns False
        if another controller created the lease first."""
        try:
            fd = os.open(self.path,
                         os.O_CREAT | os.O_EXCL | os.O_WRONLY)
        except FileExistsError:
            return False
        try:
            os.write(fd, self._payload(st).encode())
            os.fsync(fd)
        finally:
            os.close(fd)
        _fsync_path(self.dir)
        return True

    def _write_replace(self, st: LeaseState) -> None:
        tmp = f"{self.path}.tmp.{self.owner}"
        with open(tmp, "w") as f:
            f.write(self._payload(st))
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, self.path)
        _fsync_path(self.dir)

    # ----------------------------------------------------------- election
    def _mint_term(self, *extra: int) -> int:
        term = max(read_fence(self.dir), *extra, 0) + 1
        advance_fence(self.dir, term, self.owner)
        return term

    def try_acquire(self) -> LeaseState | None:
        """One election round. Returns the held lease when this
        controller is (now) the leader, None when it should stand by.
        Acquiring ADVANCES THE FENCE to the new term first, so by the
        time leadership is visible every write the previous leader's
        workers could attempt is already doomed at the commit boundary.
        """
        os.makedirs(self.dir, exist_ok=True)
        cur = self.read()
        if cur is None and not os.path.exists(self.path):
            # No lease: contend via O_EXCL — filesystem picks one winner.
            st = LeaseState(term=self._mint_term(), owner=self.owner,
                            stamp=self.clock(), ttl_s=self.policy.ttl_s)
            if self._write_excl(st):
                self.state = st
                return st
            return None
        if cur is not None and cur.owner == self.owner \
                and not cur.expired(self.clock()):
            self.state = cur                      # already the leader
            return cur
        if cur is not None and not cur.expired(self.clock()):
            return None                           # healthy foreign leader
        # Expired or torn: break it. Mint term past both the fence and
        # the dead lease's term, replace atomically, then verify the
        # takeover stuck (another standby may have raced this one; the
        # last rename wins and the loser sees a foreign owner).
        st = LeaseState(
            term=self._mint_term(cur.term if cur is not None else 0),
            owner=self.owner, stamp=self.clock(),
            ttl_s=self.policy.ttl_s)
        self._write_replace(st)
        back = self.read()
        if back is not None and back.owner == self.owner \
                and back.term == st.term:
            self.state = st
            return st
        return None

    def renew(self) -> LeaseState:
        """Refresh the stamp. Raises :class:`LeaseLost` if this
        controller's own deadline has already passed (it must not
        write — a usurper may hold the lease) or if the file shows a
        foreign owner/term."""
        if self.state is None:
            raise LeaseLost(f"{self.owner} holds no lease on {self.dir}")
        now = self.clock()
        if self.state.expired(now):
            held = self.state
            self.state = None
            raise LeaseLost(
                f"{self.owner} missed its own lease deadline on "
                f"{self.dir} (term {held.term}: last renewal "
                f"{now - held.stamp:.3f}s ago > ttl {held.ttl_s}s) — "
                "standing down without touching the lease file")
        cur = self.read()
        if cur is None or cur.owner != self.owner \
                or cur.term != self.state.term:
            self.state = None
            raise LeaseLost(
                f"{self.owner} found a foreign lease on {self.dir}: "
                f"{cur} — superseded")
        st = dataclasses.replace(cur, stamp=now)
        self._write_replace(st)
        self.state = st
        return st

    def release(self) -> None:
        """Drop leadership cleanly (normal completion): removes the
        lease file so a standby can take over without waiting out the
        ttl. No-op when not the owner."""
        if self.state is None:
            return
        cur = self.read()
        if cur is not None and cur.owner == self.owner \
                and cur.term == self.state.term:
            try:
                os.remove(self.path)
                _fsync_path(self.dir)
            except OSError:
                pass
        self.state = None
