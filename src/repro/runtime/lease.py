"""Lease-based leader election over the shared checkpoint directory
(DESIGN.md §Reliability).

Several controllers co-supervising one fleet must agree on exactly one
supervisor, with takeover when the leader dies — and the takeover must
compose with checkpoint EPOCH FENCING so a deposed leader's workers
cannot corrupt the recovery line. The two mechanisms share ONE
monotonic counter, the checkpoint directory's ``FENCE`` file
(``repro.checkpoint.advance_fence``):

  * a lease TERM is minted by advancing the fence (``term = fence+1``),
    so acquiring leadership immediately fences out every attempt epoch
    the previous leader ever granted — its in-flight workers find
    their commits rejected at the rename boundary before the new
    leader launches anything;
  * the leader mints each attempt's epoch the same way, so epochs and
    terms interleave on one total order and ``(epoch, step)`` snapshot
    ordering resolves the newest line unambiguously.

The lease itself is a crash-safe file (``LEASE``) in the checkpoint
directory:

    acquire   O_EXCL create — the filesystem arbitrates a dueling
              startup; exactly one creator wins, losers go standby
    renew     atomic replace (tmp + fsync + rename + dir fsync) with a
              fresh wall-clock stamp; BEFORE writing, the leader checks
              its OWN deadline — a leader that wakes from a long pause
              (GC, partition) past its ttl declares the lease lost
              without touching the file, so it can never clobber a
              usurper's lease (the standard check-your-own-clock
              fencing discipline)
    takeover  allowed only once ``stamp + ttl_s`` has passed (or the
              lease file is torn/corrupt — an unreadable lease cannot
              be renewed by anyone, so it is breakable); writes
              ``term = fence+1`` then verifies it won by re-reading
    release   unlink, only while still the owner

Election operations are serialized by an in-process lock (the same
discipline as ``advance_fence``'s ``_FENCE_LOCK``), so several
controllers in one process — the chaos-test topology — have NO
takeover race at all. Cross-process, the takeover's replace-then-
verify is a bounded window, not an arbiter: two standbys that both
saw the lease expired can both replace it and both re-read their own
write before seeing the other's, so both believe they lead for at
most one renewal interval (``renew_s``). That window is SAFE because
correctness never rests on the lease alone — it rests on fence
ordering: minting an attempt epoch goes through :meth:`mint_epoch`,
which re-verifies ownership against the lease file (under the lock)
in the same critical section that advances the fence, so the loser of
the window can never advance the fence past the winner's term; its
next renewal (or the mint itself) sees the foreign owner and stands
down, having launched nothing. A true multi-host deployment over a
store without POSIX O_EXCL/rename semantics needs a real CAS here —
see ROADMAP (cross-host fence minting).

Wall-clock expiry is the single-host simulation of a heartbeat
session; the injectable ``clock`` keeps chaos tests deterministic.
"""
from __future__ import annotations

import dataclasses
import json
import os
import threading
import time
from typing import Callable

from repro.checkpoint import advance_fence, read_fence
from repro.checkpoint.checkpointer import _fsync_path

LEASE_FILE = "LEASE"

# Serializes acquire/renew/mint/release across the controllers of one
# process: in-process (the chaos-test topology) the takeover and the
# leader's epoch minting cannot interleave at all. Cross-process the
# remaining replace-then-verify window is bounded and documented in
# the module docstring. Nests over the checkpointer's _FENCE_LOCK
# (mint_epoch -> advance_fence); nothing takes them in reverse order.
_ELECTION_LOCK = threading.Lock()


class LeaseLost(RuntimeError):
    """The caller no longer holds the lease: its own deadline passed
    (missed renewals — GC pause, partition) or another controller's
    term is on disk. The holder must stop supervising immediately; its
    workers' commits are already fenced out by the usurper's term."""


@dataclasses.dataclass(frozen=True)
class LeasePolicy:
    """Election knobs. ``ttl_s`` is the takeover latency floor: a dead
    leader is only safe to replace once its last renewal has aged out.
    Renewals should land several times per ttl (default ttl/3) so one
    slow poll does not read as death."""

    ttl_s: float = 2.0
    renew_every_s: float | None = None   # default ttl_s / 3
    poll_s: float = 0.05                 # standby watch interval
    standby_timeout_s: float | None = None  # give up standing by (None
    #                                       = stand by forever)

    def __post_init__(self):
        assert self.ttl_s > 0.0, self.ttl_s
        assert (self.renew_every_s is None
                or 0.0 < self.renew_every_s < self.ttl_s)
        assert self.poll_s > 0.0, self.poll_s

    @property
    def renew_s(self) -> float:
        return (self.renew_every_s if self.renew_every_s is not None
                else self.ttl_s / 3.0)


@dataclasses.dataclass(frozen=True)
class LeaseState:
    term: int
    owner: str
    stamp: float                 # wall-clock seconds at grant/renewal
    ttl_s: float

    def expired(self, now: float | None = None) -> bool:
        return (time.time() if now is None else now) \
            > self.stamp + self.ttl_s


class LeaseManager:
    """One controller's handle on the election. Not thread-safe: a
    controller renews from its single supervision loop."""

    def __init__(self, directory: str, owner: str, *,
                 policy: LeasePolicy | None = None,
                 clock: Callable[[], float] = time.time):
        self.dir = str(directory)
        self.owner = str(owner)
        self.policy = policy or LeasePolicy()
        self.clock = clock
        self.path = os.path.join(self.dir, LEASE_FILE)
        self.state: LeaseState | None = None   # held lease, if any

    # ------------------------------------------------------------ file io
    def read(self) -> LeaseState | None:
        """The lease on disk, or None if absent OR unreadable. A torn
        lease write (injected chaos; a crash mid-write from a
        fsync-less older version) parses as None — no owner could renew
        it either, so takeover treats it as immediately breakable."""
        try:
            with open(self.path) as f:
                d = json.load(f)
            return LeaseState(term=int(d["term"]), owner=str(d["owner"]),
                              stamp=float(d["stamp"]),
                              ttl_s=float(d["ttl_s"]))
        except FileNotFoundError:
            return None
        except (OSError, ValueError, KeyError, json.JSONDecodeError):
            return None

    def _payload(self, st: LeaseState) -> str:
        return json.dumps({"term": st.term, "owner": st.owner,
                           "stamp": st.stamp, "ttl_s": st.ttl_s})

    def _write_excl(self, st: LeaseState) -> bool:
        """O_EXCL create — the dueling-startup arbiter. Returns False
        if another controller created the lease first."""
        try:
            fd = os.open(self.path,
                         os.O_CREAT | os.O_EXCL | os.O_WRONLY)
        except FileExistsError:
            return False
        try:
            os.write(fd, self._payload(st).encode())
            os.fsync(fd)
        finally:
            os.close(fd)
        _fsync_path(self.dir)
        return True

    def _write_replace(self, st: LeaseState) -> None:
        tmp = f"{self.path}.tmp.{self.owner}"
        with open(tmp, "w") as f:
            f.write(self._payload(st))
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, self.path)
        _fsync_path(self.dir)

    # ----------------------------------------------------------- election
    def _mint_term(self, *extra: int) -> int:
        term = max(read_fence(self.dir), *extra, 0) + 1
        advance_fence(self.dir, term, self.owner)
        return term

    def try_acquire(self) -> LeaseState | None:
        """One election round. Returns the held lease when this
        controller is (now) the leader, None when it should stand by.
        Acquiring ADVANCES THE FENCE to the new term first, so by the
        time leadership is visible every write the previous leader's
        workers could attempt is already doomed at the commit boundary.

        In-process, the election lock makes the expiry takeover
        atomic. Cross-process, two standbys racing an expired lease
        can BOTH pass the replace-then-verify for up to one renewal
        interval (the bounded dual-leader window, module docstring);
        the fence ordering enforced by :meth:`mint_epoch` keeps that
        window harmless — the loser launches nothing and stands down
        at its next renewal.
        """
        with _ELECTION_LOCK:
            return self._try_acquire_locked()

    def _try_acquire_locked(self) -> LeaseState | None:
        os.makedirs(self.dir, exist_ok=True)
        cur = self.read()
        if cur is None and not os.path.exists(self.path):
            # No lease: contend via O_EXCL — filesystem picks one winner.
            st = LeaseState(term=self._mint_term(), owner=self.owner,
                            stamp=self.clock(), ttl_s=self.policy.ttl_s)
            if self._write_excl(st):
                self.state = st
                return st
            return None
        if cur is not None and cur.owner == self.owner \
                and not cur.expired(self.clock()):
            self.state = cur                      # already the leader
            return cur
        if cur is not None and not cur.expired(self.clock()):
            return None                           # healthy foreign leader
        # Expired or torn: break it. Mint term past both the fence and
        # the dead lease's term, replace atomically, then verify the
        # takeover stuck (another standby may have raced this one; the
        # last rename wins and the loser sees a foreign owner).
        st = LeaseState(
            term=self._mint_term(cur.term if cur is not None else 0),
            owner=self.owner, stamp=self.clock(),
            ttl_s=self.policy.ttl_s)
        self._write_replace(st)
        back = self.read()
        if back is not None and back.owner == self.owner \
                and back.term == st.term:
            self.state = st
            return st
        return None

    def renew(self) -> LeaseState:
        """Refresh the stamp. Raises :class:`LeaseLost` if this
        controller's own deadline has already passed (it must not
        write — a usurper may hold the lease) or if the file shows a
        foreign owner/term. May raise ``OSError`` from the lease write
        itself (ENOSPC, EIO) — the caller should treat that as a
        missed heartbeat, not as loss: the stamp is unchanged, so the
        next renewal either succeeds or ages out via the own-deadline
        check."""
        with _ELECTION_LOCK:
            return self._renew_locked()

    def mint_epoch(self) -> int:
        """Verify leadership and advance the shared fence to a fresh
        attempt epoch — ATOMICALLY, in one critical section, so a
        leader whose lease silently expired (a drain window, a
        relaunch backoff) can never advance the fence past a usurper's
        term: the renewal inside the lock sees the foreign owner (or
        this controller's own missed deadline) and raises
        :class:`LeaseLost` BEFORE the fence is touched. This is the
        renew-before-mint discipline the split-brain proof rests on;
        controllers must mint attempt epochs through here, never via a
        bare ``advance_fence``."""
        with _ELECTION_LOCK:
            try:
                st = self._renew_locked()
            except OSError:
                # The stamp WRITE failed (ENOSPC, EIO) — but only
                # after ownership was verified (read errors parse as a
                # foreign lease and raise LeaseLost above): a missed
                # heartbeat, not loss. The mint may proceed; the stamp
                # is refreshed by the supervision loop's next renewal.
                st = self.state
            epoch = max(read_fence(self.dir), st.term) + 1
            advance_fence(self.dir, epoch, self.owner)
            return epoch

    def _renew_locked(self) -> LeaseState:
        if self.state is None:
            raise LeaseLost(f"{self.owner} holds no lease on {self.dir}")
        now = self.clock()
        if self.state.expired(now):
            held = self.state
            self.state = None
            raise LeaseLost(
                f"{self.owner} missed its own lease deadline on "
                f"{self.dir} (term {held.term}: last renewal "
                f"{now - held.stamp:.3f}s ago > ttl {held.ttl_s}s) — "
                "standing down without touching the lease file")
        cur = self.read()
        if cur is None or cur.owner != self.owner \
                or cur.term != self.state.term:
            self.state = None
            raise LeaseLost(
                f"{self.owner} found a foreign lease on {self.dir}: "
                f"{cur} — superseded")
        st = dataclasses.replace(cur, stamp=now)
        self._write_replace(st)
        self.state = st
        return st

    def release(self) -> None:
        """Drop leadership cleanly (normal completion): removes the
        lease file so a standby can take over without waiting out the
        ttl. No-op when not the owner."""
        if self.state is None:
            return
        with _ELECTION_LOCK:
            self._release_locked()

    def _release_locked(self) -> None:
        cur = self.read()
        if cur is not None and cur.owner == self.owner \
                and cur.term == self.state.term:
            try:
                os.remove(self.path)
                _fsync_path(self.dir)
            except OSError:
                pass
        self.state = None
