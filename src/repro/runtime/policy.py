"""FaultPolicy: the reliability knobs a fit carries (DESIGN.md §Reliability).

The paper's per-iteration sync is cheap *when all nodes are healthy*
(Sec 4.1); at the 1000+-node scale the ROADMAP targets, preemptions,
stragglers and flaky loaders dominate. This policy object rides on
``SVMConfig`` (it must stay frozen/hashable — the solver lru-caches its
jitted builders on the config) and tells the drivers how to react:

  * checkpoint cadence (``ckpt_every`` iterations; the stream driver
    additionally snapshots every ``ckpt_chunks`` chunks *inside* a pass,
    so a multi-hour pass over a huge file is not itself the unit of
    loss) through ``repro.checkpoint.Checkpointer`` — snapshots are
    O(K^2/shards) statistics, never O(N) data;
  * loader retry with exponential backoff
    (``repro.data.pipeline.retrying_chunks``) so a flaky filesystem
    degrades to retries instead of a crash;
  * straggler detection thresholds feeding
    ``repro.runtime.straggler.StepTimeMonitor`` and the reaction
    (``on_straggler``): ``"record"`` events into the FitResult,
    ``"drop"`` dead replicas out of the statistic via the live-weighted
    reduction (``repro.core.distributed.live_weighted_psum`` — unbiased
    for the SVM's sum-statistics), or ``"raise"`` a StragglerError so an
    outer controller can re-mesh from the last checkpoint.
"""
from __future__ import annotations

import dataclasses

ON_STRAGGLER = ("record", "drop", "raise")


class StragglerError(RuntimeError):
    """Raised by the drivers when ``on_straggler="raise"`` and a step
    exceeds the monitor threshold — the signal for an outer controller
    to kill the job and resume from the last committed checkpoint on a
    healthy mesh (``PEMSVM.fit(..., resume_from=...)``)."""


@dataclasses.dataclass(frozen=True)
class FaultPolicy:
    """Reliability policy for a fit. All fields have safe defaults;
    ``ckpt_dir=None`` disables checkpointing entirely."""

    ckpt_dir: str | None = None     # directory for Checkpointer (None = off)
    ckpt_every: int = 10            # iterations between boundary snapshots
    ckpt_chunks: int = 0            # stream: also snapshot every n chunks
                                    # mid-pass (0 = boundary-only)
    keep_k: int = 3                 # committed checkpoints retained on disk
    loader_retries: int = 3         # consecutive loader failures tolerated
    loader_backoff: float = 0.05    # base seconds; doubles per retry
    loader_jitter: float = 0.0      # backoff *= 1 + jitter*U[0,1) — the
                                    # draw is keyed on SVMConfig.seed, so
                                    # it is DETERMINISTIC per fit while a
                                    # fleet with distinct seeds spreads
                                    # its retry storms
    straggler_threshold: float = 2.5  # x EMA -> straggler event
    straggler_warmup: int = 5       # steps ignored (compile noise)
    on_straggler: str = "record"    # record | drop | raise

    def __post_init__(self):
        assert self.ckpt_every >= 1, self.ckpt_every
        assert self.ckpt_chunks >= 0, self.ckpt_chunks
        assert self.keep_k >= 1, self.keep_k
        assert self.loader_retries >= 0, self.loader_retries
        assert self.loader_backoff >= 0.0, self.loader_backoff
        assert self.loader_jitter >= 0.0, self.loader_jitter
        assert self.straggler_threshold > 1.0, self.straggler_threshold
        assert self.on_straggler in ON_STRAGGLER, self.on_straggler

    @property
    def checkpoints_enabled(self) -> bool:
        return self.ckpt_dir is not None
