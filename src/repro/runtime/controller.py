"""FleetController: the fit-side supervisor (DESIGN.md §Reliability).

PR 6 made a single fit preemption-safe — kill it at any point and
``fit(resume_from=...)`` replays the identical trajectory from the last
committed snapshot. This module supplies the OTHER half the ROADMAP
names: the outer control loop that treats a whole fleet of fit attempts
as the unit of reliability. The controller owns worker lifecycles
end-to-end:

  * it LAUNCHES attempts — an in-process callable built per provisioning
    level (``make_host(level)``), or a real OS process
    (:class:`SubprocessHost`, the multi-host simulation: SIGTERM-able,
    crash-isolatable);
  * it CONSUMES the signals the workers already emit: ``StragglerError``
    (``FaultPolicy(on_straggler="raise")``), preemption exceptions,
    loader-retry exhaustion, and — through the shared checkpoint
    directory — monotonic progress (``Checkpointer.all_steps`` is the
    heartbeat: a worker that commits is alive AND advancing; a worker
    that is alive but not committing is indistinguishable from a hang,
    which is precisely what the watchdog assumes);
  * it REACTS per a declarative :class:`FleetPolicy` — the state machine

        attempt --retryable--> backoff --> relaunch (same level)
        attempt --straggler--> DEGRADE (level+1: shrink the mesh)
        attempt --no progress for watchdog_s--> kill --> relaunch
        degraded + recover_commits of progress --> GROW (level-1)
        attempt --terminal--> FleetError (fingerprint mismatch,
                               poisoned checkpoint, unknown exception)

    with retry budgets, exponential backoff + DETERMINISTIC jitter
    (keyed on (policy.seed, attempt): replayable in tests, decorrelated
    across controllers in a fleet), and shrink/grow re-provisioning by
    relaunching onto a different level's mesh — the checkpoint format is
    layout-free (``core/resume``), so "re-provision" is literally
    ``make_host(new_level)`` + resume, with ``elastic.remesh`` placing
    the restored tensors onto whatever mesh the new host holds.

Because every worker failure funnels into resume-from-snapshot, the
recovered model is bit-identical to the undisturbed fit whenever the
relaunch keeps the same layout, and within the documented reassociation
band across layouts — ``tests/test_fleet.py`` pins both under a
deterministic chaos schedule (``runtime.faults.FleetSchedule``).

Single-host caveat (documented, not hidden): cancelling an IN-PROCESS
attempt is cooperative — the cancel check rides the per-iteration fault
hook, so a worker hung inside one iteration is abandoned (daemon
thread) rather than killed, and could in principle commit a stale
snapshot after abandonment. Subprocess hosts have no such gap (SIGTERM
then SIGKILL); a multi-host deployment would add writer fencing
(attempt epoch in the step id) — noted in DESIGN.md.
"""
from __future__ import annotations

import dataclasses
import os
import subprocess
import sys
import threading
import time
import warnings
from typing import Any, Callable

import numpy as np

from repro.checkpoint import Checkpointer

from .faults import FleetSchedule
from .policy import StragglerError


class AttemptCancelled(RuntimeError):
    """Raised inside a worker when the controller cancels its attempt
    (watchdog kill or grow-back re-provisioning). Carries no verdict —
    the controller classifies from its own recorded cancel reason."""


class HostDied(RuntimeError):
    """A subprocess host exited nonzero (crash / injected kill)."""


class FleetError(RuntimeError):
    """Terminal controller failure: a non-retryable worker error or an
    exhausted retry budget. ``attempts`` carries the full lifecycle log
    for post-mortems."""

    def __init__(self, msg: str, attempts: list, cause=None):
        super().__init__(msg)
        self.attempts = attempts
        self.cause = cause


@dataclasses.dataclass(frozen=True)
class FleetPolicy:
    """Declarative fleet reaction policy. Everything deterministic:
    backoff jitter is keyed on (seed, attempt index), so a chaos test
    replays the exact schedule and two controllers with different seeds
    never synchronize their retry storms."""

    max_attempts: int = 6           # total launches (incl. the first)
    backoff_s: float = 0.05         # base relaunch delay; doubles per
                                    # CONSECUTIVE failure
    backoff_cap_s: float = 5.0      # exponential growth ceiling
    jitter: float = 0.1             # delay *= 1 + jitter * U[0,1)
    seed: int = 0                   # jitter determinism key
    watchdog_s: float | None = None  # no checkpoint advance within this
                                    # -> presume hang, kill, relaunch
                                    # (None = no watchdog)
    poll_s: float = 0.02            # progress-monitor poll interval
    kill_grace_s: float = 2.0       # cancel -> abandon/SIGKILL deadline
    recover_commits: int = 0        # commits at a degraded level before
                                    # growing back toward level 0
                                    # (0 = stay degraded once shrunk)
    # Classification. Terminal is checked FIRST, so FileNotFoundError
    # (poisoned/empty checkpoint dir) stays terminal even though it is
    # an OSError; ValueError covers the config-fingerprint mismatch and
    # shape mismatches — retrying cannot fix a wrong config.
    terminal: tuple = (ValueError, FileNotFoundError, AssertionError)
    retryable: tuple = (RuntimeError, IOError, OSError)

    def __post_init__(self):
        assert self.max_attempts >= 1, self.max_attempts
        assert self.backoff_s >= 0.0, self.backoff_s
        assert self.backoff_cap_s >= self.backoff_s
        assert self.jitter >= 0.0, self.jitter
        assert self.watchdog_s is None or self.watchdog_s > 0.0
        assert self.poll_s > 0.0, self.poll_s
        assert self.recover_commits >= 0, self.recover_commits

    def relaunch_delay(self, consecutive: int, attempt: int) -> float:
        """Deterministic backoff before relaunch ``attempt`` after
        ``consecutive`` straight failures (>= 1)."""
        base = min(self.backoff_cap_s,
                   self.backoff_s * (2 ** max(consecutive - 1, 0)))
        u = float(np.random.default_rng((self.seed, attempt)).random())
        return base * (1.0 + self.jitter * u)


@dataclasses.dataclass
class HostContext:
    """Everything one attempt needs from the controller. ``fault_hook``
    composes the scheduled injectors with the controller's cancel check
    — pass it into ``fit(..., fault_hook=ctx.fault_hook)`` (or ignore it
    for hosts, like subprocesses, that are cancelled externally)."""

    attempt: int
    level: int
    resume_from: str | None
    fault_hook: Callable[[int], None]
    cancel: threading.Event


@dataclasses.dataclass
class AttemptRecord:
    index: int
    level: int
    outcome: str                    # completed | retryable | straggler |
    #                                 watchdog | abandoned | reprovision |
    #                                 terminal
    error: str | None = None
    resume_step: int | None = None  # latest valid snapshot at launch
    commits: int = 0                # checkpoint commits observed
    seconds: float = 0.0
    first_commit_s: float | None = None  # launch -> first commit (the
    #                                 recovery-latency numerator)


@dataclasses.dataclass
class FleetResult:
    result: Any                     # the completing attempt's FitResult
    attempts: list                  # AttemptRecord log, launch order
    final_level: int
    n_relaunches: int               # attempts beyond the first
    recovered: bool                 # True if any failure was absorbed


class SubprocessHost:
    """One attempt as a real OS process — the multi-host simulation.

    ``code`` is a self-contained Python program (run via ``python -c``)
    that performs the fit and exits 0; it reads its attempt context from
    the environment: ``FLEET_ATTEMPT``, ``FLEET_LEVEL``,
    ``FLEET_RESUME`` (empty string = fresh). Cancellation is REAL here:
    the controller's cancel event becomes SIGTERM, then SIGKILL after
    ``FleetPolicy.kill_grace_s` — no cooperative gap. Nonzero exit
    raises :class:`HostDied` (retryable); on success ``load_result()``
    (if given) produces the value returned to the controller — e.g.
    reading the weights the program wrote, or loading the final
    snapshot from the shared checkpoint directory.
    """

    def __init__(self, code: str, *, env: dict | None = None,
                 load_result: Callable[[], Any] | None = None,
                 grace_s: float = 2.0, poll_s: float = 0.05):
        self.code = code
        self.env = dict(env or {})
        self.load_result = load_result
        self.grace_s = grace_s
        self.poll_s = poll_s

    def __call__(self, ctx: HostContext) -> Any:
        env = dict(os.environ, **self.env)
        env["FLEET_ATTEMPT"] = str(ctx.attempt)
        env["FLEET_LEVEL"] = str(ctx.level)
        env["FLEET_RESUME"] = ctx.resume_from or ""
        proc = subprocess.Popen([sys.executable, "-c", self.code],
                                env=env, stdout=subprocess.PIPE,
                                stderr=subprocess.STDOUT, text=True)
        # Drain stdout concurrently: a child that writes more than the
        # OS pipe buffer (~64KB) would otherwise block on write and
        # never exit, turning a healthy-but-verbose worker into a hang
        # (or a spurious watchdog kill).
        out_parts: list[str] = []

        def _drain(stream=proc.stdout):
            try:
                out_parts.append(stream.read())
            except (OSError, ValueError):
                pass

        reader = threading.Thread(target=_drain, daemon=True,
                                  name=f"fleet-stdout-{ctx.attempt}")
        reader.start()
        try:
            while proc.poll() is None:
                if ctx.cancel.is_set():
                    proc.terminate()          # SIGTERM-style first
                    try:
                        proc.wait(timeout=self.grace_s)
                    except subprocess.TimeoutExpired:
                        proc.kill()
                        proc.wait()
                    raise AttemptCancelled(
                        f"attempt {ctx.attempt} cancelled (subprocess "
                        "terminated)")
                time.sleep(self.poll_s)
        finally:
            if proc.poll() is None and ctx.cancel.is_set():
                proc.kill()
            reader.join(timeout=self.grace_s)
        out = "".join(out_parts)
        if proc.returncode != 0:
            tail = "\n".join(out.strip().splitlines()[-8:])
            raise HostDied(
                f"subprocess host exited {proc.returncode} on attempt "
                f"{ctx.attempt}:\n{tail}")
        return self.load_result() if self.load_result else None


class FleetController:
    """Supervise fit attempts until one completes or the policy says
    stop. See the module docstring for the state machine.

    ``make_host(level)`` returns the attempt callable for a provisioning
    level: ``host(ctx: HostContext) -> result``. Level 0 is the full
    fleet; higher levels are progressively degraded layouts (e.g. the
    (2,2) k-shard mesh at 0, the flat (4,) mesh at 1). ``n_levels``
    bounds degradation. The shared ``ckpt_dir`` is both the resume
    source and the progress heartbeat; the controller never parses
    snapshots itself, only watches committed step ids advance.
    """

    def __init__(self, make_host: Callable[[int], Callable],
                 ckpt_dir: str, *, policy: FleetPolicy | None = None,
                 n_levels: int = 1,
                 schedule: FleetSchedule | None = None,
                 sleep: Callable[[float], None] = time.sleep):
        assert n_levels >= 1, n_levels
        self.make_host = make_host
        self.ckpt_dir = str(ckpt_dir)
        self.policy = policy or FleetPolicy()
        self.n_levels = n_levels
        self.schedule = schedule or FleetSchedule()
        self.sleep = sleep
        self._ckpt = Checkpointer(self.ckpt_dir)

    # ---------------------------------------------------------- internals
    def _latest_step(self) -> int | None:
        try:
            return self._ckpt.latest_step()
        except OSError:
            return None

    def _compose_hook(self, attempt: int, cancel: threading.Event
                      ) -> Callable[[int], None]:
        scheduled = self.schedule.hook_for(attempt, cancel)

        def hook(it: int) -> None:
            if scheduled is not None:
                scheduled(it)
            # After the injector: a cancel-aware hang returns here on
            # wake-up and the attempt aborts cooperatively.
            if cancel.is_set():
                raise AttemptCancelled(
                    f"attempt {attempt} cancelled at iteration {it}")
        return hook

    def _supervise(self, thread: threading.Thread, cancel: threading.Event,
                   rec: AttemptRecord, level: int,
                   last_step: int | None) -> str | None:
        """Progress-monitor loop while the attempt thread runs. Returns
        the cancel reason (None if the attempt ended on its own).
        ``last_step`` is the committed-step baseline sampled just before
        ``thread.start()``, so a commit landing between launch and the
        first poll still counts.

        After a cancel the loop drains the thread for at most
        ``kill_grace_s`` more — a non-cooperative hang (worker stuck
        inside one iteration, never reaching the fault hook) would
        otherwise keep ``thread.is_alive()`` true forever; breaking out
        lets ``run()``'s abandon branch engage as documented."""
        pol = self.policy
        t0 = time.monotonic()
        last_advance = t0
        reason: str | None = None
        t_cancel = 0.0
        while thread.is_alive():
            self.sleep(pol.poll_s)
            step = self._latest_step()
            if step != last_step:
                now = time.monotonic()
                last_step = step
                last_advance = now
                rec.commits += 1
                if rec.first_commit_s is None:
                    rec.first_commit_s = now - t0
            if reason is not None:
                if time.monotonic() - t_cancel > pol.kill_grace_s:
                    break      # non-cooperative hang: abandon in run()
                continue       # cancelled; drain within the grace window
            if (level > 0 and pol.recover_commits > 0
                    and rec.commits >= pol.recover_commits):
                reason = "reprovision"   # healthy again: grow back
                t_cancel = time.monotonic()
                cancel.set()
            elif (pol.watchdog_s is not None
                    and time.monotonic() - last_advance > pol.watchdog_s):
                reason = "watchdog"      # alive but not advancing
                t_cancel = time.monotonic()
                cancel.set()
        return reason

    # --------------------------------------------------------------- run
    def run(self) -> FleetResult:
        pol = self.policy
        attempts: list[AttemptRecord] = []
        level = 0
        consecutive = 0
        for attempt in range(pol.max_attempts):
            cancel = threading.Event()
            ctx = HostContext(
                attempt=attempt, level=level,
                resume_from=(self.ckpt_dir
                             if self._latest_step() is not None else None),
                fault_hook=self._compose_hook(attempt, cancel),
                cancel=cancel)
            rec = AttemptRecord(index=attempt, level=level, outcome="?",
                                resume_step=self._latest_step())
            attempts.append(rec)
            host = self.make_host(level)
            box: dict[str, Any] = {}

            def work(host=host, ctx=ctx, box=box):
                try:
                    box["result"] = host(ctx)
                except BaseException as e:  # noqa: BLE001 — classified
                    box["error"] = e

            t0 = time.monotonic()
            thread = threading.Thread(target=work, daemon=True,
                                      name=f"fleet-attempt-{attempt}")
            # Baseline for commit counting, sampled immediately before
            # launch (an abandoned prior worker may still commit late).
            baseline_step = self._latest_step()
            thread.start()
            reason = self._supervise(thread, cancel, rec, level,
                                     baseline_step)
            thread.join(timeout=pol.kill_grace_s if cancel.is_set()
                        else None)
            rec.seconds = time.monotonic() - t0

            if thread.is_alive():
                # True hang: the cancel check never ran. Abandon the
                # daemon thread and relaunch from the last snapshot.
                warnings.warn(
                    f"fleet attempt {attempt} did not exit within "
                    f"{pol.kill_grace_s}s of cancellation; abandoning "
                    "the worker thread (it can no longer win: a stale "
                    "commit would be superseded by the relaunch's)",
                    RuntimeWarning, stacklevel=2)
                rec.outcome = "abandoned"
                rec.error = f"cancelled ({reason}), thread abandoned"
                consecutive += 1
            elif "result" in box:
                rec.outcome = "completed"
                return FleetResult(result=box["result"], attempts=attempts,
                                   final_level=level,
                                   n_relaunches=attempt,
                                   recovered=attempt > 0)
            else:
                err = box.get("error")
                rec.error = repr(err)
                if isinstance(err, AttemptCancelled):
                    rec.outcome = reason or "cancelled"
                    if reason == "reprovision":
                        level = max(level - 1, 0)    # grow back
                        consecutive = 0
                    else:
                        consecutive += 1             # watchdog kill
                elif isinstance(err, StragglerError):
                    rec.outcome = "straggler"
                    level = min(level + 1, self.n_levels - 1)  # degrade
                    consecutive = 0
                elif isinstance(err, pol.terminal):
                    rec.outcome = "terminal"
                    raise FleetError(
                        f"attempt {attempt} failed terminally "
                        f"(non-retryable {type(err).__name__}); see "
                        ".attempts for the lifecycle log", attempts,
                        cause=err) from err
                elif isinstance(err, pol.retryable):
                    rec.outcome = "retryable"
                    consecutive += 1
                else:
                    rec.outcome = "terminal"
                    raise FleetError(
                        f"attempt {attempt} raised unclassified "
                        f"{type(err).__name__} — treating as terminal",
                        attempts, cause=err) from err

            if attempt + 1 < pol.max_attempts and consecutive > 0:
                self.sleep(pol.relaunch_delay(consecutive, attempt + 1))

        raise FleetError(
            f"retry budget exhausted: {pol.max_attempts} attempts, none "
            "completed", attempts)
