"""FleetController: the fit-side supervisor (DESIGN.md §Reliability).

PR 6 made a single fit preemption-safe — kill it at any point and
``fit(resume_from=...)`` replays the identical trajectory from the last
committed snapshot. This module supplies the OTHER half the ROADMAP
names: the outer control loop that treats a whole fleet of fit attempts
as the unit of reliability. The controller owns worker lifecycles
end-to-end:

  * it LAUNCHES attempts — an in-process callable built per provisioning
    level (``make_host(level)``), or a real OS process
    (:class:`SubprocessHost`, the multi-host simulation: SIGTERM-able,
    crash-isolatable);
  * it CONSUMES the signals the workers already emit: ``StragglerError``
    (``FaultPolicy(on_straggler="raise")``), preemption exceptions,
    loader-retry exhaustion, and — through the shared checkpoint
    directory — monotonic progress (``Checkpointer.all_records`` is the
    heartbeat: a worker that commits is alive AND advancing; a worker
    that is alive but not committing is indistinguishable from a hang,
    which is precisely what the watchdog assumes);
  * it REACTS per a declarative :class:`FleetPolicy` — the state machine

        attempt --retryable--> backoff --> relaunch (same level)
        attempt --straggler--> DEGRADE (level+1: shrink the mesh)
        attempt --no progress for watchdog_s--> kill --> relaunch
        degraded + recover_commits of progress --> GROW (level-1)
        attempt --terminal--> FleetError (fingerprint mismatch,
                               poisoned checkpoint, unknown exception)

    with retry budgets, exponential backoff + DETERMINISTIC jitter
    (keyed on (policy.seed, attempt): replayable in tests, decorrelated
    across controllers in a fleet), and shrink/grow re-provisioning by
    relaunching onto a different level's mesh — the checkpoint format is
    layout-free (``core/resume``), so "re-provision" is literally
    ``make_host(new_level)`` + resume, with ``elastic.remesh`` placing
    the restored tensors onto whatever mesh the new host holds.

Because every worker failure funnels into resume-from-snapshot, the
recovered model is bit-identical to the undisturbed fit whenever the
relaunch keeps the same layout, and within the documented reassociation
band across layouts — ``tests/test_fleet.py`` pins both under a
deterministic chaos schedule (``runtime.faults.FleetSchedule``).

Epoch fencing (PR 9) closes the abandoned-worker window PR 8 could only
document: the controller mints a fresh attempt EPOCH before every
launch — ``advance_fence`` on the shared checkpoint directory, then
``HostContext.epoch`` into the worker's ``fit(..., epoch=)``. A worker
abandoned mid-iteration (cooperative cancel never reached) that later
wakes and tries to commit finds the fence ahead of its epoch and is
REJECTED at the rename boundary (``FencedCommitError``); and even a
commit that raced past the fence check orders epoch-major below the
successor's, so ``restore`` never selects it. The abandon branch's
"a stale commit can no longer win" is now an enforced invariant, not a
step-ordering hope. Hosts that ignore ``ctx.epoch`` (all PR 8 hosts)
still work — their writers run unfenced, exactly the legacy behavior.

Multi-controller co-supervision (PR 9): pass ``lease=LeasePolicy(...)``
and several controllers may call ``run()`` on the SAME checkpoint
directory. They elect a leader through a crash-safe lease file
(``runtime.lease``): one acquires and supervises, the rest stand by and
watch. The leader's heartbeat covers its WHOLE reign, not just the
happy-path poll loop: renewals continue through the cancel-drain
window (abandoning one hung worker must not cost the lease — with
defaults ``kill_grace_s`` equals the lease ttl), through the
post-supervise join, and through the relaunch backoff. If the leader
freezes (GC pause, partition) past the ttl anyway, a standby takes
over at ``term+1`` — which also advances the fence, so every worker
the old leader ever launched is fenced out BEFORE the new leader
launches its first resume. The deposed leader can never retaliate:
epoch minting is renew-before-mint (``LeaseManager.mint_epoch``), so
a controller whose lease silently expired stands down with
:class:`LeadershipLost` WITHOUT advancing the fence — it cannot fence
out the legitimate new leader's workers. Loss is also discovered at
the supervision-loop renewal and via a worker's ``FencedCommitError``;
all three paths end the reign rather than continuing a split brain.
"""
from __future__ import annotations

import dataclasses
import itertools
import math
import os
import subprocess
import sys
import threading
import time
import warnings
from typing import Any, Callable

import numpy as np

from repro.checkpoint import (Checkpointer, FencedCommitError,
                              FencedWriterError, advance_fence, read_fence)

from .faults import FleetSchedule
from .lease import LeaseLost, LeaseManager, LeasePolicy
from .policy import StragglerError

_CTRL_SEQ = itertools.count()


class AttemptCancelled(RuntimeError):
    """Raised inside a worker when the controller cancels its attempt
    (watchdog kill or grow-back re-provisioning). Carries no verdict —
    the controller classifies from its own recorded cancel reason."""


class HostDied(RuntimeError):
    """A subprocess host exited nonzero (crash / injected kill)."""


class FleetError(RuntimeError):
    """Terminal controller failure: a non-retryable worker error or an
    exhausted retry budget. ``attempts`` carries the full lifecycle log
    for post-mortems."""

    def __init__(self, msg: str, attempts: list, cause=None):
        super().__init__(msg)
        self.attempts = attempts
        self.cause = cause


class LeadershipLost(FleetError):
    """This controller was deposed mid-supervision: its lease expired
    (missed renewals — frozen, partitioned) or a worker's commit came
    back fenced, both meaning another controller now leads this
    checkpoint directory. NOT a fleet failure — the usurper is already
    resuming the fit from the last committed snapshot; this controller
    must simply stop. ``attempts`` logs the deposed reign."""


@dataclasses.dataclass(frozen=True)
class FleetPolicy:
    """Declarative fleet reaction policy. Everything deterministic:
    backoff jitter is keyed on (seed, attempt index), so a chaos test
    replays the exact schedule and two controllers with different seeds
    never synchronize their retry storms."""

    max_attempts: int = 6           # total launches (incl. the first)
    backoff_s: float = 0.05         # base relaunch delay; doubles per
                                    # CONSECUTIVE failure
    backoff_cap_s: float = 5.0      # exponential growth ceiling
    jitter: float = 0.1             # delay *= 1 + jitter * U[0,1)
    seed: int = 0                   # jitter determinism key
    watchdog_s: float | None = None  # no checkpoint advance within this
                                    # -> presume hang, kill, relaunch
                                    # (None = no watchdog)
    poll_s: float = 0.02            # progress-monitor poll interval
    kill_grace_s: float = 2.0       # cancel -> abandon/SIGKILL deadline
    recover_commits: int = 0        # commits at a degraded level before
                                    # growing back toward level 0
                                    # (0 = stay degraded once shrunk)
    # Classification. Terminal is checked FIRST, so FileNotFoundError
    # (poisoned/empty checkpoint dir) stays terminal even though it is
    # an OSError; ValueError covers the config-fingerprint mismatch and
    # shape mismatches — retrying cannot fix a wrong config. Fencing
    # errors are classified before either: they mean ANOTHER controller
    # leads, which is LeadershipLost, not a worker fault.
    terminal: tuple = (ValueError, FileNotFoundError, AssertionError)
    retryable: tuple = (RuntimeError, IOError, OSError)

    def __post_init__(self):
        assert self.max_attempts >= 1, self.max_attempts
        assert self.backoff_s >= 0.0, self.backoff_s
        assert self.backoff_cap_s >= self.backoff_s
        assert self.jitter >= 0.0, self.jitter
        assert self.watchdog_s is None or self.watchdog_s > 0.0
        assert self.poll_s > 0.0, self.poll_s
        assert self.recover_commits >= 0, self.recover_commits

    def relaunch_delay(self, consecutive: int, attempt: int) -> float:
        """Deterministic backoff before relaunch ``attempt`` after
        ``consecutive`` straight failures (>= 1)."""
        base = min(self.backoff_cap_s,
                   self.backoff_s * (2 ** max(consecutive - 1, 0)))
        u = float(np.random.default_rng((self.seed, attempt)).random())
        return base * (1.0 + self.jitter * u)


@dataclasses.dataclass
class HostContext:
    """Everything one attempt needs from the controller. ``fault_hook``
    composes the scheduled injectors with the controller's cancel check
    — pass it into ``fit(..., fault_hook=ctx.fault_hook)`` (or ignore it
    for hosts, like subprocesses, that are cancelled externally).
    ``epoch`` is the attempt's fence epoch — pass it into
    ``fit(..., epoch=ctx.epoch)`` so this attempt's commits are fenced
    against the directory (a host that ignores it writes unfenced,
    which is safe but forfeits zombie-commit rejection for itself)."""

    attempt: int
    level: int
    resume_from: str | None
    fault_hook: Callable[[int], None]
    cancel: threading.Event
    epoch: int = 0


@dataclasses.dataclass
class AttemptRecord:
    index: int
    level: int
    outcome: str                    # completed | retryable | straggler |
    #                                 watchdog | abandoned | reprovision |
    #                                 lease-lost | fenced | terminal
    error: str | None = None
    resume_step: int | None = None  # latest valid snapshot at launch
    epoch: int = 0                  # fence epoch minted for the attempt
    commits: int = 0                # checkpoint commits observed
    seconds: float = 0.0
    first_commit_s: float | None = None  # launch -> first commit (the
    #                                 recovery-latency numerator)


@dataclasses.dataclass
class FleetResult:
    result: Any                     # the completing attempt's FitResult
    attempts: list                  # AttemptRecord log, launch order
    final_level: int
    n_relaunches: int               # attempts beyond the first
    recovered: bool                 # True if any failure was absorbed
    term: int = 0                   # lease term held while completing
    #                                 (0 = no election configured)


class SubprocessHost:
    """One attempt as a real OS process — the multi-host simulation.

    ``code`` is a self-contained Python program (run via ``python -c``)
    that performs the fit and exits 0; it reads its attempt context from
    the environment: ``FLEET_ATTEMPT``, ``FLEET_LEVEL``,
    ``FLEET_RESUME`` (empty string = fresh), ``FLEET_EPOCH`` (the fence
    epoch — pass ``int(os.environ["FLEET_EPOCH"])`` into
    ``fit(..., epoch=)`` for fenced commits). Cancellation is REAL
    here: the controller's cancel event becomes SIGTERM, then SIGKILL
    after ``FleetPolicy.kill_grace_s`` — no cooperative gap. Nonzero
    exit raises :class:`HostDied` (retryable); on success
    ``load_result()`` (if given) produces the value returned to the
    controller — e.g. reading the weights the program wrote, or loading
    the final snapshot from the shared checkpoint directory.
    """

    def __init__(self, code: str, *, env: dict | None = None,
                 load_result: Callable[[], Any] | None = None,
                 grace_s: float = 2.0, poll_s: float = 0.05):
        self.code = code
        self.env = dict(env or {})
        self.load_result = load_result
        self.grace_s = grace_s
        self.poll_s = poll_s

    def __call__(self, ctx: HostContext) -> Any:
        env = dict(os.environ, **self.env)
        env["FLEET_ATTEMPT"] = str(ctx.attempt)
        env["FLEET_LEVEL"] = str(ctx.level)
        env["FLEET_RESUME"] = ctx.resume_from or ""
        env["FLEET_EPOCH"] = str(ctx.epoch)
        proc = subprocess.Popen([sys.executable, "-c", self.code],
                                env=env, stdout=subprocess.PIPE,
                                stderr=subprocess.STDOUT, text=True)
        # Drain stdout concurrently: a child that writes more than the
        # OS pipe buffer (~64KB) would otherwise block on write and
        # never exit, turning a healthy-but-verbose worker into a hang
        # (or a spurious watchdog kill).
        out_parts: list[str] = []

        def _drain(stream=proc.stdout):
            try:
                out_parts.append(stream.read())
            except (OSError, ValueError):
                pass

        reader = threading.Thread(target=_drain, daemon=True,
                                  name=f"fleet-stdout-{ctx.attempt}")
        reader.start()
        try:
            while proc.poll() is None:
                if ctx.cancel.is_set():
                    proc.terminate()          # SIGTERM-style first
                    try:
                        proc.wait(timeout=self.grace_s)
                    except subprocess.TimeoutExpired:
                        proc.kill()
                        proc.wait()
                    raise AttemptCancelled(
                        f"attempt {ctx.attempt} cancelled (subprocess "
                        "terminated)")
                time.sleep(self.poll_s)
        finally:
            if proc.poll() is None and ctx.cancel.is_set():
                proc.kill()
            reader.join(timeout=self.grace_s)
        out = "".join(out_parts)
        if proc.returncode != 0:
            tail = "\n".join(out.strip().splitlines()[-8:])
            raise HostDied(
                f"subprocess host exited {proc.returncode} on attempt "
                f"{ctx.attempt}:\n{tail}")
        return self.load_result() if self.load_result else None


class FleetController:
    """Supervise fit attempts until one completes or the policy says
    stop. See the module docstring for the state machine.

    ``make_host(level)`` returns the attempt callable for a provisioning
    level: ``host(ctx: HostContext) -> result``. Level 0 is the full
    fleet; higher levels are progressively degraded layouts (e.g. the
    (2,2) k-shard mesh at 0, the flat (4,) mesh at 1). ``n_levels``
    bounds degradation. The shared ``ckpt_dir`` is both the resume
    source and the progress heartbeat; the controller never parses
    snapshots itself, only watches committed (epoch, step) records
    advance.

    ``lease=LeasePolicy(...)`` opts into leader election: ``run()``
    first wins (or stands by for) the directory's lease, and only the
    leader supervises. ``owner`` names this controller in the lease and
    fence files (defaults to a unique pid-scoped name). ``stop`` is an
    external kill switch for a standby that should give up.
    """

    def __init__(self, make_host: Callable[[int], Callable],
                 ckpt_dir: str, *, policy: FleetPolicy | None = None,
                 n_levels: int = 1,
                 schedule: FleetSchedule | None = None,
                 sleep: Callable[[float], None] = time.sleep,
                 lease: LeasePolicy | None = None,
                 owner: str | None = None,
                 clock: Callable[[], float] = time.time):
        assert n_levels >= 1, n_levels
        self.make_host = make_host
        self.ckpt_dir = str(ckpt_dir)
        self.policy = policy or FleetPolicy()
        self.n_levels = n_levels
        self.schedule = schedule or FleetSchedule()
        self.sleep = sleep
        self.owner = owner or f"ctrl-pid{os.getpid()}-{next(_CTRL_SEQ)}"
        self.stop = threading.Event()
        self._lease = (LeaseManager(self.ckpt_dir, self.owner,
                                    policy=lease, clock=clock)
                       if lease is not None else None)
        self._last_epoch = 0
        self._last_renew = 0.0       # monotonic time of last heartbeat
        self._renew_failing = False  # warn once per OSError streak
        self._ckpt = Checkpointer(self.ckpt_dir)

    # ---------------------------------------------------------- internals
    def _latest_record(self) -> tuple | None:
        try:
            return self._ckpt.latest_record()
        except OSError:
            return None

    def _latest_step(self) -> int | None:
        rec = self._latest_record()
        return rec[1] if rec is not None else None

    def _mint_epoch(self, term: int) -> int:
        """A fresh fence epoch for the next attempt — advanced BEFORE
        the launch, so the previous attempt's line is already cut off
        when the successor first touches the directory (a zombie's late
        commit meets the fence, not a race).

        With an election configured this is RENEW-BEFORE-MINT: the
        mint goes through ``LeaseManager.mint_epoch``, which verifies
        ownership against the lease file in the same critical section
        that advances the fence. A leader whose lease silently expired
        (however briefly unnoticed) raises ``LeaseLost`` here and
        stands down WITHOUT advancing the fence — so a stale leader
        can never fence out the legitimate new leader's workers, which
        would invert the split-brain guarantee. The first attempt
        under a fresh lease term reuses the term itself: acquisition
        already advanced the fence to it, and terms/epochs share one
        counter (reusing never advances the fence, so it cannot cause
        an inversion either — at worst the worker opens superseded and
        gets ``FencedWriterError``)."""
        if self._lease is not None:
            if (term > 0 and self._last_epoch < term
                    and read_fence(self.ckpt_dir) <= term):
                try:
                    self._lease.renew()      # LeaseLost -> stand down
                    self._renew_failing = False
                    self._last_renew = time.monotonic()
                except OSError as e:
                    # Stamp write failed AFTER ownership verified (a
                    # renew OSError can only come from the write; read
                    # errors parse as foreign -> LeaseLost): missed
                    # heartbeat, and reusing the term advances nothing.
                    self._warn_renew_failure(e)
                epoch = term
            else:
                epoch = self._lease.mint_epoch()
                self._last_renew = time.monotonic()
        else:
            cur = read_fence(self.ckpt_dir)
            epoch = max(cur, self._last_epoch) + 1
            advance_fence(self.ckpt_dir, epoch, self.owner)
        self._last_epoch = epoch
        return epoch

    def _renew_if_due(self) -> LeaseLost | None:
        """The lease heartbeat: renew once ``renew_s`` has elapsed
        since the last renewal; no-op without an election or when the
        lease is already gone. Returns the ``LeaseLost`` when
        leadership is lost (callers cancel and stand down), else None.
        An ``OSError`` from the lease write (ENOSPC, EIO) is a MISSED
        heartbeat, not loss: warn once per failure streak and retry at
        the next poll — if failures persist past the ttl, the
        own-deadline check converts them into ``LeaseLost`` with the
        proper stand-down, and meanwhile the worker stays supervised."""
        if self._lease is None or self._lease.state is None:
            return None
        if time.monotonic() - self._last_renew < self._lease.policy.renew_s:
            return None
        try:
            self._lease.renew()
        except LeaseLost as e:
            return e
        except OSError as e:
            self._warn_renew_failure(e)
            return None
        self._renew_failing = False
        self._last_renew = time.monotonic()
        return None

    def _warn_renew_failure(self, e: OSError) -> None:
        """One RuntimeWarning per OSError streak; the stamp stays
        unrenewed so the next poll retries, and persistent failures
        age out through the lease's own-deadline check."""
        if not self._renew_failing:
            warnings.warn(
                f"controller {self.owner} failed to renew its lease "
                f"on {self.ckpt_dir} ({e!r}); treating as a missed "
                "heartbeat and retrying — persistent failures stand "
                "down via the lease ttl", RuntimeWarning, stacklevel=3)
        self._renew_failing = True

    def _join_renewing(self, thread: threading.Thread,
                       timeout: float | None) -> None:
        """``thread.join`` that keeps the lease heartbeat alive while
        waiting (the inter-attempt window the ttl must survive).
        Without an election this is a plain join. Loss detected here
        is not raised — the next attempt's mint stands down via
        ``LeadershipLost`` before the fence is touched."""
        if self._lease is None or self._lease.state is None:
            thread.join(timeout=timeout)
            return
        deadline = (None if timeout is None
                    else time.monotonic() + timeout)
        while thread.is_alive():
            thread.join(timeout=self.policy.poll_s)
            self._renew_if_due()
            if deadline is not None and time.monotonic() > deadline:
                return

    def _sleep_renewing(self, delay: float) -> None:
        """Relaunch backoff that keeps the lease heartbeat alive: the
        delay is sliced so renewals land every ~renew_s/2 (sliced by
        COUNT, not wall clock, so an injected test sleep still sees
        the same total). As with the join, loss here surfaces at the
        next mint, which stands down without advancing the fence."""
        if (self._lease is None or self._lease.state is None
                or delay <= 0.0):
            self.sleep(delay)
            return
        slice_s = max(self._lease.policy.renew_s / 2.0, 1e-3)
        n = max(1, math.ceil(delay / slice_s))
        for _ in range(n):
            self.sleep(delay / n)
            self._renew_if_due()

    def _compose_hook(self, attempt: int, cancel: threading.Event
                      ) -> Callable[[int], None]:
        scheduled = self.schedule.hook_for(attempt, cancel)

        def hook(it: int) -> None:
            if scheduled is not None:
                scheduled(it)
            # After the injector: a cancel-aware hang returns here on
            # wake-up and the attempt aborts cooperatively.
            if cancel.is_set():
                raise AttemptCancelled(
                    f"attempt {attempt} cancelled at iteration {it}")
        return hook

    def _supervise(self, thread: threading.Thread, cancel: threading.Event,
                   rec: AttemptRecord, level: int,
                   last_rec: tuple | None) -> str | None:
        """Progress-monitor loop while the attempt thread runs. Returns
        the cancel reason (None if the attempt ended on its own).
        ``last_rec`` is the committed-record baseline sampled just
        before ``thread.start()``, so a commit landing between launch
        and the first poll still counts.

        When an election is configured this loop is also the leader's
        heartbeat: the lease is renewed every ``renew_s`` of wall
        clock — INCLUDING while draining a cancelled attempt (with
        defaults ``kill_grace_s`` equals the lease ttl, so a
        renewal-free drain would guarantee an unnecessary takeover
        just for abandoning one hung worker). A controller frozen
        inside ``self.sleep`` (the injected GC pause) misses renewals;
        on wake-up ``renew()`` refuses to touch the lease past its own
        deadline and raises ``LeaseLost``, which cancels the attempt
        with reason "lease-lost". A renewal that fails with ``OSError``
        counts as a missed heartbeat and is retried (``_renew_if_due``)
        — the worker is never left running unsupervised.

        After a cancel the loop drains the thread for at most
        ``kill_grace_s`` more — a non-cooperative hang (worker stuck
        inside one iteration, never reaching the fault hook) would
        otherwise keep ``thread.is_alive()`` true forever; breaking out
        lets ``run()``'s abandon branch engage as documented."""
        pol = self.policy
        t0 = time.monotonic()
        last_advance = t0
        reason: str | None = None
        t_cancel = 0.0
        while thread.is_alive():
            self.sleep(pol.poll_s)
            step = self._latest_record()
            if step != last_rec:
                now = time.monotonic()
                last_rec = step
                last_advance = now
                rec.commits += 1
                if rec.first_commit_s is None:
                    rec.first_commit_s = now - t0
            lost = self._renew_if_due()   # heartbeat, drain included
            if lost is not None and reason != "lease-lost":
                rec.error = rec.error or str(lost)
                if reason is None:        # keep an earlier drain clock
                    t_cancel = time.monotonic()
                    cancel.set()
                reason = "lease-lost"
            if reason is not None:
                if time.monotonic() - t_cancel > pol.kill_grace_s:
                    break      # non-cooperative hang: abandon in run()
                continue       # cancelled; drain within the grace window
            if (level > 0 and pol.recover_commits > 0
                    and rec.commits >= pol.recover_commits):
                reason = "reprovision"   # healthy again: grow back
                t_cancel = time.monotonic()
                cancel.set()
            elif (pol.watchdog_s is not None
                    and time.monotonic() - last_advance > pol.watchdog_s):
                reason = "watchdog"      # alive but not advancing
                t_cancel = time.monotonic()
                cancel.set()
        return reason

    # --------------------------------------------------------------- run
    def run(self) -> FleetResult:
        """Win (or wait for) leadership, then supervise to completion.
        Without a lease policy this is single-controller supervision,
        exactly the PR 8 behavior plus per-attempt epoch fencing."""
        if self._lease is None:
            return self._run_supervised(term=0)
        lpol = self._lease.policy
        t0 = time.monotonic()
        while True:
            if self.stop.is_set():
                raise FleetError(
                    f"controller {self.owner} stopped while standing "
                    "by", [])
            st = self._lease.try_acquire()
            if st is not None:
                self._last_renew = time.monotonic()
                try:
                    result = self._run_supervised(term=st.term)
                finally:
                    # No-op if the lease was already lost (state is
                    # cleared before LeaseLost propagates); otherwise
                    # lets a standby take over without aging out the
                    # ttl — including after normal completion.
                    self._lease.release()
                return result
            if (lpol.standby_timeout_s is not None
                    and time.monotonic() - t0 > lpol.standby_timeout_s):
                raise FleetError(
                    f"controller {self.owner} gave up standing by "
                    f"after {lpol.standby_timeout_s}s (leader "
                    f"{self._lease.read()})", [])
            self.sleep(lpol.poll_s)

    def _run_supervised(self, term: int) -> FleetResult:
        pol = self.policy
        attempts: list[AttemptRecord] = []
        level = 0
        consecutive = 0
        for attempt in range(pol.max_attempts):
            cancel = threading.Event()
            try:
                epoch = self._mint_epoch(term)
            except LeaseLost as e:
                # Renew-before-mint refused: the lease expired (or was
                # usurped) somewhere renewals could not reach — the
                # fence was NOT advanced, so the new leader's workers
                # are untouched; this controller simply stops.
                raise LeadershipLost(
                    f"controller {self.owner} (term {term}) stood down "
                    f"before launching attempt {attempt}: {e}",
                    attempts) from e
            ctx = HostContext(
                attempt=attempt, level=level,
                resume_from=(self.ckpt_dir
                             if self._latest_record() is not None
                             else None),
                fault_hook=self._compose_hook(attempt, cancel),
                cancel=cancel, epoch=epoch)
            rec = AttemptRecord(index=attempt, level=level, outcome="?",
                                resume_step=self._latest_step(),
                                epoch=epoch)
            attempts.append(rec)
            host = self.make_host(level)
            box: dict[str, Any] = {}

            def work(host=host, ctx=ctx, box=box):
                try:
                    box["result"] = host(ctx)
                except BaseException as e:  # noqa: BLE001 — classified
                    box["error"] = e

            t0 = time.monotonic()
            thread = threading.Thread(target=work, daemon=True,
                                      name=f"fleet-attempt-{attempt}")
            # Baseline for commit counting, sampled immediately before
            # launch (an abandoned prior worker may still commit late).
            baseline = self._latest_record()
            thread.start()
            reason = self._supervise(thread, cancel, rec, level, baseline)
            self._join_renewing(thread, pol.kill_grace_s
                                if cancel.is_set() else None)
            rec.seconds = time.monotonic() - t0

            if thread.is_alive():
                # True hang: the cancel check never ran. Abandon the
                # daemon thread and relaunch from the last snapshot.
                warnings.warn(
                    f"fleet attempt {attempt} did not exit within "
                    f"{pol.kill_grace_s}s of cancellation; abandoning "
                    f"the worker thread (it cannot win: epoch {epoch} "
                    "is fenced out before the relaunch, so a late "
                    "commit is rejected at the rename boundary)",
                    RuntimeWarning, stacklevel=2)
                rec.outcome = "abandoned"
                rec.error = rec.error or (f"cancelled ({reason}), "
                                          "thread abandoned")
                consecutive += 1
            elif "result" in box and reason is None:
                rec.outcome = "completed"
                return FleetResult(result=box["result"], attempts=attempts,
                                   final_level=level,
                                   n_relaunches=attempt,
                                   recovered=attempt > 0, term=term)
            elif "result" in box:
                # Completed, but only after a cancel was issued (e.g.
                # the final commit and the watchdog raced, or the lease
                # was lost mid-final-iteration). For reprovision/
                # watchdog the result is still valid — the fit
                # finished. For a lost lease it is NOT ours to return.
                if reason != "lease-lost":
                    rec.outcome = "completed"
                    return FleetResult(result=box["result"],
                                       attempts=attempts,
                                       final_level=level,
                                       n_relaunches=attempt,
                                       recovered=attempt > 0, term=term)
                rec.outcome = "lease-lost"
            else:
                err = box.get("error")
                rec.error = rec.error or repr(err)
                if isinstance(err, AttemptCancelled):
                    rec.outcome = reason or "cancelled"
                    if reason == "reprovision":
                        level = max(level - 1, 0)    # grow back
                        consecutive = 0
                    else:
                        consecutive += 1             # watchdog kill
                elif isinstance(err, (FencedCommitError,
                                      FencedWriterError)):
                    # Another controller advanced the fence past this
                    # attempt's epoch: we have been deposed even if our
                    # own renewal has not noticed yet.
                    rec.outcome = "fenced"
                    raise LeadershipLost(
                        f"controller {self.owner} (term {term}) was "
                        f"fenced out at epoch {epoch}: {err} — another "
                        "controller leads this directory", attempts,
                        cause=err) from err
                elif isinstance(err, StragglerError):
                    rec.outcome = "straggler"
                    level = min(level + 1, self.n_levels - 1)  # degrade
                    consecutive = 0
                elif isinstance(err, pol.terminal):
                    rec.outcome = "terminal"
                    raise FleetError(
                        f"attempt {attempt} failed terminally "
                        f"(non-retryable {type(err).__name__}); see "
                        ".attempts for the lifecycle log", attempts,
                        cause=err) from err
                elif isinstance(err, pol.retryable):
                    rec.outcome = "retryable"
                    consecutive += 1
                else:
                    rec.outcome = "terminal"
                    raise FleetError(
                        f"attempt {attempt} raised unclassified "
                        f"{type(err).__name__} — treating as terminal",
                        attempts, cause=err) from err

            if reason == "lease-lost":
                rec.outcome = ("abandoned" if rec.outcome == "abandoned"
                               else "lease-lost")
                raise LeadershipLost(
                    f"controller {self.owner} lost the lease on "
                    f"{self.ckpt_dir} during attempt {attempt} (term "
                    f"{term}); the usurper's fence already rejects "
                    "this reign's commits", attempts)

            if attempt + 1 < pol.max_attempts and consecutive > 0:
                self._sleep_renewing(
                    pol.relaunch_delay(consecutive, attempt + 1))

        raise FleetError(
            f"retry budget exhausted: {pol.max_attempts} attempts, none "
            "completed", attempts)
