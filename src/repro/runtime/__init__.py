"""Runtime control plane: fault policy, straggler detection, elastic
re-meshing, the fleet supervisor that owns worker lifecycles (with
lease-based leader election for multi-controller co-supervision), and
the deterministic fault-injection harness that proves the recovery
paths work (DESIGN.md §Reliability)."""
from .controller import (AttemptCancelled, AttemptRecord,  # noqa: F401
                         FleetController, FleetError, FleetPolicy,
                         FleetResult, HostContext, HostDied,
                         LeadershipLost, SubprocessHost)
from .elastic import remesh, scale_batch_schedule  # noqa: F401
from .faults import (FleetSchedule, SimulatedPreemption,  # noqa: F401
                     SimulatedTermination, compose_hooks, delay_chunks,
                     delay_iterations, freezable_sleep, hang_at_iteration,
                     hold_at_iteration, io_error_every_nth,
                     kill_after_chunks, kill_at_iteration, tear_file,
                     terminate_at_iteration)
from .lease import (LeaseLost, LeaseManager, LeasePolicy,  # noqa: F401
                    LeaseState)
from .policy import FaultPolicy, StragglerError  # noqa: F401
from .straggler import StepTimeMonitor  # noqa: F401
