"""Runtime control plane: fault policy, straggler detection, elastic
re-meshing, and the deterministic fault-injection harness that proves
the recovery paths work (DESIGN.md §Reliability)."""
from .elastic import remesh, scale_batch_schedule  # noqa: F401
from .faults import (SimulatedPreemption, compose_hooks,  # noqa: F401
                     delay_chunks, delay_iterations, io_error_every_nth,
                     kill_after_chunks, kill_at_iteration)
from .policy import FaultPolicy, StragglerError  # noqa: F401
from .straggler import StepTimeMonitor  # noqa: F401
