"""Runtime control plane: straggler detection, elastic re-meshing."""
from .elastic import remesh, scale_batch_schedule  # noqa: F401
from .straggler import StepTimeMonitor  # noqa: F401
