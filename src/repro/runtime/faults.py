"""Deterministic fault injection: prove resumability, don't assert it.

Every reliability claim in DESIGN.md §Reliability is backed by a parity
test that *actually kills* a fit and resumes it; this module supplies
the deterministic killers so those tests (and ``scripts/elastic_smoke``)
are reproducible bit-for-bit:

  * ``kill_after_chunks`` — preempt the stream driver at an exact chunk
    (the budget counts across iterations/passes, so the kill can land
    mid-pass at any chosen chunk);
  * ``kill_at_iteration`` / ``delay_iterations`` — ``fault_hook``
    callables for the host-loop drivers: preempt at iteration k, or
    inflate step k's wall time so ``StepTimeMonitor`` flags it;
  * ``io_error_every_nth`` — a flaky loader that raises ``IOError`` a
    fixed number of times per chunk position (bookkeeping persists
    across re-created iterators, so bounded retry + backoff provably
    drains past every transient failure);
  * ``delay_chunks`` — per-chunk sleep injection, the stream driver's
    straggler simulator.

Controller-level injectors (PR 8, ``runtime.controller``): a fleet
supervisor sees faults per ATTEMPT, not per iteration, so the schedule
moves up a level too:

  * ``hang_at_iteration`` — a worker that stops making progress without
    dying (the failure mode only a monotonic-progress watchdog catches):
    blocks at iteration k until the controller's cancel event fires;
  * ``terminate_at_iteration`` — SIGTERM-style graceful preemption: the
    eviction notice arrives between iterations, after the boundary
    checkpoint committed (distinct exception type so policies can treat
    notice-ful eviction differently from SIGKILL);
  * ``FleetSchedule`` — the deterministic per-attempt plan: attempt
    index -> hook factory, so chaos tests replay the exact same fault
    sequence on every run.

Split-brain injectors (PR 9, fencing/election chaos): multi-controller
co-supervision adds failure modes ABOVE the attempt level — a frozen
leader, a zombie worker that outlives its controller's reign, a torn
lease file — and each gets a deterministic injector:

  * ``hold_at_iteration`` — the NON-cooperative zombie: blocks at
    iteration k until a test-controlled release event, ignoring the
    controller's cancel entirely, then lets the fit continue — so the
    abandoned worker genuinely attempts its next commit after the
    takeover, which is exactly the write epoch fencing must reject;
  * ``freezable_sleep`` — a drop-in for the controller's injected
    ``sleep`` that stalls (GC pause / partition simulation) while a
    test event is set: the leader's supervision loop stops renewing
    its lease without the thread dying;
  * ``tear_file`` — truncates a file mid-record, simulating a torn
    write to the lease (or any metadata) file.

The injectors wrap *chunk factories* (zero-arg callables returning a
fresh iterator — exactly what ``PEMSVM.fit_chunks`` consumes) or act as
``fit(..., fault_hook=...)`` callables; they never reach into solver
internals, so the code under test is the production path.
"""
from __future__ import annotations

import threading
import time
from typing import Callable, Iterable, Iterator


class SimulatedTermination(RuntimeError):
    """SIGTERM-style graceful preemption: unlike ``SimulatedPreemption``
    (SIGKILL — no notice), this models an eviction NOTICE delivered
    between iterations, with the boundary checkpoint already committed.
    Controllers may relaunch immediately (no crash suspicion)."""


class SimulatedPreemption(RuntimeError):
    """The injected stand-in for SIGKILL/eviction: raised at the exact
    configured point; tests catch it and resume from the last committed
    checkpoint like a restarted job would."""


def kill_after_chunks(make_chunks: Callable[[], Iterable], n: int,
                      exc: type = SimulatedPreemption
                      ) -> Callable[[], Iterator]:
    """Wrap a chunk factory with a whole-fit chunk budget: after ``n``
    chunks have been yielded across ALL iterators the returned factory
    ever produced, the next pull raises ``exc``. The cumulative count is
    what lets a test kill at an arbitrary chunk of an arbitrary pass."""
    count = [0]

    def factory() -> Iterator:
        for chunk in make_chunks():
            if count[0] >= n:
                raise exc(f"simulated preemption after {n} chunks")
            count[0] += 1
            yield chunk
    return factory


def kill_at_iteration(k: int, exc: type = SimulatedPreemption
                      ) -> Callable[[int], None]:
    """``fault_hook`` killing the fit right after iteration ``k``
    completes (its checkpoint, if due, is already committed — matching
    a preemption that lands between steps)."""
    def hook(it: int) -> None:
        if it >= k:
            raise exc(f"simulated preemption at iteration {k}")
    return hook


def delay_iterations(iterations: Iterable[int], seconds: float,
                     sleep: Callable[[float], None] = time.sleep
                     ) -> Callable[[int], None]:
    """``fault_hook`` inflating the wall time of the given iterations —
    the host-loop drivers time the hook inside the step window, so
    ``StepTimeMonitor`` sees these steps as stragglers."""
    slow = frozenset(iterations)

    def hook(it: int) -> None:
        if it in slow:
            sleep(seconds)
    return hook


def compose_hooks(*hooks: Callable[[int], None]) -> Callable[[int], None]:
    """Run several fault hooks in order (e.g. delay then kill)."""
    def hook(it: int) -> None:
        for h in hooks:
            h(it)
    return hook


def io_error_every_nth(make_chunks: Callable[[], Iterable], nth: int,
                       times: int = 1) -> Callable[[], Iterator]:
    """Flaky-loader factory: pulling chunk position ``nth-1, 2*nth-1,
    ...`` raises ``IOError`` — ``times`` times per position, after which
    that position succeeds forever. Failure bookkeeping is shared across
    every iterator the factory creates, so a retrying consumer
    (``data.pipeline.retrying_chunks``) provably drains the stream:
    each retry replays the already-served prefix and gets one failure
    closer to passing the flaky position."""
    assert nth >= 1, nth
    fails: dict[int, int] = {}

    def factory() -> Iterator:
        for i, chunk in enumerate(make_chunks()):
            if (i + 1) % nth == 0 and fails.get(i, 0) < times:
                fails[i] = fails.get(i, 0) + 1
                raise IOError(
                    f"injected loader failure at chunk {i} "
                    f"({fails[i]}/{times})")
            yield chunk
    return factory


def terminate_at_iteration(k: int) -> Callable[[int], None]:
    """``fault_hook`` delivering a graceful SIGTERM-style eviction right
    after iteration ``k`` completes (boundary snapshot, if due, already
    committed — the polite preemption cloud schedulers send first)."""
    return kill_at_iteration(k, exc=SimulatedTermination)


def hang_at_iteration(k: int, *, until: threading.Event,
                      poll: float = 0.01, max_seconds: float = 60.0,
                      sleep: Callable[[float], None] = time.sleep
                      ) -> Callable[[int], None]:
    """``fault_hook`` simulating a HANG at iteration ``k``: the worker
    stops advancing (no checkpoint commits, no exception) until the
    controller's cancel event ``until`` fires — exactly the failure a
    liveness heartbeat misses and a monotonic-progress watchdog
    catches. Once cancelled the hook raises nothing itself; the
    controller's own cancel check (composed after it) converts the
    wake-up into an attempt abort. ``max_seconds`` bounds the block so
    a test with a broken watchdog fails instead of deadlocking."""
    def hook(it: int) -> None:
        if it != k:
            return
        t0 = time.monotonic()
        while not until.is_set():
            if time.monotonic() - t0 > max_seconds:
                raise RuntimeError(
                    f"hang_at_iteration({k}) gave up after "
                    f"{max_seconds}s — no watchdog cancelled it")
            sleep(poll)
    return hook


def hold_at_iteration(k: int, *, release: threading.Event,
                      poll: float = 0.01, max_seconds: float = 60.0,
                      sleep: Callable[[float], None] = time.sleep
                      ) -> Callable[[int], None]:
    """``fault_hook`` simulating a NON-cooperative zombie: at iteration
    ``k`` the worker blocks until the TEST-controlled ``release`` event
    fires — the controller's cancel is ignored, so the controller
    abandons the worker (or a standby takes over), and when the test
    later releases it, the fit RESUMES and attempts its next boundary
    commit as if nothing happened. That late commit is the zombie write
    the epoch fence must reject at the rename boundary; contrast
    ``hang_at_iteration``, whose cooperative worker aborts on cancel
    and never writes again. ``max_seconds`` bounds the block so a test
    that forgets to release fails instead of deadlocking."""
    def hook(it: int) -> None:
        if it != k:
            return
        t0 = time.monotonic()
        while not release.is_set():
            if time.monotonic() - t0 > max_seconds:
                raise RuntimeError(
                    f"hold_at_iteration({k}) gave up after "
                    f"{max_seconds}s — the test never released it")
            sleep(poll)
    return hook


def freezable_sleep(frozen: threading.Event, *,
                    base: Callable[[float], None] = time.sleep,
                    poll: float = 0.01, max_seconds: float = 60.0
                    ) -> Callable[[float], None]:
    """A ``sleep`` replacement for ``FleetController(sleep=...)`` that
    simulates a GC pause / partition: while ``frozen`` is set, every
    call blocks (the supervision loop stops polling AND stops renewing
    its lease) until the event clears — the thread never dies, it just
    goes dark, which is exactly the leader failure lease expiry exists
    to catch. ``max_seconds`` bounds the freeze so a stuck test fails
    loudly."""
    def sleep_fn(seconds: float) -> None:
        base(seconds)
        t0 = time.monotonic()
        while frozen.is_set():
            if time.monotonic() - t0 > max_seconds:
                raise RuntimeError(
                    f"freezable_sleep frozen for over {max_seconds}s — "
                    "the test never thawed it")
            base(poll)
    return sleep_fn


def tear_file(path: str, nbytes: int = 8) -> None:
    """Simulate a torn write: truncate ``path`` to its first ``nbytes``
    bytes (a crash mid-write from a non-atomic writer). Readers must
    treat the result as absent/breakable, never crash on it."""
    with open(path, "rb") as f:
        head = f.read(max(nbytes, 0))
    with open(path, "wb") as f:
        f.write(head)


class FleetSchedule:
    """Deterministic per-ATTEMPT fault plan for ``FleetController``:
    ``plans[i]`` is a factory ``(cancel_event) -> fault_hook`` applied
    to attempt ``i`` (0-based, counting every launch including
    relaunches). Attempts without a plan run clean. The factory takes
    the controller's cancel event so cancel-aware injectors
    (``hang_at_iteration``) can be scheduled declaratively; factories
    that ignore it are just ``lambda cancel: kill_at_iteration(5)``.
    """

    def __init__(self, plans: dict[int, Callable] | None = None):
        self.plans = dict(plans or {})

    def hook_for(self, attempt: int,
                 cancel: threading.Event) -> Callable[[int], None] | None:
        factory = self.plans.get(attempt)
        return factory(cancel) if factory is not None else None


def delay_chunks(make_chunks: Callable[[], Iterable],
                 at_chunks: Iterable[int], seconds: float,
                 sleep: Callable[[float], None] = time.sleep
                 ) -> Callable[[], Iterator]:
    """Straggler injection for the stream driver: sleeping before the
    given cumulative chunk indices stretches the pass (and hence the
    iteration) that consumes them."""
    slow = frozenset(at_chunks)
    count = [0]

    def factory() -> Iterator:
        for chunk in make_chunks():
            if count[0] in slow:
                sleep(seconds)
            count[0] += 1
            yield chunk
    return factory
