"""Elastic scaling: move a training/solver state between meshes.

Recovery story at scale: a pod loses nodes -> the job restarts on the
surviving slice (or a grown one) -> the last committed checkpoint is
restored with the *new* mesh's shardings. Nothing in the checkpoint
format is mesh-specific (arrays are stored as logical tensors), so
elasticity is purely a restore-time choice of shardings; see
``repro.checkpoint``. This module adds the in-memory variant (no disk
round-trip) used when the job itself orchestrates the re-mesh, plus
batch re-sharding helpers."""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding


def remesh(tree: Any, shardings: Any) -> Any:
    """Re-shard every leaf onto new-mesh shardings (host round-trip —
    device-to-device resharding across different Mesh objects is not
    defined, and on a real re-deploy the host copy is the checkpoint)."""
    flat, treedef = jax.tree.flatten(tree)
    sh = treedef.flatten_up_to(shardings)
    out = [jax.device_put(np.asarray(x), s) for x, s in zip(flat, sh)]
    return jax.tree.unflatten(treedef, out)


def scale_batch_schedule(global_batch: int, old_workers: int,
                         new_workers: int, *, keep_global: bool = True):
    """When the worker count changes, either keep the global batch (per-
    worker batch changes; optimization trajectory preserved) or keep the
    per-worker batch (throughput preserved; LR should rescale). Returns
    (global_batch, lr_scale)."""
    if keep_global:
        assert global_batch % new_workers == 0, (global_batch, new_workers)
        return global_batch, 1.0
    per = global_batch // old_workers
    new_global = per * new_workers
    return new_global, new_workers / old_workers
