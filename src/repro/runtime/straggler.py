"""Straggler mitigation: detection + policy.

The paper notes (Sec 4.1) that PEMSVM's SPMD symmetry makes sync latency
small *when all nodes are healthy*; at 1000+ nodes, slow or dead hosts
dominate tails. This module provides the control-plane pieces:

  * ``StepTimeMonitor`` — per-step wall-time EMA; a step slower than
    ``threshold x EMA`` flags a straggler event. On a real deployment each
    host feeds its own timings and the flags are all-reduced; here the
    single-host monitor is driven by the training loop.
  * policy hooks — the data-plane reaction lives in
    ``repro.core.distributed.live_weighted_psum`` (drop + renormalize a
    dead replica's contribution: unbiased for the SVM's data-sums) and in
    ``repro.runtime.elastic`` (re-mesh from the last checkpoint when a
    replica is lost for good).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass
class StepTimeMonitor:
    ema_decay: float = 0.9
    threshold: float = 2.5        # x EMA -> straggler
    warmup_steps: int = 5         # ignore compile/first-step noise

    ema: float = 0.0
    n: int = 0
    events: list = dataclasses.field(default_factory=list)

    @classmethod
    def from_policy(cls, policy) -> "StepTimeMonitor":
        """Build from a ``repro.runtime.policy.FaultPolicy`` — the
        solver drivers' construction path."""
        return cls(threshold=policy.straggler_threshold,
                   warmup_steps=policy.straggler_warmup)

    def observe(self, step: int, seconds: float) -> bool:
        """Returns True if this step is a straggler event."""
        self.n += 1
        if self.n <= self.warmup_steps:
            self.ema = seconds if self.ema == 0.0 else (
                0.5 * self.ema + 0.5 * seconds)
            return False
        is_straggler = seconds > self.threshold * self.ema
        if is_straggler:
            self.events.append((step, seconds, self.ema))
        else:
            # only healthy steps move the EMA (stragglers would poison it)
            self.ema = (self.ema_decay * self.ema
                        + (1 - self.ema_decay) * seconds)
        return is_straggler

    def summary(self) -> dict:
        return {"steps": self.n, "ema_s": self.ema,
                "straggler_events": len(self.events)}
