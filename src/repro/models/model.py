"""Model facade: one API over all assigned architectures.

  build_model(cfg, ctx)  ->  Model with
    .init(key)                                  params (f32 master)
    .hidden_seq(params, batch, remat)           (B, S, D) final hidden
    .logits_seq(params, batch)                  (B, S, V) (small cfgs/tests)
    .prefill(params, batch, cache_len)          (last-token logits, caches)
    .decode(params, tokens, pos, caches)        ((B, 1, V) logits, caches)
    .init_cache(batch, cache_len, dtype)

batch dict keys by family:
  dense/moe/hybrid/ssm : tokens (B,S) int32
  vlm                  : embeds (B,S,D) + positions (3,B,S) int32
  audio (enc-dec)      : frames (B,enc_seq,D) + tokens (B,S) int32
plus labels (B,S) for training (consumed by the loss, not the model).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.sharding import ShardingCtx
from . import encdec, transformer as tfm
from .common import compute_dtype


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: Any
    ctx: ShardingCtx
    q_chunk: int = 1024
    kv_chunk: int = 1024
    ssm_chunk: int = 256
    skip_masked_blocks: bool = False
    remat_policy: str = "nothing"  # 'nothing' | 'dots' (§Perf lever)
    seq_parallel_attn: bool = False  # Ulysses-style q-seq sharding

    # ------------------------------------------------------------- params
    def init(self, key) -> dict:
        if self.cfg.enc_dec:
            return encdec.init_encdec(key, self.cfg)
        return tfm.init_decoder(key, self.cfg)

    # ------------------------------------------------------------- embed
    def _embed_in(self, params, batch, dtype):
        cfg = self.cfg
        if cfg.family == "vlm":
            h = batch["embeds"].astype(dtype)
            positions = batch["positions"]
        else:
            tokens = batch["tokens"]
            h = tfm.embed_tokens(cfg, params, tokens, dtype)
            B, S = tokens.shape
            positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        h = self.ctx.shard_batch(h)
        return h, positions

    # ---------------------------------------------------------- sequence
    def hidden_seq(self, params, batch, *, remat: bool = True):
        cfg = self.cfg
        dtype = compute_dtype(cfg)
        if cfg.enc_dec:
            memory = encdec.encode(cfg, self.ctx, params,
                                   batch["frames"].astype(dtype))
            tok = tfm.embed_tokens(cfg, params, batch["tokens"], dtype)
            tok = self.ctx.shard_batch(tok)
            return encdec.decode_seq(cfg, self.ctx, params, tok, memory,
                                     remat=remat, q_chunk=self.q_chunk,
                                     kv_chunk=self.kv_chunk)
        h, positions = self._embed_in(params, batch, dtype)
        return tfm.forward_seq(cfg, self.ctx, params, h, positions,
                               remat=remat, q_chunk=self.q_chunk,
                               kv_chunk=self.kv_chunk,
                               ssm_chunk=self.ssm_chunk,
                               skip_masked_blocks=self.skip_masked_blocks,
                               remat_policy=self.remat_policy,
                               seq_parallel_attn=self.seq_parallel_attn)

    def unembed(self, params) -> jnp.ndarray:
        return tfm.unembed_matrix(self.cfg, params)

    def logits_seq(self, params, batch, *, remat: bool = False):
        h = self.hidden_seq(params, batch, remat=remat)
        w = self.unembed(params).astype(h.dtype)
        return jnp.einsum("bsd,vd->bsv", h, w)

    # ------------------------------------------------------------ serving
    def init_cache(self, batch: int, cache_len: int, dtype=jnp.bfloat16):
        if self.cfg.enc_dec:
            return encdec.init_dec_cache(self.cfg, batch, cache_len, dtype)
        return tfm.init_cache(self.cfg, batch, cache_len, dtype)

    def prefill(self, params, batch, cache_len: int):
        cfg = self.cfg
        dtype = compute_dtype(cfg)
        if cfg.enc_dec:
            memory = encdec.encode(cfg, self.ctx, params,
                                   batch["frames"].astype(dtype))
            tok = tfm.embed_tokens(cfg, params, batch["tokens"], dtype)
            tok = self.ctx.shard_batch(tok)
            h, caches = encdec.prefill(cfg, self.ctx, params, tok, memory,
                                       cache_len, q_chunk=self.q_chunk,
                                       kv_chunk=self.kv_chunk)
        else:
            h, positions = self._embed_in(params, batch, dtype)
            h, caches = tfm.forward_prefill(
                cfg, self.ctx, params, h, positions, cache_len,
                q_chunk=self.q_chunk, kv_chunk=self.kv_chunk,
                ssm_chunk=self.ssm_chunk,
                seq_parallel_attn=self.seq_parallel_attn)
        w = self.unembed(params).astype(h.dtype)
        logits = jnp.einsum("bd,vd->bv", h[:, -1, :], w)
        return logits, caches

    def decode(self, params, tokens, pos, caches):
        """tokens: (B, 1) int32; pos: traced scalar int32."""
        cfg = self.cfg
        dtype = compute_dtype(cfg)
        tok = tfm.embed_tokens(cfg, params, tokens, dtype)
        if cfg.enc_dec:
            h, caches = encdec.decode_step(cfg, self.ctx, params, tok, pos,
                                           caches)
        else:
            h, caches = tfm.forward_decode(cfg, self.ctx, params, tok, pos,
                                           caches)
        w = self.unembed(params).astype(h.dtype)
        logits = jnp.einsum("bsd,vd->bsv", h, w)
        return logits, caches


def build_model(cfg, ctx: ShardingCtx | None = None, **kw) -> Model:
    return Model(cfg=cfg, ctx=ctx or ShardingCtx(), **kw)
