"""Mamba (S6 selective SSM) block for the Jamba hybrid.

Recurrence (per channel c, state n):
    h_t = exp(dt_t * A) * h_{t-1} + (dt_t * B_t) * x_t
    y_t = C_t . h_t + D * x_t
with input-dependent dt (softplus), B, C. Training/prefill runs a
*chunked* scan: an outer lax.scan carries the (B, d_inner, d_state)
boundary state across chunks while an associative_scan parallelizes
within each chunk (log-depth, MXU/VPU-friendly); the chunk body is
jax.checkpoint'd so the backward pass recomputes in-chunk states instead
of storing (B, S, d_inner, d_state) — the same recompute trade the CUDA
kernel makes, expressed at the XLA level (DESIGN.md §3).

Decode is O(1): one state update per token (this is why the hybrid runs
the long_500k cell).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import dense_init, split_keys


def init_mamba(key, cfg):
    D, di = cfg.d_model, cfg.d_inner
    ds, dc, dtr = cfg.mamba_d_state, cfg.mamba_d_conv, cfg.dt_rank
    ks = split_keys(key, 6)
    # S4D-real initialization for A; dt bias init for softplus range.
    a_init = jnp.tile(jnp.arange(1, ds + 1, dtype=jnp.float32), (di, 1))
    return {
        "in_proj": dense_init(ks[0], D, 2 * di),           # -> [x, z]
        "conv_w": 0.1 * jax.random.normal(ks[1], (di, dc)),
        "conv_bias": jnp.zeros((di,)),
        "x_proj": dense_init(ks[2], di, dtr + 2 * ds),     # -> [dt, B, C]
        "dt_proj": dense_init(ks[3], dtr, di),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.clip(jnp.exp(jax.random.uniform(
                ks[4], (di,)) * (jnp.log(0.1) - jnp.log(1e-3))
                + jnp.log(1e-3)), 1e-4, None))),
        "a_log": jnp.log(a_init),                          # (di, ds)
        "d_skip": jnp.ones((di,)),
        "out_proj": dense_init(ks[5], di, D,
                               scale=1.0 / (2 * cfg.n_layers) ** 0.5),
    }


def _ssm_params(cfg, p, xc):
    """xc: (B, S, di) post-conv activations -> dt, Bmat, Cmat."""
    ds, dtr = cfg.mamba_d_state, cfg.dt_rank
    proj = xc @ p["x_proj"].astype(xc.dtype)
    dt, Bm, Cm = jnp.split(proj, [dtr, dtr + ds], axis=-1)
    dt = jax.nn.softplus(dt @ p["dt_proj"].astype(xc.dtype)
                         + p["dt_bias"].astype(xc.dtype))   # (B,S,di)
    return dt.astype(jnp.float32), Bm.astype(jnp.float32), \
        Cm.astype(jnp.float32)


def _scan_chunk(A, dt, Bm, Cm, xc, h0):
    """Associative scan within one chunk.

    A: (di, ds); dt: (B, C, di); Bm/Cm: (B, C, ds); xc: (B, C, di);
    h0: (B, di, ds). Returns (y (B, C, di) f32, h_last)."""
    dA = jnp.exp(dt[..., None] * (-A))                     # (B,C,di,ds)
    dBx = (dt * xc)[..., None] * Bm[:, :, None, :]         # (B,C,di,ds)

    def combine(a, b):
        # composition of affine maps h -> A h + b
        return a[0] * b[0], b[0] * a[1] + b[1]

    Acum, bcum = jax.lax.associative_scan(combine, (dA, dBx), axis=1)
    h = Acum * h0[:, None] + bcum                          # (B,C,di,ds)
    y = jnp.einsum("bcds,bcs->bcd", h, Cm)
    return y, h[:, -1]


def mamba_seq(cfg, p, x, *, chunk: int = 256, remat: bool = True):
    """Full-sequence pass. x: (B, S, D) -> (B, S, D)."""
    B, S, D = x.shape
    di, dc = cfg.d_inner, cfg.mamba_d_conv
    xz = x @ p["in_proj"].astype(x.dtype)
    xs, z = jnp.split(xz, 2, axis=-1)                      # (B,S,di) each
    # causal depthwise conv along S
    xpad = jnp.pad(xs, ((0, 0), (dc - 1, 0), (0, 0)))
    xc = sum(xpad[:, i:i + S, :] * p["conv_w"][:, i].astype(x.dtype)
             for i in range(dc))
    xc = jax.nn.silu(xc + p["conv_bias"].astype(x.dtype))

    A = jnp.exp(p["a_log"]).astype(jnp.float32)            # (di, ds)
    c = min(chunk, S)
    if S % c:        # non-divisible (odd test shapes): single chunk
        c = S
    n = S // c

    def body(h0, xcc):
        # dt/B/C (and the (B, c, di, ds) dA/dBx expansions inside
        # _scan_chunk) are computed INSIDE the checkpointed body: they are
        # rematerialized in backward instead of living as stacked scan
        # residuals — (n, B, c, di, ds) f32 stacks dominated HBM otherwise.
        dtc, Bc, Cc = _ssm_params(cfg, p, xcc)
        y, h1 = _scan_chunk(A, dtc, Bc, Cc,
                            xcc.astype(jnp.float32), h0)
        return h1, y.astype(x.dtype)   # bf16 outputs: f32 (B,S,di) stacks
        # of every mamba layer otherwise dominate the period backward

    if remat:
        body = jax.checkpoint(body)

    resh = lambda t: t.reshape(B, n, c, *t.shape[2:]).swapaxes(0, 1)
    h0 = jnp.zeros((B, di, cfg.mamba_d_state), jnp.float32)
    _, ys = jax.lax.scan(body, h0, resh(xc))
    y = ys.swapaxes(0, 1).reshape(B, S, di)
    y = y + xc * p["d_skip"].astype(x.dtype)
    y = y * jax.nn.silu(z)
    return y @ p["out_proj"].astype(x.dtype)


def mamba_init_state(cfg, batch: int, dtype=jnp.float32):
    return {
        "h": jnp.zeros((batch, cfg.d_inner, cfg.mamba_d_state), jnp.float32),
        "conv": jnp.zeros((batch, cfg.mamba_d_conv - 1, cfg.d_inner), dtype),
    }


def mamba_decode(cfg, p, x, state):
    """One-token step. x: (B, 1, D); state: {'h', 'conv'}."""
    B = x.shape[0]
    dc = cfg.mamba_d_conv
    xz = x @ p["in_proj"].astype(x.dtype)
    xs, z = jnp.split(xz, 2, axis=-1)                      # (B,1,di)
    window = jnp.concatenate([state["conv"], xs], axis=1)  # (B,dc,di)
    xc = jnp.einsum("bcd,dc->bd", window.astype(jnp.float32),
                    p["conv_w"].astype(jnp.float32))
    xc = jax.nn.silu(xc + p["conv_bias"].astype(jnp.float32))[:, None, :]
    xc = xc.astype(x.dtype)

    dt, Bm, Cm = _ssm_params(cfg, p, xc)                   # (B,1,*)
    A = jnp.exp(p["a_log"]).astype(jnp.float32)
    dA = jnp.exp(dt[:, 0, :, None] * (-A))                 # (B,di,ds)
    dBx = (dt[:, 0, :] * xc[:, 0, :].astype(jnp.float32))[..., None] \
        * Bm[:, 0, None, :]
    h = dA * state["h"] + dBx                              # (B,di,ds)
    y = jnp.einsum("bds,bs->bd", h, Cm[:, 0, :])
    y = y + xc[:, 0, :].astype(jnp.float32) * p["d_skip"].astype(jnp.float32)
    y = (y[:, None, :].astype(x.dtype)) * jax.nn.silu(z)
    out = y @ p["out_proj"].astype(x.dtype)
    new_state = {"h": h, "conv": window[:, 1:, :]}
    del B, dc
    return out, new_state
