"""Rotary position embeddings: standard RoPE and Qwen2-VL's M-RoPE.

M-RoPE (arXiv:2409.12191): the head_dim/2 frequency pairs are split into
sections (temporal, height, width); each section rotates by its own
position stream. Text tokens use t=h=w=linear position, so M-RoPE with
equal ids degenerates to RoPE exactly (tested)."""
from __future__ import annotations

import jax.numpy as jnp


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    """(head_dim/2,) inverse frequencies."""
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                       dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float) -> jnp.ndarray:
    """x: (B, S, H, D); positions: (B, S) int -> rotated x."""
    D = x.shape[-1]
    inv = rope_freqs(D, theta)                                # (D/2,)
    ang = positions.astype(jnp.float32)[..., None] * inv      # (B, S, D/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


def apply_mrope(x: jnp.ndarray, positions3: jnp.ndarray, theta: float,
                sections: tuple[int, ...]) -> jnp.ndarray:
    """x: (B, S, H, D); positions3: (3, B, S) (t, h, w) position streams;
    sections: frequency-pair counts per stream, sum == D/2."""
    D = x.shape[-1]
    assert sum(sections) == D // 2, (sections, D)
    inv = rope_freqs(D, theta)                                # (D/2,)
    # Per-pair position stream id: section s repeated sections[s] times.
    stream = jnp.repeat(jnp.arange(len(sections)),
                        jnp.asarray(sections), total_repeat_length=D // 2)
    pos = positions3.astype(jnp.float32)[stream, :, :]        # (D/2, B, S)
    ang = jnp.moveaxis(pos, 0, -1) * inv                      # (B, S, D/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)
