"""Feed-forward blocks: SwiGLU, GELU MLP, and top-k MoE.

MoE strategy (DESIGN.md §4): expert weights are sharded over the mesh's
'model' axis (expert parallelism). On-mesh, the layer runs as a shard_map
island — tokens are replicated across the model axis (they are already
only batch-sharded), each model shard gathers the tokens routed to *its*
expert slice into an (E_loc, C, D) buffer, runs the expert GEMMs, scatters
back its partial output and psums over 'model'. No all-to-all is needed
because token activations are model-replicated; the psum is the same
collective a row-parallel dense FFN would pay. Capacity C drops overflow
tokens deterministically (GShard-style), with router weights renormalized
over surviving assignments.

Off-mesh (smoke tests) a mathematically identical jnp fallback runs the
same gather/scatter with E_loc = E.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map

from .common import dense_init, split_keys


# ---------------------------------------------------------------- dense FFN
def init_swiglu(key, d_model: int, d_ff: int, n_layers: int):
    ks = split_keys(key, 3)
    return {
        "w_gate": dense_init(ks[0], d_model, d_ff),
        "w_up": dense_init(ks[1], d_model, d_ff),
        "w_down": dense_init(ks[2], d_ff, d_model,
                             scale=1.0 / (2 * n_layers) ** 0.5),
    }


def swiglu(p, x):
    g = jax.nn.silu(x @ p["w_gate"].astype(x.dtype))
    u = x @ p["w_up"].astype(x.dtype)
    return (g * u) @ p["w_down"].astype(x.dtype)


def init_gelu_mlp(key, d_model: int, d_ff: int, n_layers: int,
                  use_bias: bool = True):
    ks = split_keys(key, 2)
    p = {
        "w_up": dense_init(ks[0], d_model, d_ff),
        "w_down": dense_init(ks[1], d_ff, d_model,
                             scale=1.0 / (2 * n_layers) ** 0.5),
    }
    if use_bias:
        p.update(b_up=jnp.zeros((d_ff,)), b_down=jnp.zeros((d_model,)))
    return p


def gelu_mlp(p, x):
    h = x @ p["w_up"].astype(x.dtype)
    if "b_up" in p:
        h = h + p["b_up"].astype(x.dtype)
    h = jax.nn.gelu(h)
    out = h @ p["w_down"].astype(x.dtype)
    if "b_down" in p:
        out = out + p["b_down"].astype(x.dtype)
    return out


# --------------------------------------------------------------------- MoE
def init_moe(key, cfg):
    D, E, F = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    ks = split_keys(key, 5)
    p = {
        "router": dense_init(ks[0], D, E, scale=0.1),
        "moe_gate": _stack_expert_init(ks[1], E, D, F),
        "moe_up": _stack_expert_init(ks[2], E, D, F),
        "moe_down": _stack_expert_init(ks[3], E, F, D,
                                       scale=1.0 / (2 * cfg.n_layers) ** 0.5),
    }
    if cfg.n_shared_experts:
        p["shared"] = init_swiglu(ks[4], D,
                                  cfg.n_shared_experts * F, cfg.n_layers)
    return p


def _stack_expert_init(key, E, d_in, d_out, scale=1.0):
    keys = jax.random.split(key, E)
    return jnp.stack([dense_init(k, d_in, d_out, scale=scale) for k in keys])


def _route(x2d, router_w, top_k: int):
    """Top-k softmax routing. x2d: (T, D). Returns gates (T,K) f32,
    expert ids (T,K) int32."""
    logits = (x2d.astype(jnp.float32) @ router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eidx = jax.lax.top_k(probs, top_k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    return gates, eidx.astype(jnp.int32)


def _expert_pass(xt, gates, eidx, wg, wu, wd, e0, E_loc, C):
    """Gather tokens of experts [e0, e0+E_loc), run GEMMs, scatter back.

    xt: (T, D); wg/wu/wd: (E_loc, D, F)/(E_loc, D, F)/(E_loc, F, D)."""
    T, D = xt.shape
    K = eidx.shape[1]
    # Position of each (token, k) assignment within its expert's queue,
    # counted in flattened (T*K) assignment order (deterministic drop
    # policy). The one-hot/cumsum is over the *local* expert slice only,
    # so its footprint is (T*K, E_loc), not (T*K, E_total).
    flat_e = eidx.reshape(-1)                                   # (T*K,)
    e_rel = flat_e - e0
    in_slice = (e_rel >= 0) & (e_rel < E_loc)
    oh = jax.nn.one_hot(jnp.where(in_slice, e_rel, E_loc),
                        E_loc + 1, dtype=jnp.int32)[:, :E_loc]  # (T*K, E_loc)
    pos = (jnp.cumsum(oh, axis=0) - oh)                         # prior count
    pos = jnp.sum(pos * oh, axis=-1)                            # (T*K,)
    keep = in_slice & (pos < C)
    e_safe = jnp.clip(e_rel, 0, E_loc - 1)
    p_safe = jnp.clip(pos, 0, C - 1)

    xt_rep = jnp.broadcast_to(xt[:, None, :], (T, K, D)).reshape(T * K, D)
    buf = jnp.zeros((E_loc, C, D), xt.dtype)
    buf = buf.at[e_safe, p_safe].add(
        jnp.where(keep[:, None], xt_rep, 0.0))
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wg.astype(xt.dtype)))
    h = h * jnp.einsum("ecd,edf->ecf", buf, wu.astype(xt.dtype))
    y = jnp.einsum("ecf,efd->ecd", h, wd.astype(xt.dtype))
    got = y[e_safe, p_safe]                                     # (T*K, D)
    gate_flat = gates.reshape(-1).astype(xt.dtype)
    got = got * jnp.where(keep, gate_flat, 0.0)[:, None]
    return got.reshape(T, K, D).sum(axis=1)                     # (T, D)


def moe_apply(cfg, ctx, p, x, *, capacity_factor: float | None = None):
    """x: (B, S, D) -> (B, S, D). ctx: ShardingCtx."""
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    if capacity_factor is None:
        capacity_factor = cfg.moe_capacity_factor

    def full_local(xl, router_w, wg, wu, wd, e0, E_loc):
        T = xl.shape[0] * xl.shape[1]
        xt = xl.reshape(T, D)
        gates, eidx = _route(xt, router_w, K)
        C = max(1, int(T * K * capacity_factor) // E)
        out = _expert_pass(xt, gates, eidx, wg, wu, wd, e0, E_loc, C)
        return out.reshape(xl.shape)

    if ctx.mesh is not None and ctx.tp_axis is not None \
            and E % ctx.axis_size(ctx.tp_axis) == 0 \
            and B % ctx.axis_size(ctx.dp_axes) == 0:
        # (decode with tiny batch falls through to the local path below —
        # at one token per step the expert GEMMs are negligible)
        tp = ctx.tp_axis
        E_loc = E // ctx.axis_size(tp)
        dp = ctx.dp_axes

        def island(xl, router_w, wg, wu, wd):
            e0 = jax.lax.axis_index(tp) * E_loc
            out = full_local(xl, router_w, wg, wu, wd, e0, E_loc)
            return jax.lax.psum(out, tp)

        other = tuple(a for a in ctx.mesh.axis_names
                      if a not in dp and a != tp)
        xspec = P(dp, None, None)
        wspec = P(tp, None, None)
        fn = shard_map(
            island, mesh=ctx.mesh,
            in_specs=(xspec, P(None, None), wspec, wspec, wspec),
            out_specs=xspec, check_vma=False)
        del other
        # cast expert weights BEFORE the island boundary: the FSDP
        # all-gather implied by the in_specs then moves bf16, not f32
        # (2x collective bytes + gathered-buffer memory otherwise)
        y = fn(x, p["router"],
               p["moe_gate"].astype(x.dtype),
               p["moe_up"].astype(x.dtype),
               p["moe_down"].astype(x.dtype))
    else:
        y = full_local(x, p["router"], p["moe_gate"], p["moe_up"],
                       p["moe_down"], 0, E)

    if cfg.n_shared_experts:
        y = y + swiglu(p["shared"], x)
    return y
