"""Shared model building blocks: norms, initializers, dtype policy."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def compute_dtype(cfg) -> jnp.dtype:
    return jnp.dtype(cfg.dtype)


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    return out.astype(x.dtype)


def layer_norm(x: jnp.ndarray, scale: jnp.ndarray, bias: jnp.ndarray,
               eps: float) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mean) * jax.lax.rsqrt(var + eps)
    out = out * scale.astype(jnp.float32) + bias.astype(jnp.float32)
    return out.astype(x.dtype)


def dense_init(key, in_dim: int, out_dim: int, *, scale: float = 1.0,
               dtype=jnp.float32) -> jnp.ndarray:
    """Truncated-normal fan-in init (LLM standard)."""
    std = scale / (in_dim ** 0.5)
    return (std * jax.random.truncated_normal(
        key, -2.0, 2.0, (in_dim, out_dim))).astype(dtype)


def embed_init(key, vocab: int, dim: int, dtype=jnp.float32) -> jnp.ndarray:
    return (jax.random.truncated_normal(key, -2.0, 2.0, (vocab, dim))
            * 0.02).astype(dtype)


def split_keys(key, n: int):
    return list(jax.random.split(key, n))
