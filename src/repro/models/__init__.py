"""Model zoo: every assigned architecture behind one facade."""
from .model import Model, build_model  # noqa: F401
