"""Whisper-style encoder-decoder backbone (arXiv:2212.04356).

The conv audio frontend is a stub per the assignment: inputs are
precomputed frame embeddings (B, enc_seq, D) from ``input_specs``. The
encoder is bidirectional self-attention; the decoder adds causal
self-attention with a KV cache and cross-attention whose K/V are computed
once from the encoder output and cached for decode. LayerNorm + GELU +
biases + learned positions (no RoPE), per the original."""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from . import attention as attn
from . import mlp
from .common import dense_init, embed_init, layer_norm, split_keys


def _init_norm(d):
    return {"scale": jnp.ones((d,)), "bias": jnp.zeros((d,))}


def _ln(x, p, eps):
    return layer_norm(x, p["scale"], p["bias"], eps)


def init_cross(key, cfg):
    D, H, dh = cfg.d_model, cfg.n_heads, cfg.head_dim
    ks = split_keys(key, 4)
    return {
        "wq": dense_init(ks[0], D, H * dh),
        "wk": dense_init(ks[1], D, H * dh),
        "wv": dense_init(ks[2], D, H * dh),
        "wo": dense_init(ks[3], H * dh, D),
        "bq": jnp.zeros((H * dh,)), "bo": jnp.zeros((D,)),
    }


def cross_kv(cfg, p, memory):
    """Precompute cross-attention K/V from encoder output (B, Se, D)."""
    B, Se, _ = memory.shape
    H, dh = cfg.n_heads, cfg.head_dim
    k = (memory @ p["wk"].astype(memory.dtype)).reshape(B, Se, H, dh)
    v = (memory @ p["wv"].astype(memory.dtype)).reshape(B, Se, H, dh)
    return k, v


def cross_attend(cfg, p, x, k, v):
    B, S, _ = x.shape
    H, dh = cfg.n_heads, cfg.head_dim
    q = (x @ p["wq"].astype(x.dtype) + p["bq"].astype(x.dtype)
         ).reshape(B, S, H, dh)
    o = attn.blockwise_attn(q, k, v, causal=False,
                            q_chunk=min(1024, S), kv_chunk=min(1024, k.shape[1]))
    return o.reshape(B, S, H * dh) @ p["wo"].astype(x.dtype) \
        + p["bo"].astype(x.dtype)


def init_enc_layer(key, cfg):
    ks = split_keys(key, 2)
    return {
        "norm1": _init_norm(cfg.d_model),
        "attn": attn.init_gqa(ks[0], cfg),
        "norm2": _init_norm(cfg.d_model),
        "ffn": mlp.init_gelu_mlp(ks[1], cfg.d_model, cfg.d_ff,
                                 cfg.n_enc_layers, use_bias=True),
    }


def init_dec_layer(key, cfg):
    ks = split_keys(key, 3)
    return {
        "norm1": _init_norm(cfg.d_model),
        "attn": attn.init_gqa(ks[0], cfg),
        "norm_x": _init_norm(cfg.d_model),
        "cross": init_cross(ks[1], cfg),
        "norm2": _init_norm(cfg.d_model),
        "ffn": mlp.init_gelu_mlp(ks[2], cfg.d_model, cfg.d_ff,
                                 cfg.n_layers, use_bias=True),
    }


def init_encdec(key, cfg) -> dict:
    ks = split_keys(key, 6 + cfg.n_enc_layers + cfg.n_layers)
    params: dict[str, Any] = {
        "embed": {"table": embed_init(ks[0], cfg.vocab, cfg.d_model)},
        # sized to cover the assigned 32k decode/prefill shapes
        "pos_table": embed_init(ks[1], 40_960, cfg.d_model),
        "enc_pos_table": embed_init(ks[2], cfg.enc_seq, cfg.d_model),
        "enc_final": _init_norm(cfg.d_model),
        "final_norm": _init_norm(cfg.d_model),
    }
    enc = [init_enc_layer(ks[6 + i], cfg) for i in range(cfg.n_enc_layers)]
    dec = [init_dec_layer(ks[6 + cfg.n_enc_layers + i], cfg)
           for i in range(cfg.n_layers)]
    params["enc_blocks"] = jax.tree.map(lambda *xs: jnp.stack(xs), *enc)
    params["dec_blocks"] = jax.tree.map(lambda *xs: jnp.stack(xs), *dec)
    return params


def encode(cfg, ctx, params, frames):
    """frames: (B, enc_seq, D) stub embeddings -> (B, enc_seq, D)."""
    Se = frames.shape[1]
    h = frames + params["enc_pos_table"][:Se].astype(frames.dtype)
    positions = jnp.broadcast_to(jnp.arange(Se)[None], frames.shape[:2])

    def body(h, p):
        hn = _ln(h, p["norm1"], cfg.norm_eps)
        h = h + attn.gqa_train(cfg, p["attn"], hn, positions, rope=False,
                               causal=False,
                               q_chunk=min(1024, Se), kv_chunk=min(1024, Se))
        hn = _ln(h, p["norm2"], cfg.norm_eps)
        h = h + mlp.gelu_mlp(p["ffn"], hn)
        h = ctx.shard_batch(h)
        return h, None

    h, _ = jax.lax.scan(body, h, params["enc_blocks"])
    return _ln(h, params["enc_final"], cfg.norm_eps)


def decode_seq(cfg, ctx, params, tokens_embed, memory, *, remat=False,
               q_chunk=1024, kv_chunk=1024):
    """Full-sequence decoder pass (training). tokens_embed: (B, S, D)."""
    B, S, _ = tokens_embed.shape
    h = tokens_embed + params["pos_table"][:S].astype(tokens_embed.dtype)
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    def body(h, p):
        hn = _ln(h, p["norm1"], cfg.norm_eps)
        h = h + attn.gqa_train(cfg, p["attn"], hn, positions, rope=False,
                               causal=True, q_chunk=min(q_chunk, S),
                               kv_chunk=min(kv_chunk, S))
        hn = _ln(h, p["norm_x"], cfg.norm_eps)
        k, v = cross_kv(cfg, p["cross"], memory)
        h = h + cross_attend(cfg, p["cross"], hn, k, v)
        hn = _ln(h, p["norm2"], cfg.norm_eps)
        h = h + mlp.gelu_mlp(p["ffn"], hn)
        h = ctx.shard_batch(h)
        return h, None

    if remat:
        body = jax.checkpoint(body)
    h, _ = jax.lax.scan(body, h, params["dec_blocks"])
    return _ln(h, params["final_norm"], cfg.norm_eps)


def init_dec_cache(cfg, batch: int, cache_len: int, dtype=jnp.bfloat16):
    L = cfg.n_layers
    H, dh = cfg.n_heads, cfg.head_dim
    kv = (L, batch, cache_len, cfg.n_kv_heads, dh)
    xkv = (L, batch, cfg.enc_seq, H, dh)
    return {
        "k": jnp.zeros(kv, dtype), "v": jnp.zeros(kv, dtype),
        "xk": jnp.zeros(xkv, dtype), "xv": jnp.zeros(xkv, dtype),
    }


def prefill(cfg, ctx, params, tokens_embed, memory, cache_len, *,
            q_chunk=1024, kv_chunk=1024):
    """Full-sequence decoder pass that also emits self/cross KV caches.

    Runs as a lax.scan over the stacked decoder blocks; the caches come
    out as the scan's stacked ys."""
    B, S, _ = tokens_embed.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    pad = cache_len - S
    h = tokens_embed + params["pos_table"][:S].astype(tokens_embed.dtype)

    def body(h, p):
        hn = _ln(h, p["norm1"], cfg.norm_eps)
        q, k, v = attn.gqa_qkv(cfg, p["attn"], hn, positions, rope=False)
        o = attn.blockwise_attn(q, k, v, causal=True,
                                q_chunk=min(q_chunk, S),
                                kv_chunk=min(kv_chunk, S))
        h = h + attn.gqa_out(cfg, p["attn"], o, h.dtype)
        k_c = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v_c = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        hn = _ln(h, p["norm_x"], cfg.norm_eps)
        xk, xv = cross_kv(cfg, p["cross"], memory)
        h = h + cross_attend(cfg, p["cross"], hn, xk, xv)
        hn = _ln(h, p["norm2"], cfg.norm_eps)
        h = h + mlp.gelu_mlp(p["ffn"], hn)
        h = ctx.shard_batch(h)
        return h, {"k": k_c, "v": v_c, "xk": xk, "xv": xv}

    h, caches = jax.lax.scan(body, h, params["dec_blocks"])
    return _ln(h, params["final_norm"], cfg.norm_eps), caches


def decode_step(cfg, ctx, params, tok_embed, pos, caches):
    """One decoder token. tok_embed: (B, 1, D)."""
    B = tok_embed.shape[0]
    h = tok_embed + jax.lax.dynamic_slice_in_dim(
        params["pos_table"], pos, 1, axis=0).astype(tok_embed.dtype)

    def body(h, xs):
        p, k_c, v_c, xk, xv = xs
        hn = _ln(h, p["norm1"], cfg.norm_eps)
        o, (k_c, v_c) = attn.gqa_decode(cfg, p["attn"], hn, pos, (k_c, v_c),
                                        rope=False)  # learned positions
        h = h + o
        hn = _ln(h, p["norm_x"], cfg.norm_eps)
        H, dh = cfg.n_heads, cfg.head_dim
        q = (hn @ p["cross"]["wq"].astype(hn.dtype)
             + p["cross"]["bq"].astype(hn.dtype)).reshape(B, 1, H, dh)
        xo = attn.decode_attn(q, xk, xv, xk.shape[1])
        h = h + (xo.reshape(B, 1, H * dh) @ p["cross"]["wo"].astype(hn.dtype)
                 + p["cross"]["bo"].astype(hn.dtype))
        hn = _ln(h, p["norm2"], cfg.norm_eps)
        h = h + mlp.gelu_mlp(p["ffn"], hn)
        return h, (k_c, v_c)

    h, (k_new, v_new) = jax.lax.scan(
        body, h, (params["dec_blocks"], caches["k"], caches["v"],
                  caches["xk"], caches["xv"]))
    caches = dict(caches, k=k_new, v=v_new)
    return _ln(h, params["final_norm"], cfg.norm_eps), caches
