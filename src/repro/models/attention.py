"""Attention: GQA with blockwise (flash-style) train/prefill path, cached
decode path, and DeepSeek-V2 MLA (latent KV) with absorbed decode.

Memory discipline: full (Sq, Skv) score materialization at 32k tokens is
~4 TB — the train/prefill path therefore runs a blockwise online-softmax
(lax.scan over KV chunks inside a scan over Q chunks), keeping live scores
at (q_chunk, kv_chunk). This is the flash-attention *algorithm* expressed
in jnp; on TPU the MXU-tiled matmuls inside each block are what the
hardware wants, and XLA keeps the running (m, l, acc) carries in
registers/VMEM.

GQA layout: q is grouped as (B, S, KVH, G, dh) so every block matmul
contracts over full tiles without materializing repeated K/V.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import rotary
from .common import dense_init, rms_norm, split_keys

_NEG_INF = -1e30


# --------------------------------------------------------------------------
# blockwise attention core
# --------------------------------------------------------------------------
def blockwise_attn(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                   causal: bool = True, q_offset=0,
                   q_chunk: int = 1024, kv_chunk: int = 1024,
                   skip_masked_blocks: bool = False) -> jnp.ndarray:
    """q: (B, Sq, H, dh); k/v: (B, Skv, KVH, dh) -> (B, Sq, H, dh).

    ``skip_masked_blocks`` wraps fully-masked KV blocks in lax.cond so the
    causal lower triangle costs ~half the FLOPs (beyond-baseline perf
    switch; see EXPERIMENTS.md §Perf)."""
    B, Sq, H, dh = q.shape
    _, Skv, KVH, _ = k.shape
    dv = v.shape[-1]            # MLA: value dim may differ from q/k dim
    G = H // KVH
    qc, kvc = min(q_chunk, Sq), min(kv_chunk, Skv)
    if Sq % qc:      # non-divisible (odd test shapes): single chunk
        qc = Sq
    if Skv % kvc:
        kvc = Skv
    nq, nkv = Sq // qc, Skv // kvc
    scale = dh ** -0.5

    qr = q.reshape(B, nq, qc, KVH, G, dh).transpose(1, 0, 2, 3, 4, 5)
    kr = k.reshape(B, nkv, kvc, KVH, dh).transpose(1, 0, 2, 3, 4)
    vr = v.reshape(B, nkv, kvc, KVH, dv).transpose(1, 0, 2, 3, 4)

    def q_step(_, iq_qb):
        iq, qb = iq_qb                      # qb: (B, qc, KVH, G, dh)
        q_pos = q_offset + iq * qc + jnp.arange(qc)

        def kv_step(carry, ikv_kb):
            m_run, l_run, acc = carry
            ikv, kb, vb = ikv_kb            # kb/vb: (B, kvc, KVH, dh)

            # checkpointed: the (qc, kvc) score/prob blocks are
            # rematerialized in the backward pass (flash-attention's
            # recompute trade) instead of being stacked as scan residuals
            # — that stack is O(S^2) bytes and dwarfs HBM at 32k tokens.
            @jax.checkpoint
            def compute(args):
                m_run, l_run, acc = args
                s = jnp.einsum("bqhgd,bkhd->bhgqk", qb, kb,
                               preferred_element_type=jnp.float32) * scale
                if causal:
                    kv_pos = ikv * kvc + jnp.arange(kvc)
                    mask = q_pos[:, None] >= kv_pos[None, :]
                    s = jnp.where(mask[None, None, None], s, _NEG_INF)
                m_new = jnp.maximum(m_run, jnp.max(s, axis=-1))
                p = jnp.exp(s - m_new[..., None])
                corr = jnp.exp(m_run - m_new)
                l_new = l_run * corr + jnp.sum(p, axis=-1)
                acc = acc * corr[..., None] + jnp.einsum(
                    "bhgqk,bkhd->bhgqd", p.astype(vb.dtype), vb,
                    preferred_element_type=jnp.float32)
                return m_new, l_new, acc

            if causal and skip_masked_blocks:
                # block is fully masked iff its first kv pos > last q pos
                live = (ikv * kvc) <= (q_offset + iq * qc + qc - 1)
                carry = jax.lax.cond(live, compute, lambda a: a,
                                     (m_run, l_run, acc))
            else:
                carry = compute((m_run, l_run, acc))
            return carry, None

        m0 = jnp.full((B, KVH, G, qc), _NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KVH, G, qc), jnp.float32)
        a0 = jnp.zeros((B, KVH, G, qc, dv), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), (jnp.arange(nkv), kr, vr))
        out = acc / jnp.maximum(l, 1e-30)[..., None]   # (B, KVH, G, qc, dh)
        return None, out.transpose(0, 3, 1, 2, 4)      # (B, qc, KVH, G, dh)

    _, outs = jax.lax.scan(q_step, None, (jnp.arange(nq), qr))
    # outs: (nq, B, qc, KVH, G, dv) -> (B, Sq, H, dv)
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq, H, dv)
    return out.astype(q.dtype)


def seq_parallel_attention(ctx, q, k, v, *, causal=True, q_chunk=1024,
                           kv_chunk=1024, skip_masked_blocks=False):
    """Ulysses-style sequence-parallel attention island: the query
    sequence is sharded over the model axis (each device runs the
    blockwise kernel over its local q chunks against replicated K/V,
    with q_offset fixing causality). Divides O(S^2) attention compute by
    the TP degree for archs whose head count cannot shard (smollm: 9
    heads on a 16-way axis -> 16x replicated attention otherwise).
    K/V replication is cheap for small-KV GQA. Falls back to plain
    blockwise attention when S doesn't divide."""
    from jax.sharding import PartitionSpec as P

    from repro.compat import shard_map

    B, S = q.shape[0], q.shape[1]
    tp = ctx.tp_axis
    dp = ctx.dp_axes if B % ctx.axis_size(ctx.dp_axes) == 0 else ()
    if tp is None or S % ctx.axis_size(tp) != 0:
        return blockwise_attn(q, k, v, causal=causal, q_chunk=q_chunk,
                              kv_chunk=kv_chunk,
                              skip_masked_blocks=skip_masked_blocks)
    S_loc = S // ctx.axis_size(tp)

    def island(q_, k_, v_):
        off = jax.lax.axis_index(tp) * S_loc
        return blockwise_attn(q_, k_, v_, causal=causal, q_offset=off,
                              q_chunk=min(q_chunk, S_loc),
                              kv_chunk=kv_chunk,
                              skip_masked_blocks=skip_masked_blocks)

    qspec = P(dp, tp, None, None)
    kvspec = P(dp, None, None, None)
    fn = shard_map(island, mesh=ctx.mesh,
                   in_specs=(qspec, kvspec, kvspec), out_specs=qspec,
                   check_vma=False)
    return fn(q, k, v)


def decode_attn_island(ctx, q, k_cache, v_cache, pos, k_new, v_new):
    """Distributed cached decode as an explicit shard_map island.

    Layout: batch over DP (when divisible), cache *sequence* over the
    model axis (context-parallel decode; long-context batch-1 cells
    spread S over data x model). Each device updates its own cache shard
    in place and computes a local online-softmax partial; the shards
    combine with O(B*H*dh) psums. This bypasses GSPMD entirely for the
    cache — the observed alternative was a full-cache regather per step
    (10-30x the useful bytes) plus an f32 upcast copy on backends without
    native bf16 dots.

    q/k_new/v_new: (B, 1, H|KVH, dh); caches: (B, S, KVH, dh).
    Returns (attn out (B, 1, H, dh), new k_cache, new v_cache)."""
    from jax.sharding import PartitionSpec as P

    from repro import compat  # local import: cycle-free
    from repro.compat import shard_map

    B, S, KVH, _ = k_cache.shape
    H, dh = q.shape[2], q.shape[3]
    dp_ok = B % ctx.axis_size(ctx.dp_axes) == 0
    dp = ctx.dp_axes if dp_ok else ()
    if dp_ok:
        seq_axes = (ctx.tp_axis,)
    else:  # long-context single-sequence: 2-D context parallelism
        seq_axes = tuple(a for a in (ctx.fsdp_axis, ctx.tp_axis) if a)
    if not seq_axes or S % ctx.axis_size(seq_axes) != 0:
        k_c = jax.lax.dynamic_update_slice_in_dim(
            k_cache, k_new.astype(k_cache.dtype), pos, axis=1)
        v_c = jax.lax.dynamic_update_slice_in_dim(
            v_cache, v_new.astype(v_cache.dtype), pos, axis=1)
        return decode_attn(q, k_c, v_c, pos + 1), k_c, v_c

    def island(q_, kc, vc, pos_, kn, vn):
        S_loc = kc.shape[1]
        off = jnp.int32(0)
        for a in seq_axes:
            off = off * compat.axis_size(a) + jax.lax.axis_index(a)
        start = off * S_loc
        rel = pos_ - start
        ok = (rel >= 0) & (rel < S_loc)
        safe = jnp.clip(rel, 0, S_loc - 1)

        def upd(cache, new):   # masked in-place row update of this shard
            cur = jax.lax.dynamic_slice_in_dim(cache, safe, 1, axis=1)
            val = jnp.where(ok, new.astype(cache.dtype), cur)
            return jax.lax.dynamic_update_slice_in_dim(cache, val, safe,
                                                       axis=1)

        kc = upd(kc, kn)
        vc = upd(vc, vn)
        G = H // KVH
        qr = q_.reshape(q_.shape[0], KVH, G, dh)
        s = jnp.einsum("bhgd,bkhd->bhgk", qr, kc).astype(jnp.float32)
        s = s * dh ** -0.5
        valid = (start + jnp.arange(S_loc))[None] <= pos_
        s = jnp.where(valid[:, None, None, :], s, _NEG_INF)
        m_loc = jnp.max(s, axis=-1)
        p = jnp.exp(s - m_loc[..., None])
        l_loc = jnp.sum(p, axis=-1)
        o_loc = jnp.einsum("bhgk,bkhd->bhgd", p.astype(vc.dtype), vc
                           ).astype(jnp.float32)
        m = jax.lax.pmax(m_loc, seq_axes)
        corr = jnp.exp(m_loc - m)
        l = jax.lax.psum(l_loc * corr, seq_axes)
        o = jax.lax.psum(o_loc * corr[..., None], seq_axes)
        o = o / jnp.maximum(l, 1e-30)[..., None]
        return o.astype(q_.dtype), kc, vc

    qspec = P(dp, None, None, None)
    cspec = P(dp, seq_axes, None, None)
    fn = shard_map(island, mesh=ctx.mesh,
                   in_specs=(qspec, cspec, cspec, P(), qspec, qspec),
                   out_specs=(qspec, cspec, cspec), check_vma=False)
    o, k_c, v_c = fn(q, k_cache, v_cache, pos, k_new, v_new)
    return o.reshape(B, 1, H, dh), k_c, v_c


def decode_attn(q: jnp.ndarray, k_cache: jnp.ndarray, v_cache: jnp.ndarray,
                valid_len) -> jnp.ndarray:
    """Single-token attention over a cache.

    q: (B, 1, H, dh); caches: (B, S, KVH, dh); valid_len: scalar or (B,).
    """
    B, _, H, dh = q.shape
    S, KVH = k_cache.shape[1], k_cache.shape[2]
    G = H // KVH
    qr = q.reshape(B, KVH, G, dh)
    # NB: operand-dtype dot (bf16): the TPU MXU accumulates f32 anyway;
    # asking XLA-CPU for preferred f32 materializes an f32 copy of the
    # whole cache (2x HBM) before the dot. Scores upcast after.
    s = jnp.einsum("bhgd,bkhd->bhgk", qr, k_cache
                   ).astype(jnp.float32) * dh ** -0.5
    pos = jnp.arange(S)
    valid = jnp.asarray(valid_len)
    mask = pos[None, :] < valid.reshape(-1, 1)         # (B or 1, S)
    s = jnp.where(mask[:, None, None, :], s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", p.astype(v_cache.dtype), v_cache)
    return out.reshape(B, 1, H, dh).astype(q.dtype)


# --------------------------------------------------------------------------
# GQA attention block
# --------------------------------------------------------------------------
def init_gqa(key, cfg):
    D, H, KVH, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = split_keys(key, 4)
    p = {
        "wq": dense_init(ks[0], D, H * dh),
        "wk": dense_init(ks[1], D, KVH * dh),
        "wv": dense_init(ks[2], D, KVH * dh),
        "wo": dense_init(ks[3], H * dh, D, scale=1.0 / (2 * cfg.n_layers) ** 0.5),
    }
    if cfg.use_bias:
        p.update(bq=jnp.zeros((H * dh,)), bk=jnp.zeros((KVH * dh,)),
                 bv=jnp.zeros((KVH * dh,)), bo=jnp.zeros((D,)))
    return p


def gqa_qkv(cfg, p, x, positions, *, rope: bool = True):
    """Project + rotate. x: (B, S, D); positions: (B, S) or (3, B, S)."""
    B, S, _ = x.shape
    H, KVH, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = x @ p["wq"].astype(x.dtype)
    k = x @ p["wk"].astype(x.dtype)
    v = x @ p["wv"].astype(x.dtype)
    if cfg.use_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    q = q.reshape(B, S, H, dh)
    k = k.reshape(B, S, KVH, dh)
    v = v.reshape(B, S, KVH, dh)
    if rope:
        if cfg.mrope:
            q = rotary.apply_mrope(q, positions, cfg.rope_theta,
                                   cfg.mrope_sections)
            k = rotary.apply_mrope(k, positions, cfg.rope_theta,
                                   cfg.mrope_sections)
        else:
            q = rotary.apply_rope(q, positions, cfg.rope_theta)
            k = rotary.apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def gqa_out(cfg, p, attn_out, dtype):
    B, S = attn_out.shape[:2]
    out = attn_out.reshape(B, S, -1) @ p["wo"].astype(dtype)
    if cfg.use_bias:
        out = out + p["bo"].astype(dtype)
    return out


def gqa_train(cfg, p, x, positions, *, q_chunk=1024, kv_chunk=1024,
              skip_masked_blocks=False, rope=True, causal=True, ctx=None,
              seq_parallel=False):
    q, k, v = gqa_qkv(cfg, p, x, positions, rope=rope)
    if seq_parallel and ctx is not None and ctx.mesh is not None:
        o = seq_parallel_attention(ctx, q, k, v, causal=causal,
                                   q_chunk=q_chunk, kv_chunk=kv_chunk,
                                   skip_masked_blocks=skip_masked_blocks)
    else:
        o = blockwise_attn(q, k, v, causal=causal, q_chunk=q_chunk,
                           kv_chunk=kv_chunk,
                           skip_masked_blocks=skip_masked_blocks)
    return gqa_out(cfg, p, o, x.dtype)


def gqa_prefill(cfg, p, x, positions, cache_len, *, q_chunk=1024,
                kv_chunk=1024, skip_masked_blocks=False, ctx=None,
                seq_parallel=False):
    """Returns (out, (k_cache, v_cache)) — caches padded to cache_len."""
    q, k, v = gqa_qkv(cfg, p, x, positions)
    if seq_parallel and ctx is not None and ctx.mesh is not None:
        o = seq_parallel_attention(ctx, q, k, v, causal=True,
                                   q_chunk=q_chunk, kv_chunk=kv_chunk,
                                   skip_masked_blocks=skip_masked_blocks)
    else:
        o = blockwise_attn(q, k, v, causal=True, q_chunk=q_chunk,
                           kv_chunk=kv_chunk,
                           skip_masked_blocks=skip_masked_blocks)
    S = x.shape[1]
    pad = cache_len - S
    if pad > 0:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    return gqa_out(cfg, p, o, x.dtype), (k, v)


def gqa_decode(cfg, p, x, pos, cache, *, rope: bool = True, ctx=None):
    """One-token step. x: (B, 1, D); pos: scalar current index; cache:
    (k, v) each (B, S_max, KVH, dh). Returns (out, new_cache).

    The new-token K/V are constrained to the cache's own layout before
    the dynamic update — without this GSPMD re-replicates the whole cache
    around the DUS (a ~10x per-step all-gather at 32k context)."""
    B = x.shape[0]
    positions = jnp.full((B, 1), pos, jnp.int32)
    if cfg.mrope:
        positions = jnp.broadcast_to(positions, (3, B, 1))
    q, k_new, v_new = gqa_qkv(cfg, p, x, positions, rope=rope)
    k_cache, v_cache = cache
    if ctx is not None and ctx.mesh is not None:
        o, k_cache, v_cache = decode_attn_island(
            ctx, q, k_cache, v_cache, pos, k_new, v_new)
    else:
        k_cache = jax.lax.dynamic_update_slice_in_dim(
            k_cache, k_new.astype(k_cache.dtype), pos, axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(
            v_cache, v_new.astype(v_cache.dtype), pos, axis=1)
        o = decode_attn(q, k_cache, v_cache, pos + 1)
    return gqa_out(cfg, p, o, x.dtype), (k_cache, v_cache)


# --------------------------------------------------------------------------
# MLA (DeepSeek-V2): latent KV compression, absorbed decode
# --------------------------------------------------------------------------
def init_mla(key, cfg):
    D, H = cfg.d_model, cfg.n_heads
    r, qr_ = cfg.kv_lora_rank, cfg.q_lora_rank
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    ks = split_keys(key, 6)
    p = {
        "wkv_a": dense_init(ks[0], D, r + dr),          # -> [ckv, k_rope]
        "kv_norm": jnp.ones((r,)),
        "wkv_b": dense_init(ks[1], r, H * (dn + dv)),   # latent -> k_nope,v
        "wo": dense_init(ks[2], H * dv, D,
                         scale=1.0 / (2 * cfg.n_layers) ** 0.5),
    }
    if qr_:
        p["wq_a"] = dense_init(ks[3], D, qr_)
        p["q_norm"] = jnp.ones((qr_,))
        p["wq_b"] = dense_init(ks[4], qr_, H * (dn + dr))
    else:
        p["wq"] = dense_init(ks[5], D, H * (dn + dr))
    return p


def _mla_q(cfg, p, x, positions):
    B, S, _ = x.shape
    H, dn, dr = cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim
    if cfg.q_lora_rank:
        ql = rms_norm(x @ p["wq_a"].astype(x.dtype), p["q_norm"],
                      cfg.norm_eps)
        q = ql @ p["wq_b"].astype(x.dtype)
    else:
        q = x @ p["wq"].astype(x.dtype)
    q = q.reshape(B, S, H, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = rotary.apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _mla_latent(cfg, p, x, positions):
    """ckv (B,S,r) normalized latent + rotated shared k_rope (B,S,1,dr)."""
    r, dr = cfg.kv_lora_rank, cfg.qk_rope_dim
    kv = x @ p["wkv_a"].astype(x.dtype)
    ckv, k_rope = kv[..., :r], kv[..., r:]
    ckv = rms_norm(ckv, p["kv_norm"], cfg.norm_eps)
    k_rope = rotary.apply_rope(k_rope[:, :, None, :], positions,
                               cfg.rope_theta)
    return ckv, k_rope


def mla_train(cfg, p, x, positions, *, q_chunk=1024, kv_chunk=1024,
              skip_masked_blocks=False):
    """Training/prefill: expand latent to full per-head K/V (standard)."""
    B, S, _ = x.shape
    H, dn, dr, dv = (cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim,
                     cfg.v_head_dim)
    q_nope, q_rope = _mla_q(cfg, p, x, positions)
    ckv, k_rope = _mla_latent(cfg, p, x, positions)
    kv = (ckv @ p["wkv_b"].astype(x.dtype)).reshape(B, S, H, dn + dv)
    k_nope, v = kv[..., :dn], kv[..., dn:]
    q = jnp.concatenate([q_nope, q_rope], -1)
    k = jnp.concatenate([k_nope,
                         jnp.broadcast_to(k_rope, (B, S, H, dr))], -1)
    o = blockwise_attn(q, k, v, causal=True, q_chunk=q_chunk,
                       kv_chunk=kv_chunk,
                       skip_masked_blocks=skip_masked_blocks)
    return o.reshape(B, S, H * dv) @ p["wo"].astype(x.dtype)


def mla_prefill(cfg, p, x, positions, cache_len, **kw):
    """Returns (out, (ckv_cache, k_rope_cache)) — the *latent* cache: this
    is MLA's contribution, 576 floats/token instead of H*(dn+dv)."""
    out = mla_train(cfg, p, x, positions, **kw)
    ckv, k_rope = _mla_latent(cfg, p, x, positions)
    S, pad = x.shape[1], cache_len - x.shape[1]
    if pad > 0:
        ckv = jnp.pad(ckv, ((0, 0), (0, pad), (0, 0)))
        k_rope = jnp.pad(k_rope, ((0, 0), (0, pad), (0, 0), (0, 0)))
    del S
    return out, (ckv, k_rope[:, :, 0, :])


def mla_decode(cfg, p, x, pos, cache, *, ctx=None):
    """Absorbed decode (the deployment path in arXiv:2405.04434): scores
    and context are taken against the latent cache directly; W_UK folds
    into the query and W_UV into the output."""
    B = x.shape[0]
    H, dn, dr, dv = (cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim,
                     cfg.v_head_dim)
    r = cfg.kv_lora_rank
    positions = jnp.full((B, 1), pos, jnp.int32)
    q_nope, q_rope = _mla_q(cfg, p, x, positions)        # (B,1,H,dn/dr)
    ckv_new, k_rope_new = _mla_latent(cfg, p, x, positions)

    ckv_cache, k_rope_cache = cache                      # (B,S,r), (B,S,dr)

    def pin(t, tp_ok=True):
        del tp_ok
        if ctx is None:
            return t
        return ctx.constrain(t, ctx.dp_axes, ctx.tp_axis, None)

    ckv_cache = pin(jax.lax.dynamic_update_slice_in_dim(
        ckv_cache, pin(ckv_new.astype(ckv_cache.dtype)), pos, axis=1))
    k_rope_cache = pin(jax.lax.dynamic_update_slice_in_dim(
        k_rope_cache,
        pin(k_rope_new[:, :, 0, :].astype(k_rope_cache.dtype), False),
        pos, axis=1), False)

    wkv_b = p["wkv_b"].reshape(r, H, dn + dv)
    w_uk, w_uv = wkv_b[..., :dn], wkv_b[..., dn:]        # (r,H,dn),(r,H,dv)
    # absorb W_UK into q: (B,1,H,dn) x (r,H,dn) -> (B,H,r)
    q_lat = jnp.einsum("bqhd,rhd->bhr", q_nope, w_uk.astype(x.dtype))
    s = jnp.einsum("bhr,bkr->bhk", q_lat,
                   ckv_cache).astype(jnp.float32)
    s = s + jnp.einsum("bqhd,bkd->bhk", q_rope,
                       k_rope_cache).astype(jnp.float32)
    s = s * (dn + dr) ** -0.5
    S = ckv_cache.shape[1]
    mask = jnp.arange(S)[None, None, :] < (pos + 1)
    s = jnp.where(mask, s, _NEG_INF)
    pweights = jax.nn.softmax(s, axis=-1)
    ctx_lat = jnp.einsum("bhk,bkr->bhr", pweights.astype(x.dtype), ckv_cache)
    o = jnp.einsum("bhr,rhd->bhd", ctx_lat, w_uv.astype(x.dtype))
    out = o.reshape(B, 1, H * dv) @ p["wo"].astype(x.dtype)
    return out, (ckv_cache, k_rope_cache)
