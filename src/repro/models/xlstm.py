"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory, parallelizable)
and sLSTM (scalar memory, true recurrence).

mLSTM per head: C_t = f_t C_{t-1} + i_t v_t k_t^T ; n_t = f_t n_{t-1} + i_t k_t
    h_t = (C_t q_t) / max(|n_t^T q_t|, 1)
with exponential input gate and sigmoid-exp forget gate stabilized by the
running max m_t (log-domain). Training/prefill uses the paper's chunkwise
form: within-chunk decay-masked attention (parallel, MXU) + cross-chunk
state carried by a lax.scan; chunk bodies are rematerialized.

sLSTM is sequential by construction (recurrent gate dependency on h_{t-1}
through block-diagonal per-head recurrent weights) — it runs as a plain
lax.scan over time; the assignment's xlstm-350m places it on 4 of 24
layers. Decode for both is O(1) state update.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import dense_init, split_keys

_EPS = 1e-6


# ------------------------------------------------------------------- mLSTM
def init_mlstm(key, cfg):
    D, di, H = cfg.d_model, cfg.d_inner, cfg.n_heads
    dh = di // H
    ks = split_keys(key, 7)
    return {
        "in_proj": dense_init(ks[0], D, 2 * di),            # -> [x, z]
        "wq": dense_init(ks[1], di, di),
        "wk": dense_init(ks[2], di, di),
        "wv": dense_init(ks[3], di, di),
        "w_if": dense_init(ks[4], di, 2 * H, scale=0.1),    # i, f gates
        "b_if": jnp.concatenate([jnp.zeros((H,)), 3.0 * jnp.ones((H,))]),
        "out_norm": jnp.ones((di,)),
        "out_proj": dense_init(ks[5], di, D,
                               scale=1.0 / (2 * cfg.n_layers) ** 0.5),
    }, dh


def _mlstm_heads(cfg, p, x):
    """x: (B, S, D) -> q,k,v (B,S,H,dh), log-gates i,f (B,S,H), z (B,S,di)."""
    B, S, _ = x.shape
    H, di = cfg.n_heads, cfg.d_inner
    dh = di // H
    xz = x @ p["in_proj"].astype(x.dtype)
    xi, z = jnp.split(xz, 2, axis=-1)
    q = (xi @ p["wq"].astype(x.dtype)).reshape(B, S, H, dh)
    k = (xi @ p["wk"].astype(x.dtype)).reshape(B, S, H, dh) * dh ** -0.5
    v = (xi @ p["wv"].astype(x.dtype)).reshape(B, S, H, dh)
    gates = (xi @ p["w_if"].astype(x.dtype)).astype(jnp.float32) \
        + p["b_if"].astype(jnp.float32)
    ig, fg = jnp.split(gates, 2, axis=-1)                   # (B,S,H) each
    logf = jax.nn.log_sigmoid(fg)
    return q, k, v, ig, logf, z


def mlstm_seq(cfg, p, x, *, chunk: int = 256, remat: bool = True):
    """Chunkwise-parallel mLSTM. x: (B, S, D) -> (B, S, D)."""
    B, S, D = x.shape
    H, di = cfg.n_heads, cfg.d_inner
    dh = di // H
    q, k, v, ig, logf, z = _mlstm_heads(cfg, p, x)
    c = min(chunk, S)
    if S % c:        # non-divisible (odd test shapes): single chunk
        c = S
    n = S // c

    resh = lambda t: t.reshape(B, n, c, *t.shape[2:]).swapaxes(0, 1)
    qs, ks_, vs = map(resh, (q.astype(jnp.float32),
                             k.astype(jnp.float32),
                             v.astype(jnp.float32)))
    igs, lfs = resh(ig), resh(logf)

    def body(carry, args):
        C0, n0, m0 = carry          # (B,H,dh,dh), (B,H,dh), (B,H)
        qc, kc, vc, ic, lfc = args  # (B,c,H,*) / (B,c,H)
        F = jnp.cumsum(lfc, axis=1)                     # (B,c,H) log decay
        # log weight of past state at step t: m0 + F_t ; of entry j<=t:
        # F_t - F_j + i_j
        a = F + m0[:, None, :]                          # past contribution
        bmat = (F[:, :, None, :] - F[:, None, :, :]
                + ic[:, None, :, :])                    # (B,t,j,H)
        causal = jnp.tril(jnp.ones((c, c), bool))
        bmat = jnp.where(causal[None, :, :, None], bmat, -jnp.inf)
        m_new = jnp.maximum(a, jnp.max(bmat, axis=2))   # (B,c,H)
        w_past = jnp.exp(a - m_new)                     # (B,c,H)
        w_in = jnp.exp(bmat - m_new[:, :, None, :])     # (B,t,j,H)
        # intra-chunk attention-style term
        scores = jnp.einsum("bthd,bjhd->btjh", qc, kc) * w_in
        num_in = jnp.einsum("btjh,bjhd->bthd", scores, vc)
        den_in = jnp.sum(scores, axis=2)[..., None]     # (B,t,H,1)
        # cross-chunk term from carried state
        num_past = jnp.einsum("bthd,bhde->bthe", qc, C0) * w_past[..., None]
        den_past = jnp.einsum("bthd,bhd->bth", qc, n0)[..., None] \
            * w_past[..., None]
        num = num_in + num_past
        den = den_in + den_past
        h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_new)[..., None] + _EPS)
        # update carried state to end of chunk
        Fc = F[:, -1, :]                                # (B,H) total decay
        m1 = jnp.maximum(Fc + m0, jnp.max(ic + (Fc[:, None, :] - F), axis=1))
        sc = jnp.exp(Fc + m0 - m1)                      # state scale
        wj = jnp.exp(ic + Fc[:, None, :] - F - m1[:, None, :])  # (B,c,H)
        C1 = C0 * sc[..., None, None] + jnp.einsum(
            "bjh,bjhd,bjhe->bhde", wj, kc, vc)
        n1 = n0 * sc[..., None] + jnp.einsum("bjh,bjhd->bhd", wj, kc)
        return (C1, n1, m1), h

    if remat:
        body = jax.checkpoint(body)
    C0 = jnp.zeros((B, H, dh, dh), jnp.float32)
    n0 = jnp.zeros((B, H, dh), jnp.float32)
    m0 = jnp.full((B, H), -1e30, jnp.float32)
    _, hs = jax.lax.scan(body, (C0, n0, m0), (qs, ks_, vs, igs, lfs))
    h = hs.swapaxes(0, 1).reshape(B, S, di).astype(x.dtype)
    h = h * (p["out_norm"].astype(x.dtype))
    h = h * jax.nn.silu(z)
    return h @ p["out_proj"].astype(x.dtype)


def mlstm_init_state(cfg, batch: int):
    H, dh = cfg.n_heads, cfg.d_inner // cfg.n_heads
    return {
        "C": jnp.zeros((batch, H, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, H, dh), jnp.float32),
        "m": jnp.full((batch, H), -1e30, jnp.float32),
    }


def mlstm_decode(cfg, p, x, state):
    """x: (B, 1, D) -> (out, new_state); O(1) per token."""
    B = x.shape[0]
    di = cfg.d_inner
    q, k, v, ig, logf, z = _mlstm_heads(cfg, p, x)
    qt, kt, vt = (t[:, 0].astype(jnp.float32) for t in (q, k, v))
    it, lft = ig[:, 0], logf[:, 0]                          # (B,H)
    m1 = jnp.maximum(lft + state["m"], it)
    fs = jnp.exp(lft + state["m"] - m1)
    is_ = jnp.exp(it - m1)
    C1 = state["C"] * fs[..., None, None] \
        + is_[..., None, None] * jnp.einsum("bhd,bhe->bhde", kt, vt)
    n1 = state["n"] * fs[..., None] + is_[..., None] * kt
    num = jnp.einsum("bhd,bhde->bhe", qt, C1)
    den = jnp.einsum("bhd,bhd->bh", qt, n1)[..., None]
    h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m1)[..., None] + _EPS)
    h = h.reshape(B, 1, di).astype(x.dtype) * p["out_norm"].astype(x.dtype)
    h = h * jax.nn.silu(z)
    return h @ p["out_proj"].astype(x.dtype), {"C": C1, "n": n1, "m": m1}


# ------------------------------------------------------------------- sLSTM
def init_slstm(key, cfg):
    D, H = cfg.d_model, cfg.n_heads
    dh = D // H
    ks = split_keys(key, 3)
    return {
        "w_gates": dense_init(ks[0], D, 4 * D),             # z, i, f, o
        "r_gates": 0.1 * jax.random.normal(ks[1], (H, dh, 4 * dh)),
        "b_gates": jnp.concatenate(
            [jnp.zeros((2 * D,)), 3.0 * jnp.ones((D,)), jnp.zeros((D,))]),
        "out_proj": dense_init(ks[2], D, D,
                               scale=1.0 / (2 * cfg.n_layers) ** 0.5),
    }


def slstm_init_state(cfg, batch: int):
    D = cfg.d_model
    return {
        "c": jnp.zeros((batch, D), jnp.float32),
        "n": jnp.ones((batch, D), jnp.float32),
        "h": jnp.zeros((batch, D), jnp.float32),
        "m": jnp.zeros((batch, D), jnp.float32),
    }


def _slstm_cell(cfg, p, xt, st):
    """xt: (B, D) f32 pre-activations W x_t; st: state dict."""
    B = xt.shape[0]
    D, H = cfg.d_model, cfg.n_heads
    dh = D // H
    hprev = st["h"].reshape(B, H, dh)
    rec = jnp.einsum("bhd,hde->bhe", hprev,
                     p["r_gates"].astype(jnp.float32)).reshape(B, 4 * D)
    pre = xt + rec + p["b_gates"].astype(jnp.float32)
    z, i, f, o = jnp.split(pre, 4, axis=-1)
    z = jnp.tanh(z)
    o = jax.nn.sigmoid(o)
    logf = jax.nn.log_sigmoid(f)
    m1 = jnp.maximum(logf + st["m"], i)
    fs = jnp.exp(logf + st["m"] - m1)
    is_ = jnp.exp(i - m1)
    c1 = fs * st["c"] + is_ * z
    n1 = fs * st["n"] + is_
    h1 = o * c1 / jnp.maximum(n1, _EPS)
    return {"c": c1, "n": n1, "h": h1, "m": m1}


def slstm_seq(cfg, p, x):
    """x: (B, S, D) -> (B, S, D); plain recurrence over time."""
    B, S, D = x.shape
    xg = (x @ p["w_gates"].astype(x.dtype)).astype(jnp.float32)

    def step(st, xt):
        st1 = _slstm_cell(cfg, p, xt, st)
        return st1, st1["h"]

    st0 = slstm_init_state(cfg, B)
    _, hs = jax.lax.scan(step, st0, xg.swapaxes(0, 1))
    h = hs.swapaxes(0, 1).astype(x.dtype)
    return h @ p["out_proj"].astype(x.dtype)


def slstm_decode(cfg, p, x, state):
    xg = (x[:, 0, :] @ p["w_gates"].astype(x.dtype)).astype(jnp.float32)
    st1 = _slstm_cell(cfg, p, xg, state)
    h = st1["h"][:, None, :].astype(x.dtype)
    return h @ p["out_proj"].astype(x.dtype), st1
