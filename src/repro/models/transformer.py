"""Unified decoder-only stack covering dense GQA, MoE, MLA, the Jamba
hybrid and xLSTM — every assigned non-enc-dec architecture.

Layers are grouped into *periods* (cfg.layer_period): within a period the
block types may differ (Jamba: 7 mamba + 1 attention; xLSTM: 5 mLSTM + 1
sLSTM), across periods they repeat, so parameters are stacked over periods
and the stack runs as one lax.scan — HLO size stays O(period), compile
time stays flat in depth, and caches ride the scan as stacked pytrees.

Training wraps the period body in jax.checkpoint (activation remat:
recompute the period in backward, keep only period-boundary activations).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from . import attention as attn
from . import mamba as mb
from . import mlp
from . import xlstm as xl
from .common import compute_dtype, embed_init, rms_norm, split_keys


# --------------------------------------------------------------- structure
def block_kind(cfg, j: int) -> tuple[str, str | None]:
    """(mixer, ffn) type names for period position j."""
    if cfg.family == "ssm":
        mixer = "slstm" if cfg.is_slstm_layer(j) else "mlstm"
        return mixer, None
    mixer = ("mla" if cfg.mla else "gqa") if cfg.is_attn_layer(j) else "mamba"
    ffn = "moe" if cfg.is_moe_layer(j) else "swiglu"
    return mixer, ffn


def init_block(key, cfg, j: int) -> dict:
    mixer, ffn = block_kind(cfg, j)
    ks = split_keys(key, 2)
    p: dict[str, Any] = {"norm1": jnp.ones((cfg.d_model,))}
    if mixer == "gqa":
        p["attn"] = attn.init_gqa(ks[0], cfg)
    elif mixer == "mla":
        p["attn"] = attn.init_mla(ks[0], cfg)
    elif mixer == "mamba":
        p["mamba"] = mb.init_mamba(ks[0], cfg)
    elif mixer == "mlstm":
        p["mlstm"], _ = xl.init_mlstm(ks[0], cfg)
    else:
        p["slstm"] = xl.init_slstm(ks[0], cfg)
    if ffn is not None:
        p["norm2"] = jnp.ones((cfg.d_model,))
        if ffn == "moe":
            p["moe"] = mlp.init_moe(ks[1], cfg)
        else:
            p["ffn"] = mlp.init_swiglu(ks[1], cfg.d_model, cfg.d_ff,
                                       cfg.n_layers)
    return p


def init_decoder(key, cfg, *, with_embed: bool = True) -> dict:
    period = cfg.layer_period
    n_periods = cfg.n_layers // period
    assert cfg.n_layers % period == 0, (cfg.n_layers, period)
    keys = split_keys(key, 3 + cfg.n_layers)
    params: dict[str, Any] = {}
    if with_embed:
        params["embed"] = {"table": embed_init(keys[0], cfg.vocab,
                                               cfg.d_model)}
        if not cfg.tie_embeddings:
            params["unembed"] = embed_init(keys[1], cfg.vocab, cfg.d_model)
    layers: dict[str, Any] = {}
    for j in range(period):
        per = [init_block(keys[3 + i * period + j], cfg, j)
               for i in range(n_periods)]
        layers[f"pos{j}"] = jax.tree.map(lambda *xs: jnp.stack(xs), *per)
    params["layers"] = layers
    params["final_norm"] = jnp.ones((cfg.d_model,))
    return params


# ------------------------------------------------------------------ caches
def init_block_cache(cfg, j: int, batch: int, cache_len: int, dtype):
    mixer, _ = block_kind(cfg, j)
    if mixer == "gqa":
        kv = (batch, cache_len, cfg.n_kv_heads, cfg.head_dim)
        return (jnp.zeros(kv, dtype), jnp.zeros(kv, dtype))
    if mixer == "mla":
        return (jnp.zeros((batch, cache_len, cfg.kv_lora_rank), dtype),
                jnp.zeros((batch, cache_len, cfg.qk_rope_dim), dtype))
    if mixer == "mamba":
        return mb.mamba_init_state(cfg, batch, dtype)
    if mixer == "mlstm":
        return xl.mlstm_init_state(cfg, batch)
    return xl.slstm_init_state(cfg, batch)


def init_cache(cfg, batch: int, cache_len: int, dtype=jnp.bfloat16):
    period = cfg.layer_period
    n_periods = cfg.n_layers // period
    caches = {}
    for j in range(period):
        one = init_block_cache(cfg, j, batch, cache_len, dtype)
        caches[f"pos{j}"] = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (n_periods,) + x.shape), one)
    return caches


# ------------------------------------------------------------- block apply
def apply_block_seq(cfg, ctx, p, j: int, h, positions, *, q_chunk, kv_chunk,
                    ssm_chunk, remat_inner=True, skip_masked_blocks=False,
                    seq_parallel_attn=False):
    del remat_inner  # chunk remat is unconditional (see mamba below)
    mixer, ffn = block_kind(cfg, j)
    hn = rms_norm(h, p["norm1"], cfg.norm_eps)
    if mixer == "gqa":
        mix = attn.gqa_train(cfg, p["attn"], hn, positions, q_chunk=q_chunk,
                             kv_chunk=kv_chunk,
                             skip_masked_blocks=skip_masked_blocks,
                             ctx=ctx, seq_parallel=seq_parallel_attn)
    elif mixer == "mla":
        mix = attn.mla_train(cfg, p["attn"], hn, positions, q_chunk=q_chunk,
                             kv_chunk=kv_chunk,
                             skip_masked_blocks=skip_masked_blocks)
    elif mixer == "mamba":
        # chunk-level remat is ALWAYS on (nested under the period-level
        # checkpoint): the (B, c, d_inner, d_state) state expansions must
        # never become stacked scan residuals, including during the
        # period's backward recompute.
        mix = mb.mamba_seq(cfg, p["mamba"], hn, chunk=ssm_chunk,
                           remat=True)
    elif mixer == "mlstm":
        mix = xl.mlstm_seq(cfg, p["mlstm"], hn, chunk=ssm_chunk,
                           remat=True)
    else:
        mix = xl.slstm_seq(cfg, p["slstm"], hn)
    h = h + mix
    if ffn is not None:
        hn = rms_norm(h, p["norm2"], cfg.norm_eps)
        f = (mlp.moe_apply(cfg, ctx, p["moe"], hn) if ffn == "moe"
             else mlp.swiglu(p["ffn"], hn))
        h = h + f
    h = ctx.shard_batch(h)
    return h


def apply_block_prefill(cfg, ctx, p, j, h, positions, cache_len, *,
                        q_chunk, kv_chunk, ssm_chunk,
                        seq_parallel_attn=False):
    """Like seq but also returns the cache for serving."""
    mixer, ffn = block_kind(cfg, j)
    hn = rms_norm(h, p["norm1"], cfg.norm_eps)
    if mixer == "gqa":
        mix, cache = attn.gqa_prefill(cfg, p["attn"], hn, positions,
                                      cache_len, q_chunk=q_chunk,
                                      kv_chunk=kv_chunk, ctx=ctx,
                                      seq_parallel=seq_parallel_attn)
    elif mixer == "mla":
        mix, cache = attn.mla_prefill(cfg, p["attn"], hn, positions,
                                      cache_len, q_chunk=q_chunk,
                                      kv_chunk=kv_chunk)
    elif mixer == "mamba":
        # final state = cache; rerun-free: seq pass returns outputs only,
        # so recompute the last state cheaply via decode of final chunk is
        # avoided by carrying state out of mamba_seq — use scan's carry.
        mix, cache = _mamba_prefill(cfg, p["mamba"], hn, ssm_chunk)
    elif mixer == "mlstm":
        mix, cache = _mlstm_prefill(cfg, p["mlstm"], hn, ssm_chunk)
    else:
        mix, cache = _slstm_prefill(cfg, p["slstm"], hn)
    h = h + mix
    if ffn is not None:
        hn = rms_norm(h, p["norm2"], cfg.norm_eps)
        f = (mlp.moe_apply(cfg, ctx, p["moe"], hn) if ffn == "moe"
             else mlp.swiglu(p["ffn"], hn))
        h = h + f
    h = ctx.shard_batch(h)
    return h, cache


def apply_block_decode(cfg, ctx, p, j, h, pos, cache):
    mixer, ffn = block_kind(cfg, j)
    hn = rms_norm(h, p["norm1"], cfg.norm_eps)
    if mixer == "gqa":
        mix, cache = attn.gqa_decode(cfg, p["attn"], hn, pos, cache,
                                     ctx=ctx)
    elif mixer == "mla":
        mix, cache = attn.mla_decode(cfg, p["attn"], hn, pos, cache,
                                     ctx=ctx)
    elif mixer == "mamba":
        mix, cache = mb.mamba_decode(cfg, p["mamba"], hn, cache)
    elif mixer == "mlstm":
        mix, cache = xl.mlstm_decode(cfg, p["mlstm"], hn, cache)
    else:
        mix, cache = xl.slstm_decode(cfg, p["slstm"], hn, cache)
    h = h + mix
    if ffn is not None:
        hn = rms_norm(h, p["norm2"], cfg.norm_eps)
        f = (mlp.moe_apply(cfg, ctx, p["moe"], hn) if ffn == "moe"
             else mlp.swiglu(p["ffn"], hn))
        h = h + f
    return h, cache


# ------------------------------------------------- prefill state extractors
def _mamba_prefill(cfg, p, hn, chunk):
    B, S, _ = hn.shape
    out = mb.mamba_seq(cfg, p, hn, chunk=chunk, remat=False)
    # recover final recurrent state by one decode sweep over the last
    # (d_conv-1 + 1) tokens is incorrect for h; instead rerun the scan
    # carrying state — mamba_seq discards it, so recompute cheaply here.
    state = mb.mamba_init_state(cfg, B, hn.dtype)
    # cheap exact state: single fused scan pass without outputs
    xz = hn @ p["in_proj"].astype(hn.dtype)
    xs, _ = jnp.split(xz, 2, axis=-1)
    dc = cfg.mamba_d_conv
    xpad = jnp.pad(xs, ((0, 0), (dc - 1, 0), (0, 0)))
    xc = sum(xpad[:, i:i + S, :] * p["conv_w"][:, i].astype(hn.dtype)
             for i in range(dc))
    xc = jax.nn.silu(xc + p["conv_bias"].astype(hn.dtype))
    dt, Bm, Cm = mb._ssm_params(cfg, p, xc)
    A = jnp.exp(p["a_log"]).astype(jnp.float32)
    c = min(256, S)
    if S % c:
        c = S
    n = S // c
    resh = lambda t: t.reshape(B, n, c, *t.shape[2:]).swapaxes(0, 1)

    def body(h0, args):
        dtc, Bc, xcc = args
        dA = jnp.exp(dtc[..., None] * (-A))
        dBx = (dtc * xcc)[..., None] * Bc[:, :, None, :]

        def step(h, t):
            return dA[:, t] * h + dBx[:, t], None
        h1, _ = jax.lax.scan(step, h0, jnp.arange(c))
        return h1, None

    h_last, _ = jax.lax.scan(body, state["h"],
                             (resh(dt), resh(Bm),
                              resh(xc.astype(jnp.float32))))
    del Cm
    state = {"h": h_last, "conv": xs[:, S - (dc - 1):, :]}
    return out, state


def _mlstm_prefill(cfg, p, hn, chunk):
    out = xl.mlstm_seq(cfg, p, hn, chunk=chunk, remat=False)
    B, S, _ = hn.shape
    q, k, v, ig, logf, _ = xl._mlstm_heads(cfg, p, hn)
    del q
    # fold the whole sequence into the state (chunked, no outputs)
    st = xl.mlstm_init_state(cfg, B)
    c = min(256, S)
    if S % c:
        c = S
    n = S // c
    resh = lambda t: t.reshape(B, n, c, *t.shape[2:]).swapaxes(0, 1)

    def body(carry, args):
        C0, n0, m0 = carry
        kc, vc, ic, lfc = args
        F = jnp.cumsum(lfc, axis=1)
        Fc = F[:, -1, :]
        m1 = jnp.maximum(Fc + m0, jnp.max(ic + (Fc[:, None, :] - F), axis=1))
        sc = jnp.exp(Fc + m0 - m1)
        wj = jnp.exp(ic + Fc[:, None, :] - F - m1[:, None, :])
        C1 = C0 * sc[..., None, None] + jnp.einsum(
            "bjh,bjhd,bjhe->bhde", wj, kc.astype(jnp.float32),
            vc.astype(jnp.float32))
        n1 = n0 * sc[..., None] + jnp.einsum(
            "bjh,bjhd->bhd", wj, kc.astype(jnp.float32))
        return (C1, n1, m1), None

    (C1, n1, m1), _ = jax.lax.scan(
        body, (st["C"], st["n"], st["m"]),
        (resh(k), resh(v), resh(ig), resh(logf)))
    return out, {"C": C1, "n": n1, "m": m1}


def _slstm_prefill(cfg, p, hn):
    B, S, _ = hn.shape
    xg = (hn @ p["w_gates"].astype(hn.dtype)).astype(jnp.float32)

    def step(st, xt):
        st1 = xl._slstm_cell(cfg, p, xt, st)
        return st1, st1["h"]

    st0 = xl.slstm_init_state(cfg, B)
    st, hs = jax.lax.scan(step, st0, xg.swapaxes(0, 1))
    out = hs.swapaxes(0, 1).astype(hn.dtype) @ p["out_proj"].astype(hn.dtype)
    return out, st


# ----------------------------------------------------------------- forward
def embed_tokens(cfg, params, tokens, dtype):
    # gather first, cast after: avoids materializing a casted copy of the
    # full (V, D) table per step
    return params["embed"]["table"][tokens].astype(dtype)


def unembed_matrix(cfg, params):
    return (params["embed"]["table"] if cfg.tie_embeddings
            else params["unembed"])


def forward_seq(cfg, ctx, params, h, positions, *, remat: bool = False,
                q_chunk: int = 1024, kv_chunk: int = 1024,
                ssm_chunk: int = 256, skip_masked_blocks: bool = False,
                remat_policy: str = "nothing",
                seq_parallel_attn: bool = False):
    """Body of train/prefill-style full-sequence passes: h (B, S, D).

    remat_policy: 'nothing' (recompute everything in backward) or 'dots'
    (save matmul outputs — incl. FSDP-gathered weights' products — so the
    backward re-gathers less at higher memory; §Perf lever)."""
    period = cfg.layer_period

    def body(h, period_params):
        for j in range(period):
            h = apply_block_seq(cfg, ctx, period_params[f"pos{j}"], j, h,
                                positions, q_chunk=q_chunk,
                                kv_chunk=kv_chunk, ssm_chunk=ssm_chunk,
                                remat_inner=not remat,
                                skip_masked_blocks=skip_masked_blocks,
                                seq_parallel_attn=seq_parallel_attn)
        return h, None

    if remat:
        if remat_policy == "dots":
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.checkpoint_dots)
        else:
            body = jax.checkpoint(body)
    h, _ = jax.lax.scan(body, h, params["layers"])
    return rms_norm(h, params["final_norm"], cfg.norm_eps)


def forward_prefill(cfg, ctx, params, h, positions, cache_len, *,
                    q_chunk=1024, kv_chunk=1024, ssm_chunk=256,
                    seq_parallel_attn=False):
    period = cfg.layer_period

    def body(h, period_params):
        caches = {}
        for j in range(period):
            h, cache = apply_block_prefill(
                cfg, ctx, period_params[f"pos{j}"], j, h, positions,
                cache_len, q_chunk=q_chunk, kv_chunk=kv_chunk,
                ssm_chunk=ssm_chunk, seq_parallel_attn=seq_parallel_attn)
            caches[f"pos{j}"] = cache
        return h, caches

    h, caches = jax.lax.scan(body, h, params["layers"])
    return rms_norm(h, params["final_norm"], cfg.norm_eps), caches


def forward_decode(cfg, ctx, params, h, pos, caches):
    period = cfg.layer_period

    def body(h, xs):
        period_params, period_caches = xs
        new = {}
        for j in range(period):
            h, c = apply_block_decode(cfg, ctx, period_params[f"pos{j}"], j,
                                      h, pos, period_caches[f"pos{j}"])
            new[f"pos{j}"] = c
        return h, new

    h, new_caches = jax.lax.scan(body, h, (params["layers"], caches))
    return rms_norm(h, params["final_norm"], cfg.norm_eps), new_caches
