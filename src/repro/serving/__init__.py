"""Serving substrate: LM prefill/decode steps + the SVM scoring path."""
from .serve_step import generate, make_decode_step, make_prefill_step  # noqa: F401
from .svm_serve import (DEFAULT_TILE, DeadlineExceeded,  # noqa: F401
                        ServableModel, ServeLoop, ServeRejected,
                        SVMScorer, WeightPager, phi_never_materialized)
