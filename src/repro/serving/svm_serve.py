"""SVM serving: fused featurize-and-score with continuous batching.

The predict-side analogue of the fit-time campaign (ROADMAP: production
serving path). A fitted model exports a frozen :class:`ServableModel`;
an :class:`SVMScorer` holds its arrays device-resident and scores
requests through a jit-compiled, shape-bucketed *score cell*;
:class:`WeightPager` LRU-pages many tenant models over a shared cell
family; :class:`ServeLoop` decouples request intake from device compute
(continuous batching: coalesce -> bucket-pad -> one dispatch -> split).

Bitwise bucket invariance — the load-bearing design decision
------------------------------------------------------------
XLA's CPU matmul is NOT bitwise stable across row counts: scoring 700
rows and slicing the first 700 of a 1000-row dispatch differ in low
bits, which would make served scores depend on which bucket a request
landed in. It IS bitwise stable at a fixed shape, regardless of row
position and of what the other rows contain. So every score cell
computes over fixed ``(tile, .)`` row tiles via ``lax.map``: any bucket
dispatches the identical per-tile computation, and a request's scores
are bit-identical whether it rides a 128-bucket alone or the tail of a
1024-bucket batch — the parity gate in ``benchmarks/serve_latency.py``
checks exactly this against the ``decision_function`` oracle (itself
routed through the same cell, satellite: no cold re-upload per call).
The feature width is pinned per model (``ServableModel.weights`` rows),
since zero-padding columns is also not bitwise neutral.

The Nystrom family runs ``ops.nystrom_score`` per tile — the *scoring*
epilogue of the fused featurizer: the phi tile lives in VMEM, feeds one
MXU matmul against the resident (M, C) weight block, and dies; the
(N, M) feature matrix never exists in HBM at predict time either
(``phi_never_materialized`` walks the traced jaxpr to prove it). C
score columns carry tenants/classes and, in MC-posterior mode, the
uncertainty directions: with U = L^{-T} from the Cholesky factor of the
posterior precision P = lam I + S, ``std(margin) = ||phi U||`` row-wise
— margin +- calibrated uncertainty is the same single fused dispatch.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from collections import OrderedDict
from concurrent.futures import Future
from typing import Callable

import numpy as np

import jax
import jax.numpy as jnp

from repro.kernels import ops

DEFAULT_TILE = 128

# One compiled score cell per static configuration, shared by every
# tenant model with that configuration (the weight-paging contract:
# weights are runtime operands, not closure constants). TRACE_COUNTS
# increments inside the cell body — a Python side effect that runs only
# when jax traces, so it counts compilations, not calls (the no-retrace
# regression tests key off it).
_CELL_CACHE: dict[tuple, Callable] = {}
TRACE_COUNTS: dict[tuple, int] = {}


def _get_cell(key: tuple) -> Callable:
    """jit-compiled score cell for a static config key.

    linear key:  ("linear", add_bias, tile)
                 cell(X (B, D), mask (B,), W (Kfit, C)) -> (B, C)
                 in-cell prep mirrors fit: bias column (= mask, the
                 stream driver's own convention) appended FIRST, then
                 zero columns up to Kfit (the pad_features width).
    nystrom key: ("nystrom", kind, sigma, phi_add_bias, tile, backend)
                 cell(X, mask, W (M, C), lm, pj) -> (B, C)
                 per-tile ops.nystrom_score — phi in VMEM only.
    """
    if key in _CELL_CACHE:
        return _CELL_CACHE[key]
    family = key[0]
    if family == "linear":
        _, add_bias, tile = key

        def cell(X, mask, W):
            TRACE_COUNTS[key] = TRACE_COUNTS.get(key, 0) + 1
            B, D = X.shape
            Kfit, C = W.shape
            pad = Kfit - (D + int(add_bias))
            if pad < 0:
                raise ValueError(
                    f"request feature width {D} (+bias={add_bias}) "
                    f"exceeds the model's fitted width {Kfit}")

            def one(args):
                x, m = args
                xb = (jnp.concatenate([x, m[:, None]], axis=1)
                      if add_bias else x)
                if pad:
                    xb = jnp.pad(xb, ((0, 0), (0, pad)))
                return (xb @ W) * m[:, None]

            out = jax.lax.map(
                one, (X.reshape(B // tile, tile, D),
                      mask.reshape(B // tile, tile)))
            return out.reshape(B, C)
    else:
        _, kind, sigma, phi_add_bias, tile, backend = key

        def cell(X, mask, W, lm, pj):
            TRACE_COUNTS[key] = TRACE_COUNTS.get(key, 0) + 1
            B, D = X.shape

            def one(args):
                x, m = args
                return ops.nystrom_score(
                    x, lm, pj, W, m, sigma=sigma, kind=kind,
                    add_bias=phi_add_bias, backend=backend,
                    block_n=tile)

            out = jax.lax.map(
                one, (X.reshape(B // tile, tile, D),
                      mask.reshape(B // tile, tile)))
            return out.reshape(B, W.shape[1])

    _CELL_CACHE[key] = jax.jit(cell)
    TRACE_COUNTS.setdefault(key, 0)
    return _CELL_CACHE[key]


@dataclasses.dataclass(frozen=True, eq=False)
class ServableModel:
    """Frozen, host-side export of a fitted SVM — everything serving
    needs, nothing it doesn't (replaces reaching into the solver's
    ``_weights``/``_train_X``/``_phi_arrays`` plumbing).

    ``weights`` is (Kfit, C) float32: columns [0, n_outputs) are margin
    directions (1, or num_classes for MLT); any remaining columns are
    the posterior uncertainty directions U = L^{-T} (MC mode), so
    ``std(margin) = ||phi @ U||`` row-wise. ``landmarks``/``proj``
    present selects the fused Nystrom score cell (this also carries the
    exact-KRN model: landmarks = train rows, proj = omega[:, None],
    weights = [[1.]]); absent selects the linear cell, whose in-cell
    prep appends the bias column and pads to Kfit.
    """
    task: str                       # "cls" | "svr" | "mlt"
    weights: np.ndarray             # (Kfit, C) f32, margin cols first
    n_outputs: int                  # margin columns (1 or num_classes)
    n_features: int                 # raw request width D
    add_bias: bool = False          # linear-cell bias column
    landmarks: np.ndarray | None = None
    proj: np.ndarray | None = None
    phi_kind: str = "rbf"
    phi_sigma: float = 1.0
    phi_add_bias: bool = False
    backend: str | None = None
    name: str = "svm"

    def __post_init__(self):
        object.__setattr__(
            self, "weights", np.asarray(self.weights, np.float32))
        assert self.weights.ndim == 2 and \
            self.n_outputs <= self.weights.shape[1]
        if self.landmarks is not None:
            object.__setattr__(
                self, "landmarks", np.asarray(self.landmarks, np.float32))
            object.__setattr__(
                self, "proj", np.asarray(self.proj, np.float32))

    @property
    def family(self) -> str:
        return "linear" if self.landmarks is None else "nystrom"

    @property
    def has_uncertainty(self) -> bool:
        return self.weights.shape[1] > self.n_outputs

    @property
    def nbytes(self) -> int:
        n = self.weights.nbytes
        if self.landmarks is not None:
            n += self.landmarks.nbytes + self.proj.nbytes
        return n


class SVMScorer:
    """Device-resident scorer for one :class:`ServableModel`.

    Arrays go to device exactly once (construction); every ``score``
    call pads its rows to a bucket, dispatches the shared jit cell, and
    slices the real rows back — mask-aware, so padding rows never
    change scores (see the module docstring for why that holds
    *bitwise*). Buckets are the power-of-two ladder
    tile, 2*tile, ..., max_bucket; larger batches chunk by max_bucket
    so every dispatch shape comes from the fixed ladder.
    """

    def __init__(self, model: ServableModel, *, tile: int = DEFAULT_TILE,
                 max_bucket: int = 1024):
        assert max_bucket % tile == 0
        self.model = model
        self.tile = tile
        self.max_bucket = max_bucket
        self._W = jnp.asarray(model.weights)
        if model.family == "nystrom":
            self._lm = jnp.asarray(model.landmarks)
            self._pj = jnp.asarray(model.proj)
            self.cell_key = ("nystrom", model.phi_kind,
                             float(model.phi_sigma), model.phi_add_bias,
                             tile, model.backend)
        else:
            self._lm = self._pj = None
            self.cell_key = ("linear", model.add_bias, tile)
        self._cell = _get_cell(self.cell_key)

    # ------------------------------------------------------------ buckets
    def bucket_for(self, n: int) -> int:
        b = self.tile
        while b < n and b < self.max_bucket:
            b *= 2
        return b

    @property
    def traces(self) -> int:
        """Compilation count of this scorer's (shared) cell."""
        return TRACE_COUNTS.get(self.cell_key, 0)

    # ------------------------------------------------------------ scoring
    def _dispatch(self, Xb: np.ndarray, mb: np.ndarray) -> jax.Array:
        args = (jnp.asarray(Xb), jnp.asarray(mb), self._W)
        if self._lm is not None:
            args += (self._lm, self._pj)
        return self._cell(*args)

    def score(self, X: np.ndarray) -> np.ndarray:
        """(n, C) float32 score columns for (n, D) raw request rows."""
        X = np.asarray(X, np.float32)
        if X.ndim != 2 or X.shape[1] != self.model.n_features:
            raise ValueError(
                f"model {self.model.name!r} expects (n, "
                f"{self.model.n_features}) requests, got {X.shape}")
        n = X.shape[0]
        outs, i = [], 0
        while i < n:
            take = min(n - i, self.max_bucket)
            b = self.bucket_for(take)
            Xb = np.zeros((b, X.shape[1]), np.float32)
            Xb[:take] = X[i:i + take]
            mb = np.zeros((b,), np.float32)
            mb[:take] = 1.0
            outs.append(np.asarray(self._dispatch(Xb, mb))[:take])
            i += take
        return outs[0] if len(outs) == 1 else np.concatenate(outs)

    def margins(self, X: np.ndarray) -> np.ndarray:
        out = self.score(X)[:, : self.model.n_outputs]
        return out[:, 0] if self.model.n_outputs == 1 else out

    def score_with_std(self, X: np.ndarray
                       ) -> tuple[np.ndarray, np.ndarray]:
        """(margin, std): calibrated posterior uncertainty serving.

        The uncertainty columns U = L^{-T} ride the same weight block,
        so margin and std come out of ONE fused dispatch:
        std_i = ||phi_i @ U|| = sqrt(phi_i^T P^{-1} phi_i).
        """
        assert self.model.has_uncertainty, (
            "model exported without posterior; use "
            "export_servable(posterior_from=(X, y))")
        out = self.score(X)
        k = self.model.n_outputs
        margin = out[:, 0] if k == 1 else out[:, :k]
        std = np.sqrt(np.sum(out[:, k:].astype(np.float64) ** 2, axis=1))
        return margin, std.astype(np.float32)

    def predict(self, X: np.ndarray) -> np.ndarray:
        m = self.margins(X)
        if self.model.task == "mlt":
            return np.argmax(m, axis=1)
        if self.model.task == "svr":
            return m
        return np.where(m >= 0, 1, -1)


def phi_never_materialized(scorer: SVMScorer, bucket: int) -> bool:
    """Walk the traced jaxpr of the score cell at ``bucket`` rows and
    verify no intermediate carries a full-batch phi / cross-Gram shape
    (bucket, m) or (bucket, M) — the residency gate the serve benchmark
    asserts. Requires bucket > tile so per-tile VMEM shapes (tile, m),
    which are the *point* of the fusion, are distinguishable."""
    m = scorer.model
    if m.family == "linear":
        return True
    assert bucket > scorer.tile and bucket % scorer.tile == 0
    phi_widths = {m.proj.shape[1], m.proj.shape[1] + 1,
                  m.landmarks.shape[0]}

    def cell_fn(X, mask):
        return scorer._cell(X, mask, scorer._W, scorer._lm, scorer._pj)

    jaxpr = jax.make_jaxpr(cell_fn)(
        jnp.zeros((bucket, m.n_features), jnp.float32),
        jnp.zeros((bucket,), jnp.float32))

    def walk(jx) -> bool:
        for eqn in jx.eqns:
            for v in eqn.outvars:
                shape = getattr(getattr(v, "aval", None), "shape", ())
                if (len(shape) == 2 and shape[0] == bucket
                        and shape[1] in phi_widths):
                    return False
            for val in eqn.params.values():
                sub = getattr(val, "jaxpr", val)
                if hasattr(sub, "eqns") and not walk(sub):
                    return False
        return True

    return walk(jaxpr.jaxpr)


class WeightPager:
    """LRU device residency for many tenant models over the shared cell
    family: register() keeps the host-side ServableModel; scorer()
    pages its arrays onto the device (building an SVMScorer) and evicts
    the least-recently-used tenant past ``max_resident`` — compiled
    cells are shared by configuration, so paging a tenant in is a
    weight upload, not a recompile."""

    def __init__(self, max_resident: int = 8, *,
                 tile: int = DEFAULT_TILE, max_bucket: int = 1024):
        assert max_resident >= 1
        self.max_resident = max_resident
        self.tile = tile
        self.max_bucket = max_bucket
        self._models: dict[str, ServableModel] = {}
        self._resident: OrderedDict[str, SVMScorer] = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def register(self, model: ServableModel) -> None:
        self._models[model.name] = model
        self._resident.pop(model.name, None)  # stale weights out

    @property
    def model_names(self) -> list[str]:
        return list(self._models)

    @property
    def resident_names(self) -> list[str]:
        return list(self._resident)

    @property
    def resident_bytes(self) -> int:
        return sum(s.model.nbytes for s in self._resident.values())

    def scorer(self, name: str) -> SVMScorer:
        if name in self._resident:
            self.hits += 1
            self._resident.move_to_end(name)
            return self._resident[name]
        if name not in self._models:
            raise KeyError(f"unknown model {name!r}; register() first")
        self.misses += 1
        s = SVMScorer(self._models[name], tile=self.tile,
                      max_bucket=self.max_bucket)
        self._resident[name] = s
        while len(self._resident) > self.max_resident:
            self._resident.popitem(last=False)
            self.evictions += 1
        return s


class DeadlineExceeded(RuntimeError):
    """A request's per-request deadline passed before it was scored; its
    Future fails with this instead of waiting forever."""


class ServeRejected(RuntimeError):
    """Backpressure: the intake queue is at capacity, so the request was
    shed at submit time — an explicit, immediate rejection the client
    can retry against another replica, instead of unbounded queueing
    that turns overload into timeouts for everyone."""


@dataclasses.dataclass
class _Request:
    model: str
    X: np.ndarray
    future: Future
    t_submit: float
    deadline_s: float | None = None   # absolute perf_counter() time


class ServeLoop:
    """Continuous-batching request loop (the actor/learner split,
    predict-side): intake enqueues (model, rows) and returns a Future;
    a drain — threaded (``start``) or synchronous (``step``, what tests
    and benchmarks drive) — coalesces queued requests per model,
    concatenates their rows, scores them as ONE bucketed dispatch
    through the :class:`WeightPager`, and splits the score rows back to
    each request's Future. Padding is mask-aware and per-tile fixed, so
    coalescing never changes any request's bits (module docstring).

    Overload behavior is explicit (DESIGN.md §Reliability): with
    ``max_queue`` set the intake is BOUNDED — a submit against a full
    queue returns a Future already failed with :class:`ServeRejected`
    (load shedding, counted in ``n_rejected``); a request whose
    deadline (``deadline_ms`` per request, or ``default_deadline_ms``)
    has passed by the time the drain picks it up fails with
    :class:`DeadlineExceeded` instead of occupying a batch slot
    (counted in ``n_expired``). Expiry is checked at drain time, so it
    is deterministic under the synchronous ``step()`` drive."""

    def __init__(self, pager: WeightPager, *, max_batch: int = 1024,
                 max_wait_ms: float = 2.0, max_queue: int | None = None,
                 default_deadline_ms: float | None = None):
        assert max_queue is None or max_queue >= 1, max_queue
        self.pager = pager
        self.max_batch = max_batch
        self.max_wait_ms = max_wait_ms
        self.default_deadline_ms = default_deadline_ms
        self._q: queue.Queue[_Request] = queue.Queue(
            maxsize=max_queue or 0)
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None
        # Counters are mutated from client threads (submit: n_rejected)
        # and the drain thread (_serve: everything else) concurrently,
        # so every read-modify-write goes through one lock.
        self._stats_lock = threading.Lock()
        self.latencies_ms: list[float] = []
        self.n_requests = 0
        self.n_rows = 0
        self.n_batches = 0
        self.n_rejected = 0
        self.n_expired = 0

    # ------------------------------------------------------------- intake
    def submit(self, model: str, X: np.ndarray, *,
               deadline_ms: float | None = None) -> Future:
        X = np.asarray(X, np.float32)
        assert X.ndim == 2 and X.shape[0] >= 1
        fut: Future = Future()
        now = time.perf_counter()
        ms = deadline_ms if deadline_ms is not None \
            else self.default_deadline_ms
        deadline = now + ms / 1e3 if ms is not None else None
        try:
            self._q.put_nowait(_Request(model, X, fut, now, deadline))
        except queue.Full:
            with self._stats_lock:
                self.n_rejected += 1
            fut.set_exception(ServeRejected(
                f"intake queue at capacity ({self._q.maxsize} requests); "
                "request shed — retry against another replica or back "
                "off"))
        return fut

    # -------------------------------------------------------------- drain
    def _drain_queue(self, block: bool) -> list[_Request]:
        reqs: list[_Request] = []
        rows = 0
        timeout = self.max_wait_ms / 1e3
        while rows < self.max_batch:
            try:
                r = self._q.get(block=block and not reqs,
                                timeout=timeout if block else None)
            except queue.Empty:
                break
            reqs.append(r)
            rows += r.X.shape[0]
        return reqs

    def _serve(self, reqs: list[_Request]) -> None:
        # Deadline check first: an expired request must not occupy batch
        # rows (its client has already given up).
        now = time.perf_counter()
        live: list[_Request] = []
        for r in reqs:
            if r.deadline_s is not None and now > r.deadline_s:
                with self._stats_lock:
                    self.n_expired += 1
                r.future.set_exception(DeadlineExceeded(
                    f"request for {r.model!r} expired after "
                    f"{(now - r.t_submit) * 1e3:.1f} ms in queue "
                    f"(deadline {(r.deadline_s - r.t_submit) * 1e3:.1f} "
                    "ms)"))
            else:
                live.append(r)
        by_model: dict[str, list[_Request]] = {}
        for r in live:
            by_model.setdefault(r.model, []).append(r)
        for name, group in by_model.items():
            try:
                scorer = self.pager.scorer(name)
                X = (group[0].X if len(group) == 1
                     else np.concatenate([r.X for r in group]))
                scores = scorer.score(X)
            except Exception as e:  # noqa: BLE001 — fail the futures
                for r in group:
                    r.future.set_exception(e)
                continue
            done = time.perf_counter()
            i = 0
            for r in group:
                n = r.X.shape[0]
                r.future.set_result(scores[i:i + n])
                i += n
            with self._stats_lock:
                self.n_batches += 1
                self.n_requests += len(group)
                self.n_rows += i
                self.latencies_ms.extend(
                    (done - r.t_submit) * 1e3 for r in group)

    def step(self) -> int:
        """Synchronous drain: serve everything queued right now.
        Returns the number of requests served."""
        reqs = self._drain_queue(block=False)
        if reqs:
            self._serve(reqs)
        return len(reqs)

    # ------------------------------------------------------------ threaded
    def _run(self) -> None:
        while not self._stop.is_set():
            reqs = self._drain_queue(block=True)
            if reqs:
                self._serve(reqs)
        self.step()  # final flush

    def start(self) -> "ServeLoop":
        assert self._thread is None, "already started"
        self._stop.clear()
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name="svm-serve-loop")
        self._thread.start()
        return self

    def stop(self) -> None:
        if self._thread is not None:
            self._stop.set()
            self._thread.join()
            self._thread = None

    # ------------------------------------------------------------- stats
    def latency_quantiles(self) -> dict:
        with self._stats_lock:
            counts = {"rejected": self.n_rejected,
                      "expired": self.n_expired}
            lat = np.asarray(self.latencies_ms)
        if lat.size == 0:
            return {"p50_ms": None, "p99_ms": None, **counts}
        q = np.quantile(lat, [0.5, 0.99])
        return {"p50_ms": float(q[0]), "p99_ms": float(q[1]), **counts}
