"""Token samplers for the serving path."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def greedy(logits: jnp.ndarray) -> jnp.ndarray:
    """logits: (B, V) -> (B,) int32."""
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def temperature(key, logits: jnp.ndarray, temp: float = 1.0,
                top_k: int = 0) -> jnp.ndarray:
    lg = logits.astype(jnp.float32) / max(temp, 1e-6)
    if top_k:
        kth = jnp.sort(lg, axis=-1)[:, -top_k][:, None]
        lg = jnp.where(lg < kth, -1e30, lg)
    return jax.random.categorical(key, lg, axis=-1).astype(jnp.int32)
