"""Serve-step builders: prefill and single-token decode.

``make_decode_step`` is the function lowered for the decode_32k /
long_500k dry-run cells: one new token against a seq_len-deep cache, cache
donated so the update is in-place."""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from . import sampler


def make_prefill_step(model, cache_len: int) -> Callable:
    """(params, batch) -> (next_token (B,), caches)."""
    def prefill_step(params, batch):
        logits, caches = model.prefill(params, batch, cache_len)
        return sampler.greedy(logits), caches
    return prefill_step


def make_decode_step(model, *, temp: float = 0.0, top_k: int = 0) -> Callable:
    """(params, tokens (B,1), pos, caches[, key]) ->
    (next_token (B,), logits, caches)."""
    def decode_step(params, tokens, pos, caches, key=None):
        logits, caches = model.decode(params, tokens, pos, caches)
        lg = logits[:, -1, :]
        if temp > 0.0:
            tok = sampler.temperature(key, lg, temp, top_k)
        else:
            tok = sampler.greedy(lg)
        return tok, lg, caches
    return decode_step


def generate(model, params, batch, *, steps: int, cache_len: int,
             temp: float = 0.0, top_k: int = 0, seed: int = 0):
    """Host-loop generation (examples / correctness tests; production uses
    the jitted steps directly)."""
    prefill = jax.jit(make_prefill_step(model, cache_len))
    decode = jax.jit(make_decode_step(model, temp=temp, top_k=top_k))
    tok, caches = prefill(params, batch)
    prompt_len = batch["tokens"].shape[1]
    out = [tok]
    key = jax.random.PRNGKey(seed)
    for i in range(steps - 1):
        key, sub = jax.random.split(key)
        tok, _, caches = decode(params, tok[:, None],
                                jnp.int32(prompt_len + i), caches, sub)
        out.append(tok)
    return jnp.stack(out, axis=1)
