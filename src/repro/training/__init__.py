"""Training substrate: AdamW (from scratch), chunked xent, train_step."""
from .optimizer import AdamWConfig, apply_updates, init_state, schedule  # noqa: F401
from .train_step import (  # noqa: F401
    chunked_softmax_xent, init_train_state, make_loss_fn, make_train_step)
