"""Train-step builder: chunked cross-entropy + AdamW + remat.

Chunked loss: at yi-34b train_4k the full logits tensor is
256 x 4096 x 64000 bf16 = 134 GB — never materialized. The final hidden
states are scanned in sequence chunks; each chunk computes its (B, C, V)
logits, its loss contribution, and is dropped (and rematerialized in the
backward by jax.checkpoint). Memory per chunk ~ B_loc * C * V_loc.
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from . import optimizer as opt


def chunked_softmax_xent(hidden: jnp.ndarray, unembed: jnp.ndarray,
                         labels: jnp.ndarray, *, chunk: int = 512,
                         z_loss: float = 0.0) -> jnp.ndarray:
    """Mean next-token xent. hidden: (B, S, D); unembed: (V, D);
    labels: (B, S) int32."""
    B, S, D = hidden.shape
    c = min(chunk, S)
    assert S % c == 0, (S, c)
    n = S // c
    w = unembed.astype(hidden.dtype)

    hs = hidden.reshape(B, n, c, D).swapaxes(0, 1)     # (n, B, c, D)
    ls = labels.reshape(B, n, c).swapaxes(0, 1)

    @jax.checkpoint
    def body(acc, args):
        h, lab = args
        logits = jnp.einsum("bcd,vd->bcv", h, w).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lab[..., None], axis=-1)[..., 0]
        loss = jnp.sum(lse - gold)
        if z_loss:
            loss = loss + z_loss * jnp.sum(jnp.square(lse))
        return acc + loss, None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (hs, ls))
    return total / (B * S)


def make_loss_fn(model, *, remat: bool = True, loss_chunk: int = 512,
                 z_loss: float = 0.0) -> Callable:
    def loss_fn(params, batch):
        hidden = model.hidden_seq(params, batch, remat=remat)
        return chunked_softmax_xent(hidden, model.unembed(params),
                                    batch["labels"], chunk=loss_chunk,
                                    z_loss=z_loss)
    return loss_fn


def init_train_state(model, key) -> dict:
    params = model.init(key)
    return {"params": params, "opt": opt.init_state(params)}


def make_train_step(model, opt_cfg: opt.AdamWConfig, *, remat: bool = True,
                    loss_chunk: int = 512, z_loss: float = 0.0,
                    microbatches: int = 1) -> Callable:
    """(state, batch) -> (state, metrics). Pure function of its inputs —
    jit/shard it at the launcher with in/out shardings.

    ``microbatches > 1`` runs gradient accumulation: the global batch is
    split along axis 0 and scanned, shrinking peak activation memory by
    the accumulation factor at the cost of one extra f32 gradient buffer.
    """
    loss_fn = make_loss_fn(model, remat=remat, loss_chunk=loss_chunk,
                           z_loss=z_loss)

    def grads_of(params, batch):
        if microbatches == 1:
            return jax.value_and_grad(loss_fn)(params, batch)

        def split(x):
            B = x.shape[1] if x.ndim >= 2 and x.shape[0] == 3 else x.shape[0]
            assert B % microbatches == 0, (B, microbatches)
            if x.ndim >= 2 and x.shape[0] == 3:   # (3, B, S) m-rope ids
                return x.reshape(3, microbatches, B // microbatches,
                                 *x.shape[2:]).swapaxes(0, 1)
            return x.reshape(microbatches, B // microbatches, *x.shape[1:])

        micro = jax.tree.map(split, batch)

        def body(acc, mb):
            loss_acc, g_acc = acc
            loss, g = jax.value_and_grad(loss_fn)(params, mb)
            g_acc = jax.tree.map(lambda a, b: a + b.astype(jnp.float32),
                                 g_acc, g)
            return (loss_acc + loss, g_acc), None

        zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                            params)
        (loss_sum, g_sum), _ = jax.lax.scan(
            body, (jnp.zeros((), jnp.float32), zero), micro)
        inv = 1.0 / microbatches
        return loss_sum * inv, jax.tree.map(lambda g: g * inv, g_sum)

    def train_step(state, batch):
        loss, grads = grads_of(state["params"], batch)
        params, opt_state, metrics = opt.apply_updates(
            opt_cfg, state["params"], grads, state["opt"])
        metrics = dict(metrics, loss=loss)
        return {"params": params, "opt": opt_state}, metrics

    return train_step
