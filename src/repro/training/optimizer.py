"""AdamW + gradient clipping + LR schedules, written from scratch
(no optax in the deployment environment).

State is a pytree mirroring params (m, v in f32), sharded identically to
the parameters by the launcher (ZeRO: the optimizer state of a
'data'-sharded parameter is 'data'-sharded too — GSPMD propagates the
specs through this update because it is elementwise)."""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    """Linear warmup -> cosine decay to min_lr_ratio * lr."""
    warm = jnp.minimum(1.0, (step + 1) / max(cfg.warmup_steps, 1))
    prog = jnp.clip((step - cfg.warmup_steps) /
                    max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def init_state(params) -> dict:
    zeros = lambda p: jax.tree.map(
        lambda x: jnp.zeros(x.shape, jnp.float32), p)
    return {"m": zeros(params), "v": zeros(params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def apply_updates(cfg: AdamWConfig, params, grads, state):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"]
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))
    lr = schedule(cfg, step)
    t = (step + 1).astype(jnp.float32)
    bc1 = 1.0 - cfg.b1 ** t
    bc2 = 1.0 - cfg.b2 ** t

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        # decoupled weight decay on matrices only (ndim >= 2)
        if p.ndim >= 2:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state["m"])
    flat_v = tdef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = tdef.unflatten([o[0] for o in out])
    new_state = {"m": tdef.unflatten([o[1] for o in out]),
                 "v": tdef.unflatten([o[2] for o in out]),
                 "step": step + 1}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
