"""Production mesh definition.

A FUNCTION, not a module-level constant: importing this module must never
touch jax device state (the dry-run sets the host-device-count XLA flag
before its first jax import; anything at module scope here would lock the
device count prematurely)."""
from __future__ import annotations

from repro.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes, axis_types=("auto",) * len(axes))


def make_host_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh over forced host devices (tests)."""
    return make_mesh(shape, axes, axis_types=("auto",) * len(axes))
