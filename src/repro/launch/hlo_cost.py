"""Exact cost analysis over optimized HLO text, with loop trip counts.

XLA's ``compiled.cost_analysis()`` counts each while-loop *body once*,
which under-counts scanned-layer models by orders of magnitude. The
optimized HLO carries ``backend_config={"known_trip_count":{"n":...}}`` on
every loop XLA could bound (all lax.scan loops qualify), so this module
re-derives, per device (the module is the per-partition SPMD program):

  * flops             — dot: 2 * |result| * |contracting|; elementwise: |result|
  * hbm_bytes         — operand+result bytes at fusion granularity
                        (inside-fusion intermediates are free; dynamic
                        slice/update/gather touch only the moved slice)
  * collective_bytes  — operand payload per collective kind

each multiplied through nested while-loop trip counts. ``conditional``
branches count at max() (mutually exclusive at runtime).
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {"pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2,
                "u16": 2, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4,
                "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
                "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e5m2fnuz": 1}

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\](?:\{[^}]*\})?")
_TRIP_RE = re.compile(r'known_trip_count[^0-9]*(\d+)')
_PARAM_RE = re.compile(r"%?([\w\.\-]+)\s*:\s*((?:\([^)]*\)|[\w\[\]\{\},]+))")


def _parse_inst_line(s: str):
    """'[ROOT] %name = <type> opcode(<rest>' -> (name, type, op, rest) or
    None. Handles tuple types with nested parens and /*index=N*/ comments."""
    if s.startswith("ROOT "):
        s = s[5:]
    eq = s.find(" = ")
    if eq < 0:
        return None
    name = s[:eq].strip().lstrip("%")
    if not re.fullmatch(r"[\w\.\-]+", name):
        return None
    rhs = s[eq + 3:].lstrip()
    # result type: balanced parens for tuples, else token up to space
    if rhs.startswith("("):
        depth = 0
        for i, ch in enumerate(rhs):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        type_str, rest = rhs[:i + 1], rhs[i + 1:].lstrip()
    else:
        sp = rhs.find(" ")
        if sp < 0:
            return None
        type_str, rest = rhs[:sp], rhs[sp + 1:].lstrip()
    om = re.match(r"([\w\-]+)\(", rest)
    if not om:
        return None
    return name, type_str, om.group(1), rest[om.end():]

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "power", "maximum", "minimum",
    "exponential", "exponential-minus-one", "log", "log-plus-one", "tanh",
    "logistic", "rsqrt", "sqrt", "cbrt", "negate", "abs", "sign", "floor",
    "ceil", "round-nearest-afz", "round-nearest-even", "remainder", "atan2",
    "cosine", "sine", "tan", "erf", "compare", "select", "clamp", "and",
    "or", "xor", "not", "shift-left", "shift-right-arithmetic",
    "shift-right-logical", "convert", "is-finite", "stochastic-convert",
}
_MOVEMENT = {"copy", "transpose", "concatenate", "pad", "slice", "reverse",
             "broadcast"}
_FREE = {"bitcast", "reshape", "tuple", "get-tuple-element", "parameter",
         "constant", "iota", "after-all", "partition-id", "replica-id",
         "copy-start", "copy-done", "domain", "opt-barrier",
         "get-dimension-size"}
_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute", "collective-broadcast")


def _shapes_of(type_str: str):
    """[(dtype, elems, bytes)] for every array shape in a type string."""
    out = []
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt in _DTYPE_BYTES:
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            out.append((dt, n, n * _DTYPE_BYTES[dt]))
    return out


def _dims_of(type_str: str):
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(x) for x in m.group(2).split(",") if x]


def _total_bytes(type_str: str) -> float:
    return float(sum(b for _, _, b in _shapes_of(type_str)))


def _total_elems(type_str: str) -> float:
    return float(sum(e for _, e, _ in _shapes_of(type_str)))


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_by_kind: dict = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    coll_ops: float = 0.0

    def add(self, o: "Cost"):
        self.flops += o.flops
        self.hbm_bytes += o.hbm_bytes
        self.coll_bytes += o.coll_bytes
        self.coll_ops += o.coll_ops
        for k, v in o.coll_by_kind.items():
            self.coll_by_kind[k] += v

    def scaled(self, f: float) -> "Cost":
        return Cost(self.flops * f, self.hbm_bytes * f, self.coll_bytes * f,
                    defaultdict(float, {k: v * f for k, v in
                                        self.coll_by_kind.items()}),
                    self.coll_ops * f)


@dataclasses.dataclass
class Inst:
    name: str
    type_str: str
    op: str
    rest: str       # everything after the opening paren of operands
    operands: list  # operand names


def _split_operands(arg_str: str) -> list[str]:
    """Operand names from 'a, %b.2, f32[2]{0} %c, ...)...' up to the
    matching close paren (depth-aware, including the commas inside
    shape brackets like f32[32,32]{1,0})."""
    names, depth, cur = [], 0, []
    for ch in arg_str:
        if ch in "([{":
            depth += 1
            cur.append(ch)
        elif ch in ")]}":
            if depth == 0:
                break
            depth -= 1
            cur.append(ch)
        elif ch == "," and depth == 0:
            names.append("".join(cur).strip())
            cur = []
        else:
            cur.append(ch)
    if cur:
        names.append("".join(cur).strip())
    out = []
    for n in names:
        m = re.search(r"%?([\w\.\-]+)$", n.strip())
        if m:
            out.append(m.group(1))
    return out


class HloModule:
    def __init__(self, text: str):
        self.computations: dict[str, list[Inst]] = {}
        self.types: dict[str, str] = {}   # instruction/param name -> type
        self.entry = None
        self._parse(text)
        self._cache: dict[str, Cost] = {}

    def _parse(self, text: str):
        cur = None
        for raw in text.splitlines():
            s = raw.strip()
            if not s or s.startswith("//"):
                continue
            is_inst = re.match(r"(?:ROOT\s+)?%?[\w\.\-]+\s*=", s)
            if s.endswith("{") and ("->" in s) and not is_inst:
                header = s[:-1]
                m = re.match(r"(?:ENTRY\s+)?%?([\w\.\-]+)\s*\((.*)\)\s*->",
                             header)
                if not m:
                    continue
                cur = m.group(1)
                self.computations[cur] = []
                if s.startswith("ENTRY"):
                    self.entry = cur
                for pname, ptype in _PARAM_RE.findall(m.group(2)):
                    self.types[pname] = ptype
                continue
            if s.startswith("}"):
                cur = None
                continue
            if cur is None or "=" not in s:
                continue
            parsed = _parse_inst_line(s)
            if parsed is None:
                continue
            name, type_str, op, rest = parsed
            self.types[name] = type_str
            self.computations[cur].append(
                Inst(name, type_str, op, rest, _split_operands(rest)))

    # ------------------------------------------------------------- costing
    def cost_of(self, comp: str) -> Cost:
        comp = comp.lstrip("%")
        if comp in self._cache:
            return self._cache[comp]
        total = Cost()
        self._cache[comp] = total
        for inst in self.computations.get(comp, []):
            total.add(self._inst_cost(inst))
        return total

    def entry_cost(self) -> Cost:
        return self.cost_of(self.entry)

    def _operand_bytes(self, inst: Inst) -> float:
        return sum(_total_bytes(self.types.get(o, "")) for o in inst.operands)

    def _inst_cost(self, inst: Inst) -> Cost:
        op, rest = inst.op, inst.rest
        res_bytes = _total_bytes(inst.type_str)
        res_elems = _total_elems(inst.type_str)

        if op == "while":
            trips = 1
            tm = _TRIP_RE.search(rest)
            if tm:
                trips = int(tm.group(1))
            inner = Cost()
            bm = re.search(r"body=%?([\w\.\-]+)", rest)
            cm = re.search(r"condition=%?([\w\.\-]+)", rest)
            if bm:
                inner.add(self.cost_of(bm.group(1)))
            if cm:
                inner.add(self.cost_of(cm.group(1)))
            return inner.scaled(trips)

        if op == "conditional":
            branches = []
            bm = re.search(r"branch_computations=\{([^}]*)\}", rest)
            if bm:
                branches = [b.strip() for b in bm.group(1).split(",")]
            else:
                for key in ("true_computation", "false_computation"):
                    m = re.search(key + r"=%?([\w\.\-]+)", rest)
                    if m:
                        branches.append(m.group(1))
            costs = [self.cost_of(b) for b in branches]
            if not costs:
                return Cost()
            return max(costs, key=lambda c: c.flops + c.hbm_bytes)

        if op in ("call", "map", "async-start"):
            cm = re.search(r"(?:to_apply|called_computation|calls)="
                           r"%?([\w\.\-]+)", rest)
            return self.cost_of(cm.group(1)) if cm else Cost()

        if op == "fusion":
            cm = re.search(r"calls=%?([\w\.\-]+)", rest)
            inner = self.cost_of(cm.group(1)) if cm else Cost()
            return Cost(inner.flops,
                        res_bytes + self._operand_bytes(inst),
                        inner.coll_bytes, inner.coll_by_kind, inner.coll_ops)

        base = op[:-6] if op.endswith("-start") else op
        if base in _COLL_KINDS:
            if op.endswith("-done"):
                return Cost()
            payload = self._operand_bytes(inst)
            return Cost(0.0, payload + res_bytes, payload,
                        defaultdict(float, {base: payload}), 1.0)

        if op == "dot":
            contract = 1
            cd = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", rest)
            if cd and inst.operands:
                lhs_dims = _dims_of(self.types.get(inst.operands[0], ""))
                for i in (int(x) for x in cd.group(1).split(",") if x):
                    if i < len(lhs_dims):
                        contract *= lhs_dims[i]
            return Cost(2.0 * res_elems * contract,
                        res_bytes + self._operand_bytes(inst))

        if op == "convolution":
            k_elems = (_total_elems(self.types.get(inst.operands[1], ""))
                       if len(inst.operands) > 1 else 1.0)
            out_ch = max(1.0, _dims_of(inst.type_str)[-1]
                         if _dims_of(inst.type_str) else 1.0)
            return Cost(2.0 * res_elems * max(1.0, k_elems / out_ch),
                        res_bytes + self._operand_bytes(inst))

        if op == "reduce":
            return Cost(sum(_total_elems(self.types.get(o, ""))
                            for o in inst.operands[: len(inst.operands) // 2]),
                        res_bytes + self._operand_bytes(inst))

        if op == "dynamic-slice":
            return Cost(0.0, 2.0 * res_bytes)
        if op == "dynamic-update-slice":
            upd = (_total_bytes(self.types.get(inst.operands[1], ""))
                   if len(inst.operands) > 1 else res_bytes)
            return Cost(0.0, 2.0 * upd)
        if op == "gather":
            return Cost(0.0, 2.0 * res_bytes)
        if op == "scatter":
            upd = (_total_bytes(self.types.get(inst.operands[-1], ""))
                   if inst.operands else res_bytes)
            return Cost(res_elems, 2.0 * upd)
        if op in ("rng", "rng-bit-generator"):
            return Cost(res_elems, res_bytes)
        if op == "custom-call":
            # cholesky/topk/etc: count boundary bytes, no flops estimate
            return Cost(0.0, res_bytes + self._operand_bytes(inst))
        if op in ("reduce-window", "select-and-scatter"):
            return Cost(res_elems * 8.0, res_bytes + self._operand_bytes(inst))

        if op in _ELEMENTWISE:
            return Cost(res_elems, res_bytes + self._operand_bytes(inst))
        if op in _MOVEMENT:
            return Cost(0.0, res_bytes + self._operand_bytes(inst))
        if op in _FREE:
            return Cost()
        return Cost(0.0, res_bytes + self._operand_bytes(inst))


def analyze(hlo_text: str) -> dict:
    mod = HloModule(hlo_text)
    c = mod.entry_cost()
    return {
        "flops": c.flops,
        "hbm_bytes": c.hbm_bytes,
        "collective_bytes": c.coll_bytes,
        "collective_ops": c.coll_ops,
        "collectives_by_kind": dict(c.coll_by_kind),
    }
