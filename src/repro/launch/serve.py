"""Batched serving driver: prefill a batch of prompts, decode greedily.

    PYTHONPATH=src python -m repro.launch.serve \
        --arch smollm-135m --preset tiny --batch 4 --prompt-len 32 --steps 16
"""
from __future__ import annotations

import argparse
import dataclasses
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--preset", default="tiny", choices=["tiny", "full"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--temp", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.models import build_model
    from repro.serving import generate

    cfg = get_config(args.arch)
    if args.preset == "tiny":
        cfg = dataclasses.replace(
            cfg, n_layers=cfg.layer_period * 2, d_model=128, n_heads=4,
            n_kv_heads=2 if cfg.n_kv_heads < cfg.n_heads else 4, head_dim=32,
            d_ff=256 if cfg.d_ff else 0, vocab=2048,
            **({"n_experts": 4, "top_k": 2, "moe_d_ff": 64}
               if cfg.n_experts else {}),
            **({"n_enc_layers": 2, "enc_seq": 64} if cfg.enc_dec else {}),
            **({"mrope_sections": (4, 6, 6)} if cfg.mrope else {}),
            **({"kv_lora_rank": 64, "q_lora_rank": 96, "qk_rope_dim": 16,
                "qk_nope_dim": 32, "v_head_dim": 32} if cfg.mla else {}))

    model = build_model(cfg, q_chunk=min(512, args.prompt_len),
                        kv_chunk=min(512, args.prompt_len))
    params = model.init(jax.random.PRNGKey(args.seed))

    key = jax.random.PRNGKey(args.seed + 1)
    batch = {"tokens": jax.random.randint(
        key, (args.batch, args.prompt_len), 0, cfg.vocab)}
    if cfg.enc_dec:
        batch["frames"] = jnp.zeros((args.batch, cfg.enc_seq, cfg.d_model))

    t0 = time.time()
    out = generate(model, params, batch,
                   steps=args.steps,
                   cache_len=args.prompt_len + args.steps,
                   temp=args.temp, seed=args.seed)
    dt = time.time() - t0
    print(f"generated {out.shape} tokens in {dt:.2f}s "
          f"({args.batch * args.steps / dt:.1f} tok/s)")
    print("first sequences:", out[:2].tolist())


if __name__ == "__main__":
    main()
