"""Serving drivers.

LM mode (default) — prefill a batch of prompts, decode greedily:

    PYTHONPATH=src python -m repro.launch.serve \
        --arch smollm-135m --preset tiny --batch 4 --prompt-len 32 --steps 16

SVM mode — fit demo tenants, export ServableModels, page them through
a shared score cell, and drive the threaded continuous-batching loop:

    PYTHONPATH=src python -m repro.launch.serve --mode svm \
        --tenants 6 --requests 200 --family nystrom
"""
from __future__ import annotations

import argparse
import dataclasses
import time


def main_svm(args) -> None:
    import numpy as np

    from repro.core import PEMSVM, SVMConfig
    from repro.core.nystrom import NystromSVM
    from repro.serving import ServeLoop, WeightPager

    rng = np.random.default_rng(args.seed)
    n, d = 4_000, 32
    X = rng.normal(size=(n, d)).astype(np.float32)

    pager = WeightPager(max_resident=args.resident)
    oracles = {}
    for t in range(args.tenants):
        w = rng.normal(size=d)
        y = np.where(X @ w > 0, 1.0, -1.0).astype(np.float32)
        if args.family == "nystrom":
            model = NystromSVM(
                SVMConfig(formulation="KRN", sigma=3.0, lam=0.1,
                          max_iters=15, min_iters=5), n_landmarks=48)
        else:
            model = PEMSVM(SVMConfig(max_iters=15, min_iters=5))
        model.fit(X, y)
        name = f"tenant{t}"
        pager.register(model.export_servable(name=name))
        oracles[name] = model.decision_function(X[:256])

    loop = ServeLoop(pager).start()
    t0 = time.time()
    futs = []
    for i in range(args.requests):
        nr = int(rng.integers(1, 97))
        j = int(rng.integers(0, n - nr + 1))
        futs.append((f"tenant{i % args.tenants}",
                     loop.submit(f"tenant{i % args.tenants}", X[j:j + nr])))
    rows = sum(f.result(timeout=60).shape[0] for _, f in futs)
    dt = time.time() - t0
    loop.stop()

    q = loop.latency_quantiles()
    ok = all(
        np.array_equal(pager.scorer(name).score(X[:256])[:, 0], oracle)
        for name, oracle in oracles.items())
    print(f"served {loop.n_requests} requests / {rows} rows in {dt:.2f}s "
          f"({rows / dt:.0f} rows/s) over {loop.n_batches} batches")
    print(f"latency p50={q['p50_ms']:.2f}ms p99={q['p99_ms']:.2f}ms  "
          f"pager hits={pager.hits} misses={pager.misses} "
          f"evictions={pager.evictions} "
          f"resident={pager.resident_bytes}B")
    print(f"bitwise parity vs decision_function across all tenants: {ok}")
    if not ok:
        raise SystemExit(1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="lm", choices=["lm", "svm"])
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--preset", default="tiny", choices=["tiny", "full"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--temp", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--tenants", type=int, default=4)
    ap.add_argument("--resident", type=int, default=4)
    ap.add_argument("--requests", type=int, default=100)
    ap.add_argument("--family", default="linear",
                    choices=["linear", "nystrom"])
    args = ap.parse_args()

    if args.mode == "svm":
        main_svm(args)
        return

    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.models import build_model
    from repro.serving import generate

    cfg = get_config(args.arch)
    if args.preset == "tiny":
        cfg = dataclasses.replace(
            cfg, n_layers=cfg.layer_period * 2, d_model=128, n_heads=4,
            n_kv_heads=2 if cfg.n_kv_heads < cfg.n_heads else 4, head_dim=32,
            d_ff=256 if cfg.d_ff else 0, vocab=2048,
            **({"n_experts": 4, "top_k": 2, "moe_d_ff": 64}
               if cfg.n_experts else {}),
            **({"n_enc_layers": 2, "enc_seq": 64} if cfg.enc_dec else {}),
            **({"mrope_sections": (4, 6, 6)} if cfg.mrope else {}),
            **({"kv_lora_rank": 64, "q_lora_rank": 96, "qk_rope_dim": 16,
                "qk_nope_dim": 32, "v_head_dim": 32} if cfg.mla else {}))

    model = build_model(cfg, q_chunk=min(512, args.prompt_len),
                        kv_chunk=min(512, args.prompt_len))
    params = model.init(jax.random.PRNGKey(args.seed))

    key = jax.random.PRNGKey(args.seed + 1)
    batch = {"tokens": jax.random.randint(
        key, (args.batch, args.prompt_len), 0, cfg.vocab)}
    if cfg.enc_dec:
        batch["frames"] = jnp.zeros((args.batch, cfg.enc_seq, cfg.d_model))

    t0 = time.time()
    out = generate(model, params, batch,
                   steps=args.steps,
                   cache_len=args.prompt_len + args.steps,
                   temp=args.temp, seed=args.seed)
    dt = time.time() - t0
    print(f"generated {out.shape} tokens in {dt:.2f}s "
          f"({args.batch * args.steps / dt:.1f} tok/s)")
    print("first sequences:", out[:2].tolist())


if __name__ == "__main__":
    main()
