"""Dry-run cell builder for the paper's own workload: one PEMSVM
iteration (the Fig.-1 map-reduce) at paper scale on the production mesh.

These cells are *additional* to the 40 assigned (arch x shape) cells —
they are the "most representative of the paper's technique" hillclimb
target in EXPERIMENTS.md §Perf. Shapes follow paper Table 3:

  svm_dna      N=25.6M  K=800   CLS   (dna: 25M x 800)
  svm_alpha    N=262144 K=500   CLS   (alpha: 250k x 500)
  svm_mnist8m  N=4.19M  K=784   MLT10 (mnist8m: 4M x 798 [784+pad])
  svm_year     N=262144 K=96    SVR   (year: 250k x 90 [+pad])

Options (--opt): mode=EM|MC, triangle=0|1, reduce_dtype=bfloat16,
k_shard=1 (2-D Sigma statistic over the model axis), dtype=bfloat16
(input compression), backend (kernels backend for the statistics).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import distributed, linear, multiclass, svr
from repro.core.linear import SVMData

SVM_SHAPES = {
    "svm_dna": dict(N=25_600_000, K=800, task="CLS"),
    "svm_alpha": dict(N=262_144, K=500, task="CLS"),
    "svm_mnist8m": dict(N=4_194_304, K=784, task="MLT", M=10),
    "svm_year": dict(N=262_144, K=96, task="SVR"),
}


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def build_svm_cell(arch: str, shape_name: str, mesh, opts: dict):
    spec = SVM_SHAPES[shape_name]
    N, K, task = spec["N"], spec["K"], spec["task"]
    M = spec.get("M", 2)
    mode = opts.get("mode", "MC" if task == "MLT" else "EM")  # paper's picks
    dtype = opts.get("dtype", "float32")
    k_shard = bool(int(opts.get("k_shard", 0)))

    if k_shard:
        data_axes = tuple(a for a in mesh.axis_names if a != "model")
        k_shard_axis = "model"
        # The 2-D statistic splits Sigma columns over 'model'; the
        # windowed kernels need the statistic width divisible
        # (pad_features_to is the user-facing fix — _k_block errors).
        assert K % mesh.shape["model"] == 0, (
            f"K={K} not divisible by model axis {mesh.shape['model']}; "
            "pad with data.pipeline.pad_features_to")
    else:
        data_axes = tuple(mesh.axis_names)
        k_shard_axis = None
    shards = distributed.num_shards(mesh, data_axes)
    assert N % shards == 0, (N, shards)

    common = dict(mode=mode, lam=float(opts.get("lam", 1.0)), eps=1e-6,
                  jitter=1e-7, axes=data_axes,
                  triangle=bool(int(opts.get("triangle", 1))),
                  backend=None,
                  reduce_dtype=opts.get("reduce_dtype"))

    if task == "CLS":
        def step(data, state, key):
            return linear.cls_step(data, state, key,
                                   k_shard_axis=k_shard_axis, **common)
        state_struct = sds((K,), jnp.float32)
        state_spec = P(None)
        tdtype = jnp.float32
    elif task == "SVR":
        def step(data, state, key):
            return svr.svr_step(data, state, key, eps_ins=1e-3,
                                k_shard_axis=k_shard_axis, **common)
        state_struct = sds((K,), jnp.float32)
        state_spec = P(None)
        tdtype = jnp.float32
    else:
        def step(data, state, key):
            return multiclass.mlt_step(data, state, key, num_classes=M,
                                       k_shard_axis=k_shard_axis,
                                       **common)
        state_struct = sds((M, K), jnp.float32)
        state_spec = P(None, None)
        tdtype = jnp.int32

    jitted = distributed.shard_wrap(mesh, data_axes, step,
                                    state_spec=state_spec)

    row = P(data_axes)
    data_structs = SVMData(X=sds((N, K), dtype), target=sds((N,), tdtype),
                           mask=sds((N,), jnp.float32))
    data_sh = SVMData(X=NamedSharding(mesh, P(data_axes, None)),
                      target=NamedSharding(mesh, row),
                      mask=NamedSharding(mesh, row))
    key_struct = sds((2,), jnp.uint32)
    return (jitted, (data_structs, state_struct, key_struct),
            (data_sh, NamedSharding(mesh, state_spec),
             NamedSharding(mesh, P(None))))
