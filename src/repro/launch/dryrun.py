import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape)
cell on the production mesh and extract the roofline terms.

The two lines above run before ANY other import — jax locks the device
count at first init, and the dry-run (and only the dry-run) needs 512
placeholder host devices to build the 16x16 / 2x16x16 meshes.

Usage:
  python -m repro.launch.dryrun --arch yi-34b --shape train_4k \
      [--multi-pod] [--out runs/dryrun] [--opt k=v ...]

Emits one JSON per cell with cost/memory analysis + per-collective bytes
parsed from the optimized HLO. benchmarks/roofline.py turns these into
the EXPERIMENTS.md tables.
"""
import argparse  # noqa: E402
import json  # noqa: E402
import re  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro import compat  # noqa: E402
from repro.configs import SHAPES, applicable, get_config  # noqa: E402
from repro.launch import hlo_cost  # noqa: E402
from repro.launch import specs as sp  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import build_model  # noqa: E402
from repro.serving import make_decode_step, make_prefill_step  # noqa: E402
from repro.training import AdamWConfig, make_train_step  # noqa: E402

# TPU v5e-class hardware constants (per chip) for §Roofline
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s/link

_COLL_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute")
_LAST_CACHE_INFO = None
_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
                "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
                "f64": 8, "c64": 8, "c128": 16}
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def parse_collectives(hlo_text: str) -> dict:
    """Sum operand bytes of every collective op in the (per-partition)
    optimized HLO. Returns {op_kind: bytes, 'total': bytes}."""
    out = {k: 0 for k in _COLL_OPS}
    n_ops = 0
    for line in hlo_text.splitlines():
        ls = line.strip()
        m = re.match(r"%?[\w.\-]+ = .*? (" + "|".join(_COLL_OPS) +
                     r")(?:-start|-done)?\(", ls)
        if not m:
            continue
        kind = m.group(1)
        if "-done(" in ls:       # async pair: count the -start only
            continue
        n_ops += 1
        # operand types appear inside the call parens
        args = ls.split("(", 1)[1]
        b = sum(_shape_bytes(dt, dims)
                for dt, dims in _SHAPE_RE.findall(args.split("),")[0] + ")")
                if dt in _DTYPE_BYTES)
        out[kind] += b
    out["total"] = sum(out[k] for k in _COLL_OPS)
    out["n_ops"] = n_ops
    return out


def build_cell(arch: str, shape_name: str, multi_pod: bool, opts: dict):
    """Returns (mesh, fn, example_args, in_shardings, out_shardings,
    donate)."""
    if arch.startswith("pemsvm"):
        from repro.launch.svm_cell import build_svm_cell
        mesh = make_production_mesh(multi_pod=multi_pod)
        jitted, args, in_sh = build_svm_cell(arch, shape_name, mesh, opts)
        return mesh, jitted, args, in_sh, None, ()
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    ctx = sp.make_ctx(mesh, shape)
    model = build_model(
        cfg, ctx,
        q_chunk=int(opts.get("q_chunk", 1024)),
        kv_chunk=int(opts.get("kv_chunk", 1024)),
        ssm_chunk=int(opts.get("ssm_chunk", 256)),
        skip_masked_blocks=bool(int(opts.get("skip_masked_blocks", 0))),
        remat_policy=opts.get("remat_policy", "nothing"),
        seq_parallel_attn=bool(int(opts.get("seq_attn", 0))))

    if shape.kind == "train":
        pstructs, pspecs = sp.param_struct_specs(cfg, ctx)
        ostructs, ospecs = sp.opt_state_specs(pstructs, pspecs)
        bstructs, bspecs = sp.batch_specs(cfg, shape, ctx, with_labels=True)
        state_structs = {"params": pstructs, "opt": ostructs}
        state_specs = {"params": pspecs, "opt": ospecs}
        step = make_train_step(
            model, AdamWConfig(),
            remat=bool(int(opts.get("remat", 1))),
            loss_chunk=int(opts.get("loss_chunk", 512)),
            microbatches=int(opts.get("microbatches", 1)))
        return (mesh, step, (state_structs, bstructs),
                (state_specs, bspecs), (state_specs, P()), ())

    # Serving param layout levers (§Perf): FSDP is a training pattern —
    # without optimizer state, weights can replicate over 'data'
    # (serve_fsdp=0) and even over 'model' (serve_tp=0, small models).
    import dataclasses as _dc
    pctx = ctx
    if not int(opts.get("serve_fsdp", 1)):
        pctx = _dc.replace(pctx, fsdp_axis=None)
    if not int(opts.get("serve_tp", 1)):
        pctx = _dc.replace(pctx, tp_axis=None)
    pstructs, pspecs = sp.param_struct_specs(cfg, pctx, dtype=cfg.dtype)
    if shape.kind == "prefill":
        bstructs, bspecs = sp.batch_specs(cfg, shape, ctx, with_labels=False)
        cstructs, cspecs = sp.cache_specs(cfg, shape, ctx)
        del cstructs
        step = make_prefill_step(model, cache_len=shape.seq_len)
        tok_spec = ctx.spec((shape.global_batch,), ctx.dp_axes)
        return (mesh, step, (pstructs, bstructs), (pspecs, bspecs),
                (tok_spec, cspecs), ())

    # decode: one new token against a seq_len cache
    B = shape.global_batch
    cstructs, cspecs = sp.cache_specs(cfg, shape, ctx)
    global _LAST_CACHE_INFO
    _LAST_CACHE_INFO = (cstructs, cspecs, ctx)
    tok_struct = sp.sds((B, 1), jnp.int32)
    pos_struct = sp.sds((), jnp.int32)
    tok_spec = ctx.spec((B, 1), ctx.dp_axes, None)
    step = make_decode_step(model)
    lg_spec = ctx.spec((B, cfg.vocab), ctx.dp_axes,
                       ctx.tp_axis if cfg.vocab % ctx.axis_size(
                           ctx.tp_axis) == 0 else None)
    return (mesh, step, (pstructs, tok_struct, pos_struct, cstructs),
            (pspecs, tok_spec, P(), cspecs),
            (ctx.spec((B,), ctx.dp_axes), lg_spec, cspecs),
            (3,))  # donate the cache


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             opts: dict | None = None, *, keep_hlo: bool = False) -> dict:
    opts = opts or {}
    is_svm = arch.startswith("pemsvm")
    mesh_name = "2x16x16" if multi_pod else "16x16"
    chips = 512 if multi_pod else 256
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "chips": chips, "opts": opts, "ok": False}

    if not is_svm:
        cfg = get_config(arch)
        shape = SHAPES[shape_name]
        runs, reason = applicable(cfg, shape)
        if not runs:
            rec.update(skipped=True, reason=reason, ok=True)
            return rec

    t0 = time.time()
    try:
        mesh, fn, args, in_sh, out_sh, donate = build_cell(
            arch, shape_name, multi_pod, opts)
        with compat.set_mesh(mesh):
            if is_svm:     # svm cells arrive pre-wrapped by shard_map
                jitted = fn
            else:
                jitted = jax.jit(fn, in_shardings=in_sh,
                                 out_shardings=out_sh,
                                 donate_argnums=donate)
            lowered = jitted.lower(*args)
            rec["lower_s"] = round(time.time() - t0, 1)
            t1 = time.time()
            compiled = lowered.compile()
            rec["compile_s"] = round(time.time() - t1, 1)

        hlo = compiled.as_text()
        cost = hlo_cost.analyze(hlo)
        rec["flops_per_device"] = cost["flops"]
        rec["bytes_per_device"] = cost["hbm_bytes"]
        # XLA's own (loop-bodies-once) numbers, for reference
        ca = compiled.cost_analysis() or {}
        rec["xla_flops_once"] = float(ca.get("flops", 0.0))
        ma = compiled.memory_analysis()
        rec["memory"] = {
            "argument_bytes": int(getattr(ma, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(ma, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(ma, "temp_size_in_bytes", 0)),
            "generated_code_bytes": int(
                getattr(ma, "generated_code_size_in_bytes", 0)),
        }
        per_dev_total = (rec["memory"]["argument_bytes"]
                         + rec["memory"]["output_bytes"]
                         + rec["memory"]["temp_bytes"])
        rec["memory"]["total_bytes"] = per_dev_total
        # Buffer donation is NOT implemented on the CPU backend, so the
        # donated KV/state cache of decode cells is double-counted here
        # (once as a non-aliased output, once as the DUS copy in temp).
        # On the TPU target the cache updates in place; subtract both
        # phantom copies for the fits-HBM verdict and record the
        # adjustment explicitly.
        if _LAST_CACHE_INFO is not None and donate:
            cstructs_, cspecs_, ctx_ = _LAST_CACHE_INFO
            cache_bytes = 0
            for leaf, spec_ in zip(jax.tree.leaves(cstructs_),
                                   jax.tree.leaves(
                                       cspecs_, is_leaf=lambda x: hasattr(
                                           x, 'spec') or x is None)):
                n_shards = 1
                spec_obj = getattr(spec_, 'spec', spec_)
                if spec_obj is not None:
                    for entry in spec_obj:
                        if entry is None:
                            continue
                        axes_ = entry if isinstance(entry, tuple) else (entry,)
                        for a in axes_:
                            n_shards *= mesh.shape[a]
                cache_bytes += (leaf.size * leaf.dtype.itemsize) // n_shards
            rec["memory"]["donated_cache_bytes_per_device"] = cache_bytes
            # Three phantom copies on CPU: (a) non-aliased output buffer,
            # (b) the scan's loop-state double buffer, (c) the DUS copy —
            # all alias in place on TPU for donated buffers threaded
            # through the layer scan. One live cache stays (in args).
            adj = per_dev_total - 3 * cache_bytes
            rec["memory"]["total_bytes_tpu_donated"] = adj
            rec["memory"]["fits_16gb_hbm"] = bool(adj < 16e9)
        else:
            rec["memory"]["fits_16gb_hbm"] = bool(per_dev_total < 16e9)

        rec["collectives_per_device"] = {
            "total": cost["collective_bytes"],
            "n_ops": cost["collective_ops"],
            **cost["collectives_by_kind"]}
        if keep_hlo:
            rec["hlo_path"] = f"/tmp/hlo_{arch}_{shape_name}_{mesh_name}.txt"
            with open(rec["hlo_path"], "w") as f:
                f.write(hlo)

        # roofline terms (global FLOPs = per-device x chips)
        coll = rec["collectives_per_device"]["total"]
        rec["terms"] = {
            "compute_s": rec["flops_per_device"] / PEAK_FLOPS,
            "memory_s": rec["bytes_per_device"] / HBM_BW,
            "collective_s": coll / ICI_BW,
        }
        rec["terms"]["dominant"] = max(rec["terms"],
                                       key=lambda k: rec["terms"][k])
        # model flops: 6ND for LM cells; N*K^2 + 3NK (+K^3/3 solve) per
        # SVM iteration (paper Sec 4.3: the Sigma^p statistic dominates)
        if is_svm:
            from repro.launch.svm_cell import SVM_SHAPES
            sp_ = SVM_SHAPES[shape_name]
            m_cls = sp_.get("M", 1) if sp_["task"] == "MLT" else 1
            nd = m_cls * (2 * sp_["N"] * sp_["K"] ** 2
                          + 6 * sp_["N"] * sp_["K"] + sp_["K"] ** 3 / 3)
        else:
            tokens = shape.global_batch * (
                shape.seq_len if shape.kind != "decode" else 1)
            nd = 6 * cfg.active_params() * tokens
            if shape.kind in ("prefill", "decode"):
                nd = nd / 3  # 2ND for inference
        rec["model_flops"] = float(nd)
        global_flops = rec["flops_per_device"] * chips
        rec["useful_flops_ratio"] = (rec["model_flops"] / global_flops
                                     if global_flops else 0.0)
        rec["ok"] = True
    except Exception as e:  # noqa: BLE001 — record and continue the sweep
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    rec["total_s"] = round(time.time() - t0, 1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    from repro.launch.svm_cell import SVM_SHAPES
    ap.add_argument("--shape", required=True,
                    choices=sorted(SHAPES) + sorted(SVM_SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="runs/dryrun")
    ap.add_argument("--keep-hlo", action="store_true")
    ap.add_argument("--opt", action="append", default=[],
                    help="k=v model/step options (q_chunk, remat, ...)")
    args = ap.parse_args()
    opts = dict(kv.split("=", 1) for kv in args.opt)

    rec = run_cell(args.arch, args.shape, args.multi_pod, opts,
                   keep_hlo=args.keep_hlo)
    os.makedirs(args.out, exist_ok=True)
    tag = "multi" if args.multi_pod else "single"
    suffix = ("_" + "_".join(f"{k}-{v}" for k, v in sorted(opts.items()))
              if opts else "")
    path = os.path.join(args.out,
                        f"{args.arch}_{args.shape}_{tag}{suffix}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=2)
    print(json.dumps({k: v for k, v in rec.items()
                      if k not in ("traceback",)}, indent=2))
    if not rec["ok"]:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
