"""Run the full dry-run sweep: every (arch x shape) cell on both meshes,
plus the paper's PEMSVM cells. Each cell runs in a fresh subprocess (the
host-device-count XLA flag locks at first jax init) and is cached by its
output JSON, so the sweep is resumable.

    PYTHONPATH=src python -m repro.launch.sweep [--out runs/dryrun]
        [--force] [--only yi-34b,...] [--single-pod-only]

Baseline option policy (recorded in each JSON):
  * train cells of >10B-param archs: microbatches=4 (activation memory;
    see EXPERIMENTS.md §Dry-run) — part of the baseline config, chosen
    before any hillclimbing.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

from repro.configs import SHAPES, get_config, list_archs
from repro.launch.svm_cell import SVM_SHAPES


def baseline_opts(arch: str, shape_name: str) -> list[str]:
    if arch.startswith("pemsvm"):
        return []
    opts = []
    if SHAPES[shape_name].kind == "train":
        # 1M tokens global batch: gradient accumulation is part of the
        # baseline config (activation memory; DESIGN.md §4). The two
        # biggest-activation archs accumulate 8 microbatches.
        mb = 8 if arch in ("jamba-v0.1-52b", "deepseek-v2-236b") else 4
        opts.append(f"microbatches={mb}")
    return opts


def cell_path(out: str, arch: str, shape: str, multi: bool,
              opts: list[str]) -> str:
    tag = "multi" if multi else "single"
    suffix = ("_" + "_".join(o.replace("=", "-") for o in sorted(opts))
              if opts else "")
    return os.path.join(out, f"{arch}_{shape}_{tag}{suffix}.json")


def run_one(arch: str, shape: str, multi: bool, out: str,
            opts: list[str], timeout: int = 1800) -> dict:
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape, "--out", out]
    if multi:
        cmd.append("--multi-pod")
    for o in opts:
        cmd += ["--opt", o]
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.abspath("src"), env.get("PYTHONPATH", "")])
    t0 = time.time()
    p = subprocess.run(cmd, capture_output=True, text=True, env=env,
                       timeout=timeout)
    path = cell_path(out, arch, shape, multi, opts)
    if os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    return {"arch": arch, "shape": shape, "ok": False,
            "error": (p.stderr or p.stdout)[-1500:],
            "total_s": round(time.time() - t0, 1)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="runs/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--only", default="")
    ap.add_argument("--single-pod-only", action="store_true")
    ap.add_argument("--skip-svm", action="store_true")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    cells: list[tuple[str, str]] = []
    for arch in list_archs():
        for shape in SHAPES:
            cells.append((arch, shape))
    if not args.skip_svm:
        for shape in SVM_SHAPES:
            cells.append(("pemsvm", shape))
    if args.only:
        keep = set(args.only.split(","))
        cells = [(a, s) for a, s in cells if a in keep or s in keep]

    meshes = [False] if args.single_pod_only else [False, True]
    total = ok = skipped = failed = 0
    t_start = time.time()
    for arch, shape in cells:
        for multi in meshes:
            opts = baseline_opts(arch, shape)
            path = cell_path(args.out, arch, shape, multi, opts)
            total += 1
            if os.path.exists(path) and not args.force:
                with open(path) as f:
                    rec = json.load(f)
            else:
                rec = run_one(arch, shape, multi, args.out, opts)
            tag = "multi" if multi else "single"
            if rec.get("skipped"):
                skipped += 1
                print(f"[{total:3d}] SKIP {arch} {shape} {tag}: "
                      f"{rec['reason'][:60]}", flush=True)
            elif rec.get("ok"):
                ok += 1
                fits = rec["memory"]["fits_16gb_hbm"]
                print(f"[{total:3d}] OK   {arch} {shape} {tag} "
                      f"compile={rec.get('compile_s', '?')}s "
                      f"dominant={rec['terms']['dominant']} "
                      f"fits={'Y' if fits else 'N'} "
                      f"ratio={rec['useful_flops_ratio']:.3f}", flush=True)
            else:
                failed += 1
                print(f"[{total:3d}] FAIL {arch} {shape} {tag}: "
                      f"{rec.get('error', '')[:120]}", flush=True)
    print(f"\nsweep: {ok} ok, {skipped} skipped, {failed} failed "
          f"of {total} in {(time.time() - t_start) / 60:.1f} min")
    sys.exit(1 if failed else 0)


if __name__ == "__main__":
    main()
