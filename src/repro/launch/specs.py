"""ShapeDtypeStruct input stand-ins + sharding specs for every
(architecture x input-shape) dry-run cell.

``input_specs`` follows the assignment contract: weak-type-correct,
shardable, no device allocation. Modality frontends are stubs — the VLM
cell feeds precomputed patch embeddings (+ 3-stream M-RoPE position ids),
the audio cell feeds precomputed conv-frontend frame embeddings."""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import ModelConfig, ShapeConfig
from repro.models import build_model
from repro.sharding import ShardingCtx, param_specs


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


# ------------------------------------------------------------------ batches
def batch_specs(cfg: ModelConfig, shape: ShapeConfig, ctx: ShardingCtx,
                *, with_labels: bool):
    """(spec_tree, sharding_tree) for the host batch of one step."""
    B, S = shape.global_batch, shape.seq_len
    dp = ctx.dp_axes
    specs: dict[str, Any] = {}
    shards: dict[str, Any] = {}
    if cfg.family == "vlm":
        specs["embeds"] = sds((B, S, cfg.d_model), cfg.dtype)
        shards["embeds"] = ctx.spec((B, S, cfg.d_model), dp, None, None)
        specs["positions"] = sds((3, B, S), jnp.int32)
        shards["positions"] = ctx.spec((3, B, S), None, dp, None)
    else:
        specs["tokens"] = sds((B, S), jnp.int32)
        shards["tokens"] = ctx.spec((B, S), dp, None)
    if cfg.enc_dec:
        specs["frames"] = sds((B, cfg.enc_seq, cfg.d_model), cfg.dtype)
        shards["frames"] = ctx.spec((B, cfg.enc_seq, cfg.d_model),
                                    dp, None, None)
    if with_labels:
        specs["labels"] = sds((B, S), jnp.int32)
        shards["labels"] = ctx.spec((B, S), dp, None)
    return specs, shards


# ------------------------------------------------------------------- caches
def cache_specs(cfg: ModelConfig, shape: ShapeConfig, ctx: ShardingCtx):
    """(spec_tree, sharding_tree) for the KV/state caches of decode cells.

    long-context (batch too small to shard): the cache *sequence* dim goes
    over 'data' (context parallelism); recurrent state dims go over
    'model' where divisible."""
    B, S = shape.global_batch, shape.seq_len
    model = build_model(cfg)
    specs = jax.eval_shape(
        lambda: model.init_cache(B, S, jnp.dtype(cfg.dtype)))
    dp, tp = ctx.dp_axes, ctx.tp_axis
    long_ctx = B % ctx.axis_size(dp) != 0   # e.g. long_500k: B=1

    def leaf_spec(x):
        # Layout (EXPERIMENTS.md §Dry-run): batch over DP, cache SEQUENCE
        # over 'model' (context-parallel decode; matches the shard_map
        # decode island in models/attention.py). Inner-dim (head_dim)
        # sharding is deliberately avoided — GSPMD answers it with a
        # full-cache regather around the dynamic update. The leading
        # stacked-periods dim is NEVER sharded: the layer scan slices it
        # every iteration and a sharded slice dim becomes a per-layer
        # gather. long_500k (batch 1) spreads S over (data x model).
        sh = x.shape
        wanted = []
        used_dp = used_tp = False
        for i, d in enumerate(sh):
            if i == 0:                      # stacked periods
                wanted.append(None)
            elif d == S and long_ctx:
                both = (ctx.fsdp_axis, tp)
                if d % ctx.axis_size(both) == 0:
                    wanted.append(both)
                else:
                    wanted.append(tp)
                used_tp = True
            elif d == S and not used_tp and d % ctx.axis_size(tp) == 0:
                wanted.append(tp)
                used_tp = True
            elif not used_dp and d == B and d % ctx.axis_size(dp) == 0:
                wanted.append(dp)
                used_dp = True
            elif (not used_tp and d != S and d >= 64
                    and d % ctx.axis_size(tp) == 0):
                # recurrent-state leaves (no seq dim): inner dim over tp
                wanted.append(tp)
                used_tp = True
            else:
                wanted.append(None)
        return ctx.spec(sh, *wanted)

    shards = jax.tree.map(leaf_spec, specs)
    return specs, shards


# ------------------------------------------------------------------- params
def param_struct_specs(cfg: ModelConfig, ctx: ShardingCtx, *,
                       dtype=None):
    """(param ShapeDtypeStruct tree, sharding spec tree). ``dtype``
    overrides storage dtype (serve cells hold bf16 params)."""
    model = build_model(cfg)
    shapes = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    if dtype is not None:
        shapes = jax.tree.map(
            lambda x: sds(x.shape, dtype) if jnp.issubdtype(
                x.dtype, jnp.floating) else x, shapes)
    return shapes, param_specs(ctx, shapes)


def opt_state_specs(pstructs, pspecs):
    """Optimizer state mirrors parameters (ZeRO sharding)."""
    return ({"m": pstructs, "v": pstructs, "step": sds((), jnp.int32)},
            {"m": pspecs, "v": pspecs, "step": P()})


def make_ctx(mesh, shape: ShapeConfig | None = None) -> ShardingCtx:
    multi = "pod" in mesh.axis_names
    dp = ("pod", "data") if multi else ("data",)
    return ShardingCtx(mesh=mesh, dp_axes=dp, tp_axis="model",
                       fsdp_axis="data")
