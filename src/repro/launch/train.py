"""End-to-end LM training driver.

Runs any registered architecture (``--arch``) at any scale preset
(``--preset tiny|small|full``) on synthetic token streams, with the full
production substrate engaged: sharded data pipeline, AdamW + chunked
xent + remat + optional microbatching, async fault-tolerant
checkpointing (restore-on-start), straggler monitoring, and optional
host-device meshes for CPU bring-up.

    PYTHONPATH=src python -m repro.launch.train \
        --arch smollm-135m --preset tiny --steps 200

On real TPU pods the same driver runs with the production mesh
(``--mesh production`` / ``--multi-pod``); nothing in the loop is
host-count-specific (the data pipeline feeds per-host shards).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--preset", default="tiny",
                    choices=["tiny", "small", "full"])
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--mesh", default="none",
                    help="'none' | 'RxC' host mesh | 'production'")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--host-devices", type=int, default=0,
                    help="force N host devices (set BEFORE jax import)")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.host_devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.host_devices}")

    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.checkpoint import Checkpointer
    from repro.configs import get_config
    from repro.data import ShardedBatcher, make_lm_tokens
    from repro.launch.mesh import make_host_mesh, make_production_mesh
    from repro.launch import specs as sp
    from repro.models import build_model
    from repro.runtime import StepTimeMonitor
    from repro.sharding import ShardingCtx, param_specs
    from repro.training import (AdamWConfig, init_state, make_train_step)

    cfg = get_config(args.arch)
    if args.preset == "tiny":
        cfg = dataclasses.replace(
            cfg, n_layers=cfg.layer_period * 2, d_model=128, n_heads=4,
            n_kv_heads=2 if cfg.n_kv_heads < cfg.n_heads else 4, head_dim=32,
            d_ff=256 if cfg.d_ff else 0, vocab=2048,
            **({"n_experts": 4, "top_k": 2, "moe_d_ff": 64}
               if cfg.n_experts else {}),
            **({"n_enc_layers": 2, "enc_seq": 64} if cfg.enc_dec else {}),
            **({"mrope_sections": (4, 6, 6)} if cfg.mrope else {}),
            **({"kv_lora_rank": 64, "q_lora_rank": 96, "qk_rope_dim": 16,
                "qk_nope_dim": 32, "v_head_dim": 32} if cfg.mla else {}))
    elif args.preset == "small":
        cfg = dataclasses.replace(cfg, n_layers=cfg.layer_period * 2)

    # --- mesh / ctx
    mesh = None
    if args.mesh == "production":
        mesh = make_production_mesh(multi_pod=args.multi_pod)
    elif args.mesh != "none":
        r, c = (int(x) for x in args.mesh.split("x"))
        mesh = make_host_mesh((r, c))
    ctx = (sp.make_ctx(mesh) if mesh is not None else ShardingCtx())

    model = build_model(cfg, ctx, q_chunk=min(1024, args.seq),
                        kv_chunk=min(1024, args.seq))
    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=max(10, args.steps // 20),
                          total_steps=args.steps)
    step_fn = make_train_step(model, opt_cfg, loss_chunk=min(512, args.seq),
                              microbatches=args.microbatches)

    # --- init (sharded when on-mesh)
    key = jax.random.PRNGKey(args.seed)
    if mesh is not None:
        pspecs = param_specs(ctx, jax.eval_shape(model.init, key))
        shardings = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs)
        params = jax.jit(model.init, out_shardings=shardings)(key)
        state = {"params": params, "opt": init_state(params)}
        step_fn = jax.jit(step_fn, donate_argnums=(0,))
    else:
        params = model.init(key)
        state = {"params": params, "opt": init_state(params)}
        step_fn = jax.jit(step_fn, donate_argnums=(0,))

    n_params = sum(x.size for x in jax.tree.leaves(state["params"]))
    print(f"arch={args.arch} preset={args.preset} params={n_params:,} "
          f"devices={len(jax.devices())}")

    # --- checkpointing / restore
    ckpt = Checkpointer(args.ckpt_dir) if args.ckpt_dir else None
    start_step = 0
    if ckpt is not None and ckpt.latest_step() is not None:
        state = ckpt.restore(state)
        start_step = ckpt.latest_step()
        print(f"restored checkpoint at step {start_step}")

    # --- data
    stream = make_lm_tokens(
        max(args.steps, 200) * args.batch * args.seq + args.seq + 1,
        cfg.vocab, seed=args.seed)
    batcher = ShardedBatcher(stream, args.batch, args.seq, mesh=mesh,
                             batch_axes=ctx.dp_axes if mesh else ("data",))
    batcher.seek(start_step)
    monitor = StepTimeMonitor()

    it = iter(batcher)
    losses = []
    for step in range(start_step, args.steps):
        tokens, labels = next(it)
        batch = {"tokens": tokens, "labels": labels}
        if cfg.enc_dec:
            frames = jnp.zeros((args.batch, cfg.enc_seq, cfg.d_model),
                               jnp.float32)
            batch["frames"] = frames
        t0 = time.time()
        state, metrics = step_fn(state, batch)
        loss = float(metrics["loss"])
        dt = time.time() - t0
        if monitor.observe(step, dt):
            print(f"  [straggler] step {step} took {dt:.2f}s "
                  f"(ema {monitor.ema:.2f}s)")
        losses.append(loss)
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss {loss:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} {dt:.2f}s")
        if ckpt is not None and (step + 1) % args.ckpt_every == 0:
            ckpt.save(step + 1, state)
    if ckpt is not None:
        ckpt.save(args.steps, state, blocking=True)
    print(json.dumps({"first_loss": losses[0], "last_loss": losses[-1],
                      "monitor": monitor.summary()}))


if __name__ == "__main__":
    main()
