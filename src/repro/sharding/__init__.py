"""Divisibility-aware sharding rules for the production mesh."""
from .rules import ShardingCtx, param_spec, param_specs  # noqa: F401
