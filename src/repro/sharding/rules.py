"""Divisibility-aware sharding rules (DESIGN.md §4).

Mesh contract (launch/mesh.py): axes ('data', 'model') single-pod or
('pod', 'data', 'model') multi-pod. Layout:

  * batch over DP = ('pod','data'); TP over 'model'; FSDP (ZeRO-3 style
    parameter + optimizer sharding) over 'data'.
  * matmul weights (in, out): P(fsdp, tp) — all-gathered over 'data' at
    use, contracted over 'model' with psum (GSPMD inserts both).
  * MoE expert stacks (E, in, out): P(tp, fsdp, None) — expert parallelism
    over 'model' (the shard_map island in models/mlp.py consumes this).
  * embeddings (V, D): vocab over tp when divisible, else P(None, tp).
  * long_500k (batch=1) shards the KV-cache sequence dim over 'data'
    (context parallelism) instead of batch.

JAX rejects non-divisible input shardings, so every rule filters axes by
divisibility (e.g. granite's vocab 49155 on a 16-way axis -> replicated).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ShardingCtx:
    """Mesh + axis roles, threaded through model builders.

    mesh=None (unit tests / single-CPU smoke) turns every constraint into
    a no-op and makes specs fully replicated."""
    mesh: Mesh | None = None
    dp_axes: tuple[str, ...] = ("data",)       # ('pod','data') multi-pod
    tp_axis: str | None = "model"
    fsdp_axis: str | None = "data"             # param/optimizer sharding
    cache_seq_axes: tuple[str, ...] = ()       # context parallelism (500k)

    # -------------------------------------------------------------- sizes
    def axis_size(self, axes) -> int:
        if self.mesh is None or axes is None:
            return 1
        if isinstance(axes, str):
            axes = (axes,)
        return int(np.prod([self.mesh.shape[a] for a in axes]))

    def _fit(self, dim: int, axes):
        """Return axes if they evenly divide dim, else None."""
        if axes is None or self.mesh is None:
            return None
        if dim % self.axis_size(axes) == 0:
            return axes
        return None

    def spec(self, shape: Sequence[int], *wanted) -> P:
        """PartitionSpec with non-divisible entries dropped."""
        assert len(wanted) == len(shape), (shape, wanted)
        return P(*[self._fit(d, a) for d, a in zip(shape, wanted)])

    def constrain(self, x, *wanted):
        """with_sharding_constraint honoring divisibility; no-op off-mesh."""
        if self.mesh is None:
            return x
        spec = self.spec(x.shape, *wanted)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, spec))

    def shard_batch(self, x):
        """Inter-block activation layout: batch over DP; for (B, S, D)
        activations additionally shard the *sequence* over the model axis
        (Megatron sequence parallelism): the per-period boundary
        activations a rematerialized backward must keep alive shrink by
        the TP degree, and GSPMD turns the surrounding TP collectives
        into all-gather/reduce-scatter pairs at the block edges. Dims
        that don't divide (e.g. decode S=1) drop the constraint."""
        if x.ndim == 3:
            return self.constrain(x, self.dp_axes, self.tp_axis, None)
        return self.constrain(x, self.dp_axes, *(None,) * (x.ndim - 1))

    def named(self, spec: P) -> NamedSharding:
        assert self.mesh is not None
        return NamedSharding(self.mesh, spec)


def param_spec(ctx: ShardingCtx, path: str, shape: tuple[int, ...]) -> P:
    """Sharding rule for one parameter, dispatched on its tree path.

    Conventions: paths are '/'-joined dict keys, e.g.
    'layers/attn/wq', 'layers/moe/w_up', 'embed/table'. Params that live
    under a scanned layer stack carry a leading layer dim; rules key on
    the *trailing* dims. Unknown leaves fall back to replicated."""
    tp, fsdp = ctx.tp_axis, ctx.fsdp_axis
    name = path.split("/")[-1]
    stacked = "layers" in path or "blocks" in path
    lead = (None,) * (1 if stacked else 0)

    if ctx.mesh is None:
        return P(*(None,) * len(shape))

    def tail_spec(*axes):
        assert len(lead) + len(axes) == len(shape), (path, shape, axes)
        return ctx.spec(shape, *lead, *axes)

    # --- embeddings / unembedding (never stacked)
    if name in ("table", "unembed"):
        V, _ = shape
        if V % ctx.axis_size(tp) == 0:
            return ctx.spec(shape, tp, fsdp)
        return ctx.spec(shape, None, tp)
    if name == "pos_table":
        return ctx.spec(shape, None, tp)

    nd = len(shape) - len(lead)  # logical rank of the per-layer param

    # --- MoE expert stacks (E, in, out): EP over tp + FSDP over in-dim.
    # The FSDP dim costs a bf16 all-gather of each layer's local experts
    # at use (the alternative — EP-only storage — replicates the f32
    # optimizer state over 'data': +170 GB/device at deepseek-v2 scale,
    # strictly worse). The gather is bf16 (cast-before-island in mlp.py)
    # and is the dominant collective of MoE train cells; see §Perf.
    if nd == 3 and ("moe" in path or "experts" in path):
        return tail_spec(tp, fsdp, None)

    # --- biases / norms / gates (1-D): shard tp-sized inner vectors
    if nd == 1:
        return tail_spec(tp if name in ("d_skip", "conv_bias", "dt_bias")
                         else None)

    # --- row-parallel output projections: contract dim carries tp
    if nd == 2 and name in ("wo", "w_down", "out_proj", "down"):
        return tail_spec(tp, fsdp)

    # --- SSM block internals: inner (d_inner) dim carries tp
    if nd == 2 and name in ("x_proj", "w_if"):
        return tail_spec(tp, None)
    if nd == 2 and name == "a_log":
        return tail_spec(tp, None)

    # --- conv kernels (channels, width): channels over tp
    if nd == 2 and name.startswith("conv"):
        return tail_spec(tp, None)

    # --- default matmul weight (in, out): column parallel + FSDP
    if nd == 2:
        return tail_spec(fsdp, tp)
    return P(*(None,) * len(shape))


def param_specs(ctx: ShardingCtx, params) -> dict:
    """Spec pytree mirroring a params pytree (layer-stacked leaves get a
    leading None)."""
    def visit(path_elems, leaf):
        path = "/".join(str(getattr(p, "key", p)) for p in path_elems)
        return param_spec(ctx, path, leaf.shape)
    return jax.tree_util.tree_map_with_path(visit, params)
