"""LibSVM text-format IO (the paper's datasets ship in this format).

The paper's MPI implementation has each process read its own partition of
the datafile (Sec 5.6/5.7.1); ``load_libsvm`` supports that pattern via
``rank``/``world`` striping so host h parses only every world-th line
group. Dense output (the TPU-side layout; DESIGN.md §6.3).

``iter_libsvm`` is the out-of-core flavor: it yields fixed-shape padded
row blocks with validity masks, so the dataset is never resident at once
— the sufficient statistics Sigma = X^T diag(1/gamma) X and the
mu-numerator are exact sums over rows (paper Fig. 1), and the solver's
``driver="stream"`` accumulates them chunk by chunk (DESIGN.md
§Perf/Streaming).
"""
from __future__ import annotations

from typing import Iterator

import numpy as np


def save_libsvm(path: str, X: np.ndarray, y: np.ndarray) -> None:
    with open(path, "w") as f:
        for row, label in zip(X, y):
            nz = np.nonzero(row)[0]
            feats = " ".join(f"{j + 1}:{row[j]:.6g}" for j in nz)
            lab = int(label) if float(label).is_integer() else float(label)
            f.write(f"{lab} {feats}\n")


def parse_libsvm_line(line: str, lineno: int):
    """Parse one libsvm line into (label, {col0: val}) or None.

    Tolerates ``#`` comment suffixes and blank/whitespace-only lines
    (returns None for those). Malformed labels or ``idx:val`` tokens
    raise ValueError naming the line and token, instead of an opaque
    float()/int() error from deep inside a parse loop.
    """
    line = line.split("#", 1)[0].strip()
    if not line:
        return None
    parts = line.split()
    try:
        label = float(parts[0])
    except ValueError:
        raise ValueError(
            f"libsvm parse error at line {lineno}: label {parts[0]!r} "
            "is not a number") from None
    feat = {}
    for tok in parts[1:]:
        idx, sep, val = tok.partition(":")
        try:
            if not sep:
                raise ValueError
            j = int(idx)
            v = float(val)
        except ValueError:
            raise ValueError(
                f"libsvm parse error at line {lineno}: malformed "
                f"'idx:val' token {tok!r}") from None
        if j < 1:
            raise ValueError(
                f"libsvm parse error at line {lineno}: feature index "
                f"{j} out of range (indices are 1-based)")
        feat[j - 1] = v
    return label, feat


def load_libsvm(path: str, n_features: int | None = None,
                rank: int = 0, world: int = 1):
    """Parse a libsvm file; with world > 1, return this rank's row stripe
    (round-robin by line index — the paper's per-process IO split)."""
    rows, labels = [], []
    max_j = 0
    with open(path) as f:
        for i, line in enumerate(f):
            if world > 1 and (i % world) != rank:
                continue
            parsed = parse_libsvm_line(line, i + 1)
            if parsed is None:
                continue
            label, feat = parsed
            labels.append(label)
            if feat:
                max_j = max(max_j, max(feat))
            rows.append(feat)
    K = n_features if n_features is not None else max_j + 1
    X = np.zeros((len(rows), K), np.float32)
    for i, feat in enumerate(rows):
        for j, v in feat.items():
            if j < K:
                X[i, j] = v
    return X, np.asarray(labels, np.float32)


def iter_libsvm(path: str, chunk_rows: int, n_features: int,
                rank: int = 0, world: int = 1,
                ) -> Iterator[tuple[np.ndarray, np.ndarray, np.ndarray]]:
    """Stream a libsvm file as fixed-shape padded row blocks.

    Yields ``(X (chunk_rows, n_features) f32, y (chunk_rows,) f32,
    mask (chunk_rows,) f32)``; every block has the same shape (the final
    partial block is zero-padded with ``mask == 0``), so downstream jit
    caches see one shape. Padded rows follow the repo-wide convention
    (DESIGN.md §6.3): X-row = 0, target = 0, mask = 0 — their sufficient
    statistics contributions are exactly zero.

    With ``world > 1``, yields only rank's round-robin line stripe
    (the paper's Sec 5.6 per-process IO split); striping is by raw line
    index so every rank agrees on the split without coordination.

    ``n_features`` is required: a streaming reader cannot discover the
    feature-space width without a full extra pass.
    """
    if chunk_rows < 1:
        raise ValueError(f"chunk_rows must be >= 1, got {chunk_rows}")
    if n_features < 1:
        raise ValueError(f"n_features must be >= 1, got {n_features}")
    X = np.zeros((chunk_rows, n_features), np.float32)
    y = np.zeros((chunk_rows,), np.float32)
    mask = np.zeros((chunk_rows,), np.float32)
    fill = 0
    with open(path) as f:
        for i, line in enumerate(f):
            if world > 1 and (i % world) != rank:
                continue
            parsed = parse_libsvm_line(line, i + 1)
            if parsed is None:
                continue
            label, feat = parsed
            y[fill] = label
            mask[fill] = 1.0
            for j, v in feat.items():
                if j < n_features:
                    X[fill, j] = v
            fill += 1
            if fill == chunk_rows:
                yield X.copy(), y.copy(), mask.copy()
                X[:] = 0.0
                y[:] = 0.0
                mask[:] = 0.0
                fill = 0
    if fill:
        yield X.copy(), y.copy(), mask.copy()
