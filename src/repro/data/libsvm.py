"""LibSVM text-format IO (the paper's datasets ship in this format).

The paper's MPI implementation has each process read its own partition of
the datafile (Sec 5.6/5.7.1); ``load_libsvm`` supports that pattern via
``rank``/``world`` striping so host h parses only every world-th line
group. Dense output (the TPU-side layout; DESIGN.md §6.3)."""
from __future__ import annotations

import numpy as np


def save_libsvm(path: str, X: np.ndarray, y: np.ndarray) -> None:
    with open(path, "w") as f:
        for row, label in zip(X, y):
            nz = np.nonzero(row)[0]
            feats = " ".join(f"{j + 1}:{row[j]:.6g}" for j in nz)
            lab = int(label) if float(label).is_integer() else float(label)
            f.write(f"{lab} {feats}\n")


def load_libsvm(path: str, n_features: int | None = None,
                rank: int = 0, world: int = 1):
    """Parse a libsvm file; with world > 1, return this rank's row stripe
    (round-robin by line index — the paper's per-process IO split)."""
    rows, labels = [], []
    max_j = 0
    with open(path) as f:
        for i, line in enumerate(f):
            if world > 1 and (i % world) != rank:
                continue
            parts = line.split()
            if not parts:
                continue
            labels.append(float(parts[0]))
            feat = {}
            for tok in parts[1:]:
                j, v = tok.split(":")
                j = int(j) - 1
                feat[j] = float(v)
                max_j = max(max_j, j)
            rows.append(feat)
    K = n_features if n_features is not None else max_j + 1
    X = np.zeros((len(rows), K), np.float32)
    for i, feat in enumerate(rows):
        for j, v in feat.items():
            if j < K:
                X[i, j] = v
    return X, np.asarray(labels, np.float32)
