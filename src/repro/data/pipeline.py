"""Sharded host->device input pipeline for the LM training path.

Deterministic, restartable (state = integer step, so checkpoint/resume is
exact), with background prefetch. Each global batch is laid out
(global_batch, seq_len) and device_put with batch sharded over the mesh's
data axes — the multi-host generalization feeds per-host addressable
shards the same way the paper parallelizes datafile IO across MPI ranks
(Sec 5.6)."""
from __future__ import annotations

import queue
import threading
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


class ShardedBatcher:
    """Iterates (tokens, targets) batches from a token stream.

    Targets are next-token shifted. State is the step counter; ``seek``
    restores mid-epoch position after restart."""

    def __init__(self, stream: np.ndarray, batch: int, seq_len: int,
                 mesh: Mesh | None = None, batch_axes=("data",),
                 prefetch: int = 2, seed: int = 0):
        self.stream = stream
        self.batch, self.seq_len = batch, seq_len
        self.mesh, self.batch_axes = mesh, tuple(batch_axes)
        self.prefetch = prefetch
        self.step = 0
        n_windows = (len(stream) - 1) // seq_len
        self.n_windows = n_windows
        self.rng = np.random.default_rng(seed)
        self._order = self.rng.permutation(n_windows)

    def seek(self, step: int) -> None:
        self.step = step

    def _host_batch(self, step: int):
        idx = [self._order[(step * self.batch + i) % self.n_windows]
               for i in range(self.batch)]
        toks = np.stack([self.stream[j * self.seq_len:
                                     j * self.seq_len + self.seq_len + 1]
                         for j in idx])
        return toks[:, :-1].astype(np.int32), toks[:, 1:].astype(np.int32)

    def _place(self, arrs):
        if self.mesh is None:
            return tuple(jnp.asarray(a) for a in arrs)
        sh = NamedSharding(self.mesh, P(self.batch_axes, None))
        return tuple(jax.device_put(a, sh) for a in arrs)

    def __iter__(self) -> Iterator:
        q: queue.Queue = queue.Queue(maxsize=self.prefetch)
        stop = threading.Event()

        def worker():
            s = self.step
            while not stop.is_set():
                try:
                    q.put((s, self._host_batch(s)), timeout=0.2)
                    s += 1
                except queue.Full:
                    continue

        t = threading.Thread(target=worker, daemon=True)
        t.start()
        try:
            while True:
                s, arrs = q.get()
                self.step = s + 1
                yield self._place(arrs)
        finally:
            stop.set()
            t.join(timeout=1.0)
