"""Sharded host->device input pipelines.

Two consumers share the double-buffering pattern here:

  * ``ShardedBatcher`` — the LM training path. Deterministic, restartable
    (state = integer step, so checkpoint/resume is exact), with background
    prefetch. Each global batch is laid out (global_batch, seq_len) and
    device_put with batch sharded over the mesh's data axes.
  * ``ChunkPrefetcher`` — the SVM out-of-core path: wraps any iterator of
    fixed-shape host row blocks (e.g. ``data.libsvm.iter_libsvm``) and
    overlaps host parse/copy with device compute, the way the paper
    parallelizes datafile IO across MPI ranks (Sec 5.6). The solver's
    ``driver="stream"`` consumes one of these per pass (DESIGN.md
    §Perf/Streaming).
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Callable, Iterable, Iterator

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def pad_features_to(X: np.ndarray, multiple: int | None = None, *,
                    width: int | None = None) -> np.ndarray:
    """Zero-pad the FEATURE (last) dimension of a row block so its
    width divides ``multiple`` — the explicit route to a k_shard-
    divisible statistic width (``core/linear._k_block`` refuses
    indivisible K rather than silently truncating Sigma columns;
    ``SVMConfig.pad_features`` plumbs this per-fit so callers need not
    pre-pad datasets by hand).

    ``width=`` instead pads to an ABSOLUTE target width (the serving
    prep mode: requests must widen to the model's fitted width, never
    narrow) and REFUSES a target below the current width — slicing
    features off would silently change every score, so it is an error,
    not a truncation.

    Zero columns are exact no-ops for every statistic in this package:
    their Sigma rows/columns and b entries are zero, the ridge pins
    their weights to 0, and predictions are unchanged. Accepts numpy or
    jax arrays (returns the matching kind); width already divisible (or
    already equal to ``width``) is an identity.
    """
    K = X.shape[-1]
    if width is not None:
        assert multiple is None, "pass either multiple or width, not both"
        if width < K:
            raise ValueError(
                f"target width {width} is below the current feature "
                f"width {K}; refusing to slice columns off")
        pad = width - K
    else:
        if multiple is None or multiple <= 1:
            return X
        pad = (-K) % multiple
    if pad == 0:
        return X
    widths = [(0, 0)] * (X.ndim - 1) + [(0, pad)]
    if isinstance(X, np.ndarray):
        return np.pad(X, widths)
    return jnp.pad(X, widths)


def reservoir_rows(chunks: Iterable, m: int, seed: int = 0
                   ) -> tuple[np.ndarray, int]:
    """Uniform sample of ``m`` valid rows from an iterator of
    (X, y, mask) host chunks, in ONE pass and O(m * D) memory.

    Classic reservoir sampling over the masked rows, so an out-of-core
    source (``iter_libsvm``) can supply Nystrom landmarks without ever
    being resident: valid row j replaces a reservoir slot with
    probability m / (j + 1). The slot draws are vectorized per CHUNK
    (one ``rng.integers`` call with a per-row high vector — the draws
    stay independent with the classic marginals), so the pass costs
    O(rows) NumPy work, not one Generator call per row. Returns
    (rows (m', D), n_valid) with m' = min(m, n_valid); chunk padding
    (mask == 0) is skipped.
    """
    rng = np.random.default_rng(seed)
    reservoir: list[np.ndarray] = []
    seen = 0
    for Xc, _, mc in chunks:
        rows = np.asarray(Xc, np.float32)[np.asarray(mc) > 0]
        fill = min(max(m - len(reservoir), 0), len(rows))
        reservoir.extend(np.array(r) for r in rows[:fill])
        seen += fill
        rows = rows[fill:]
        if not len(rows):
            continue
        # Row i of this chunk is global valid-row (seen + i): draw its
        # slot from [0, seen + i + 1) — all rows in one call.
        slots = rng.integers(0, seen + 1 + np.arange(len(rows)))
        seen += len(rows)
        for i in np.nonzero(slots < m)[0]:    # in order: later rows win
            reservoir[slots[i]] = np.array(rows[i])
    if not reservoir:
        raise ValueError("reservoir_rows: source yielded no valid rows")
    return np.stack(reservoir), seen


@dataclasses.dataclass
class RetryStats:
    """Cumulative loader-retry accounting for one consumer — how much
    I/O flakiness a fit absorbed. ``retrying_chunks`` mutates the
    instance it is handed; the stream driver threads one per fit and
    surfaces it as ``FitResult.loader_retries``/``loader_backoff_s`` so
    an outer controller (``runtime.controller``) can budget on it."""

    retries: int = 0          # total retry_on failures absorbed
    backoff_s: float = 0.0    # total seconds slept backing off
    exhausted: int = 0        # budgets that ran out (error re-raised)


def retrying_chunks(factory: Callable[[int], Iterable], *,
                    retries: int = 3, backoff: float = 0.05,
                    jitter: float = 0.0, seed: int = 0,
                    retry_on: tuple = (IOError, OSError),
                    sleep: Callable[[float], None] = time.sleep,
                    stats: RetryStats | None = None
                    ) -> Iterator:
    """Bounded retry + exponential backoff around a restartable chunk
    source — how ``driver="stream"`` turns a flaky filesystem into
    retries instead of a crash (DESIGN.md §Reliability).

    ``factory(skip)`` must return a fresh iterator with the first
    ``skip`` chunks already skipped (for a file-backed source this is a
    re-open + fast-forward; ``itertools.islice`` over a fresh generator
    works for any source). On a ``retry_on`` error the source is
    re-created past the chunks already yielded, after sleeping
    ``backoff * 2**(attempt-1) * (1 + jitter*u)`` seconds with
    ``u ~ U[0,1)`` drawn from a ``seed``-keyed generator — DETERMINISTIC
    jitter: the same (seed, failure sequence) sleeps the same schedule,
    so chaos tests replay bit-for-bit while a fleet of consumers with
    distinct seeds desynchronizes instead of thundering-herding a
    recovering filesystem. ``retries`` CONSECUTIVE failures at the same
    position exhaust the budget and re-raise (a success resets the
    count, so a loader failing every nth chunk once is survivable
    indefinitely with retries >= 1). ``retries=0`` is pass-through.
    Exceptions outside ``retry_on`` — including the fault harness's
    ``SimulatedPreemption`` — propagate immediately: a preemption is not
    a retryable IO blip. ``stats`` (a :class:`RetryStats`) accumulates
    what was absorbed.
    """
    rng = np.random.default_rng(seed)
    yielded = 0
    attempt = 0
    it = None
    while True:
        try:
            if it is None:     # (re)open inside the retry net: the
                it = iter(factory(yielded))  # open itself can fail too
            chunk = next(it)
        except StopIteration:
            return
        except retry_on:
            attempt += 1
            if attempt > retries:
                if stats is not None:
                    stats.exhausted += 1
                raise
            pause = backoff * (2 ** (attempt - 1))
            if jitter > 0.0:
                pause *= 1.0 + jitter * float(rng.random())
            if stats is not None:
                stats.retries += 1
                stats.backoff_s += pause
            sleep(pause)
            it = None
            continue
        attempt = 0
        yielded += 1
        yield chunk


class ChunkPrefetcher:
    """Double-buffered host->device prefetch over an iterator of array
    tuples.

    A background thread pulls host blocks from ``chunks``, transfers them
    (``place``, default ``jnp.asarray`` per leaf) and parks up to
    ``depth`` transferred blocks in a queue, so the device never waits
    on host IO and at most ``depth + 2`` blocks are device-resident at
    once — ``depth`` queued, one in the worker's hand (placed *before*
    the put so the transfer overlaps compute), one held by the consumer.
    That bound is what keeps ``driver="stream"``'s peak residency
    proportional to the chunk size, not the dataset
    (``max_resident_bytes`` reports the high-water mark).

    Worker exceptions (e.g. a libsvm parse error mid-file) are forwarded
    through the queue as a tagged item and re-raised at the consumer's
    iteration site — never swallowed in the thread, and never able to
    strand a consumer blocked on ``q.get()``.
    """

    _DONE = object()
    _ERROR = object()

    def __init__(self, chunks: Iterable, depth: int = 2,
                 place: Callable | None = None):
        if depth < 1:
            raise ValueError(
                f"prefetch depth must be >= 1 (got {depth}): the worker "
                "needs at least one queue slot, so actual residency is "
                "never below 3 chunks and a silent clamp would break "
                "the documented (depth + 2) bound")
        self.chunks = chunks
        self.depth = int(depth)
        self.place = place or (
            lambda arrs: tuple(jnp.asarray(a) for a in arrs))
        self.max_resident_bytes = 0

    @staticmethod
    def _nbytes(arrs) -> int:
        # Both np.ndarray and jax.Array expose .nbytes without forcing
        # a device->host transfer (np.asarray here would download every
        # chunk right after uploading it).
        return sum(int(a.nbytes) for a in jax.tree_util.tree_leaves(arrs))

    def __iter__(self) -> Iterator:
        q: queue.Queue = queue.Queue(maxsize=self.depth)
        stop = threading.Event()

        def put(item) -> bool:
            # Stop-aware bounded put: never blocks forever against a
            # consumer that stopped draining.
            while not stop.is_set():
                try:
                    q.put(item, timeout=0.2)
                    return True
                except queue.Full:
                    continue
            return False

        def worker():
            try:
                for arrs in self.chunks:
                    placed = self.place(arrs)
                    nbytes = self._nbytes(placed)
                    if not put((placed, nbytes)):
                        return
            except BaseException as e:  # noqa: BLE001 — forwarded below
                put((self._ERROR, e))
            else:
                put((self._DONE, None))

        t = threading.Thread(target=worker, daemon=True)
        t.start()
        resident = 0
        try:
            while True:
                item, payload = q.get()
                if item is self._DONE:
                    return
                if item is self._ERROR:
                    raise payload
                placed, nbytes = item, payload
                # The consumer holds this block while ``depth`` more sit
                # transferred in the queue and the worker may hold one
                # further block it placed before a full-queue put.
                resident = nbytes * (self.depth + 2)
                self.max_resident_bytes = max(self.max_resident_bytes,
                                              resident)
                yield placed
        finally:
            stop.set()
            t.join(timeout=1.0)


class ShardedBatcher:
    """Iterates (tokens, targets) batches from a token stream.

    Targets are next-token shifted. State is the step counter; ``seek``
    restores mid-epoch position after restart — including *mid-iteration*:
    the prefetch worker tags every queued batch with a generation counter,
    ``seek`` bumps the generation, and stale prefetched steps are
    discarded instead of being yielded (the worker restarts from the new
    step the next time it produces)."""

    def __init__(self, stream: np.ndarray, batch: int, seq_len: int,
                 mesh: Mesh | None = None, batch_axes=("data",),
                 prefetch: int = 2, seed: int = 0):
        self.stream = stream
        self.batch, self.seq_len = batch, seq_len
        self.mesh, self.batch_axes = mesh, tuple(batch_axes)
        self.prefetch = prefetch
        self.step = 0
        self._gen = 0
        n_windows = (len(stream) - 1) // seq_len
        self.n_windows = n_windows
        self.rng = np.random.default_rng(seed)
        self._order = self.rng.permutation(n_windows)

    def seek(self, step: int) -> None:
        # Order matters: the worker re-reads ``step`` only after it
        # observes the generation bump.
        self.step = step
        self._gen += 1

    def _host_batch(self, step: int):
        idx = [self._order[(step * self.batch + i) % self.n_windows]
               for i in range(self.batch)]
        toks = np.stack([self.stream[j * self.seq_len:
                                     j * self.seq_len + self.seq_len + 1]
                         for j in idx])
        return toks[:, :-1].astype(np.int32), toks[:, 1:].astype(np.int32)

    def _place(self, arrs):
        if self.mesh is None:
            return tuple(jnp.asarray(a) for a in arrs)
        sh = NamedSharding(self.mesh, P(self.batch_axes, None))
        return tuple(jax.device_put(a, sh) for a in arrs)

    def __iter__(self) -> Iterator:
        q: queue.Queue = queue.Queue(maxsize=self.prefetch)
        stop = threading.Event()

        def worker():
            gen = -1
            s = 0
            while not stop.is_set():
                if gen != self._gen:
                    gen = self._gen
                    s = self.step
                item = (gen, s, self._host_batch(s))
                placed = False
                while not stop.is_set() and gen == self._gen:
                    try:
                        q.put(item, timeout=0.2)
                        placed = True
                        break
                    except queue.Full:
                        continue
                if placed:
                    s += 1

        t = threading.Thread(target=worker, daemon=True)
        t.start()
        try:
            while True:
                gen, s, arrs = q.get()
                if gen != self._gen:
                    continue  # stale: prefetched before the last seek()
                self.step = s + 1
                yield self._place(arrs)
        finally:
            stop.set()
            t.join(timeout=1.0)
