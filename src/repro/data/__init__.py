"""Data substrate: synthetic generators shaped like the paper's datasets,
LibSVM-format IO, and the sharded host->device pipeline."""
from .synthetic import (  # noqa: F401
    make_alpha_like, make_dna_like, make_mnist8m_like, make_year_like,
    make_blobs, make_circles, make_lm_tokens)
from .libsvm import load_libsvm, save_libsvm  # noqa: F401
from .pipeline import ShardedBatcher  # noqa: F401
