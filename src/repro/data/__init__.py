"""Data substrate: synthetic generators shaped like the paper's datasets,
LibSVM-format IO, and the sharded host->device pipeline."""
from .synthetic import (  # noqa: F401
    make_alpha_like, make_dna_like, make_mnist8m_like, make_year_like,
    make_blobs, make_circles, make_lm_tokens)
from .libsvm import (iter_libsvm, load_libsvm, parse_libsvm_line,  # noqa: F401
                     save_libsvm)
from .pipeline import (ChunkPrefetcher, RetryStats,  # noqa: F401
                       ShardedBatcher, pad_features_to, reservoir_rows,
                       retrying_chunks)
