"""Synthetic dataset generators shaped like the paper's Table 3.

| paper name | N          | K   | M  | type | generator here        |
|------------|------------|-----|----|------|-----------------------|
| alpha      | 250,000    | 500 | 2  | CLS  | make_alpha_like       |
| dna        | 25,000,000 | 800 | 2  | CLS  | make_dna_like         |
| year       | 250,000    | 90  | -  | SVR  | make_year_like        |
| mnist8m    | 4,000,000  | 798 | 10 | MLT  | make_mnist8m_like     |

Defaults are scaled down for CPU benchmarking (pass n/k explicitly for the
paper's full sizes — the generators are streaming-friendly, O(N*K) memory
only for the returned array). Generation is deterministic per seed. Also:
``make_lm_tokens`` synthesizes token streams for the LM architectures'
training path (a deterministic mixture of Zipfian unigrams and repeated
n-gram motifs, so a real model shows decreasing loss)."""
from __future__ import annotations

import numpy as np


def _blob_classifier(rng, n, k, margin_noise):
    w = rng.normal(size=k) / np.sqrt(k)
    X = rng.normal(size=(n, k)).astype(np.float32)
    logits = X @ w + margin_noise * rng.normal(size=n)
    y = np.where(logits > 0, 1.0, -1.0).astype(np.float32)
    return X, y


def make_alpha_like(n: int = 50_000, k: int = 500, seed: int = 0,
                    margin_noise: float = 0.5):
    """Dense, moderately hard binary problem (Pascal LSL 'alpha' shape)."""
    rng = np.random.default_rng(seed)
    return _blob_classifier(rng, n, k, margin_noise)


def make_dna_like(n: int = 200_000, k: int = 800, seed: int = 1,
                  sparsity: float = 0.25, margin_noise: float = 0.45):
    """'dna'-shaped: wide-ish, sparse-ish binary data. Values in {0,1}
    scaled; labels from a planted hyperplane with noise -> ~90% achievable
    accuracy like the paper's Table 5."""
    rng = np.random.default_rng(seed)
    X = (rng.random((n, k)) < sparsity).astype(np.float32)
    w = rng.normal(size=k) / np.sqrt(k * sparsity)
    logits = X @ w - np.median(X @ w) + margin_noise * rng.normal(size=n)
    y = np.where(logits > 0, 1.0, -1.0).astype(np.float32)
    return X, y


def make_year_like(n: int = 50_000, k: int = 90, seed: int = 2,
                   noise: float = 0.3):
    """'YearPredictionMSD'-shaped regression; targets normalized to
    zero-mean unit-variance exactly like the paper's Sec 5.10 protocol."""
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n, k)).astype(np.float32)
    w = rng.normal(size=k) / np.sqrt(k)
    ynorm = X @ w + noise * rng.normal(size=n)
    ynorm = (ynorm - ynorm.mean()) / ynorm.std()
    return X, ynorm.astype(np.float32)


def make_mnist8m_like(n: int = 100_000, k: int = 798, m: int = 10,
                      seed: int = 3, margin_noise: float = 1.0):
    """'mnist8m'-shaped 10-class problem: class-prototype mixture in [0,1]
    pixel-ish features."""
    rng = np.random.default_rng(seed)
    protos = rng.random((m, k)).astype(np.float32)
    labels = rng.integers(0, m, size=n).astype(np.int32)
    X = 0.5 * protos[labels] + 0.5 * rng.random((n, k)).astype(np.float32)
    # label noise so accuracy lands in the high-80s like Table 8
    flip = rng.random(n) < 0.08
    labels[flip] = rng.integers(0, m, size=int(flip.sum()))
    del margin_noise
    return X.astype(np.float32), labels


def make_blobs(n: int = 2000, k: int = 20, seed: int = 0,
               margin_noise: float = 0.1):
    """Small generic binary blobs (tests/examples)."""
    rng = np.random.default_rng(seed)
    return _blob_classifier(rng, n, k, margin_noise)


def make_circles(n: int = 400, seed: int = 0):
    """Radially-separated classes — not linearly separable (KRN demo)."""
    rng = np.random.default_rng(seed)
    r = np.concatenate([rng.uniform(0, 1, n // 2),
                        rng.uniform(1.5, 2.5, n - n // 2)])
    th = rng.uniform(0, 2 * np.pi, n)
    X = np.stack([r * np.cos(th), r * np.sin(th)], 1).astype(np.float32)
    y = np.concatenate([np.ones(n // 2), -np.ones(n - n // 2)])
    return X, y.astype(np.float32)


def make_lm_tokens(n_tokens: int, vocab: int, seed: int = 0,
                   motif_len: int = 16, n_motifs: int = 64) -> np.ndarray:
    """Synthetic token stream: Zipfian unigrams + repeated motifs so a
    language model has learnable structure (loss decreases)."""
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    probs = (1.0 / ranks); probs /= probs.sum()
    stream = rng.choice(vocab, size=n_tokens, p=probs).astype(np.int32)
    motifs = rng.choice(vocab, size=(n_motifs, motif_len), p=probs)
    n_insert = n_tokens // (motif_len * 4)
    pos = rng.integers(0, max(1, n_tokens - motif_len), size=n_insert)
    for p in pos:
        stream[p:p + motif_len] = motifs[rng.integers(0, n_motifs)]
    return stream
