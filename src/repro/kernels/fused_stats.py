"""Pallas TPU kernel: the WHOLE per-iteration statistic in one X pass.

``fused_estep`` already fuses (margin, gamma, b); the Sigma statistic was
a second full pass over X (``weighted_gram``/``syrk_tri``). This kernel
emits all four outputs of one EM iteration from a single ``pallas_call``:

    margin_d = w^T x_d
    gamma_d  = max(eps, |rho_d - margin_d|)          (paper Eq. 9/36)
    b        = sum_d (rho_d/gamma_d + beta_d) x_d    (Eq. 6/39 numerator)
    S        = sum_d (m_d/gamma_d) x_d x_d^T         (Sigma^p, Table 9)

so X streams HBM->VMEM ONCE per iteration instead of twice — on a
memory-bound statistic that halves iteration HBM traffic (DESIGN.md
§Perf). ``m_d`` is an optional extra weight mask on the Sigma weights
only (the KRN path suppresses padded Gram rows with it; LIN passes ones).

Grid is 1-D over N-blocks; each step holds a (bn, K) X tile, the (K, 1)
weight vector and the full (K, K) fp32 Sigma accumulator in VMEM. That
accumulator bounds the usable K: K <= ~1500 fits the ~16 MB VMEM budget
with bn=512 (K*K*4B + 2*bn*K*4B). Larger K should use ``syrk_tri`` +
``fused_estep`` (two passes, tiled K). The SVM regime of the paper
(K = 54..800 after bias) sits comfortably inside.

Unlike ``syrk_tri`` the Sigma accumulation here is a dense rank-bn
update: the triangle trick does not compose with single-pass streaming
(a triangle block grid must revisit X tiles per (i, j) pair, which is
exactly the second pass we are eliminating). Dense-SYRK FLOPs at half
the HBM traffic vs half the FLOPs at full traffic — the roofline in
DESIGN.md §Perf says fused wins whenever the statistic is memory-bound,
i.e. precisely when N >> K.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _make_kernel(eps: float):
    def _kernel(x_ref, rho_ref, beta_ref, wmask_ref, w_ref,
                margin_ref, gamma_ref, b_ref, s_ref):
        x = x_ref[...].astype(jnp.float32)          # (bn, K)
        wv = w_ref[...].astype(jnp.float32)         # (K, 1)
        rho = rho_ref[...].astype(jnp.float32)      # (bn, 1)
        beta = beta_ref[...].astype(jnp.float32)    # (bn, 1)
        wmask = wmask_ref[...].astype(jnp.float32)  # (bn, 1)

        margin = jax.lax.dot_general(                # (bn, 1) on the MXU
            x, wv, dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        margin_ref[...] = margin
        gamma = jnp.maximum(jnp.abs(rho - margin), eps)
        gamma_ref[...] = gamma
        coef = rho / gamma + beta                    # (bn, 1)

        @pl.when(pl.program_id(0) == 0)
        def _init():
            b_ref[...] = jnp.zeros_like(b_ref)
            s_ref[...] = jnp.zeros_like(s_ref)

        b_ref[...] += jax.lax.dot_general(           # x^T coef: (K, 1)
            x, coef, dimension_numbers=(((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        xw = x * (wmask / gamma)                     # (bn, K) weighted rows
        s_ref[...] += jax.lax.dot_general(           # x^T diag(m/gamma) x
            xw, x, dimension_numbers=(((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
    return _kernel


@functools.partial(jax.jit,
                   static_argnames=("eps", "block_n", "interpret"))
def fused_stats(X: jnp.ndarray, rho: jnp.ndarray, beta: jnp.ndarray,
                wvec: jnp.ndarray, wmask: jnp.ndarray | None = None, *,
                eps: float = 1e-6, block_n: int = 512,
                interpret: bool = False):
    """Returns (margin (N,), gamma (N,), b (K,), S (K, K)), all f32.

    X: (N, K); rho/beta/wmask: (N,); wvec: (K,). Zero-padded rows carry
    rho = beta = 0 so coef is exactly 0, and their X-row is 0 so the S
    contribution vanishes regardless of the padded gamma value.
    """
    N, K = X.shape
    if wmask is None:
        wmask = jnp.ones((N,), jnp.float32)
    bn = min(block_n, _round_up(N, 8))
    Kp = _round_up(K, 128)
    Np = _round_up(N, bn)
    if (Np, Kp) != (N, K):
        X = jnp.pad(X, ((0, Np - N), (0, Kp - K)))
        rho = jnp.pad(rho, (0, Np - N))
        beta = jnp.pad(beta, (0, Np - N))
        wmask = jnp.pad(wmask, (0, Np - N))
        wvec = jnp.pad(wvec, (0, Kp - K))

    grid = (Np // bn,)
    margin, gamma, b, S = pl.pallas_call(
        _make_kernel(float(eps)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, Kp), lambda n: (n, 0)),   # X rows
            pl.BlockSpec((bn, 1), lambda n: (n, 0)),    # rho
            pl.BlockSpec((bn, 1), lambda n: (n, 0)),    # beta
            pl.BlockSpec((bn, 1), lambda n: (n, 0)),    # Sigma weight mask
            pl.BlockSpec((Kp, 1), lambda n: (0, 0)),    # w (replicated)
        ],
        out_specs=[
            pl.BlockSpec((bn, 1), lambda n: (n, 0)),    # margin
            pl.BlockSpec((bn, 1), lambda n: (n, 0)),    # gamma
            pl.BlockSpec((Kp, 1), lambda n: (0, 0)),    # b (revisited)
            pl.BlockSpec((Kp, Kp), lambda n: (0, 0)),   # S (revisited)
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Np, 1), jnp.float32),
            jax.ShapeDtypeStruct((Np, 1), jnp.float32),
            jax.ShapeDtypeStruct((Kp, 1), jnp.float32),
            jax.ShapeDtypeStruct((Kp, Kp), jnp.float32),
        ],
        interpret=interpret,
    )(X, rho.reshape(Np, 1), beta.reshape(Np, 1), wmask.reshape(Np, 1),
      wvec.reshape(Kp, 1))
    return margin[:N, 0], gamma[:N, 0], b[:K, 0], S[:K, :K]


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m
