"""Pallas TPU kernel: the WHOLE per-iteration statistic in one X pass.

``fused_estep`` already fuses (margin, gamma, b); the Sigma statistic was
a second full pass over X (``weighted_gram``/``syrk_tri``). This kernel
emits every output of one iteration from a single ``pallas_call``:

    margin_d  = w^T x_d
    aug_d     = per-row augmentation update on the margin tile
                (an EPILOGUE from ``epilogues.py``: EM gamma, the MC
                inverse-Gaussian transform of pre-drawn (nu, u) noise,
                or SVR's double (gamma, omega) mixture — Eq. 9/5/25-28)
    b         = sum_d coef_d x_d                 (Eq. 6/28/39 numerator)
    S         = sum_d (m_d * weight_d) x_d x_d^T (Sigma^p, Table 9)

so X streams HBM->VMEM ONCE per iteration instead of two (EM) or three
(the pre-fusion MC/SVR paths: margin matmul, b matmul, SYRK) — on a
memory-bound statistic stream count IS iteration time (DESIGN.md
§Perf, §Perf/MC-SVR). ``m_d`` is an optional extra weight mask on the
Sigma weights only (the KRN path suppresses padded Gram rows with it;
LIN passes ones). MC epilogues consume pre-drawn per-row noise streamed
in as extra (N,) operands — O(N) bytes next to the N*K*4 X stream — so
the kernel stays PRNG-free and the draws stay bitwise identical to the
``augment.gamma_mc_rowwise`` oracle (see ``epilogues.py``).

Grid is 1-D over N-blocks; each step holds a (bn, K) X tile, the (K, 1)
weight vector and the full (K, K) fp32 Sigma accumulator in VMEM. That
accumulator bounds the usable K: K <= ~1500 fits the ~16 MB VMEM budget
with bn=512 (K*K*4B + 2*bn*K*4B; the per-row noise/aug vectors add
<= 6*bn*4B — noise). Larger K should use the split pair (two passes,
tiled K). The SVM regime of the paper (K = 54..800 after bias) sits
comfortably inside.

``col_start``/``col_blk`` switch Sigma to a COLUMN-WINDOWED output
S_blk = X^T diag(m*w) X[:, start:start+blk] — the 2-D (data x model)
``k_shard_axis`` statistic (DESIGN.md §Perf/k-shard): each model shard
accumulates only its (K, K/n) column block, margin/aug/b unchanged, so
the 2-D layout keeps the one-X-stream property. ``col_blk`` is static
(it shapes the accumulator); ``col_start`` is a TRACED scalar — inside
``shard_map`` it is ``axis_index * blk``, which no static argument can
express. The kernel therefore loads the window with an in-VMEM dynamic
slice of the X tile at a 128-ALIGNED traced base (the scalar rides in
SMEM), over-fetching up to one lane-tile on each side; the wrapper
slices the exact [start, start+blk) columns out of the aligned result.
The narrowed (K, Cw) accumulator is what lets K beyond the full-width
cap still fuse (``ops.fused_stats_fits``).

Unlike ``syrk_tri`` the Sigma accumulation here is a dense rank-bn
update: the triangle trick does not compose with single-pass streaming
(a triangle block grid must revisit X tiles per (i, j) pair, which is
exactly the second pass we are eliminating). Dense-SYRK FLOPs at a
third to half the HBM traffic vs half the FLOPs at full traffic — the
roofline in DESIGN.md §Perf says fused wins whenever the statistic is
memory-bound, i.e. precisely when N >> K.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import epilogues


def _make_kernel(epilogue: str, eps: float, eps_ins: float,
                 n_noise: int, n_aug: int, windowed: bool = False,
                 rng: bool = False, n_chains: int = 1):
    def _kernel(*refs):
        if rng:
            seed_ref, refs = refs[0], refs[1:]
        if windowed:
            c0_ref, refs = refs[0], refs[1:]
        x_ref, rho_ref, beta_ref, wmask_ref, w_ref = refs[:5]
        noise_refs = refs[5:5 + n_noise]
        outs = refs[5 + n_noise:]
        margin_ref, aug_refs = outs[0], outs[1:1 + n_aug]
        b_ref, s_ref = outs[-2], outs[-1]

        x = x_ref[...].astype(jnp.float32)          # (bn, K)
        wv = w_ref[...].astype(jnp.float32)         # (K, C)
        rho = rho_ref[...].astype(jnp.float32)      # (bn, 1)
        beta = beta_ref[...].astype(jnp.float32)    # (bn, 1)
        wmask = wmask_ref[...].astype(jnp.float32)  # (bn, 1)

        margin = jax.lax.dot_general(                # (bn, C) on the MXU
            x, wv, dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        margin_ref[...] = margin
        if rng:                                      # in-kernel counter RNG
            noise = epilogues.fused_noise(
                seed_ref, pl.program_id(0) * x.shape[0], margin.shape,
                epilogue)
        else:                                        # pre-drawn operands
            noise = tuple(r[...].astype(jnp.float32) for r in noise_refs)
        aug, weight, coef = epilogues.apply_epilogue(
            epilogue, margin, rho, beta, noise, eps, eps_ins)
        for ref, a in zip(aug_refs, aug):
            ref[...] = a

        @pl.when(pl.program_id(0) == 0)
        def _init():
            b_ref[...] = jnp.zeros_like(b_ref)
            s_ref[...] = jnp.zeros_like(s_ref)

        b_ref[...] += jax.lax.dot_general(           # x^T coef: (K, C)
            x, coef, dimension_numbers=(((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        if windowed:                                 # aligned column window
            xc = jax.lax.dynamic_slice(
                x, (0, c0_ref[0]), (x.shape[0], s_ref.shape[1]))
        else:
            xc = x
        if n_chains == 1:
            xw = x * (wmask * weight)                # (bn, K) weighted rows
            s_ref[...] += jax.lax.dot_general(       # x^T diag(m*w) x[:, w]
                xw, xc, dimension_numbers=(((0,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
        else:
            # One Sigma block per chain, laid side by side in a 2-D
            # (Kp, C*Kp) accumulator: static per-chain column slices
            # keep every block 128-lane aligned without a 3-D BlockSpec.
            # The X tile is loaded ONCE; only the rank-bn updates (pure
            # MXU work) scale with C — that is the nearly-free-chains
            # claim.
            cw = s_ref.shape[1] // n_chains
            for c in range(n_chains):
                xw = x * (wmask * weight[:, c:c + 1])
                s_ref[:, c * cw:(c + 1) * cw] += jax.lax.dot_general(
                    xw, xc, dimension_numbers=(((0,), (0,)), ((), ())),
                    preferred_element_type=jnp.float32)
    return _kernel


def col_window_geometry(Kp: int, col_blk: int) -> int:
    """Width of the ALIGNED in-kernel column window: the requested blk
    rounded to lanes plus one extra lane-tile of slack so any unaligned
    traced start lands inside a 128-aligned slice, capped at the padded
    width (then the 'window' is just the full accumulator)."""
    return min(Kp, _round_up(col_blk, 128) + 128)


def aligned_window_base(col_start, Kp: int, Cw: int):
    """(a0, off): 128-aligned traced base covering [start, start+blk)
    within [0, Kp - Cw], and the offset of ``col_start`` inside it."""
    c0 = jnp.asarray(col_start, jnp.int32)
    a0 = jnp.clip((c0 // 128) * 128, 0, Kp - Cw)
    return a0, c0 - a0


@functools.partial(jax.jit,
                   static_argnames=("epilogue", "eps", "eps_ins",
                                    "block_n", "col_blk", "interpret"))
def fused_stats(X: jnp.ndarray, rho: jnp.ndarray, beta: jnp.ndarray,
                wvec: jnp.ndarray, wmask: jnp.ndarray | None = None,
                noise: tuple | None = None,
                col_start: jnp.ndarray | int | None = None,
                seed: jnp.ndarray | None = None, *,
                epilogue: str = "em_hinge", eps: float = 1e-6,
                eps_ins: float = 0.0, block_n: int = 512,
                col_blk: int | None = None,
                interpret: bool = False):
    """Returns (margin (N,), *aug (N,) each, b (K,), S), all f32 — aug
    is (gamma,) for the hinge epilogues, (gamma, omega) for SVR. S is
    (K, K), or the (K, col_blk) column block S[:, start:start+blk]
    when a ``(col_start, col_blk)`` window is given (module docstring:
    static blk shapes the accumulator, traced start rides in SMEM).

    X: (N, K); rho/beta/wmask: (N,); wvec: (K,); noise: ``noise_arity``
    pre-drawn (N,) arrays for the MC epilogues (see ``epilogues.py``).
    ``seed`` (a (4,) uint32 [k0, k1, row0, chain0] from
    ``rng.pack_seed``) switches the MC epilogues to the IN-KERNEL
    counter RNG: no noise operands enter the kernel at all, the (nu, u)
    streams are derived per (global row, chain) inside the body and are
    bitwise equal to ``rng.draw_fused_noise`` — so the whole draw is
    chunk/shard/mesh-invariant with ZERO extra HBM traffic.

    A 2-D ``wvec`` of shape (K, C) runs C Gibbs chains over the single
    X stream (requires ``seed``; incompatible with a column window):
    margin/aug become (N, C), b becomes (K, C) and S becomes (C, K, K)
    — the X tile is read once and only MXU work scales with C.
    Zero-padded rows carry rho = beta = 0 so the hinge coef is exactly
    0, and their X-row is 0 so the b/S contributions vanish regardless
    of the augmentation values (SVR's MC coef is nonzero on padded rows
    — the zero X-row alone makes it a no-op).
    """
    N, K = X.shape
    multi = wvec.ndim == 2
    C = wvec.shape[1] if multi else 1
    windowed = col_blk is not None
    assert windowed == (col_start is not None), (
        "col_start and col_blk must be given together")
    rng = seed is not None
    n_aug = epilogues.aug_arity(epilogue)
    noise = tuple(noise) if noise is not None else ()
    if rng:
        assert not noise, (
            "seed (in-kernel RNG) and pre-drawn noise operands are "
            "mutually exclusive")
        n_noise = 0
    else:
        n_noise = epilogues.noise_arity(epilogue)
        assert len(noise) == n_noise, (
            f"epilogue {epilogue!r} needs {n_noise} noise operands, "
            f"got {len(noise)}")
    assert not (multi and windowed), (
        "multichain fused_stats does not compose with a column window")
    assert not multi or rng, (
        "multichain fused_stats requires the in-kernel RNG seed")
    if wmask is None:
        wmask = jnp.ones((N,), jnp.float32)
    bn = min(block_n, _round_up(N, 8))
    Kp = _round_up(K, 128)
    Np = _round_up(N, bn)
    if (Np, Kp) != (N, K):
        X = jnp.pad(X, ((0, Np - N), (0, Kp - K)))
        rho = jnp.pad(rho, (0, Np - N))
        beta = jnp.pad(beta, (0, Np - N))
        wmask = jnp.pad(wmask, (0, Np - N))
        wvec = (jnp.pad(wvec, ((0, Kp - K), (0, 0))) if multi
                else jnp.pad(wvec, (0, Kp - K)))
        noise = tuple(jnp.pad(z, (0, Np - N)) for z in noise)

    extra_specs: list = []
    extra_ops: tuple = ()
    if rng:
        extra_specs.append(pl.BlockSpec(memory_space=pltpu.SMEM))
        extra_ops += (seed,)
    if windowed:
        Sw = col_window_geometry(Kp, col_blk)
        a0, off = aligned_window_base(col_start, Kp, Sw)
        extra_specs.append(pl.BlockSpec(memory_space=pltpu.SMEM))
        extra_ops += (a0.reshape(1),)
    else:
        Sw = Kp

    grid = (Np // bn,)
    row_spec = pl.BlockSpec((bn, 1), lambda n: (n, 0))
    chn_spec = pl.BlockSpec((bn, C), lambda n: (n, 0))
    outs = pl.pallas_call(
        _make_kernel(epilogue, float(eps), float(eps_ins), n_noise,
                     n_aug, windowed, rng, C),
        grid=grid,
        in_specs=extra_specs + [                        # [seed] [base]
            pl.BlockSpec((bn, Kp), lambda n: (n, 0)),   # X rows
            row_spec,                                   # rho
            row_spec,                                   # beta
            row_spec,                                   # Sigma weight mask
            pl.BlockSpec((Kp, C), lambda n: (0, 0)),    # w (replicated)
        ] + [row_spec] * n_noise,                       # pre-drawn noise
        out_specs=[chn_spec]                            # margin
        + [chn_spec] * n_aug                            # gamma (, omega)
        + [
            pl.BlockSpec((Kp, C), lambda n: (0, 0)),    # b (revisited)
            pl.BlockSpec((Kp, C * Sw), lambda n: (0, 0)),  # S (revisited)
        ],
        out_shape=[jax.ShapeDtypeStruct((Np, C), jnp.float32)]
        * (1 + n_aug)
        + [
            jax.ShapeDtypeStruct((Kp, C), jnp.float32),
            jax.ShapeDtypeStruct((Kp, C * Sw), jnp.float32),
        ],
        interpret=interpret,
    )(*extra_ops, X, rho.reshape(Np, 1), beta.reshape(Np, 1),
      wmask.reshape(Np, 1),
      wvec.reshape(Kp, C),
      *(z.reshape(Np, 1) for z in noise))
    per_row, (b, S) = outs[:1 + n_aug], outs[-2:]
    if windowed:
        S = jax.lax.dynamic_slice(S[:K], (jnp.int32(0), off),
                                  (K, col_blk))
    elif multi:
        S = jnp.stack([S[:K, c * Kp:c * Kp + K] for c in range(C)])
    else:
        S = S[:K, :K]
    if multi:
        return (*(v[:N] for v in per_row), b[:K], S)
    return (*(v[:N, 0] for v in per_row), b[:K, 0], S)


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m
