"""Pallas TPU kernels for the paper's compute hot-spots.

The paper (Sec 5.14) offloads the rate-limiting statistic
Sigma_d (1/gamma_d) x_d x_d^T to a GPU kernel; this package is the
TPU-native counterpart (see DESIGN.md §3):

  * weighted_gram — X^T diag(w) X, MXU-tiled weighted SYRK.
  * fused_estep   — margin -> gamma -> mu-numerator in one HBM pass.
  * rbf_gram      — tiled RBF Gram blocks for the KRN formulation.

``ops`` holds the backend-dispatching public wrappers; ``ref`` the pure-jnp
oracles used as ground truth and as the CPU path.
"""
from . import ops, ref  # noqa: F401
from .ops import fused_estep, rbf_gram, weighted_gram  # noqa: F401
