"""Pallas TPU kernels for the paper's compute hot-spots.

The paper (Sec 5.14) offloads the rate-limiting statistic
Sigma_d (1/gamma_d) x_d x_d^T to a GPU kernel; this package is the
TPU-native counterpart (see DESIGN.md §3):

  * weighted_gram — X^T diag(w) X, MXU-tiled weighted SYRK (dense grid).
  * syrk_tri      — same statistic over only the lower-triangle block
                    pairs (~2x fewer FLOPs; DESIGN.md §Perf).
  * fused_estep   — margin -> gamma -> mu-numerator in one HBM pass.
  * fused_stats   — the WHOLE iteration statistic (margin, aug, b,
                    Sigma) in a single X pass (one HBM stream/iter),
                    parameterized by an augmentation epilogue
                    (``epilogues``: EM/MC hinge, SVR double mixture —
                    MC noise pre-drawn, transform applied in-kernel).
  * rbf_gram      — tiled RBF Gram blocks for the KRN formulation.
  * nystrom_phi / nystrom_fused_stats — Nystrom featurization fused
                    with the iteration statistic: the phi tile lives
                    only in VMEM (nonlinear path, DESIGN.md §Perf).

``ops`` holds the backend-dispatching public wrappers; ``ref`` the pure-jnp
oracles used as ground truth and as the CPU path.
"""
from . import epilogues, ops, ref  # noqa: F401
from .ops import (fused_estep, fused_stats, nystrom_fused_stats,  # noqa: F401
                  nystrom_phi, rbf_gram, syrk_tri, weighted_gram)
