"""Pallas TPU kernel: fused E-step for the generic augmented hinge.

For the hinge family max(0, beta_d * (rho_d - w^T x_d)) (binary CLS is
rho = beta = y; Crammer-Singer per-class updates supply their own rho/beta,
paper Eq. 34-39) this computes in ONE pass over X:

    margin_d = w^T x_d
    gamma_d  = max(eps, |rho_d - margin_d|)     # EM update, paper Eq. 9/36
    b        = sum_d (rho_d / gamma_d + beta_d) x_d   # mu numerator, Eq. 6/39

and also emits the margins themselves, which the driver needs every
iteration for the paper's objective-change stopping rule (Sec 5.5).

The paper's implementation makes separate passes for gamma, for the mu
statistic and for the objective (its GPU path only offloads Sigma); fusing
means X moves HBM->VMEM once instead of three times — a memory-hierarchy
optimization specific to this port (DESIGN.md §3). Grid is 1-D over
N-blocks; each step holds a (bn, K) X tile plus the full (K, 1) weight
vector in VMEM, emits the margin and gamma blocks, and accumulates b into a
revisited (K, 1) fp32 output tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _make_kernel(eps: float):
    def _kernel(x_ref, rho_ref, beta_ref, w_ref, margin_ref, gamma_ref, b_ref):
        x = x_ref[...].astype(jnp.float32)          # (bn, K)
        wv = w_ref[...].astype(jnp.float32)         # (K, 1)
        rho = rho_ref[...].astype(jnp.float32)      # (bn, 1)
        beta = beta_ref[...].astype(jnp.float32)    # (bn, 1)

        margin = jax.lax.dot_general(                # (bn, 1) on the MXU
            x, wv, dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        margin_ref[...] = margin
        gamma = jnp.maximum(jnp.abs(rho - margin), eps)
        gamma_ref[...] = gamma
        coef = rho / gamma + beta                    # (bn, 1)

        @pl.when(pl.program_id(0) == 0)
        def _init():
            b_ref[...] = jnp.zeros_like(b_ref)

        b_ref[...] += jax.lax.dot_general(           # x^T coef: (K, 1)
            x, coef, dimension_numbers=(((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
    return _kernel


@functools.partial(jax.jit,
                   static_argnames=("eps", "block_n", "interpret"))
def fused_estep(X: jnp.ndarray, rho: jnp.ndarray, beta: jnp.ndarray,
                wvec: jnp.ndarray, *, eps: float = 1e-6,
                block_n: int = 1024,
                interpret: bool = False
                ) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Returns (margin (N,), gamma (N,), b (K,)), all f32.

    X: (N, K); rho/beta: (N,); wvec: (K,). Zero-padded rows are given
    rho=0, beta=0 so their coef is 0/gamma + 0 = 0 exactly (gamma clamps to
    eps > 0), contributing nothing to b.
    """
    N, K = X.shape
    bn = min(block_n, _round_up(N, 8))
    Kp = _round_up(K, 128)
    Np = _round_up(N, bn)
    if (Np, Kp) != (N, K):
        X = jnp.pad(X, ((0, Np - N), (0, Kp - K)))
        rho = jnp.pad(rho, (0, Np - N))
        beta = jnp.pad(beta, (0, Np - N))
        wvec = jnp.pad(wvec, (0, Kp - K))

    grid = (Np // bn,)
    margin, gamma, b = pl.pallas_call(
        _make_kernel(float(eps)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, Kp), lambda n: (n, 0)),   # X rows
            pl.BlockSpec((bn, 1), lambda n: (n, 0)),    # rho
            pl.BlockSpec((bn, 1), lambda n: (n, 0)),    # beta
            pl.BlockSpec((Kp, 1), lambda n: (0, 0)),    # w (replicated)
        ],
        out_specs=[
            pl.BlockSpec((bn, 1), lambda n: (n, 0)),    # margin
            pl.BlockSpec((bn, 1), lambda n: (n, 0)),    # gamma
            pl.BlockSpec((Kp, 1), lambda n: (0, 0)),    # b (revisited)
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Np, 1), jnp.float32),
            jax.ShapeDtypeStruct((Np, 1), jnp.float32),
            jax.ShapeDtypeStruct((Kp, 1), jnp.float32),
        ],
        interpret=interpret,
    )(X, rho.reshape(Np, 1), beta.reshape(Np, 1), wvec.reshape(Kp, 1))
    return margin[:N, 0], gamma[:N, 0], b[:K, 0]


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m
