"""Pallas TPU kernel: tiled RBF Gram blocks for the KRN formulation.

    K_ij = exp(-||x_i - x_j||^2 / (2 sigma^2))        (paper Sec 3.1)

||x_i - x_j||^2 is expanded as sq_i - 2 x_i.x_j + sq_j so the inner product
runs on the MXU; the squared norms are computed inside the tile (recomputing
them per tile is cheaper than an extra HBM stream at these shapes). Grid is
(N1/b1, N2/b2); each step holds one (b1, K) and one (b2, K) strip in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def rbf_tile(x1: jnp.ndarray, x2: jnp.ndarray,
             inv_two_sigma_sq: float) -> jnp.ndarray:
    """The RBF Gram tile body: K_ij = exp(-||x1_i - x2_j||^2 / 2 sigma^2)
    for one (b1, K) x (b2, K) VMEM tile pair, inner product on the MXU.

    Shared by ``rbf_gram`` and the fused Nystrom featurize kernel
    (``nystrom_phi.py``), so the two paths cannot drift numerically.
    """
    sq1 = jnp.sum(x1 * x1, axis=1, keepdims=True)          # (b1, 1)
    sq2 = jnp.sum(x2 * x2, axis=1, keepdims=True)          # (b2, 1)
    cross = jax.lax.dot_general(                            # (b1, b2)
        x1, x2, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    d2 = jnp.maximum(sq1 - 2.0 * cross + sq2.T, 0.0)
    return jnp.exp(-d2 * inv_two_sigma_sq)


def _make_kernel(inv_two_sigma_sq: float):
    def _kernel(x1_ref, x2_ref, out_ref):
        x1 = x1_ref[...].astype(jnp.float32)      # (b1, K)
        x2 = x2_ref[...].astype(jnp.float32)      # (b2, K)
        out_ref[...] = rbf_tile(x1, x2, inv_two_sigma_sq)
    return _kernel


@functools.partial(jax.jit,
                   static_argnames=("sigma", "block_n", "interpret"))
def rbf_gram(X1: jnp.ndarray, X2: jnp.ndarray, *, sigma: float = 1.0,
             block_n: int = 256, interpret: bool = False) -> jnp.ndarray:
    """RBF Gram matrix (N1, N2) f32 via Pallas tiles.

    Padding note: padded rows produce garbage Gram entries (exp of a real
    number, not 0) in the padded region only; they are sliced off before
    return, so callers always see exact values.
    """
    N1, K = X1.shape
    N2, K2 = X2.shape
    assert K == K2, (K, K2)
    b1 = min(block_n, _round_up(N1, 8))
    b2 = min(block_n, _round_up(N2, 128))
    Kp = _round_up(K, 128)
    N1p, N2p = _round_up(N1, b1), _round_up(N2, b2)
    if (N1p, Kp) != (N1, K):
        X1 = jnp.pad(X1, ((0, N1p - N1), (0, Kp - K)))
    if (N2p, Kp) != (N2, K):
        X2 = jnp.pad(X2, ((0, N2p - N2), (0, Kp - K)))

    out = pl.pallas_call(
        _make_kernel(1.0 / (2.0 * float(sigma) ** 2)),
        grid=(N1p // b1, N2p // b2),
        in_specs=[
            pl.BlockSpec((b1, Kp), lambda i, j: (i, 0)),
            pl.BlockSpec((b2, Kp), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((b1, b2), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((N1p, N2p), jnp.float32),
        interpret=interpret,
    )(X1, X2)
    return out[:N1, :N2]


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m
