"""Triangle-blocked weighted SYRK: S = X^T diag(w) X touching only the
lower-triangle block pairs.

The paper notes (Sec 4.1) that Sigma is symmetric so "it suffices to
compute only the upper or lower triangle". ``weighted_gram`` exploits that
on the wire (triangle-packed psum) but still runs the full (K/bk)^2 block
grid — 2x the necessary FLOPs on the rate-limiting statistic. Here the
grid enumerates only the T = nb(nb+1)/2 block pairs with bk-row-index
i >= j, flattened to a 1-D triangular index t:

    i(t) = floor((sqrt(8t + 1) - 1) / 2),   j(t) = t - i(i+1)/2

``tri_ij`` computes that mapping in pure integer-exact arithmetic (fp32
sqrt seed + two integer corrections). The kernel itself consumes it as a
precomputed (T, 2) lookup table through ``PrefetchScalarGridSpec`` — the
TPU idiom for data-dependent block grids: the table is prefetched to
SMEM and each BlockSpec index map is a single scalar gather. (The
arithmetic-in-index-map variant recomputes ~a dozen scalar ops per spec
per grid step, which measurably erodes the FLOP win — the scalar stream
runs ahead of the MXU and any extra latency there stalls DMA issue; in
interpret mode it actually made the kernel *slower* than dense.)

Grid is (T, N/bn) with the N dimension innermost so the (bk, bk) fp32
output tile stays VMEM-resident across the N sweep, exactly like the
dense kernel (DESIGN.md §Perf).

The kernel writes only lower-triangle blocks; the full matrix is rebuilt
afterwards with a block-level where/transpose mirror (diagonal blocks are
computed in full, so the element-level upper triangle inside them is
already correct).

VMEM per step = 2*bn*bk (input tiles) + bn (weights) + bk*bk*4B
(accumulator); defaults (bn=512, bk=256) stay well under ~4 MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _tri(i):
    return i * (i + 1) // 2


def tri_ij(t):
    """Flattened lower-triangle index t -> block pair (i, j), i >= j.

    Integer-exact for any practical grid (fp32 sqrt seed, then two
    exact integer corrections). Used to *derive* the lookup table and
    by tests; the kernel reads the table via scalar prefetch."""
    tf = t.astype(jnp.float32) if hasattr(t, "astype") else jnp.float32(t)
    i = ((jnp.sqrt(8.0 * tf + 1.0) - 1.0) * 0.5).astype(jnp.int32)
    i = jnp.where(_tri(i) > t, i - 1, i)
    i = jnp.where(_tri(i + 1) <= t, i + 1, i)
    return i, t - _tri(i)


def _kernel(ij_ref, x_lhs_ref, w_ref, x_rhs_ref, out_ref):
    del ij_ref  # consumed by the index maps only
    @pl.when(pl.program_id(1) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    xl = x_lhs_ref[...].astype(jnp.float32) * w_ref[...].astype(jnp.float32)
    xr = x_rhs_ref[...].astype(jnp.float32)
    # (bk, bn) @ (bn, bk) on the MXU, fp32 accumulation.
    out_ref[...] += jax.lax.dot_general(
        xl, xr, dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("block_n", "block_k",
                                             "interpret"))
def syrk_tri(X: jnp.ndarray, w: jnp.ndarray, *,
             block_n: int = 512, block_k: int = 256,
             interpret: bool = False) -> jnp.ndarray:
    """S = X^T diag(w) X via the triangle-blocked Pallas SYRK.

    X: (N, K); w: (N,). Returns the full symmetric (K, K) f32 matrix
    (mirrored from the computed lower block triangle). Inputs are
    zero-padded to block multiples; zero-weight rows are exact no-ops.
    """
    N, K = X.shape
    bn = min(block_n, _round_up(N, 8))
    bk = min(block_k, _round_up(K, 128))
    Np, Kp = _round_up(N, bn), _round_up(K, bk)
    if (Np, Kp) != (N, K):
        X = jnp.pad(X, ((0, Np - N), (0, Kp - K)))
        w = jnp.pad(w, (0, Np - N))
    w2 = w.reshape(Np, 1)

    nb = Kp // bk
    ii, jj = np.tril_indices(nb)            # == tri_ij(arange(T)), exact
    ij = jnp.asarray(np.stack([ii, jj], axis=1).astype(np.int32))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,              # the (T, 2) block-pair table
        grid=(_tri(nb), Np // bn),
        in_specs=[
            pl.BlockSpec((bn, bk), lambda t, n, ij: (n, ij[t, 0])),  # lhs
            pl.BlockSpec((bn, 1), lambda t, n, ij: (n, 0)),          # w
            pl.BlockSpec((bn, bk), lambda t, n, ij: (n, ij[t, 1])),  # rhs
        ],
        out_specs=pl.BlockSpec((bk, bk),
                               lambda t, n, ij: (ij[t, 0], ij[t, 1])),
    )
    out = pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((Kp, Kp), jnp.float32),
        interpret=interpret,
    )(ij, X, w2, X)
    # Mirror: upper-triangle blocks come from the transposed lower
    # blocks; diagonal blocks were computed in full and pass through.
    bi = jnp.arange(Kp) // bk
    lower = bi[:, None] >= bi[None, :]
    S = jnp.where(lower, out, out.T)
    return S[:K, :K]


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m
