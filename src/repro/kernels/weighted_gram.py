"""Pallas TPU kernel for the paper's rate-limiting statistic.

    S = X^T diag(w) X  =  sum_d w_d x_d x_d^T          (paper Sec 5.14, Table 9)

The paper computes this with an OpenCL kernel that partitions data rows
across GPU compute-unit local memories and reduces through global memory.
TPU adaptation (DESIGN.md §3): re-express as a weighted SYRK and tile for
the MXU. Grid is (K/bk1, K/bk2, N/bn) with the N dimension innermost so the
(bk1, bk2) fp32 output tile stays resident in VMEM and is accumulated across
N-steps — replacing the GPU's two-pass global-memory reduction with a
single-pass revisited-output accumulation.

Block sizes default to MXU/VPU-aligned multiples of (8, 128). VMEM use per
step = bn*bk1 + bn*bk2 (inputs, input dtype) + bk1*bk2 (fp32 accumulator);
defaults (bn=512, bk=256) stay well under ~4 MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_lhs_ref, w_ref, x_rhs_ref, out_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    xl = x_lhs_ref[...].astype(jnp.float32) * w_ref[...].astype(jnp.float32)
    xr = x_rhs_ref[...].astype(jnp.float32)
    # (bk1, bn) @ (bn, bk2) on the MXU, fp32 accumulation.
    out_ref[...] += jax.lax.dot_general(
        xl, xr, dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("block_n", "block_k", "interpret"))
def weighted_gram(X: jnp.ndarray, w: jnp.ndarray, *,
                  block_n: int = 512, block_k: int = 256,
                  interpret: bool = False) -> jnp.ndarray:
    """S = X^T diag(w) X via Pallas. X: (N, K); w: (N,). Returns (K, K) f32.

    Inputs are zero-padded to block multiples (zero weight rows are exact
    no-ops for the sum) and the result is sliced back.
    """
    N, K = X.shape
    bn = min(block_n, _round_up(N, 8))
    bk = min(block_k, _round_up(K, 128))
    Np, Kp = _round_up(N, bn), _round_up(K, bk)
    if (Np, Kp) != (N, K):
        X = jnp.pad(X, ((0, Np - N), (0, Kp - K)))
        w = jnp.pad(w, (0, Np - N))
    w2 = w.reshape(Np, 1)

    grid = (Kp // bk, Kp // bk, Np // bn)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bk), lambda i, j, n: (n, i)),   # X tile for lhs
            pl.BlockSpec((bn, 1), lambda i, j, n: (n, 0)),    # weights
            pl.BlockSpec((bn, bk), lambda i, j, n: (n, j)),   # X tile for rhs
        ],
        out_specs=pl.BlockSpec((bk, bk), lambda i, j, n: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Kp, Kp), jnp.float32),
        interpret=interpret,
    )(X, w2, X)
    return out[:K, :K]


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m
