"""Pure-jnp reference oracles for every Pallas kernel in this package.

These are the ground truth used by tests (assert_allclose vs interpret-mode
Pallas) and the default CPU execution path of ``ops.py``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import epilogues, rng


def seed_noise(seed, n: int, n_chains: int, epilogue: str):
    """Materialize the in-kernel counter stream for ``n`` rows.

    ``seed`` is the (4,) uint32 [k0, k1, row0, chain0] operand
    (``rng.pack_seed``).  Returns the epilogue's noise tuple with (n,)
    arrays for a single chain, (n, n_chains) for a multichain call —
    bitwise identical to the values the fused kernels derive in-body,
    because both sides run the same elementwise ``rng`` code.
    """
    rows = seed[2].astype(jnp.int32) + jnp.arange(n, dtype=jnp.int32)
    chains = seed[3].astype(jnp.int32)
    if n_chains > 1:
        rows = rows[:, None]
        chains = chains + jnp.arange(n_chains, dtype=jnp.int32)[None, :]
    return rng.counter_noise(seed[0], seed[1], rows, chains,
                             epilogues.noise_arity(epilogue))


def weighted_gram(X: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """S = X^T diag(w) X  == sum_d w_d x_d x_d^T.

    The paper's rate-limiting statistic (its Table-9 GPU kernel).

    Args:
      X: (N, K) design matrix.
      w: (N,) per-datum weights (1/gamma_d in the paper).

    Returns:
      (K, K) float32 matrix.
    """
    Xf = X.astype(jnp.float32)
    wf = w.astype(jnp.float32)
    return (Xf * wf[:, None]).T @ Xf


def fused_estep(X: jnp.ndarray, rho: jnp.ndarray, beta: jnp.ndarray,
                wvec: jnp.ndarray, eps: float
                ) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Fused E-step for the generic hinge max(0, beta*(rho - w^T x)).

    Computes, in one logical pass over X:
      margin_d = w^T x_d
      gamma_d  = max(eps, |rho_d - margin_d|)          (paper Eq. 9 / 36 + 5.7.3 clamp)
      b        = sum_d (rho_d/gamma_d + beta_d) x_d    (paper Eq. 6 / 39 numerator)

    Binary CLS is the special case rho = beta = y in {+1,-1}:
      gamma = |1 - y w^T x|, b = sum y(1+1/gamma) x.

    Returns:
      (margin (N,), gamma (N,), b (K,)), all float32.
    """
    Xf = X.astype(jnp.float32)
    wf = wvec.astype(jnp.float32)
    margin = Xf @ wf
    gamma = jnp.maximum(jnp.abs(rho.astype(jnp.float32) - margin), eps)
    coef = rho.astype(jnp.float32) / gamma + beta.astype(jnp.float32)
    b = Xf.T @ coef
    return margin, gamma, b


def syrk_tri(X: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Oracle for the triangle-blocked SYRK — identical mathematical
    content to ``weighted_gram``; the Pallas flavor merely skips the
    redundant upper-triangle block computations."""
    return weighted_gram(X, w)


def fused_stats(X: jnp.ndarray, rho: jnp.ndarray, beta: jnp.ndarray,
                wvec: jnp.ndarray, wmask: jnp.ndarray | None,
                eps: float, epilogue: str = "em_hinge",
                noise: tuple | None = None, eps_ins: float = 0.0,
                col_window: tuple | None = None,
                seed: jnp.ndarray | None = None):
    """One-sweep iteration statistic under any augmentation epilogue:
    margin -> (aug, sigma_weight, coef) -> (b, Sigma) in one logical
    pass (``kernels/epilogues.py`` holds the epilogue family; MC
    flavors consume pre-drawn per-row ``noise``).

    S = X^T diag(wmask * sigma_weight) X with the weights from THIS
    sweep's epilogue; wmask defaults to ones (the KRN path passes its
    row mask, the phi-space paths their row-validity mask).

    ``col_window = (start, blk)`` narrows Sigma to its column block
    X^T diag(w) X[:, start:start+blk] — the 2-D (data x model)
    ``k_shard_axis`` statistic. ``start`` may be TRACED (it is
    ``axis_index * blk`` inside shard_map); ``blk`` is static.

    ``seed`` (the (4,) uint32 [k0, k1, row0, chain0] from
    ``rng.pack_seed``) replaces pre-drawn ``noise`` with the counter
    stream (rng mode 'fused'); a 2-D (K, C) ``wvec`` then runs C chains
    at once — margin/aug become (N, C), b (K, C) and S (C, K, K).

    Returns:
      (margin (N,), *aug (N,) each, b (K,), S), all float32 — aug =
      (gamma,) for the hinge epilogues, (gamma, omega) for SVR; S is
      (K, K) full or (K, blk) windowed.
    """
    Xf = X.astype(jnp.float32)
    if wvec.ndim == 2:
        assert seed is not None, "multichain fused_stats requires seed"
        assert col_window is None, (
            "multichain fused_stats does not compose with a column "
            "window")
        C = wvec.shape[1]
        margin = Xf @ wvec.astype(jnp.float32)            # (N, C)
        noise = seed_noise(seed, X.shape[0], C, epilogue)
        aug, weight, coef = epilogues.apply_epilogue(
            epilogue, margin, rho.astype(jnp.float32)[:, None],
            beta.astype(jnp.float32)[:, None], noise, eps, eps_ins)
        w = (weight if wmask is None
             else wmask.astype(jnp.float32)[:, None] * weight)
        b = Xf.T @ coef                                   # (K, C)
        S = jnp.stack([(Xf * w[:, c:c + 1]).T @ Xf for c in range(C)])
        return (margin, *aug, b, S)
    if seed is not None:
        noise = seed_noise(seed, X.shape[0], 1, epilogue)
    margin = Xf @ wvec.astype(jnp.float32)
    aug, weight, coef = epilogues.apply_epilogue(
        epilogue, margin, rho.astype(jnp.float32),
        beta.astype(jnp.float32), noise, eps, eps_ins)
    w = weight if wmask is None else wmask.astype(jnp.float32) * weight
    b = Xf.T @ coef
    if col_window is None:
        return (margin, *aug, b, weighted_gram(X, w))
    start, blk = col_window
    Xc = jax.lax.dynamic_slice_in_dim(Xf, jnp.asarray(start, jnp.int32),
                                      blk, axis=1)
    return (margin, *aug, b, (Xf * w[:, None]).T @ Xc)


def nystrom_phi(X: jnp.ndarray, landmarks: jnp.ndarray, proj: jnp.ndarray,
                mask: jnp.ndarray | None, sigma: float, kind: str,
                add_bias: bool) -> jnp.ndarray:
    """Oracle for the fused Nystrom featurizer (nystrom_phi.py).

    phi = k(X, landmarks) @ proj, rows zeroed by ``mask``, with an
    optional mask-valued bias column appended (M = proj cols + bias).
    A zero X row is NOT a zero phi row under rbf, so the mask is load-
    bearing here — unlike the LIN kernels' zero-row convention.
    """
    Xf = X.astype(jnp.float32)
    if kind == "rbf":
        kmat = rbf_gram(Xf, landmarks, sigma)
    elif kind == "linear":
        kmat = Xf @ landmarks.astype(jnp.float32).T
    else:
        raise ValueError(f"unknown kernel kind {kind!r}")
    phi = kmat @ proj.astype(jnp.float32)
    maskv = (jnp.ones((X.shape[0], 1), jnp.float32) if mask is None
             else mask.astype(jnp.float32)[:, None])
    if add_bias:
        phi = jnp.concatenate([phi, jnp.ones_like(maskv)], axis=1)
    return phi * maskv


def nystrom_score(X: jnp.ndarray, landmarks: jnp.ndarray,
                  proj: jnp.ndarray, W: jnp.ndarray,
                  mask: jnp.ndarray | None, sigma: float, kind: str,
                  add_bias: bool) -> jnp.ndarray:
    """Oracle for the fused scoring epilogue (serving): (N, C) f32
    scores = nystrom_phi(X, ...) @ W — C score columns per row (one per
    tenant/class/uncertainty direction). Masked rows score 0."""
    phi = nystrom_phi(X, landmarks, proj, mask, sigma, kind, add_bias)
    return phi @ W.astype(jnp.float32)


def nystrom_fused_stats(X: jnp.ndarray, landmarks: jnp.ndarray,
                        proj: jnp.ndarray, rho: jnp.ndarray,
                        beta: jnp.ndarray, wvec: jnp.ndarray,
                        mask: jnp.ndarray | None, sigma: float, kind: str,
                        add_bias: bool, eps: float,
                        epilogue: str = "em_hinge",
                        noise: tuple | None = None, eps_ins: float = 0.0,
                        col_window: tuple | None = None,
                        seed: jnp.ndarray | None = None):
    """Oracle for the featurize-and-accumulate kernel: fused_stats on
    nystrom_phi, i.e. the whole phi-space iteration statistic under any
    augmentation epilogue (EM/MC hinge, SVR's double mixture).
    ``col_window`` narrows Sigma to a PHI-column block (the
    ``k_shard_axis`` composition; see ``fused_stats``).

    Returns (margin (N,), *aug (N,) each, b (M,), S (M, M) or
    (M, blk)), all f32.
    """
    phi = nystrom_phi(X, landmarks, proj, mask, sigma, kind, add_bias)
    return fused_stats(phi, rho, beta, wvec, mask, eps,
                       epilogue=epilogue, noise=noise, eps_ins=eps_ins,
                       col_window=col_window, seed=seed)


def rbf_gram(X1: jnp.ndarray, X2: jnp.ndarray, sigma: float) -> jnp.ndarray:
    """RBF Gram block: K_ij = exp(-||x_i - x_j||^2 / (2 sigma^2)).

    Args:
      X1: (N1, K), X2: (N2, K).

    Returns:
      (N1, N2) float32.
    """
    X1f = X1.astype(jnp.float32)
    X2f = X2.astype(jnp.float32)
    sq1 = jnp.sum(X1f * X1f, axis=-1, keepdims=True)
    sq2 = jnp.sum(X2f * X2f, axis=-1, keepdims=True)
    d2 = sq1 - 2.0 * (X1f @ X2f.T) + sq2.T
    d2 = jnp.maximum(d2, 0.0)
    return jnp.exp(-d2 / (2.0 * sigma * sigma))
