"""Pallas TPU kernels: fused Nystrom featurize(-and-accumulate).

The Nystrom path (core/nystrom.py) turns the kernel SVM into the linear
PEMSVM on phi(x) = K_mm^{-1/2} k_m(x). Naively that is three passes with
two HBM round-trips of an (N, m) intermediate:

    K_nm = rbf(X, landmarks)      (N, m)  -> HBM
    phi  = K_nm @ proj            (N, m)  -> HBM
    stats = fused_stats(phi, ...)         <- HBM

Both kernels here keep phi tile-local in VMEM instead. Per (bn, D)
X block they compute the RBF cross-Gram against the (m, D) landmark
strip (the ``rbf_gram`` tile body, shared code), apply the precomputed
(m, m) ``K_mm^{-1/2}`` projection on the MXU, and then either

  * ``nystrom_phi``         — write the phi tile out (the device-side
    featurizer: prediction, and MLT's M-pass class sweep where one
    featurize serves all M statistics passes), or
  * ``nystrom_fused_stats`` — feed the phi tile straight into the
    one-sweep statistic (margin, aug, b, Sigma) of ``fused_stats``,
    under ANY augmentation epilogue (``epilogues.py``: EM/MC hinge,
    SVR's double mixture — MC noise is pre-drawn and streamed in as
    (N,) operands): X streams HBM->VMEM ONCE and phi NEVER exists as
    an (N, m) array, for EM and MC, CLS and SVR alike.

Layout conventions (match the solver's padding scheme):

  * ``mask`` zeroes phi rows explicitly — unlike LIN, a zero X row does
    NOT give a zero phi row (rbf k(0, l) = exp(-||l||^2/2 sigma^2)), so
    padded rows must be killed by the mask, not the data.
  * ``add_bias`` appends the phi-space bias as column m with value
    ``mask`` (1 for valid rows, 0 for padding) — the same
    bias-column-is-the-mask trick the stream driver uses for X.

VMEM per grid step (fp32, padded dims): the X tile bn*D, the landmark
strip m*D, the projection m*M, the cross tile bn*m, the phi tile bn*M,
and the (M, M) Sigma accumulator (M = m + add_bias). ``ops.py`` holds
the byte-budget check and falls back to featurize-then-accumulate
(``nystrom_phi`` + the K-tiled ``fused_stats``) when it does not fit —
see DESIGN.md §Perf/Nystrom for the accounting and the roofline
argument for why the fusion wins in the m <= sqrt(N) regime.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import epilogues
from .fused_stats import aligned_window_base, col_window_geometry
from .rbf_gram import rbf_tile


def _phi_tile(x, lm, pj, maskv, *, kind: str, inv_two_sigma_sq: float,
              bias_col: int | None):
    """One (bn, M) phi tile from a (bn, D) X tile, entirely in VMEM.

    x: (bn, Dp); lm: (Lp, Dp) landmark strip; pj: (Lp, Wp) projection
    (zero-padded rows/cols are exact no-ops); maskv: (bn, 1).
    ``bias_col`` (static) is the column index receiving the mask-valued
    bias, or None.
    """
    if kind == "rbf":
        kmat = rbf_tile(x, lm, inv_two_sigma_sq)            # (bn, Lp)
    elif kind == "linear":  # the cross-Gram IS the inner product
        kmat = jax.lax.dot_general(
            x, lm, dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
    else:  # match the ref oracle: never silently fall through
        raise ValueError(f"unknown kernel kind {kind!r}")
    phi = jax.lax.dot_general(                               # (bn, Wp)
        kmat, pj, dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    if bias_col is not None:
        cols = jax.lax.broadcasted_iota(jnp.int32, phi.shape, 1)
        phi = phi + jnp.where(cols == bias_col, 1.0, 0.0)
    return phi * maskv


def _make_phi_kernel(kind: str, inv_two_sigma_sq: float,
                     bias_col: int | None):
    def _kernel(x_ref, lm_ref, pj_ref, mask_ref, out_ref):
        out_ref[...] = _phi_tile(
            x_ref[...].astype(jnp.float32),
            lm_ref[...].astype(jnp.float32),
            pj_ref[...].astype(jnp.float32),
            mask_ref[...].astype(jnp.float32),
            kind=kind, inv_two_sigma_sq=inv_two_sigma_sq,
            bias_col=bias_col)
    return _kernel


def _make_score_kernel(kind: str, inv_two_sigma_sq: float,
                       bias_col: int | None):
    """The *scoring* epilogue (serving): phi tile -> margin columns.

    Instead of accumulating (b, Sigma) like the fit-time epilogues, the
    per-tile phi feeds one MXU matmul against the resident (Wp, Cp)
    weight block — C score columns per row (one per tenant/class/
    uncertainty direction) — and phi dies in VMEM. This is predict-time
    single-stream: X is read once and the only HBM write is the (bn, Cp)
    score tile."""
    def _kernel(x_ref, lm_ref, pj_ref, mask_ref, w_ref, out_ref):
        phi = _phi_tile(
            x_ref[...].astype(jnp.float32),
            lm_ref[...].astype(jnp.float32),
            pj_ref[...].astype(jnp.float32),
            mask_ref[...].astype(jnp.float32),
            kind=kind, inv_two_sigma_sq=inv_two_sigma_sq,
            bias_col=bias_col)
        out_ref[...] = jax.lax.dot_general(
            phi, w_ref[...].astype(jnp.float32),
            dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
    return _kernel


def _make_fused_kernel(kind: str, inv_two_sigma_sq: float,
                       bias_col: int | None, epilogue: str, eps: float,
                       eps_ins: float, n_noise: int, n_aug: int,
                       windowed: bool = False, rng: bool = False):
    def _kernel(*refs):
        if rng:
            seed_ref, refs = refs[0], refs[1:]
        if windowed:
            c0_ref, refs = refs[0], refs[1:]
        x_ref, lm_ref, pj_ref, mask_ref, rho_ref, beta_ref, w_ref = refs[:7]
        noise_refs = refs[7:7 + n_noise]
        outs = refs[7 + n_noise:]
        margin_ref, aug_refs = outs[0], outs[1:1 + n_aug]
        b_ref, s_ref = outs[-2], outs[-1]

        maskv = mask_ref[...].astype(jnp.float32)            # (bn, 1)
        phi = _phi_tile(
            x_ref[...].astype(jnp.float32),
            lm_ref[...].astype(jnp.float32),
            pj_ref[...].astype(jnp.float32),
            maskv, kind=kind, inv_two_sigma_sq=inv_two_sigma_sq,
            bias_col=bias_col)
        rho = rho_ref[...].astype(jnp.float32)               # (bn, 1)
        beta = beta_ref[...].astype(jnp.float32)             # (bn, 1)
        wv = w_ref[...].astype(jnp.float32)                  # (Wp, 1)

        # From here this is exactly fused_stats' tile body with X := phi.
        margin = jax.lax.dot_general(
            phi, wv, dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        margin_ref[...] = margin
        if rng:                                  # in-kernel counter RNG
            noise = epilogues.fused_noise(
                seed_ref, pl.program_id(0) * phi.shape[0], margin.shape,
                epilogue)
        else:                                    # pre-drawn operands
            noise = tuple(r[...].astype(jnp.float32) for r in noise_refs)
        aug, weight, coef = epilogues.apply_epilogue(
            epilogue, margin, rho, beta, noise, eps, eps_ins)
        for ref, a in zip(aug_refs, aug):
            ref[...] = a

        @pl.when(pl.program_id(0) == 0)
        def _init():
            b_ref[...] = jnp.zeros_like(b_ref)
            s_ref[...] = jnp.zeros_like(s_ref)

        b_ref[...] += jax.lax.dot_general(                   # phi^T coef
            phi, coef, dimension_numbers=(((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        pw = phi * (maskv * weight)                          # weighted rows
        if windowed:                    # aligned phi-column window, VMEM
            pc = jax.lax.dynamic_slice(
                phi, (0, c0_ref[0]), (phi.shape[0], s_ref.shape[1]))
        else:
            pc = phi
        s_ref[...] += jax.lax.dot_general(                   # phi^T D phi_w
            pw, pc, dimension_numbers=(((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
    return _kernel


def _pad_operands(X, landmarks, proj, mask, add_bias, bn):
    """Zero-pad every operand to tile multiples; returns the padded
    arrays plus (Np, Wp, M) where M = proj cols + add_bias."""
    N, D = X.shape
    m, P = proj.shape
    assert landmarks.shape == (m, D), (landmarks.shape, (m, D))
    M = P + int(add_bias)
    Dp = _round_up(D, 128)
    Lp = _round_up(m, 128)   # lane dim of the (bn, m) cross tile
    Wp = _round_up(max(M, 1), 128)
    Np = _round_up(N, bn)
    if mask is None:
        mask = jnp.ones((N,), jnp.float32)
    X = jnp.pad(X, ((0, Np - N), (0, Dp - D)))
    mask = jnp.pad(mask.astype(jnp.float32), (0, Np - N))
    landmarks = jnp.pad(landmarks, ((0, Lp - m), (0, Dp - D)))
    proj = jnp.pad(proj, ((0, Lp - m), (0, Wp - P)))
    return X, landmarks, proj, mask, Np, Wp, M


@functools.partial(jax.jit, static_argnames=("sigma", "kind", "add_bias",
                                             "block_n", "interpret"))
def nystrom_phi(X: jnp.ndarray, landmarks: jnp.ndarray, proj: jnp.ndarray,
                mask: jnp.ndarray | None = None, *, sigma: float = 1.0,
                kind: str = "rbf", add_bias: bool = False,
                block_n: int = 256, interpret: bool = False) -> jnp.ndarray:
    """phi = [rbf(X, landmarks) @ proj, bias] — (N, M) f32, M = m + bias.

    One X stream, no (N, m) cross-Gram intermediate. ``mask`` zeroes
    invalid rows (see module docstring); None means all rows valid.
    """
    N, D = X.shape
    bn = min(block_n, _round_up(N, 8))
    X, landmarks, proj, mask, Np, Wp, M = _pad_operands(
        X, landmarks, proj, mask, add_bias, bn)
    out = pl.pallas_call(
        _make_phi_kernel(kind, 1.0 / (2.0 * float(sigma) ** 2),
                         M - 1 if add_bias else None),
        grid=(Np // bn,),
        in_specs=[
            pl.BlockSpec((bn, X.shape[1]), lambda n: (n, 0)),
            pl.BlockSpec(landmarks.shape, lambda n: (0, 0)),
            pl.BlockSpec(proj.shape, lambda n: (0, 0)),
            pl.BlockSpec((bn, 1), lambda n: (n, 0)),
        ],
        out_specs=pl.BlockSpec((bn, Wp), lambda n: (n, 0)),
        out_shape=jax.ShapeDtypeStruct((Np, Wp), jnp.float32),
        interpret=interpret,
    )(X, landmarks, proj, mask.reshape(Np, 1))
    return out[:N, :M]


@functools.partial(jax.jit, static_argnames=("sigma", "kind", "add_bias",
                                             "block_n", "interpret"))
def nystrom_score(X: jnp.ndarray, landmarks: jnp.ndarray,
                  proj: jnp.ndarray, W: jnp.ndarray,
                  mask: jnp.ndarray | None = None, *, sigma: float = 1.0,
                  kind: str = "rbf", add_bias: bool = False,
                  block_n: int = 256,
                  interpret: bool = False) -> jnp.ndarray:
    """scores = nystrom_phi(X, ...) @ W — (N, C) f32, phi never in HBM.

    The predict-side counterpart of ``nystrom_fused_stats``: the same
    in-VMEM phi tile, but the epilogue is a matmul against a (M, C)
    multi-output weight block (C = tenants x classes x uncertainty
    directions) instead of the Sigma accumulation. Masked rows score 0
    in every column. One X stream; HBM traffic is X in + (N, C) out.
    """
    N, D = X.shape
    MW, C = W.shape
    bn = min(block_n, _round_up(N, 8))
    X, landmarks, proj, mask, Np, Wp, M = _pad_operands(
        X, landmarks, proj, mask, add_bias, bn)
    assert MW == M, (
        f"W rows ({MW}) must equal the phi width "
        f"(proj cols + add_bias = {M})")
    Cp = _round_up(C, 128)
    Wmat = jnp.pad(W.astype(jnp.float32), ((0, Wp - M), (0, Cp - C)))
    out = pl.pallas_call(
        _make_score_kernel(kind, 1.0 / (2.0 * float(sigma) ** 2),
                           M - 1 if add_bias else None),
        grid=(Np // bn,),
        in_specs=[
            pl.BlockSpec((bn, X.shape[1]), lambda n: (n, 0)),
            pl.BlockSpec(landmarks.shape, lambda n: (0, 0)),
            pl.BlockSpec(proj.shape, lambda n: (0, 0)),
            pl.BlockSpec((bn, 1), lambda n: (n, 0)),
            pl.BlockSpec(Wmat.shape, lambda n: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bn, Cp), lambda n: (n, 0)),
        out_shape=jax.ShapeDtypeStruct((Np, Cp), jnp.float32),
        interpret=interpret,
    )(X, landmarks, proj, mask.reshape(Np, 1), Wmat)
    return out[:N, :C]


@functools.partial(jax.jit, static_argnames=("sigma", "kind", "add_bias",
                                             "epilogue", "eps", "eps_ins",
                                             "block_n", "col_blk",
                                             "interpret"))
def nystrom_fused_stats(X: jnp.ndarray, landmarks: jnp.ndarray,
                        proj: jnp.ndarray, rho: jnp.ndarray,
                        beta: jnp.ndarray, wvec: jnp.ndarray,
                        mask: jnp.ndarray | None = None,
                        noise: tuple | None = None,
                        col_start: jnp.ndarray | int | None = None,
                        seed: jnp.ndarray | None = None, *,
                        sigma: float = 1.0, kind: str = "rbf",
                        add_bias: bool = False,
                        epilogue: str = "em_hinge", eps: float = 1e-6,
                        eps_ins: float = 0.0,
                        block_n: int = 256, col_blk: int | None = None,
                        interpret: bool = False):
    """The whole phi-space iteration statistic in ONE X pass.

    Returns (margin (N,), *aug (N,) each, b (M,), S), all f32 —
    exactly ``fused_stats`` (same epilogue family: EM/MC hinge, SVR's
    double mixture) evaluated on phi = nystrom_phi(X, ...), except phi
    never leaves VMEM. S is (M, M), or the (M, col_blk) PHI-column
    block S[:, start:start+blk] under a ``(col_start, col_blk)`` window
    — the ``k_shard_axis`` x Nystrom composition: the phi tile is
    computed in-kernel against the full landmark strip and only the
    windowed phi columns feed the Sigma accumulator (static blk shapes
    the accumulator; the traced 128-aligned base rides in SMEM, exactly
    ``fused_stats``'s windowing). MC epilogues consume pre-drawn
    per-row ``noise`` operands like ``fused_stats`` does. Padded/masked
    rows contribute zero to b and S (phi row zeroed, and the Sigma
    weight is mask-scaled; the hinge coef is additionally zero at
    rho = beta = 0).
    """
    N, D = X.shape
    windowed = col_blk is not None
    assert windowed == (col_start is not None), (
        "col_start and col_blk must be given together")
    rng = seed is not None
    n_aug = epilogues.aug_arity(epilogue)
    noise = tuple(noise) if noise is not None else ()
    if rng:
        assert not noise, (
            "seed (in-kernel RNG) and pre-drawn noise operands are "
            "mutually exclusive")
        n_noise = 0
    else:
        n_noise = epilogues.noise_arity(epilogue)
        assert len(noise) == n_noise, (
            f"epilogue {epilogue!r} needs {n_noise} noise operands, "
            f"got {len(noise)}")
    bn = min(block_n, _round_up(N, 8))
    X, landmarks, proj, mask, Np, Wp, M = _pad_operands(
        X, landmarks, proj, mask, add_bias, bn)
    rho = jnp.pad(rho.astype(jnp.float32), (0, Np - N))
    beta = jnp.pad(beta.astype(jnp.float32), (0, Np - N))
    wvec = jnp.pad(wvec.astype(jnp.float32), (0, Wp - M))
    noise = tuple(jnp.pad(z.astype(jnp.float32), (0, Np - N))
                  for z in noise)

    extra_specs: list = []
    extra_ops: tuple = ()
    if rng:
        extra_specs.append(pl.BlockSpec(memory_space=pltpu.SMEM))
        extra_ops += (seed,)
    if windowed:
        Sw = col_window_geometry(Wp, col_blk)
        a0, off = aligned_window_base(col_start, Wp, Sw)
        extra_specs.append(pl.BlockSpec(memory_space=pltpu.SMEM))
        extra_ops += (a0.reshape(1),)
    else:
        Sw = Wp

    row_spec = pl.BlockSpec((bn, 1), lambda n: (n, 0))
    outs = pl.pallas_call(
        _make_fused_kernel(kind, 1.0 / (2.0 * float(sigma) ** 2),
                           M - 1 if add_bias else None, epilogue,
                           float(eps), float(eps_ins), n_noise, n_aug,
                           windowed, rng),
        grid=(Np // bn,),
        in_specs=extra_specs + [                            # [aligned base]
            pl.BlockSpec((bn, X.shape[1]), lambda n: (n, 0)),   # X rows
            pl.BlockSpec(landmarks.shape, lambda n: (0, 0)),    # strip
            pl.BlockSpec(proj.shape, lambda n: (0, 0)),         # K_mm^-1/2
            row_spec,                                           # mask
            row_spec,                                           # rho
            row_spec,                                           # beta
            pl.BlockSpec((Wp, 1), lambda n: (0, 0)),            # w
        ] + [row_spec] * n_noise,                               # noise
        out_specs=[row_spec]                                    # margin
        + [row_spec] * n_aug                                    # gamma(,omega)
        + [
            pl.BlockSpec((Wp, 1), lambda n: (0, 0)),            # b (revisit)
            pl.BlockSpec((Wp, Sw), lambda n: (0, 0)),           # S (revisit)
        ],
        out_shape=[jax.ShapeDtypeStruct((Np, 1), jnp.float32)]
        * (1 + n_aug)
        + [
            jax.ShapeDtypeStruct((Wp, 1), jnp.float32),
            jax.ShapeDtypeStruct((Wp, Sw), jnp.float32),
        ],
        interpret=interpret,
    )(*extra_ops, X, landmarks, proj, mask.reshape(Np, 1),
      rho.reshape(Np, 1), beta.reshape(Np, 1), wvec.reshape(Wp, 1),
      *(z.reshape(Np, 1) for z in noise))
    per_row, (b, S) = outs[:1 + n_aug], outs[-2:]
    if windowed:
        S = jax.lax.dynamic_slice(S[:M], (jnp.int32(0), off),
                                  (M, col_blk))
    else:
        S = S[:M, :M]
    return (*(v[:N, 0] for v in per_row), b[:M, 0], S)


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m
