"""Per-row augmentation epilogues executed INSIDE the fused statistics
kernels (the in-kernel half of the ``core/augment.py`` split).

The one-sweep kernels (``fused_stats``, ``nystrom_fused_stats``) compute
the margin tile and the (b, Sigma) accumulators from one HBM pass over
X. What sits between the margin and the accumulators is the per-row
augmentation update — gamma for the hinge, (gamma, omega) for SVR's
double mixture — and it differs by {EM, MC} x {hinge, SVR}. This module
is that family, written as pure elementwise jnp so the SAME code runs

  * on (bn, 1) tiles inside a Pallas kernel body,
  * on (N,) vectors in the ``ref`` oracles and the K-tiled fallbacks.

MC draws are split into *draw generation* and *transform*: a PRNG half
produces per-row (nu, u) pairs keyed by GLOBAL row index, and the kernel
applies the deterministic Michael-Schucany-Haas transform
(``ig_transform``) below. The PRNG half has two sources: the legacy
host pre-draw (``core/augment.draw_ig_noise`` -> (N,) operands streamed
next to the N*K*4 X stream) and, under rng mode 'fused', the in-kernel
counter cipher (``fused_noise`` below / ``kernels/rng.py``) keyed by
(iteration key, global row, chain id) — no operands at all. Either way
the bits depend only on (key, row[, chain]), so the sampled chain is
bitwise chunk/shard/mesh-invariant and identical to its host oracle
(``augment.gamma_mc_rowwise`` resp. ``rng.draw_fused_noise``); the
kernel never needs a stateful PRNG (DESIGN.md §Perf/MC-SVR, §Perf/RNG).

Epilogue contract: ``apply_epilogue`` maps the margin tile to
(aug, sigma_weight, coef) where

  aug           per-row augmentation variables — (gamma,) for the hinge
                epilogues, (gamma, omega) for SVR (kernel outputs);
  sigma_weight  Sigma = X^T diag(wmask * sigma_weight) X;
  coef          b = X^T coef (the mu-numerator weights).

This module must stay import-free of ``repro.core`` (the kernels import
it, and core imports the kernels).
"""
from __future__ import annotations

import jax.numpy as jnp

from . import rng

# Clamp for the IG mean (mu = 1/|residual| explodes as the margin hits
# the hinge knee). 1/MU_MAX is far below any useful gamma clamp.
_MU_MAX = 1e8

# em_hinge  — today's EM E-step: gamma = max(eps, |rho - margin|)
#             (paper Eq. 9/36 + the Sec 5.7.3 clamp).
# mc_hinge  — the Gibbs draw gamma^{-1} ~ IG(1/|rho - margin|, 1)
#             (paper Eq. 5) via pre-drawn (nu, u).
# em_svr /  — SVR's double mixture (paper Eq. 25-28): gamma from
# mc_svr      res - eps_ins, omega from res + eps_ins, combined weights
#             1/gamma + 1/omega and coef (y-eps)/gamma + (y+eps)/omega.
EPILOGUES = ("em_hinge", "mc_hinge", "em_svr", "mc_svr")

# (nu, u) operand pairs consumed per row: one per IG mixture drawn.
_NOISE_ARITY = {"em_hinge": 0, "mc_hinge": 2, "em_svr": 0, "mc_svr": 4}
# augmentation variables emitted per row: (gamma,) or (gamma, omega).
_AUG_ARITY = {"em_hinge": 1, "mc_hinge": 1, "em_svr": 2, "mc_svr": 2}


def noise_arity(epilogue: str) -> int:
    """Number of pre-drawn (N,) noise operands the epilogue consumes."""
    return _NOISE_ARITY[epilogue]


def aug_arity(epilogue: str) -> int:
    """Number of per-row augmentation outputs (1 hinge, 2 SVR)."""
    return _AUG_ARITY[epilogue]


def fused_noise(seed, tile_row0, shape, epilogue: str):
    """In-kernel counter noise for one margin tile (rng mode 'fused').

    ``seed`` is the (4,) uint32 [k0, k1, row0, chain0] operand (an SMEM
    ref or a host array); the derived (nu, u) streams are bitwise equal
    to ``rng.draw_fused_noise`` at the same (row, chain, key)
    coordinates — this is what replaces the pre-drawn (N,) noise
    operands when the kernels run with an in-kernel RNG seed.  ``shape``
    is the margin tile shape (bn, C): rows advance along dim 0, chain
    ids along dim 1.
    """
    return rng.tile_noise(seed, tile_row0, shape, _NOISE_ARITY[epilogue])


def ig_transform(mu: jnp.ndarray, nu: jnp.ndarray, u: jnp.ndarray,
                 lam: float = 1.0) -> jnp.ndarray:
    """Michael-Schucany-Haas IG(mu, lam) transform of pre-drawn noise.

    x = mu + mu^2 y/(2 lam) - mu/(2 lam) sqrt(4 mu lam y + mu^2 y^2),
    y = nu^2, accepted when u <= mu/(mu+x), else mu^2/x. Deterministic
    given (nu ~ N(0,1), u ~ U(0,1)) — the PRNG lives with the caller,
    which is what lets the fused kernels apply this on a margin tile.
    """
    y = nu * nu
    muy = mu * y
    x = mu + mu * muy / (2.0 * lam) - (mu / (2.0 * lam)) * jnp.sqrt(
        4.0 * mu * lam * y + muy * muy)
    # Guard the fp edge where the sqrt slightly overshoots mu.
    x = jnp.maximum(x, jnp.finfo(mu.dtype).tiny)
    return jnp.where(u <= mu / (mu + x), x, mu * mu / x)


def ig_gamma_from_noise(residual: jnp.ndarray, nu: jnp.ndarray,
                        u: jnp.ndarray, eps: float) -> jnp.ndarray:
    """Gibbs gamma update from pre-drawn noise (paper Eq. 5, clamped).

    gamma^{-1} ~ IG(1/|residual|, 1) realized through ``ig_transform``;
    arithmetic is kept identical to ``augment.gamma_mc`` so the fused
    kernels reproduce the oracle draws bitwise given the same residual.
    """
    r = jnp.abs(residual.astype(jnp.float32))
    mu = jnp.minimum(1.0 / jnp.maximum(r, 1.0 / _MU_MAX), _MU_MAX)
    inv_gamma = ig_transform(mu, nu, u)
    return jnp.maximum(1.0 / jnp.maximum(inv_gamma, 1.0 / _MU_MAX), eps)


def apply_epilogue(epilogue: str, margin: jnp.ndarray, rho: jnp.ndarray,
                   beta: jnp.ndarray, noise: tuple, eps: float,
                   eps_ins: float = 0.0):
    """-> (aug, sigma_weight, coef); see the module docstring contract.

    All inputs are f32 and shape-aligned with ``margin`` (tiles or
    vectors). ``rho`` is the generic-hinge intercept for the hinge
    epilogues and the regression target y for the SVR ones; ``beta`` is
    the hinge sign (unused by SVR). ``noise`` carries ``noise_arity``
    pre-drawn arrays: (nu, u) for mc_hinge, (nu_g, u_g, nu_o, u_o) for
    mc_svr — gamma's mixture first, then omega's.
    """
    if epilogue == "em_hinge":
        gamma = jnp.maximum(jnp.abs(rho - margin), eps)
        return (gamma,), 1.0 / gamma, rho / gamma + beta
    if epilogue == "mc_hinge":
        nu, u = noise
        gamma = ig_gamma_from_noise(rho - margin, nu, u, eps)
        return (gamma,), 1.0 / gamma, rho / gamma + beta
    if epilogue in ("em_svr", "mc_svr"):
        res = rho - margin
        if epilogue == "em_svr":
            gamma = jnp.maximum(jnp.abs(res - eps_ins), eps)
            omega = jnp.maximum(jnp.abs(res + eps_ins), eps)
        else:
            nu_g, u_g, nu_o, u_o = noise
            gamma = ig_gamma_from_noise(res - eps_ins, nu_g, u_g, eps)
            omega = ig_gamma_from_noise(res + eps_ins, nu_o, u_o, eps)
        weight = 1.0 / gamma + 1.0 / omega
        coef = (rho - eps_ins) / gamma + (rho + eps_ins) / omega
        return (gamma, omega), weight, coef
    raise ValueError(f"epilogue must be one of {EPILOGUES}, "
                     f"got {epilogue!r}")
