"""Counter-based RNG for in-kernel Gibbs noise (DESIGN.md §Perf).

The MC epilogues need two uniform streams per row (``nu`` -> N(0,1) via
inverse-CDF, ``u`` -> U(0,1)) per inverse-Gaussian mixture.  Instead of
pre-drawing them on the host and streaming (N,) operands into the fused
kernels, we derive the bits on the fly from a stateless counter cipher:

    bits = threefry2x32(k0, k1,
                        c0 = global_row,
                        c1 = chain_id * 4 + mixture_word)

``(k0, k1)`` are the raw 32-bit words of the per-iteration PRNG subkey
(per-class ``fold_in`` for MLT happens before the words are extracted),
``global_row = shard_row_offset + chunk_row0 + tile_row`` and
``mixture`` is 0 for the gamma draw and 1 for the SVR omega draw.  The
counter fixes the draw for a (seed, row, chain, iteration) coordinate,
so the stream is chunking-, sharding- and mesh-layout-invariant by
construction, and C chains are C counter planes over one X stream.

Everything here is plain uint32/float32 ``jnp`` arithmetic -- the SAME
code runs on the host (the materialized-noise oracle, ``rng mode
'fused_predraw'``), in the ``ref`` path, and inside Pallas kernel
bodies, which is what makes the in-kernel draws *bitwise* equal to the
oracle.  We deliberately do NOT use ``pltpu.prng_random_bits``: the TPU
hardware generator cannot be replayed bit-exactly on the host, and the
whole verification story (and elastic resume) rests on replayability.

Bitwise stability across EVAL CONTEXTS (eager vs jit vs kernel body) is
load-bearing and shapes the float pipeline: under jit XLA contracts
``a * b + c`` into an FMA, while op-by-op eager execution cannot, so
any polynomial (Horner) evaluation would round differently inside a
jitted kernel than in an eager oracle call.  The bits->float maps below
therefore use only single-primitive transcendentals (log, sqrt, cos)
joined by bare multiplies -- Box-Muller for the normal, never an
erfinv polynomial -- leaving nothing for the compiler to contract.

This module must stay import-free of ``repro.core`` (kernel layer).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_U32 = jnp.uint32
# Threefry-2x32, 20 rounds: 5 groups of 4 with alternating rotation
# schedules and a key injection after each group.
_ROTATIONS = ((13, 15, 26, 6), (17, 29, 16, 24))
_PARITY = 0x1BD11BDA
_TWO_PI = 6.283185307179586


def _rotl(x, d: int):
    return (x << _U32(d)) | (x >> _U32(32 - d))


def threefry2x32(k0, k1, c0, c1):
    """Threefry-2x32 block cipher (20 rounds), pure uint32 jnp ops.

    ``k0``/``k1`` are uint32 key words; ``c0``/``c1`` uint32 counter
    words (scalars or arrays, broadcast together).  Returns the two
    uint32 output words.  Runs identically on host, ref and Pallas
    backends -- no primitive RNG involved.
    """
    k0 = jnp.asarray(k0, _U32)
    k1 = jnp.asarray(k1, _U32)
    ks = (k0, k1, k0 ^ k1 ^ _U32(_PARITY))
    x0 = jnp.asarray(c0, _U32) + ks[0]
    x1 = jnp.asarray(c1, _U32) + ks[1]
    for i in range(5):
        for d in _ROTATIONS[i % 2]:
            x0 = x0 + x1
            x1 = _rotl(x1, d)
            x1 = x0 ^ x1
        x0 = x0 + ks[(i + 1) % 3]
        x1 = x1 + ks[(i + 2) % 3] + _U32(i + 1)
    return x0, x1


def uniform_from_bits(bits):
    """uint32 bits -> f32 uniform, strictly inside (0, 1).

    Uses the top 23 bits so that ``int + 0.5`` stays exactly
    representable in f32 (24-bit significand): the result is
    ``(i + 0.5) * 2^-23`` for i in [0, 2^23), i.e. in
    [2^-24, 1 - 2^-24] -- never 0 or 1, so the Box-Muller log below
    stays finite.  ``(i + 0.5) * c`` is add-then-mul, not an FMA shape.
    """
    i = (bits >> _U32(9)).astype(jnp.float32)
    return (i + jnp.float32(0.5)) * jnp.float32(2.0 ** -23)


def normal_from_bits(bits0, bits1):
    """Two uint32 words -> one f32 standard normal via Box-Muller.

    nu = sqrt(-2 ln u1) * cos(2 pi u2).  Only single-primitive
    transcendentals joined by bare multiplies (module docstring: no
    ``a*b + c`` pattern the compiler could FMA-contract), so the value
    is bitwise identical in eager, jit and kernel-body evaluation.
    u1 is bounded away from 0 (``uniform_from_bits``), so the log and
    the result stay finite: |nu| <= sqrt(-2 ln 2^-24) ~ 5.77.
    """
    r = jnp.sqrt(jnp.float32(-2.0) * jnp.log(uniform_from_bits(bits0)))
    return r * jnp.cos(jnp.float32(_TWO_PI) * uniform_from_bits(bits1))


def counter_noise(k0, k1, rows, chains, n_noise: int):
    """The (nu, u[, nu_o, u_o]) tuple for given row/chain coordinates.

    ``rows``/``chains`` are int32 (arrays or scalars, broadcastable);
    ``n_noise`` is the epilogue's noise arity (2 for the single gamma
    mixture, 4 for SVR's gamma+omega double mixture).  Mixture m uses
    counter words ``c1 = chain*4 + 2m`` (both cipher output words feed
    the Box-Muller normal) and ``c1 = chain*4 + 2m + 1`` (word 0 is the
    accept-reject uniform).  Pure elementwise math, so the values are
    bitwise identical whether evaluated on (N,) host rows, (bn, 1)
    kernel tiles or (bn, C) multichain tiles.
    """
    assert n_noise in (2, 4), n_noise
    rows = jnp.asarray(rows, _U32)
    out = []
    for m in range(n_noise // 2):
        base = (jnp.asarray(chains, _U32) << _U32(2)) | _U32(2 * m)
        n0, n1 = threefry2x32(k0, k1, rows, base)
        u0, _ = threefry2x32(k0, k1, rows, base | _U32(1))
        out.append(normal_from_bits(n0, n1))
        out.append(uniform_from_bits(u0))
    return tuple(out)


def key_words(key):
    """Raw (k0, k1) uint32 words of a JAX PRNG key (typed or legacy)."""
    if jnp.issubdtype(jnp.asarray(key).dtype, jax.dtypes.prng_key):
        key = jax.random.key_data(key)
    key = jnp.asarray(key)
    return key[..., 0].astype(_U32), key[..., 1].astype(_U32)


def pack_seed(key, row0=0, chain0=0):
    """(4,) uint32 seed operand [k0, k1, row0, chain0] for the kernels.

    ``row0``/``chain0`` may be traced (shard row offsets are); they are
    carried as uint32 and re-interpreted as int32 inside the kernel, so
    the packing is exact for any non-negative 31-bit offset.
    """
    k0, k1 = key_words(key)
    return jnp.stack([
        k0, k1,
        jnp.asarray(row0, jnp.int32).astype(_U32),
        jnp.asarray(chain0, jnp.int32).astype(_U32),
    ])


def tile_noise(seed, tile_row0, shape, n_noise: int):
    """Noise tuple for one (bn, C) kernel tile.

    ``seed`` is the unpacked (4,) uint32 seed (indexable: a loaded SMEM
    ref or a host array); ``tile_row0`` the tile's first row relative
    to the operand (caller adds ``program_id * block_n``).  Row ids use
    a 2-D broadcasted iota over dim 0 and chain ids over dim 1 (TPU
    requires >= 2-D iota).
    """
    rows = (seed[2].astype(jnp.int32) + tile_row0
            + jax.lax.broadcasted_iota(jnp.int32, shape, 0))
    chains = (seed[3].astype(jnp.int32)
              + jax.lax.broadcasted_iota(jnp.int32, shape, 1))
    return counter_noise(seed[0], seed[1], rows, chains, n_noise)


def draw_fused_noise(key, n: int, row0=0, chain=0, n_noise: int = 2):
    """Host materialization of the counter stream (the bitwise oracle).

    Returns ``n_noise`` arrays of shape (n,): exactly the values the
    fused kernels generate in-body for rows [row0, row0 + n) of chain
    ``chain`` -- rng mode 'fused_predraw' feeds these through the
    legacy (N,) operand path to pin whole-fit bitwise parity.
    """
    k0, k1 = key_words(key)
    rows = jnp.asarray(row0, jnp.int32) + jnp.arange(n, dtype=jnp.int32)
    return counter_noise(k0, k1, rows, jnp.asarray(chain, jnp.int32),
                         n_noise)
