"""Backend-dispatching wrappers around the Pallas kernels.

Every op exists in three flavors:
  * ``ref``       — pure jnp oracle (ref.py); default on CPU hosts.
  * ``interpret`` — Pallas kernel executed by the interpreter (CPU
                    correctness validation of the real kernel body).
  * ``pallas``    — compiled Pallas TPU kernel; default on TPU.

``backend=None`` picks by ``jax.default_backend()``. The SVM solvers thread
a backend choice through so the same code serves tests (interpret), CPU
benchmarks (ref → XLA) and TPU production (pallas).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import epilogues
from . import fused_estep as _fused_estep
from . import fused_stats as _fused_stats
from . import nystrom_phi as _nystrom_phi
from . import rbf_gram as _rbf_gram
from . import ref
from . import syrk as _syrk
from . import weighted_gram as _weighted_gram

VALID_BACKENDS = ("ref", "interpret", "pallas")


def default_backend() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def _resolve(backend: str | None) -> str:
    backend = backend or default_backend()
    if backend not in VALID_BACKENDS:
        raise ValueError(f"backend must be one of {VALID_BACKENDS}, got {backend!r}")
    return backend


def _check_noise(epilogue: str, noise: tuple | None,
                 seed=None) -> None:
    """Validate the noise configuration HERE, once, so every route —
    ref, kernel, K-tiled and VMEM fallbacks — fails with the same
    message instead of an opaque unpack error inside the epilogue.

    Exactly one noise source is allowed: pre-drawn (N,) operands
    (rng mode 'host'/'fused_predraw') or the in-kernel counter ``seed``
    (rng mode 'fused') — never both."""
    got = 0 if noise is None else len(noise)
    if seed is not None:
        if got:
            raise ValueError(
                f"rng='fused' derives the {epilogue!r} noise in-kernel "
                f"from the counter seed, but {got} pre-drawn noise= "
                "operand(s) (augment.draw_ig_noise) were passed as "
                "well — drop the noise= operands or set "
                "SVMConfig.rng='host' to stream pre-drawn noise")
        return
    want = epilogues.noise_arity(epilogue)
    if got != want:
        raise ValueError(
            f"epilogue {epilogue!r} needs {want} pre-drawn noise "
            f"operands (augment.draw_ig_noise), got {got} — or pass "
            "seed= (SVMConfig.rng='fused') to derive them in-kernel")


def weighted_gram(X: jnp.ndarray, w: jnp.ndarray, *,
                  backend: str | None = None, **kw) -> jnp.ndarray:
    """S = X^T diag(w) X, (K, K) f32."""
    backend = _resolve(backend)
    if backend == "ref":
        return ref.weighted_gram(X, w)
    return _weighted_gram.weighted_gram(
        X, w, interpret=(backend == "interpret"), **kw)


def syrk_tri(X: jnp.ndarray, w: jnp.ndarray, *,
             backend: str | None = None, **kw) -> jnp.ndarray:
    """S = X^T diag(w) X computing only lower-triangle blocks (~2x fewer
    FLOPs than ``weighted_gram``); result is the full symmetric matrix."""
    backend = _resolve(backend)
    if backend == "ref":
        return ref.syrk_tri(X, w)
    return _syrk.syrk_tri(X, w, interpret=(backend == "interpret"), **kw)


# fused_stats holds the full (K, K) fp32 Sigma accumulator in VMEM;
# past this K the tile no longer fits (~16 MB VMEM with the X tile) and
# the kernel must not be attempted (DESIGN.md §Perf). Above it, the
# K-tiled two-pass pair is the correct regime anyway (compute-bound).
# The augmentation epilogues only add per-row (bn, 1) vectors (noise,
# gamma/omega) — <= 6 * bn * 4 B, noise next to the K^2 accumulator —
# so one cap serves every epilogue.
FUSED_STATS_MAX_K = 1536
_FUSED_STATS_VMEM_BUDGET = 14 * 2 ** 20


def _fused_stats_vmem_words(n_features: int, col_blk: int,
                            block_n: int, epilogue: str,
                            rng: bool = False) -> int:
    """fp32 words resident per grid step of the COLUMN-WINDOWED fused
    statistic (DESIGN.md §Perf/k-shard): the X tile, w/b, the narrowed
    (Kp, Cw) Sigma accumulator, and the epilogue's per-row vectors
    (rho/beta/wmask/margin + noise + aug). Under the in-kernel RNG
    (``rng=True``) the noise operands are derived in registers — zero
    resident words."""
    Kp = _ru(n_features, 128)
    Cw = min(Kp, _ru(col_blk, 128) + 128)
    per_row = (4 + (0 if rng else epilogues.noise_arity(epilogue))
               + epilogues.aug_arity(epilogue))
    return block_n * Kp + 2 * Kp + Kp * Cw + per_row * block_n


def fused_stats_fits(n_features: int, col_blk: int | None = None,
                     block_n: int = 512,
                     epilogue: str = "em_hinge",
                     rng: bool = False) -> bool:
    """Whether the one-pass fused-statistic kernel's working set fits
    VMEM. Full-width Sigma keeps the documented FUSED_STATS_MAX_K cap;
    a column window narrows the accumulator to (K, Cw), so K beyond the
    full cap can still fuse as long as the byte budget holds."""
    if col_blk is None:
        return n_features <= FUSED_STATS_MAX_K
    return 4 * _fused_stats_vmem_words(
        n_features, col_blk, block_n, epilogue,
        rng) <= _FUSED_STATS_VMEM_BUDGET


def fused_stats(X: jnp.ndarray, rho: jnp.ndarray, beta: jnp.ndarray,
                wvec: jnp.ndarray, wmask: jnp.ndarray | None = None,
                noise: tuple | None = None, *,
                epilogue: str = "em_hinge", eps: float = 1e-6,
                eps_ins: float = 0.0, col_window: tuple | None = None,
                seed: jnp.ndarray | None = None,
                backend: str | None = None, **kw):
    """(margin, *aug, b, S): the whole iteration statistic in one X
    pass (single HBM stream instead of the split margin/b/Sigma
    passes), under any augmentation ``epilogue`` (``epilogues.py``):
    em_hinge/mc_hinge return (margin, gamma, b, S); the SVR double
    mixture returns (margin, gamma, omega, b, S). MC flavors consume
    pre-drawn per-row ``noise`` arrays (``augment.draw_ig_noise``) OR,
    when ``seed`` (the (4,) uint32 counter seed from ``rng.pack_seed``)
    is given, derive them in-kernel with zero extra operands (rng mode
    'fused'; mixing both sources is rejected).

    A 2-D (K, C) ``wvec`` with ``seed`` runs C Gibbs chains over the
    single X stream: margin/aug (N, C), b (K, C), S (C, K, K).

    ``col_window = (start, blk)`` narrows Sigma to its column block
    X^T diag(w) X[:, start:start+blk] — the 2-D (data x model)
    ``k_shard_axis`` statistic stays single-stream: ``blk`` is static,
    ``start`` may be traced (``axis_index * blk`` inside shard_map).

    For K > FUSED_STATS_MAX_K (full width; C*K for C chains) or past
    the windowed byte budget (``fused_stats_fits``) the Pallas flavors
    fall back to the K-tiled split pair (E-step + syrk_tri; windowed:
    plain-XLA column block) rather than blow VMEM — callers get the
    same outputs either way."""
    backend = _resolve(backend)
    _check_noise(epilogue, noise, seed)
    multi = wvec.ndim == 2
    n_chains = wvec.shape[1] if multi else 1
    if backend == "ref":
        return ref.fused_stats(X, rho, beta, wvec, wmask, eps,
                               epilogue=epilogue, noise=noise,
                               eps_ins=eps_ins, col_window=col_window,
                               seed=seed)
    if col_window is not None:
        start, blk = col_window
        if not fused_stats_fits(X.shape[1], blk,
                                kw.get("block_n", 512), epilogue,
                                seed is not None):
            # Windowed split fallback: the narrowed Sigma block is a
            # plain (weighted X)^T Xcols matmul XLA tiles itself —
            # the compute-bound regime where stream count stops being
            # the bound (the triangle SYRK does not apply to an
            # off-diagonal rectangular block).
            return ref.fused_stats(X, rho, beta, wvec, wmask, eps,
                                   epilogue=epilogue, noise=noise,
                                   eps_ins=eps_ins,
                                   col_window=col_window, seed=seed)
        return _fused_stats.fused_stats(
            X, rho, beta, wvec, wmask, noise, start, seed,
            epilogue=epilogue, eps=eps, eps_ins=eps_ins, col_blk=blk,
            interpret=(backend == "interpret"), **kw)
    if X.shape[1] * n_chains > FUSED_STATS_MAX_K:
        kw.pop("block_n", None)
        if multi:
            # Multichain past the VMEM cap: the C stacked Sigma blocks
            # are plain XLA matmuls (compute-bound regime).
            return ref.fused_stats(X, rho, beta, wvec, wmask, eps,
                                   epilogue=epilogue, noise=noise,
                                   eps_ins=eps_ins, seed=seed)
        if epilogue == "em_hinge":
            margin, gamma, b = fused_estep(X, rho, beta, wvec, eps=eps,
                                           backend=backend)
            w = (1.0 / gamma) if wmask is None else wmask / gamma
            return margin, gamma, b, syrk_tri(X, w, backend=backend)
        # Generalized split fallback: the O(NK) E-step (margin, aug,
        # coef) runs as plain XLA; only the O(NK^2) Sigma goes through
        # the K-tiled SYRK kernel. 3 X streams — the compute-bound
        # regime where stream count stops being the bound anyway.
        if seed is not None:
            noise = ref.seed_noise(seed, X.shape[0], 1, epilogue)
        Xf = X.astype(jnp.float32)
        margin = Xf @ wvec.astype(jnp.float32)
        aug, weight, coef = epilogues.apply_epilogue(
            epilogue, margin, rho.astype(jnp.float32),
            beta.astype(jnp.float32), noise, eps, eps_ins)
        w = weight if wmask is None else wmask.astype(jnp.float32) * weight
        b = Xf.T @ coef
        return (margin, *aug, b, syrk_tri(X, w, backend=backend))
    return _fused_stats.fused_stats(
        X, rho, beta, wvec, wmask, noise, None, seed,
        epilogue=epilogue, eps=eps,
        eps_ins=eps_ins, interpret=(backend == "interpret"), **kw)


def fused_estep(X: jnp.ndarray, rho: jnp.ndarray, beta: jnp.ndarray,
                wvec: jnp.ndarray, *, eps: float = 1e-6,
                backend: str | None = None, **kw):
    """(gamma, b): EM gamma update fused with the mu-numerator statistic."""
    backend = _resolve(backend)
    if backend == "ref":
        return ref.fused_estep(X, rho, beta, wvec, eps)
    return _fused_estep.fused_estep(
        X, rho, beta, wvec, eps=eps, interpret=(backend == "interpret"), **kw)


def rbf_gram(X1: jnp.ndarray, X2: jnp.ndarray, *, sigma: float = 1.0,
             backend: str | None = None, **kw) -> jnp.ndarray:
    """RBF Gram matrix (N1, N2) f32."""
    backend = _resolve(backend)
    if backend == "ref":
        return ref.rbf_gram(X1, X2, sigma)
    return _rbf_gram.rbf_gram(
        X1, X2, sigma=float(sigma), interpret=(backend == "interpret"), **kw)


# The fused Nystrom kernel holds the landmark strip, the projection, the
# phi tile AND the (M, M) Sigma accumulator in VMEM at once; past this
# landmark count (or the byte budget below, for wide D) it must not be
# attempted. The fallback — featurize (nystrom_phi) then accumulate
# (fused_stats, itself K-tiled past FUSED_STATS_MAX_K) — is the right
# regime anyway: at large m the statistic turns compute-bound and the
# fusion's HBM saving stops mattering (DESIGN.md §Perf/Nystrom).
NYSTROM_FUSED_MAX_M = 1024
_NYSTROM_VMEM_BUDGET = 14 * 2 ** 20


def _ru(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _nystrom_vmem_words(n_landmarks: int, n_features: int, add_bias: bool,
                        block_n: int, with_stats: bool,
                        epilogue: str = "em_hinge",
                        col_blk: int | None = None,
                        rng: bool = False) -> int:
    """fp32 words resident per grid step of the Nystrom kernels
    (DESIGN.md §Perf/Nystrom accounting). ``with_stats`` adds the
    Sigma/b accumulators only the fused flavor allocates; the epilogue
    adds its pre-drawn noise operands and extra aug outputs (per-row
    vectors — noise next to the phi tile, but accounted). ``col_blk``
    narrows the Sigma accumulator to its aligned (Wp, Cw) k-shard
    column window."""
    Lp = _ru(n_landmarks, 128)
    Dp = _ru(n_features, 128)
    Wp = _ru(n_landmarks + int(add_bias), 128)
    words = (block_n * Dp        # X tile
             + Lp * Dp           # landmark strip
             + Lp * Wp           # projection
             + block_n * Lp      # cross-Gram tile
             + block_n * Wp)     # phi tile
    if with_stats:
        per_row = (4                               # mask/rho/beta/margin
                   + (0 if rng else epilogues.noise_arity(epilogue))
                   + epilogues.aug_arity(epilogue))
        Cw = Wp if col_blk is None else min(Wp, _ru(col_blk, 128) + 128)
        words += (Wp * Cw        # Sigma accumulator (windowed: narrowed)
                  + Wp + per_row * block_n)  # w/b + per-row vectors
    return words


def nystrom_fused_fits(n_landmarks: int, n_features: int,
                       add_bias: bool = True, block_n: int = 256,
                       epilogue: str = "em_hinge",
                       col_blk: int | None = None,
                       rng: bool = False) -> bool:
    """Whether the one-pass featurize-and-accumulate kernel's working
    set fits the VMEM budget (epilogue-aware: MC/SVR flavors carry up
    to 6 extra per-row vectors — zero under the in-kernel RNG; a
    k-shard column window narrows the Sigma accumulator)."""
    if n_landmarks > NYSTROM_FUSED_MAX_M:
        return False
    return 4 * _nystrom_vmem_words(n_landmarks, n_features, add_bias,
                                   block_n, True, epilogue,
                                   col_blk, rng) <= _NYSTROM_VMEM_BUDGET


def _nystrom_phi_fits(n_landmarks: int, n_features: int,
                      add_bias: bool = True, block_n: int = 256) -> bool:
    """Featurize-only working set — no Sigma/b accumulators, so the phi
    kernel keeps serving shapes the fused budget rejects (e.g. wide D
    at m near the cap)."""
    if n_landmarks > NYSTROM_FUSED_MAX_M:
        return False
    return 4 * _nystrom_vmem_words(n_landmarks, n_features, add_bias,
                                   block_n, False) <= _NYSTROM_VMEM_BUDGET


def nystrom_phi(X: jnp.ndarray, landmarks: jnp.ndarray, proj: jnp.ndarray,
                mask: jnp.ndarray | None = None, *, sigma: float = 1.0,
                kind: str = "rbf", add_bias: bool = False,
                backend: str | None = None, **kw) -> jnp.ndarray:
    """Device-side Nystrom featurizer: phi = k(X, landmarks) @ proj with
    masked rows zeroed and an optional mask-valued bias column.

    (N, M) f32, M = proj.shape[1] + add_bias. One X stream, no (N, m)
    cross-Gram intermediate. Oversized landmark strips fall back to the
    jnp oracle (XLA tiles the matmuls itself)."""
    backend = _resolve(backend)
    if backend != "ref" and _nystrom_phi_fits(
            landmarks.shape[0], X.shape[1], add_bias,
            kw.get("block_n", 256)):
        return _nystrom_phi.nystrom_phi(
            X, landmarks, proj, mask, sigma=float(sigma), kind=kind,
            add_bias=add_bias, interpret=(backend == "interpret"), **kw)
    return ref.nystrom_phi(X, landmarks, proj, mask, float(sigma), kind,
                           add_bias)


def nystrom_score_fits(n_landmarks: int, n_features: int,
                       n_score_cols: int, add_bias: bool = False,
                       block_n: int = 256) -> bool:
    """Whether the fused scoring epilogue's working set fits VMEM: the
    featurize-only set plus the resident (Wp, Cp) weight block and the
    (bn, Cp) score tile (serving's only HBM write)."""
    if n_landmarks > NYSTROM_FUSED_MAX_M:
        return False
    Wp = _ru(n_landmarks + int(add_bias), 128)
    Cp = _ru(n_score_cols, 128)
    words = (_nystrom_vmem_words(n_landmarks, n_features, add_bias,
                                 block_n, False)
             + Wp * Cp + block_n * Cp)
    return 4 * words <= _NYSTROM_VMEM_BUDGET


def nystrom_score(X: jnp.ndarray, landmarks: jnp.ndarray,
                  proj: jnp.ndarray, W: jnp.ndarray,
                  mask: jnp.ndarray | None = None, *,
                  sigma: float = 1.0, kind: str = "rbf",
                  add_bias: bool = False,
                  backend: str | None = None, **kw) -> jnp.ndarray:
    """(N, C) scores = nystrom_phi(X, ...) @ W in one fused pass — the
    predict-side epilogue: phi stays a per-row-block VMEM tile and dies
    after one MXU matmul against the resident (M, C) weight block, so
    serving never materializes the (N, M) feature matrix in HBM. C
    columns carry tenants/classes/uncertainty directions. Oversized
    working sets fall back to featurize-then-matmul (ref oracle)."""
    backend = _resolve(backend)
    if backend != "ref" and nystrom_score_fits(
            landmarks.shape[0], X.shape[1], W.shape[1], add_bias,
            kw.get("block_n", 256)):
        return _nystrom_phi.nystrom_score(
            X, landmarks, proj, W, mask, sigma=float(sigma), kind=kind,
            add_bias=add_bias, interpret=(backend == "interpret"), **kw)
    return ref.nystrom_score(X, landmarks, proj, W, mask, float(sigma),
                             kind, add_bias)


def nystrom_fused_stats(X: jnp.ndarray, landmarks: jnp.ndarray,
                        proj: jnp.ndarray, rho: jnp.ndarray,
                        beta: jnp.ndarray, wvec: jnp.ndarray,
                        mask: jnp.ndarray | None = None,
                        noise: tuple | None = None, *,
                        sigma: float = 1.0, kind: str = "rbf",
                        add_bias: bool = False,
                        epilogue: str = "em_hinge", eps: float = 1e-6,
                        eps_ins: float = 0.0,
                        col_window: tuple | None = None,
                        seed: jnp.ndarray | None = None,
                        backend: str | None = None, **kw):
    """(margin, *aug, b, S): the whole phi-space iteration statistic in
    one X pass — ``fused_stats`` (any augmentation epilogue: EM/MC
    hinge, SVR's double mixture) on nystrom_phi(X) with phi never
    leaving VMEM (so the (N, m) feature matrix never exists in HBM).
    ``col_window = (start, blk)`` narrows Sigma to a PHI-column block —
    the ``k_shard_axis`` x Nystrom composition, still one X stream (the
    phi tile is featurized in-kernel and only its windowed columns feed
    the accumulator).

    When the landmark strip + projection + Sigma accumulator (+ the
    epilogue's per-row noise/aug vectors) exceed the VMEM budget
    (``nystrom_fused_fits``), falls back to featurize-then-accumulate:
    nystrom_phi materializes phi for this row block and fused_stats
    (K-tiled past its own cap, window passed through) consumes it under
    the same epilogue — callers get the same outputs either way."""
    backend = _resolve(backend)
    _check_noise(epilogue, noise, seed)
    if backend == "ref":
        return ref.nystrom_fused_stats(X, landmarks, proj, rho, beta,
                                       wvec, mask, float(sigma), kind,
                                       add_bias, eps, epilogue=epilogue,
                                       noise=noise, eps_ins=eps_ins,
                                       col_window=col_window, seed=seed)
    if not nystrom_fused_fits(landmarks.shape[0], X.shape[1], add_bias,
                              kw.get("block_n", 256), epilogue,
                              col_window[1] if col_window else None,
                              seed is not None):
        phi = nystrom_phi(X, landmarks, proj, mask, sigma=sigma, kind=kind,
                          add_bias=add_bias, backend=backend)
        return fused_stats(phi, rho, beta, wvec, mask, noise,
                           epilogue=epilogue, eps=eps, eps_ins=eps_ins,
                           col_window=col_window, seed=seed,
                           backend=backend)
    if col_window is not None:
        start, blk = col_window
        return _nystrom_phi.nystrom_fused_stats(
            X, landmarks, proj, rho, beta, wvec, mask, noise, start,
            seed, sigma=float(sigma), kind=kind, add_bias=add_bias,
            epilogue=epilogue, eps=eps, eps_ins=eps_ins, col_blk=blk,
            interpret=(backend == "interpret"), **kw)
    return _nystrom_phi.nystrom_fused_stats(
        X, landmarks, proj, rho, beta, wvec, mask, noise, None, seed,
        sigma=float(sigma), kind=kind, add_bias=add_bias,
        epilogue=epilogue, eps=eps, eps_ins=eps_ins,
        interpret=(backend == "interpret"), **kw)
