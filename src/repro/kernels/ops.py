"""Backend-dispatching wrappers around the Pallas kernels.

Every op exists in three flavors:
  * ``ref``       — pure jnp oracle (ref.py); default on CPU hosts.
  * ``interpret`` — Pallas kernel executed by the interpreter (CPU
                    correctness validation of the real kernel body).
  * ``pallas``    — compiled Pallas TPU kernel; default on TPU.

``backend=None`` picks by ``jax.default_backend()``. The SVM solvers thread
a backend choice through so the same code serves tests (interpret), CPU
benchmarks (ref → XLA) and TPU production (pallas).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import fused_estep as _fused_estep
from . import fused_stats as _fused_stats
from . import rbf_gram as _rbf_gram
from . import ref
from . import syrk as _syrk
from . import weighted_gram as _weighted_gram

VALID_BACKENDS = ("ref", "interpret", "pallas")


def default_backend() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def _resolve(backend: str | None) -> str:
    backend = backend or default_backend()
    if backend not in VALID_BACKENDS:
        raise ValueError(f"backend must be one of {VALID_BACKENDS}, got {backend!r}")
    return backend


def weighted_gram(X: jnp.ndarray, w: jnp.ndarray, *,
                  backend: str | None = None, **kw) -> jnp.ndarray:
    """S = X^T diag(w) X, (K, K) f32."""
    backend = _resolve(backend)
    if backend == "ref":
        return ref.weighted_gram(X, w)
    return _weighted_gram.weighted_gram(
        X, w, interpret=(backend == "interpret"), **kw)


def syrk_tri(X: jnp.ndarray, w: jnp.ndarray, *,
             backend: str | None = None, **kw) -> jnp.ndarray:
    """S = X^T diag(w) X computing only lower-triangle blocks (~2x fewer
    FLOPs than ``weighted_gram``); result is the full symmetric matrix."""
    backend = _resolve(backend)
    if backend == "ref":
        return ref.syrk_tri(X, w)
    return _syrk.syrk_tri(X, w, interpret=(backend == "interpret"), **kw)


# fused_stats holds the full (K, K) fp32 Sigma accumulator in VMEM;
# past this K the tile no longer fits (~16 MB VMEM with the X tile) and
# the kernel must not be attempted (DESIGN.md §Perf). Above it, the
# K-tiled two-pass pair is the correct regime anyway (compute-bound).
FUSED_STATS_MAX_K = 1536


def fused_stats(X: jnp.ndarray, rho: jnp.ndarray, beta: jnp.ndarray,
                wvec: jnp.ndarray, wmask: jnp.ndarray | None = None, *,
                eps: float = 1e-6, backend: str | None = None, **kw):
    """(margin, gamma, b, S): the whole EM iteration statistic in one
    X pass (single HBM stream instead of estep + gram).

    For K > FUSED_STATS_MAX_K the Pallas flavors fall back to the
    K-tiled split pair (fused_estep + syrk_tri) rather than blow the
    VMEM budget — callers get the same outputs either way."""
    backend = _resolve(backend)
    if backend == "ref":
        return ref.fused_stats(X, rho, beta, wvec, wmask, eps)
    if X.shape[1] > FUSED_STATS_MAX_K:
        kw.pop("block_n", None)
        margin, gamma, b = fused_estep(X, rho, beta, wvec, eps=eps,
                                       backend=backend)
        w = (1.0 / gamma) if wmask is None else wmask / gamma
        return margin, gamma, b, syrk_tri(X, w, backend=backend)
    return _fused_stats.fused_stats(
        X, rho, beta, wvec, wmask, eps=eps,
        interpret=(backend == "interpret"), **kw)


def fused_estep(X: jnp.ndarray, rho: jnp.ndarray, beta: jnp.ndarray,
                wvec: jnp.ndarray, *, eps: float = 1e-6,
                backend: str | None = None, **kw):
    """(gamma, b): EM gamma update fused with the mu-numerator statistic."""
    backend = _resolve(backend)
    if backend == "ref":
        return ref.fused_estep(X, rho, beta, wvec, eps)
    return _fused_estep.fused_estep(
        X, rho, beta, wvec, eps=eps, interpret=(backend == "interpret"), **kw)


def rbf_gram(X1: jnp.ndarray, X2: jnp.ndarray, *, sigma: float = 1.0,
             backend: str | None = None, **kw) -> jnp.ndarray:
    """RBF Gram matrix (N1, N2) f32."""
    backend = _resolve(backend)
    if backend == "ref":
        return ref.rbf_gram(X1, X2, sigma)
    return _rbf_gram.rbf_gram(
        X1, X2, sigma=float(sigma), interpret=(backend == "interpret"), **kw)
