"""Backend-dispatching wrappers around the Pallas kernels.

Every op exists in three flavors:
  * ``ref``       — pure jnp oracle (ref.py); default on CPU hosts.
  * ``interpret`` — Pallas kernel executed by the interpreter (CPU
                    correctness validation of the real kernel body).
  * ``pallas``    — compiled Pallas TPU kernel; default on TPU.

``backend=None`` picks by ``jax.default_backend()``. The SVM solvers thread
a backend choice through so the same code serves tests (interpret), CPU
benchmarks (ref → XLA) and TPU production (pallas).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import fused_estep as _fused_estep
from . import rbf_gram as _rbf_gram
from . import ref
from . import weighted_gram as _weighted_gram

VALID_BACKENDS = ("ref", "interpret", "pallas")


def default_backend() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def _resolve(backend: str | None) -> str:
    backend = backend or default_backend()
    if backend not in VALID_BACKENDS:
        raise ValueError(f"backend must be one of {VALID_BACKENDS}, got {backend!r}")
    return backend


def weighted_gram(X: jnp.ndarray, w: jnp.ndarray, *,
                  backend: str | None = None, **kw) -> jnp.ndarray:
    """S = X^T diag(w) X, (K, K) f32."""
    backend = _resolve(backend)
    if backend == "ref":
        return ref.weighted_gram(X, w)
    return _weighted_gram.weighted_gram(
        X, w, interpret=(backend == "interpret"), **kw)


def fused_estep(X: jnp.ndarray, rho: jnp.ndarray, beta: jnp.ndarray,
                wvec: jnp.ndarray, *, eps: float = 1e-6,
                backend: str | None = None, **kw):
    """(gamma, b): EM gamma update fused with the mu-numerator statistic."""
    backend = _resolve(backend)
    if backend == "ref":
        return ref.fused_estep(X, rho, beta, wvec, eps)
    return _fused_estep.fused_estep(
        X, rho, beta, wvec, eps=eps, interpret=(backend == "interpret"), **kw)


def rbf_gram(X1: jnp.ndarray, X2: jnp.ndarray, *, sigma: float = 1.0,
             backend: str | None = None, **kw) -> jnp.ndarray:
    """RBF Gram matrix (N1, N2) f32."""
    backend = _resolve(backend)
    if backend == "ref":
        return ref.rbf_gram(X1, X2, sigma)
    return _rbf_gram.rbf_gram(
        X1, X2, sigma=float(sigma), interpret=(backend == "interpret"), **kw)
