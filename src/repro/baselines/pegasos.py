"""Pegasos: Primal Estimated sub-GrAdient SOlver for SVM [14 in paper].

Mini-batch projected sub-gradient descent on the paper's objective Eq. 1
(with lambda as the L2 coefficient). Step t uses eta_t = 1/(lambda * t) and
the optional ball projection ||w|| <= 1/sqrt(lambda). Single-threaded in
the paper's comparisons; here one jitted lax.scan."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class PegasosSVM:
    lam: float = 1.0
    n_steps: int = 2000
    batch_size: int = 256
    project: bool = True
    seed: int = 0
    add_bias: bool = True

    def fit(self, X: np.ndarray, y: np.ndarray) -> "PegasosSVM":
        X = np.asarray(X, np.float32)
        if self.add_bias:
            X = np.concatenate([X, np.ones((X.shape[0], 1), np.float32)], 1)
        y = np.asarray(y, np.float32)
        N, K = X.shape
        Xj, yj = jnp.asarray(X), jnp.asarray(y)
        lam, B, project = self.lam, min(self.batch_size, N), self.project

        def step(w, inp):
            t, key = inp
            idx = jax.random.randint(key, (B,), 0, N)
            xb, yb = Xj[idx], yj[idx]
            margin = yb * (xb @ w)
            g_loss = -(xb * (yb * (margin < 1.0))[:, None]).sum(0) * (2.0 / B)
            eta = 1.0 / (lam * t)
            w = (1.0 - eta * lam) * w - eta * g_loss
            if project:
                norm = jnp.linalg.norm(w)
                w = w * jnp.minimum(1.0, 1.0 / (jnp.sqrt(lam) * norm + 1e-30))
            return w, None

        keys = jax.random.split(jax.random.PRNGKey(self.seed), self.n_steps)
        ts = jnp.arange(1, self.n_steps + 1, dtype=jnp.float32)
        w0 = jnp.zeros((K,), jnp.float32)
        w, _ = jax.lax.scan(step, w0, (ts, keys))
        self.w = np.asarray(w)
        return self

    def decision_function(self, X: np.ndarray) -> np.ndarray:
        X = np.asarray(X, np.float32)
        if self.add_bias:
            X = np.concatenate([X, np.ones((X.shape[0], 1), np.float32)], 1)
        return X @ self.w

    def predict(self, X: np.ndarray) -> np.ndarray:
        return np.where(self.decision_function(X) >= 0, 1, -1)

    def score(self, X: np.ndarray, y: np.ndarray) -> float:
        return float(np.mean(self.predict(X) == np.asarray(y)))
