"""Dual coordinate descent for L1-loss linear SVM — the LibLinear "LL-Dual"
solver the paper benchmarks against [5 in paper; Hsieh et al. 2008].

Solves  min_alpha 1/2 a^T Q a - sum(a),  0 <= a_i <= C,
Q_ij = y_i y_j x_i x_j, maintaining w = sum a_i y_i x_i. The paper's
objective Eq. 1 (1/2 lam ||w||^2 + 2 sum xi) is proportional to the
standard form with C = 2/lam, so minimizers coincide.

Coordinates are swept in a fixed random permutation per epoch inside one
jitted lax.scan (the algorithm is inherently sequential — this is the
single-threaded baseline, exactly the role it plays in the paper's
tables)."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class DCDSVM:
    C: float = 1.0
    n_epochs: int = 10
    seed: int = 0
    add_bias: bool = True

    @classmethod
    def from_lam(cls, lam: float, **kw) -> "DCDSVM":
        return cls(C=2.0 / lam, **kw)

    def fit(self, X: np.ndarray, y: np.ndarray) -> "DCDSVM":
        X = np.asarray(X, np.float32)
        if self.add_bias:
            X = np.concatenate([X, np.ones((X.shape[0], 1), np.float32)], 1)
        y = np.asarray(y, np.float32)
        N, K = X.shape
        Xj, yj = jnp.asarray(X), jnp.asarray(y)
        qdiag = jnp.sum(Xj * Xj, axis=1)
        C = jnp.float32(self.C)

        rng = np.random.default_rng(self.seed)
        order = np.stack([rng.permutation(N) for _ in range(self.n_epochs)])
        order = jnp.asarray(order.reshape(-1), jnp.int32)

        def step(carry, i):
            w, alpha = carry
            xi, yi, ai = Xj[i], yj[i], alpha[i]
            G = yi * (xi @ w) - 1.0
            a_new = jnp.clip(ai - G / jnp.maximum(qdiag[i], 1e-12), 0.0, C)
            w = w + (a_new - ai) * yi * xi
            alpha = alpha.at[i].set(a_new)
            return (w, alpha), None

        w0 = jnp.zeros((K,), jnp.float32)
        a0 = jnp.zeros((N,), jnp.float32)
        (w, _), _ = jax.lax.scan(step, (w0, a0), order)
        self.w = np.asarray(w)
        return self

    def decision_function(self, X: np.ndarray) -> np.ndarray:
        X = np.asarray(X, np.float32)
        if self.add_bias:
            X = np.concatenate([X, np.ones((X.shape[0], 1), np.float32)], 1)
        return X @ self.w

    def predict(self, X: np.ndarray) -> np.ndarray:
        return np.where(self.decision_function(X) >= 0, 1, -1)

    def score(self, X: np.ndarray, y: np.ndarray) -> float:
        return float(np.mean(self.predict(X) == np.asarray(y)))
