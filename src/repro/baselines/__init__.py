"""Baselines the paper compares against (Table 4), reimplemented in JAX.

  * Pegasos  — primal estimated sub-gradient solver (Shalev-Shwartz 2007).
  * DCD      — dual coordinate descent, the LibLinear "LL-Dual" algorithm
               (Hsieh et al. 2008) for L1-loss linear SVM.

Used by the benchmark tables to reproduce the paper's accuracy-parity
claims without external binaries.
"""
from .dcd import DCDSVM  # noqa: F401
from .pegasos import PegasosSVM  # noqa: F401
