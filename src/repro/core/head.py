"""MaxMarginHead: the paper's technique as a first-class feature of every
assigned architecture (DESIGN.md §4, Arch-applicability).

The paper positions the sampling SVM as the building block for *composite
max-margin models* (MedLDA and friends, Sec 1): any model that produces
features can get an exact, parallel max-margin readout without mean-field
approximations. Here the composite model is <LM backbone + SVM head>:

    features h = pool(backbone(tokens))  (B, F)   — any repro.models arch
    head     trained by PEMSVM's parallel EM/MCMC on the same mesh

The head reuses the mesh's data axes for the Fig.-1 map-reduce, so SVM
training composes with the backbone's DP x TP layout. The backbone is
frozen during head fitting (the paper's algorithm is for convex models; it
does not replace SGD for the transformer interior)."""
from __future__ import annotations

from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from .solver import PEMSVM, SVMConfig


def mean_pool(hidden: jnp.ndarray, mask: jnp.ndarray | None = None
              ) -> jnp.ndarray:
    """(B, T, D) -> (B, D) masked mean over tokens."""
    if mask is None:
        return jnp.mean(hidden, axis=1)
    m = mask[..., None].astype(hidden.dtype)
    return jnp.sum(hidden * m, axis=1) / jnp.maximum(jnp.sum(m, axis=1), 1.0)


def last_token_pool(hidden: jnp.ndarray, lengths: jnp.ndarray) -> jnp.ndarray:
    """(B, T, D) -> (B, D) hidden state at the last valid position."""
    idx = jnp.clip(lengths - 1, 0, hidden.shape[1] - 1)
    return jnp.take_along_axis(hidden, idx[:, None, None], axis=1)[:, 0]


class MaxMarginHead:
    """PEMSVM readout over backbone features.

    feature_fn: batch -> (B, F) pooled features (jit-able, frozen params
    closed over). Fitting extracts features in batches, then runs the
    parallel SVM on the provided mesh."""

    def __init__(self, config: SVMConfig, feature_fn: Callable,
                 mesh: Mesh | None = None,
                 data_axes: Sequence[str] | None = None,
                 feature_batch: int = 256):
        self.svm = PEMSVM(config, mesh=mesh, data_axes=data_axes)
        self.feature_fn = jax.jit(feature_fn)
        self.feature_batch = feature_batch

    def extract(self, inputs: np.ndarray) -> np.ndarray:
        feats = []
        for i in range(0, len(inputs), self.feature_batch):
            feats.append(np.asarray(
                self.feature_fn(jnp.asarray(inputs[i:i + self.feature_batch]))))
        return np.concatenate(feats, axis=0)

    def fit(self, inputs: np.ndarray, y: np.ndarray):
        return self.svm.fit(self.extract(inputs), y)

    def predict(self, inputs: np.ndarray) -> np.ndarray:
        return self.svm.predict(self.extract(inputs))

    def score(self, inputs: np.ndarray, y: np.ndarray) -> float:
        """Higher-is-better (accuracy, or negated RMSE for SVR) —
        see ``PEMSVM.score``."""
        return self.svm.score(self.extract(inputs), y)

    def rmse(self, inputs: np.ndarray, y: np.ndarray) -> float:
        return self.svm.rmse(self.extract(inputs), y)
