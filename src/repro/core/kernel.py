"""KRN-{EM,MC}-CLS: kernelized SVM via data augmentation (paper Sec 3.1).

The dual weight omega (N,) replaces w; the Gram matrix K replaces X, and
the prior precision becomes lam*K (pseudo-prior N(0, (lam K)^{-1})):

  gamma_d  <- |1 - y_d K_d omega|                       (Eq. 19)
  Sigma^p  =  sum_d (1/gamma_d) K_d^T K_d               (N x N)
  mu^p     =  sum_d y_d (1 + 1/gamma_d) K_d^T
  P        =  lam*K + sum_p Sigma^p,  mu = P^{-1} mu^p  (Eq. 18)

Distribution shards *rows* of K (each row d belongs to datum d, exactly the
paper's data partitioning); omega is replicated. Iteration time is the
paper's O(N^2[N/P + log P + log N]) — KRN is for modest N (Sec 4.3).

Padding: the Gram matrix is padded as blockdiag(K, I) with masked rows.
Padded components see prior precision lam*I and zero statistics, so their
posterior is centered at 0 and they never touch real components.
"""
from __future__ import annotations

from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.kernels import ops
from . import augment, objective, stats
from .linear import SVMData


def gram_matrix(X1: jnp.ndarray, X2: jnp.ndarray, *, kind: str = "rbf",
                sigma: float = 1.0, backend: str | None = None) -> jnp.ndarray:
    """Gram block between two sets of rows."""
    if kind == "rbf":
        return ops.rbf_gram(X1, X2, sigma=sigma, backend=backend)
    if kind == "linear":
        return X1.astype(jnp.float32) @ X2.astype(jnp.float32).T
    raise ValueError(f"unknown kernel kind {kind!r}")


def pad_gram(K: jnp.ndarray, n_pad: int) -> jnp.ndarray:
    """blockdiag(K, I_pad): keeps the padded prior well-conditioned."""
    if n_pad == 0:
        return K
    N = K.shape[0]
    out = jnp.zeros((N + n_pad, N + n_pad), K.dtype)
    out = out.at[:N, :N].set(K)
    return out.at[jnp.arange(N, N + n_pad), jnp.arange(N, N + n_pad)].set(1.0)


@partial(jax.jit, static_argnames=("mode", "lam", "eps", "jitter", "axes",
                                   "triangle", "backend", "reduce_dtype"))
def krn_step(data: SVMData, K_prior: jnp.ndarray, omega: jnp.ndarray,
             key: jax.Array, *, mode: str = "EM", lam: float = 1.0,
             eps: float = 1e-6, jitter: float = 1e-6,
             axes: Sequence[str] = (), triangle: bool = True,
             backend: str | None = None,
             reduce_dtype: str | None = None,
             live: jnp.ndarray | None = None):
    """One KRN-*-CLS iteration.

    data.X holds this shard's *rows of the padded Gram matrix* (N_loc, N);
    K_prior is the full padded Gram (replicated; the lam*K prior term).
    Returns (omega_new, aux dict).
    """
    K_rows, y, mask = data

    # Identical structure to LIN with X := K_rows, w := omega.
    # Masked rows contribute: their K-row is e_d (blockdiag identity), but
    # y = 0 there, so b gets 0; S would get (1/gamma_pad) e_d e_d^T — a
    # positive diagonal on padded components only. gamma_pad = |0 - omega_d|
    # stays near 0 -> clamp; suppress via the explicit Sigma weight mask.
    # Both modes stream the Gram rows ONCE: MC pre-draws per-GLOBAL-row
    # (nu, u) noise (fold_in(iter_key, row index) — the sampled chain is
    # independent of the mesh layout) and the IG transform runs inside
    # the kernel epilogue (DESIGN.md §Perf/MC-SVR).
    if mode == "EM":
        epilogue, noise = "em_hinge", None
    else:
        row0 = stats.shard_row_offset(K_rows.shape[0], axes)
        epilogue = "mc_hinge"
        noise = augment.draw_ig_noise(key, K_rows.shape[0], row0)
    margin, gamma, b, S = ops.fused_stats(K_rows, y, y, omega, mask,
                                          noise, epilogue=epilogue,
                                          eps=eps, backend=backend)
    S, b = stats.reduce_stats(S, b, axes, triangle=triangle,
                              reduce_dtype=reduce_dtype, live=live)

    L, mu = stats.posterior_params(S, b, lam, prior_precision=K_prior,
                                   jitter=jitter)
    omega_new = mu if mode == "EM" else stats.draw_weight(key, L, mu)

    K_omega = K_prior @ omega_new
    obj = objective.kernel_reg(omega_new, K_omega, lam) + stats.preduce(
        objective.hinge_obj_terms(margin, y, mask), axes, live)
    return omega_new, {"objective": obj,
                       "gamma_mean": stats.masked_mean(gamma, mask, axes, live)}


def decision_function(omega: jnp.ndarray, X_train: jnp.ndarray,
                      X_test: jnp.ndarray, *, kind: str = "rbf",
                      sigma: float = 1.0,
                      backend: str | None = None) -> jnp.ndarray:
    """f(x) = sum_d omega_d k(x_d, x)."""
    K_cross = gram_matrix(X_test, X_train, kind=kind, sigma=sigma,
                          backend=backend)
    return K_cross @ omega.astype(jnp.float32)
