"""Polson-Scott data augmentation: scale-variable (gamma) updates.

Lemma 1 (paper Eq. 3): exp(-2 max(0, u)) = ∫ N(u | -gamma, gamma) dgamma,
giving closed-form conditionals:

  EM   (Eq. 9):  gamma_d = |rho_d - w^T x_d|
  MCMC (Eq. 5):  gamma_d^{-1} ~ InverseGaussian(|rho_d - w^T x_d|^{-1}, 1)

where (rho, beta) parameterize the generic hinge max(0, beta*(rho - w^T x));
binary CLS has rho = beta = y (paper Sec 2), Crammer-Singer supplies
per-class rho/beta (Eq. 34-36), and SVR uses two mixtures (Eq. 25-26).

Per paper Sec 5.7.3, gamma values are clamped to >= eps instead of using
Greene's restricted least squares to handle support vectors (gamma -> 0).

Since the single-stream Gibbs refactor (DESIGN.md §Perf/MC-SVR) this
module is split in two halves:

  * DRAW GENERATION (here): rowwise-keyed PRNG — ``draw_ig_noise``
    pre-draws the per-row (nu, u) pairs the MC epilogues consume, keyed
    by GLOBAL row index so the chain is bitwise chunk/shard-invariant.
    O(N) bytes — noise next to the N*K*4 X stream.
  * IN-KERNEL TRANSFORM (``kernels/epilogues.py``): the deterministic
    Michael-Schucany-Haas transform and the epilogue family applied to
    the margin tile inside the fused statistics kernels (re-exported
    here as ``ig_transform`` / ``ig_gamma_from_noise``).

``gamma_mc`` / ``gamma_mc_rowwise`` remain the batch-level oracles the
fused paths are tested against (bitwise, given the same residuals).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.epilogues import (_MU_MAX, ig_gamma_from_noise,  # noqa: F401
                                     ig_transform)
# Counter-based noise (SVMConfig.rng = 'fused'/'fused_predraw'):
# ``draw_fused_noise`` is the host materialization of the stream the
# fused kernels derive in-body, ``pack_seed`` builds their (4,) uint32
# seed operand. ``draw_ig_noise`` below stays the rng='host' oracle.
from repro.kernels.rng import draw_fused_noise, pack_seed  # noqa: F401


def sample_inverse_gaussian(key: jax.Array, mu: jnp.ndarray,
                            lam: float = 1.0) -> jnp.ndarray:
    """Draw IG(mu, lam): split the key into (normal, uniform) noise and
    apply the Michael-Schucany-Haas transform (``ig_transform``)."""
    k1, k2 = jax.random.split(key)
    nu = jax.random.normal(k1, mu.shape, dtype=mu.dtype)
    u = jax.random.uniform(k2, mu.shape, dtype=mu.dtype)
    return ig_transform(mu, nu, u, lam)


def draw_ig_noise(key: jax.Array, n: int, row0: jnp.ndarray | int = 0,
                  dtype=jnp.float32) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Pre-draw the per-row (nu, u) noise one IG mixture consumes.

    Row d draws from ``fold_in(key, row0 + d)`` split into a normal and
    a uniform — exactly the keying and draw order of the
    ``gamma_mc_rowwise`` oracle, so feeding these arrays to
    ``ig_gamma_from_noise`` (host-side or inside a fused kernel
    epilogue) reproduces the oracle's gamma draws bitwise, for ANY
    chunking or sharding of the rows. SVR's double mixture calls this
    twice on split keys (gamma's then omega's mixture), matching the
    pre-fusion split-key oracle.
    """
    ids = jnp.asarray(row0, jnp.int32) + jnp.arange(n, dtype=jnp.int32)
    keys = jax.vmap(jax.random.fold_in, in_axes=(None, 0))(key, ids)

    def one(k):
        k1, k2 = jax.random.split(k)
        return (jax.random.normal(k1, (), dtype),
                jax.random.uniform(k2, (), dtype))

    return jax.vmap(one)(keys)


def gamma_em(residual: jnp.ndarray, eps: float) -> jnp.ndarray:
    """EM update: gamma = max(eps, |residual|) (paper Eq. 9 + 5.7.3 clamp)."""
    return jnp.maximum(jnp.abs(residual), eps)


def gamma_mc(key: jax.Array, residual: jnp.ndarray, eps: float) -> jnp.ndarray:
    """Gibbs update: gamma^{-1} ~ IG(1/|residual|, 1), clamped (Eq. 5)."""
    r = jnp.abs(residual.astype(jnp.float32))
    mu = jnp.minimum(1.0 / jnp.maximum(r, 1.0 / _MU_MAX), _MU_MAX)
    inv_gamma = sample_inverse_gaussian(key, mu)
    return jnp.maximum(1.0 / jnp.maximum(inv_gamma, 1.0 / _MU_MAX), eps)


def gamma_mc_rowwise(key: jax.Array, residual: jnp.ndarray, eps: float,
                     row0: jnp.ndarray | int) -> jnp.ndarray:
    """Gibbs gamma update with one PRNG key per *global* row.

    Row d draws from ``fold_in(key, row0 + d)``, so the sampled gammas
    depend only on (iteration key, global row index) — NOT on how the
    rows are batched. Streaming chunk accumulation (any chunk_rows),
    the in-memory drivers, and mesh row-sharding therefore all produce
    bitwise-identical draws, which is what makes the out-of-core
    ``driver="stream"`` exactly reproducible against the in-memory
    oracle for MC (DESIGN.md §Perf/Streaming). Costs one extra threefry
    hash per row — O(N), noise next to the O(NK^2) Sigma statistic.

    This is THE draw oracle: the fused single-stream MC paths pre-draw
    the same per-row noise (``draw_ig_noise``) and apply the transform
    in-kernel, and are tested bitwise against this function.
    """
    n = residual.shape[0]
    ids = jnp.asarray(row0, jnp.int32) + jnp.arange(n, dtype=jnp.int32)
    keys = jax.vmap(jax.random.fold_in, in_axes=(None, 0))(key, ids)
    r = jnp.abs(residual.astype(jnp.float32))
    mu = jnp.minimum(1.0 / jnp.maximum(r, 1.0 / _MU_MAX), _MU_MAX)
    inv_gamma = jax.vmap(sample_inverse_gaussian)(keys, mu)
    return jnp.maximum(1.0 / jnp.maximum(inv_gamma, 1.0 / _MU_MAX), eps)


def update_gamma(mode: str, key: jax.Array | None, residual: jnp.ndarray,
                 eps: float, row0: jnp.ndarray | int | None = None
                 ) -> jnp.ndarray:
    """Dispatch EM vs MC gamma update on a residual rho - w^T x.

    ``row0`` selects the chunking-invariant rowwise MC draw (the LIN
    paths pass the chunk/shard's global row offset); None keeps the
    batch draw (KRN, and direct callers)."""
    if mode == "EM":
        return gamma_em(residual.astype(jnp.float32), eps)
    if mode == "MC":
        assert key is not None, "MC gamma update needs a PRNG key"
        if row0 is None:
            return gamma_mc(key, residual, eps)
        return gamma_mc_rowwise(key, residual, eps, row0)
    raise ValueError(f"mode must be 'EM' or 'MC', got {mode!r}")
