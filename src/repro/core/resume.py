"""Checkpoint payload for preemption-safe fits (DESIGN.md §Reliability).

The E-step statistics are exact sums over rows and the MC chain is keyed
per global row, so a fit's whole resumable state is tiny — O(K^2), never
O(N):

  arrays   state (K,)/(M,K) f32, the PRNG carry key, the f64 MC sample
           sum (= mean * n_avg, driver-independent), and for a MID-PASS
           stream snapshot the iteration subkey plus the partial chunk
           totals (tot_*); with decayed warm-start stats, the frozen
           previous-fit (S, b) ride along (prev_*); with a windowed
           warm start (cfg.window), the whole hard-expiry ring of
           per-generation partials rides along (win{i}_*) — the ring is
           frozen for the fit, so restoring it verbatim makes every
           post-resume fold bit-identical.
  meta     scalar loop state: completed iteration count, histories,
           stopping-rule counters, the chunk cursor, and the config
           FINGERPRINT (the semantic fields that must match for the
           resumed trajectory to be the uninterrupted one).

Driver/layout fields (driver, scan_chunk, chunk_rows, prefetch, backend,
mesh axes, reduce dtype/packing, fault policy) are deliberately OUTSIDE
the fingerprint: a checkpoint written by ``driver="stream"`` on one mesh
restores into ``driver="scan"`` on another — that cross-layout freedom
is the elastic-fit contract, and it is sound because every excluded
field only re-associates the same exact sums. The one exception is a
mid-pass snapshot, whose chunk cursor is meaningful only for a stream
fit with the SAME chunk_rows (checked at restore).

Step numbering: ``step = it * 1_000_000 + chunk_idx`` — boundary saves
(chunk_idx = 0) and mid-pass saves share one monotonic axis, so
``Checkpointer.latest_step()`` is always the most recent commit of the
newest writer line. Under multi-controller co-supervision each attempt
additionally carries a fence EPOCH (``fit(..., epoch=)``); snapshots
order epoch-major, so a zombie attempt's late commit — even one that
lands — never outranks its successor's (DESIGN.md §Reliability).
"""
from __future__ import annotations

import json
from typing import Any

import numpy as np

from repro.checkpoint import Checkpointer

from .stats import StatsWindow

_MIDPASS_STRIDE = 1_000_000

# Fields whose values change the fit trajectory itself (as opposed to
# its schedule or layout). max_iters is excluded on purpose: resuming
# with a larger budget is how a preempted fit is EXTENDED. rng /
# n_chains / chain0 are semantic: the noise SOURCE and the chain
# coordinates select which counter stream the Gibbs chain consumes, so
# resuming a 'host' checkpoint under 'fused' (or at a different chain
# block) would silently continue a DIFFERENT chain.
_SEMANTIC_FIELDS = (
    "formulation", "algorithm", "task", "lam", "eps", "eps_ins",
    "num_classes", "kernel", "sigma", "min_iters", "patience", "tol",
    "burnin", "jitter", "add_bias", "seed", "pad_features", "decay",
    "window", "rng", "n_chains", "chain0",
)


def config_fingerprint(cfg) -> str:
    vals = {f: getattr(cfg, f) for f in _SEMANTIC_FIELDS}
    vals["phi_spec"] = repr(cfg.phi_spec) if cfg.phi_spec else None
    return json.dumps(vals, sort_keys=True)


def step_id(it: int, chunk_idx: int = 0) -> int:
    assert 0 <= chunk_idx < _MIDPASS_STRIDE, chunk_idx
    return it * _MIDPASS_STRIDE + chunk_idx


def save_snapshot(ckpt: Checkpointer, cfg, *, it: int, state, key,
                  samp_sum, n_avg: int, n_small: int, objs: list,
                  aux_hist: dict, n_syncs: int, converged: bool = False,
                  prev_stats: dict | None = None,
                  window_stats: list | None = None,
                  sub=None, totals: dict | None = None,
                  chunk_idx: int = 0, row0: int = 0,
                  blocking: bool = False) -> int:
    """Commit one resume point; returns its step id.

    ``it`` is the number of COMPLETED iterations; ``sub``/``totals``
    present make this a mid-pass stream snapshot of iteration it + 1,
    with ``chunk_idx`` chunks already folded into ``totals``.
    """
    in_pass = totals is not None
    arrays: dict[str, Any] = {
        "state": np.asarray(state, np.float32),
        "key": np.asarray(key),
        "samp_sum": np.asarray(samp_sum, np.float64),
    }
    if in_pass:
        arrays["sub"] = np.asarray(sub)
        for k, v in totals.items():
            arrays[f"tot_{k}"] = np.asarray(v)
    if prev_stats is not None:
        for k, v in prev_stats.items():
            arrays[f"prev_{k}"] = np.asarray(v)
    if window_stats:
        arrays.update(StatsWindow.pack(window_stats))
    meta = {
        "fingerprint": config_fingerprint(cfg),
        "it": int(it),
        "n_avg": int(n_avg),
        "n_small": int(n_small),
        "objs": [float(v) for v in objs],
        "aux": {k: [float(x) for x in v] for k, v in aux_hist.items()},
        "n_syncs": int(n_syncs),
        "converged": bool(converged),
        "in_pass": bool(in_pass),
        "chunk_idx": int(chunk_idx),
        "row0": int(row0),
        "chunk_rows": int(cfg.chunk_rows),
    }
    step = step_id(it + 1 if in_pass else it, chunk_idx if in_pass else 0)
    ckpt.save(step, arrays, meta=meta, blocking=blocking)
    return step


def load_snapshot(ckpt: Checkpointer, step: int | None = None) -> dict:
    """Flat payload dict: meta scalars + 'state'/'key'/'samp_sum' host
    arrays, plus 'sub'/'totals'/'prev_stats' when present."""
    arrays, manifest = ckpt.restore_named(step)
    meta = manifest["meta"]
    payload = dict(meta)
    payload["step"] = manifest["step"]
    # The attempt epoch the snapshot was committed under (0 for legacy
    # unfenced writers). Outside the fingerprint on purpose: epochs are
    # attempt lineage, not problem semantics — every epoch of the same
    # fingerprint is the same trajectory.
    payload["epoch"] = int(manifest.get("epoch", 0))
    payload["state"] = arrays["state"]
    payload["key"] = arrays["key"]
    payload["samp_sum"] = arrays["samp_sum"]
    payload["sub"] = arrays.get("sub")
    totals = {k[len("tot_"):]: v for k, v in arrays.items()
              if k.startswith("tot_")}
    payload["totals"] = totals or None
    prev = {k[len("prev_"):]: v for k, v in arrays.items()
            if k.startswith("prev_")}
    payload["prev_stats"] = prev or None
    payload["window_stats"] = StatsWindow.unpack(arrays) or None
    return payload


def check_compatible(payload: dict, cfg) -> None:
    fp = config_fingerprint(cfg)
    if payload["fingerprint"] != fp:
        theirs = json.loads(payload["fingerprint"])
        ours = json.loads(fp)
        diff = sorted(k for k in ours
                      if ours[k] != theirs.get(k, object()))
        raise ValueError(
            "checkpoint was written by a semantically different config; "
            f"mismatched fields: {diff} — resume requires the same "
            "problem (driver/mesh/chunking MAY differ, these may not)")
    if payload["in_pass"]:
        if cfg.driver != "stream":
            raise ValueError(
                "mid-pass checkpoint (partial chunk totals) can only "
                f"resume into driver='stream', not {cfg.driver!r}; pick "
                "an iteration-boundary step (Checkpointer.all_steps) "
                "for cross-driver resume")
        if payload["chunk_rows"] != cfg.chunk_rows:
            raise ValueError(
                "mid-pass checkpoint's chunk cursor was written at "
                f"chunk_rows={payload['chunk_rows']}, current config "
                f"has {cfg.chunk_rows}; the skip count would land "
                "mid-chunk — match chunk_rows or resume from a "
                "boundary step")
