"""LIN-{EM,MC}-CLS: linear binary SVM via data augmentation (paper Sec 2, 4).

One iteration over a *local* data shard (rows of other shards live on other
devices; reductions go through ``stats.reduce_stats``):

  E-step   gamma_d from the residual y_d - w^T x_d      O(NK/P)
  stats    Sigma^p = X^T diag(1/gamma) X                O(NK^2/P)   <- Pallas
           mu^p    = X^T (y (1 + 1/gamma))              O(NK/P)     <- fused
  reduce   psum over data axes                          O(K^2 log P)
  M-step   Cholesky solve (EM) / Gaussian draw (MC)     O(K^3), replicated

Padding convention: invalid rows have X-row == 0 and target == 0, which
makes their statistics contributions exactly zero; ``mask`` only enters the
objective.

``k_shard``: beyond-paper optimization (DESIGN.md §Perf) — additionally
split the Sigma^p *column blocks* over the mesh's model axis, turning the
paper's 1-D data-parallel statistic into a 2-D (data x model) one. Each
model shard computes X^T diag(w) X[:, cols]; the blocks are psum'd over
data axes only and all-gathered over the model axis.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp

from repro import compat
from repro.kernels import ops
from . import augment, objective, stats


class SVMData(NamedTuple):
    """A (possibly local-shard) view of the training set."""
    X: jnp.ndarray       # (N, K) rows zeroed where mask == 0
    target: jnp.ndarray  # y in {+-1} (CLS), float (SVR), int (MLT); 0 if padded
    mask: jnp.ndarray    # (N,) 1.0 valid / 0.0 padding


@dataclasses.dataclass(frozen=True)
class PhiSpec:
    """Static half of a Nystrom feature map (core/nystrom.py).

    The array half — the (m, D) landmark strip and the (m, m)
    ``K_mm^{-1/2}`` projection — travels separately as a ``phi``
    operand pair through every step/chunk function, because SVMConfig
    must stay hashable (the solver lru-caches jitted builders on it)
    and the arrays must stay traced (no retrace per fit).

    With a PhiSpec present, the chunk-callable statistics featurize
    on device: data.X holds RAW rows (D-wide), and the state/statistic
    dimension is ``proj.shape[1] + add_bias``. ``add_bias`` appends the
    phi-space bias column (mask-valued, so padding stays a no-op) —
    the X-space ``SVMConfig.add_bias`` must be False in this mode.
    """
    sigma: float = 1.0
    kind: str = "rbf"
    add_bias: bool = True


def accumulate_stats(X: jnp.ndarray, rho: jnp.ndarray, beta: jnp.ndarray,
                     w: jnp.ndarray, *, mode: str, key: jax.Array | None,
                     eps: float, backend: str | None,
                     row0: jnp.ndarray | int = 0,
                     phi=None, phi_spec: PhiSpec | None = None,
                     mask: jnp.ndarray | None = None):
    """(margin, gamma, Sigma^p, mu^p) for the generic hinge over one row
    block — THE chunk-callable statistic every driver shares: the
    in-memory drivers call it on the whole (padded) set, the mesh SPMD
    step calls it on the local shard, and ``driver="stream"`` calls it
    per chunk and sums (the statistics are exact sums over rows, paper
    Fig. 1, so chunk accumulation is exact). Shared by CLS (rho=beta=y)
    and each Crammer-Singer class update.

    Padded rows (X-row = 0, rho = beta = 0) contribute exactly zero to
    Sigma and b, so a partially-valid block needs no special casing.

    ``row0`` is the block's global row offset: MC gamma draws are keyed
    per global row so the sampled chain is invariant to chunking and
    sharding layout.

    BOTH modes stream X once through ``fused_stats``: EM with the
    ``em_hinge`` epilogue (today's path), MC with ``mc_hinge`` — the
    per-row (nu, u) noise is pre-drawn here (``augment.draw_ig_noise``,
    rowwise-keyed, bitwise-identical to the ``gamma_mc_rowwise``
    oracle) and the inverse-Gaussian transform runs INSIDE the kernel
    on the margin tile, so the draw no longer forces a separate margin
    pass + SYRK (3 X streams -> 1; DESIGN.md §Perf/MC-SVR).

    ``phi``/``phi_spec`` switch the statistic to Nystrom phi-space
    (core/nystrom.py): X holds RAW rows and phi = (landmarks, proj) is
    featurized ON DEVICE inside the statistic. Both modes fuse
    featurization into the single X sweep (``ops.nystrom_fused_stats``
    — the (N, m) phi matrix never exists, for EM *and* MC). ``mask``
    is required in phi-space — a zero X row is NOT a zero phi row, so
    padding must be masked rather than relying on the zero-row layout.
    """
    if mode == "EM":
        epilogue, noise = "em_hinge", None
    else:
        epilogue = "mc_hinge"
        noise = augment.draw_ig_noise(key, X.shape[0], row0)
    if phi_spec is not None:
        landmarks, proj = phi
        if mask is None:
            mask = jnp.ones((X.shape[0],), jnp.float32)
        margin, gamma, b, S = ops.nystrom_fused_stats(
            X, landmarks, proj, rho, beta, w, mask, noise,
            sigma=phi_spec.sigma, kind=phi_spec.kind,
            add_bias=phi_spec.add_bias, epilogue=epilogue, eps=eps,
            backend=backend)
    else:
        margin, gamma, b, S = ops.fused_stats(
            X, rho, beta, w, None, noise, epilogue=epilogue, eps=eps,
            backend=backend)
    return margin, gamma, S, b


# Back-compat name: pre-streaming callers knew this as local_stats.
local_stats = accumulate_stats


def _k_block(S_or_X, axis_name):
    """Column block bounds of a K-dim array for this model-axis shard.

    K must divide the model-axis size: a truncating ``K // n`` here would
    silently drop the trailing ``K % n`` columns of Sigma (the all-gather
    below would rebuild a (K, n*(K//n)) matrix) and corrupt the posterior.
    """
    K = S_or_X.shape[-1]
    p = jax.lax.axis_index(axis_name)
    n = compat.axis_size(axis_name)
    if K % n != 0:
        raise ValueError(
            f"k_shard_axis {axis_name!r} of size {n} does not divide "
            f"K={K}; pad the feature dimension to a multiple of {n} "
            f"(e.g. with zero columns) or drop k_shard_axis.")
    blk = K // n
    return p * blk, blk


@partial(jax.jit, static_argnames=("mode", "lam", "eps", "jitter", "axes",
                                   "triangle", "backend", "k_shard_axis",
                                   "reduce_dtype", "phi_spec"))
def cls_step(data: SVMData, w: jnp.ndarray, key: jax.Array, *,
             mode: str = "EM", lam: float = 1.0, eps: float = 1e-6,
             jitter: float = 1e-6, axes: Sequence[str] = (),
             triangle: bool = True, backend: str | None = None,
             k_shard_axis: str | None = None,
             reduce_dtype: str | None = None,
             phi=None, phi_spec: PhiSpec | None = None):
    """One LIN-*-CLS iteration. Returns (w_new, aux dict)."""
    X, y, mask = data
    # Rowwise MC draws are keyed by global row index, so shards need no
    # per-shard key folds — the row offset decorrelates them and keeps
    # the chain identical to the single-device and streaming drivers.
    row0 = stats.shard_row_offset(X.shape[0], axes)

    if phi_spec is not None and k_shard_axis is not None:
        raise NotImplementedError(
            "k_shard_axis does not compose with the Nystrom phi path "
            "yet: the 2-D Sigma column split would need a column-tiled "
            "featurize kernel")
    if k_shard_axis is None:
        margin, gamma, S, b = accumulate_stats(
            X, y, y, w, mode=mode, key=key, eps=eps, backend=backend,
            row0=row0, phi=phi, phi_spec=phi_spec, mask=mask)
        S, b = stats.reduce_stats(S, b, axes, triangle=triangle,
                                  reduce_dtype=reduce_dtype)
    else:
        # 2-D statistic: this model-shard computes only a column block of
        # Sigma^p, psums it over data axes, then all-gathers blocks.
        if mode == "EM":
            margin, gamma, b = ops.fused_estep(X, y, y, w, eps=eps,
                                               backend=backend)
        else:
            margin = X.astype(jnp.float32) @ w.astype(jnp.float32)
            gamma = augment.gamma_mc_rowwise(key, y - margin, eps, row0)
            # Cast BEFORE the arithmetic, matching accumulate_stats'
            # rho/beta handling: a wider target dtype (f64 under x64)
            # would otherwise silently upcast b and the whole posterior
            # solve (regression: tests/test_mc_fused.py).
            yf = y.astype(jnp.float32)
            b = X.astype(jnp.float32).T @ (yf / gamma + yf)
        start, blk = _k_block(X, k_shard_axis)
        Xcols = jax.lax.dynamic_slice_in_dim(X, start, blk, axis=1)
        S_blk = (X.astype(jnp.float32) * (1.0 / gamma)[:, None]).T @ Xcols
        S_blk = stats.preduce(S_blk, axes)          # (K, K/n) over data axes
        b = stats.preduce(b, axes)
        S = jax.lax.all_gather(S_blk, k_shard_axis, axis=1, tiled=True)

    L, mu = stats.posterior_params(S, b, lam, jitter=jitter)
    w_new = mu if mode == "EM" else stats.draw_weight(key, L, mu)

    obj = objective.l2_reg(w_new, lam) + stats.preduce(
        objective.hinge_obj_terms(margin, y, mask), axes)
    n_sv = stats.preduce(jnp.sum(mask * (gamma <= 2.0 * eps)), axes)
    return w_new, {"objective": obj,
                   "gamma_mean": stats.masked_mean(gamma, mask, axes),
                   "n_sv": n_sv}


def cls_chunk_stats(chunk: SVMData, w: jnp.ndarray, key: jax.Array,
                    row0: jnp.ndarray, *, mode: str, eps: float,
                    backend: str | None, phi=None,
                    phi_spec: PhiSpec | None = None) -> dict:
    """Streaming E-step body for CLS: one chunk's additive contributions.

    Every field is an exact sum over the chunk's valid rows, so the
    stream driver tree-sums these dicts across chunks and lands on the
    same (Sigma, b, loss, aux) the in-memory step computes in one shot
    (padded rows contribute zero by the layout convention; in phi-space
    the mask enforces it — see ``accumulate_stats``).
    """
    X, y, mask = chunk
    margin, gamma, S, b = accumulate_stats(
        X, y, y, w, mode=mode, key=key, eps=eps, backend=backend,
        row0=row0, phi=phi, phi_spec=phi_spec, mask=mask)
    return {
        "S": S,
        "b": b,
        "loss": objective.hinge_obj_terms(margin, y, mask),
        "gamma_sum": jnp.sum(gamma * mask),
        "mask_sum": jnp.sum(mask),
        "n_sv": jnp.sum(mask * (gamma <= 2.0 * eps)),
    }


def decision_function(w: jnp.ndarray, X: jnp.ndarray) -> jnp.ndarray:
    return X.astype(jnp.float32) @ w.astype(jnp.float32)


def init_weight(K: int) -> jnp.ndarray:
    return jnp.zeros((K,), jnp.float32)
