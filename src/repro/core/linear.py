"""LIN-{EM,MC}-CLS: linear binary SVM via data augmentation (paper Sec 2, 4).

One iteration over a *local* data shard (rows of other shards live on other
devices; reductions go through ``stats.reduce_stats``):

  E-step   gamma_d from the residual y_d - w^T x_d      O(NK/P)
  stats    Sigma^p = X^T diag(1/gamma) X                O(NK^2/P)   <- Pallas
           mu^p    = X^T (y (1 + 1/gamma))              O(NK/P)     <- fused
  reduce   psum over data axes                          O(K^2 log P)
  M-step   Cholesky solve (EM) / Gaussian draw (MC)     O(K^3), replicated

Padding convention: invalid rows have X-row == 0 and target == 0, which
makes their statistics contributions exactly zero; ``mask`` only enters the
objective.

``k_shard``: beyond-paper optimization (DESIGN.md §Perf/k-shard) —
additionally split the Sigma^p *column blocks* over the mesh's model
axis, turning the paper's 1-D data-parallel statistic into a 2-D
(data x model) one. Each model shard computes X^T diag(w) X[:, cols]
INSIDE the single-stream fused kernel (the ``col_window`` parameter of
``ops.fused_stats`` / ``ops.nystrom_fused_stats``, so EM, MC and the
Nystrom phi path all stay one X stream on the 2-D layout); the blocks
ride one packed psum over the data axes with b and are all-gathered
over the model axis (``stats.reduce_kshard``).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp

from repro import compat
from repro.kernels import ops
from . import augment, objective, stats


class SVMData(NamedTuple):
    """A (possibly local-shard) view of the training set."""
    X: jnp.ndarray       # (N, K) rows zeroed where mask == 0
    target: jnp.ndarray  # y in {+-1} (CLS), float (SVR), int (MLT); 0 if padded
    mask: jnp.ndarray    # (N,) 1.0 valid / 0.0 padding


@dataclasses.dataclass(frozen=True)
class PhiSpec:
    """Static half of a Nystrom feature map (core/nystrom.py).

    The array half — the (m, D) landmark strip and the (m, m)
    ``K_mm^{-1/2}`` projection — travels separately as a ``phi``
    operand pair through every step/chunk function, because SVMConfig
    must stay hashable (the solver lru-caches jitted builders on it)
    and the arrays must stay traced (no retrace per fit).

    With a PhiSpec present, the chunk-callable statistics featurize
    on device: data.X holds RAW rows (D-wide), and the state/statistic
    dimension is ``proj.shape[1] + add_bias``. ``add_bias`` appends the
    phi-space bias column (mask-valued, so padding stays a no-op) —
    the X-space ``SVMConfig.add_bias`` must be False in this mode.
    """
    sigma: float = 1.0
    kind: str = "rbf"
    add_bias: bool = True


def accumulate_stats(X: jnp.ndarray, rho: jnp.ndarray, beta: jnp.ndarray,
                     w: jnp.ndarray, *, mode: str, key: jax.Array | None,
                     eps: float, backend: str | None,
                     row0: jnp.ndarray | int = 0,
                     phi=None, phi_spec: PhiSpec | None = None,
                     mask: jnp.ndarray | None = None,
                     col_window: tuple | None = None,
                     rng: str = "host", chain0: int = 0):
    """(margin, gamma, Sigma^p, mu^p) for the generic hinge over one row
    block — THE chunk-callable statistic every driver shares: the
    in-memory drivers call it on the whole (padded) set, the mesh SPMD
    step calls it on the local shard, and ``driver="stream"`` calls it
    per chunk and sums (the statistics are exact sums over rows, paper
    Fig. 1, so chunk accumulation is exact). Shared by CLS (rho=beta=y)
    and each Crammer-Singer class update.

    Padded rows (X-row = 0, rho = beta = 0) contribute exactly zero to
    Sigma and b, so a partially-valid block needs no special casing.

    ``row0`` is the block's global row offset: MC gamma draws are keyed
    per global row so the sampled chain is invariant to chunking and
    sharding layout.

    BOTH modes stream X once through ``fused_stats``: EM with the
    ``em_hinge`` epilogue (today's path), MC with ``mc_hinge`` — the
    per-row (nu, u) noise is pre-drawn here (``augment.draw_ig_noise``,
    rowwise-keyed, bitwise-identical to the ``gamma_mc_rowwise``
    oracle) and the inverse-Gaussian transform runs INSIDE the kernel
    on the margin tile, so the draw no longer forces a separate margin
    pass + SYRK (3 X streams -> 1; DESIGN.md §Perf/MC-SVR).

    ``phi``/``phi_spec`` switch the statistic to Nystrom phi-space
    (core/nystrom.py): X holds RAW rows and phi = (landmarks, proj) is
    featurized ON DEVICE inside the statistic. Both modes fuse
    featurization into the single X sweep (``ops.nystrom_fused_stats``
    — the (N, m) phi matrix never exists, for EM *and* MC). ``mask``
    is required in phi-space — a zero X row is NOT a zero phi row, so
    padding must be masked rather than relying on the zero-row layout.

    ``col_window = (start, blk)`` narrows Sigma to its column block —
    the 2-D (data x model) ``k_shard_axis`` statistic (DESIGN.md
    §Perf/k-shard). The window composes with BOTH modes and with the
    phi path (where it selects PHI columns), so the single-X-stream
    property carries to the 2-D layout unchanged; margin/gamma/b stay
    full width.

    ``rng`` selects the MC noise source (DESIGN.md §Perf/RNG):
    'host' pre-draws the fold_in-keyed (nu, u) operands
    (``augment.draw_ig_noise``, today's path); 'fused' ships only the
    (4,) uint32 counter seed and the kernels derive the bits in-body;
    'fused_predraw' materializes the SAME counter stream on the host
    (``augment.draw_fused_noise``) and feeds it through the legacy
    operand path — the whole-fit bitwise oracle for 'fused'.
    ``chain0`` offsets the counter's chain coordinate; a 2-D (K, C)
    ``w`` under 'fused' runs C Gibbs chains over the one X stream
    (margin/gamma (N, C), b (K, C), S (C, K, K)).
    """
    if mode == "EM":
        epilogue, noise, seed = "em_hinge", None, None
    elif rng == "host":
        epilogue, seed = "mc_hinge", None
        noise = augment.draw_ig_noise(key, X.shape[0], row0)
    elif rng == "fused_predraw":
        epilogue, seed = "mc_hinge", None
        noise = augment.draw_fused_noise(key, X.shape[0], row0, chain0, 2)
    else:
        assert rng == "fused", rng
        epilogue, noise = "mc_hinge", None
        seed = augment.pack_seed(key, row0, chain0)
    if phi_spec is not None:
        landmarks, proj = phi
        if mask is None:
            mask = jnp.ones((X.shape[0],), jnp.float32)
        margin, gamma, b, S = ops.nystrom_fused_stats(
            X, landmarks, proj, rho, beta, w, mask, noise,
            sigma=phi_spec.sigma, kind=phi_spec.kind,
            add_bias=phi_spec.add_bias, epilogue=epilogue, eps=eps,
            col_window=col_window, seed=seed, backend=backend)
    else:
        margin, gamma, b, S = ops.fused_stats(
            X, rho, beta, w, None, noise, epilogue=epilogue, eps=eps,
            col_window=col_window, seed=seed, backend=backend)
    return margin, gamma, S, b


# Back-compat name: pre-streaming callers knew this as local_stats.
local_stats = accumulate_stats


def _k_block(width: int, axis_name: str):
    """(start, blk) Sigma column window of the width-K statistic for
    this model-axis shard — ``blk`` is static, ``start`` traced
    (``axis_index * blk``); the pair feeds ``accumulate_stats``'s
    ``col_window`` directly. ``width`` is the STATISTIC dimension:
    X columns for LIN, the phi width (``w.shape[0]``) in phi-space.

    The model-axis size must divide K: a truncating ``K // n`` here
    would silently drop the trailing ``K % n`` columns of Sigma (the
    all-gather would rebuild a (K, n*(K//n)) matrix) and corrupt the
    posterior.
    """
    n = compat.axis_size(axis_name)
    if width % n != 0:
        raise ValueError(
            f"k_shard_axis {axis_name!r} of size {n} does not divide "
            f"K={width}; pad the feature dimension to a multiple of "
            f"{n} with explicit zero columns "
            f"(data.pipeline.pad_features_to / SVMConfig.pad_features) "
            f"or drop k_shard_axis.")
    blk = width // n
    return jax.lax.axis_index(axis_name) * blk, blk


def chain_keys(key: jax.Array, chain0: int, n_chains: int) -> jax.Array:
    """Per-chain weight-draw keys: ``fold_in(key, chain0 + c)``.

    Under the counter rng modes EVERY weight draw is chain-keyed (even
    n_chains = 1), so chain c's draw depends only on (iteration key,
    absolute chain id) — never on how many chains ride the same fit."""
    ids = jnp.asarray(chain0, jnp.int32) + jnp.arange(n_chains,
                                                      dtype=jnp.int32)
    return jax.vmap(jax.random.fold_in, in_axes=(None, 0))(key, ids)


def multichain_draw(key: jax.Array, S: jnp.ndarray, b: jnp.ndarray,
                    lam: float, jitter: float, chain0: int):
    """Per-chain posterior solves + chain-keyed Gibbs weight draws.

    ``S`` (C, K, K), ``b`` (K, C) -> (C, K) draws: C independent
    Cholesky factorizations of lam*I + S_c and
    ``draw_weight(fold_in(key, chain0 + c), L_c, mu_c)``."""
    C = S.shape[0]
    L, mu = jax.vmap(
        lambda Sc, bc: stats.posterior_params(Sc, bc, lam, jitter=jitter)
    )(S, b.T)
    return jax.vmap(stats.draw_weight)(chain_keys(key, chain0, C), L, mu)


@partial(jax.jit, static_argnames=("mode", "lam", "eps", "jitter", "axes",
                                   "triangle", "backend", "k_shard_axis",
                                   "reduce_dtype", "phi_spec", "rng",
                                   "n_chains", "chain0"))
def cls_step(data: SVMData, w: jnp.ndarray, key: jax.Array, *,
             mode: str = "EM", lam: float = 1.0, eps: float = 1e-6,
             jitter: float = 1e-6, axes: Sequence[str] = (),
             triangle: bool = True, backend: str | None = None,
             k_shard_axis: str | None = None,
             reduce_dtype: str | None = None,
             phi=None, phi_spec: PhiSpec | None = None,
             live: jnp.ndarray | None = None,
             rng: str = "host", n_chains: int = 1, chain0: int = 0):
    """One LIN-*-CLS iteration. Returns (w_new, aux dict).

    ``live`` (this shard's liveness weight) renormalizes every reduction
    around dropped replicas — see ``stats.preduce``; all-ones is bitwise
    the plain psum.

    ``rng``/``chain0`` select the MC noise source (see
    ``accumulate_stats``). ``n_chains > 1`` (counter rng only) carries
    the weight state CHAIN-MAJOR as (C, K): the statistic runs all C
    chains over one X stream, the C posterior solves are vmapped, and
    the reported objective/diagnostics are cross-chain means."""
    X, y, mask = data
    multi = n_chains > 1
    # Rowwise MC draws are keyed by global row index, so shards need no
    # per-shard key folds — the row offset decorrelates them and keeps
    # the chain identical to the single-device and streaming drivers.
    row0 = stats.shard_row_offset(X.shape[0], axes)

    # 2-D (data x model) statistic: this model-shard computes only its
    # Sigma column block — INSIDE the same single-stream fused kernel
    # (col_window), for EM and MC, X- and phi-space alike; the packed
    # psum + block all-gather rebuild the full Sigma (stats.reduce_kshard).
    col_window = (_k_block(w.shape[0], k_shard_axis)
                  if k_shard_axis is not None else None)
    margin, gamma, S, b = accumulate_stats(
        X, y, y, w.T if multi else w, mode=mode, key=key, eps=eps,
        backend=backend, row0=row0, phi=phi, phi_spec=phi_spec, mask=mask,
        col_window=col_window, rng=rng, chain0=chain0)
    if k_shard_axis is None:
        S, b = stats.reduce_stats(S, b, axes, triangle=triangle,
                                  reduce_dtype=reduce_dtype, live=live)
    else:
        S, b = stats.reduce_kshard(S, b, axes, k_shard_axis,
                                   reduce_dtype=reduce_dtype, live=live)

    if multi:
        w_new = multichain_draw(key, S, b, lam, jitter, chain0)
        maskc = jnp.broadcast_to(mask[:, None], margin.shape)
        obj = objective.l2_reg(w_new, lam) / n_chains + stats.preduce(
            objective.hinge_obj_terms(margin, y[:, None], maskc),
            axes, live) / n_chains
        n_sv = stats.preduce(jnp.sum(maskc * (gamma <= 2.0 * eps)),
                             axes, live) / n_chains
        gamma_mean = stats.masked_mean(gamma, maskc, axes, live)
    else:
        L, mu = stats.posterior_params(S, b, lam, jitter=jitter)
        if mode == "EM":
            w_new = mu
        elif rng == "host":
            w_new = stats.draw_weight(key, L, mu)
        else:
            w_new = stats.draw_weight(chain_keys(key, chain0, 1)[0], L, mu)
        obj = objective.l2_reg(w_new, lam) + stats.preduce(
            objective.hinge_obj_terms(margin, y, mask), axes, live)
        n_sv = stats.preduce(jnp.sum(mask * (gamma <= 2.0 * eps)),
                             axes, live)
        gamma_mean = stats.masked_mean(gamma, mask, axes, live)
    return w_new, {"objective": obj,
                   "gamma_mean": gamma_mean,
                   "n_sv": n_sv}


def cls_chunk_stats(chunk: SVMData, w: jnp.ndarray, key: jax.Array,
                    row0: jnp.ndarray, *, mode: str, eps: float,
                    backend: str | None, phi=None,
                    phi_spec: PhiSpec | None = None,
                    rng: str = "host", n_chains: int = 1,
                    chain0: int = 0) -> dict:
    """Streaming E-step body for CLS: one chunk's additive contributions.

    Every field is an exact sum over the chunk's valid rows, so the
    stream driver tree-sums these dicts across chunks and lands on the
    same (Sigma, b, loss, aux) the in-memory step computes in one shot
    (padded rows contribute zero by the layout convention; in phi-space
    the mask enforces it — see ``accumulate_stats``).

    Multichain (counter rng) chunks carry S (C, K, K) / b (K, C) and
    chain-MEAN scalar diagnostics; the counter keying makes the draws —
    and therefore the whole chain — invariant to the chunk grid, which
    is what the elastic mid-pass resume test pins bitwise.
    """
    X, y, mask = chunk
    multi = n_chains > 1
    margin, gamma, S, b = accumulate_stats(
        X, y, y, w.T if multi else w, mode=mode, key=key, eps=eps,
        backend=backend, row0=row0, phi=phi, phi_spec=phi_spec, mask=mask,
        rng=rng, chain0=chain0)
    if multi:
        maskc = jnp.broadcast_to(mask[:, None], margin.shape)
        return {
            "S": S,
            "b": b,
            "loss": objective.hinge_obj_terms(margin, y[:, None],
                                              maskc) / n_chains,
            "gamma_sum": jnp.sum(gamma * maskc) / n_chains,
            "mask_sum": jnp.sum(mask),
            "n_sv": jnp.sum(maskc * (gamma <= 2.0 * eps)) / n_chains,
        }
    return {
        "S": S,
        "b": b,
        "loss": objective.hinge_obj_terms(margin, y, mask),
        "gamma_sum": jnp.sum(gamma * mask),
        "mask_sum": jnp.sum(mask),
        "n_sv": jnp.sum(mask * (gamma <= 2.0 * eps)),
    }


def decision_function(w: jnp.ndarray, X: jnp.ndarray) -> jnp.ndarray:
    return X.astype(jnp.float32) @ w.astype(jnp.float32)


def init_weight(K: int) -> jnp.ndarray:
    return jnp.zeros((K,), jnp.float32)
