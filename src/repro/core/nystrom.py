"""Nyström-approximated kernel SVM — answering the paper's open question.

Paper Sec 4.3 (KRN): "PSVM approximates the N by N kernel matrix with an
N by sqrt(N) matrix, and gets very good accuracy. Maybe there is a way to
do something similar with the sampling kernel SVM formulation?"

Yes — and it composes exactly with the augmentation. Pick m landmarks
(paper-suggested m = sqrt(N)); with K_mm the landmark Gram and K_nm the
cross-Gram, the Nyström feature map

    phi(x) = K_mm^{-1/2} k_m(x)      (m-dimensional)

satisfies phi(x)^T phi(x') ~= k(x, x'). Substituting w = sum_d a_d phi(x_d)
into the kernel problem (paper Eq. 12) turns the pseudo-prior
N(0, (lam K)^{-1}) into N(0, lam^{-1} I_m) in phi-space: the kernel SVM
becomes EXACTLY the linear PEMSVM on phi features. Every piece of the
parallel machinery then applies unchanged:

  * iteration cost falls from O(N^2[N/P + log N]) to O(m^2[N/P + log m])
    = O(N[N/P + ...]) at m = sqrt(N) — the cubic-in-N blocker the paper
    names is gone;
  * the map step is embarrassingly parallel over rows (phi is computed
    per shard); the reduce is the familiar m x m triangle psum;
  * EM/MC/CLS/SVR/MLT all inherit the approximation for free (it's just
    a feature transform).

K_mm^{-1/2} is computed once via eigendecomposition with a spectral
floor (rank truncation) for stability.
"""
from __future__ import annotations

import numpy as np

from . import kernel as krn
from .solver import PEMSVM, SVMConfig

import jax.numpy as jnp


def nystrom_features(X: np.ndarray, landmarks: np.ndarray, *,
                     kind: str = "rbf", sigma: float = 1.0,
                     spectral_floor: float = 1e-6,
                     backend: str | None = None) -> np.ndarray:
    """phi = K_nm @ K_mm^{-1/2}: (N, m) Nyström features."""
    K_mm = np.asarray(krn.gram_matrix(
        jnp.asarray(landmarks), jnp.asarray(landmarks), kind=kind,
        sigma=sigma, backend=backend), np.float64)
    K_nm = np.asarray(krn.gram_matrix(
        jnp.asarray(X), jnp.asarray(landmarks), kind=kind, sigma=sigma,
        backend=backend), np.float64)
    w, V = np.linalg.eigh(0.5 * (K_mm + K_mm.T))
    floor = spectral_floor * max(w.max(), 1e-30)
    keep = w > floor
    inv_sqrt = (V[:, keep] / np.sqrt(w[keep])) @ V[:, keep].T
    return (K_nm @ inv_sqrt).astype(np.float32)


class NystromSVM:
    """KRN-*-{CLS,SVR,MLT} via Nyström features + the linear parallel
    solver. m defaults to ceil(sqrt(N)) per the paper's PSVM reference."""

    def __init__(self, config: SVMConfig, n_landmarks: int | None = None,
                 mesh=None, data_axes=None, seed: int = 0):
        assert config.formulation == "KRN", "NystromSVM approximates KRN"
        self.kernel_kind = config.kernel
        self.sigma = config.sigma
        self.n_landmarks = n_landmarks
        self.seed = seed
        # delegate to the LIN machinery in phi-space; lam carries over
        # because the phi-space pseudo-prior is lam^{-1} I exactly.
        lin_cfg = SVMConfig(
            formulation="LIN", algorithm=config.algorithm, task=config.task,
            lam=config.lam, eps=config.eps, eps_ins=config.eps_ins,
            num_classes=config.num_classes, max_iters=config.max_iters,
            min_iters=config.min_iters, patience=config.patience,
            tol=config.tol, burnin=config.burnin,
            triangle_reduce=config.triangle_reduce,
            reduce_dtype=config.reduce_dtype, backend=config.backend,
            add_bias=True, seed=config.seed)
        self.svm = PEMSVM(lin_cfg, mesh=mesh, data_axes=data_axes)
        self._landmarks: np.ndarray | None = None

    def fit(self, X: np.ndarray, y: np.ndarray):
        X = np.asarray(X, np.float32)
        N = X.shape[0]
        m = self.n_landmarks or int(np.ceil(np.sqrt(N)))
        rng = np.random.default_rng(self.seed)
        self._landmarks = X[rng.choice(N, size=min(m, N), replace=False)]
        phi = nystrom_features(X, self._landmarks, kind=self.kernel_kind,
                               sigma=self.sigma,
                               backend=self.svm.config.backend)
        return self.svm.fit(phi, y)

    def _phi(self, X: np.ndarray) -> np.ndarray:
        return nystrom_features(np.asarray(X, np.float32), self._landmarks,
                                kind=self.kernel_kind, sigma=self.sigma,
                                backend=self.svm.config.backend)

    def predict(self, X: np.ndarray) -> np.ndarray:
        return self.svm.predict(self._phi(X))

    def decision_function(self, X: np.ndarray) -> np.ndarray:
        return self.svm.decision_function(self._phi(X))

    def score(self, X: np.ndarray, y: np.ndarray) -> float:
        return self.svm.score(self._phi(X), y)
