"""Nyström-approximated kernel SVM — answering the paper's open question.

Paper Sec 4.3 (KRN): "PSVM approximates the N by N kernel matrix with an
N by sqrt(N) matrix, and gets very good accuracy. Maybe there is a way to
do something similar with the sampling kernel SVM formulation?"

Yes — and it composes exactly with the augmentation. Pick m landmarks
(paper-suggested m = sqrt(N)); with K_mm the landmark Gram and K_nm the
cross-Gram, the Nyström feature map

    phi(x) = K_mm^{-1/2} k_m(x)      (m-dimensional)

satisfies phi(x)^T phi(x') ~= k(x, x'). Substituting w = sum_d a_d phi(x_d)
into the kernel problem (paper Eq. 12) turns the pseudo-prior
N(0, (lam K)^{-1}) into N(0, lam^{-1} I_m) in phi-space: the kernel SVM
becomes EXACTLY the linear PEMSVM on phi features. Every piece of the
parallel machinery then applies unchanged:

  * iteration cost falls from O(N^2[N/P + log N]) to O(m^2[N/P + log m])
    = O(N[N/P + ...]) at m = sqrt(N) — the cubic-in-N blocker the paper
    names is gone;
  * the map step is embarrassingly parallel over rows; the reduce is the
    familiar m x m triangle psum;
  * EM/MC x CLS/SVR/MLT all inherit the approximation for free, INCLUDING
    the drivers: ``NystromSVM`` delegates to the linear PEMSVM with
    ``config.phi_spec`` set, so ``driver="scan"`` (chunked on-device) and
    ``driver="stream"`` (out-of-core over RAW rows) both work — the
    nonlinear path inherits every hot-path optimization of the linear one.

Featurization happens ON DEVICE inside the statistic kernels
(``kernels/nystrom_phi.py``): the EM hot path fuses the RBF cross-Gram,
the K_mm^{-1/2} projection and the (margin, gamma, b, Sigma) accumulation
into one X sweep — the (N, m) phi matrix never exists in HBM, and the
stream driver's device residency is bounded by (prefetch + 2) raw D-wide
chunks regardless of m (DESIGN.md §Perf/Nystrom).

Host-side work is exactly two one-time O(m^2)-memory steps: landmark
selection (uniform; reservoir-sampled for out-of-core sources) and the
``K_mm^{-1/2}`` eigendecomposition with a spectral floor — cached on the
model, so prediction never refactorizes.
"""
from __future__ import annotations

import dataclasses

import numpy as np

import jax.numpy as jnp

from . import kernel as krn
from .linear import PhiSpec
from .solver import FitResult, PEMSVM, SVMConfig


def nystrom_projection(landmarks: np.ndarray, *, kind: str = "rbf",
                       sigma: float = 1.0, spectral_floor: float = 1e-6,
                       backend: str | None = None) -> np.ndarray:
    """K_mm^{-1/2} (m, m) float64 via one eigendecomposition.

    The spectral floor truncates near-null directions of the landmark
    Gram (rank deficiency from duplicate/near-duplicate landmarks) so
    the inverse square root stays bounded. This is the ONLY
    decomposition the Nyström path ever runs — fit computes it once and
    caches it; prediction reuses it.
    """
    K_mm = np.asarray(krn.gram_matrix(
        jnp.asarray(landmarks), jnp.asarray(landmarks), kind=kind,
        sigma=sigma, backend=backend), np.float64)
    w, V = np.linalg.eigh(0.5 * (K_mm + K_mm.T))
    floor = spectral_floor * max(w.max(), 1e-30)
    keep = w > floor
    return (V[:, keep] / np.sqrt(w[keep])) @ V[:, keep].T


def nystrom_features(X: np.ndarray, landmarks: np.ndarray, *,
                     kind: str = "rbf", sigma: float = 1.0,
                     spectral_floor: float = 1e-6,
                     backend: str | None = None) -> np.ndarray:
    """phi = K_nm @ K_mm^{-1/2}: (N, m) Nyström features.

    Host float64 featurization that MATERIALIZES phi — kept as the
    accuracy oracle and benchmark baseline; the fit path uses the
    on-device fused kernels instead (see module docstring)."""
    proj = nystrom_projection(landmarks, kind=kind, sigma=sigma,
                              spectral_floor=spectral_floor,
                              backend=backend)
    K_nm = np.asarray(krn.gram_matrix(
        jnp.asarray(X), jnp.asarray(landmarks), kind=kind, sigma=sigma,
        backend=backend), np.float64)
    return (K_nm @ proj).astype(np.float32)


class NystromSVM:
    """KRN-{EM,MC}-{CLS,SVR,MLT} via on-device Nyström featurization +
    the linear parallel solver. m defaults to ceil(sqrt(N)) per the
    paper's PSVM reference.

    Accepts any KRN ``SVMConfig`` — including ``driver="stream"`` (the
    out-of-core nonlinear fit; raw rows stream, phi never materializes)
    and the SVR/MLT tasks the exact Gram solver cannot serve.
    """

    def __init__(self, config: SVMConfig, n_landmarks: int | None = None,
                 mesh=None, data_axes=None, seed: int = 0,
                 spectral_floor: float = 1e-6):
        assert config.formulation == "KRN", "NystromSVM approximates KRN"
        self.config = config
        self.kernel_kind = config.kernel
        self.sigma = config.sigma
        self.n_landmarks = n_landmarks
        self.seed = seed
        self.spectral_floor = spectral_floor
        # Delegate to the LIN machinery in phi-space; lam carries over
        # because the phi-space pseudo-prior is lam^{-1} I exactly.
        # dataclasses.replace propagates EVERY config field (driver,
        # scan_chunk, chunk_rows, prefetch, jitter, k_shard_axis, and
        # whatever is added next) — only the three phi-mode fields are
        # overridden: the bias moves to phi-space (add_bias=False +
        # PhiSpec.add_bias=True; an X-space bias column would perturb
        # the RBF distances).
        lin_cfg = dataclasses.replace(
            config, formulation="LIN", add_bias=False,
            phi_spec=PhiSpec(sigma=config.sigma, kind=config.kernel,
                             add_bias=True))
        self.svm = PEMSVM(lin_cfg, mesh=mesh, data_axes=data_axes)
        self._landmarks: np.ndarray | None = None
        self._proj: np.ndarray | None = None

    # ------------------------------------------------------------ fitting
    def _install_featurizer(self, landmarks: np.ndarray) -> None:
        """The one-time host-side setup: cache the landmark strip and
        K_mm^{-1/2}, and hand both to the delegate's device path.
        ``eigh`` runs exactly once per fit; predict/score/
        decision_function reuse the cache."""
        self._landmarks = np.asarray(landmarks, np.float32)
        self._proj = nystrom_projection(
            self._landmarks, kind=self.kernel_kind, sigma=self.sigma,
            spectral_floor=self.spectral_floor,
            backend=self.svm.config.backend).astype(np.float32)
        self.svm._phi_arrays = (self._landmarks, self._proj)

    @staticmethod
    def _continuing(fit_kw: dict) -> bool:
        """A resumed/warm-started fit must REUSE the featurizer that
        produced the checkpointed phi-space weights — re-drawing
        landmarks would silently change the feature map under them."""
        return (fit_kw.get("resume_from") is not None
                or fit_kw.get("warm_start") is not None)

    def fit(self, X: np.ndarray, y: np.ndarray, **fit_kw) -> FitResult:
        """``fit_kw`` forwards the elastic surface (resume_from /
        warm_start / fault_hook / ...) — see ``PEMSVM.fit``. Landmark
        selection is seed-deterministic, and is skipped entirely when
        continuing a fit whose featurizer is already installed."""
        X = np.asarray(X, np.float32)
        if not (self._continuing(fit_kw) and self._landmarks is not None):
            N = X.shape[0]
            m = self.n_landmarks or int(np.ceil(np.sqrt(N)))
            rng = np.random.default_rng(self.seed)
            self._install_featurizer(
                X[rng.choice(N, size=min(m, N), replace=False)])
        return self.svm.fit(X, y, **fit_kw)

    def fit_libsvm(self, path: str, n_features: int,
                   **fit_kw) -> FitResult:
        """Out-of-core nonlinear fit from a libsvm file.

        One reservoir-sampling pass picks the landmarks (O(m D) host
        memory), then the delegate streams RAW rows chunk by chunk —
        featurize-and-accumulate on device, so peak device input
        residency is (prefetch + 2) D-wide chunks and the dataset is
        never resident on host or device. ``fit_kw`` forwards the
        elastic surface; continuing a fit (resume/warm start) reuses
        the installed featurizer and skips the sampling pass."""
        from repro.data import iter_libsvm, reservoir_rows

        cfg = self.svm.config
        if not (self._continuing(fit_kw) and self._landmarks is not None):
            chunks = iter_libsvm(path, cfg.chunk_rows, n_features)
            if self.n_landmarks:
                landmarks, _ = reservoir_rows(chunks, self.n_landmarks,
                                              seed=self.seed)
            else:
                # m = ceil(sqrt(N)) needs N first: count on a cheap extra
                # pass (the file is re-read every iteration anyway).
                n_valid = sum(int(np.sum(np.asarray(mc) > 0))
                              for _, _, mc in chunks)
                m = int(np.ceil(np.sqrt(n_valid)))
                landmarks, _ = reservoir_rows(
                    iter_libsvm(path, cfg.chunk_rows, n_features), m,
                    seed=self.seed)
            self._install_featurizer(landmarks)
        return self.svm.fit_libsvm(path, n_features, **fit_kw)

    # ---------------------------------------------------------- inference
    def _phi(self, X: np.ndarray, add_bias: bool = False) -> np.ndarray:
        """(N, m [+1]) Nyström features from the CACHED projection (no
        eigendecomposition; host-precision oracle path).

        Feature order is PINNED to the device path
        (``kernels.ref.nystrom_phi`` / the fused kernels): the
        phi-space bias column, when requested, is appended LAST — after
        the projected features — and any zero-column padding would come
        after that (the delegate config forbids ``pad_features`` with
        ``phi_spec``, so phi width is landmark count + bias, exactly).
        ``tests/test_svm_serving.py`` holds the parity test."""
        assert self._proj is not None, "fit first"
        K_nm = np.asarray(krn.gram_matrix(
            jnp.asarray(np.asarray(X, np.float32)),
            jnp.asarray(self._landmarks), kind=self.kernel_kind,
            sigma=self.sigma, backend=self.svm.config.backend), np.float64)
        phi = (K_nm @ self._proj.astype(np.float64)).astype(np.float32)
        if add_bias:
            phi = np.concatenate(
                [phi, np.ones((phi.shape[0], 1), np.float32)], axis=1)
        return phi

    def export_servable(self, *, name: str = "svm",
                        posterior_from: tuple | None = None):
        """Freeze into a ``serving.ServableModel`` (fused Nystrom score
        cell; ``posterior_from=(X, y)`` adds the phi-space posterior
        uncertainty columns — exact here, since the phi-space prior is
        lam^{-1} I). See ``PEMSVM.export_servable``."""
        return self.svm.export_servable(name=name,
                                        posterior_from=posterior_from)

    def scorer(self):
        """Cached device-resident ``serving.SVMScorer`` (see
        ``PEMSVM.scorer``)."""
        return self.svm.scorer()

    def predict(self, X: np.ndarray) -> np.ndarray:
        return self.svm.predict(np.asarray(X, np.float32))

    def decision_function(self, X: np.ndarray) -> np.ndarray:
        return self.svm.decision_function(np.asarray(X, np.float32))

    def rmse(self, X: np.ndarray, y: np.ndarray) -> float:
        return self.svm.rmse(np.asarray(X, np.float32), y)

    def score(self, X: np.ndarray, y: np.ndarray) -> float:
        return self.svm.score(np.asarray(X, np.float32), y)
