"""LIN-{EM,MC}-SVR: support vector regression via the *double* scale
mixture (paper Sec 3.2, Lemma 3).

Two augmentation variables per datum for the eps-insensitive loss
max(0, |y - w^T x| - eps_ins):

  gamma_d <- |y_d - w^T x_d - eps_ins|     (Eq. 25)
  omega_d <- |y_d - w^T x_d + eps_ins|     (Eq. 26)

  Sigma^p = X^T diag(1/gamma + 1/omega) X               (Eq. 27)
  mu^p    = X^T ((y - eps)/gamma + (y + eps)/omega)     (Eq. 28; the paper's
            "lambda_d" in Eq. 28 is a typo for gamma_d)

Iteration cost is the paper's "constant factor of 2" over CLS (Sec 4.3).

``svr_local_stats`` is the chunk-callable statistic (exact row sums),
shared by the in-memory step, the mesh SPMD step, and the streaming
driver's per-chunk accumulation — same pattern as
``linear.accumulate_stats``.
"""
from __future__ import annotations

from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.kernels import ops
from . import augment, objective, stats
from .linear import PhiSpec, SVMData, _k_block, chain_keys, multichain_draw


def svr_local_stats(X: jnp.ndarray, y: jnp.ndarray, w: jnp.ndarray, *,
                    mode: str, key: jax.Array | None, eps: float,
                    eps_ins: float, backend: str | None,
                    row0: jnp.ndarray | int = 0,
                    phi=None, phi_spec: PhiSpec | None = None,
                    mask: jnp.ndarray | None = None,
                    col_window: tuple | None = None,
                    rng: str = "host", chain0: int = 0):
    """(pred, gamma, omega, Sigma^p, mu^p) over one row block.

    BOTH mixtures now run as a ``fused_stats`` epilogue (``em_svr`` /
    ``mc_svr``): the kernel computes gamma and omega from the margin
    tile, the combined weights 1/gamma + 1/omega and the coef
    (y-eps)/gamma + (y+eps)/omega, so the whole Eq. 25-28 statistic is
    ONE X stream per iteration instead of the pre-fusion three (pred
    matmul, b matmul, SYRK) — DESIGN.md §Perf/MC-SVR. MC pre-draws both
    mixtures' (nu, u) noise per global row (two independent streams via
    a key split — gamma's mixture from the low key, omega's from the
    high, exactly the split-key rowwise oracle), so the chain stays
    invariant to chunking and sharding layout. Padded rows (X-row = 0,
    y = 0) contribute exactly zero to Sigma and b.

    ``phi``/``phi_spec`` switch to Nystrom phi-space through
    ``ops.nystrom_fused_stats`` under the same SVR epilogues: the block
    featurizes in VMEM and no phi block is materialized, for EM and MC
    alike; ``mask`` zeroes phi rows (a zero X row is not a zero phi
    row) and scales the Sigma weights.

    ``col_window`` narrows Sigma to this model-shard's column block
    (the 2-D ``k_shard_axis`` statistic), composing with both modes
    and the phi path — see ``linear.accumulate_stats``.

    ``rng``/``chain0`` select the MC noise source (see
    ``linear.accumulate_stats``): under the counter modes BOTH
    mixtures come from ONE key — the gamma mixture is counter plane
    2m=0, omega's 2m=2 — replacing the host path's key split; a 2-D
    (K, C) ``w`` under 'fused' runs C chains over the one X stream."""
    epilogue = "em_svr" if mode == "EM" else "mc_svr"
    noise, seed = None, None
    if mode == "MC":
        if rng == "host":
            k_lo, k_hi = jax.random.split(key)
            nu_g, u_g = augment.draw_ig_noise(k_lo, X.shape[0], row0)
            nu_o, u_o = augment.draw_ig_noise(k_hi, X.shape[0], row0)
            noise = (nu_g, u_g, nu_o, u_o)
        elif rng == "fused_predraw":
            noise = augment.draw_fused_noise(key, X.shape[0], row0,
                                             chain0, 4)
        else:
            assert rng == "fused", rng
            seed = augment.pack_seed(key, row0, chain0)
    beta0 = jnp.zeros((X.shape[0],), jnp.float32)  # hinge sign: unused
    if phi_spec is not None:
        landmarks, proj = phi
        if mask is None:
            mask = jnp.ones((X.shape[0],), jnp.float32)
        pred, gamma, omega, b, S = ops.nystrom_fused_stats(
            X, landmarks, proj, y, beta0, w, mask, noise,
            sigma=phi_spec.sigma, kind=phi_spec.kind,
            add_bias=phi_spec.add_bias, epilogue=epilogue, eps=eps,
            eps_ins=eps_ins, col_window=col_window, seed=seed,
            backend=backend)
    else:
        pred, gamma, omega, b, S = ops.fused_stats(
            X, y, beta0, w, None, noise, epilogue=epilogue, eps=eps,
            eps_ins=eps_ins, col_window=col_window, seed=seed,
            backend=backend)
    return pred, gamma, omega, S, b


def svr_chunk_stats(chunk: SVMData, w: jnp.ndarray, key: jax.Array,
                    row0: jnp.ndarray, *, mode: str, eps: float,
                    eps_ins: float, backend: str | None, phi=None,
                    phi_spec: PhiSpec | None = None,
                    rng: str = "host", n_chains: int = 1,
                    chain0: int = 0) -> dict:
    """Streaming E-step body for SVR: one chunk's additive contributions
    (tree-summed across chunks by the stream driver). Multichain chunks
    carry S (C, K, K) / b (K, C) and chain-mean scalar diagnostics —
    see ``linear.cls_chunk_stats``."""
    X, y, mask = chunk
    multi = n_chains > 1
    pred, gamma, omega, S, b = svr_local_stats(
        X, y, w.T if multi else w, mode=mode, key=key, eps=eps,
        eps_ins=eps_ins, backend=backend, row0=row0, phi=phi,
        phi_spec=phi_spec, mask=mask, rng=rng, chain0=chain0)
    if multi:
        maskc = jnp.broadcast_to(mask[:, None], pred.shape)
        return {
            "S": S,
            "b": b,
            "loss": objective.svr_obj_terms(pred, y[:, None], eps_ins,
                                            maskc) / n_chains,
            "gamma_sum": jnp.sum(gamma * maskc) / n_chains,
            "omega_sum": jnp.sum(omega * maskc) / n_chains,
            "mask_sum": jnp.sum(mask),
        }
    return {
        "S": S,
        "b": b,
        "loss": objective.svr_obj_terms(pred, y, eps_ins, mask),
        "gamma_sum": jnp.sum(gamma * mask),
        "omega_sum": jnp.sum(omega * mask),
        "mask_sum": jnp.sum(mask),
    }


@partial(jax.jit, static_argnames=("mode", "lam", "eps", "eps_ins", "jitter",
                                   "axes", "triangle", "backend",
                                   "k_shard_axis", "reduce_dtype",
                                   "phi_spec", "rng", "n_chains", "chain0"))
def svr_step(data: SVMData, w: jnp.ndarray, key: jax.Array, *,
             mode: str = "EM", lam: float = 1.0, eps: float = 1e-6,
             eps_ins: float = 1e-3, jitter: float = 1e-6,
             axes: Sequence[str] = (), triangle: bool = True,
             backend: str | None = None,
             k_shard_axis: str | None = None,
             reduce_dtype: str | None = None,
             phi=None, phi_spec: PhiSpec | None = None,
             live: jnp.ndarray | None = None,
             rng: str = "host", n_chains: int = 1, chain0: int = 0):
    """One LIN-*-SVR iteration. Returns (w_new, aux dict). ``live``
    renormalizes the reductions around dropped replicas (stats.preduce).
    ``rng``/``n_chains``/``chain0`` mirror ``linear.cls_step``: the
    weight state is chain-major (C, K) when n_chains > 1."""
    X, y, mask = data
    multi = n_chains > 1
    row0 = stats.shard_row_offset(X.shape[0], axes)

    col_window = (_k_block(w.shape[0], k_shard_axis)
                  if k_shard_axis is not None else None)
    pred, gamma, omega, S, b = svr_local_stats(
        X, y, w.T if multi else w, mode=mode, key=key, eps=eps,
        eps_ins=eps_ins, backend=backend, row0=row0, phi=phi,
        phi_spec=phi_spec, mask=mask, col_window=col_window, rng=rng,
        chain0=chain0)
    if k_shard_axis is None:
        S, b = stats.reduce_stats(S, b, axes, triangle=triangle,
                                  reduce_dtype=reduce_dtype, live=live)
    else:
        S, b = stats.reduce_kshard(S, b, axes, k_shard_axis,
                                   reduce_dtype=reduce_dtype, live=live)

    if multi:
        w_new = multichain_draw(key, S, b, lam, jitter, chain0)
        maskc = jnp.broadcast_to(mask[:, None], pred.shape)
        obj = objective.l2_reg(w_new, lam) / n_chains + stats.preduce(
            objective.svr_obj_terms(pred, y[:, None], eps_ins, maskc),
            axes, live) / n_chains
        return w_new, {
            "objective": obj,
            "gamma_mean": stats.masked_mean(gamma, maskc, axes, live),
            "omega_mean": stats.masked_mean(omega, maskc, axes, live)}

    L, mu = stats.posterior_params(S, b, lam, jitter=jitter)
    if mode == "EM":
        w_new = mu
    elif rng == "host":
        w_new = stats.draw_weight(key, L, mu)
    else:
        w_new = stats.draw_weight(chain_keys(key, chain0, 1)[0], L, mu)

    obj = objective.l2_reg(w_new, lam) + stats.preduce(
        objective.svr_obj_terms(pred, y, eps_ins, mask), axes, live)
    return w_new, {"objective": obj,
                   "gamma_mean": stats.masked_mean(gamma, mask, axes, live),
                   "omega_mean": stats.masked_mean(omega, mask, axes, live)}
