"""LIN-{EM,MC}-SVR: support vector regression via the *double* scale
mixture (paper Sec 3.2, Lemma 3).

Two augmentation variables per datum for the eps-insensitive loss
max(0, |y - w^T x| - eps_ins):

  gamma_d <- |y_d - w^T x_d - eps_ins|     (Eq. 25)
  omega_d <- |y_d - w^T x_d + eps_ins|     (Eq. 26)

  Sigma^p = X^T diag(1/gamma + 1/omega) X               (Eq. 27)
  mu^p    = X^T ((y - eps)/gamma + (y + eps)/omega)     (Eq. 28; the paper's
            "lambda_d" in Eq. 28 is a typo for gamma_d)

Iteration cost is the paper's "constant factor of 2" over CLS (Sec 4.3).
"""
from __future__ import annotations

from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.kernels import ops
from . import augment, objective, stats
from .linear import SVMData


@partial(jax.jit, static_argnames=("mode", "lam", "eps", "eps_ins", "jitter",
                                   "axes", "triangle", "backend",
                                   "reduce_dtype"))
def svr_step(data: SVMData, w: jnp.ndarray, key: jax.Array, *,
             mode: str = "EM", lam: float = 1.0, eps: float = 1e-6,
             eps_ins: float = 1e-3, jitter: float = 1e-6,
             axes: Sequence[str] = (), triangle: bool = True,
             backend: str | None = None,
             reduce_dtype: str | None = None):
    """One LIN-*-SVR iteration. Returns (w_new, aux dict)."""
    X, y, mask = data
    gkey = key
    if axes:
        for ax in axes:
            gkey = jax.random.fold_in(gkey, jax.lax.axis_index(ax))
    k_lo, k_hi = jax.random.split(gkey)

    pred = X.astype(jnp.float32) @ w.astype(jnp.float32)
    res = y.astype(jnp.float32) - pred
    gamma = augment.update_gamma(mode, k_lo, res - eps_ins, eps)
    omega = augment.update_gamma(mode, k_hi, res + eps_ins, eps)

    weights = 1.0 / gamma + 1.0 / omega
    S = ops.syrk_tri(X, weights, backend=backend)
    coef = (y - eps_ins) / gamma + (y + eps_ins) / omega
    b = X.astype(jnp.float32).T @ coef
    S, b = stats.reduce_stats(S, b, axes, triangle=triangle,
                              reduce_dtype=reduce_dtype)

    L, mu = stats.posterior_params(S, b, lam, jitter=jitter)
    w_new = mu if mode == "EM" else stats.draw_weight(key, L, mu)

    obj = objective.l2_reg(w_new, lam) + stats.preduce(
        objective.svr_obj_terms(pred, y, eps_ins, mask), axes)
    return w_new, {"objective": obj,
                   "gamma_mean": stats.masked_mean(gamma, mask, axes),
                   "omega_mean": stats.masked_mean(omega, mask, axes)}
