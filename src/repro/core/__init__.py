"""The paper's primary contribution: PEMSVM — parallel EM/MCMC SVM via
Polson-Scott data augmentation (see DESIGN.md).

Public API:
  SVMConfig / PEMSVM / FitResult  — the solver facade (all six option axes)
  MaxMarginHead                   — composite max-margin models over backbones
  lam_from_C                      — paper's C <-> lambda mapping
"""
from .head import MaxMarginHead, last_token_pool, mean_pool  # noqa: F401
from .nystrom import NystromSVM  # noqa: F401
from .linear import PhiSpec, SVMData  # noqa: F401
from .solver import FitResult, PEMSVM, SVMConfig, lam_from_C  # noqa: F401
