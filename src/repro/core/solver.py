"""PEMSVM driver: the paper's solver facade.

Option axes exactly as paper Sec 4.2 — formulation LIN|KRN, algorithm
EM|MC, task CLS|MLT|SVR — addressable as option strings like "LIN-EM-CLS".

Implements the paper's run protocol:
  * objective evaluated every iteration; stop when the iterative change
    falls to tol*N (Sec 5.5, tol = 0.001),
  * gamma clamping for support vectors (Sec 5.7.3),
  * MC posterior averaging with a burn-in (Sec 5.13): the reported weight
    is the running average of samples after ``burnin`` iterations,
  * bias absorbed as a fixed unit feature (Sec 2.1).

With ``mesh`` given, data is row-sharded over the mesh's data axes and every
iteration is one SPMD step (map -> psum -> replicated solve), the Fig. 1
architecture. Without a mesh it runs the identical code single-device.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from . import distributed, kernel as krn, linear, multiclass, objective, svr
from .linear import SVMData

FORMULATIONS = ("LIN", "KRN")
ALGORITHMS = ("EM", "MC")
TASKS = ("CLS", "MLT", "SVR")


def lam_from_C(C: float) -> float:
    """Paper Eq. 1: min 1/2 lam ||w||^2 + 2 sum xi  <=>  C = 2/lam."""
    return 2.0 / C


@dataclasses.dataclass(frozen=True)
class SVMConfig:
    formulation: str = "LIN"
    algorithm: str = "EM"
    task: str = "CLS"
    lam: float = 1.0
    eps: float = 1e-6            # gamma clamp (paper Sec 5.7.3)
    eps_ins: float = 1e-3        # SVR precision (paper Sec 3.2 footnote)
    num_classes: int = 2
    kernel: str = "rbf"
    sigma: float = 1.0
    max_iters: int = 200
    min_iters: int = 10          # guard against flat-start plateaus
    patience: int = 1            # consecutive small-change iters required
    tol: float = 1e-3            # stop at |delta obj| <= tol * N (Sec 5.5)
    burnin: int = 10             # MC burn-in (Sec 5.13)
    jitter: float | None = None  # None -> 1e-7 (LIN), 1e-4 (KRN fp32 Gram)
    triangle_reduce: bool = True
    reduce_dtype: str | None = None  # 'bfloat16' = compressed reduction
    backend: str | None = None   # kernels backend: ref | interpret | pallas
    add_bias: bool = True
    seed: int = 0
    k_shard_axis: str | None = None  # beyond-paper 2-D Sigma statistic

    def __post_init__(self):
        assert self.formulation in FORMULATIONS, self.formulation
        assert self.algorithm in ALGORITHMS, self.algorithm
        assert self.task in TASKS, self.task
        if self.formulation == "KRN" and self.task != "CLS":
            raise NotImplementedError(
                "paper provides KRN for binary classification")
        if self.jitter is None:
            object.__setattr__(
                self, "jitter",
                1e-4 if self.formulation == "KRN" else 1e-7)

    @classmethod
    def from_options(cls, options: str, **kw) -> "SVMConfig":
        f, a, t = options.upper().split("-")
        return cls(formulation=f, algorithm=a, task=t, **kw)

    @property
    def options(self) -> str:
        return f"{self.formulation}-{self.algorithm}-{self.task}"


@dataclasses.dataclass
class FitResult:
    weights: np.ndarray             # averaged weights (MC) / final (EM)
    last_sample: np.ndarray
    objective: list
    aux_history: dict
    n_iters: int
    converged: bool


class PEMSVM:
    """Parallel EM/MCMC SVM (paper's PEMSVM)."""

    def __init__(self, config: SVMConfig, mesh: Mesh | None = None,
                 data_axes: Sequence[str] | None = None):
        self.config = config
        self.mesh = mesh
        if mesh is not None and data_axes is None:
            excl = (config.k_shard_axis,) if config.k_shard_axis else ()
            data_axes = distributed.data_axes_of(mesh, model_axes=excl)
        self.data_axes: tuple[str, ...] = tuple(data_axes or ())
        self._train_X: np.ndarray | None = None  # kept for KRN prediction

    # ------------------------------------------------------------- fitting
    def fit(self, X: np.ndarray, y: np.ndarray) -> FitResult:
        cfg = self.config
        X = np.asarray(X, np.float32)
        y = np.asarray(y)
        if cfg.add_bias and cfg.formulation == "LIN":
            X = np.concatenate([X, np.ones((X.shape[0], 1), np.float32)], 1)
        N = X.shape[0]

        data, prior, state = self._prepare(X, y)
        step = self._build_step(prior is not None)

        key = jax.random.PRNGKey(cfg.seed)
        objs: list[float] = []
        aux_hist: dict[str, list] = {}
        mean_w = None
        n_avg = 0
        n_small = 0
        converged = False
        it = 0
        for it in range(1, cfg.max_iters + 1):
            key, sub = jax.random.split(key)
            args = (data, prior, state, sub) if prior is not None else (
                data, state, sub)
            state, aux = step(*args)
            obj = float(aux["objective"])
            objs.append(obj)
            for k, v in aux.items():
                aux_hist.setdefault(k, []).append(float(v))
            if cfg.algorithm == "MC" and it > cfg.burnin:
                w_np = np.asarray(state, np.float64)
                mean_w = w_np if mean_w is None else (
                    mean_w * n_avg + w_np) / (n_avg + 1)
                n_avg += 1
            # Paper Sec 5.5 stopping rule on the objective change
            # (patience > 1 hardens it against flat starts / MC noise,
            # cf. the paper's own multiple-local-minima caveat in 5.13).
            if len(objs) >= 2 and abs(objs[-1] - objs[-2]) <= cfg.tol * N:
                n_small += 1
            else:
                n_small = 0
            if it >= cfg.min_iters and n_small >= cfg.patience:
                if cfg.algorithm == "EM" or n_avg >= 1:
                    converged = True
                    break

        last = np.asarray(state, np.float32)
        weights = (np.asarray(mean_w, np.float32)
                   if mean_w is not None else last)
        self._weights = weights
        return FitResult(weights=weights, last_sample=last, objective=objs,
                         aux_history=aux_hist, n_iters=it, converged=converged)

    # ------------------------------------------------------ setup helpers
    def _prepare(self, X: np.ndarray, y: np.ndarray):
        cfg = self.config
        N, K = X.shape
        if cfg.task == "CLS":
            target = np.asarray(y, np.float32)
            uniq = set(np.unique(target).tolist())
            assert uniq <= {-1.0, 1.0}, f"CLS labels must be +-1, got {uniq}"
        elif cfg.task == "MLT":
            target = np.asarray(y, np.int32)
        else:
            target = np.asarray(y, np.float32)

        if cfg.formulation == "KRN":
            self._train_X = X
            G = np.asarray(krn.gram_matrix(
                jnp.asarray(X), jnp.asarray(X), kind=cfg.kernel,
                sigma=cfg.sigma, backend=cfg.backend))
            shards = (distributed.num_shards(self.mesh, self.data_axes)
                      if self.mesh else 1)
            chunk = shards * 8
            Npad = ((N + chunk - 1) // chunk) * chunk - N
            Gp = np.asarray(krn.pad_gram(jnp.asarray(G), Npad))
            tp = np.concatenate([target, np.zeros((Npad,), target.dtype)])
            if self.mesh is not None:
                data = distributed.shard_rows(self.mesh, self.data_axes,
                                              Gp, tp)
                prior = jax.device_put(
                    Gp, NamedSharding(self.mesh, P(None, None)))
            else:
                mask = np.concatenate([np.ones(N, np.float32),
                                       np.zeros(Npad, np.float32)])
                data = SVMData(jnp.asarray(Gp), jnp.asarray(tp),
                               jnp.asarray(mask))
                prior = jnp.asarray(Gp)
            state = jnp.zeros((Gp.shape[0],), jnp.float32)
            return data, prior, state

        # LIN
        if self.mesh is not None:
            data = distributed.shard_rows(self.mesh, self.data_axes, X,
                                          target)
        else:
            Xp, tp, mask = distributed.pad_rows(X, target, 1)
            data = SVMData(jnp.asarray(Xp), jnp.asarray(tp),
                           jnp.asarray(mask))
        if cfg.task == "MLT":
            state = jnp.zeros((cfg.num_classes, K), jnp.float32)
        else:
            state = jnp.zeros((K,), jnp.float32)
        if self.mesh is not None:
            state = jax.device_put(state, NamedSharding(
                self.mesh, P(*(None,) * state.ndim)))
        return data, None, state

    def _build_step(self, has_prior: bool):
        cfg = self.config
        axes = self.data_axes if self.mesh is not None else ()
        common = dict(mode=cfg.algorithm, lam=cfg.lam, eps=cfg.eps,
                      jitter=cfg.jitter, axes=tuple(axes),
                      triangle=cfg.triangle_reduce, backend=cfg.backend,
                      reduce_dtype=cfg.reduce_dtype)

        if cfg.formulation == "KRN":
            def step(data, prior, state, key):
                return krn.krn_step(data, prior, state, key, **common)
        elif cfg.task == "CLS":
            def step(data, state, key):
                return linear.cls_step(data, state, key,
                                       k_shard_axis=cfg.k_shard_axis,
                                       **common)
        elif cfg.task == "SVR":
            def step(data, state, key):
                return svr.svr_step(data, state, key,
                                    eps_ins=cfg.eps_ins, **common)
        else:
            def step(data, state, key):
                return multiclass.mlt_step(data, state, key,
                                           num_classes=cfg.num_classes,
                                           **common)

        if self.mesh is None:
            return step
        state_spec = P(None, None) if cfg.task == "MLT" else P(None)
        return distributed.shard_wrap(self.mesh, self.data_axes, step,
                                      state_spec=state_spec,
                                      has_prior=has_prior)

    # ---------------------------------------------------------- inference
    def decision_function(self, X: np.ndarray) -> np.ndarray:
        cfg = self.config
        w = jnp.asarray(self._weights)
        X = np.asarray(X, np.float32)
        if cfg.formulation == "KRN":
            f = krn.decision_function(
                w[: self._train_X.shape[0]], jnp.asarray(self._train_X),
                jnp.asarray(X), kind=cfg.kernel, sigma=cfg.sigma,
                backend=cfg.backend)
            return np.asarray(f)
        if cfg.add_bias:
            X = np.concatenate([X, np.ones((X.shape[0], 1), np.float32)], 1)
        if cfg.task == "MLT":
            return np.asarray(jnp.asarray(X) @ w.T)
        return np.asarray(linear.decision_function(w, jnp.asarray(X)))

    def predict(self, X: np.ndarray) -> np.ndarray:
        f = self.decision_function(X)
        if self.config.task == "MLT":
            return np.argmax(f, axis=1)
        if self.config.task == "SVR":
            return f
        return np.where(f >= 0, 1, -1)

    def score(self, X: np.ndarray, y: np.ndarray) -> float:
        pred = self.predict(X)
        if self.config.task == "SVR":
            return float(np.sqrt(np.mean((pred - np.asarray(y)) ** 2)))
        return float(np.mean(pred == np.asarray(y)))
