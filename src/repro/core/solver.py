"""PEMSVM driver: the paper's solver facade.

Option axes exactly as paper Sec 4.2 — formulation LIN|KRN, algorithm
EM|MC, task CLS|MLT|SVR — addressable as option strings like "LIN-EM-CLS".

Implements the paper's run protocol:
  * objective evaluated every iteration; stop when the iterative change
    falls to tol*N (Sec 5.5, tol = 0.001),
  * gamma clamping for support vectors (Sec 5.7.3),
  * MC posterior averaging with a burn-in (Sec 5.13): the reported weight
    is the running average of samples after ``burnin`` iterations,
  * bias absorbed as a fixed unit feature (Sec 2.1).

With ``mesh`` given, data is row-sharded over the mesh's data axes and every
iteration is one SPMD step (map -> psum -> replicated solve), the Fig. 1
architecture. Without a mesh it runs the identical code single-device.
"""
from __future__ import annotations

import dataclasses
import functools
import itertools
import time
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.checkpoint import Checkpointer
from repro.runtime.policy import FaultPolicy, StragglerError
from repro.runtime.straggler import StepTimeMonitor

from . import (distributed, kernel as krn, linear, multiclass, objective,
               resume as resume_mod, stats, svr)
from .linear import PhiSpec, SVMData

FORMULATIONS = ("LIN", "KRN")
ALGORITHMS = ("EM", "MC")
TASKS = ("CLS", "MLT", "SVR")


def lam_from_C(C: float) -> float:
    """Paper Eq. 1: min 1/2 lam ||w||^2 + 2 sum xi  <=>  C = 2/lam."""
    return 2.0 / C


@dataclasses.dataclass(frozen=True)
class SVMConfig:
    formulation: str = "LIN"
    algorithm: str = "EM"
    task: str = "CLS"
    lam: float = 1.0
    eps: float = 1e-6            # gamma clamp (paper Sec 5.7.3)
    eps_ins: float = 1e-3        # SVR precision (paper Sec 3.2 footnote)
    num_classes: int = 2
    kernel: str = "rbf"
    sigma: float = 1.0
    max_iters: int = 200
    min_iters: int = 10          # guard against flat-start plateaus
    patience: int = 1            # consecutive small-change iters required
    tol: float = 1e-3            # stop at |delta obj| <= tol * N (Sec 5.5)
    driver: str = "scan"         # scan = chunked on-device lax.scan driver
    scan_chunk: int = 16         # device iterations per host sync
    chunk_rows: int = 4096       # stream driver: rows device-resident at once
    prefetch: int = 2            # stream driver: host->device lookahead depth
    burnin: int = 10             # MC burn-in (Sec 5.13)
    jitter: float | None = None  # None -> 1e-7 (LIN), 1e-4 (KRN fp32 Gram)
    triangle_reduce: bool = True
    reduce_dtype: str | None = None  # 'bfloat16' = compressed reduction
    backend: str | None = None   # kernels backend: ref | interpret | pallas
    add_bias: bool = True
    seed: int = 0
    k_shard_axis: str | None = None  # beyond-paper 2-D Sigma statistic
    pad_features: int | None = None  # zero-pad LIN width to a multiple
    phi_spec: PhiSpec | None = None  # Nystrom phi-space mode (NystromSVM)
    fault: FaultPolicy | None = None  # checkpoint/retry/straggler policy
    decay: float = 0.0           # warm-start statistic decay (stream only)
    window: int = 0              # hard-expiry statistics horizon in fit
                                 # generations (stream only; 0 = off) —
                                 # the ring-of-partials alternative to
                                 # decay (stats.StatsWindow)
    rng: str = "host"            # MC noise source: host pre-draw |
                                 # fused (in-kernel counter cipher) |
                                 # fused_predraw (counter stream fed
                                 # through the operand path — the
                                 # bitwise oracle for 'fused')
    n_chains: int = 1            # parallel Gibbs chains over one X
                                 # stream (rng='fused', CLS/SVR LIN)
    chain0: int = 0              # first chain id (counter plane offset)

    def __post_init__(self):
        assert self.formulation in FORMULATIONS, self.formulation
        assert self.algorithm in ALGORITHMS, self.algorithm
        assert self.task in TASKS, self.task
        assert self.driver in ("scan", "loop", "stream"), self.driver
        assert self.scan_chunk >= 1, self.scan_chunk
        assert self.rng in ("host", "fused", "fused_predraw"), self.rng
        assert self.n_chains >= 1, self.n_chains
        assert self.chain0 >= 0, self.chain0
        if self.rng != "host":
            # The counter modes replace the MC Gibbs draws; EM has no
            # draws. The exact-Gram KRN step has no counter plumbing,
            # but a KRN config is also the user-facing surface of
            # NystromSVM (which replaces it with a LIN + phi_spec
            # delegate), so the formulation check lives in
            # PEMSVM.__init__ where only real exact-Gram fits land.
            assert self.algorithm == "MC", (
                f"rng={self.rng!r} selects the MC noise source; "
                "algorithm='EM' draws no noise")
        if self.n_chains > 1:
            # Multichain = C counter planes over one X stream: only the
            # in-kernel counter can address them (the operand paths
            # carry one (N,) stream), and the multichain kernel is the
            # full-width linear CLS/SVR statistic.
            assert self.rng == "fused", (
                "n_chains > 1 requires rng='fused' (the per-chain noise "
                "is derived in-kernel from the chain counter plane)")
            assert self.task in ("CLS", "SVR"), (
                "n_chains > 1 covers CLS/SVR; MLT's class sweep is one "
                "chain (run separate fits with distinct chain0 instead)")
            assert self.phi_spec is None, (
                "n_chains > 1 is the LIN X-space multichain kernel; "
                "the Nystrom phi route is single-chain")
            assert self.k_shard_axis is None, (
                "n_chains > 1 does not compose with the 2-D column-"
                "windowed statistic; drop k_shard_axis")
        # pad_features targets the LIN X-space statistic width (the
        # k_shard divisibility helper); phi-space width is the landmark
        # count + bias, which the user picks directly.
        assert self.pad_features is None or (
            self.pad_features >= 1 and self.phi_spec is None
            and self.formulation == "LIN"), self.pad_features
        assert self.chunk_rows >= 1, self.chunk_rows
        assert self.prefetch >= 1, self.prefetch  # residency = prefetch+2
        # decay re-weights ACCUMULATED statistics between fits — only the
        # stream driver keeps the summed (S, b) on the host-visible path
        # where the frozen previous-fit statistic can be folded in.
        assert 0.0 <= self.decay < 1.0, self.decay
        assert self.decay == 0.0 or self.driver == "stream", (
            "decay (online warm-start statistics) requires "
            "driver='stream'")
        # window is decay's hard-expiry sibling: a ring of the last
        # window-1 generations' FRESH (S, b) partials summed at full
        # weight, older generations dropped exactly. Same stream-only
        # constraint, and the two semantics are mutually exclusive.
        assert self.window >= 0, self.window
        assert self.window == 0 or self.driver == "stream", (
            "window (hard-expiry warm-start statistics) requires "
            "driver='stream'")
        assert self.window == 0 or self.decay == 0.0, (
            "window and decay are competing warm-start semantics "
            "(hard expiry vs geometric); pick one")
        # KRN x {SVR, MLT, stream} is valid CONFIGURATION now: NystromSVM
        # serves all of it through the phi-space route. Only the exact
        # N x N-Gram solver (PEMSVM) rejects those combinations, at fit
        # time — see PEMSVM._prepare / fit.
        if self.phi_spec is not None:
            assert self.formulation == "LIN", (
                "phi_spec is the LIN-delegate mode NystromSVM builds; "
                "construct a KRN config and wrap it in NystromSVM")
            assert not self.add_bias, (
                "phi_spec carries its own phi-space bias column; "
                "X-space add_bias must be False (a bias feature would "
                "perturb the RBF distances)")
        if self.jitter is None:
            object.__setattr__(
                self, "jitter",
                1e-4 if self.formulation == "KRN" else 1e-7)

    @classmethod
    def from_options(cls, options: str, **kw) -> "SVMConfig":
        f, a, t = options.upper().split("-")
        return cls(formulation=f, algorithm=a, task=t, **kw)

    @property
    def options(self) -> str:
        return f"{self.formulation}-{self.algorithm}-{self.task}"


@dataclasses.dataclass
class FitResult:
    weights: np.ndarray             # averaged weights (MC) / final (EM)
    last_sample: np.ndarray
    objective: list
    aux_history: dict
    n_iters: int
    converged: bool
    n_host_syncs: int = 0           # device->host objective transfers
    peak_input_bytes: int = 0       # stream driver: max device-resident input
    stats: dict | None = None       # effective (S, b) at the final M-step
    #                                 (stream driver with decay > 0 or
    #                                 window >= 1) — feed back via
    #                                 fit(warm_start=result)
    straggler_events: list = dataclasses.field(default_factory=list)
    resumed_at: int | None = None   # completed iterations restored from
    #                                 checkpoint (None = fresh fit)
    n_checkpoints: int = 0          # snapshots committed during this fit
    stats_window: list | None = None  # hard-expiry ring for the NEXT
    #                                 generation (stream, window >= 1):
    #                                 this fit's fresh (S, b) plus the
    #                                 retained donors, newest first
    loader_retries: int = 0         # transient loader failures absorbed
    #                                 by retrying_chunks during this fit
    loader_backoff_s: float = 0.0   # seconds slept backing those off
    chain_weights: np.ndarray | None = None  # (C, K) per-chain posterior
    #                                 means (n_chains > 1) — ``weights``
    #                                 is their cross-chain mean
    chain_std: np.ndarray | None = None      # (K,) cross-chain std
    #                                 (ddof=1) of the per-chain means


@functools.lru_cache(maxsize=256)
def _build_step_fn(cfg: SVMConfig, mesh: Mesh | None,
                   data_axes: tuple, has_prior: bool,
                   has_live: bool = False):
    """One-iteration step function for (config, mesh). Module-level and
    lru-cached so the jit/scan caches are shared across PEMSVM instances
    with identical configuration (SVMConfig is frozen, hence hashable).

    ``has_live`` appends a trailing liveness-vector operand (mesh path
    only): each data shard's 0/1 weight, renormalizing the reductions
    around dropped replicas (``stats.preduce``); all-ones is bitwise the
    plain psum, so the mesh drivers thread it unconditionally.
    """
    axes = data_axes if mesh is not None else ()
    common = dict(mode=cfg.algorithm, lam=cfg.lam, eps=cfg.eps,
                  jitter=cfg.jitter, axes=tuple(axes),
                  triangle=cfg.triangle_reduce, backend=cfg.backend,
                  reduce_dtype=cfg.reduce_dtype)
    if cfg.formulation != "KRN":
        # Counter-rng plumbing (LIN steps only; KRN keeps the legacy
        # host draw and the config rejects rng != 'host' there).
        common.update(rng=cfg.rng, chain0=cfg.chain0)
    chains = dict(n_chains=cfg.n_chains)

    def _live(rest):
        return rest[0] if rest else None

    if cfg.formulation == "KRN":
        def step(data, prior, state, key, *rest):
            return krn.krn_step(data, prior, state, key,
                                live=_live(rest), **common)
    elif cfg.phi_spec is not None:
        # Nystrom phi-space steps: the featurizer arrays (landmarks,
        # K_mm^{-1/2}) ride the replicated ``prior`` slot — the same
        # plumbing the exact-KRN Gram prior uses — so the scan driver
        # and shard_wrap carry them without a second mechanism.
        if cfg.task == "CLS":
            def step(data, prior, state, key, *rest):
                return linear.cls_step(data, state, key,
                                       k_shard_axis=cfg.k_shard_axis,
                                       phi=prior, phi_spec=cfg.phi_spec,
                                       live=_live(rest), **common,
                                       **chains)
        elif cfg.task == "SVR":
            def step(data, prior, state, key, *rest):
                return svr.svr_step(data, state, key,
                                    eps_ins=cfg.eps_ins, phi=prior,
                                    k_shard_axis=cfg.k_shard_axis,
                                    phi_spec=cfg.phi_spec,
                                    live=_live(rest), **common,
                                    **chains)
        else:
            def step(data, prior, state, key, *rest):
                return multiclass.mlt_step(data, state, key,
                                           num_classes=cfg.num_classes,
                                           k_shard_axis=cfg.k_shard_axis,
                                           phi=prior,
                                           phi_spec=cfg.phi_spec,
                                           live=_live(rest), **common)
    elif cfg.task == "CLS":
        def step(data, state, key, *rest):
            return linear.cls_step(data, state, key,
                                   k_shard_axis=cfg.k_shard_axis,
                                   live=_live(rest), **common, **chains)
    elif cfg.task == "SVR":
        def step(data, state, key, *rest):
            return svr.svr_step(data, state, key,
                                k_shard_axis=cfg.k_shard_axis,
                                eps_ins=cfg.eps_ins,
                                live=_live(rest), **common, **chains)
    else:
        def step(data, state, key, *rest):
            return multiclass.mlt_step(data, state, key,
                                       k_shard_axis=cfg.k_shard_axis,
                                       num_classes=cfg.num_classes,
                                       live=_live(rest), **common)

    if mesh is None:
        return step
    state_spec = (P(None, None) if cfg.task == "MLT" or cfg.n_chains > 1
                  else P(None))
    prior_spec = ((P(None, None), P(None, None))
                  if cfg.phi_spec is not None else P(None, None))
    return distributed.shard_wrap(mesh, data_axes, step,
                                  state_spec=state_spec,
                                  has_prior=has_prior,
                                  prior_spec=prior_spec,
                                  has_live=has_live)


@functools.lru_cache(maxsize=256)
def _chunk_runner(cfg: SVMConfig, mesh: Mesh | None, data_axes: tuple,
                  has_prior: bool, has_live: bool = False):
    """Jitted scan-of-steps chunk runner for the scan driver.

    Runs len(its) iterations fully on device, carrying the MC sample
    sum and the Sec 5.5 objective-change stopping statistic in scan
    state, and stacking the per-iteration aux dict as the trace.
    lru-cached (jit caches key on function identity) so same-config
    fits never retrace.
    """
    step = _build_step_fn(cfg, mesh, data_axes, has_prior, has_live)
    is_mc = cfg.algorithm == "MC"

    def body(operands, carry, it):
        data, prior, tol_n, live = operands
        (state, samp_sum, n_avg, key, prev_obj, n_small, done,
         it_done) = carry
        key, sub = jax.random.split(key)
        args = (data, prior, state, sub) if has_prior else (
            data, state, sub)
        if has_live:
            args = args + (live,)
        new_state, aux = step(*args)
        obj = aux["objective"]
        # Freeze every statistic once converged; the loop driver would
        # have stopped here, so later iterations are exact no-ops.
        state = jax.tree_util.tree_map(
            lambda new, old: jnp.where(done, old, new), new_state, state)
        take = jnp.logical_and(~done, is_mc & (it > cfg.burnin))
        n_avg_new = n_avg + take.astype(jnp.int32)
        # Per-chunk fp32 sample sum; the host zeroes it between chunks
        # and combines the chunk sums in float64 (see _fit_scan).
        samp_sum = jnp.where(take, samp_sum + new_state, samp_sum)
        # Paper Sec 5.5 stopping rule on the objective change
        # (patience > 1 hardens it against flat starts / MC noise,
        # cf. the paper's multiple-local-minima caveat in 5.13).
        small = jnp.abs(obj - prev_obj) <= tol_n
        n_small = jnp.where(done, n_small,
                            jnp.where(small, n_small + 1, 0))
        conv_now = jnp.logical_and(
            ~done,
            (it >= cfg.min_iters) & (n_small >= cfg.patience)
            & ((not is_mc) | (n_avg_new >= 1)))
        it_done = jnp.where(conv_now, it, it_done)
        prev_obj = jnp.where(done, prev_obj, obj)
        carry = (state, samp_sum, n_avg_new, key, prev_obj, n_small,
                 done | conv_now, it_done)
        return carry, aux

    def runner(data, prior, carry, its, tol_n, live=None):
        return jax.lax.scan(
            functools.partial(body, (data, prior, tol_n, live)), carry,
            its)

    return jax.jit(runner)


@functools.lru_cache(maxsize=256)
def _stream_fns(cfg: SVMConfig):
    """Jitted per-chunk accumulators + replicated M-step for the stream
    driver. lru-cached on the frozen config so repeated fits share jit
    caches; shapes fixed by chunk_rows mean ONE trace per dataset width.

    Contract: ``chunk`` maps one (chunk_rows, K) block to a dict of
    row-additive contributions; ``add`` tree-sums them; ``mstep`` is the
    unchanged replicated posterior solve/draw on the summed statistics.
    For MLT, ``chunk``/``mstep`` additionally take the traced class
    index (one solve per class per sweep) and ``obj`` scores the
    end-of-sweep W on one block.

    Every chunk/obj fn takes a trailing ``phi`` operand — None for LIN,
    the (landmarks, projection) pair for the Nystrom phi-space route,
    in which case the chunk featurizes ON DEVICE and the raw D-wide
    rows are all that ever crosses host->device.
    """
    common = dict(mode=cfg.algorithm, eps=cfg.eps, backend=cfg.backend,
                  phi_spec=cfg.phi_spec)
    add = jax.jit(functools.partial(jax.tree_util.tree_map, jnp.add))

    if cfg.task == "MLT":
        @jax.jit
        def chunk(data, W, key, row0, y_cls, phi):
            return multiclass.mlt_class_chunk_stats(
                data, W, key, row0, y_cls,
                num_classes=cfg.num_classes, phi=phi, **common,
                rng=cfg.rng, chain0=cfg.chain0)

        @jax.jit
        def mstep(W, S, b, key, y_cls):
            L, mu = stats.posterior_params(S, b, cfg.lam,
                                           jitter=cfg.jitter)
            if cfg.algorithm == "EM":
                w_new = mu
            else:
                ky = jax.random.fold_in(key, y_cls)
                if cfg.rng != "host":
                    ky = jax.random.fold_in(ky, cfg.chain0)
                w_new = stats.draw_weight(ky, L, mu)
            return W.at[y_cls].set(w_new)

        @jax.jit
        def obj(data, W, phi):
            return multiclass.mlt_chunk_obj(data, W, phi, cfg.phi_spec,
                                            cfg.backend)

        @jax.jit
        def obj_total(W, loss_sum):
            return objective.l2_reg(W, cfg.lam) + loss_sum

        return dict(chunk=chunk, add=add, mstep=mstep, obj=obj,
                    obj_total=obj_total)

    chains = dict(rng=cfg.rng, n_chains=cfg.n_chains, chain0=cfg.chain0)
    if cfg.task == "SVR":
        @jax.jit
        def chunk(data, w, key, row0, phi):
            return svr.svr_chunk_stats(data, w, key, row0,
                                       eps_ins=cfg.eps_ins, phi=phi,
                                       **common, **chains)
    else:
        @jax.jit
        def chunk(data, w, key, row0, phi):
            return linear.cls_chunk_stats(data, w, key, row0, phi=phi,
                                          **common, **chains)

    @jax.jit
    def mstep(S, b, loss_sum, key):
        if cfg.n_chains > 1:
            # Per-chain posterior solves + chain-keyed draws; the chunk
            # loss is already the cross-chain mean, so only l2 scales.
            w_new = linear.multichain_draw(key, S, b, cfg.lam,
                                           cfg.jitter, cfg.chain0)
            obj = (objective.l2_reg(w_new, cfg.lam) / cfg.n_chains
                   + loss_sum)
            return w_new, obj
        L, mu = stats.posterior_params(S, b, cfg.lam, jitter=cfg.jitter)
        if cfg.algorithm == "EM":
            w_new = mu
        elif cfg.rng == "host":
            w_new = stats.draw_weight(key, L, mu)
        else:
            w_new = stats.draw_weight(
                linear.chain_keys(key, cfg.chain0, 1)[0], L, mu)
        return w_new, objective.l2_reg(w_new, cfg.lam) + loss_sum

    return dict(chunk=chunk, add=add, mstep=mstep)


class _FitRuntime:
    """Per-fit reliability state (DESIGN.md §Reliability): fault policy,
    checkpointer, straggler monitor, the restored resume payload, the
    per-shard liveness vector, and the host loop's scalar state — owned
    HERE (not in loop locals) so the stream driver's mid-pass saver sees
    a consistent snapshot of iteration counters and histories.
    """

    def __init__(self, svm: "PEMSVM", resume_from, resume_step,
                 warm_start, live, fault_hook, epoch: int | None = None):
        cfg = svm.config
        self.svm = svm
        self.policy = cfg.fault or FaultPolicy()
        self.monitor = StepTimeMonitor.from_policy(self.policy)
        self.hook = fault_hook
        self.events: list = []
        self.n_checkpoints = 0
        self.last_saved_it = 0
        self.resumed_at: int | None = None
        self.midpass: dict | None = None
        self.pending_sub = None
        self.cur_it = 0
        from repro.data.pipeline import RetryStats
        self.retry_stats = RetryStats()

        if resume_from is not None and warm_start is not None:
            raise ValueError(
                "resume_from (continue THIS fit from its checkpoint) and "
                "warm_start (start a NEW fit from a finished model) are "
                "mutually exclusive")
        if resume_step is not None and resume_from is None:
            raise ValueError("resume_step without resume_from")

        # ``epoch`` is the attempt's fence token (minted by an outer
        # controller / lease takeover): the writer advances the shared
        # FENCE at open — raising FencedWriterError if this attempt is
        # already superseded — and every commit re-checks it at the
        # rename boundary, so an abandoned zombie attempt can never
        # land a stale snapshot over its successor's line. None keeps
        # the legacy unfenced single-writer behavior.
        self.epoch = epoch
        self.ckpt = (Checkpointer(self.policy.ckpt_dir,
                                  keep_k=self.policy.keep_k,
                                  epoch=epoch)
                     if self.policy.checkpoints_enabled else None)

        self.payload: dict | None = None
        if resume_from is not None:
            src = (resume_from if isinstance(resume_from, Checkpointer)
                   else Checkpointer(str(resume_from),
                                     keep_k=self.policy.keep_k,
                                     epoch=(epoch if self.ckpt is None
                                            else None)))
            self.payload = resume_mod.load_snapshot(src, resume_step)
            resume_mod.check_compatible(self.payload, cfg)
            self.resumed_at = int(self.payload["it"])
            if self.ckpt is None:
                # keep committing to the directory we resumed from, so
                # a chain of preemptions never loses progress
                self.ckpt = src

        self.warm_state = None
        self.prev_stats: dict | None = None
        self.window_entries: list = []
        if warm_start is not None:
            self.warm_state = np.asarray(warm_start.last_sample,
                                         np.float32)
            if cfg.decay > 0.0:
                if warm_start.stats is None:
                    raise ValueError(
                        "decay > 0 folds the previous fit's statistics "
                        "into the new one, but warm_start.stats is None "
                        "— the donor fit must itself run driver='stream' "
                        "with decay > 0 (which populates FitResult.stats)")
                self.prev_stats = {k: np.asarray(v)
                                   for k, v in warm_start.stats.items()}
            if cfg.window >= 2:
                if warm_start.stats_window is None:
                    raise ValueError(
                        "window >= 2 retains the previous generations' "
                        "fresh statistics, but warm_start.stats_window "
                        "is None — the donor fit must itself run "
                        "driver='stream' with window >= 1 (which "
                        "populates FitResult.stats_window)")
                # Hard expiry happens HERE: entries beyond the horizon
                # are dropped before the fit ever folds them.
                self.window_entries = [
                    {k: np.asarray(v) for k, v in e.items()}
                    for e in warm_start.stats_window][: cfg.window - 1]
        if self.payload is not None and self.payload.get("prev_stats"):
            self.prev_stats = self.payload["prev_stats"]
        if self.payload is not None and self.payload.get("window_stats"):
            self.window_entries = self.payload["window_stats"]

        self.live_dev = None
        self._live_host: np.ndarray | None = None
        if svm.mesh is not None:
            n = distributed.num_shards(svm.mesh, svm.data_axes)
            vec = np.ones((n,), np.float32)
            if live is not None:
                live = np.asarray(live, np.float32)
                if live.shape != (n,):
                    raise ValueError(
                        f"live must be one weight per data shard, shape "
                        f"({n},); got {live.shape}")
                vec = live.copy()
            self._live_host = vec
            self._place_live()
        elif live is not None:
            raise ValueError("live (per-shard liveness weights) needs a "
                             "mesh — single-device fits have no shards "
                             "to drop")

    def _place_live(self) -> None:
        svm = self.svm
        sh = NamedSharding(svm.mesh, P(tuple(svm.data_axes)))
        self.live_dev = jax.device_put(self._live_host, sh)

    def drop_shards(self, idxs) -> None:
        """Zero the liveness weight of the given data shards — their
        statistics contributions drop and the psums renormalize
        (``stats.preduce``), the unbiased sum-statistic estimate."""
        if self._live_host is None or not idxs:
            return
        for i in idxs:
            self._live_host[int(i)] = 0.0
        self._place_live()

    # ---------------------------------------------------- host loop state
    def init_loop(self, state0):
        """Restore-or-init the loop scalar state; returns the initial
        device state (restored arrays are placed through
        ``runtime.elastic.remesh``, so a checkpoint written on one mesh
        layout resumes onto whatever mesh this PEMSVM holds)."""
        cfg = self.svm.config
        p = self.payload
        if p is not None:
            restored = np.asarray(p["state"], np.float32)
            if restored.shape != tuple(np.shape(state0)):
                raise ValueError(
                    f"checkpoint state has shape {restored.shape}, this "
                    f"fit expects {tuple(np.shape(state0))} — same "
                    "dataset/featurization required to resume")
            self.key = jnp.asarray(p["key"])
            self.it0 = int(p["it"])
            self.objs = [float(v) for v in p["objs"]]
            self.aux_hist = {k: list(v) for k, v in p["aux"].items()}
            self.n_avg = int(p["n_avg"])
            self.n_small = int(p["n_small"])
            self.mean_w = (np.asarray(p["samp_sum"], np.float64)
                           / self.n_avg if self.n_avg > 0 else None)
            state = self._place_state(restored, state0)
            if p["in_pass"]:
                self.pending_sub = jnp.asarray(p["sub"])
                self.midpass = {
                    "totals": {k: jnp.asarray(v)
                               for k, v in p["totals"].items()},
                    "skip": int(p["chunk_idx"]),
                    "row0": int(p["row0"]),
                }
        else:
            self.key = jax.random.PRNGKey(cfg.seed)
            self.it0 = 0
            self.objs = []
            self.aux_hist = {}
            self.n_avg = 0
            self.n_small = 0
            self.mean_w = None
            state = state0
            if self.warm_state is not None:
                if self.warm_state.shape != tuple(np.shape(state0)):
                    raise ValueError(
                        f"warm_start weights have shape "
                        f"{self.warm_state.shape}, this fit expects "
                        f"{tuple(np.shape(state0))}")
                state = self._place_state(self.warm_state, state0)
        self.last_saved_it = self.it0
        return state

    def _place_state(self, host_state: np.ndarray, like):
        svm = self.svm
        if svm.mesh is None:
            return jnp.asarray(host_state)
        from repro.runtime.elastic import remesh
        spec = P(*(None,) * np.ndim(host_state))
        return remesh(host_state, NamedSharding(svm.mesh, spec))

    # -------------------------------------------------------- checkpoints
    def samp_sum_of(self, state) -> np.ndarray:
        if self.mean_w is not None:
            return np.asarray(self.mean_w, np.float64) * self.n_avg
        return np.zeros(np.shape(state), np.float64)

    def boundary_due(self, it: int) -> bool:
        return (self.ckpt is not None and self.policy.ckpt_every > 0
                and it - self.last_saved_it >= self.policy.ckpt_every)

    def save_snapshot(self, it: int, state, *, converged: bool = False,
                      samp_sum=None, n_syncs: int | None = None,
                      sub=None, totals: dict | None = None,
                      chunk_idx: int = 0, row0: int = 0,
                      blocking: bool = False) -> None:
        if self.ckpt is None:
            return
        resume_mod.save_snapshot(
            self.ckpt, self.svm.config, it=it, state=state, key=self.key,
            samp_sum=(self.samp_sum_of(state) if samp_sum is None
                      else samp_sum),
            n_avg=self.n_avg, n_small=self.n_small, objs=self.objs,
            aux_hist=self.aux_hist,
            n_syncs=len(self.objs) if n_syncs is None else n_syncs,
            converged=converged, prev_stats=self.prev_stats,
            window_stats=self.window_entries or None, sub=sub,
            totals=totals, chunk_idx=chunk_idx, row0=row0,
            blocking=blocking)
        self.n_checkpoints += 1
        if totals is None:
            self.last_saved_it = it

    def flush(self) -> None:
        """Drain the async checkpoint writer at fit exit — normal OR
        unwinding (preemption/straggler): once fit returns or raises,
        every enqueued snapshot is committed, so the caller can resume
        from the directory immediately without racing the writer. A
        background write failure is recorded as an event rather than
        raised (it must not mask the exception being unwound; the
        on-disk state simply stays at the previous commit)."""
        if self.ckpt is None:
            return
        try:
            self.ckpt.wait()
        except Exception as e:  # noqa: BLE001
            self.events.append({"checkpoint_error": repr(e)})

    # ---------------------------------------------------------- straggler
    def observe(self, it: int, seconds: float) -> None:
        if not self.monitor.observe(it, seconds):
            return
        self.events.append(
            {"it": it, "seconds": float(seconds),
             "ema": float(self.monitor.ema)})
        pol = self.policy
        if pol.on_straggler == "raise":
            raise StragglerError(
                f"iteration {it} took {seconds:.4f}s > "
                f"{pol.straggler_threshold} x EMA "
                f"{self.monitor.ema:.4f}s")
        if pol.on_straggler == "drop":
            self.drop_shards(self.svm._suspect_shards)
            self.svm._suspect_shards.clear()


class PEMSVM:
    """Parallel EM/MCMC SVM (paper's PEMSVM)."""

    def __init__(self, config: SVMConfig, mesh: Mesh | None = None,
                 data_axes: Sequence[str] | None = None):
        if config.formulation == "KRN" and config.rng != "host":
            # NystromSVM never forwards its KRN surface config here (it
            # builds a LIN + phi_spec delegate), so any KRN config that
            # reaches PEMSVM is a real exact-Gram fit.
            raise ValueError(
                f"rng={config.rng!r} needs the fused LIN statistics; the "
                "exact-Gram KRN step has no counter plumbing — use "
                "NystromSVM for kernel models")
        self.config = config
        self.mesh = mesh
        if mesh is not None and data_axes is None:
            excl = (config.k_shard_axis,) if config.k_shard_axis else ()
            data_axes = distributed.data_axes_of(mesh, model_axes=excl)
        self.data_axes: tuple[str, ...] = tuple(data_axes or ())
        self._train_X: np.ndarray | None = None  # kept for KRN prediction
        # Nystrom phi-space featurizer arrays (landmarks, K_mm^{-1/2});
        # set by NystromSVM before fit when config.phi_spec is present.
        self._phi_arrays: tuple | None = None
        # Raw request width D (pre-bias, pre-pad) — recorded at fit so
        # the serving export can validate request shapes.
        self._n_features: int | None = None
        # (source arrays, SVMScorer) — the device-resident scorer is
        # built once per fitted model; identity of the source arrays is
        # the invalidation key (a refit assigns new objects, and the
        # cache holds the old ones alive so ids cannot be recycled).
        self._scorer_cache: tuple | None = None
        # data-shard indices a health probe has flagged; consumed by the
        # fault policy's on_straggler='drop' reaction.
        self._suspect_shards: set[int] = set()
        # (C, K) per-chain posterior means of the last multichain fit
        # (None otherwise) — the serving export turns these into
        # ensemble uncertainty columns.
        self._chain_weights: np.ndarray | None = None

    def report_slow_shard(self, *shard_idx: int) -> None:
        """Designate data-shard indices as straggler suspects. With
        ``FaultPolicy(on_straggler='drop')``, the next straggler event
        zeroes their liveness weight: their statistics contributions
        drop out and every reduction renormalizes (unbiased for the
        SVM's sum-statistics; see ``stats.preduce``). On a real
        multi-host deployment the per-host health probe feeds this; in
        tests the fault harness does."""
        self._suspect_shards.update(int(i) for i in shard_idx)

    # ------------------------------------------------------------- fitting
    def _phi_width(self) -> int:
        """State/statistic dimension in phi-space: projection columns
        plus the phi-space bias column."""
        assert self._phi_arrays is not None, (
            "config.phi_spec is set but no featurizer arrays were "
            "installed; fit through NystromSVM, which selects landmarks "
            "and computes K_mm^{-1/2} before delegating")
        return (self._phi_arrays[1].shape[1]
                + int(self.config.phi_spec.add_bias))

    def fit(self, X: np.ndarray, y: np.ndarray, *,
            resume_from=None, resume_step: int | None = None,
            warm_start: FitResult | None = None,
            live=None, fault_hook: Callable | None = None,
            epoch: int | None = None) -> FitResult:
        """Fit. The keyword group is the elastic/preemption-safe surface:

        ``resume_from`` (dir path or ``Checkpointer``) continues a
        preempted fit from its last committed snapshot (``resume_step``
        pins a specific one) — onto whatever driver/mesh THIS solver
        holds, since checkpoints store logical host tensors
        (``core.resume``). ``warm_start`` (a previous ``FitResult``)
        starts a NEW fit from the donor's last sample; with
        ``config.decay > 0`` (stream driver) the donor's statistics are
        folded in at weight ``decay`` so fresh chunks update an existing
        model instead of refitting from scratch. ``live`` is an initial
        per-data-shard liveness vector (mesh only). ``fault_hook(it)``
        is called once per completed iteration — the deterministic
        fault-injection seam (``repro.runtime.faults``). ``epoch`` is
        the attempt's fence token under multi-controller co-supervision
        (``HostContext.epoch``): commits carry it, restore orders by
        (epoch, step), and a superseded attempt's commits are rejected
        at the rename boundary (DESIGN.md §Reliability).
        """
        rt = _FitRuntime(self, resume_from, resume_step, warm_start,
                         live, fault_hook, epoch)
        cfg = self.config
        X = np.asarray(X, np.float32)
        y = np.asarray(y)
        self._n_features = X.shape[1]
        if cfg.add_bias and cfg.formulation == "LIN":
            X = np.concatenate([X, np.ones((X.shape[0], 1), np.float32)], 1)
        if cfg.pad_features:
            # Explicit zero-column padding of the (post-bias) statistic
            # width — the supported route to a k_shard-divisible K
            # (padded columns carry zero statistics; the ridge pins
            # their weights to 0, so predictions are unchanged).
            from repro.data.pipeline import pad_features_to
            X = pad_features_to(X, cfg.pad_features)
        N = X.shape[0]

        try:
            if cfg.driver == "stream":
                if cfg.formulation == "KRN":
                    raise NotImplementedError(
                        "driver='stream' cannot use the exact N x N Gram "
                        "statistic (not row-chunk-additive); use "
                        "NystromSVM, whose phi-space route streams raw "
                        "rows")
                return self._fit_stream_arrays(X, y, rt)

            data, prior, state = self._prepare(X, y)
            if cfg.driver == "loop":
                step = self._build_step(prior is not None,
                                        self.mesh is not None)
                return self._fit_loop(data, prior, state, step, N, rt)
            return self._fit_scan(data, prior, state, N, rt)
        finally:
            rt.flush()

    def fit_libsvm(self, path: str, n_features: int, rank: int = 0,
                   world: int = 1, **fit_kw) -> FitResult:
        """Fit directly from a libsvm file.

        With ``driver="stream"`` the file is re-read chunk by chunk every
        pass (``data.libsvm.iter_libsvm`` + prefetch) and the dataset is
        never materialized — host AND device residency are bounded by
        ``chunk_rows``. Other drivers load it resident and defer to
        ``fit``. ``rank``/``world`` stripe lines per host (paper Sec 5.6).
        ``fit_kw`` forwards the elastic surface (resume_from /
        warm_start / fault_hook / ...) — see ``fit``.
        """
        from repro.data import iter_libsvm, load_libsvm

        cfg = self.config
        if cfg.driver != "stream":
            X, y = load_libsvm(path, n_features, rank=rank, world=world)
            return self.fit(X, y, **fit_kw)
        if world > 1:
            # A rank stripe is a PARTIAL dataset; stream has no
            # cross-rank reduction (it rejects meshes), so fitting a
            # stripe would silently return weights trained on 1/world
            # of the rows.
            raise NotImplementedError(
                "driver='stream' with world > 1 needs a cross-host "
                "reduction that does not exist yet; stream the full "
                "file (world=1) or use a resident driver on a mesh")
        if cfg.pad_features:
            from repro.data.pipeline import pad_features_to
        self._n_features = n_features
        K = (self._phi_width() if cfg.phi_spec is not None
             else n_features + (1 if cfg.add_bias else 0))
        if cfg.pad_features:
            K = K + (-K) % cfg.pad_features

        def make_chunks():
            for Xc, yc, mc in iter_libsvm(path, cfg.chunk_rows,
                                          n_features, rank=rank,
                                          world=world):
                if cfg.add_bias:
                    # bias column = mask: padded rows keep all-zero X.
                    Xc = np.concatenate([Xc, mc[:, None]], axis=1)
                if cfg.pad_features:
                    Xc = pad_features_to(Xc, cfg.pad_features)
                yield SVMData(Xc, self._stream_target(yc, mc), mc)

        return self.fit_chunks(make_chunks, K, **fit_kw)

    def fit_chunks(self, make_chunks: Callable, K: int, *,
                   resume_from=None, resume_step: int | None = None,
                   warm_start: FitResult | None = None,
                   fault_hook: Callable | None = None,
                   epoch: int | None = None) -> FitResult:
        """Out-of-core fit over an arbitrary restartable chunk source.

        ``make_chunks()`` returns a fresh iterator of host
        ``(X, target, mask)`` blocks with the statistic width already
        final (bias column appended, features padded); ``K`` is that
        width. This is the seam the fault-injection harness wraps
        (``runtime.faults.kill_after_chunks`` etc.) and the entry point
        ``fit_libsvm`` builds on. Loader retries, mid-pass checkpoints
        and resume skipping compose around the factory per
        ``config.fault``; see ``fit`` for the keyword group.
        """
        cfg = self.config
        if cfg.driver != "stream":
            raise ValueError(
                f"fit_chunks is the stream driver's entry point; "
                f"config.driver is {cfg.driver!r}")
        if cfg.formulation == "KRN":
            raise NotImplementedError(
                "driver='stream' cannot use the exact N x N Gram "
                "statistic; use NystromSVM (phi-space streams raw rows)")
        rt = _FitRuntime(self, resume_from, resume_step, warm_start,
                         None, fault_hook, epoch)
        try:
            return self._fit_stream(make_chunks, K, rt)
        finally:
            rt.flush()

    def _stream_target(self, y: np.ndarray, mask: np.ndarray) -> np.ndarray:
        """Validate + cast one chunk's labels (the _prepare checks,
        applied chunk-locally)."""
        task = self.config.task
        if task == "MLT":
            return np.asarray(y, np.int32)
        y = np.asarray(y, np.float32)
        if task == "CLS":
            valid = y[np.asarray(mask) > 0]
            bad = set(np.unique(valid).tolist()) - {-1.0, 1.0}
            assert not bad, f"CLS labels must be +-1, got extras {bad}"
        return y

    def _fit_stream_arrays(self, X: np.ndarray, y: np.ndarray,
                           rt: "_FitRuntime") -> FitResult:
        """driver='stream' on in-memory arrays: chunk views, zero-copy
        per pass (the out-of-core entry point is ``fit_libsvm``)."""
        cfg = self.config
        target = self._stream_target(np.asarray(y), np.ones(len(y)))
        Xp, tp, mask = distributed.pad_rows(X, target, 1,
                                            multiple=cfg.chunk_rows)
        cr = cfg.chunk_rows

        def make_chunks():
            for i0 in range(0, Xp.shape[0], cr):
                yield SVMData(Xp[i0:i0 + cr], tp[i0:i0 + cr],
                              mask[i0:i0 + cr])

        K = (self._phi_width() if cfg.phi_spec is not None
             else X.shape[1])
        return self._fit_stream(make_chunks, K, rt)

    def _fit_scan(self, data, prior, state, N: int,
                  rt: "_FitRuntime") -> FitResult:
        """Chunked on-device driver (DESIGN.md §Perf).

        The per-iteration loop driver blocks on a device->host transfer
        EVERY iteration (``float(aux["objective"])``), serializing
        dispatch with compute. Here ``scan_chunk`` iterations run as one
        ``lax.scan`` with the MC sample accumulator and the Sec 5.5
        objective-change stopping statistic carried in scan state; the
        host sees one transfer per chunk (the stacked aux trace plus the
        convergence flags) and decides whether to launch the next chunk.
        Total host syncs <= ceil(max_iters / scan_chunk).

        The MC posterior average accumulates a per-chunk fp32 sample sum
        on device and combines the chunk sums in float64 on host, so its
        rounding error matches the loop driver's f64 running mean to
        within one chunk's worth of fp32 additions regardless of chain
        length.

        Iterations after the in-chunk convergence point still execute
        (at most scan_chunk - 1 of them, once) but their updates are
        masked out, so results match the loop driver exactly: the same
        per-iteration key splits, the same update-then-check ordering,
        and the trace truncated at the converged iteration.

        Reliability: resume restores the whole carry from a boundary
        snapshot (state, key chain, f64 sample sum, stopping counters)
        and checkpoints/straggler-observes once per host sync — the
        chunk boundary is the natural commit point, since the carry is
        only consistent on host there.
        """
        cfg = self.config
        has_live = self.mesh is not None
        runner = _chunk_runner(cfg, self.mesh, tuple(self.data_axes),
                               prior is not None, has_live)
        tol_n = jnp.float32(cfg.tol * N)
        state = rt.init_loop(state)
        objs = rt.objs
        aux_hist = rt.aux_hist
        # f64 host accumulator of the MC sample sum (driver-independent:
        # the checkpoint stores mean * n_avg, which is exactly this).
        samp_sum = rt.samp_sum_of(state)
        n_syncs = int(rt.payload["n_syncs"]) if rt.payload else 0
        carry = (
            state,                          # current weight / sample
            jnp.zeros_like(state),          # this chunk's MC sample sum
            jnp.int32(rt.n_avg),            # total samples accumulated
            rt.key,                         # iteration key chain
            jnp.float32(objs[-1] if objs else np.inf),  # previous objective
            jnp.int32(rt.n_small),          # consecutive small-change count
            jnp.asarray(False),             # converged flag
            jnp.int32(0),                   # iteration convergence hit
        )
        it0 = rt.it0
        converged = False
        it_done = 0
        while it0 < cfg.max_iters:
            t0 = time.perf_counter()
            chunk = min(cfg.scan_chunk, cfg.max_iters - it0)
            its = jnp.arange(it0 + 1, it0 + chunk + 1, dtype=jnp.int32)
            carry, aux_stack = runner(data, prior, carry, its, tol_n,
                                      rt.live_dev)
            # The single per-chunk host sync: flags, the chunk's sample
            # sum, and the stacked aux trace in one transfer.
            aux_np, chunk_sum, done_np, it_done_np = jax.device_get(
                (aux_stack, carry[1], carry[6], carry[7]))
            converged = bool(done_np)
            it_done = int(it_done_np)
            n_syncs += 1
            samp_sum += np.asarray(chunk_sum, np.float64)
            carry = (carry[0], jnp.zeros_like(carry[1])) + carry[2:]
            valid = (it_done - it0) if converged else chunk
            objs.extend(float(v) for v in aux_np["objective"][:valid])
            for k, v in aux_np.items():
                aux_hist.setdefault(k, []).extend(
                    float(x) for x in v[:valid])
            it0 += chunk
            done_its = it_done if converged else it0
            # Mirror the carry scalars into rt so snapshots see the same
            # loop state the host-loop drivers would.
            rt.key = carry[3]
            rt.n_avg = int(carry[2])
            rt.n_small = int(carry[5])
            rt.cur_it = done_its
            if rt.n_avg > 0:
                rt.mean_w = samp_sum / rt.n_avg
            if not converged and rt.boundary_due(done_its):
                rt.save_snapshot(done_its, carry[0], samp_sum=samp_sum,
                                 n_syncs=n_syncs)
            if rt.hook is not None:
                rt.hook(done_its)
            rt.observe(done_its, time.perf_counter() - t0)
            if converged:
                break

        n_iters = it_done if converged else it0
        last = np.asarray(carry[0], np.float32)
        n_avg = int(carry[2])
        weights = ((samp_sum / n_avg).astype(np.float32)
                   if n_avg > 0 else last)
        self._weights = weights
        if rt.ckpt is not None and n_iters > rt.last_saved_it:
            rt.save_snapshot(n_iters, carry[0], converged=converged,
                             samp_sum=samp_sum, n_syncs=n_syncs,
                             blocking=True)
        return self._finalize_chains(FitResult(
                         weights=weights, last_sample=last, objective=objs,
                         aux_history=aux_hist, n_iters=n_iters,
                         converged=converged, n_host_syncs=n_syncs,
                         straggler_events=rt.events,
                         resumed_at=rt.resumed_at,
                         n_checkpoints=rt.n_checkpoints,
                         loader_retries=rt.retry_stats.retries,
                         loader_backoff_s=rt.retry_stats.backoff_s))

    def _finalize_chains(self, result: FitResult) -> FitResult:
        """Multichain post-processing, shared by every driver: the raw
        fit state is the (C, K) per-chain posterior means — expose them
        as ``chain_weights``, report their cross-chain mean as THE
        weights (a C-chain posterior-mean estimate), and their ddof=1
        std as the per-coordinate ensemble spread. Single-chain fits
        pass through untouched."""
        if self.config.n_chains <= 1:
            self._chain_weights = None
            return result
        cw = np.asarray(result.weights, np.float32)
        result.chain_weights = cw
        result.chain_std = np.std(cw.astype(np.float64), axis=0,
                                  ddof=1).astype(np.float32)
        result.weights = np.mean(cw.astype(np.float64),
                                 axis=0).astype(np.float32)
        self._weights = result.weights
        self._chain_weights = cw
        return result

    def _fit_host_loop(self, iterate, state0,
                       rt: "_FitRuntime") -> FitResult:
        """Shared host-loop tail for the loop and stream drivers: key
        chain, trace bookkeeping, MC posterior averaging (f64 running
        mean) and the paper's Sec 5.5 stopping rule, in ONE place so the
        drivers cannot drift apart semantically.

        ``iterate(sub_key, state) -> (state, aux dict, n_valid)`` runs
        one full iteration (n_valid = valid-row count for the tol*N
        stopping threshold; the stream driver only knows it after its
        first pass, hence per-iteration).

        Reliability (DESIGN.md §Reliability): the loop scalars live on
        ``rt``, which restores them from a checkpoint (``init_loop``)
        and snapshots them at the ``ckpt_every`` cadence. Per-iteration
        order — subkey (a mid-pass resume consumes the SAVED subkey
        instead of splitting, so the chain is exactly the uninterrupted
        one) -> iterate -> histories/averages/stopping counters ->
        boundary snapshot -> fault hook -> straggler observe ->
        convergence. The snapshot precedes the hook so a simulated kill
        at iteration k resumes from k's own commit; snapshots are async
        (a kill racing an in-flight commit just resumes from the
        previous boundary, which replays identical subkeys to the same
        result).
        """
        cfg = self.config
        state = rt.init_loop(state0)
        objs = rt.objs
        aux_hist = rt.aux_hist
        converged = False
        it = rt.it0
        for it in range(rt.it0 + 1, cfg.max_iters + 1):
            t0 = time.perf_counter()
            if rt.pending_sub is not None:
                sub, rt.pending_sub = rt.pending_sub, None
            else:
                rt.key, sub = jax.random.split(rt.key)
            rt.cur_it = it
            state, aux, n_valid = iterate(sub, state)
            objs.append(float(aux["objective"]))
            for k, v in aux.items():
                aux_hist.setdefault(k, []).append(float(v))
            if cfg.algorithm == "MC" and it > cfg.burnin:
                w_np = np.asarray(state, np.float64)
                rt.mean_w = w_np if rt.mean_w is None else (
                    rt.mean_w * rt.n_avg + w_np) / (rt.n_avg + 1)
                rt.n_avg += 1
            # Paper Sec 5.5 stopping rule on the objective change.
            if (len(objs) >= 2
                    and abs(objs[-1] - objs[-2]) <= cfg.tol * n_valid):
                rt.n_small += 1
            else:
                rt.n_small = 0
            if rt.boundary_due(it):
                rt.save_snapshot(it, state)
            if rt.hook is not None:
                rt.hook(it)
            rt.observe(it, time.perf_counter() - t0)
            if it >= cfg.min_iters and rt.n_small >= cfg.patience:
                if cfg.algorithm == "EM" or rt.n_avg >= 1:
                    converged = True
                    break

        if rt.ckpt is not None and it > rt.last_saved_it:
            rt.save_snapshot(it, state, converged=converged,
                             blocking=True)
        last = np.asarray(state, np.float32)
        weights = (np.asarray(rt.mean_w, np.float32)
                   if rt.mean_w is not None else last)
        self._weights = weights
        return self._finalize_chains(FitResult(
                         weights=weights, last_sample=last, objective=objs,
                         aux_history=aux_hist, n_iters=it,
                         converged=converged, n_host_syncs=len(objs),
                         straggler_events=rt.events,
                         resumed_at=rt.resumed_at,
                         n_checkpoints=rt.n_checkpoints,
                         loader_retries=rt.retry_stats.retries,
                         loader_backoff_s=rt.retry_stats.backoff_s))

    def _fit_loop(self, data, prior, state, step, N: int,
                  rt: "_FitRuntime") -> FitResult:
        """Per-iteration Python driver: one host sync per iteration.

        Kept as the semantic oracle for the scan driver (tests compare
        the two traces) and as an escape hatch for step functions whose
        aux is not scan-stackable."""
        has_live = self.mesh is not None

        def iterate(sub, state):
            args = ((data, prior, state, sub) if prior is not None
                    else (data, state, sub))
            if has_live:
                args = args + (rt.live_dev,)
            state, aux = step(*args)
            return state, aux, N

        return self._fit_host_loop(iterate, state, rt)

    def _fit_stream(self, make_chunks, K: int,
                    rt: "_FitRuntime") -> FitResult:
        """Out-of-core driver (DESIGN.md §Perf/Streaming).

        The paper's Fig. 1 iteration is a map-reduce over row shards:
        Sigma and the mu-numerator are exact sums over rows, so the
        E-step streams fixed-shape chunks through the same fused/SYRK
        kernels the resident drivers use (``accumulate_stats``),
        tree-summing per-chunk contributions on device, then runs the
        unchanged replicated M-step. Peak device residency is the
        (prefetch + 2) in-flight chunks plus the O(K^2) statistics —
        independent of N (``FitResult.peak_input_bytes``).

        Host-loop semantics (stopping rule, key chain, MC posterior
        averaging) are literally ``_fit_loop``'s — both feed the shared
        ``_fit_host_loop`` tail; with the rowwise MC gamma draw the
        sampled chain is also chunking-invariant, so stream fits match
        the resident drivers to fp32 reassociation tolerance for BOTH
        algorithms. One host sync per pass (the summed statistics),
        M + 1 passes per iteration for MLT.

        Reliability (DESIGN.md §Reliability): the chunk source is
        wrapped in ``retrying_chunks`` per the fault policy (flaky
        loaders degrade to retries, restarting the source past the
        chunks already folded); with ``ckpt_chunks > 0`` a MID-PASS
        snapshot commits every n chunks — pre-iteration state, the
        iteration subkey and the partial totals — and resume skips the
        already-folded chunks and continues the same pass, bit-for-bit.
        With ``config.decay > 0`` a warm-started fit folds the donor's
        statistics in at weight decay each M-step (an exponentially
        decayed window over fit generations); with ``config.window >= 1``
        it instead folds a HARD-EXPIRY ring of the last window-1
        generations' fresh partials at full weight
        (``stats.StatsWindow`` — exact data expiry for the online
        scenario). Either way the loss/objective stays fresh-data-only;
        ``FitResult.stats`` carries the effective statistics and
        ``FitResult.stats_window`` the advanced ring for the next
        generation.
        """
        cfg = self.config
        if self.mesh is not None:
            raise NotImplementedError(
                "driver='stream' is single-process: on a mesh, stream "
                "per-host shards via data_axes striping instead "
                "(rank/world in fit_libsvm)")
        from repro.data import ChunkPrefetcher, retrying_chunks

        fns = _stream_fns(cfg)
        is_mlt = cfg.task == "MLT"
        if is_mlt:
            state0 = jnp.zeros((cfg.num_classes, K), jnp.float32)
        elif cfg.n_chains > 1:
            state0 = jnp.zeros((cfg.n_chains, K), jnp.float32)
        else:
            state0 = jnp.zeros((K,), jnp.float32)
        # Nystrom featurizer arrays ride along to every chunk call; the
        # raw D-wide rows are the only per-chunk host->device traffic.
        phi = (tuple(jnp.asarray(a) for a in self._phi_arrays)
               if cfg.phi_spec is not None else None)
        pol = rt.policy
        # Donor statistics (decay > 0 warm start): frozen for the whole
        # fit — the window decays per fit GENERATION, not per iteration.
        prev = (None if rt.prev_stats is None else
                {k: jnp.asarray(v) for k, v in rt.prev_stats.items()})
        # Hard-expiry ring (window >= 1): the retained generations'
        # fresh partials, device-resident, frozen for the whole fit.
        win = (stats.StatsWindow(
                   cfg.window,
                   [{k: jnp.asarray(v) for k, v in e.items()}
                    for e in rt.window_entries])
               if cfg.window >= 1 else None)
        eff_stats = None
        fresh_stats = None
        peak_bytes = 0

        def chunk_source(skip):
            it = make_chunks()
            return itertools.islice(it, skip, None) if skip else it

        def stream(skip0):
            """Prefetched chunk iterator starting at chunk index skip0,
            with loader retries restarting past what already arrived."""
            if pol.loader_retries > 0:
                src = retrying_chunks(
                    lambda done: chunk_source(skip0 + done),
                    retries=pol.loader_retries,
                    backoff=pol.loader_backoff,
                    jitter=pol.loader_jitter, seed=cfg.seed,
                    stats=rt.retry_stats)
            else:
                src = chunk_source(skip0)
            return ChunkPrefetcher(src, depth=cfg.prefetch)

        def sweep(fn, skip0=0, totals0=None, row00=0, saver=None):
            """One pass over the data: tree-sum fn(chunk, row0)
            contributions on device (one host transfer per pass).
            ``skip0``/``totals0``/``row00`` continue a partially-swept
            pass (mid-pass resume); ``saver`` commits the partial totals
            every ``ckpt_chunks`` chunks."""
            nonlocal peak_bytes
            pf = stream(skip0)
            totals = totals0
            row0 = row00
            consumed = skip0
            for chunk in pf:
                data = SVMData(*chunk)
                part = fn(data, jnp.int32(row0))
                totals = part if totals is None else fns["add"](totals,
                                                                part)
                row0 += data.X.shape[0]
                consumed += 1
                if (saver is not None and pol.ckpt_chunks > 0
                        and consumed % pol.ckpt_chunks == 0):
                    saver(totals, consumed, row0)
            if totals is None:
                raise ValueError("stream source yielded no chunks")
            peak_bytes = max(peak_bytes, pf.max_resident_bytes)
            return totals

        def iterate(sub, state):
            # One blocking device->host transfer per iteration: the
            # statistics stay on device through every sweep/solve and
            # the scalar trace comes down in a single device_get.
            nonlocal eff_stats, fresh_stats
            midpass, rt.midpass = rt.midpass, None
            keep_stats = cfg.decay > 0.0 or win is not None
            if is_mlt:
                # MLT snapshots at iteration boundaries only (a sweep
                # is per class; a mid-sweep cursor would also need the
                # class index — not worth the surface).
                eff_S, eff_b, fr_S, fr_b = [], [], [], []
                for y_cls in range(cfg.num_classes):
                    t = sweep(lambda d, r0, _y=jnp.int32(y_cls):
                              fns["chunk"](d, state, sub, r0, _y, phi))
                    S, b = t["S"], t["b"]
                    fr_S.append(S)
                    fr_b.append(b)
                    if cfg.decay > 0.0 and prev is not None:
                        S = S + cfg.decay * prev["S"][y_cls]
                        b = b + cfg.decay * prev["b"][y_cls]
                    if win is not None:
                        for e in win.entries:  # newest first, like folded
                            S = S + e["S"][y_cls]
                            b = b + e["b"][y_cls]
                    if keep_stats:
                        eff_S.append(S)
                        eff_b.append(b)
                    state = fns["mstep"](state, S, b, sub,
                                         jnp.int32(y_cls))
                if keep_stats:
                    eff_stats = {"S": jnp.stack(eff_S),
                                 "b": jnp.stack(eff_b)}
                    fresh_stats = {"S": jnp.stack(fr_S),
                                   "b": jnp.stack(fr_b)}
                t = sweep(lambda d, r0: fns["obj"](d, state, phi))
                obj, mask_sum = jax.device_get(
                    (fns["obj_total"](state, t["loss"]), t["mask_sum"]))
                aux = {"objective": float(obj)}
            else:
                def saver(totals, consumed, row0):
                    # Pre-iteration state + this iteration's subkey +
                    # the partial totals: resume replays the remainder
                    # of THIS pass on the identical chain.
                    rt.save_snapshot(rt.cur_it - 1, state, sub=sub,
                                     totals=totals, chunk_idx=consumed,
                                     row0=row0)

                sv = saver if rt.ckpt is not None else None
                body = lambda d, r0: fns["chunk"](d, state, sub, r0, phi)
                if midpass is not None:
                    t = sweep(body, skip0=midpass["skip"],
                              totals0=midpass["totals"],
                              row00=midpass["row0"], saver=sv)
                else:
                    t = sweep(body, saver=sv)
                if keep_stats:
                    fresh_stats = {"S": t["S"], "b": t["b"]}
                    t = dict(t)
                    if cfg.decay > 0.0 and prev is not None:
                        t["S"] = t["S"] + cfg.decay * prev["S"]
                        t["b"] = t["b"] + cfg.decay * prev["b"]
                    if win is not None:
                        folded = win.folded(fresh_stats)
                        t["S"], t["b"] = folded["S"], folded["b"]
                    eff_stats = {"S": t["S"], "b": t["b"]}
                state, obj_dev = fns["mstep"](t["S"], t["b"], t["loss"],
                                              sub)
                obj, scalars = jax.device_get(
                    (obj_dev, {k: v for k, v in t.items()
                               if k not in ("S", "b")}))
                mask_sum = scalars["mask_sum"]
                den = max(float(mask_sum), 1.0)
                aux = {"objective": float(obj),
                       "gamma_mean": float(scalars["gamma_sum"]) / den}
                if cfg.task == "SVR":
                    aux["omega_mean"] = float(scalars["omega_sum"]) / den
                else:
                    aux["n_sv"] = float(scalars["n_sv"])
            return state, aux, float(mask_sum)

        result = self._fit_host_loop(iterate, state0, rt)
        result.peak_input_bytes = int(peak_bytes)
        if eff_stats is not None:
            result.stats = {k: np.asarray(v)
                            for k, v in eff_stats.items()}
        if win is not None and fresh_stats is not None:
            # The ring the NEXT generation folds: this fit's fresh
            # partials pushed in front, horizon enforced.
            result.stats_window = win.advance(
                {k: np.asarray(v) for k, v in fresh_stats.items()})
        return result

    # ------------------------------------------------------ setup helpers
    def _prepare(self, X: np.ndarray, y: np.ndarray):
        cfg = self.config
        N, K = X.shape
        if cfg.task == "CLS":
            target = np.asarray(y, np.float32)
            uniq = set(np.unique(target).tolist())
            assert uniq <= {-1.0, 1.0}, f"CLS labels must be +-1, got {uniq}"
        elif cfg.task == "MLT":
            target = np.asarray(y, np.int32)
        else:
            target = np.asarray(y, np.float32)

        if cfg.formulation == "KRN":
            if cfg.task != "CLS":
                raise NotImplementedError(
                    "the paper's exact KRN solver covers binary "
                    "classification only; NystromSVM serves KRN "
                    f"{cfg.task} through the phi-space route")
            self._train_X = X
            G = np.asarray(krn.gram_matrix(
                jnp.asarray(X), jnp.asarray(X), kind=cfg.kernel,
                sigma=cfg.sigma, backend=cfg.backend))
            shards = (distributed.num_shards(self.mesh, self.data_axes)
                      if self.mesh else 1)
            chunk = shards * 8
            Npad = ((N + chunk - 1) // chunk) * chunk - N
            Gp = np.asarray(krn.pad_gram(jnp.asarray(G), Npad))
            tp = np.concatenate([target, np.zeros((Npad,), target.dtype)])
            if self.mesh is not None:
                data = distributed.shard_rows(self.mesh, self.data_axes,
                                              Gp, tp)
                prior = jax.device_put(
                    Gp, NamedSharding(self.mesh, P(None, None)))
            else:
                mask = np.concatenate([np.ones(N, np.float32),
                                       np.zeros(Npad, np.float32)])
                data = SVMData(jnp.asarray(Gp), jnp.asarray(tp),
                               jnp.asarray(mask))
                prior = jnp.asarray(Gp)
            state = jnp.zeros((Gp.shape[0],), jnp.float32)
            return data, prior, state

        # LIN (raw rows in phi-space mode: featurization happens inside
        # the step, so only D-wide rows are sharded/resident)
        if self.mesh is not None:
            data = distributed.shard_rows(self.mesh, self.data_axes, X,
                                          target)
        else:
            Xp, tp, mask = distributed.pad_rows(X, target, 1)
            data = SVMData(jnp.asarray(Xp), jnp.asarray(tp),
                           jnp.asarray(mask))
        prior = None
        if cfg.phi_spec is not None:
            K = self._phi_width()
            prior = tuple(jnp.asarray(a, jnp.float32)
                          for a in self._phi_arrays)
            if self.mesh is not None:
                rep = NamedSharding(self.mesh, P(None, None))
                prior = tuple(jax.device_put(a, rep) for a in prior)
        if cfg.task == "MLT":
            state = jnp.zeros((cfg.num_classes, K), jnp.float32)
        elif cfg.n_chains > 1:
            state = jnp.zeros((cfg.n_chains, K), jnp.float32)
        else:
            state = jnp.zeros((K,), jnp.float32)
        if self.mesh is not None:
            state = jax.device_put(state, NamedSharding(
                self.mesh, P(*(None,) * state.ndim)))
        return data, prior, state

    def _build_step(self, has_prior: bool, has_live: bool = False):
        return _build_step_fn(self.config, self.mesh,
                              tuple(self.data_axes), has_prior, has_live)

    # ---------------------------------------------------------- inference
    def export_servable(self, *, name: str = "svm",
                        posterior_from: tuple | None = None):
        """Freeze this fitted model into a ``serving.ServableModel`` —
        the serving path's whole view of it (no reaching back into
        ``_weights``/``_train_X``/``_phi_arrays``).

        The exact-KRN model rides the SAME fused Nystrom score cell:
        landmarks are the train rows, the projection is the dual weight
        column omega[:, None], and the score weight is [[1.]] — so
        score = k(X, X_train) @ omega with the cross-Gram tile never
        leaving VMEM.

        ``posterior_from=(X, y)`` appends the MC-posterior uncertainty
        directions U = L^{-T} as extra weight columns (one E-step at
        the fitted weights rebuilds (S, b); L = chol(lam I + S)), so a
        scorer serves margin +- calibrated std in one dispatch
        (``SVMScorer.score_with_std``).
        """
        from repro.serving.svm_serve import ServableModel

        cfg = self.config
        assert self._weights is not None, "fit first"
        w = np.asarray(self._weights, np.float32)
        task = cfg.task.lower()
        if cfg.formulation == "KRN":
            if posterior_from is not None:
                raise NotImplementedError(
                    "posterior serving for the exact-Gram model needs "
                    "the kernel prior precision; fit NystromSVM, whose "
                    "phi-space posterior is lam^{-1} I exactly")
            ntrain = self._train_X.shape[0]
            return ServableModel(
                task=task, weights=np.ones((1, 1), np.float32),
                n_outputs=1, n_features=self._train_X.shape[1],
                landmarks=self._train_X, proj=w[:ntrain, None],
                phi_kind=cfg.kernel, phi_sigma=cfg.sigma,
                phi_add_bias=False, backend=cfg.backend, name=name)
        if cfg.task == "MLT":
            W, n_out = np.ascontiguousarray(w.T), cfg.num_classes
        else:
            W, n_out = w[:, None], 1
        if posterior_from is not None:
            U = self._posterior_columns(*posterior_from)
            W = np.concatenate([W, U], axis=1)
        elif self._chain_weights is not None:
            # Multichain ensemble uncertainty: extra columns
            # (w_c - wbar) / sqrt(C - 1), so the scorer's row-wise
            # ||x @ U|| (score_with_std) IS the ddof=1 std of the C
            # chains' margins — posterior spread served from the same
            # single fused dispatch as the mean margin.
            cw = self._chain_weights.astype(np.float64)
            U = (cw - cw.mean(axis=0)) / np.sqrt(cw.shape[0] - 1)
            W = np.concatenate([W, U.T.astype(np.float32)], axis=1)
        if cfg.phi_spec is not None:
            lm, pj = self._phi_arrays
            return ServableModel(
                task=task, weights=W, n_outputs=n_out,
                n_features=lm.shape[1], landmarks=lm, proj=pj,
                phi_kind=cfg.phi_spec.kind, phi_sigma=cfg.phi_spec.sigma,
                phi_add_bias=cfg.phi_spec.add_bias, backend=cfg.backend,
                name=name)
        D = self._n_features
        if D is None:
            if cfg.pad_features:
                raise ValueError(
                    "raw feature width unknown (fit_chunks with "
                    "pad_features); set svm._n_features or fit via "
                    "fit/fit_libsvm")
            D = W.shape[0] - int(cfg.add_bias)
        expect = D + int(cfg.add_bias)
        if cfg.pad_features:
            expect += (-expect) % cfg.pad_features
        assert expect == W.shape[0], (
            f"recorded request width {D} preps to {expect} columns but "
            f"the fitted weights have {W.shape[0]}")
        return ServableModel(task=task, weights=W, n_outputs=n_out,
                             n_features=D, add_bias=cfg.add_bias,
                             backend=cfg.backend, name=name)

    def _posterior_columns(self, X: np.ndarray, y: np.ndarray
                           ) -> np.ndarray:
        """U = L^{-T} (Kfit, Kfit) f32: the uncertainty directions of
        the weight posterior N(mu, P^{-1}) at the FITTED weights — one
        E-step over (X, y) rebuilds the sufficient statistic S, then
        P = lam I + S (+ the config's relative jitter, mirroring
        ``stats.posterior_params``) and L = chol(P). Served std is
        ||phi U|| = sqrt(phi^T P^{-1} phi)."""
        from repro.kernels import ops

        cfg = self.config
        if cfg.task == "MLT":
            raise NotImplementedError(
                "MLT posterior columns need per-class statistics; "
                "export per-class binary models instead")
        X = np.asarray(X, np.float32)
        if cfg.phi_spec is not None:
            lm, pj = (jnp.asarray(a, jnp.float32)
                      for a in self._phi_arrays)
            Xp = ops.nystrom_phi(
                jnp.asarray(X), lm, pj, None, sigma=cfg.phi_spec.sigma,
                kind=cfg.phi_spec.kind, add_bias=cfg.phi_spec.add_bias,
                backend=cfg.backend)
        else:
            if cfg.add_bias:
                X = np.concatenate(
                    [X, np.ones((X.shape[0], 1), np.float32)], 1)
            if cfg.pad_features:
                from repro.data.pipeline import pad_features_to
                X = pad_features_to(X, cfg.pad_features)
            Xp = jnp.asarray(X)
        yf = jnp.asarray(np.asarray(y, np.float32))
        beta = yf if cfg.task == "CLS" else jnp.zeros_like(yf)
        epi = "em_hinge" if cfg.task == "CLS" else "em_svr"
        out = ops.fused_stats(Xp, yf, beta, jnp.asarray(self._weights),
                              None, None, epilogue=epi, eps=cfg.eps,
                              eps_ins=cfg.eps_ins, backend=cfg.backend)
        S = np.asarray(out[-1], np.float64)
        K = S.shape[0]
        P = S + cfg.lam * np.eye(K)
        P = 0.5 * (P + P.T)
        P += (cfg.jitter * np.trace(P) / K) * np.eye(K)
        L = np.linalg.cholesky(P)
        return np.linalg.solve(L, np.eye(K)).T.astype(np.float32)

    def scorer(self):
        """The device-resident ``serving.SVMScorer`` for this fitted
        model, built ONCE per fit: weights/featurizer arrays are
        device-put at construction and every ``decision_function`` /
        ``predict`` call reuses them (no per-call host->device
        re-upload, no re-jit — the no-retrace regression tests gate
        this). A refit assigns new source arrays, which invalidates
        the cache by identity."""
        from repro.serving.svm_serve import SVMScorer

        src = (self._weights, self._train_X, self._phi_arrays)
        if (self._scorer_cache is None
                or any(a is not b
                       for a, b in zip(self._scorer_cache[0], src))):
            self._scorer_cache = (src, SVMScorer(self.export_servable()))
        return self._scorer_cache[1]

    def decision_function(self, X: np.ndarray) -> np.ndarray:
        X = np.asarray(X, np.float32)
        if self._n_features is None:  # fit_chunks-direct fits
            self._n_features = X.shape[1]
        return self.scorer().margins(X)

    def predict(self, X: np.ndarray) -> np.ndarray:
        f = self.decision_function(X)
        if self.config.task == "MLT":
            return np.argmax(f, axis=1)
        if self.config.task == "SVR":
            return f
        return np.where(f >= 0, 1, -1)

    def rmse(self, X: np.ndarray, y: np.ndarray) -> float:
        """Root-mean-square prediction error (SVR)."""
        assert self.config.task == "SVR", "rmse is the SVR error metric"
        pred = self.predict(X)
        return float(np.sqrt(np.mean(
            (pred - np.asarray(y, np.float32)) ** 2)))

    def score(self, X: np.ndarray, y: np.ndarray) -> float:
        """HIGHER IS BETTER for every task: accuracy for CLS/MLT and
        *negated* RMSE for SVR (use ``rmse`` for the raw error). The
        old behavior returned raw RMSE here, silently inverting the
        ordering for callers comparing scores across tasks."""
        if self.config.task == "SVR":
            return -self.rmse(X, y)
        pred = self.predict(X)
        return float(np.mean(pred == np.asarray(y)))
