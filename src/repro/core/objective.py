"""Objectives, decision functions and metrics for every PEMSVM task.

The paper's stopping rule (Sec 5.5) monitors the regularized-risk objective
each iteration and stops when the iterative change falls to tol*N
(tol = 0.001). Objectives here are written over *local* shards with an
explicit validity mask (padding rows contribute zero) and reduced with
psum by the callers.
"""
from __future__ import annotations

import jax.numpy as jnp


def hinge_obj_terms(margins: jnp.ndarray, y: jnp.ndarray,
                    mask: jnp.ndarray) -> jnp.ndarray:
    """sum_d 2*max(0, 1 - y_d m_d) over valid rows (paper Eq. 1 loss term)."""
    return jnp.sum(mask * 2.0 * jnp.maximum(0.0, 1.0 - y * margins))


def svr_obj_terms(pred: jnp.ndarray, y: jnp.ndarray, eps_ins: float,
                  mask: jnp.ndarray) -> jnp.ndarray:
    """sum_d 2*max(0, |y_d - f_d| - eps) (paper Eq. 20 loss term)."""
    return jnp.sum(mask * 2.0 * jnp.maximum(0.0, jnp.abs(y - pred) - eps_ins))


def cs_obj_terms(scores: jnp.ndarray, labels: jnp.ndarray,
                 mask: jnp.ndarray) -> jnp.ndarray:
    """Crammer-Singer loss sum_d 2*max_y(Delta_d(y) - Delta f_d(y)) (Eq. 30).

    scores: (N, M) f_d(y); labels: (N,) int; Delta = 0/1 cost.
    """
    N, M = scores.shape
    onehot = jnp.eye(M, dtype=scores.dtype)[labels]
    delta = 1.0 - onehot
    true_score = jnp.sum(scores * onehot, axis=1)
    worst = jnp.max(scores + delta, axis=1)
    return jnp.sum(mask * 2.0 * jnp.maximum(0.0, worst - true_score))


def l2_reg(w: jnp.ndarray, lam: float) -> jnp.ndarray:
    """0.5 * lam * ||w||_2^2 (flattens multi-class W)."""
    return 0.5 * lam * jnp.sum(jnp.square(w))


def kernel_reg(omega: jnp.ndarray, K_omega: jnp.ndarray, lam: float) -> jnp.ndarray:
    """0.5 * lam * omega^T K omega (paper Eq. 15 regularizer).

    Takes the precomputed K @ omega so callers can reuse the margin matvec.
    """
    return 0.5 * lam * jnp.dot(omega, K_omega)


def accuracy(pred_labels: jnp.ndarray, labels: jnp.ndarray,
             mask: jnp.ndarray | None = None) -> jnp.ndarray:
    ok = (pred_labels == labels).astype(jnp.float32)
    if mask is None:
        return jnp.mean(ok)
    return jnp.sum(ok * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def rmse(pred: jnp.ndarray, y: jnp.ndarray,
         mask: jnp.ndarray | None = None) -> jnp.ndarray:
    se = jnp.square(pred - y)
    if mask is None:
        return jnp.sqrt(jnp.mean(se))
    return jnp.sqrt(jnp.sum(se * mask) / jnp.maximum(jnp.sum(mask), 1.0))
