"""LIN-{EM,MC}-MLT: Crammer-Singer multiclass SVM (paper Sec 3.3).

Hierarchical block update (paper's 2-layer structure): the outer loop
cycles over classes y = 1..M; given the other classes' weights w_{-y}, the
class-y conditional is a *binary-style* augmented problem with

  zeta_d(y) = max_{y' != y} (w_{y'}^T x_d + Delta_d(y'))   (indep. of w_y)
  rho_d^y   = zeta_d(y) - Delta_d(y)
  beta_d^y  = +1 if y == y_d else -1                        (Eq. 34-35)

then gamma_{yd} = |rho_d^y - w_y^T x_d| (Eq. 36) and the Gaussian step
Eq. 38-39 — i.e. exactly ``linear.accumulate_stats`` with per-class
(rho, beta). Delta is the standard 0/1 cost. Iteration time is M x LIN
(paper Sec 4.3).

Each class conditional IS ``linear.accumulate_stats``, so the fused
epilogue family applies per class: an MC sweep issues M single-stream
fused passes (margin, Gibbs gamma via in-kernel IG transform, b, Sigma
per class) instead of the pre-fusion 3M X streams — the M-class Gibbs
sweep itself stays inherently sequential (class y's rho depends on the
already-updated w_{<y}), so M streams per sweep is the floor
(DESIGN.md §Perf/MC-SVR, ROADMAP Open items).

The class loop maintains the score matrix F = X W^T and refreshes only
column y after updating w_y (one GEMV instead of a full GEMM per class).
The streaming path (``mlt_class_chunk_stats``) instead *recomputes* the
chunk's F from the current W each pass — mathematically identical,
because the incrementally-maintained F's columns are exactly X w_c for
each class c at its current value — trading O(NKM^2) extra margin
FLOPs per sweep (each of the M class passes rebuilds the (N, M) score
matrix) for never holding N rows at once; Sigma's O(NK^2 M) still
dominates while M < K.
"""
from __future__ import annotations

from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.kernels import ops
from . import objective, stats
from .linear import PhiSpec, SVMData, _k_block, accumulate_stats

_NEG = -1e30


def _maybe_featurize(X: jnp.ndarray, mask: jnp.ndarray, phi,
                     phi_spec: PhiSpec | None, backend: str | None):
    """Nystrom phi-space entry for MLT: featurize the block and run the
    per-class conditional on the (rows, M_phi) result.

    In the in-memory step the block is the whole (local) set, so one
    featurize serves all M class passes (scores + M stats sweeps) —
    cheaper than M fused featurize passes, the opposite trade from
    binary CLS where the fused kernel's single pass wins (DESIGN.md
    §Perf/Nystrom). The STREAMING driver re-streams chunks per class
    pass, so it pays this featurize (M + 1) times per chunk per
    iteration — inherent to not holding phi resident, and the same
    recompute-vs-residency trade the LIN stream path already makes for
    MLT's score matrix (module docstring): at most ~(1 + D/m) extra
    work over each pass's O(rows · m^2) Sigma statistic. Zeroed phi
    rows keep padded rows exact no-ops for Sigma/b even though the
    Crammer-Singer rho of a padded row is nonzero."""
    if phi_spec is None:
        return X
    landmarks, proj = phi
    return ops.nystrom_phi(X, landmarks, proj, mask, sigma=phi_spec.sigma,
                           kind=phi_spec.kind, add_bias=phi_spec.add_bias,
                           backend=backend)


def _rho_beta(F: jnp.ndarray, labels: jnp.ndarray, y: jnp.ndarray,
              M: int):
    """Per-class hinge parameters for class y (traced int)."""
    N = F.shape[0]
    class_ids = jnp.arange(M)
    onehot_lbl = (labels[:, None] == class_ids[None, :]).astype(jnp.float32)
    delta = 1.0 - onehot_lbl                             # Delta_d(y') 0/1 cost
    A = F + delta
    A_excl = jnp.where(class_ids[None, :] == y, _NEG, A)
    zeta = jnp.max(A_excl, axis=1)                       # zeta_d(y)
    delta_y = (labels != y).astype(jnp.float32)          # Delta_d(y)
    rho = zeta - delta_y
    beta = jnp.where(labels == y, 1.0, -1.0)
    return rho, beta


def mlt_class_chunk_stats(chunk: SVMData, W: jnp.ndarray, key: jax.Array,
                          row0: jnp.ndarray, y: jnp.ndarray, *,
                          num_classes: int, mode: str, eps: float,
                          backend: str | None, phi=None,
                          phi_spec: PhiSpec | None = None,
                          rng: str = "host", chain0: int = 0) -> dict:
    """Streaming class-y E-step body: one chunk's (Sigma, b) contribution.

    Recomputes the chunk's score matrix from the *current* W (classes
    before y already updated this sweep), reproducing the in-memory
    step's incrementally-maintained F exactly — see module docstring.
    The gamma key is ``fold_in(key, y)`` + rowwise (counter rng modes
    build their seed from the same per-class key), matching
    ``mlt_step``'s per-class keying, so MC chains agree bitwise with the
    in-memory drivers."""
    X, labels, mask = chunk
    X = _maybe_featurize(X, mask, phi, phi_spec, backend)
    F = X.astype(jnp.float32) @ W.T.astype(jnp.float32)
    rho, beta = _rho_beta(F, labels, y, num_classes)
    _, _, S, b = accumulate_stats(
        X, rho, beta, W[y], mode=mode, key=jax.random.fold_in(key, y),
        eps=eps, backend=backend, row0=row0, rng=rng, chain0=chain0)
    return {"S": S, "b": b}


def mlt_chunk_obj(chunk: SVMData, W: jnp.ndarray, phi=None,
                  phi_spec: PhiSpec | None = None,
                  backend: str | None = None) -> dict:
    """Streaming objective body: the chunk's Crammer-Singer loss terms
    at the end-of-sweep W, plus the valid-row count (both additive)."""
    X, labels, mask = chunk
    X = _maybe_featurize(X, mask, phi, phi_spec, backend)
    F = X.astype(jnp.float32) @ W.T.astype(jnp.float32)
    return {"loss": objective.cs_obj_terms(F, labels, mask),
            "mask_sum": jnp.sum(mask)}


@partial(jax.jit, static_argnames=("num_classes", "mode", "lam", "eps",
                                   "jitter", "axes", "triangle", "backend",
                                   "k_shard_axis", "reduce_dtype",
                                   "phi_spec", "rng", "chain0"))
def mlt_step(data: SVMData, W: jnp.ndarray, key: jax.Array, *,
             num_classes: int, mode: str = "EM", lam: float = 1.0,
             eps: float = 1e-6, jitter: float = 1e-6,
             axes: Sequence[str] = (), triangle: bool = True,
             backend: str | None = None,
             k_shard_axis: str | None = None,
             reduce_dtype: str | None = None,
             phi=None, phi_spec: PhiSpec | None = None,
             live: jnp.ndarray | None = None,
             rng: str = "host", chain0: int = 0):
    """One outer MLT iteration = one block sweep over all M classes.

    W: (M, K). Returns (W_new, aux dict). ``k_shard_axis`` switches
    every class conditional to the 2-D (data x model) column-windowed
    statistic (one window per shard, shared by all M passes — the
    class sweep stays M single-stream fused passes).

    ``rng``/``chain0``: the counter modes key class y's in-kernel noise
    from ``pack_seed(fold_in(key, y), row0, chain0)`` and its weight
    draw from ``fold_in(fold_in(key, y), chain0)`` — MLT runs a single
    chain (n_chains > 1 is CLS/SVR-only), so chain0 just addresses
    which counter plane this fit occupies.
    """
    X, labels, mask = data
    X = _maybe_featurize(X, mask, phi, phi_spec, backend)
    M = num_classes
    Xf = X.astype(jnp.float32)
    row0 = stats.shard_row_offset(X.shape[0], axes)
    col_window = (_k_block(W.shape[1], k_shard_axis)
                  if k_shard_axis is not None else None)

    F0 = Xf @ W.T.astype(jnp.float32)                    # (N, M)

    def body(y, carry):
        W, F = carry
        rho, beta = _rho_beta(F, labels, y, M)
        # Padding rows: X-row == 0 => margin 0 and zero stats contribution.
        _, gamma, S, b = accumulate_stats(
            X, rho, beta, W[y], mode=mode,
            key=jax.random.fold_in(key, y), eps=eps, backend=backend,
            row0=row0, col_window=col_window, rng=rng, chain0=chain0)
        if k_shard_axis is None:
            S, b = stats.reduce_stats(S, b, axes, triangle=triangle,
                                      reduce_dtype=reduce_dtype, live=live)
        else:
            S, b = stats.reduce_kshard(S, b, axes, k_shard_axis,
                                       reduce_dtype=reduce_dtype, live=live)
        L, mu = stats.posterior_params(S, b, lam, jitter=jitter)
        if mode == "EM":
            w_new = mu
        else:
            ky = jax.random.fold_in(key, y)
            if rng != "host":
                ky = jax.random.fold_in(ky, chain0)
            w_new = stats.draw_weight(ky, L, mu)
        W = W.at[y].set(w_new)
        F = F.at[:, y].set(Xf @ w_new)
        return (W, F)

    W_new, F = jax.lax.fori_loop(0, M, body, (W.astype(jnp.float32), F0))

    obj = objective.l2_reg(W_new, lam) + stats.preduce(
        objective.cs_obj_terms(F, labels, mask), axes, live)
    return W_new, {"objective": obj}


def predict(W: jnp.ndarray, X: jnp.ndarray) -> jnp.ndarray:
    """argmax_y w_y^T x (paper Eq. 29)."""
    return jnp.argmax(X.astype(jnp.float32) @ W.T.astype(jnp.float32), axis=1)
