"""The paper's map-reduce architecture (Sec 4, Fig. 1) on a JAX mesh.

Every step function in this package is written over a *local* shard with
explicit ``psum`` reductions over ``axes``; this module supplies the
machinery around them:

  * ``shard_rows`` — partition the training set across the mesh's data
    axes exactly like the paper assigns D^p to process p (padding rows are
    zeroed and masked so statistics are exact).
  * ``shard_wrap`` — wrap a step function in ``shard_map`` so each device
    runs the identical SPMD program (the paper's observation that all
    slaves perform the same operations — hence minimal sync latency — is
    preserved; the master is replaced by a replicated solve, DESIGN.md §6).
  * ``FaultTolerantReduce`` semantics: reductions take a per-shard liveness
    weight so a failed/evicted replica contributes zero and the global
    statistic renormalizes (Sec "large-scale runnability"); see
    ``repro.runtime`` for the detection side.

The SVM is embarrassingly data-parallel, so by default it consumes *every*
mesh axis as a data axis (the paper scales to 480 cores with pure data
parallelism; on a 2x16x16 pod-slice that is 512-way). ``k_shard_axis``
optionally switches the Sigma statistic to the 2-D (data x model) scheme
(beyond-paper; see linear.py).
"""
from __future__ import annotations

import functools
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map

from .linear import SVMData
from .stats import shard_row_offset  # noqa: F401 — re-export (public API)


def data_axes_of(mesh: Mesh, model_axes: Sequence[str] = ()) -> tuple[str, ...]:
    """All mesh axes not reserved for the model — the SVM's worker grid."""
    return tuple(a for a in mesh.axis_names if a not in model_axes)


def num_shards(mesh: Mesh, axes: Sequence[str]) -> int:
    return int(np.prod([mesh.shape[a] for a in axes], dtype=np.int64))


def pad_rows(X: np.ndarray, target: np.ndarray, shards: int,
             multiple: int = 8):
    """Zero-pad rows to a multiple of (shards * multiple); returns SVMData
    host arrays. Padded rows: X-row = 0, target = 0, mask = 0."""
    N = X.shape[0]
    chunk = shards * multiple
    Np = ((N + chunk - 1) // chunk) * chunk
    pad = Np - N
    Xp = np.concatenate([X, np.zeros((pad,) + X.shape[1:], X.dtype)], axis=0)
    tp = np.concatenate([target, np.zeros((pad,), target.dtype)], axis=0)
    mask = np.concatenate([np.ones((N,), np.float32),
                           np.zeros((pad,), np.float32)], axis=0)
    return Xp, tp, mask


def shard_rows(mesh: Mesh, axes: Sequence[str], X: np.ndarray,
               target: np.ndarray) -> SVMData:
    """Place the training set row-sharded over ``axes`` (paper Sec 4.1).

    I/O note (paper Sec 5.6): in a real multi-host deployment each host
    feeds only its addressable shard (repro.data.pipeline); here the
    single-host path materializes and shards.
    """
    shards = num_shards(mesh, axes)
    Xp, tp, mask = pad_rows(X, target, shards)
    row_spec = P(tuple(axes))
    data = SVMData(
        X=jax.device_put(Xp, NamedSharding(mesh, P(tuple(axes), None))),
        target=jax.device_put(tp, NamedSharding(mesh, row_spec)),
        mask=jax.device_put(mask, NamedSharding(mesh, row_spec)),
    )
    return data


def shard_wrap(mesh: Mesh, axes: Sequence[str],
               step_fn: Callable, *, state_spec=P(None),
               has_prior: bool = False,
               prior_spec=P(None, None),
               has_live: bool = False) -> Callable:
    """shard_map a step(data, [prior,] state, key[, live]) -> (state, aux)
    function.

    data is row-sharded over ``axes``; state/key/prior replicated; outputs
    replicated (the psum/replicated-solve structure guarantees it).
    ``prior_spec`` is the (pytree of) replicated spec(s) for the prior
    slot — a single (N, N) Gram for exact KRN, or the Nystrom
    (landmarks, projection) pair.

    ``has_live`` appends a liveness-vector slot: a (num_shards,) fp32
    array sharded over the data axes like the rows, so each shard
    receives its own scalar weight and the step's reductions renormalize
    around dropped replicas (``stats.preduce``). An all-ones vector is
    bitwise the plain psum, so the solver passes it unconditionally on
    the mesh path.
    """
    dspec = P(tuple(axes))
    data_specs = SVMData(X=P(tuple(axes), None), target=dspec, mask=dspec)
    in_specs = ((data_specs, prior_spec, state_spec, P(None)) if has_prior
                else (data_specs, state_spec, P(None)))
    if has_live:
        in_specs = in_specs + (dspec,)
    out_specs = (state_spec, P())  # P() = replicated scalars in the aux dict

    wrapped = shard_map(step_fn, mesh=mesh, in_specs=in_specs,
                        out_specs=out_specs, check_vma=False)
    return jax.jit(wrapped)


def live_weighted_psum(x: jnp.ndarray, live: jnp.ndarray,
                       axes: Sequence[str]) -> jnp.ndarray:
    """Failure-tolerant mean-preserving reduction: sum_p live_p x_p scaled
    by P / sum_p live_p. A dead replica (live=0) drops out and the
    statistic renormalizes — the SVM's sums are over data, so this is the
    unbiased estimate the paper's stopping rule keeps working with.
    (Thin alias of ``stats.preduce(..., live=...)``, which the step
    functions call directly so the fused collectives stay fused.)"""
    from . import stats as _stats
    return _stats.preduce(x, tuple(axes), live)
