"""Sufficient statistics and their (optionally compressed) reductions.

The paper's parallel structure (Sec 4.1, Fig. 1): every worker computes

    Sigma^p = sum_d (1/gamma_d) x_d x_d^T        (K x K)
    mu^p    = sum_d (rho_d/gamma_d + beta_d) x_d (K,)

and the global statistics are plain sums over workers. On TPU the reduce is
``jax.lax.psum`` over the mesh data axes. The paper notes (Sec 4.1) that
Sigma^p is symmetric so "it suffices to compute only the upper or lower
triangle" — we exploit that as a *triangle-packed* psum, reducing the
dominant collective from K^2 to K(K+1)/2 elements.
"""
from __future__ import annotations

import itertools
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat


def shard_row_offset(local_n: int, axes: Sequence[str]) -> jnp.ndarray:
    """Global row index of this shard's first row, inside shard_map.

    ``distributed.shard_rows`` lays rows out row-major over ``axes`` in
    order, so the linear shard index is the mixed-radix number over the
    axis indices; times the local row count gives the offset. Identity
    (0) outside a mesh. The LIN steps feed this to the rowwise MC gamma
    draw (``augment.gamma_mc_rowwise``) so a mesh fit draws the *same*
    gammas as the single-device and streaming drivers — sharding layout
    no longer changes the chain."""
    if not axes:
        return jnp.int32(0)
    off = jnp.int32(0)
    for ax in axes:
        off = off * compat.axis_size(ax) + jax.lax.axis_index(ax)
    return off * local_n


def triangle_pack(S: jnp.ndarray) -> jnp.ndarray:
    """Pack a symmetric (K, K) matrix into its K(K+1)/2 lower triangle."""
    K = S.shape[0]
    idx = jnp.tril_indices(K)
    return S[idx]


def triangle_unpack(packed: jnp.ndarray, K: int) -> jnp.ndarray:
    """Inverse of triangle_pack: rebuild the full symmetric matrix."""
    idx = jnp.tril_indices(K)
    S = jnp.zeros((K, K), packed.dtype).at[idx].set(packed)
    return S + jnp.tril(S, -1).T


def preduce(x: jnp.ndarray, axes: Sequence[str] | None,
            live: jnp.ndarray | None = None) -> jnp.ndarray:
    """psum over mesh axes when running inside shard_map; identity otherwise.

    ``live`` (this shard's liveness weight, shape () or (1,)) switches to
    the failure-tolerant renormalized reduction: sum_p live_p x_p scaled
    by P / sum_p live_p. A dead replica (live = 0) drops out and the
    statistic stays an unbiased estimate of the full-data sum — the SVM's
    statistics are sums over rows, so dropping a shard and scaling is
    exactly the bootstrap-style estimate DESIGN.md §Reliability argues
    for. With live = 1 everywhere this is BITWISE the plain psum
    (x * 1.0 and * (P/P) are exact), so the solver can thread it
    unconditionally on the mesh path."""
    if not axes:
        return x
    if live is None:
        return jax.lax.psum(x, tuple(axes))
    lv = jnp.reshape(live, ())
    # Weight in x's dtype (liveness is 0/1 — exact even in bf16) so a
    # reduce_dtype-compressed payload stays compressed on the wire; the
    # den psum is one fp32 scalar.
    num = jax.lax.psum(lv.astype(x.dtype) * x, tuple(axes))
    den = jax.lax.psum(lv.astype(jnp.float32), tuple(axes))
    total = float(np.prod([compat.axis_size(a) for a in axes]))
    scale = total / jnp.maximum(den, 1.0)
    return num * scale.astype(num.dtype)


def masked_mean(x: jnp.ndarray, mask: jnp.ndarray,
                axes: Sequence[str] | None,
                live: jnp.ndarray | None = None) -> jnp.ndarray:
    """Globally-reduced mean of x over valid rows (diagnostics). The
    ``live`` renormalization factors cancel between num and den, so the
    dropped-shard mean is the mean over surviving rows — the right
    diagnostic."""
    num = preduce(jnp.sum(x * mask), axes, live)
    den = preduce(jnp.sum(mask), axes, live)
    return num / jnp.maximum(den, 1.0)


def reduce_stats(S: jnp.ndarray, b: jnp.ndarray,
                 axes: Sequence[str] | None,
                 triangle: bool = True,
                 reduce_dtype: str | None = None,
                 live: jnp.ndarray | None = None
                 ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """All-reduce (Sigma^p, mu^p) across data-parallel workers.

    ``triangle=True`` concatenates the packed triangle of S with b into one
    fused psum — half the collective bytes of a dense K x K reduce plus one
    fewer collective launch (paper Sec 4.1's symmetry observation, made
    wire-level).

    ``reduce_dtype='bfloat16'`` compresses the reduction payload 2x more
    (gradient-compression analogue for the paper's statistic). int8
    transport is NOT expressible as an XLA all-reduce — the on-wire
    accumulator would overflow at 512 workers — so bf16 is the honest
    compressed option on TPU; the fp32 magnitude is restored after the
    reduce. CAUTION (measured, EXPERIMENTS.md §Perf A4): requires the
    gamma clamp eps >= 1e-3 — at the default 1e-6 clamp the 1/gamma
    dynamic range (1e6) exceeds bf16's 8-bit mantissa and the posterior
    solve collapses to chance accuracy.

    ``live`` threads the failure-tolerant renormalized reduction (see
    ``preduce``) through the fused collective.

    A multichain statistic — S (C, K, K), b (K, C) — packs each chain's
    triangle into the same single fused psum (C * K(K+1)/2 + C*K
    payload): the symmetry win and the one-collective launch carry to C
    chains unchanged."""
    if not axes:
        return S, b

    def maybe_cast(x):
        return x.astype(reduce_dtype) if reduce_dtype else x

    def uncast(x):
        return x.astype(jnp.float32) if reduce_dtype else x

    if not triangle:
        return (uncast(preduce(maybe_cast(S), axes, live)),
                uncast(preduce(maybe_cast(b), axes, live)))
    if S.ndim == 3:
        C, K = S.shape[0], S.shape[1]
        tri = K * (K + 1) // 2
        fused = jnp.concatenate([jax.vmap(triangle_pack)(S).reshape(-1),
                                 b.reshape(-1)])
        fused = uncast(preduce(maybe_cast(fused), axes, live))
        S = jax.vmap(lambda p: triangle_unpack(p, K))(
            fused[: C * tri].reshape(C, tri))
        return S, fused[C * tri:].reshape(b.shape)
    K = S.shape[0]
    fused = jnp.concatenate([triangle_pack(S), b])
    fused = uncast(preduce(maybe_cast(fused), axes, live))
    return triangle_unpack(fused[: K * (K + 1) // 2], K), fused[K * (K + 1) // 2:]


def reduce_kshard(S_blk: jnp.ndarray, b: jnp.ndarray,
                  axes: Sequence[str] | None, k_shard_axis: str,
                  reduce_dtype: str | None = None,
                  live: jnp.ndarray | None = None
                  ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Reduce the 2-D (data x model) statistic: ONE packed psum of this
    model-shard's (K, K/n) Sigma column block concatenated with b over
    the data axes (mirroring ``reduce_stats``'s triangle+mu packing —
    one collective launch instead of the former separate S_blk and b
    psums), then an all-gather of the column blocks over the model axis
    rebuilding the full (K, K) Sigma.

    The block is an off-diagonal rectangle, so there is no triangle to
    pack — the payload per device is already K*K/n + K, a factor n
    below the 1-D dense reduce (and 2/n below the triangle-packed one
    for n >= 2: the 2-D layout's collective win, DESIGN.md
    §Perf/k-shard). ``reduce_dtype`` compresses the psum payload like
    ``reduce_stats`` (same bf16 clamp caveat); the all-gather stays
    fp32 — it is 1/n of the psum bytes and rebuilds the matrix the
    replicated solve factorizes.
    """
    K, blk = S_blk.shape

    def maybe_cast(x):
        return x.astype(reduce_dtype) if reduce_dtype else x

    def uncast(x):
        return x.astype(jnp.float32) if reduce_dtype else x

    fused = jnp.concatenate([S_blk.reshape(-1), b])
    # live is a DATA-axis weight, replicated over the model axis, so
    # every model shard renormalizes by the same factor and the
    # all-gathered Sigma stays consistent.
    fused = uncast(preduce(maybe_cast(fused), axes, live))
    S_blk = fused[: K * blk].reshape(K, blk)
    b = fused[K * blk:]
    S = jax.lax.all_gather(S_blk, k_shard_axis, axis=1, tiled=True)
    return S, b


def posterior_params(S: jnp.ndarray, b: jnp.ndarray, lam: float,
                     prior_precision: jnp.ndarray | None = None,
                     jitter: float = 0.0):
    """Return (L, mu) for the Gaussian conditional p(w | gamma, D) (Eq. 4/6).

    Precision P = lam*I + S (linear) or lam*K + S (kernel, pass
    ``prior_precision=K``); L is its lower Cholesky factor and mu = P^{-1} b.
    The solve is replicated on every device — the paper's "master" reduce +
    broadcast steps collapse into the all-reduce (DESIGN.md §6.1).
    """
    K = S.shape[0]
    if prior_precision is None:
        P = S + lam * jnp.eye(K, dtype=S.dtype)
    else:
        P = S + lam * prior_precision
    P = 0.5 * (P + P.T)  # exact symmetry for the factorization
    # Relative jitter: fp32 Gram/SYRK statistics carry O(eps * trace/K)
    # negative eigenvalue noise; scale the ridge to the problem.
    scale = jnp.trace(P) / K
    P = P + (jitter * scale) * jnp.eye(K, dtype=S.dtype)
    L = jnp.linalg.cholesky(P)
    mu = jax.scipy.linalg.cho_solve((L, True), b)
    return L, mu


def draw_weight(key: jax.Array, L: jnp.ndarray, mu: jnp.ndarray) -> jnp.ndarray:
    """MC draw w ~ N(mu, P^{-1}) via w = mu + L^{-T} z (paper Eq. 4)."""
    z = jax.random.normal(key, mu.shape, dtype=mu.dtype)
    return mu + jax.scipy.linalg.solve_triangular(L.T, z, lower=False)


class StatsWindow:
    """Hard-expiry ring of per-generation (Sigma, b) statistic partials
    — the windowed alternative to the geometric ``SVMConfig.decay``
    warm start (DESIGN.md §Reliability).

    Decay folds the previous generation's EFFECTIVE statistics in at
    weight d, so every generation ever seen keeps a geometric tail —
    old data never fully leaves the model. A window instead retains the
    FRESH partials of the last ``horizon - 1`` generations verbatim and
    sums them at full weight; a generation older than the horizon is
    dropped outright. Because (Sigma, b) are plain sums over rows, the
    drop is EXACT data expiry: the expired rows' contribution to the
    effective statistic is identically zero afterwards — the semantics
    GDPR-style retention horizons need and decay cannot give.

    ``entries[0]`` is the newest retained previous generation. The ring
    is frozen for the whole fit (generations advance per fit, not per
    iteration — same contract as decay) and rides the checkpoint
    payload verbatim (``pack``/``unpack``), so a killed fit resumes
    folding bit-identical sums: resume-exactness reduces to the ring
    arrays being restored as saved, which ``core.resume`` tests pin.
    """

    def __init__(self, horizon: int, entries=()):
        assert horizon >= 1, horizon
        self.horizon = int(horizon)
        self.entries = [dict(e) for e in entries][: self.horizon - 1]

    def folded(self, fresh: dict) -> dict:
        """Effective statistics for the M-step: fresh + every retained
        generation at full weight (newest first — a fixed association
        order, so repeated folds are bitwise reproducible)."""
        out = dict(fresh)
        for e in self.entries:
            out["S"] = out["S"] + e["S"]
            out["b"] = out["b"] + e["b"]
        return out

    def advance(self, fresh: dict) -> list[dict]:
        """The ring the NEXT generation carries: this generation's fresh
        partials pushed in front, hard-truncated to the horizon."""
        head = [{k: np.asarray(fresh[k]) for k in ("S", "b")}]
        return (head + self.entries)[: self.horizon - 1]

    @staticmethod
    def pack(entries) -> dict:
        """Flat ``{win{i}_{S,b}: array}`` dict for the checkpoint
        payload (``core.resume.save_snapshot``)."""
        return {f"win{i}_{k}": np.asarray(e[k])
                for i, e in enumerate(entries) for k in ("S", "b")}

    @staticmethod
    def unpack(arrays: dict) -> list:
        """Inverse of ``pack`` over a flat checkpoint-arrays dict."""
        out: list[dict] = []
        for i in itertools.count():
            if f"win{i}_S" not in arrays:
                break
            out.append({"S": np.asarray(arrays[f"win{i}_S"]),
                        "b": np.asarray(arrays[f"win{i}_b"])})
        return out
