"""Fault-tolerant checkpointing: async, atomic, keep-K, elastic restore,
epoch-fenced multi-writer safety.

Layout (one directory per step; the epoch tag appears for fenced
writers with epoch > 0 — legacy single-writer directories stay valid):
    <dir>/step_000000100/            # epoch-0 (legacy) name
    <dir>/step_000000100.e000003/    # the same step written at epoch 3
        manifest.json        # tree structure, shapes, dtypes, epoch
        arrays/<idx>.npy     # one file per leaf (host-gathered)
    <dir>/step_000000100.e000003.COMMIT  # written last -> atomicity
    <dir>/FENCE              # advance-only max epoch ever granted

Design points for 1000+ node deployments (documented where this
single-host implementation stands in for the multi-host version):
  * save is ASYNC: the step's arrays are snapshotted to host memory
    synchronously (cheap device->host copy) and written by a background
    thread, so training never blocks on the filesystem;
  * atomicity by COMMIT marker — restore only considers committed steps,
    so a node failure mid-save never corrupts the restore point. Every
    file (arrays, manifest, the marker) is fsynced and the containing
    directories are fsynced around the rename, so the commit cannot be
    reordered ahead of its data by the page cache on a power loss;
  * EPOCH FENCING makes the directory safe under multiple concurrent
    writers (several controllers co-supervising one checkpoint store):
    a writer opened with a fence token (``epoch=``) advances the
    shared ``FENCE`` file at open; its commits re-read the fence AFTER
    the data fsync and BEFORE the rename/COMMIT become visible, and a
    superseded writer (fence > own epoch) has the commit rejected at
    that rename boundary (``FencedCommitError``) — a zombie worker's
    late commit can never win over a relaunch's line. Restore resolves
    the newest snapshot by ``(epoch, step)`` ordering, epoch-major, so
    even a commit that races past the fence check never outranks the
    successor line. Fencing at COMMIT rather than at ``save()`` keeps
    the check off the hot path and closes the enqueue->write race: the
    authoritative read happens on the writer thread, after the data is
    durable, immediately before visibility;
  * defense in depth past the marker: restore VALIDATES the newest
    committed snapshot (manifest parse, array load, shape/dtype check
    against the manifest) and on a truncated/corrupt/concurrently-GCed
    snapshot it warns and falls back to the previous entry instead of
    crashing the resume (`latest_valid_step`/`restore*`);
  * keep_k garbage collection bounds disk (ordered by (epoch, step),
    so a superseded line's snapshots age out first);
  * ELASTIC restore: arrays are saved as full (host-gathered) logical
    tensors, so a checkpoint written on a 2x16x16 mesh restores onto a
    16x16 (or any other) mesh — restore takes target shardings and
    device_puts each leaf accordingly. On multi-host each host would
    write only its addressable shards (same manifest format, per-shard
    files), which is a file-naming change, not a format change.
"""
from __future__ import annotations

import itertools
import json
import os
import re
import shutil
import threading
import time
import warnings
from typing import Any

import jax
import numpy as np

FENCE_FILE = "FENCE"

_OWNER_SEQ = itertools.count()   # unique default owner per writer

_FENCE_LOCK = threading.Lock()   # serialize in-process fence advances

_STEP_RE = re.compile(r"^step_(\d{9})(?:\.e(\d{6}))?$")
_COMMIT_RE = re.compile(r"^step_(\d{9})(?:\.e(\d{6}))?\.COMMIT$")
_TMP_RE = re.compile(r"^\.tmp_step_(\d{9})(?:\.e(\d{6}))?(?:\.(.+))?$")


class FencedWriterError(RuntimeError):
    """Raised at ``Checkpointer`` construction when the fence token is
    already superseded: another writer line (a lease takeover, a
    relaunched attempt) advanced the shared FENCE past this epoch, so
    nothing this writer could commit would ever be restored."""


class FencedCommitError(RuntimeError):
    """A commit was rejected at the rename boundary: the shared FENCE
    advanced past this writer's epoch between open and commit — the
    writer is a zombie (its controller abandoned it, or its controller
    lost the lease) and its snapshot must not become visible."""

    def __init__(self, msg: str, *, step: int, epoch: int, fence: int,
                 directory: str):
        super().__init__(msg)
        self.step = step
        self.epoch = epoch
        self.fence = fence
        self.directory = directory


class CheckpointWriteError(RuntimeError):
    """A background checkpoint write failed. Wraps the original error
    with the step id and directory so a fleet log can attribute the
    lost commit to a snapshot (the on-disk state stays at the previous
    commit). The original exception rides ``__cause__``."""

    def __init__(self, msg: str, *, step: int, epoch: int,
                 directory: str):
        super().__init__(msg)
        self.step = step
        self.epoch = epoch
        self.directory = directory


def _fsync_path(path: str) -> None:
    """fsync a file or directory by path (directory fsync is what makes
    a rename durable on POSIX filesystems)."""
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _fence_floor(directory: str) -> int:
    """Lower bound on the max epoch ever granted, recovered from the
    epoch tags in step/COMMIT/tmp names. Every tagged entry was written
    by a writer whose epoch the fence had been advanced to, so the
    advance-only counter can never legitimately sit below this."""
    try:
        names = os.listdir(directory)
    except OSError:
        return 0
    floor = 0
    for f in names:
        m = _STEP_RE.match(f) or _COMMIT_RE.match(f) or _TMP_RE.match(f)
        if m is not None:
            floor = max(floor, int(m.group(2) or 0))
    return floor


def read_fence(directory: str) -> int:
    """Max epoch ever granted on this checkpoint directory (0 if no
    fenced writer has opened it). A torn/corrupt/deleted FENCE file
    does NOT read as 0 — that would let ``advance_fence`` roll the
    advance-only counter backward and previously-fenced zombie epochs
    would pass the commit-boundary check again. Instead the fence is
    recovered from the epoch tags present in the directory
    (``_fence_floor``): a lower bound, but one that covers every epoch
    with on-disk evidence, so zombie rejection survives torn
    metadata."""
    try:
        with open(os.path.join(directory, FENCE_FILE)) as f:
            return int(json.load(f)["epoch"])
    except (OSError, TypeError, ValueError, KeyError,
            json.JSONDecodeError):
        return _fence_floor(directory)


def advance_fence(directory: str, epoch: int, owner: str | None = None
                  ) -> int:
    """Advance the shared fence to ``epoch`` (no-op if already there or
    beyond); returns the resulting fence. The write is atomic
    (tmp + fsync + rename + directory fsync), so a concurrent reader
    sees either the old or the new epoch, never a tear. Advance-only:
    the fence is the single monotonic counter that attempt epochs AND
    lease terms are minted from (``runtime/lease.py``); because
    ``read_fence`` recovers a floor from on-disk epoch tags when the
    FENCE file itself is torn, corruption cannot be leveraged to write
    an epoch below what the directory's contents already prove."""
    # The lock serializes in-process advancers (several controllers in
    # one test process): without it, two threads could interleave
    # read-then-replace and roll the fence BACKWARD. Cross-process the
    # window is benign for correctness of the protocols built on top —
    # terms/epochs are minted max(fence)+1 and verified after write
    # (lease re-read; FencedWriterError at open) — but in-process we
    # can simply not have the window.
    with _FENCE_LOCK:
        cur = read_fence(directory)
        if epoch <= cur:
            return cur
        os.makedirs(directory, exist_ok=True)
        tmp = os.path.join(
            directory,
            f".{FENCE_FILE}.tmp.{os.getpid()}.{next(_OWNER_SEQ)}")
        with open(tmp, "w") as f:
            json.dump({"epoch": int(epoch), "owner": owner,
                       "time": time.time()}, f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, os.path.join(directory, FENCE_FILE))
        _fsync_path(directory)
        return epoch


def _tree_flatten_with_names(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path) for path, _ in flat]
    return names, [leaf for _, leaf in flat], treedef


class Checkpointer:
    """``epoch=None`` (default) is the legacy single-writer mode: no
    fence is advanced and commits are never rejected — exactly the
    pre-fencing behavior. ``epoch=e`` opens a FENCED writer: the shared
    FENCE advances to ``e`` at open (raising :class:`FencedWriterError`
    if already superseded) and every commit re-checks the fence at the
    rename boundary. ``owner`` scopes the tmp work directories so a
    sweep never deletes a live competitor's in-flight write."""

    def __init__(self, directory: str, keep_k: int = 3, *,
                 epoch: int | None = None, owner: str | None = None):
        self.dir = directory
        self.keep_k = keep_k
        self.epoch = int(epoch) if epoch is not None else 0
        self._fenced = epoch is not None
        self.owner = (str(owner) if owner
                      else f"pid{os.getpid()}w{next(_OWNER_SEQ)}")
        self.fenced_commits = 0          # rejected-at-boundary count
        os.makedirs(directory, exist_ok=True)
        if self._fenced:
            fence = read_fence(directory)
            if fence > self.epoch:
                raise FencedWriterError(
                    f"checkpoint writer opened with fence token (epoch) "
                    f"{self.epoch}, but {directory} has already granted "
                    f"epoch {fence} — this writer line is superseded and "
                    "must not commit (resume under a fresh epoch instead)")
            advance_fence(directory, self.epoch, self.owner)
        self._sweep_stale_tmp()
        self._thread: threading.Thread | None = None
        self._error: Exception | None = None

    # ------------------------------------------------------------- naming
    def _name(self, step: int, epoch: int | None = None) -> str:
        e = self.epoch if epoch is None else epoch
        base = f"step_{step:09d}"
        return base if e == 0 else f"{base}.e{e:06d}"

    @staticmethod
    def _parse_commit(fname: str) -> tuple[int, int] | None:
        m = _COMMIT_RE.match(fname)
        if m is None:
            return None
        return (int(m.group(2) or 0), int(m.group(1)))   # (epoch, step)

    def _sweep_stale_tmp(self) -> None:
        """Remove stale ``.tmp_step_*`` work directories left by a
        crash mid-save. OWNER-SCOPED: with several writers sharing the
        directory, sweeping everything would delete a live competitor's
        in-flight write. A tmp is swept iff it belongs to this owner,
        predates this writer's epoch (its line is fenced — it can never
        commit, so its work is garbage), or carries no owner tag at all
        (legacy writer, by definition single-writer)."""
        for f in os.listdir(self.dir):
            m = _TMP_RE.match(f)
            if m is None:
                continue
            tmp_epoch = int(m.group(2) or 0)
            tmp_owner = m.group(3)
            if (tmp_owner is None or tmp_owner == self.owner
                    or tmp_epoch < self.epoch):
                shutil.rmtree(os.path.join(self.dir, f),
                              ignore_errors=True)

    # ------------------------------------------------------------- saving
    def save(self, step: int, tree: Any, *, blocking: bool = False,
             meta: dict | None = None) -> None:
        """Snapshot to host, then write in the background.

        ``meta`` is an optional JSON-able dict stored in the manifest —
        the solver keeps its scalar resume state (iteration, histories,
        config fingerprint) there so the array leaves stay pure tensors.
        """
        self.wait()  # at most one outstanding save
        names, leaves, _ = _tree_flatten_with_names(tree)
        host = [np.asarray(x) for x in leaves]   # device->host snapshot

        def _write():
            name = self._name(step)
            # Fenced writers OWN their tmp dirs (multi-writer safety);
            # legacy writers keep the untagged PR-6 name, whose sweep
            # assumes single-writer.
            tmp = os.path.join(
                self.dir, f".tmp_{name}.{self.owner}" if self._fenced
                else f".tmp_{name}")
            try:
                final = os.path.join(self.dir, name)
                shutil.rmtree(tmp, ignore_errors=True)
                os.makedirs(os.path.join(tmp, "arrays"))
                manifest = {"step": step, "epoch": self.epoch,
                            "time": time.time(),
                            "meta": meta or {}, "leaves": []}
                for i, (n, a) in enumerate(zip(names, host)):
                    with open(os.path.join(tmp, "arrays", f"{i}.npy"),
                              "wb") as f:
                        np.save(f, a)
                        f.flush()
                        os.fsync(f.fileno())
                    manifest["leaves"].append(
                        {"name": n, "idx": i, "shape": list(a.shape),
                         "dtype": str(a.dtype)})
                with open(os.path.join(tmp, "manifest.json"), "w") as f:
                    json.dump(manifest, f)
                    f.flush()
                    os.fsync(f.fileno())
                # Data must be durable BEFORE the rename/COMMIT become
                # visible, or a power loss could leave a committed step
                # with torn contents.
                _fsync_path(os.path.join(tmp, "arrays"))
                _fsync_path(tmp)
                # FENCE CHECK at the rename boundary: after the data
                # fsync, before anything becomes visible. A writer
                # whose epoch was superseded while it was writing (its
                # controller lost the lease; its attempt was abandoned
                # and relaunched) is a zombie — reject the commit.
                if self._fenced:
                    fence = read_fence(self.dir)
                    if fence > self.epoch:
                        shutil.rmtree(tmp, ignore_errors=True)
                        self.fenced_commits += 1
                        raise FencedCommitError(
                            f"commit of {name} in {self.dir} rejected: "
                            f"writer epoch {self.epoch} superseded by "
                            f"fence {fence} — a newer attempt owns this "
                            "checkpoint line (zombie write fenced out)",
                            step=step, epoch=self.epoch, fence=fence,
                            directory=self.dir)
                if os.path.exists(final + ".COMMIT"):
                    # Same (epoch, step) already committed — never
                    # clobber a committed snapshot; same epoch + same
                    # step means the identical trajectory bits anyway.
                    shutil.rmtree(tmp, ignore_errors=True)
                else:
                    # A final dir WITHOUT a commit marker is the crash
                    # window (death between rename and COMMIT): it was
                    # never a restore candidate, so the next writer of
                    # the same step replaces it.
                    shutil.rmtree(final, ignore_errors=True)
                    os.rename(tmp, final)
                    _fsync_path(self.dir)              # durable rename
                    with open(final + ".COMMIT", "w") as f:
                        f.flush()
                        os.fsync(f.fileno())           # atomic commit mark
                    _fsync_path(self.dir)
                self._gc()
            except FencedCommitError as e:
                self._error = e
            except Exception as e:  # noqa: BLE001
                shutil.rmtree(tmp, ignore_errors=True)
                self._error = CheckpointWriteError(
                    f"background checkpoint write of {name} in "
                    f"{self.dir} failed ({e!r}) — the commit is lost; "
                    "on-disk state stays at the previous committed "
                    "snapshot", step=step, epoch=self.epoch,
                    directory=self.dir)
                self._error.__cause__ = e

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()
        if blocking:
            self.wait()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self) -> None:
        if not self.keep_k:
            return
        records = self.all_records()
        for e, s in records[: -self.keep_k]:
            name = self._name(s, e)
            shutil.rmtree(os.path.join(self.dir, name),
                          ignore_errors=True)
            try:
                os.remove(os.path.join(self.dir, name + ".COMMIT"))
            except OSError:
                pass

    # ------------------------------------------------------------ restore
    def all_records(self) -> list[tuple[int, int]]:
        """All committed snapshots as ``(epoch, step)``, sorted
        epoch-major: the LAST entry is what restore resolves with no
        pin. Epoch-major ordering is the fencing guarantee's second
        half — even a zombie commit that raced past the fence check
        never outranks the successor line's snapshots."""
        out = []
        for f in os.listdir(self.dir):
            rec = self._parse_commit(f)
            if rec is not None:
                out.append(rec)
        return sorted(out)

    def all_steps(self) -> list[int]:
        return sorted({s for _, s in self.all_records()})

    def latest_record(self) -> tuple[int, int] | None:
        records = self.all_records()
        return records[-1] if records else None

    def latest_step(self) -> int | None:
        rec = self.latest_record()
        return rec[1] if rec else None

    def _read_record(self, epoch: int, step: int) -> tuple[dict, dict]:
        """Load + VALIDATE one committed snapshot: the manifest must
        parse and every leaf array must load with the manifest's
        shape/dtype. Raises on any corruption (truncated npy, torn
        manifest, missing file — including a directory a competitor's
        GC deleted between listing and load) — the fallback loop below
        turns that into skip-and-warn."""
        final = os.path.join(self.dir, self._name(step, epoch))
        with open(os.path.join(final, "manifest.json")) as f:
            manifest = json.load(f)
        arrays: dict[str, np.ndarray] = {}
        for e in manifest["leaves"]:
            a = np.load(os.path.join(final, "arrays", f"{e['idx']}.npy"))
            if (list(a.shape) != list(e["shape"])
                    or str(a.dtype) != e["dtype"]):
                raise ValueError(
                    f"leaf {e['name']!r} of {self._name(step, epoch)} "
                    f"loads as {a.shape}/{a.dtype}, manifest says "
                    f"{e['shape']}/{e['dtype']} — corrupt snapshot")
            arrays[e["name"]] = a
        return arrays, manifest

    def _resolve_pin(self, step: int) -> tuple[int, int]:
        """A pinned step resolves to its newest epoch (the successor
        line's copy when both a zombie and its successor committed the
        same step id)."""
        epochs = [e for e, s in self.all_records() if s == step]
        if not epochs:
            raise FileNotFoundError(
                f"no committed checkpoint for step {step} in {self.dir}")
        return max(epochs), step

    def _load_valid(self, step: int | None) -> tuple[int, dict, dict]:
        """Resolve ``step`` to a VALID snapshot. An explicit step is
        loaded strictly (corruption raises — the caller pinned it). With
        ``step=None``, committed records are tried newest-first in
        ``(epoch, step)`` order; a truncated/corrupt/concurrently-
        deleted snapshot is skipped with a warning and the previous
        entry is used instead, so one torn write (or a competitor's GC
        racing this read) never poisons the whole resume directory."""
        if step is not None:
            epoch, step = self._resolve_pin(step)
            arrays, manifest = self._read_record(epoch, step)
            return step, arrays, manifest
        records = self.all_records()
        if not records:
            raise FileNotFoundError(f"no committed checkpoint in {self.dir}")
        for e, s in reversed(records):
            try:
                arrays, manifest = self._read_record(e, s)
                return s, arrays, manifest
            except Exception as exc:  # noqa: BLE001 — corrupt: try older
                warnings.warn(
                    f"checkpoint {self._name(s, e)} in {self.dir} is "
                    f"unreadable ({exc!r}); falling back to the previous "
                    "committed snapshot", RuntimeWarning, stacklevel=3)
        raise FileNotFoundError(
            f"all {len(records)} committed checkpoints in {self.dir} are "
            "corrupt — nothing to restore (poisoned checkpoint "
            "directory)")

    def latest_valid_step(self) -> int | None:
        """Newest committed step that actually loads — what restore()
        with ``step=None`` will use. Corrupt newer steps warn."""
        try:
            step, _, _ = self._load_valid(None)
        except FileNotFoundError:
            return None
        return step

    def restore(self, tree_like: Any, step: int | None = None,
                shardings: Any = None) -> Any:
        """Restore into the structure of ``tree_like``; with ``shardings``
        given (a matching tree of NamedSharding / None), each leaf is
        device_put with its target sharding — this is the elastic-remesh
        path (checkpoint mesh need not equal restore mesh)."""
        step, arrays, manifest = self._load_valid(step)
        names, leaves, treedef = _tree_flatten_with_names(tree_like)
        by_name = {e["name"]: e for e in manifest["leaves"]}
        sh_leaves = (jax.tree.leaves(shardings, is_leaf=lambda x: x is None)
                     if shardings is not None else [None] * len(leaves))
        out = []
        for n, leaf, sh in zip(names, leaves, sh_leaves):
            if n not in by_name:
                raise ValueError(
                    f"checkpoint step_{step:09d} in {self.dir} has no "
                    f"leaf named {n!r}; it holds "
                    f"{sorted(e['name'] for e in manifest['leaves'])} — "
                    "the restore tree's structure does not match what "
                    "was saved (config/model mismatch?)")
            a = arrays[n]
            want = tuple(getattr(leaf, "shape", a.shape))
            if tuple(a.shape) != want:
                raise ValueError(
                    f"checkpoint leaf {n!r} of step_{step:09d} in "
                    f"{self.dir} has shape {tuple(a.shape)}, the restore "
                    f"tree expects {want} — restoring requires matching "
                    "logical shapes (checkpoints are layout-free, so an "
                    "elastic remesh changes SHARDING, never shape; a "
                    "shape change means a different dataset, "
                    "featurization, or model was used)")
            out.append(jax.device_put(a, sh) if sh is not None
                       else jax.device_put(a))
        return jax.tree.unflatten(treedef, out)

    def restore_named(self, step: int | None = None
                      ) -> tuple[dict, dict]:
        """Restore as a flat ``{leaf_name: np.ndarray}`` dict plus the
        manifest (which carries ``meta``). Structure-free counterpart of
        ``restore`` for callers whose payload shape is data-dependent —
        the solver's resume path, where history lengths and the presence
        of mid-pass accumulators vary per checkpoint."""
        _, arrays, manifest = self._load_valid(step)
        return arrays, manifest
