"""Fault-tolerant checkpointing: async, atomic, keep-K, elastic restore.

Layout (one directory per step):
    <dir>/step_000100/
        manifest.json        # tree structure, shapes, dtypes, mesh info
        arrays/<idx>.npy     # one file per leaf (host-gathered)
    <dir>/step_000100.COMMIT # written last -> crash-safe atomicity

Design points for 1000+ node deployments (documented where this
single-host implementation stands in for the multi-host version):
  * save is ASYNC: the step's arrays are snapshotted to host memory
    synchronously (cheap device->host copy) and written by a background
    thread, so training never blocks on the filesystem;
  * atomicity by COMMIT marker — restore only considers committed steps,
    so a node failure mid-save never corrupts the restore point. Every
    file (arrays, manifest, the marker) is fsynced and the containing
    directories are fsynced around the rename, so the commit cannot be
    reordered ahead of its data by the page cache on a power loss;
  * defense in depth past the marker: restore VALIDATES the newest
    committed snapshot (manifest parse, array load, shape/dtype check
    against the manifest) and on a truncated/corrupt snapshot — torn
    write, bit rot, an fsync-less writer from an older version — it
    warns and falls back to the previous keep_k entry instead of
    crashing the resume (`latest_valid_step`/`restore*`);
  * keep_k garbage collection bounds disk;
  * ELASTIC restore: arrays are saved as full (host-gathered) logical
    tensors, so a checkpoint written on a 2x16x16 mesh restores onto a
    16x16 (or any other) mesh — restore takes target shardings and
    device_puts each leaf accordingly. On multi-host each host would
    write only its addressable shards (same manifest format, per-shard
    files), which is a file-naming change, not a format change.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
import warnings
from typing import Any

import jax
import numpy as np


def _fsync_path(path: str) -> None:
    """fsync a file or directory by path (directory fsync is what makes
    a rename durable on POSIX filesystems)."""
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _tree_flatten_with_names(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path) for path, _ in flat]
    return names, [leaf for _, leaf in flat], treedef


class Checkpointer:
    def __init__(self, directory: str, keep_k: int = 3):
        self.dir = directory
        self.keep_k = keep_k
        os.makedirs(directory, exist_ok=True)
        self._sweep_stale_tmp()
        self._thread: threading.Thread | None = None
        self._error: Exception | None = None

    def _sweep_stale_tmp(self) -> None:
        """Remove ``.tmp_step_*`` work directories left by a crash
        mid-save. They are never restore candidates (no COMMIT marker),
        but without this sweep they accumulate forever on a preemption-
        heavy deployment; construction is the natural restart point."""
        for f in os.listdir(self.dir):
            if f.startswith(".tmp_step_"):
                shutil.rmtree(os.path.join(self.dir, f),
                              ignore_errors=True)

    # ------------------------------------------------------------- saving
    def save(self, step: int, tree: Any, *, blocking: bool = False,
             meta: dict | None = None) -> None:
        """Snapshot to host, then write in the background.

        ``meta`` is an optional JSON-able dict stored in the manifest —
        the solver keeps its scalar resume state (iteration, histories,
        config fingerprint) there so the array leaves stay pure tensors.
        """
        self.wait()  # at most one outstanding save
        names, leaves, _ = _tree_flatten_with_names(tree)
        host = [np.asarray(x) for x in leaves]   # device->host snapshot

        def _write():
            try:
                tmp = os.path.join(self.dir, f".tmp_step_{step:09d}")
                final = os.path.join(self.dir, f"step_{step:09d}")
                shutil.rmtree(tmp, ignore_errors=True)
                os.makedirs(os.path.join(tmp, "arrays"))
                manifest = {"step": step, "time": time.time(),
                            "meta": meta or {}, "leaves": []}
                for i, (n, a) in enumerate(zip(names, host)):
                    with open(os.path.join(tmp, "arrays", f"{i}.npy"),
                              "wb") as f:
                        np.save(f, a)
                        f.flush()
                        os.fsync(f.fileno())
                    manifest["leaves"].append(
                        {"name": n, "idx": i, "shape": list(a.shape),
                         "dtype": str(a.dtype)})
                with open(os.path.join(tmp, "manifest.json"), "w") as f:
                    json.dump(manifest, f)
                    f.flush()
                    os.fsync(f.fileno())
                # Data must be durable BEFORE the rename/COMMIT become
                # visible, or a power loss could leave a committed step
                # with torn contents.
                _fsync_path(os.path.join(tmp, "arrays"))
                _fsync_path(tmp)
                shutil.rmtree(final, ignore_errors=True)
                os.rename(tmp, final)
                _fsync_path(self.dir)                  # durable rename
                with open(final + ".COMMIT", "w") as f:
                    f.flush()
                    os.fsync(f.fileno())               # atomic commit mark
                _fsync_path(self.dir)
                self._gc()
            except Exception as e:  # noqa: BLE001
                self._error = e

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()
        if blocking:
            self.wait()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep_k] if self.keep_k else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:09d}"),
                          ignore_errors=True)
            try:
                os.remove(os.path.join(self.dir, f"step_{s:09d}.COMMIT"))
            except OSError:
                pass

    # ------------------------------------------------------------ restore
    def all_steps(self) -> list[int]:
        out = []
        for f in os.listdir(self.dir):
            if f.endswith(".COMMIT"):
                out.append(int(f[len("step_"):-len(".COMMIT")]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def _read_step(self, step: int) -> tuple[dict, dict]:
        """Load + VALIDATE one committed step: the manifest must parse
        and every leaf array must load with the manifest's shape/dtype.
        Raises on any corruption (truncated npy, torn manifest, missing
        file) — the fallback loop below turns that into skip-and-warn."""
        final = os.path.join(self.dir, f"step_{step:09d}")
        with open(os.path.join(final, "manifest.json")) as f:
            manifest = json.load(f)
        arrays: dict[str, np.ndarray] = {}
        for e in manifest["leaves"]:
            a = np.load(os.path.join(final, "arrays", f"{e['idx']}.npy"))
            if (list(a.shape) != list(e["shape"])
                    or str(a.dtype) != e["dtype"]):
                raise ValueError(
                    f"leaf {e['name']!r} of step_{step:09d} loads as "
                    f"{a.shape}/{a.dtype}, manifest says "
                    f"{e['shape']}/{e['dtype']} — corrupt snapshot")
            arrays[e["name"]] = a
        return arrays, manifest

    def _load_valid(self, step: int | None) -> tuple[int, dict, dict]:
        """Resolve ``step`` to a VALID snapshot. An explicit step is
        loaded strictly (corruption raises — the caller pinned it). With
        ``step=None``, committed steps are tried newest-first; a
        truncated/corrupt snapshot is skipped with a warning and the
        previous keep_k entry is used instead, so one torn write never
        poisons the whole resume directory."""
        if step is not None:
            arrays, manifest = self._read_step(step)
            return step, arrays, manifest
        steps = self.all_steps()
        if not steps:
            raise FileNotFoundError(f"no committed checkpoint in {self.dir}")
        for s in reversed(steps):
            try:
                arrays, manifest = self._read_step(s)
                return s, arrays, manifest
            except Exception as e:  # noqa: BLE001 — corrupt: try older
                warnings.warn(
                    f"checkpoint step_{s:09d} in {self.dir} is "
                    f"unreadable ({e!r}); falling back to the previous "
                    "committed snapshot", RuntimeWarning, stacklevel=3)
        raise FileNotFoundError(
            f"all {len(steps)} committed checkpoints in {self.dir} are "
            "corrupt — nothing to restore (poisoned checkpoint "
            "directory)")

    def latest_valid_step(self) -> int | None:
        """Newest committed step that actually loads — what restore()
        with ``step=None`` will use. Corrupt newer steps warn."""
        try:
            step, _, _ = self._load_valid(None)
        except FileNotFoundError:
            return None
        return step

    def restore(self, tree_like: Any, step: int | None = None,
                shardings: Any = None) -> Any:
        """Restore into the structure of ``tree_like``; with ``shardings``
        given (a matching tree of NamedSharding / None), each leaf is
        device_put with its target sharding — this is the elastic-remesh
        path (checkpoint mesh need not equal restore mesh)."""
        step, arrays, manifest = self._load_valid(step)
        names, leaves, treedef = _tree_flatten_with_names(tree_like)
        by_name = {e["name"]: e for e in manifest["leaves"]}
        sh_leaves = (jax.tree.leaves(shardings, is_leaf=lambda x: x is None)
                     if shardings is not None else [None] * len(leaves))
        out = []
        for n, leaf, sh in zip(names, leaves, sh_leaves):
            if n not in by_name:
                raise ValueError(
                    f"checkpoint step_{step:09d} in {self.dir} has no "
                    f"leaf named {n!r}; it holds "
                    f"{sorted(e['name'] for e in manifest['leaves'])} — "
                    "the restore tree's structure does not match what "
                    "was saved (config/model mismatch?)")
            a = arrays[n]
            want = tuple(getattr(leaf, "shape", a.shape))
            assert tuple(a.shape) == want, (n, a.shape, want)
            out.append(jax.device_put(a, sh) if sh is not None
                       else jax.device_put(a))
        return jax.tree.unflatten(treedef, out)

    def restore_named(self, step: int | None = None
                      ) -> tuple[dict, dict]:
        """Restore as a flat ``{leaf_name: np.ndarray}`` dict plus the
        manifest (which carries ``meta``). Structure-free counterpart of
        ``restore`` for callers whose payload shape is data-dependent —
        the solver's resume path, where history lengths and the presence
        of mid-pass accumulators vary per checkpoint."""
        _, arrays, manifest = self._load_valid(step)
        return arrays, manifest
