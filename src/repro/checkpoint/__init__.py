"""Fault-tolerant checkpointing (async, atomic, keep-K, elastic
restore, epoch-fenced multi-writer safety)."""
from .checkpointer import (Checkpointer, CheckpointWriteError,  # noqa: F401
                           FencedCommitError, FencedWriterError,
                           advance_fence, read_fence)
