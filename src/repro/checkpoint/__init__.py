"""Fault-tolerant checkpointing (async, atomic, keep-K, elastic restore)."""
from .checkpointer import Checkpointer  # noqa: F401
