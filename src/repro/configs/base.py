"""Config schema for the assigned architectures and input shapes.

Every architecture in the assignment table gets a ``ModelConfig`` in its
own module (src/repro/configs/<id>.py) registered under its ``--arch`` id.
``ShapeConfig`` encodes the four assigned input shapes; applicability of
``long_500k`` / decode shapes is derived from the architecture family
(DESIGN.md §4)."""
from __future__ import annotations

import dataclasses
from typing import Callable


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 128
    # --- MoE
    n_experts: int = 0          # routed experts (0 = dense)
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0           # per-expert hidden dim
    moe_every: int = 1          # MoE block on layers l % moe_every == moe_offset
    moe_offset: int = 0
    moe_capacity_factor: float = 1.25  # GShard-style drop policy
    # --- MLA (deepseek-v2)
    mla: bool = False
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    qk_rope_dim: int = 64
    qk_nope_dim: int = 128
    v_head_dim: int = 128
    # --- hybrid (jamba): attention on layers l % attn_every == attn_offset
    attn_every: int = 0         # 0 = attention everywhere
    attn_offset: int = 0
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2
    mamba_dt_rank: int = 0      # 0 -> ceil(d_model/16)
    # --- xLSTM: sLSTM on layers l % slstm_every == slstm_offset
    slstm_every: int = 0        # 0 = no sLSTM (all mLSTM)
    slstm_offset: int = 0
    lstm_expand: int = 2
    # --- encoder-decoder (whisper)
    enc_dec: bool = False
    n_enc_layers: int = 0
    enc_seq: int = 1500         # whisper 30s @ 50Hz after conv stride 2
    # --- VLM
    mrope: bool = False
    mrope_sections: tuple[int, ...] = (16, 24, 24)   # pairs of head_dim/2
    frontend: str | None = None  # 'audio' | 'vision' stubs (embeddings input)
    # --- common
    rope_theta: float = 1e4
    norm_eps: float = 1e-5
    use_bias: bool = False
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    source: str = ""            # provenance tag from the assignment table

    # ------------------------------------------------------------- derived
    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def subquadratic(self) -> bool:
        """Eligible for long_500k (SSM / hybrid)."""
        return self.family in ("ssm", "hybrid")

    @property
    def layer_period(self) -> int:
        """Homogeneous layer-group size for scan-over-layers."""
        import math
        p = 1
        if self.attn_every:
            p = math.lcm(p, self.attn_every)
        if self.moe_every > 1:
            p = math.lcm(p, self.moe_every)
        if self.slstm_every:
            p = math.lcm(p, self.slstm_every)
        return p

    @property
    def d_inner(self) -> int:           # mamba / xlstm inner width
        return self.mamba_expand * self.d_model if self.family == "hybrid" \
            else self.lstm_expand * self.d_model

    @property
    def dt_rank(self) -> int:
        return self.mamba_dt_rank or -(-self.d_model // 16)

    def is_attn_layer(self, layer: int) -> bool:
        if self.family == "ssm":
            return False
        if not self.attn_every:
            return True
        return layer % self.attn_every == self.attn_offset

    def is_moe_layer(self, layer: int) -> bool:
        if not self.n_experts:
            return False
        return layer % self.moe_every == self.moe_offset

    def is_slstm_layer(self, layer: int) -> bool:
        if not self.slstm_every:
            return False
        return layer % self.slstm_every == self.slstm_offset

    def num_params(self) -> int:
        """Analytic parameter count (used for 6ND roofline MODEL_FLOPS)."""
        d, V = self.d_model, self.vocab
        total = V * d  # embedding
        if not self.tie_embeddings:
            total += V * d
        for l in range(self.n_layers):
            if self.is_attn_layer(l):
                if self.mla:
                    qd = (self.qk_rope_dim + self.qk_nope_dim)
                    total += d * self.q_lora_rank if self.q_lora_rank else 0
                    qin = self.q_lora_rank or d
                    total += qin * self.n_heads * qd
                    total += d * (self.kv_lora_rank + self.qk_rope_dim)
                    total += self.kv_lora_rank * self.n_heads * (
                        self.qk_nope_dim + self.v_head_dim)
                    total += self.n_heads * self.v_head_dim * d
                else:
                    total += d * self.n_heads * self.head_dim * 2  # q, o
                    total += d * self.n_kv_heads * self.head_dim * 2
            elif self.family == "hybrid":  # mamba block
                di, ds, dc = self.d_inner, self.mamba_d_state, self.mamba_d_conv
                total += d * 2 * di + di * dc + di * (self.dt_rank + 2 * ds)
                total += self.dt_rank * di + di * ds + di + di * d
            if self.family == "ssm":
                di = self.d_inner
                hd = di // self.n_heads
                if self.is_slstm_layer(l):
                    total += 4 * d * d + 4 * self.n_heads * (d // self.n_heads) ** 2
                else:
                    total += d * 2 * di + 3 * di * di // self.n_heads + di * d
                total += 2 * d  # norms
                continue
            if self.is_moe_layer(l):
                e = self.n_experts + self.n_shared_experts
                total += e * 3 * d * self.moe_d_ff + d * self.n_experts
            elif self.d_ff:
                mult = 2 if self.use_bias else 3  # gelu mlp vs swiglu
                total += mult * d * self.d_ff
            total += 2 * d  # norms
        if self.enc_dec:
            for _ in range(self.n_enc_layers):
                total += d * self.n_heads * self.head_dim * 4
                total += 2 * d * self.d_ff + 2 * d
            # decoder cross-attention
            total += self.n_layers * (d * self.n_heads * self.head_dim * 4 + d)
            total += self.enc_seq * d  # encoder positions
        total += d  # final norm
        return total

    def active_params(self) -> int:
        """Active (per-token) params for MoE 6ND accounting."""
        if not self.n_experts:
            return self.num_params()
        full_moe = self.n_experts * 3 * self.d_model * self.moe_d_ff
        act_moe = (self.top_k) * 3 * self.d_model * self.moe_d_ff
        n_moe_layers = sum(self.is_moe_layer(l) for l in range(self.n_layers))
        return self.num_params() - n_moe_layers * (full_moe - act_moe)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                   # 'train' | 'prefill' | 'decode'


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def applicable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """(runs, reason-if-skipped) for an (arch, shape) cell."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, ("full-attention architecture: 500k-token cache is "
                       "quadratic-regime; skipped per assignment note")
    return True, ""


_REGISTRY: dict[str, Callable[[], ModelConfig]] = {}


def register(arch_id: str):
    def deco(fn):
        _REGISTRY[arch_id] = fn
        return fn
    return deco


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _REGISTRY:
        # import config modules lazily on first miss
        from . import _load_all
        _load_all()
    if arch_id not in _REGISTRY:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[arch_id]()


def list_archs() -> list[str]:
    from . import _load_all
    _load_all()
    return sorted(_REGISTRY)
