"""Architecture configs (one module per assigned arch) + shape table."""
import importlib

from .base import (  # noqa: F401
    SHAPES, ModelConfig, ShapeConfig, applicable, get_config, list_archs,
    register)

_MODULES = [
    "yi_34b", "granite_3_2b", "smollm_135m", "deepseek_67b",
    "granite_moe_1b_a400m", "deepseek_v2_236b", "jamba_v0_1_52b",
    "xlstm_350m", "qwen2_vl_72b", "whisper_small", "svm_paper",
]

_loaded = False


def _load_all():
    global _loaded
    if _loaded:
        return
    for m in _MODULES:
        importlib.import_module(f"repro.configs.{m}")
    _loaded = True
