"""Whisper-small transformer backbone: encoder-decoder, conv audio
frontend stubbed to precomputed frame embeddings
[arXiv:2212.04356; unverified]."""
from .base import ModelConfig, register


@register("whisper-small")
def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-small", family="audio",
        n_layers=12, d_model=768, n_heads=12, n_kv_heads=12, head_dim=64,
        d_ff=3072, vocab=51865,
        enc_dec=True, n_enc_layers=12, enc_seq=1500,
        frontend="audio", use_bias=True, tie_embeddings=True,
        source="arXiv:2212.04356; unverified",
    )
