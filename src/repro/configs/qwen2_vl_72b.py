"""Qwen2-VL-72B language backbone: GQA + M-RoPE, dynamic-resolution vision
stubbed to precomputed patch embeddings [arXiv:2409.12191; hf]."""
from .base import ModelConfig, register


@register("qwen2-vl-72b")
def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-72b", family="vlm",
        n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
        d_ff=29568, vocab=152064, rope_theta=1e6,
        mrope=True, mrope_sections=(16, 24, 24), frontend="vision",
        source="arXiv:2409.12191; hf",
    )
