"""SmolLM-135M: small llama-arch GQA [hf:HuggingFaceTB/SmolLM-135M].

Also the ~100M-class model used by the end-to-end training example."""
from .base import ModelConfig, register


@register("smollm-135m")
def config() -> ModelConfig:
    return ModelConfig(
        name="smollm-135m", family="dense",
        n_layers=30, d_model=576, n_heads=9, n_kv_heads=3, head_dim=64,
        d_ff=1536, vocab=49152, rope_theta=1e4, tie_embeddings=True,
        source="hf:HuggingFaceTB/SmolLM-135M",
    )
