"""xLSTM-350M: mLSTM blocks with periodic sLSTM blocks
[arXiv:2405.04517; unverified].

Assignment: 24L d_model=1024 4H d_ff=0 (projections live inside the
blocks). sLSTM on l % 6 == 5 (4 of 24; ~7:1 mLSTM:sLSTM)."""
from .base import ModelConfig, register


@register("xlstm-350m")
def config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-350m", family="ssm",
        n_layers=24, d_model=1024, n_heads=4, n_kv_heads=4, head_dim=256,
        d_ff=0, vocab=50304, lstm_expand=2,
        slstm_every=6, slstm_offset=5,
        source="arXiv:2405.04517; unverified",
    )
