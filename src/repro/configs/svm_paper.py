"""The paper's own experiment configurations (Tables 3-10) as SVMConfig
factories, dataset-shape pairs included."""
from repro.core import SVMConfig, lam_from_C

# Paper Table 5 / Fig 2: dna, LIN-EM-CLS, C=1e-5
dna_lin_em_cls = lambda: SVMConfig.from_options(
    "LIN-EM-CLS", lam=lam_from_C(1e-5), max_iters=100)
# Paper Table 6: year, LIN-EM-SVR, C=0.01, eps=0.3
year_lin_em_svr = lambda: SVMConfig.from_options(
    "LIN-EM-SVR", lam=lam_from_C(0.01), eps_ins=0.3, max_iters=100)
# Paper Table 7: news20 subset, KRN-EM-CLS, C=1
news20_krn_em_cls = lambda: SVMConfig.from_options(
    "KRN-EM-CLS", lam=lam_from_C(1.0), sigma=1.0, max_iters=100)
# Paper Table 8: mnist8m, LIN-MC-MLT, C=0.04
mnist8m_lin_mc_mlt = lambda: SVMConfig.from_options(
    "LIN-MC-MLT", lam=lam_from_C(0.04), num_classes=10, max_iters=100,
    burnin=10)
