"""Jamba-v0.1-52B: hybrid Mamba+attention (1:7 interleave) with 16-expert
top-2 MoE every other layer [arXiv:2403.19887; hf].

Layer l is attention iff l % 8 == 4 (4 of 32); MoE iff l % 2 == 1."""
from .base import ModelConfig, register


@register("jamba-v0.1-52b")
def config() -> ModelConfig:
    return ModelConfig(
        name="jamba-v0.1-52b", family="hybrid",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
        d_ff=14336, vocab=65536,
        n_experts=16, top_k=2, moe_d_ff=14336, moe_every=2, moe_offset=1,
        attn_every=8, attn_offset=4,
        mamba_d_state=16, mamba_d_conv=4, mamba_expand=2,
        source="arXiv:2403.19887; hf",
    )
