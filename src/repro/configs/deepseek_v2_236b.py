"""DeepSeek-V2-236B: MLA (kv_lora=512) + MoE 2 shared + 160 routed top-6
[arXiv:2405.04434; hf].

Assignment table lists GQA kv=128 (i.e. MHA head count) and d_ff=1536 (the
per-expert hidden dim); MLA replaces the KV cache with a 512-dim latent."""
from .base import ModelConfig, register


@register("deepseek-v2-236b")
def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-236b", family="moe",
        n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128, head_dim=128,
        d_ff=12288, vocab=102400,
        n_experts=160, n_shared_experts=2, top_k=6, moe_d_ff=1536,
        moe_every=1, moe_offset=0,
        mla=True, kv_lora_rank=512, q_lora_rank=1536,
        qk_rope_dim=64, qk_nope_dim=128, v_head_dim=128,
        source="arXiv:2405.04434; hf",
    )
