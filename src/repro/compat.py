"""JAX version-compatibility shims — the single place for them.

``shard_map`` moved twice upstream:

  * jax <  0.4.?? : ``jax.experimental.shard_map.shard_map`` (kwarg
    ``check_rep``)
  * jax >= 0.6    : public ``jax.shard_map`` (kwarg ``check_vma``)

Every module in this package imports it from here so the repo runs on
either API. The wrapper also translates the replication-check kwarg in
both directions, since callers were written against the new name.
"""
from __future__ import annotations

import inspect

import jax as _jax

try:  # jax >= 0.6: public API
    from jax import shard_map as _shard_map  # type: ignore[attr-defined]
except ImportError:  # jax 0.4.x / 0.5.x
    from jax.experimental.shard_map import shard_map as _shard_map

_PARAMS = frozenset(inspect.signature(_shard_map).parameters)


def shard_map(f=None, **kw):
    """Call the underlying shard_map, renaming the replication-check kwarg
    (``check_vma`` <-> ``check_rep``) to whatever this jax exposes."""
    if "check_vma" in kw and "check_vma" not in _PARAMS:
        kw["check_rep"] = kw.pop("check_vma")
    elif "check_rep" in kw and "check_rep" not in _PARAMS:
        kw["check_vma"] = kw.pop("check_rep")
    if f is None:  # support use as a decorator factory
        return lambda fn: _shard_map(fn, **kw)
    return _shard_map(f, **kw)


def axis_size(axis_name) -> int:
    """Static size of a named mesh axis, inside shard_map/pmap tracing.

    ``jax.lax.axis_size`` is jax >= 0.6; older jax exposes the same
    static value through ``jax.core.axis_frame`` (which, depending on
    version, returns the frame or the size itself)."""
    if hasattr(_jax.lax, "axis_size"):
        return _jax.lax.axis_size(axis_name)
    from jax.core import axis_frame
    frame = axis_frame(axis_name)
    return getattr(frame, "size", frame)


def set_mesh(mesh):
    """Context manager activating ``mesh``: ``jax.set_mesh`` on new jax,
    the Mesh's own context-manager protocol on older releases."""
    if hasattr(_jax, "set_mesh"):
        return _jax.set_mesh(mesh)
    return mesh


_MAKE_MESH_PARAMS = frozenset(inspect.signature(_jax.make_mesh).parameters)


def make_mesh(axis_shapes, axis_names, *, axis_types=None, **kw):
    """``jax.make_mesh`` dropping ``axis_types`` (jax >= 0.5 only) when
    this jax does not accept it. Callers that want explicit axis types
    pass the *name* ``"auto"``/``"explicit"`` per axis (or a sequence of
    jax AxisType values on new jax)."""
    if axis_types is not None and "axis_types" in _MAKE_MESH_PARAMS:
        AxisType = _jax.sharding.AxisType
        axis_types = tuple(
            getattr(AxisType, t.capitalize()) if isinstance(t, str) else t
            for t in axis_types)
        kw["axis_types"] = axis_types
    return _jax.make_mesh(axis_shapes, axis_names, **kw)
