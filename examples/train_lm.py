"""End-to-end LM training driver (deliverable (b)): wraps
repro.launch.train. The default trains a reduced model for a quick CPU
demo; ``--preset full --arch smollm-135m`` is the real ~135M-parameter
run (use on TPU, or be very patient on CPU).

    PYTHONPATH=src python examples/train_lm.py --steps 200
"""
import subprocess
import sys


def main():
    args = sys.argv[1:] or ["--arch", "smollm-135m", "--preset", "tiny",
                            "--steps", "200", "--batch", "8",
                            "--seq", "256", "--ckpt-dir", "runs/train_lm"]
    cmd = [sys.executable, "-m", "repro.launch.train"] + args
    print("running:", " ".join(cmd))
    raise SystemExit(subprocess.call(cmd, env={
        **__import__("os").environ,
        "PYTHONPATH": "src"}))


if __name__ == "__main__":
    main()
