"""Composite max-margin model (paper Sec 1 / DESIGN.md §4): a frozen LM
backbone + PEMSVM head — the MedLDA-style use case the paper motivates,
with any assigned architecture as the feature extractor.

    PYTHONPATH=src python examples/lm_feature_svm.py [--arch smollm-135m]
"""
import argparse
import dataclasses
import sys

sys.path.insert(0, "src")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.core import MaxMarginHead, SVMConfig, mean_pool  # noqa: E402
from repro.models import build_model  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    args = ap.parse_args()

    cfg = dataclasses.replace(
        get_config(args.arch), n_layers=2, d_model=128, n_heads=4,
        n_kv_heads=2, head_dim=32, d_ff=256, vocab=256)
    model = build_model(cfg, q_chunk=32, kv_chunk=32)
    params = model.init(jax.random.PRNGKey(0))

    # synthetic "document classification": token-range signal
    rng = np.random.default_rng(0)
    N, S = 1200, 32
    cls = rng.random(N) > 0.5
    toks = np.where(cls[:, None], rng.integers(0, 96, (N, S)),
                    rng.integers(160, 256, (N, S))).astype(np.int32)
    y = np.where(cls, 1.0, -1.0)

    def feature_fn(tokens):
        h = model.hidden_seq(params, {"tokens": tokens}, remat=False)
        return mean_pool(h.astype(jnp.float32))

    head = MaxMarginHead(SVMConfig(lam=0.1, max_iters=60), feature_fn)
    res = head.fit(toks[:1000], y[:1000])
    print(f"backbone={args.arch} (frozen, reduced)  head=LIN-EM-CLS")
    print(f"converged={res.converged} iters={res.n_iters}")
    print(f"train acc={head.score(toks[:1000], y[:1000]):.4f}  "
          f"test acc={head.score(toks[1000:], y[1000:]):.4f}")


if __name__ == "__main__":
    main()
