"""Quickstart: train the paper's parallel sampling SVM (PEMSVM).

    PYTHONPATH=src python examples/quickstart.py

Fits LIN-EM-CLS on a synthetic binary problem with the paper's protocol
(objective-change stopping, gamma clamping), reports accuracy and the
convergence trace. Runs identically on one device or a TPU pod — pass a
mesh to PEMSVM(...) to engage the Fig.-1 map-reduce over all devices."""
import sys

sys.path.insert(0, "src")

from repro.core import PEMSVM, SVMConfig, lam_from_C  # noqa: E402
from repro.data import make_blobs  # noqa: E402


def main():
    X, y = make_blobs(n=20_000, k=100, seed=0)
    Xtr, ytr, Xte, yte = X[:16_000], y[:16_000], X[16_000:], y[16_000:]

    config = SVMConfig.from_options("LIN-EM-CLS", lam=lam_from_C(1.0),
                                    max_iters=100)
    svm = PEMSVM(config)           # PEMSVM(config, mesh=...) on a pod
    result = svm.fit(Xtr, ytr)

    print(f"options       : {config.options}")
    print(f"converged     : {result.converged} "
          f"({result.n_iters} iterations — paper reports 40-60 for EM)")
    print(f"train objective: {result.objective[0]:.1f} -> "
          f"{result.objective[-1]:.1f}")
    print(f"test accuracy : {svm.score(Xte, yte):.4f}")

    # MCMC flavor: posterior-averaged weights (paper Sec 5.13)
    mc = PEMSVM(SVMConfig.from_options("LIN-MC-CLS", lam=lam_from_C(1.0),
                                       max_iters=60, burnin=10))
    mc.fit(Xtr, ytr)
    print(f"MC accuracy   : {mc.score(Xte, yte):.4f} (averaged samples)")


if __name__ == "__main__":
    main()
