"""Beyond-paper example: the paper asks (Sec 4.3) whether PSVM's sqrt(N)
kernel approximation can compose with the sampling SVM — NystromSVM is
that composition. Kernel accuracy at linear-solver cost.

    PYTHONPATH=src python examples/nystrom_kernel_svm.py
"""
import sys, time

sys.path.insert(0, "src")

from repro.core import NystromSVM, SVMConfig  # noqa: E402
from repro.data import make_circles  # noqa: E402


def main():
    X, y = make_circles(10_000)
    t0 = time.time()
    svm = NystromSVM(SVMConfig.from_options(
        "KRN-EM-CLS", lam=0.1, sigma=0.7, max_iters=60))  # m = sqrt(N) = 100
    res = svm.fit(X, y)
    print(f"N=10,000 kernel SVM via m=100 landmarks: "
          f"acc={svm.score(X, y):.4f} iters={res.n_iters} "
          f"({time.time() - t0:.1f}s; exact KRN is O(N^3) per iteration)")


if __name__ == "__main__":
    main()
