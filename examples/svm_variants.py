"""All six option axes of the paper (Sec 4.2) on their matching tasks:

  LIN-{EM,MC}-CLS   binary classification     (dna-like)
  LIN-EM-SVR        support vector regression (year protocol, eps=0.3)
  LIN-MC-MLT        Crammer-Singer multiclass (mnist8m protocol, C=0.04)
  KRN-{EM,MC}-CLS   RBF kernel                (not linearly separable)

    PYTHONPATH=src python examples/svm_variants.py
"""
import sys

sys.path.insert(0, "src")

from repro.core import PEMSVM, SVMConfig, lam_from_C  # noqa: E402
from repro.data import (  # noqa: E402
    make_circles, make_dna_like, make_mnist8m_like, make_year_like)


def main():
    X, y = make_dna_like(20_000, 200)
    for algo in ("EM", "MC"):
        svm = PEMSVM(SVMConfig.from_options(
            f"LIN-{algo}-CLS", lam=lam_from_C(1e-5), max_iters=60))
        r = svm.fit(X, y)
        print(f"LIN-{algo}-CLS  acc={svm.score(X, y):.4f} "
              f"iters={r.n_iters}")

    Xr, yr = make_year_like(20_000, 90)
    svr = PEMSVM(SVMConfig.from_options(
        "LIN-EM-SVR", lam=lam_from_C(0.01), eps_ins=0.3, max_iters=60))
    svr.fit(Xr, yr)
    print(f"LIN-EM-SVR  rmse={svr.rmse(Xr, yr):.4f} (paper: 0.90 on year)")

    Xm, lm = make_mnist8m_like(10_000, 128, 10)
    mlt = PEMSVM(SVMConfig.from_options(
        "LIN-MC-MLT", num_classes=10, lam=lam_from_C(0.04), max_iters=35,
        min_iters=25))
    mlt.fit(Xm, lm)
    print(f"LIN-MC-MLT  acc={mlt.score(Xm, lm):.4f}")

    Xc, yc = make_circles(600)
    for algo in ("EM", "MC"):
        k = PEMSVM(SVMConfig.from_options(
            f"KRN-{algo}-CLS", lam=lam_from_C(1.0), sigma=0.7,
            max_iters=50))
        k.fit(Xc, yc)
        print(f"KRN-{algo}-CLS  acc={k.score(Xc, yc):.4f} "
              f"(linear would be ~0.5)")


if __name__ == "__main__":
    main()
