"""Batched serving example (deliverable (b)): prefill + greedy decode on
any assigned architecture via repro.launch.serve.

    PYTHONPATH=src python examples/serve_lm.py --arch xlstm-350m
"""
import subprocess
import sys


def main():
    args = sys.argv[1:] or ["--arch", "smollm-135m", "--preset", "tiny",
                            "--batch", "4", "--prompt-len", "32",
                            "--steps", "16"]
    cmd = [sys.executable, "-m", "repro.launch.serve"] + args
    print("running:", " ".join(cmd))
    raise SystemExit(subprocess.call(cmd, env={
        **__import__("os").environ,
        "PYTHONPATH": "src"}))


if __name__ == "__main__":
    main()
