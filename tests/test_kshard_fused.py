"""Column-tiled fused statistics: the single-stream 2-D (data x model)
``k_shard_axis`` path (ISSUE 5).

Layers under test:

  1. Kernel: the column-windowed ``fused_stats`` /
     ``nystrom_fused_stats`` equal the full kernel's column slice on
     odd masked shapes, across ref and interpret backends, for every
     epilogue, at aligned AND unaligned (traced) window starts.
  2. Draws: the windowed MC statistic's gamma draws are BITWISE the
     ``gamma_mc_rowwise`` oracle's on the dispatch path — margin/gamma
     stay full-width, so windowing cannot perturb the chain.
  3. Invariance (subprocess, multi-device CPU): on a 2-D (data x
     model) mesh, k_shard fits match the replicated single-device fits
     — exactly at iteration one, within the documented fp32 windows on
     short chains — for CLS/SVR/MLT, EM and MC, and the MC chain is
     the SAME chain (rowwise-keyed draws; the SVR accept-reject fork
     channel gets the streaming tests' loose long-chain band).
  4. Composition: k_shard x phi_spec (the formerly NotImplementedError
     pair) — whole-fit EM parity <= 1e-4 vs the replicated Nystrom
     path.
  5. Padding: ``pad_features_to`` + ``SVMConfig.pad_features`` make an
     indivisible K fit under k_shard with unchanged predictions;
     ``_k_block`` still hard-errors and names the helper.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import augment
from repro.data.pipeline import pad_features_to
from repro.kernels import ops

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))

WINDOWS = ((0, 29), (5, 7), (22, 7), (13, 1), (0, 1))


def _problem(n=37, k=29, seed=0):
    rng = np.random.default_rng(seed)
    X = jnp.asarray(rng.normal(size=(n, k)).astype(np.float32))
    y = jnp.asarray(rng.choice([-1.0, 1.0], n).astype(np.float32))
    ys = jnp.asarray((np.asarray(X) @ rng.normal(size=k))
                     .astype(np.float32))
    w = jnp.asarray(rng.normal(size=k).astype(np.float32))
    wm = jnp.asarray((rng.random(n) > 0.2).astype(np.float32))
    return X, y, ys, w, wm


# ------------------------------------------------ 1. windowed == slice
@pytest.mark.parametrize("backend", ["ref", "interpret"])
@pytest.mark.parametrize("epilogue", ["em_hinge", "mc_hinge", "em_svr",
                                      "mc_svr"])
def test_windowed_equals_full_column_slice(backend, epilogue):
    X, y, ys, w, wm = _problem()
    key = jax.random.PRNGKey(3)
    svr = epilogue.endswith("svr")
    rho = ys if svr else y
    beta = jnp.zeros_like(y) if svr else y
    if epilogue == "mc_hinge":
        noise = augment.draw_ig_noise(key, X.shape[0], 11)
    elif epilogue == "mc_svr":
        k_lo, k_hi = jax.random.split(key)
        noise = (*augment.draw_ig_noise(k_lo, X.shape[0], 11),
                 *augment.draw_ig_noise(k_hi, X.shape[0], 11))
    else:
        noise = None
    kw = dict(epilogue=epilogue, eps=1e-4, eps_ins=0.2, backend=backend)
    full = ops.fused_stats(X, rho, beta, w, wm, noise, **kw)
    for start, blk in WINDOWS:
        # traced start: the in-mesh reality (axis_index * blk)
        win = ops.fused_stats(X, rho, beta, w, wm, noise,
                              col_window=(jnp.int32(start), blk), **kw)
        np.testing.assert_allclose(
            np.asarray(win[-1]),
            np.asarray(full[-1])[:, start:start + blk],
            rtol=2e-6, atol=2e-6, err_msg=f"S window ({start}, {blk})")
        # margin / aug / b are full-width and UNCHANGED by windowing
        for a, b_ in zip(win[:-1], full[:-1]):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b_))


@pytest.mark.parametrize("backend", ["ref", "interpret"])
def test_nystrom_windowed_equals_full_phi_column_slice(backend):
    rng = np.random.default_rng(1)
    n, m, d = 37, 13, 9
    X = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    L = jnp.asarray(rng.normal(size=(m, d)).astype(np.float32))
    proj = jnp.asarray(rng.normal(size=(m, m)).astype(np.float32))
    y = jnp.asarray(rng.choice([-1.0, 1.0], n).astype(np.float32))
    wm = jnp.asarray((rng.random(n) > 0.3).astype(np.float32))
    wphi = jnp.asarray(rng.normal(size=m + 1).astype(np.float32))
    noise = augment.draw_ig_noise(jax.random.PRNGKey(5), n, 3)
    for epilogue, nz in (("em_hinge", None), ("mc_hinge", noise)):
        kw = dict(sigma=0.9, add_bias=True, epilogue=epilogue, eps=1e-4,
                  backend=backend)
        full = ops.nystrom_fused_stats(X, L, proj, y, y, wphi, wm, nz,
                                       **kw)
        for start, blk in ((0, 14), (3, 5), (9, 5), (7, 7), (13, 1)):
            win = ops.nystrom_fused_stats(
                X, L, proj, y, y, wphi, wm, nz,
                col_window=(jnp.int32(start), blk), **kw)
            np.testing.assert_allclose(
                np.asarray(win[-1]),
                np.asarray(full[-1])[:, start:start + blk],
                rtol=2e-5, atol=2e-5)
            for a, b_ in zip(win[:-1], full[:-1]):
                np.testing.assert_array_equal(np.asarray(a),
                                              np.asarray(b_))


def test_windowed_vmem_fallback_matches_kernel():
    """Past the windowed byte budget the dispatch falls back to the
    plain-XLA column block; outputs must match the kernel route."""
    X, y, _, w, wm = _problem()
    assert not ops.fused_stats_fits(X.shape[1], 7, block_n=10 ** 6)
    assert ops.fused_stats_fits(X.shape[1], 7)
    kw = dict(epilogue="em_hinge", eps=1e-4)
    win = ops.fused_stats(X, y, y, w, wm, None, col_window=(5, 7),
                          backend="interpret", **kw)
    fb = ops.fused_stats(X, y, y, w, wm, None, col_window=(5, 7),
                         backend="interpret", block_n=10 ** 6, **kw)
    for a, b_ in zip(win, fb):
        # different routes (Pallas tile vs XLA matmul): fp32
        # reassociation tolerance, not bitwise
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   rtol=5e-4, atol=1e-5)


def test_windowed_budget_unlocks_k_beyond_full_cap():
    """The narrowed accumulator is the point of the windowed budget: a
    K past FUSED_STATS_MAX_K (full-width fallback regime) still FUSES
    when only a column block is accumulated."""
    K = ops.FUSED_STATS_MAX_K + 512
    assert not ops.fused_stats_fits(K)
    assert ops.fused_stats_fits(K, col_blk=K // 16)


# ------------------------------------------------ 2. bitwise MC draws
def test_windowed_mc_draws_bitwise_vs_oracle():
    X, y, _, w, wm = _problem(64, 16, seed=7)
    key, row0, eps = jax.random.PRNGKey(9), 17, 1e-6
    margin = X @ w
    want = augment.gamma_mc_rowwise(key, y - margin, eps, row0)
    noise = augment.draw_ig_noise(key, X.shape[0], row0)
    out = ops.fused_stats(X, y, y, w, None, noise,
                          col_window=(jnp.int32(4), 4),
                          epilogue="mc_hinge", eps=eps, backend="ref")
    np.testing.assert_array_equal(np.asarray(out[1]), np.asarray(want))


# ------------------------------------------------ 5. feature padding
def test_pad_features_to():
    X = np.ones((5, 7), np.float32)
    P = pad_features_to(X, 4)
    assert P.shape == (5, 8)
    np.testing.assert_array_equal(P[:, 7:], 0.0)
    assert pad_features_to(X, 7) is X          # already divisible
    assert pad_features_to(X, 1) is X
    Pj = pad_features_to(jnp.asarray(X), 4)    # jax arrays too
    assert isinstance(Pj, jnp.ndarray) and Pj.shape == (5, 8)


def test_k_block_error_names_the_pad_helper():
    from repro.compat import make_mesh, shard_map
    from jax.sharding import PartitionSpec as P

    from repro.core.linear import _k_block

    mesh = make_mesh((1,), ("model",))

    def f(x):
        return jnp.asarray(_k_block(x.shape[-1], "model")[0])

    g = jax.jit(shard_map(f, mesh=mesh, in_specs=(P(None, None),),
                          out_specs=P(), check_vma=False))
    assert int(g(jnp.zeros((4, 6)))) == 0
    # the real refusal needs axis size > 1 -> exercised in the
    # subprocess tests below; here check the message contract directly
    import repro.core.linear as linear_mod
    import repro.compat as compat_mod
    orig = compat_mod.axis_size
    try:
        compat_mod.axis_size = lambda a: 2
        with pytest.raises(ValueError) as ei:
            linear_mod._k_block(7, "model")
    finally:
        compat_mod.axis_size = orig
    msg = str(ei.value)
    assert "does not divide" in msg
    assert "pad_features_to" in msg


# ------------------------ 3./4. subprocess multi-device fit invariance
def run_with_devices(code: str, n_devices: int = 4, timeout: int = 600):
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={n_devices}")
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    p = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env,
                       timeout=timeout)
    assert p.returncode == 0, f"STDOUT:\n{p.stdout}\nSTDERR:\n{p.stderr}"
    return p.stdout


HEADER = """
import numpy as np, jax, jax.numpy as jnp
from repro import compat
from repro.core import PEMSVM, SVMConfig
mesh = compat.make_mesh((2, 2), ("data", "model"),
                        axis_types=("auto",) * 2)
rng = np.random.default_rng(0)
N, K = 1024, 23                       # +bias -> 24, model axis 2 | 24
w_true = rng.normal(size=K)
X = rng.normal(size=(N, K)).astype(np.float32)
y = np.where(X @ w_true + 0.3 * rng.normal(size=N) > 0, 1.0, -1.0)
ys = (X @ w_true).astype(np.float32)
lab = rng.integers(0, 3, N).astype(np.int32)
def trace_rel(a, b):
    a, b = np.array(a.objective), np.array(b.objective)
    return np.abs(a - b) / np.maximum(np.abs(b), 1.0)
"""


def test_kshard_2d_mesh_em_parity_all_tasks():
    """EM on the 2-D mesh: CLS, SVR and MLT (the two newly-enabled
    tasks) match the replicated fit — deterministic, so tight."""
    run_with_devices(HEADER + """
for task, tgt in (("CLS", y), ("SVR", ys), ("MLT", lab)):
    cfg = dict(task=task, max_iters=15, min_iters=15, eps=1e-2,
               num_classes=3)
    r1 = PEMSVM(SVMConfig(**cfg)).fit(X, tgt)
    rk = PEMSVM(SVMConfig(k_shard_axis="model", **cfg), mesh=mesh,
                data_axes=("data",)).fit(X, tgt)
    rel = np.abs(rk.weights - r1.weights).max() / np.abs(r1.weights).max()
    assert rel < 1e-3, (task, rel)
print("EM k_shard parity OK")
""")


def test_kshard_2d_mesh_mc_chain_invariance():
    """MC on the 2-D mesh draws the SAME chain as the replicated fit:
    iteration one is exact (same rowwise-keyed draws), short chains
    stay in the documented fp32 windows (CLS tight; SVR gets the
    streaming tests' loose long-chain band — the IG accept-reject fork
    channel, DESIGN.md §Perf/Streaming)."""
    run_with_devices(HEADER + """
bands = {"CLS": 2e-3, "SVR": 5e-2, "MLT": 2e-3}
for task, tgt in (("CLS", y), ("SVR", ys), ("MLT", lab)):
    cfg = dict(task=task, algorithm="MC", max_iters=12, min_iters=12,
               eps=1e-2, burnin=6, num_classes=3)
    r1 = PEMSVM(SVMConfig(**cfg)).fit(X, tgt)
    rk = PEMSVM(SVMConfig(k_shard_axis="model", **cfg), mesh=mesh,
                data_axes=("data",)).fit(X, tgt)
    rel = trace_rel(rk, r1)
    assert rel[0] < 1e-6, (task, rel[0])          # same draws at iter 1
    assert rel.max() < bands[task], (task, rel)
print("MC k_shard chain invariance OK")
""")


def test_kshard_mesh_layout_invariance():
    """The sampled MC chain must not depend on HOW the 2-D mesh is
    laid out: (2, 2) and (1, 4) (data x model) give the same chain up
    to fp32 psum reassociation."""
    run_with_devices(HEADER + """
mesh14 = compat.make_mesh((1, 4), ("data", "model"),
                          axis_types=("auto",) * 2)
cfg = dict(task="CLS", algorithm="MC", max_iters=10, min_iters=10,
           eps=1e-2, burnin=5)
a = PEMSVM(SVMConfig(k_shard_axis="model", **cfg), mesh=mesh,
           data_axes=("data",)).fit(X, y)
b = PEMSVM(SVMConfig(k_shard_axis="model", **cfg), mesh=mesh14,
           data_axes=("data",)).fit(X, y)
rel = trace_rel(a, b)
assert rel.max() < 2e-3, rel
print("mesh layout invariance OK")
""")


def test_kshard_phi_spec_whole_fit_parity():
    """The formerly-NotImplementedError composition: k_shard_axis x
    phi_spec (Nystrom). Whole-fit EM parity <= 1e-4 vs the replicated
    Nystrom path; MC iteration one exact."""
    run_with_devices(HEADER + """
from repro.core.nystrom import NystromSVM
def kcfg(**kw):
    return SVMConfig(formulation="KRN", sigma=1.2, eps=1e-2,
                     max_iters=15, min_iters=15, **kw)
n1 = NystromSVM(kcfg(), n_landmarks=31)           # phi width 32 -> | 2
r1 = n1.fit(X, y)
nk = NystromSVM(kcfg(k_shard_axis="model"), n_landmarks=31, mesh=mesh,
                data_axes=("data",))
rk = nk.fit(X, y)
rel = np.abs(rk.weights - r1.weights).max() / np.abs(r1.weights).max()
assert rel < 1e-4, rel
assert abs(n1.score(X, y) - nk.score(X, y)) < 1e-2
mc1 = NystromSVM(kcfg(algorithm="MC", burnin=5), n_landmarks=31)
a = mc1.fit(X, y)
mck = NystromSVM(kcfg(algorithm="MC", burnin=5, k_shard_axis="model"),
                 n_landmarks=31, mesh=mesh, data_axes=("data",))
b = mck.fit(X, y)
rel = trace_rel(b, a)
assert rel[0] < 1e-6, rel[0]
assert rel.max() < 5e-3, rel
print("k_shard x phi_spec parity OK")
""")


def test_kshard_pad_features_whole_fit():
    """Indivisible width (K=23 + bias = 24... use model=4 -> 24 | 4 is
    fine, so go through a 23-wide no-bias fit: 23 % 2 != 0): the
    config plumb pads to a k_shard-divisible width, predictions match
    the unpadded replicated fit, and WITHOUT the pad _k_block raises
    the pad-helper error."""
    run_with_devices(HEADER + """
base = PEMSVM(SVMConfig(max_iters=15, min_iters=15, eps=1e-2,
                        add_bias=False)).fit(X, y)
padded = PEMSVM(SVMConfig(max_iters=15, min_iters=15, eps=1e-2,
                          add_bias=False, k_shard_axis="model",
                          pad_features=2),
                mesh=mesh, data_axes=("data",))
rp = padded.fit(X, y)
assert rp.weights.shape == (24,)
rel = np.abs(rp.weights[:K] - base.weights).max() / np.abs(
    base.weights).max()
assert rel < 1e-3, rel
assert rp.weights[K:].max() == 0.0          # zero columns stay zero
b1 = PEMSVM(SVMConfig(max_iters=15, eps=1e-2, add_bias=False))
b1._weights = base.weights
assert abs(padded.score(X, y) - b1.score(X, y)) < 1e-6
try:
    PEMSVM(SVMConfig(max_iters=2, min_iters=1, eps=1e-2,
                     add_bias=False, k_shard_axis="model"),
           mesh=mesh, data_axes=("data",)).fit(X, y)
except ValueError as e:
    assert "pad_features_to" in str(e), e
else:
    raise SystemExit("expected ValueError for K=23 over 2-way axis")
print("pad_features whole-fit OK")
""")
