"""Checkpointer: atomicity, keep-K GC, async errors, restore, epoch
fencing (multi-writer safety)."""
import os
import shutil

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (Checkpointer, CheckpointWriteError,
                              FencedCommitError, FencedWriterError,
                              advance_fence, read_fence)


def _tree(x=1.0):
    return {"a": jnp.full((4, 3), x), "b": {"c": jnp.arange(5) * int(x)}}


def test_save_restore_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(10, _tree(2.0), blocking=True)
    out = ck.restore(_tree(0.0))
    np.testing.assert_allclose(np.asarray(out["a"]), 2.0)
    np.testing.assert_allclose(np.asarray(out["b"]["c"]), np.arange(5) * 2)


def test_latest_and_keep_k(tmp_path):
    ck = Checkpointer(str(tmp_path), keep_k=2)
    for s in [1, 2, 3, 4]:
        ck.save(s, _tree(float(s)), blocking=True)
    assert ck.all_steps() == [3, 4]
    assert ck.latest_step() == 4
    out = ck.restore(_tree(0.0), step=3)
    np.testing.assert_allclose(np.asarray(out["a"]), 3.0)


def test_uncommitted_checkpoint_ignored(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(5, _tree(5.0), blocking=True)
    # simulate crash mid-save of step 6: directory exists, no COMMIT
    os.makedirs(tmp_path / "step_000000006" / "arrays")
    assert ck.latest_step() == 5


def test_restore_missing_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        Checkpointer(str(tmp_path)).restore(_tree())


def test_async_save_overlaps_and_waits(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, _tree(1.0))        # async
    ck.save(2, _tree(2.0))        # waits for 1, starts 2
    ck.wait()
    assert ck.all_steps() == [1, 2]


def test_shape_mismatch_is_diagnosable_valueerror(tmp_path):
    """A shape mismatch at restore is an operator-facing config error,
    not an internal invariant: the message must name the leaf, both
    shapes, and explain that an elastic remesh changes SHARDING never
    shape (so the operator doesn't misattribute it to resizing the
    fleet)."""
    ck = Checkpointer(str(tmp_path))
    ck.save(1, _tree(), blocking=True)
    bad = {"a": jnp.zeros((2, 2)), "b": {"c": jnp.arange(5)}}
    with pytest.raises(ValueError) as ei:
        ck.restore(bad)
    msg = str(ei.value)
    assert "'a'" in msg and "(4, 3)" in msg and "(2, 2)" in msg
    assert "remesh" in msg and "SHARDING" in msg


def test_background_write_failure_carries_step_and_dir(tmp_path,
                                                       monkeypatch):
    """An async write failure surfaces at the next save()/wait() as
    CheckpointWriteError carrying the step id and directory (so a fleet
    log can attribute the lost commit), with the original error as
    __cause__."""
    ck = Checkpointer(str(tmp_path))
    ck.save(1, _tree(1.0), blocking=True)

    def boom(*a, **k):
        raise IOError("injected: disk full")
    monkeypatch.setattr(np, "save", boom)
    ck.save(5, _tree(5.0))
    with pytest.raises(CheckpointWriteError) as ei:
        ck.wait()
    assert ei.value.step == 5
    assert ei.value.directory == str(tmp_path)
    assert "step_000000005" in str(ei.value)
    assert isinstance(ei.value.__cause__, IOError)
    monkeypatch.undo()
    assert ck.all_steps() == [1]          # on-disk state untouched
    ck.save(5, _tree(5.0), blocking=True)  # writer still usable
    assert ck.all_steps() == [1, 5]


def test_stale_tmp_swept_at_construction(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, _tree(), blocking=True)
    # simulate a crash mid-save: orphaned work dir, no COMMIT
    os.makedirs(tmp_path / ".tmp_step_000000002" / "arrays")
    ck2 = Checkpointer(str(tmp_path))
    assert not (tmp_path / ".tmp_step_000000002").exists()
    assert ck2.all_steps() == [1]  # committed steps untouched


def test_restore_missing_leaf_names_it(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, {"a": jnp.zeros(3)}, blocking=True)
    with pytest.raises(ValueError, match="no leaf named 'zzz'"):
        ck.restore({"a": jnp.zeros(3), "zzz": jnp.zeros(2)})


def test_meta_roundtrip_and_restore_named(tmp_path):
    ck = Checkpointer(str(tmp_path))
    meta = {"it": 7, "objs": [1.0, 0.5], "fingerprint": "xyz"}
    ck.save(7, {"state": jnp.arange(4.0), "key": jnp.zeros(2)},
            meta=meta, blocking=True)
    arrays, manifest = ck.restore_named()
    assert manifest["meta"] == meta
    assert set(arrays) == {"state", "key"}
    np.testing.assert_array_equal(arrays["state"], np.arange(4.0))
    # pinned step works too
    arrays2, m2 = ck.restore_named(step=7)
    assert m2["step"] == 7


# ---------------------------------------- torn-write defense (PR 8)
def _truncate(path, nbytes=8):
    """Simulate a torn write: keep only the first bytes of a file —
    what a power loss can leave behind despite a COMMIT marker written
    by an fsync-less older writer."""
    with open(path, "rb") as f:
        head = f.read(nbytes)
    with open(path, "wb") as f:
        f.write(head)


def test_torn_array_falls_back_with_warning(tmp_path):
    """A truncated leaf in the NEWEST committed snapshot must not kill
    the resume: restore warns and falls back to the previous keep_k
    entry; ``latest_valid_step`` reports the step restore will use."""
    ck = Checkpointer(str(tmp_path), keep_k=3)
    for s in [1, 2, 3]:
        ck.save(s, _tree(float(s)), blocking=True)
    _truncate(tmp_path / "step_000000003" / "arrays" / "0.npy")

    assert ck.latest_step() == 3                 # still committed...
    with pytest.warns(RuntimeWarning, match="step_000000003"):
        assert ck.latest_valid_step() == 2       # ...but not restorable
    with pytest.warns(RuntimeWarning):
        out = ck.restore(_tree(0.0))
    np.testing.assert_allclose(np.asarray(out["a"]), 2.0)


def test_torn_manifest_falls_back(tmp_path):
    ck = Checkpointer(str(tmp_path), keep_k=3)
    ck.save(1, _tree(1.0), blocking=True)
    ck.save(2, _tree(2.0), blocking=True)
    _truncate(tmp_path / "step_000000002" / "manifest.json")
    with pytest.warns(RuntimeWarning):
        arrays, manifest = ck.restore_named()
    assert manifest["step"] == 1


def test_wrong_shape_on_disk_is_corruption(tmp_path):
    """Bit-rot that still parses: an array whose shape/dtype disagrees
    with the manifest is treated as corruption, not silently restored."""
    ck = Checkpointer(str(tmp_path), keep_k=3)
    ck.save(1, _tree(1.0), blocking=True)
    ck.save(2, _tree(2.0), blocking=True)
    np.save(tmp_path / "step_000000002" / "arrays" / "0.npy",
            np.zeros((9, 9), np.float64))
    with pytest.warns(RuntimeWarning, match="corrupt"):
        out = ck.restore(_tree(0.0))
    np.testing.assert_allclose(np.asarray(out["a"]), 1.0)


def test_all_corrupt_is_poisoned_directory(tmp_path):
    ck = Checkpointer(str(tmp_path), keep_k=3)
    for s in [1, 2]:
        ck.save(s, _tree(float(s)), blocking=True)
        _truncate(tmp_path / f"step_{s:09d}" / "manifest.json")
    with pytest.warns(RuntimeWarning):
        with pytest.raises(FileNotFoundError, match="poisoned"):
            ck.restore(_tree(0.0))
    with pytest.warns(RuntimeWarning):
        assert ck.latest_valid_step() is None


def test_pinned_step_loads_strictly(tmp_path):
    """An EXPLICITLY pinned step does not silently fall back — the
    caller asked for those bits, so corruption raises."""
    ck = Checkpointer(str(tmp_path), keep_k=3)
    ck.save(1, _tree(1.0), blocking=True)
    ck.save(2, _tree(2.0), blocking=True)
    _truncate(tmp_path / "step_000000002" / "arrays" / "0.npy")
    with pytest.raises(Exception):
        ck.restore(_tree(0.0), step=2)
    out = ck.restore(_tree(0.0), step=1)         # older pin still fine
    np.testing.assert_allclose(np.asarray(out["a"]), 1.0)


# ------------------------------------ crash window + GC races (PR 9)
def test_crash_between_rename_and_commit_is_recoverable(tmp_path):
    """Death in the window between os.rename(tmp, final) and the COMMIT
    write leaves a final dir with no marker. Restore must never
    consider it, and the NEXT writer of the same step must replace it
    cleanly rather than erroring or committing the orphan's bits."""
    ck = Checkpointer(str(tmp_path))
    ck.save(1, _tree(1.0), blocking=True)
    # Simulate the crash window for step 2: full final dir, no COMMIT.
    ck.save(2, _tree(99.0), blocking=True)
    os.remove(tmp_path / "step_000000002.COMMIT")
    assert ck.latest_step() == 1                  # orphan invisible
    # The relaunch re-saves step 2 (different bits — the orphan's were
    # never acknowledged): it must win.
    ck2 = Checkpointer(str(tmp_path))
    ck2.save(2, _tree(2.0), blocking=True)
    assert ck2.latest_step() == 2
    out = ck2.restore(_tree(0.0), step=2)
    np.testing.assert_allclose(np.asarray(out["a"]), 2.0)


def test_competitor_gc_race_falls_back_with_warning(tmp_path):
    """all_records() -> _read_record() can race another writer's _gc:
    the listed snapshot vanishes between listing and load. That must be
    absorbed by skip-and-warn (FileNotFoundError is just another form
    of 'this entry is unreadable'), falling back to the previous
    record."""
    ck = Checkpointer(str(tmp_path), keep_k=3)
    for s in [1, 2, 3]:
        ck.save(s, _tree(float(s)), blocking=True)
    # Competitor's _gc deleted the newest snapshot dir but its COMMIT
    # marker still lists it (the rmtree-then-remove window).
    shutil.rmtree(tmp_path / "step_000000003")
    assert ck.latest_step() == 3                  # still listed...
    with pytest.warns(RuntimeWarning, match="step_000000003"):
        out = ck.restore(_tree(0.0))              # ...but skipped
    np.testing.assert_allclose(np.asarray(out["a"]), 2.0)
    with pytest.warns(RuntimeWarning):
        assert ck.latest_valid_step() == 2


def test_same_record_never_clobbered(tmp_path):
    """save() must not rmtree a COMMITTED copy of the same (epoch,
    step) — under co-supervision that can be a competitor's live
    restore source. The duplicate save is dropped (same epoch + step
    implies identical trajectory bits in production; here we use
    different bits to observe which copy survives)."""
    ck = Checkpointer(str(tmp_path), epoch=1, owner="w1")
    ck.save(5, _tree(1.0), blocking=True)
    ck2 = Checkpointer(str(tmp_path), epoch=1, owner="w2")
    ck2.save(5, _tree(7.0), blocking=True)        # dropped, no error
    out = ck2.restore(_tree(0.0))
    np.testing.assert_allclose(np.asarray(out["a"]), 1.0)


# ---------------------------------------------- epoch fencing (PR 9)
def test_fence_reads_zero_and_advances_monotonically(tmp_path):
    d = str(tmp_path)
    assert read_fence(d) == 0
    assert advance_fence(d, 3, "a") == 3
    assert advance_fence(d, 2, "b") == 3          # advance-only
    assert read_fence(d) == 3
    _truncate(tmp_path / "FENCE", 2)              # torn fence
    # No epoch-tagged entries on disk -> nothing to recover a floor
    # from; reads 0 rather than crashing.
    assert read_fence(d) == 0


def test_torn_fence_recovers_floor_from_epoch_tags(tmp_path):
    """A torn/deleted FENCE must not roll the advance-only counter
    backward: read_fence recovers a floor from the epoch tags in
    step/COMMIT names, advance_fence refuses to write below it, and a
    previously-fenced zombie epoch STAYS fenced after the corruption
    (tear_file chaos simulates exactly this torn metadata)."""
    d = str(tmp_path)
    zombie = Checkpointer(d, epoch=1, owner="z")
    zombie.save(1, _tree(1.0), blocking=True)
    succ = Checkpointer(d, epoch=2, owner="s")
    succ.save(2, _tree(2.0), blocking=True)

    _truncate(tmp_path / "FENCE", 2)              # torn fence
    assert read_fence(d) == 2                     # floor from .e tags
    assert advance_fence(d, 1, "x") == 2          # cannot roll back
    zombie.save(10, _tree(666.0))                 # zombie still fenced
    with pytest.raises(FencedCommitError) as ei:
        zombie.wait()
    assert ei.value.fence == 2

    os.remove(tmp_path / "FENCE")                 # deleted outright
    assert read_fence(d) == 2                     # same floor
    with pytest.raises(FencedWriterError):
        Checkpointer(d, epoch=1, owner="late")    # stale open refused


def test_legacy_writer_stays_unfenced(tmp_path):
    """epoch=None (every pre-PR-9 call site) must behave exactly as
    before: no FENCE file appears, names carry no epoch tag, commits
    are never rejected even if someone else fences the directory."""
    ck = Checkpointer(str(tmp_path))
    ck.save(1, _tree(1.0), blocking=True)
    assert not (tmp_path / "FENCE").exists()
    assert (tmp_path / "step_000000001").exists()
    advance_fence(str(tmp_path), 9, "other")
    ck.save(2, _tree(2.0), blocking=True)         # still commits
    assert ck.all_steps() == [1, 2]


def test_stale_fence_token_rejected_at_open(tmp_path):
    Checkpointer(str(tmp_path), epoch=4)
    with pytest.raises(FencedWriterError, match="epoch 4"):
        Checkpointer(str(tmp_path), epoch=3)
    Checkpointer(str(tmp_path), epoch=4)          # same epoch reopens


def test_zombie_commit_rejected_at_rename_boundary(tmp_path):
    """The core fencing guarantee: a writer superseded AFTER it
    enqueued a save has the commit rejected at the rename boundary —
    the snapshot never becomes visible, bitwise nothing on disk
    changes, and the error carries enough context to log."""
    zombie = Checkpointer(str(tmp_path), epoch=1, owner="zombie")
    zombie.save(1, _tree(1.0), blocking=True)
    before = sorted(os.listdir(tmp_path))
    successor = Checkpointer(str(tmp_path), epoch=2, owner="succ")
    zombie.save(10, _tree(666.0))                 # late zombie write
    with pytest.raises(FencedCommitError) as ei:
        zombie.wait()
    assert (ei.value.step, ei.value.epoch, ei.value.fence) == (10, 1, 2)
    assert zombie.fenced_commits == 1
    # Bitwise: the directory is unchanged except the advanced FENCE.
    after = sorted(os.listdir(tmp_path))
    assert after == before
    successor.save(2, _tree(2.0), blocking=True)
    assert successor.latest_record() == (2, 2)


def test_epoch_major_ordering_beats_step_ordering(tmp_path):
    """Belt-and-suspenders: even if a zombie's HIGHER step id had
    landed (simulating a commit that raced past the fence check), the
    successor's lower-step snapshot outranks it — records order
    epoch-major, and a pinned step resolves to its newest epoch."""
    old = Checkpointer(str(tmp_path), epoch=1, owner="old")
    old.save(5, _tree(5.0), blocking=True)
    old.save(10, _tree(10.0), blocking=True)      # zombie's high step
    succ = Checkpointer(str(tmp_path), epoch=2, owner="succ")
    succ.save(5, _tree(50.0), blocking=True)      # resumed line, low step
    assert succ.all_records() == [(1, 5), (1, 10), (2, 5)]
    assert succ.latest_record() == (2, 5)
    out = succ.restore(_tree(0.0))
    np.testing.assert_allclose(np.asarray(out["a"]), 50.0)
    out = succ.restore(_tree(0.0), step=5)        # pin -> newest epoch
    np.testing.assert_allclose(np.asarray(out["a"]), 50.0)


def test_keep_k_gc_ages_out_superseded_line_first(tmp_path):
    ck1 = Checkpointer(str(tmp_path), keep_k=2, epoch=1)
    ck1.save(8, _tree(8.0), blocking=True)
    ck1.save(9, _tree(9.0), blocking=True)
    ck2 = Checkpointer(str(tmp_path), keep_k=2, epoch=2)
    ck2.save(1, _tree(1.0), blocking=True)
    ck2.save(2, _tree(2.0), blocking=True)
    assert ck2.all_records() == [(2, 1), (2, 2)]  # old line gc'd first


def test_tmp_sweep_is_owner_scoped(tmp_path):
    """A new fenced writer must not sweep a live competitor's in-flight
    tmp dir (same epoch, different owner); it must sweep its own
    leftovers, legacy untagged tmps, and fenced-out lines' tmps."""
    d = tmp_path
    os.makedirs(d / ".tmp_step_000000001.e000002.alice" / "arrays")
    os.makedirs(d / ".tmp_step_000000002.e000002.bob" / "arrays")
    os.makedirs(d / ".tmp_step_000000003.e000001.carol" / "arrays")
    os.makedirs(d / ".tmp_step_000000004" / "arrays")   # legacy
    Checkpointer(str(d), epoch=2, owner="bob")
    assert (d / ".tmp_step_000000001.e000002.alice").exists()  # live peer
    assert not (d / ".tmp_step_000000002.e000002.bob").exists()   # own
    assert not (d / ".tmp_step_000000003.e000001.carol").exists()  # fenced
    assert not (d / ".tmp_step_000000004").exists()               # legacy


def test_manifest_records_epoch(tmp_path):
    ck = Checkpointer(str(tmp_path), epoch=3)
    ck.save(1, _tree(1.0), blocking=True)
    _, manifest = ck.restore_named()
    assert manifest["epoch"] == 3
    assert (tmp_path / "step_000000001.e000003").exists()
