"""Checkpointer: atomicity, keep-K GC, async errors, restore."""
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer


def _tree(x=1.0):
    return {"a": jnp.full((4, 3), x), "b": {"c": jnp.arange(5) * int(x)}}


def test_save_restore_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(10, _tree(2.0), blocking=True)
    out = ck.restore(_tree(0.0))
    np.testing.assert_allclose(np.asarray(out["a"]), 2.0)
    np.testing.assert_allclose(np.asarray(out["b"]["c"]), np.arange(5) * 2)


def test_latest_and_keep_k(tmp_path):
    ck = Checkpointer(str(tmp_path), keep_k=2)
    for s in [1, 2, 3, 4]:
        ck.save(s, _tree(float(s)), blocking=True)
    assert ck.all_steps() == [3, 4]
    assert ck.latest_step() == 4
    out = ck.restore(_tree(0.0), step=3)
    np.testing.assert_allclose(np.asarray(out["a"]), 3.0)


def test_uncommitted_checkpoint_ignored(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(5, _tree(5.0), blocking=True)
    # simulate crash mid-save of step 6: directory exists, no COMMIT
    os.makedirs(tmp_path / "step_000000006" / "arrays")
    assert ck.latest_step() == 5


def test_restore_missing_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        Checkpointer(str(tmp_path)).restore(_tree())


def test_async_save_overlaps_and_waits(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, _tree(1.0))        # async
    ck.save(2, _tree(2.0))        # waits for 1, starts 2
    ck.wait()
    assert ck.all_steps() == [1, 2]


def test_shape_mismatch_detected(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, _tree(), blocking=True)
    bad = {"a": jnp.zeros((2, 2)), "b": {"c": jnp.arange(5)}}
    with pytest.raises(AssertionError):
        ck.restore(bad)


def test_stale_tmp_swept_at_construction(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, _tree(), blocking=True)
    # simulate a crash mid-save: orphaned work dir, no COMMIT
    os.makedirs(tmp_path / ".tmp_step_000000002" / "arrays")
    ck2 = Checkpointer(str(tmp_path))
    assert not (tmp_path / ".tmp_step_000000002").exists()
    assert ck2.all_steps() == [1]  # committed steps untouched


def test_restore_missing_leaf_names_it(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, {"a": jnp.zeros(3)}, blocking=True)
    with pytest.raises(ValueError, match="no leaf named 'zzz'"):
        ck.restore({"a": jnp.zeros(3), "zzz": jnp.zeros(2)})


def test_meta_roundtrip_and_restore_named(tmp_path):
    ck = Checkpointer(str(tmp_path))
    meta = {"it": 7, "objs": [1.0, 0.5], "fingerprint": "xyz"}
    ck.save(7, {"state": jnp.arange(4.0), "key": jnp.zeros(2)},
            meta=meta, blocking=True)
    arrays, manifest = ck.restore_named()
    assert manifest["meta"] == meta
    assert set(arrays) == {"state", "key"}
    np.testing.assert_array_equal(arrays["state"], np.arange(4.0))
    # pinned step works too
    arrays2, m2 = ck.restore_named(step=7)
    assert m2["step"] == 7


# ---------------------------------------- torn-write defense (PR 8)
def _truncate(path, nbytes=8):
    """Simulate a torn write: keep only the first bytes of a file —
    what a power loss can leave behind despite a COMMIT marker written
    by an fsync-less older writer."""
    with open(path, "rb") as f:
        head = f.read(nbytes)
    with open(path, "wb") as f:
        f.write(head)


def test_torn_array_falls_back_with_warning(tmp_path):
    """A truncated leaf in the NEWEST committed snapshot must not kill
    the resume: restore warns and falls back to the previous keep_k
    entry; ``latest_valid_step`` reports the step restore will use."""
    ck = Checkpointer(str(tmp_path), keep_k=3)
    for s in [1, 2, 3]:
        ck.save(s, _tree(float(s)), blocking=True)
    _truncate(tmp_path / "step_000000003" / "arrays" / "0.npy")

    assert ck.latest_step() == 3                 # still committed...
    with pytest.warns(RuntimeWarning, match="step_000000003"):
        assert ck.latest_valid_step() == 2       # ...but not restorable
    with pytest.warns(RuntimeWarning):
        out = ck.restore(_tree(0.0))
    np.testing.assert_allclose(np.asarray(out["a"]), 2.0)


def test_torn_manifest_falls_back(tmp_path):
    ck = Checkpointer(str(tmp_path), keep_k=3)
    ck.save(1, _tree(1.0), blocking=True)
    ck.save(2, _tree(2.0), blocking=True)
    _truncate(tmp_path / "step_000000002" / "manifest.json")
    with pytest.warns(RuntimeWarning):
        arrays, manifest = ck.restore_named()
    assert manifest["step"] == 1


def test_wrong_shape_on_disk_is_corruption(tmp_path):
    """Bit-rot that still parses: an array whose shape/dtype disagrees
    with the manifest is treated as corruption, not silently restored."""
    ck = Checkpointer(str(tmp_path), keep_k=3)
    ck.save(1, _tree(1.0), blocking=True)
    ck.save(2, _tree(2.0), blocking=True)
    np.save(tmp_path / "step_000000002" / "arrays" / "0.npy",
            np.zeros((9, 9), np.float64))
    with pytest.warns(RuntimeWarning, match="corrupt"):
        out = ck.restore(_tree(0.0))
    np.testing.assert_allclose(np.asarray(out["a"]), 1.0)


def test_all_corrupt_is_poisoned_directory(tmp_path):
    ck = Checkpointer(str(tmp_path), keep_k=3)
    for s in [1, 2]:
        ck.save(s, _tree(float(s)), blocking=True)
        _truncate(tmp_path / f"step_{s:09d}" / "manifest.json")
    with pytest.warns(RuntimeWarning):
        with pytest.raises(FileNotFoundError, match="poisoned"):
            ck.restore(_tree(0.0))
    with pytest.warns(RuntimeWarning):
        assert ck.latest_valid_step() is None


def test_pinned_step_loads_strictly(tmp_path):
    """An EXPLICITLY pinned step does not silently fall back — the
    caller asked for those bits, so corruption raises."""
    ck = Checkpointer(str(tmp_path), keep_k=3)
    ck.save(1, _tree(1.0), blocking=True)
    ck.save(2, _tree(2.0), blocking=True)
    _truncate(tmp_path / "step_000000002" / "arrays" / "0.npy")
    with pytest.raises(Exception):
        ck.restore(_tree(0.0), step=2)
    out = ck.restore(_tree(0.0), step=1)         # older pin still fine
    np.testing.assert_allclose(np.asarray(out["a"]), 1.0)
