"""Out-of-core streaming fit (driver='stream') vs the in-memory oracles.

Three layers of exactness, strongest first:

  1. Per-iteration statistics: chunked accumulation of (Sigma, b) over
     ANY chunk size/padding == the one-shot computation, to fp32
     reassociation tolerance, for EM and MC (the rowwise MC gamma draw
     makes the sampled chain chunking-invariant by construction).
  2. Whole-fit trajectories: stream == scan final weights whenever the
     iteration map is not chaotically amplifying fp32 noise — EM at a
     sane gamma clamp, MC on short chains (DESIGN.md §Perf/Streaming
     documents the 1/gamma^2 sensitivity; same caveat as the bf16
     reduce and the mesh-vs-single-device band).
  3. Quality: long/tight-clamp fits must still land on the same
     decision function (score parity) even where trajectories fork.
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.core import PEMSVM, SVMConfig
from repro.core.linear import accumulate_stats
from repro.core.svr import svr_local_stats


def _chunked_stats(X, rho, beta, w, mode, key, chunk_rows, pad_tail):
    """Sum accumulate_stats over fixed-shape padded chunks."""
    N, K = X.shape
    S = np.zeros((K, K), np.float32)
    b = np.zeros((K,), np.float32)
    for i0 in range(0, N, chunk_rows):
        i1 = min(i0 + chunk_rows, N)
        rows = chunk_rows + (pad_tail if i1 == N else 0)
        Xc = np.zeros((rows, K), np.float32)
        rc = np.zeros((rows,), np.float32)
        bc = np.zeros((rows,), np.float32)
        Xc[:i1 - i0] = X[i0:i1]
        rc[:i1 - i0] = rho[i0:i1]
        bc[:i1 - i0] = beta[i0:i1]
        _, _, Sc, bvec = accumulate_stats(
            jnp.asarray(Xc), jnp.asarray(rc), jnp.asarray(bc),
            jnp.asarray(w), mode=mode, key=key, eps=1e-6, backend=None,
            row0=i0)
        S += np.asarray(Sc)
        b += np.asarray(bvec)
    return S, b


@settings(max_examples=15, deadline=None)
@given(st.integers(16, 400), st.integers(0, 37), st.integers(0, 2 ** 20))
def test_stream_stats_chunking_invariant_em(chunk_rows, pad_tail, seed):
    """Property: EM Sigma/b are identical (fp32 tolerance) for every
    chunk size and tail padding."""
    rng = np.random.default_rng(seed)
    N, K = 301, 9
    X = rng.normal(size=(N, K)).astype(np.float32)
    y = rng.choice([-1.0, 1.0], N).astype(np.float32)
    w = rng.normal(size=K).astype(np.float32)
    key = jax.random.PRNGKey(0)
    _, _, S0, b0 = accumulate_stats(
        jnp.asarray(X), jnp.asarray(y), jnp.asarray(y), jnp.asarray(w),
        mode="EM", key=key, eps=1e-6, backend=None, row0=0)
    S, b = _chunked_stats(X, y, y, w, "EM", key, chunk_rows, pad_tail)
    np.testing.assert_allclose(S, np.asarray(S0), rtol=1e-5,
                               atol=1e-4 * np.abs(S0).max())
    np.testing.assert_allclose(b, np.asarray(b0), rtol=1e-5,
                               atol=1e-4 * max(1.0, np.abs(b0).max()))


@settings(max_examples=10, deadline=None)
@given(st.integers(16, 400), st.integers(0, 2 ** 20))
def test_stream_stats_chunking_invariant_mc(chunk_rows, seed):
    """Property: the MC chain is chunking-invariant — rowwise-keyed
    gamma draws give the SAME Sigma/b for every chunk size."""
    rng = np.random.default_rng(seed)
    N, K = 257, 7
    X = rng.normal(size=(N, K)).astype(np.float32)
    y = rng.choice([-1.0, 1.0], N).astype(np.float32)
    w = rng.normal(size=K).astype(np.float32)
    key = jax.random.PRNGKey(seed % 1000)
    _, _, S0, b0 = accumulate_stats(
        jnp.asarray(X), jnp.asarray(y), jnp.asarray(y), jnp.asarray(w),
        mode="MC", key=key, eps=1e-6, backend=None, row0=0)
    S, b = _chunked_stats(X, y, y, w, "MC", key, chunk_rows, 0)
    np.testing.assert_allclose(S, np.asarray(S0), rtol=1e-4,
                               atol=1e-4 * np.abs(S0).max())
    np.testing.assert_allclose(b, np.asarray(b0), rtol=1e-4,
                               atol=1e-4 * max(1.0, np.abs(b0).max()))


def test_svr_stats_chunking_invariant_mc():
    """SVR's double mixture: both rowwise draws chunking-invariant."""
    rng = np.random.default_rng(3)
    N, K = 200, 6
    X = rng.normal(size=(N, K)).astype(np.float32)
    y = (X @ rng.normal(size=K)).astype(np.float32)
    w = rng.normal(size=K).astype(np.float32)
    key = jax.random.PRNGKey(5)
    _, _, _, S0, b0 = svr_local_stats(
        jnp.asarray(X), jnp.asarray(y), jnp.asarray(w), mode="MC",
        key=key, eps=1e-6, eps_ins=0.2, backend=None, row0=0)
    S = np.zeros((K, K), np.float32)
    b = np.zeros((K,), np.float32)
    for i0 in range(0, N, 48):
        i1 = min(i0 + 48, N)
        Xc = np.zeros((48, K), np.float32)
        yc = np.zeros((48,), np.float32)
        Xc[:i1 - i0] = X[i0:i1]
        yc[:i1 - i0] = y[i0:i1]
        _, _, _, Sc, bc = svr_local_stats(
            jnp.asarray(Xc), jnp.asarray(yc), jnp.asarray(w), mode="MC",
            key=key, eps=1e-6, eps_ins=0.2, backend=None, row0=i0)
        S += np.asarray(Sc)
        b += np.asarray(bc)
    np.testing.assert_allclose(S, np.asarray(S0), rtol=1e-4,
                               atol=1e-4 * np.abs(S0).max())
    np.testing.assert_allclose(b, np.asarray(b0), rtol=1e-4,
                               atol=1e-4 * max(1.0, np.abs(b0).max()))


# --------------------------------------------------------- whole-fit parity
def _problem(task, seed=0, N=1024, K=16, M=3):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(N, K)).astype(np.float32)
    w_true = rng.normal(size=K)
    if task == "SVR":
        y = (X @ w_true).astype(np.float32)
    elif task == "MLT":
        y = np.argmax(X @ rng.normal(size=(M, K)).T, 1).astype(np.int32)
    else:
        y = np.where(X @ w_true + 0.3 * rng.normal(size=N) > 0, 1.0, -1.0)
    return X, y


# Chain lengths/clamps chosen inside the non-chaotic regime (see module
# docstring): EM tolerates long fits at eps=1e-2 and holds 1e-4. MC's
# bound is looser: the IG sampler's accept-reject branch is
# discontinuous, so a near-hinge row (mu = 1/|residual| large) can flip
# on an fp32-reassociation-sized residual perturbation and inject an
# O(1) single-gamma difference — a few flips land the weights ~1e-4
# apart even on short chains (MLT worst: M solves/iteration multiply
# the flip opportunities). The draws themselves are chunking-invariant
# (property tests above); only their *inputs* drift.
@pytest.mark.parametrize("options,kw,iters,bound", [
    ("LIN-EM-CLS", {}, 30, 1e-4),
    ("LIN-EM-SVR", dict(eps_ins=0.3), 30, 1e-4),
    ("LIN-EM-MLT", dict(num_classes=3), 16, 1e-4),
    ("LIN-MC-CLS", dict(burnin=8), 16, 2e-4),
    ("LIN-MC-SVR", dict(eps_ins=0.3, burnin=8), 16, 2e-4),
    ("LIN-MC-MLT", dict(num_classes=3, burnin=2, eps=1e-1), 6, 1e-3),
])
def test_stream_fit_matches_scan(options, kw, iters, bound):
    """Acceptance: chunk_rows < N/8, final weights within the combo's
    rel-err bound (1e-4 for the deterministic EM combos)."""
    task = options.split("-")[-1]
    X, y = _problem(task)
    kw = {"eps": 1e-2, **kw}
    kw["max_iters"] = kw["min_iters"] = iters
    scan = PEMSVM(SVMConfig.from_options(options, **kw))
    strm = PEMSVM(SVMConfig.from_options(options, driver="stream",
                                         chunk_rows=100, **kw))
    rs = scan.fit(X, y)
    rt = strm.fit(X, y)
    assert 100 < X.shape[0] / 8
    rel = (np.abs(rt.weights - rs.weights).max()
           / max(1e-12, np.abs(rs.weights).max()))
    assert rel <= bound, (options, rel)
    np.testing.assert_allclose(rt.objective[0], rs.objective[0],
                               rtol=1e-5)
    # score: accuracy (CLS/MLT) may flip a knife-edge point; RMSE (SVR)
    # tracks the 1e-4 weight band.
    assert abs(strm.score(X, y) - scan.score(X, y)) < 1e-3


def test_stream_chunk_size_invariance():
    """The chunking must be invisible: different chunk_rows give the
    same trajectory (incl. a chunk size that forces heavy padding)."""
    X, y = _problem("CLS")
    traces = []
    for cr in (64, 100, 300, 2048):
        res = PEMSVM(SVMConfig(driver="stream", chunk_rows=cr, eps=1e-2,
                               max_iters=10, min_iters=10)).fit(X, y)
        traces.append(np.array(res.objective))
    for t in traces[1:]:
        np.testing.assert_allclose(t, traces[0], rtol=1e-4)


def test_stream_early_stop_and_aux_match_loop():
    """Stopping rule and aux keys mirror the loop driver."""
    X, y = _problem("CLS")
    loop = PEMSVM(SVMConfig(driver="loop", eps=1e-2, max_iters=100)).fit(
        X, y)
    strm = PEMSVM(SVMConfig(driver="stream", chunk_rows=128, eps=1e-2,
                            max_iters=100)).fit(X, y)
    assert strm.converged and loop.converged
    assert strm.n_iters == loop.n_iters
    assert set(strm.aux_history) == set(loop.aux_history) == {
        "objective", "gamma_mean", "n_sv"}
    np.testing.assert_allclose(strm.aux_history["n_sv"],
                               loop.aux_history["n_sv"])


def test_stream_long_mc_chain_score_parity():
    """Beyond the exactness window, quality must still agree."""
    X, y = _problem("CLS")
    scan = PEMSVM(SVMConfig(algorithm="MC", max_iters=40))
    strm = PEMSVM(SVMConfig(algorithm="MC", max_iters=40,
                            driver="stream", chunk_rows=128))
    scan.fit(X, y)
    strm.fit(X, y)
    assert abs(scan.score(X, y) - strm.score(X, y)) < 0.02


def test_stream_peak_residency_bounded():
    """Device input residency is (prefetch+2) chunks — prefetch queued,
    one in the worker's hand, one at the consumer — independent of N."""
    X, y = _problem("CLS", N=2048, K=16)
    cfg = SVMConfig(driver="stream", chunk_rows=48, prefetch=2,
                    max_iters=3, min_iters=3)
    res = PEMSVM(cfg).fit(X, y)
    K = X.shape[1] + 1  # bias
    chunk_bytes = 48 * K * 4 + 2 * 48 * 4      # X + target + mask
    assert 0 < res.peak_input_bytes <= 4 * chunk_bytes
    resident_bytes = 2048 * K * 4
    assert res.peak_input_bytes < resident_bytes / 8


def test_stream_masked_tail_chunk():
    """N not divisible by chunk_rows: the padded tail must be a no-op
    (same fit as a divisible chunking)."""
    X, y = _problem("CLS", N=1000)  # 1000 = 7*128 + 104 -> padded tail
    a = PEMSVM(SVMConfig(driver="stream", chunk_rows=128, eps=1e-2,
                         max_iters=8, min_iters=8)).fit(X, y)
    b = PEMSVM(SVMConfig(driver="stream", chunk_rows=100, eps=1e-2,
                         max_iters=8, min_iters=8)).fit(X, y)
    np.testing.assert_allclose(a.weights, b.weights, rtol=1e-4,
                               atol=1e-5)


def test_stream_fit_libsvm_end_to_end(tmp_path):
    """File -> chunked reader -> prefetcher -> stream fit == resident
    fit on the same data, including comment/blank-line tolerance."""
    from repro.data import save_libsvm

    X, y = _problem("CLS", N=600, K=10)
    p = str(tmp_path / "toy.libsvm")
    save_libsvm(p, X, y)
    lines = open(p).read().splitlines()
    with open(p, "w") as f:
        f.write("# generated by test\n\n")
        for i, ln in enumerate(lines):
            f.write(ln + ("  # sv" if i % 7 == 0 else "") + "\n")
            if i % 11 == 0:
                f.write("   \n")
    kw = dict(eps=1e-2, max_iters=12, min_iters=12)
    resident = PEMSVM(SVMConfig(**kw)).fit(X, y)
    streamed = PEMSVM(SVMConfig(driver="stream", chunk_rows=64,
                                **kw)).fit_libsvm(p, n_features=10)
    rel = (np.abs(streamed.weights - resident.weights).max()
           / np.abs(resident.weights).max())
    assert rel <= 1e-4, rel


def test_stream_rejects_exact_krn_and_mesh():
    """KRN + stream is a valid CONFIG now (NystromSVM's phi-space route
    streams raw rows); only the exact N x N Gram solver still rejects
    it, at fit time, pointing at NystromSVM."""
    cfg = SVMConfig(formulation="KRN", driver="stream")
    X, y = _problem("CLS", N=64, K=4)
    with pytest.raises(NotImplementedError, match="NystromSVM"):
        PEMSVM(cfg).fit(X, y)
    with pytest.raises(NotImplementedError, match="NystromSVM"):
        PEMSVM(cfg).fit_libsvm("/nonexistent.libsvm", n_features=4)


def test_stream_fit_libsvm_nonstream_falls_back(tmp_path):
    """fit_libsvm with a resident driver loads and defers to fit."""
    from repro.data import save_libsvm

    X, y = _problem("CLS", N=200, K=6)
    p = str(tmp_path / "toy.libsvm")
    save_libsvm(p, X, y)
    a = PEMSVM(SVMConfig(max_iters=5, min_iters=5)).fit_libsvm(
        p, n_features=6)
    b = PEMSVM(SVMConfig(max_iters=5, min_iters=5)).fit(X, y)
    np.testing.assert_allclose(a.weights, b.weights, rtol=1e-4, atol=1e-5)
