"""Fleet-controller chaos suite (DESIGN.md §Reliability, PR 8).

PR 6 proved a SINGLE fit is preemption-safe; this suite proves the
OUTER loop: ``runtime.controller.FleetController`` supervising a fleet
of fit attempts through a deterministic fault schedule
(``runtime.faults.FleetSchedule``) — kills, graceful terminations,
hangs caught by the progress watchdog, flaky loaders, straggler-forced
degradation and grow-back re-provisioning — and the recovered model is
BITWISE the uninterrupted fit when the relaunch keeps the layout, and
within the documented reassociation band when a forced remesh changes
it (subprocess mesh test).

Also here: the windowed-statistics (hard data expiry) semantics that
ride the same checkpoint substrate, and the controller unit surface
(deterministic backoff, terminal classification order, retry budgets,
real-OS-process SubprocessHost lifecycles).
PR 9 adds the split-brain chaos proofs: epoch-fenced commits under
multi-controller co-supervision, lease-based leader election (dueling
startup, frozen-leader takeover, torn lease files), and the acceptance
scenario — leader A frozen mid-supervision with a NON-cooperative
zombie worker, standby B takes over at term+1, the zombie's late
commit is rejected at the rename boundary, and B's recovered model is
bitwise the undisturbed single-controller fit.
"""
import dataclasses
import os
import subprocess
import sys
import textwrap
import threading
import time
import warnings

import numpy as np
import pytest

from repro.checkpoint import Checkpointer, FencedCommitError, read_fence
from repro.core import PEMSVM, SVMConfig
from repro.core.linear import SVMData
from repro.runtime import faults
from repro.runtime.controller import (FleetController, FleetError,
                                      FleetPolicy, LeadershipLost,
                                      SubprocessHost)
from repro.runtime.faults import FleetSchedule
from repro.runtime.lease import (LeaseLost, LeaseManager, LeasePolicy,
                                 LEASE_FILE)
from repro.runtime.policy import FaultPolicy

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))

_rng = np.random.default_rng(0)
N, K = 257, 9
X = _rng.normal(size=(N, K)).astype(np.float32)
_w_true = _rng.normal(size=K + 1)
Y_CLS = np.where(X @ _w_true[:K] + _w_true[K] > 0, 1.0, -1.0).astype(
    np.float32)
Y_SVR = (X @ _w_true[:K]).astype(np.float32)


def _chunk_factory(tgt):
    """Restartable 5-chunk source over the module data (257 rows padded
    to 5 x 64) — the shape ``fit_chunks`` consumes."""
    Xp = np.concatenate([X, np.zeros((63, K), np.float32)])
    yp = np.concatenate([tgt, np.zeros(63, np.float32)])
    mp = np.concatenate([np.ones(N, np.float32),
                         np.zeros(63, np.float32)])

    def make():
        for i0 in range(0, 320, 64):
            yield SVMData(Xp[i0:i0 + 64], yp[i0:i0 + 64], mp[i0:i0 + 64])
    return make


# ---------------------------------------------- end-to-end chaos recovery
@pytest.mark.parametrize("algo", ["EM", "MC"])
@pytest.mark.parametrize("task", ["CLS", "SVR"])
def test_fleet_chaos_recovers_bitwise(algo, task, tmp_path):
    """The headline: a fleet run through a deterministic chaos schedule
    — SIGKILL-style preemption on attempt 0, SIGTERM-style eviction on
    attempt 1, a flaky loader failing on EVERY attempt — converges to
    the exact bits of the undisturbed fit, for EM and MC, CLS and SVR.
    Every failure funnels into resume-from-snapshot on the same layout,
    so recovery is lossless by construction, not by tolerance."""
    tgt = Y_CLS if task == "CLS" else Y_SVR
    base = _chunk_factory(tgt)
    kw = dict(algorithm=algo, task=task, driver="stream", chunk_rows=64,
              max_iters=12, min_iters=12, burnin=3)
    ref = PEMSVM(SVMConfig(**kw)).fit_chunks(base, K)

    pol = FaultPolicy(ckpt_dir=str(tmp_path), ckpt_every=2,
                      loader_retries=3, loader_backoff=1e-3)
    cfg = SVMConfig(**kw, fault=pol)

    def make_host(level):
        # A FRESH flaky wrapper per attempt: chunk position 2 fails once
        # per attempt, so even the completing attempt absorbs a loader
        # retry (surfaced on FitResult.loader_retries below).
        flaky = faults.io_error_every_nth(base, nth=3, times=1)

        def host(ctx):
            return PEMSVM(cfg).fit_chunks(
                flaky, K, resume_from=ctx.resume_from,
                fault_hook=ctx.fault_hook)
        return host

    fc = FleetController(
        make_host, str(tmp_path),
        policy=FleetPolicy(max_attempts=5, backoff_s=1e-3, seed=3),
        schedule=FleetSchedule({
            0: lambda cancel: faults.kill_at_iteration(4),
            1: lambda cancel: faults.terminate_at_iteration(7),
        }))
    fr = fc.run()

    assert [a.outcome for a in fr.attempts] == [
        "retryable", "retryable", "completed"]
    assert fr.recovered and fr.n_relaunches == 2
    assert fr.attempts[1].resume_step is not None     # resumed, not fresh
    assert fr.result.resumed_at is not None and fr.result.resumed_at >= 6
    assert fr.result.loader_retries >= 1              # flaky loader absorbed
    assert fr.result.loader_backoff_s > 0.0
    assert np.array_equal(ref.weights, fr.result.weights)
    assert np.allclose(ref.objective, fr.result.objective)


def test_fleet_watchdog_catches_hang(tmp_path):
    """A worker that stops advancing WITHOUT dying (the failure liveness
    checks miss): the monotonic-progress watchdog sees no checkpoint
    advance, cancels the attempt, and the relaunch finishes bitwise."""
    kw = dict(algorithm="EM", task="CLS", driver="loop", max_iters=10,
              min_iters=10)
    ref = PEMSVM(SVMConfig(**kw)).fit(X, Y_CLS)
    pol = FaultPolicy(ckpt_dir=str(tmp_path), ckpt_every=1)
    cfg = SVMConfig(**kw, fault=pol)

    def make_host(level):
        def host(ctx):
            return PEMSVM(cfg).fit(X, Y_CLS, resume_from=ctx.resume_from,
                                   fault_hook=ctx.fault_hook)
        return host

    fc = FleetController(
        make_host, str(tmp_path),
        # watchdog_s outlasts first-iteration compile (which delays the
        # first commit) but not the injected hang.
        policy=FleetPolicy(max_attempts=3, backoff_s=1e-3,
                           watchdog_s=4.0, poll_s=0.02),
        schedule=FleetSchedule({
            0: lambda cancel: faults.hang_at_iteration(
                3, until=cancel, max_seconds=30.0),
        }))
    with warnings.catch_warnings():
        warnings.simplefilter("error")   # cooperative cancel: no abandon
        fr = fc.run()

    assert [a.outcome for a in fr.attempts] == ["watchdog", "completed"]
    assert fr.attempts[0].commits >= 1
    assert fr.attempts[0].first_commit_s is not None
    assert fr.result.resumed_at == 3
    assert np.array_equal(ref.weights, fr.result.weights)


def test_fleet_abandons_noncooperative_hang(tmp_path):
    """A worker stuck INSIDE one iteration never reaches the fault hook,
    so it cannot observe cancel: the watchdog fires, the supervise loop
    waits at most kill_grace_s for it to exit, and run() abandons the
    daemon thread (RuntimeWarning) instead of spinning on it forever —
    the relaunch then completes normally."""
    kw = dict(algorithm="EM", task="CLS", driver="loop", max_iters=8,
              min_iters=8)
    ref = PEMSVM(SVMConfig(**kw)).fit(X, Y_CLS)
    cfg = SVMConfig(**kw, fault=FaultPolicy(ckpt_dir=str(tmp_path),
                                            ckpt_every=1))
    release = threading.Event()   # bounds the abandoned worker's life

    def make_host(level):
        def host(ctx):
            if ctx.attempt == 0:
                release.wait(30.0)    # ignores ctx.cancel entirely
                raise RuntimeError("hung worker released")
            return PEMSVM(cfg).fit(X, Y_CLS, resume_from=ctx.resume_from,
                                   fault_hook=ctx.fault_hook)
        return host

    fc = FleetController(
        make_host, str(tmp_path),
        policy=FleetPolicy(max_attempts=3, backoff_s=1e-3,
                           watchdog_s=0.3, poll_s=0.02,
                           kill_grace_s=0.2))
    try:
        with pytest.warns(RuntimeWarning, match="abandoning"):
            fr = fc.run()
    finally:
        release.set()

    assert [a.outcome for a in fr.attempts] == ["abandoned", "completed"]
    # Abandoned within ~watchdog + grace, not the worker's 30s hang.
    assert fr.attempts[0].seconds < 5.0
    assert fr.recovered and fr.final_level == 0
    assert np.array_equal(ref.weights, fr.result.weights)


def test_fleet_straggler_degrade_then_growback(tmp_path):
    """``on_straggler="raise"`` escalates to the controller: the fleet
    SHRINKS one provisioning level, and after ``recover_commits`` of
    observed progress at the degraded level it cancels the attempt and
    GROWS back to level 0 — three lifecycles, one bitwise trajectory.
    (Both levels keep the single-device layout here, so parity stays
    bitwise; the subprocess mesh test below does the real remesh.)"""
    kw = dict(algorithm="EM", task="CLS", driver="loop", max_iters=14,
              min_iters=14)
    ref = PEMSVM(SVMConfig(**kw)).fit(X, Y_CLS)
    pol = FaultPolicy(ckpt_dir=str(tmp_path), ckpt_every=1,
                      on_straggler="raise", straggler_threshold=3.0,
                      straggler_warmup=2)
    cfg = SVMConfig(**kw, fault=pol)
    # A uniform floor delay dominates sub-ms timing noise, so only the
    # injected spike at iteration 6 crosses 3 x EMA.
    floor = faults.delay_iterations(range(1, 15), 0.05)
    levels_used = []

    def make_host(level):
        levels_used.append(level)

        def host(ctx):
            return PEMSVM(cfg).fit(X, Y_CLS, resume_from=ctx.resume_from,
                                   fault_hook=ctx.fault_hook)
        return host

    fc = FleetController(
        make_host, str(tmp_path),
        policy=FleetPolicy(max_attempts=5, backoff_s=1e-3,
                           recover_commits=1, poll_s=0.01),
        n_levels=2,
        schedule=FleetSchedule({
            0: lambda cancel: faults.compose_hooks(
                floor, faults.delay_iterations([6], 0.5)),
            1: lambda cancel: floor,
            2: lambda cancel: floor,
        }))
    fr = fc.run()

    assert [a.outcome for a in fr.attempts] == [
        "straggler", "reprovision", "completed"]
    assert levels_used == [0, 1, 0]
    assert fr.final_level == 0
    assert np.array_equal(ref.weights, fr.result.weights)


# -------------------------------------------------- controller unit tests
def test_relaunch_delay_deterministic():
    pol = FleetPolicy(backoff_s=0.1, backoff_cap_s=1.0, jitter=0.2,
                      seed=7)
    d = pol.relaunch_delay(1, 2)
    assert d == pol.relaunch_delay(1, 2)            # replayable
    assert d != pol.relaunch_delay(1, 3)            # decorrelated
    assert 0.1 <= d <= 0.1 * 1.2                    # jitter bounds
    assert d != FleetPolicy(backoff_s=0.1, backoff_cap_s=1.0, jitter=0.2,
                            seed=8).relaunch_delay(1, 2)

    flat = FleetPolicy(backoff_s=0.1, backoff_cap_s=10.0, jitter=0.0)
    assert flat.relaunch_delay(1, 0) == pytest.approx(0.1)
    assert flat.relaunch_delay(3, 0) == pytest.approx(0.4)  # doubles
    capped = FleetPolicy(backoff_s=0.1, backoff_cap_s=0.15, jitter=0.0)
    assert capped.relaunch_delay(5, 0) == pytest.approx(0.15)


def test_terminal_classification_beats_retryable(tmp_path):
    """FileNotFoundError IS an OSError (retryable family), but the
    terminal check runs first — a poisoned/missing checkpoint must not
    burn the retry budget on a config problem retrying cannot fix."""
    def make_host(level):
        def host(ctx):
            raise FileNotFoundError("poisoned checkpoint directory")
        return host

    fc = FleetController(make_host, str(tmp_path),
                         policy=FleetPolicy(max_attempts=4))
    with pytest.raises(FleetError) as ei:
        fc.run()
    assert isinstance(ei.value.cause, FileNotFoundError)
    assert len(ei.value.attempts) == 1              # no retries spent
    assert ei.value.attempts[0].outcome == "terminal"


def test_fingerprint_mismatch_is_terminal(tmp_path):
    """The real terminal path end-to-end: a relaunch with a DIFFERENT
    semantic config hits the resume fingerprint check (ValueError naming
    the field) and the controller stops immediately."""
    kw = dict(algorithm="EM", task="CLS", driver="loop", max_iters=4,
              min_iters=4)
    pol = FaultPolicy(ckpt_dir=str(tmp_path), ckpt_every=2)
    PEMSVM(SVMConfig(**kw, fault=pol)).fit(X, Y_CLS)   # donor checkpoint

    def make_host(level):
        def host(ctx):
            return PEMSVM(SVMConfig(**kw, lam=2.0, fault=pol)).fit(
                X, Y_CLS, resume_from=ctx.resume_from)
        return host

    fc = FleetController(make_host, str(tmp_path),
                         policy=FleetPolicy(max_attempts=4))
    with pytest.raises(FleetError) as ei:
        fc.run()
    assert "lam" in str(ei.value.cause)
    assert ei.value.attempts[0].outcome == "terminal"


def test_retry_budget_exhausted_with_deterministic_backoff(tmp_path):
    def make_host(level):
        def host(ctx):
            raise IOError("host storage gone")
        return host

    slept = []
    pol = FleetPolicy(max_attempts=3, backoff_s=0.01, jitter=0.5, seed=11)
    fc = FleetController(make_host, str(tmp_path), policy=pol,
                         sleep=slept.append)
    with pytest.raises(FleetError, match="budget exhausted"):
        fc.run()
    # Exactly the policy's deterministic schedule, no real sleeping.
    assert slept == [pol.relaunch_delay(1, 1), pol.relaunch_delay(2, 2)]


def test_subprocess_host_died_then_completes(tmp_path):
    """SubprocessHost: a real OS process that crashes on attempt 0
    (HostDied, retryable) and succeeds on attempt 1; ``load_result``
    supplies the controller's return value."""
    code = textwrap.dedent("""
        import os, sys
        if os.environ["FLEET_ATTEMPT"] == "0":
            print("injected crash")
            sys.exit(3)
        print("level", os.environ["FLEET_LEVEL"])
    """)

    fc = FleetController(
        lambda level: SubprocessHost(code, load_result=lambda: "done"),
        str(tmp_path), policy=FleetPolicy(max_attempts=3, backoff_s=0.0))
    fr = fc.run()
    assert fr.result == "done"
    assert [a.outcome for a in fr.attempts] == ["retryable", "completed"]
    assert "exited 3" in fr.attempts[0].error
    assert "injected crash" in fr.attempts[0].error   # output tail kept


def test_subprocess_verbose_child_does_not_deadlock(tmp_path):
    """A child that writes far more than the OS pipe buffer (~64KB) to
    stdout must still exit: stdout is drained concurrently, so a
    healthy-but-verbose worker neither blocks on write nor gets killed
    as a spurious 'watchdog'."""
    code = textwrap.dedent("""
        import sys
        for i in range(4000):
            print("x" * 80)          # ~320KB >> pipe buffer
        sys.exit(0)
    """)

    fc = FleetController(
        lambda level: SubprocessHost(code, load_result=lambda: "ok",
                                     poll_s=0.02),
        str(tmp_path),
        policy=FleetPolicy(max_attempts=1, watchdog_s=20.0,
                           poll_s=0.02))
    fr = fc.run()
    assert fr.result == "ok"
    assert [a.outcome for a in fr.attempts] == ["completed"]


def test_subprocess_watchdog_real_sigterm(tmp_path):
    """A subprocess that never commits progress: the watchdog fires and
    cancellation is REAL (SIGTERM, then SIGKILL past the grace window)
    — no cooperative gap, unlike in-process attempts."""
    code = textwrap.dedent("""
        import os, time
        if os.environ["FLEET_ATTEMPT"] == "0":
            time.sleep(60)          # hung: no commits, no exit
    """)

    fc = FleetController(
        lambda level: SubprocessHost(code, poll_s=0.02),
        str(tmp_path),
        policy=FleetPolicy(max_attempts=3, backoff_s=1e-3,
                           watchdog_s=0.5, poll_s=0.02, kill_grace_s=2.0))
    fr = fc.run()
    assert [a.outcome for a in fr.attempts] == ["watchdog", "completed"]
    assert fr.attempts[0].seconds < 30.0              # killed, not waited


# ------------------------------------------- cross-mesh forced remesh
def run_with_devices(code: str, n_devices: int = 4, timeout: int = 600):
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={n_devices}")
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    p = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env,
                       timeout=timeout)
    assert p.returncode == 0, f"STDOUT:\n{p.stdout}\nSTDERR:\n{p.stderr}"
    return p.stdout


def test_fleet_forced_remesh_within_band():
    """The elastic re-provisioning headline: a straggler on the (2,2)
    k-sharded mesh forces a SHRINK onto the flat (4,) mesh — a real
    remesh, not a relabel. The controller resumes the degraded attempt
    from the shared checkpoint dir and the final model lands within the
    documented EM cross-mesh reassociation band of the uninterrupted
    flat-mesh fit."""
    run_with_devices("""
import numpy as np, tempfile
from repro import compat
from repro.core import PEMSVM, SVMConfig
from repro.runtime import faults
from repro.runtime.controller import FleetController, FleetPolicy
from repro.runtime.faults import FleetSchedule
from repro.runtime.policy import FaultPolicy

mesh_a = compat.make_mesh((2, 2), ("data", "model"),
                          axis_types=("auto",) * 2)
mesh_b = compat.make_mesh((4,), ("data",), axis_types=("auto",))
rng = np.random.default_rng(0)
N, K = 512, 23
w_true = rng.normal(size=K)
X = rng.normal(size=(N, K)).astype(np.float32)
y = np.where(X @ w_true + 0.3 * rng.normal(size=N) > 0, 1.0, -1.0)

kw = dict(algorithm="EM", task="CLS", driver="loop", max_iters=10,
          min_iters=10, eps=1e-2)
# Wide margins so a loaded machine cannot flip the outcome: the floor
# dominates per-iteration compute jitter (a spurious straggler needs a
# >2x-floor hiccup) and the injected spike stays >3x EMA even if the
# sharded fit's real step time inflates the EMA by ~1s under load.
floor = faults.delay_iterations(range(1, 11), 0.15)
with tempfile.TemporaryDirectory() as d:
    pol = FaultPolicy(ckpt_dir=d, ckpt_every=2, keep_k=10,
                      on_straggler="raise", straggler_threshold=3.0,
                      straggler_warmup=2)
    ref_b = PEMSVM(SVMConfig(**kw), mesh=mesh_b,
                   data_axes=("data",)).fit(X, y)

    def make_host(level):
        def host(ctx):
            if level == 0:       # full fleet: 2-D mesh, k-sharded stat
                svm = PEMSVM(SVMConfig(**kw, k_shard_axis="model",
                                       fault=pol),
                             mesh=mesh_a, data_axes=("data",))
            else:                # degraded: flat mesh
                svm = PEMSVM(SVMConfig(**kw, fault=pol), mesh=mesh_b,
                             data_axes=("data",))
            return svm.fit(X, y, resume_from=ctx.resume_from,
                           fault_hook=ctx.fault_hook)
        return host

    fc = FleetController(
        make_host, d,
        policy=FleetPolicy(max_attempts=4, backoff_s=1e-3),
        n_levels=2,
        schedule=FleetSchedule({
            0: lambda cancel: faults.compose_hooks(
                floor, faults.delay_iterations([6], 2.5)),
            1: lambda cancel: floor,
        }))
    fr = fc.run()
    assert [a.outcome for a in fr.attempts] == ["straggler", "completed"]
    assert fr.final_level == 1                       # stayed degraded
    assert fr.result.resumed_at is not None
    rel = (np.abs(fr.result.weights - ref_b.weights).max()
           / np.abs(ref_b.weights).max())
    assert rel < 1e-4, rel
print("fleet remesh OK")
""")


# ------------------------------------------- windowed statistics (expiry)
def test_window_hard_expiry_is_exact(tmp_path):
    """window=2 keeps exactly ONE previous generation's fresh partials:
    a donor dragging extra stale generations beyond the horizon changes
    NOTHING (bitwise) — hard expiry, not down-weighting — while the
    retained generation provably shifts the fit."""
    kw = dict(algorithm="EM", task="CLS", driver="stream", chunk_rows=64,
              max_iters=6, min_iters=6, window=2)
    g1 = PEMSVM(SVMConfig(**kw)).fit(X, Y_CLS)
    assert g1.stats is not None and len(g1.stats_window) == 1
    g2 = PEMSVM(SVMConfig(**kw)).fit(X, -Y_CLS, warm_start=g1)
    assert len(g2.stats_window) == 1                # ring stays bounded

    # Effective statistics = fresh + retained ring, exactly.
    assert np.array_equal(
        g2.stats["S"], g2.stats_window[0]["S"] + g1.stats_window[0]["S"])
    assert np.array_equal(
        g2.stats["b"], g2.stats_window[0]["b"] + g1.stats_window[0]["b"])

    g3 = PEMSVM(SVMConfig(**kw)).fit(X, Y_CLS, warm_start=g2)
    fat = dataclasses.replace(                       # stale gen appended
        g2, stats_window=g2.stats_window + g1.stats_window)
    g3b = PEMSVM(SVMConfig(**kw)).fit(X, Y_CLS, warm_start=fat)
    assert np.array_equal(g3.weights, g3b.weights)   # expired = gone

    fresh = PEMSVM(SVMConfig(**kw)).fit(X, Y_CLS)
    assert not np.allclose(g3.weights, fresh.weights)  # ring does fold


def test_window_multiclass_shapes():
    kw = dict(algorithm="EM", task="MLT", num_classes=3, driver="stream",
              chunk_rows=64, max_iters=4, min_iters=4, window=2)
    ym = np.argmax(X @ _rng.normal(size=(3, K)).T, 1).astype(np.int32)
    d1 = PEMSVM(SVMConfig(**kw)).fit(X, ym)
    # Generation 2 sees RELABELED data, so the folded ring must actually
    # move the solution (same-data folding only rescales S and b).
    d2 = PEMSVM(SVMConfig(**kw)).fit(X, (ym + 1) % 3, warm_start=d1)
    assert d2.stats["S"].shape == (3, K + 1, K + 1)
    assert d2.stats_window[0]["S"].shape == (3, K + 1, K + 1)
    assert d2.stats_window[0]["b"].shape == (3, K + 1)
    assert not np.allclose(d1.weights, d2.weights)


def test_window_kill_resume_bitwise(tmp_path):
    """The ring rides the checkpoint (win{i}_* arrays): a warm-started
    windowed fit killed mid-flight resumes WITHOUT the donor in hand and
    still folds bit-identical sums — resume-exactness for hard expiry."""
    kw = dict(algorithm="MC", task="CLS", driver="stream", chunk_rows=64,
              max_iters=10, min_iters=10, burnin=3, window=2)
    donor = PEMSVM(SVMConfig(**kw)).fit(X, Y_CLS)
    ref = PEMSVM(SVMConfig(**kw)).fit(X, -Y_CLS, warm_start=donor)

    d = str(tmp_path)
    pol = FaultPolicy(ckpt_dir=d, ckpt_every=2)
    cfg = SVMConfig(**kw, fault=pol)
    with pytest.raises(faults.SimulatedPreemption):
        PEMSVM(cfg).fit(X, -Y_CLS, warm_start=donor,
                        fault_hook=faults.kill_at_iteration(5))
    res = PEMSVM(cfg).fit(X, -Y_CLS, resume_from=d)

    assert np.array_equal(ref.weights, res.weights)
    assert np.array_equal(res.stats["S"], ref.stats["S"])
    assert np.array_equal(res.stats_window[0]["S"],
                          ref.stats_window[0]["S"])

    # window is SEMANTIC: a different horizon must refuse the snapshot.
    with pytest.raises(ValueError, match="window"):
        PEMSVM(SVMConfig(**{**kw, "window": 3}, fault=pol)).fit(
            X, -Y_CLS, resume_from=d)


def test_window_config_guards():
    with pytest.raises(AssertionError):              # competing semantics
        SVMConfig(driver="stream", chunk_rows=64, window=2, decay=0.5)
    with pytest.raises(AssertionError):              # stream-only
        SVMConfig(driver="loop", window=2)
    donor = PEMSVM(SVMConfig(algorithm="EM", driver="stream",
                             chunk_rows=64, max_iters=4, min_iters=4)
                   ).fit(X, Y_CLS)                   # window=0: no ring
    with pytest.raises(ValueError, match="stats_window"):
        PEMSVM(SVMConfig(algorithm="EM", driver="stream", chunk_rows=64,
                         max_iters=4, min_iters=4, window=2)).fit(
            X, Y_CLS, warm_start=donor)


# --------------------------------------------------- loader retry surface
def test_retrying_chunks_jitter_deterministic():
    """Backoff jitter is keyed on the seed: the same (seed, failure
    sequence) sleeps the same schedule bit-for-bit; a different seed
    desynchronizes. RetryStats surfaces what was absorbed."""
    import itertools

    from repro.data import RetryStats
    from repro.data.pipeline import retrying_chunks

    def run(seed):
        inj = faults.io_error_every_nth(lambda: iter(range(6)), 2,
                                        times=1)
        slept, stats = [], RetryStats()
        out = list(retrying_chunks(
            lambda skip: itertools.islice(inj(), skip, None),
            retries=3, backoff=0.5, jitter=0.3, seed=seed,
            sleep=slept.append, stats=stats))
        return out, slept, stats

    out_a, slept_a, st_a = run(seed=5)
    out_b, slept_b, _ = run(seed=5)
    out_c, slept_c, _ = run(seed=6)
    assert out_a == out_b == out_c == list(range(6))  # all drained
    assert slept_a == slept_b                         # replayable
    assert slept_a != slept_c                         # decorrelated
    assert len(slept_a) == 3                          # positions 1, 3, 5
    for s in slept_a:
        assert 0.5 <= s <= 0.5 * 1.3                  # base * (1+j*U)
    assert st_a.retries == 3 and st_a.exhausted == 0
    assert st_a.backoff_s == pytest.approx(sum(slept_a))


# ------------------------------------------ lease election units (PR 9)
def _clockpair(d, ttl=2.0):
    """Two managers on one dir sharing a settable fake clock."""
    t = [0.0]
    pol = LeasePolicy(ttl_s=ttl)
    a = LeaseManager(str(d), "A", policy=pol, clock=lambda: t[0])
    b = LeaseManager(str(d), "B", policy=pol, clock=lambda: t[0])
    return a, b, t


def test_lease_dueling_startup_one_winner(tmp_path):
    """O_EXCL arbitration: of two controllers starting on an empty
    directory, exactly one becomes leader at term 1 (and the fence
    advances with it); the other stands by."""
    a, b, t = _clockpair(tmp_path)
    la, lb = a.try_acquire(), b.try_acquire()
    assert la is not None and lb is None
    assert la.term == 1 and la.owner == "A"
    assert read_fence(str(tmp_path)) == 1
    assert b.try_acquire() is None                   # still standing by
    assert a.try_acquire().term == 1                 # re-entrant for owner


def test_lease_expiry_takeover_advances_term_and_fence(tmp_path):
    a, b, t = _clockpair(tmp_path, ttl=2.0)
    assert a.try_acquire().term == 1
    t[0] = 1.0
    a.renew()                                        # healthy heartbeat
    assert b.try_acquire() is None
    t[0] = 3.5                                       # stamp 1.0 + ttl 2.0 < now
    lb = b.try_acquire()
    assert lb is not None and lb.term == 2           # term+1 takeover
    assert read_fence(str(tmp_path)) == 2            # fence rides along
    with pytest.raises(LeaseLost, match="deadline"):
        a.renew()                                    # deposed leader
    assert b.read().owner == "B"                     # A never wrote


def test_lease_renew_refuses_past_own_deadline_before_writing(tmp_path):
    """The frozen-leader-wakes race: a leader past its OWN ttl must not
    touch the lease file even if no usurper has appeared yet — the
    check is on its own stamp, not on what is on disk."""
    a, b, t = _clockpair(tmp_path, ttl=1.0)
    a.try_acquire()
    t[0] = 5.0                                       # woke from a long pause
    with pytest.raises(LeaseLost, match="standing down"):
        a.renew()
    assert a.read().owner == "A"                     # file untouched
    assert a.state is None                           # holder gave it up


def test_lease_torn_file_is_breakable(tmp_path):
    a, b, t = _clockpair(tmp_path)
    assert a.try_acquire().term == 1
    faults.tear_file(os.path.join(str(tmp_path), LEASE_FILE), 7)
    assert a.read() is None                          # unreadable != crash
    lb = b.try_acquire()                             # torn -> breakable now
    assert lb is not None and lb.term == 2


def test_lease_release_lets_standby_in_immediately(tmp_path):
    a, b, t = _clockpair(tmp_path)
    a.try_acquire()
    b.release()                                      # non-owner: no-op
    assert a.read().owner == "A"
    a.release()
    assert a.read() is None
    assert b.try_acquire().term == 2                 # no ttl wait needed


def test_mint_epoch_requires_live_lease(tmp_path):
    """Renew-before-mint at the lease level: mint_epoch verifies
    ownership in the SAME critical section that advances the fence, so
    a manager whose lease expired (or was usurped) raises LeaseLost
    WITHOUT advancing — the usurper's term stays the top of the
    counter."""
    a, b, t = _clockpair(tmp_path, ttl=2.0)
    assert a.try_acquire().term == 1
    assert a.mint_epoch() == 2                   # healthy leader mints
    assert read_fence(str(tmp_path)) == 2
    t[0] = 5.0                                   # a's lease ages out
    lb = b.try_acquire()
    assert lb is not None and lb.term == 3       # past fence AND term
    with pytest.raises(LeaseLost):
        a.mint_epoch()                           # deposed: refuses
    assert read_fence(str(tmp_path)) == 3        # fence NOT advanced
    assert b.renew().term == 3                   # usurper unharmed


def test_stale_leader_cannot_fence_out_usurper(tmp_path):
    """THE fence-inversion regression: leader A's lease silently
    expires mid-attempt (a renewal-free window) and standby B takes
    over at term 2. A's relaunch must NOT advance the shared fence
    past B's term — that would fence out the LEGITIMATE leader's
    workers and outrank B's line in (epoch, step) restore order. With
    renew-before-mint, A stands down with LeadershipLost and the fence
    still reads B's term."""
    d = str(tmp_path)
    t = [0.0]
    lease = LeasePolicy(ttl_s=2.0)
    usurper = LeaseManager(d, "B", policy=lease, clock=lambda: t[0])

    def make_host(level):
        def host(ctx):
            # While A's attempt runs: its lease ages out unnoticed
            # (the clock jump) and B takes over; then a retryable
            # failure sends A toward a relaunch it must refuse.
            t[0] = 5.0
            assert usurper.try_acquire() is not None
            raise IOError("flaky host")
        return host

    A = FleetController(
        make_host, d,
        policy=FleetPolicy(max_attempts=3, backoff_s=1e-3, poll_s=0.01),
        lease=lease, owner="A", clock=lambda: t[0])
    with pytest.raises(LeadershipLost):
        A.run()
    assert read_fence(d) == 2                    # B's term, NOT beyond
    assert usurper.read().owner == "B"           # lease untouched by A


def test_leader_renews_through_drain_window(tmp_path):
    """Abandoning one non-cooperative worker must not cost the lease:
    kill_grace_s EXCEEDS the ttl here, so a renewal-free cancel-drain
    would guarantee an unnecessary takeover (and the relaunch mint
    would then stand down). With the drain heartbeat the same
    controller keeps its term across the abandon and completes."""
    kw = dict(algorithm="EM", task="CLS", driver="loop", max_iters=6,
              min_iters=6)
    ref = PEMSVM(SVMConfig(**kw)).fit(X, Y_CLS)
    d = str(tmp_path)
    cfg = SVMConfig(**kw, fault=FaultPolicy(ckpt_dir=d, ckpt_every=1))
    release = threading.Event()

    def make_host(level):
        def host(ctx):
            if ctx.attempt == 0:
                release.wait(30.0)               # ignores cancel
                raise RuntimeError("hung worker released")
            return PEMSVM(cfg).fit(X, Y_CLS, resume_from=ctx.resume_from,
                                   fault_hook=ctx.fault_hook,
                                   epoch=ctx.epoch)
        return host

    fc = FleetController(
        make_host, d,
        policy=FleetPolicy(max_attempts=3, backoff_s=1e-3,
                           watchdog_s=0.3, poll_s=0.02,
                           kill_grace_s=1.2),
        lease=LeasePolicy(ttl_s=0.6, renew_every_s=0.1), owner="A")
    try:
        with pytest.warns(RuntimeWarning, match="abandoning"):
            fr = fc.run()
    finally:
        release.set()

    assert [a.outcome for a in fr.attempts] == ["abandoned", "completed"]
    assert fr.term == 1                          # never deposed
    assert fc._lease.read() is None              # released cleanly
    assert np.array_equal(ref.weights, fr.result.weights)


def test_renew_oserror_is_missed_heartbeat(tmp_path, monkeypatch):
    """An OSError from the lease WRITE (ENOSPC-style) mid-supervision
    must neither crash the controller out from under a live worker nor
    depose it: the failure is a missed heartbeat (one RuntimeWarning
    per streak), renewals retry next poll, and once the disk recovers
    the reign completes with its term intact."""
    kw = dict(algorithm="EM", task="CLS", driver="loop", max_iters=8,
              min_iters=8)
    d = str(tmp_path)
    cfg = SVMConfig(**kw, fault=FaultPolicy(ckpt_dir=d, ckpt_every=1))

    def make_host(level):
        def host(ctx):
            return PEMSVM(cfg).fit(X, Y_CLS, resume_from=ctx.resume_from,
                                   fault_hook=ctx.fault_hook,
                                   epoch=ctx.epoch)
        return host

    real = LeaseManager._write_replace
    fails = {"n": 0}

    def flaky_write(self, st):
        # Acquisition goes through _write_excl, so this hits RENEWALS:
        # fail the first two, then recover.
        if fails["n"] < 2:
            fails["n"] += 1
            raise OSError(28, "No space left on device")
        return real(self, st)

    monkeypatch.setattr(LeaseManager, "_write_replace", flaky_write)
    fc = FleetController(
        make_host, d, policy=FleetPolicy(max_attempts=2, poll_s=0.01),
        lease=LeasePolicy(ttl_s=5.0, renew_every_s=0.01), owner="A")
    with pytest.warns(RuntimeWarning, match="missed heartbeat"):
        fr = fc.run()

    assert fails["n"] >= 1                       # failure was exercised
    assert fr.term == 1
    assert [a.outcome for a in fr.attempts] == ["completed"]


def test_controller_mints_fresh_epoch_per_attempt(tmp_path):
    """Even without an election, every launch gets a fresh fence epoch
    advanced BEFORE the attempt starts — the PR 8 abandoned-worker
    caveat is closed by construction, not by the election feature."""
    seen = []

    def make_host(level):
        def host(ctx):
            seen.append(ctx.epoch)
            if ctx.attempt == 0:
                raise IOError("flaky host")
            return "ok"
        return host

    fc = FleetController(make_host, str(tmp_path),
                         policy=FleetPolicy(max_attempts=3, backoff_s=0.0))
    fr = fc.run()
    assert seen == [1, 2]
    assert [a.epoch for a in fr.attempts] == [1, 2]
    assert read_fence(str(tmp_path)) == 2
    assert fr.term == 0                              # no election configured


def test_standby_timeout_gives_up_cleanly(tmp_path):
    foreign = LeaseManager(str(tmp_path), "other")
    assert foreign.try_acquire() is not None         # healthy live leader

    def make_host(level):
        def host(ctx):                               # must never launch
            raise AssertionError("standby launched a host")
        return host

    fc = FleetController(
        make_host, str(tmp_path), policy=FleetPolicy(max_attempts=1),
        lease=LeasePolicy(ttl_s=30.0, poll_s=0.02, standby_timeout_s=0.15),
        owner="B")
    with pytest.raises(FleetError, match="standing by"):
        fc.run()


# ------------------------------------- split-brain chaos proofs (PR 9)
def test_dueling_controllers_elect_and_both_finish_bitwise(tmp_path):
    """Two controllers started on the SAME checkpoint directory with no
    coordination beyond the lease file: one leads and fits; the other
    stands by, acquires after the release, resumes from the final
    snapshot (instantly — the fit is already converged), and both
    return the bitwise-identical model."""
    kw = dict(algorithm="EM", task="CLS", driver="loop", max_iters=8,
              min_iters=8)
    ref = PEMSVM(SVMConfig(**kw)).fit(X, Y_CLS)
    cfg = SVMConfig(**kw, fault=FaultPolicy(ckpt_dir=str(tmp_path),
                                            ckpt_every=1))

    def make_host(level):
        def host(ctx):
            return PEMSVM(cfg).fit(X, Y_CLS, resume_from=ctx.resume_from,
                                   fault_hook=ctx.fault_hook,
                                   epoch=ctx.epoch)
        return host

    def ctrl(owner):
        return FleetController(
            make_host, str(tmp_path),
            policy=FleetPolicy(max_attempts=2, poll_s=0.02),
            lease=LeasePolicy(ttl_s=5.0, poll_s=0.05), owner=owner)

    out = {}
    ts = [threading.Thread(target=lambda o=o: out.update({o: ctrl(o).run()}))
          for o in ("A", "B")]
    for th in ts:
        th.start()
    for th in ts:
        th.join(timeout=120)
        assert not th.is_alive()

    terms = sorted(fr.term for fr in out.values())
    assert terms[0] >= 1 and terms[1] > terms[0]      # distinct reigns
    for fr in out.values():
        assert np.array_equal(ref.weights, fr.result.weights)
    # The loser's fit resumed from the winner's FINAL snapshot.
    late = max(out.values(), key=lambda fr: fr.term)
    assert late.result.resumed_at == 8


def test_frozen_leader_takeover_fences_zombie_commit(tmp_path):
    """THE acceptance scenario (ISSUE 9). Controller A leads and its
    worker commits; A freezes mid-supervision (injected GC pause) while
    its worker blocks NON-cooperatively inside an iteration (ignores
    cancel — a genuine zombie). Standby B's lease expires A, takes over
    at term+1 (fence rides along), resumes from A's last commit and
    completes. The zombie is then released: it attempts its next
    boundary commit and is REJECTED at the rename boundary
    (FencedCommitError) — the on-disk record set does not change. A
    thaws, notices its lease is gone, and raises LeadershipLost. B's
    model is bitwise the undisturbed single-controller fit on the same
    layout."""
    kw = dict(algorithm="EM", task="CLS", driver="loop", max_iters=14,
              min_iters=14)
    ref = PEMSVM(SVMConfig(**kw)).fit(X, Y_CLS)
    d = str(tmp_path)
    cfg = SVMConfig(**kw, fault=FaultPolicy(ckpt_dir=d, ckpt_every=1))

    frozen = threading.Event()
    release = threading.Event()
    zombie = {}

    def make_host_a(level):
        def host(ctx):
            # ROGUE worker: ignores ctx.fault_hook (and with it the
            # controller's cancel) — blocks at iteration 5 until the
            # TEST releases it, then keeps fitting and tries to commit.
            try:
                return PEMSVM(cfg).fit(
                    X, Y_CLS, resume_from=ctx.resume_from,
                    fault_hook=faults.hold_at_iteration(
                        5, release=release, max_seconds=120.0),
                    epoch=ctx.epoch)
            except Exception as e:
                zombie["error"] = e
                raise
        return host

    def make_host_b(level):
        def host(ctx):
            return PEMSVM(cfg).fit(X, Y_CLS, resume_from=ctx.resume_from,
                                   fault_hook=ctx.fault_hook,
                                   epoch=ctx.epoch)
        return host

    lease = LeasePolicy(ttl_s=0.6, renew_every_s=0.1, poll_s=0.05)
    A = FleetController(
        make_host_a, d,
        policy=FleetPolicy(max_attempts=2, poll_s=0.02,
                           kill_grace_s=0.3),
        lease=lease, owner="A",
        sleep=faults.freezable_sleep(frozen, max_seconds=120.0))
    B = FleetController(
        make_host_b, d,
        policy=FleetPolicy(max_attempts=2, poll_s=0.02),
        lease=lease, owner="B")

    out = {}

    def run_a():
        try:
            out["A"] = A.run()
        except FleetError as e:
            out["A"] = e

    ta = threading.Thread(target=run_a)
    ta.start()
    # Wait until A's worker has committed and is held at iteration 5.
    deadline = time.monotonic() + 60.0
    ck = Checkpointer(d, keep_k=0)
    while (ck.latest_record() or (0, 0))[1] < 5_000_000:
        assert time.monotonic() < deadline, "A's worker never reached it=5"
        time.sleep(0.02)
    assert read_fence(d) == 1                        # A's reign, epoch 1
    frozen.set()                                     # leader goes dark

    fr_b = None
    tb = threading.Thread(
        target=lambda: out.__setitem__("B", B.run()))
    tb.start()
    tb.join(timeout=120)
    assert not tb.is_alive()
    fr_b = out["B"]
    assert fr_b.term == 2                            # takeover at term+1
    assert fr_b.attempts[0].epoch == 2
    assert fr_b.result.resumed_at == 5               # resumed A's line
    assert np.array_equal(ref.weights, fr_b.result.weights)  # BITWISE

    # Release the zombie: it fits on and attempts its next boundary
    # commit, which the fence must reject without touching the records.
    records_before = ck.all_records()
    release.set()
    deadline = time.monotonic() + 60.0
    while "error" not in zombie:
        assert time.monotonic() < deadline, "zombie never hit the fence"
        time.sleep(0.02)
    assert isinstance(zombie["error"], FencedCommitError)
    assert zombie["error"].epoch == 1 and zombie["error"].fence == 2
    assert ck.all_records() == records_before        # nothing landed
    assert ck.latest_record()[0] == 2                # B's line on top

    # Thaw A: its next renewal sees the missed deadline and it stands
    # down with LeadershipLost (abandoning the already-dead worker).
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        frozen.clear()
        ta.join(timeout=120)
    assert not ta.is_alive()
    assert isinstance(out["A"], LeadershipLost)
    # Depending on whether the zombie thread was already dead at thaw,
    # A notices via the fenced commit or via its missed renewal.
    assert out["A"].attempts[0].outcome in ("fenced", "abandoned",
                                            "lease-lost")

    # The directory's resolved restore is B's line — epoch-major, so
    # even a zombie commit that HAD raced past the fence could not
    # outrank it.
    arrays, manifest = ck.restore_named()
    assert manifest["epoch"] == 2
