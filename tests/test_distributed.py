"""Multi-device distribution tests. These MUST run in subprocesses: the
host device count is locked at first jax init, and the main test process
stays single-device (see conftest note)."""
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def run_with_devices(code: str, n_devices: int = 8, timeout: int = 600):
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={n_devices}")
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    p = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env,
                       timeout=timeout)
    assert p.returncode == 0, f"STDOUT:\n{p.stdout}\nSTDERR:\n{p.stderr}"
    return p.stdout


HEADER = """
import numpy as np, jax, jax.numpy as jnp
from repro import compat
from jax.sharding import PartitionSpec as P
from repro.core import PEMSVM, SVMConfig
mesh = compat.make_mesh((4, 2), ("data", "model"),
                     axis_types=("auto",) * 2)
rng = np.random.default_rng(0)
N, K = 1037, 23
w_true = rng.normal(size=K)
X = rng.normal(size=(N, K)).astype(np.float32)
y = np.where(X @ w_true + 0.3 * rng.normal(size=N) > 0, 1.0, -1.0)
"""


def test_sharded_em_single_step_exact():
    run_with_devices(HEADER + """
cfg = SVMConfig(max_iters=1, min_iters=1)
r1 = PEMSVM(cfg).fit(X, y)
r8 = PEMSVM(cfg, mesh=mesh).fit(X, y)
np.testing.assert_allclose(r8.weights, r1.weights, rtol=1e-4, atol=1e-5)
""")


def test_sharded_em_convergence_agreement():
    run_with_devices(HEADER + """
cfg = SVMConfig(max_iters=40)
r1 = PEMSVM(cfg).fit(X, y)
s8 = PEMSVM(cfg, mesh=mesh); r8 = s8.fit(X, y)
rel = abs(r1.objective[-1] - r8.objective[-1]) / abs(r1.objective[-1])
# fp32 reduction-order divergence compounds over 40 iterations; the
# emulated-device CPU backend needs a slightly looser band than TPU.
assert rel < 2e-2, rel
assert s8.score(X, y) > 0.95
""")


def test_sharded_triangle_vs_dense_reduce_equal():
    run_with_devices(HEADER + """
a = PEMSVM(SVMConfig(max_iters=5, min_iters=1, triangle_reduce=True),
           mesh=mesh).fit(X, y)
b = PEMSVM(SVMConfig(max_iters=5, min_iters=1, triangle_reduce=False),
           mesh=mesh).fit(X, y)
np.testing.assert_allclose(a.weights, b.weights, rtol=1e-3, atol=1e-4)
""")


def test_sharded_compressed_reduce_needs_coarser_clamp():
    """bf16 compressed reduction: parity at gamma clamp >= 1e-3; at 1e-6
    the 1/gamma dynamic range (1e6) exceeds the 8-bit mantissa and the
    solve collapses (EXPERIMENTS.md §Perf A4)."""
    run_with_devices(HEADER + """
a = PEMSVM(SVMConfig(max_iters=30, eps=1e-3), mesh=mesh)
b = PEMSVM(SVMConfig(max_iters=30, eps=1e-3, reduce_dtype="bfloat16"),
           mesh=mesh)
a.fit(X, y); b.fit(X, y)
assert abs(a.score(X, y) - b.score(X, y)) < 0.02, (
    a.score(X, y), b.score(X, y))
# regression: the documented failure mode at the default tight clamp
c = PEMSVM(SVMConfig(max_iters=30, eps=1e-6, reduce_dtype="bfloat16"),
           mesh=mesh)
c.fit(X, y)
assert c.score(X, y) < 0.9   # collapses -> do NOT use bf16 with eps=1e-6
""")


def test_k_shard_two_dimensional_statistic():
    run_with_devices(HEADER + """
Xp = np.concatenate([X, np.ones((N, 1), np.float32)], 1)
base = PEMSVM(SVMConfig(max_iters=30, add_bias=False)).fit(Xp, y)
ks = PEMSVM(SVMConfig(max_iters=30, add_bias=False, k_shard_axis="model"),
            mesh=mesh, data_axes=("data",)).fit(Xp, y)
rel = abs(base.objective[-1] - ks.objective[-1]) / abs(base.objective[-1])
assert rel < 1e-2, rel
""")


def test_sharded_mc_mlt_svr_krn():
    run_with_devices(HEADER + """
mc = PEMSVM(SVMConfig(algorithm="MC", max_iters=40), mesh=mesh)
mc.fit(X, y); assert mc.score(X, y) > 0.93
M = 3
Wt = rng.normal(size=(M, K))
labels = np.argmax(X @ Wt.T, axis=1).astype(np.int32)
m = PEMSVM(SVMConfig(algorithm="MC", task="MLT", num_classes=M,
                     max_iters=30), mesh=mesh)
m.fit(X, labels); assert m.score(X, labels) > 0.9
ys = (X @ w_true).astype(np.float32)
s = PEMSVM(SVMConfig(task="SVR", lam=0.1, max_iters=30), mesh=mesh)
s.fit(X, ys); assert s.rmse(X, ys) < 0.1
r_ = np.concatenate([rng.uniform(0, 1, 150), rng.uniform(1.5, 2.5, 150)])
th = rng.uniform(0, 2 * np.pi, 300)
Xc = np.stack([r_ * np.cos(th), r_ * np.sin(th)], 1).astype(np.float32)
yc = np.concatenate([np.ones(150), -np.ones(150)]).astype(np.float32)
k = PEMSVM(SVMConfig(formulation="KRN", lam=0.1, sigma=0.7, max_iters=30),
           mesh=mesh)
k.fit(Xc, yc); assert k.score(Xc, yc) > 0.97
""", timeout=900)


def test_krn_mc_chain_is_mesh_layout_invariant():
    """KRN MC gamma draws are keyed per GLOBAL row (PR-3, mirroring the
    LIN rowwise keying of PR-2): a mesh fit draws the SAME gamma chain
    as the single-device one. The assertion target is the first
    iteration's gamma_mean — margins are exactly 0 at omega = 0, so the
    draws are bitwise-identical iff the keying is layout-invariant; the
    pre-fix per-axis key folds shifted it by O(1/sqrt(N)). (Weight-level
    parity is NOT testable for KRN: the near-singular lam*K + S solve
    amplifies psum-reordering noise to O(1), same reason the EM mesh
    test gates on score.) N = 320 divides both layouts' padding chunks
    (8 and 64) so the two runs see identical padded shapes."""
    run_with_devices("""
import numpy as np, jax, jax.numpy as jnp
from repro import compat
from repro.core import PEMSVM, SVMConfig
mesh = compat.make_mesh((4, 2), ("data", "model"),
                     axis_types=("auto",) * 2)
rng = np.random.default_rng(0)
N = 320
r_ = np.concatenate([rng.uniform(0, 1, N // 2),
                     rng.uniform(1.5, 2.5, N // 2)])
th = rng.uniform(0, 2 * np.pi, N)
X = np.stack([r_ * np.cos(th), r_ * np.sin(th)], 1).astype(np.float32)
y = np.concatenate([np.ones(N // 2), -np.ones(N // 2)]).astype(np.float32)
cfg = SVMConfig(formulation="KRN", algorithm="MC", lam=0.1, sigma=0.7,
                burnin=0, max_iters=1, min_iters=1)
r1 = PEMSVM(cfg).fit(X, y)
r8 = PEMSVM(cfg, mesh=mesh).fit(X, y)
assert r1.weights.shape == r8.weights.shape, (r1.weights.shape,
                                              r8.weights.shape)
g1 = r1.aux_history["gamma_mean"][0]
g8 = r8.aux_history["gamma_mean"][0]
np.testing.assert_allclose(g8, g1, rtol=1e-5)
np.testing.assert_allclose(r8.objective[0], r1.objective[0], rtol=1e-4)
""")


def test_nystrom_mesh_matches_single_device():
    """The phi-space delegate on a mesh: raw rows are sharded, the
    featurizer arrays ride the replicated prior slot, and the EM fit
    matches the single-device one."""
    run_with_devices("""
import numpy as np
from repro import compat
from repro.core import NystromSVM, SVMConfig
mesh = compat.make_mesh((4, 2), ("data", "model"),
                     axis_types=("auto",) * 2)
rng = np.random.default_rng(0)
N, D = 1024, 12
X = rng.normal(size=(N, D)).astype(np.float32)
wt = rng.normal(size=D)
y = np.where(np.tanh(X @ wt) + 0.3 * rng.normal(size=N) > 0,
             1.0, -1.0).astype(np.float32)
cfg = SVMConfig(formulation="KRN", lam=1.0, sigma=3.0, eps=1e-2,
                max_iters=10, min_iters=10)
a = NystromSVM(cfg, n_landmarks=32)
r1 = a.fit(X, y)
b = NystromSVM(cfg, mesh=mesh, data_axes=("data", "model"),
               n_landmarks=32)
r8 = b.fit(X, y)
rel = np.abs(r8.weights - r1.weights).max() / np.abs(r1.weights).max()
assert rel < 1e-3, rel
assert abs(a.score(X, y) - b.score(X, y)) < 1e-2
""")


def test_k_shard_indivisible_K_raises():
    """K=23 over a model axis of 2: _k_block must raise, not silently
    drop the trailing column of Sigma."""
    run_with_devices(HEADER + """
try:
    PEMSVM(SVMConfig(max_iters=2, min_iters=1, add_bias=False,
                     k_shard_axis="model"),
           mesh=mesh, data_axes=("data",)).fit(X, y)
except ValueError as e:
    assert "does not divide" in str(e), e
else:
    raise SystemExit("expected ValueError for K=23 over 2-way model axis")
""")


def test_live_weighted_psum_drops_dead_replica():
    run_with_devices("""
import numpy as np, jax, jax.numpy as jnp
from repro import compat
from jax.sharding import PartitionSpec as P
from repro.compat import shard_map
from repro.core.distributed import live_weighted_psum
mesh = compat.make_mesh((8,), ("data",),
                     axis_types=("auto",))
def f(x, live):
    return live_weighted_psum(x, live, ("data",))
g = jax.jit(shard_map(f, mesh=mesh, in_specs=(P("data"), P("data")),
                      out_specs=P("data"), check_vma=False))
x = jnp.arange(8.0)          # one value per replica
live = jnp.ones(8).at[3].set(0.0)   # replica 3 died
out = np.asarray(g(x, live))
# unbiased mean-preserving: sum of the 7 live values * 8/7
want = (x.sum() - 3.0) * 8.0 / 7.0
np.testing.assert_allclose(out, want, rtol=1e-6)
""")


def test_elastic_remesh_roundtrip():
    run_with_devices("""
import numpy as np, jax, jax.numpy as jnp
from repro import compat
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.runtime import remesh, scale_batch_schedule
m1 = compat.make_mesh((8,), ("data",), axis_types=("auto",))
m2 = compat.make_mesh((4, 2), ("data", "model"),
                   axis_types=("auto",) * 2)
tree = {"w": jnp.arange(64.0).reshape(8, 8)}
t1 = jax.device_put(tree, NamedSharding(m1, P("data", None)))
t2 = remesh(t1, {"w": NamedSharding(m2, P("model", "data"))})
np.testing.assert_allclose(np.asarray(t2["w"]),
                           np.arange(64.0).reshape(8, 8))
gb, lr = scale_batch_schedule(256, 8, 4, keep_global=True)
assert (gb, lr) == (256, 1.0)
gb, lr = scale_batch_schedule(256, 8, 16, keep_global=False)
assert gb == 512 and lr == 2.0
""")


def test_seq_parallel_attention_matches_blockwise():
    run_with_devices("""
import numpy as np, jax, jax.numpy as jnp
from repro import compat
from repro.models.attention import blockwise_attn, seq_parallel_attention
from repro.sharding import ShardingCtx
mesh = compat.make_mesh((2, 4), ("data", "model"),
                     axis_types=("auto",) * 2)
ctx = ShardingCtx(mesh=mesh, dp_axes=("data",), tp_axis="model",
                  fsdp_axis="data")
key = jax.random.PRNGKey(0)
B, S, H, KVH, dh = 2, 64, 3, 3, 16   # H=3: not divisible by model axis
q = jax.random.normal(key, (B, S, H, dh))
k = jax.random.normal(jax.random.PRNGKey(1), (B, S, KVH, dh))
v = jax.random.normal(jax.random.PRNGKey(2), (B, S, KVH, dh))
ref = blockwise_attn(q, k, v, causal=True, q_chunk=16, kv_chunk=16)
with compat.set_mesh(mesh):
    got = jax.jit(lambda a, b, c: seq_parallel_attention(
        ctx, a, b, c, causal=True, q_chunk=16, kv_chunk=16))(q, k, v)
np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=2e-4,
                           atol=2e-4)
print("seq-parallel attention OK")
""")


def test_decode_island_matches_dense_decode():
    run_with_devices("""
import numpy as np, jax, jax.numpy as jnp
from repro import compat
from repro.models.attention import decode_attn, decode_attn_island
from repro.sharding import ShardingCtx
mesh = compat.make_mesh((2, 4), ("data", "model"),
                     axis_types=("auto",) * 2)
ctx = ShardingCtx(mesh=mesh, dp_axes=("data",), tp_axis="model",
                  fsdp_axis="data")
key = jax.random.PRNGKey(0)
B, S, H, KVH, dh = 4, 32, 4, 2, 8
pos = 17
kc = jax.random.normal(key, (B, S, KVH, dh))
vc = jax.random.normal(jax.random.PRNGKey(1), (B, S, KVH, dh))
q = jax.random.normal(jax.random.PRNGKey(2), (B, 1, H, dh))
kn = jax.random.normal(jax.random.PRNGKey(3), (B, 1, KVH, dh))
vn = jax.random.normal(jax.random.PRNGKey(4), (B, 1, KVH, dh))
# dense reference
kc_ref = jax.lax.dynamic_update_slice_in_dim(kc, kn, pos, axis=1)
vc_ref = jax.lax.dynamic_update_slice_in_dim(vc, vn, pos, axis=1)
ref = decode_attn(q, kc_ref, vc_ref, pos + 1)
with compat.set_mesh(mesh):
    o, kc2, vc2 = jax.jit(lambda *a: decode_attn_island(ctx, *a))(
        q, kc, vc, jnp.int32(pos), kn, vn)
np.testing.assert_allclose(np.asarray(o), np.asarray(ref), rtol=2e-4,
                           atol=2e-4)
np.testing.assert_allclose(np.asarray(kc2), np.asarray(kc_ref), rtol=1e-5)
print("decode island OK")
""")
