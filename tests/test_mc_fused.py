"""Single-stream Gibbs: the epilogue-parameterized fused statistics
(DESIGN.md §Perf/MC-SVR).

Layers, strongest first:

  1. BITWISE draw parity: the pre-drawn (nu, u) noise + in-kernel IG
     transform must reproduce the ``gamma_mc_rowwise`` / split-key
     oracles bit for bit (given the same residuals) — on odd masked
     shapes, under any chunking, and through the fused chunk-callables.
  2. Kernel parity: the mc_hinge / em_svr / mc_svr epilogues inside the
     Pallas kernels (interpret mode) match the jnp oracles. At w = 0
     the margins are exactly zero on both sides and the (nu, u) noise
     operands are bitwise-shared, so the MC draws must agree to FMA-
     contraction tolerance with ZERO accept-reject flips (the compiler
     may contract the transform's multiply-adds inside the kernel, so
     in-kernel arithmetic is lsb-close rather than bit-equal — the
     bitwise guarantee lives on the dispatch/ref path, layer 1); at
     random w the margin's own lsb noise can additionally flip the IG
     accept-reject branch on near-hinge rows (the documented discrete
     channel), so those checks assert the kernel outputs are
     *self-consistent* with the kernel's own emitted draws.
  3. Invariance: mesh layout must not change the sampled chain for the
     fused MC CLS/SVR paths (subprocess, multi-device CPU).
  4. Regression: the k_shard MC branch casts targets to f32 before the
     b statistic (a wider dtype would upcast the whole posterior solve).
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import augment
from repro.core.linear import accumulate_stats
from repro.core.svr import svr_local_stats
from repro.kernels import epilogues, ops, ref

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
RNG = np.random.default_rng(0)


def _run_with_devices(code: str, n_devices: int = 8, timeout: int = 600,
                      extra_env: dict | None = None):
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={n_devices}")
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.update(extra_env or {})
    p = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env,
                       timeout=timeout)
    assert p.returncode == 0, f"STDOUT:\n{p.stdout}\nSTDERR:\n{p.stderr}"
    return p.stdout


# ------------------------------------------------ 1. bitwise draw parity
@pytest.mark.parametrize("n,row0", [(1, 0), (77, 13), (256, 0), (301, 99)])
def test_predraw_transform_matches_rowwise_oracle_bitwise(n, row0):
    """draw_ig_noise + ig_gamma_from_noise == gamma_mc_rowwise, bit for
    bit: the vectorized pre-draw path is the same PRNG tree and the
    same arithmetic as the vmapped oracle."""
    key = jax.random.PRNGKey(n + row0)
    res = jnp.asarray(RNG.normal(size=n).astype(np.float32) * 3.0)
    want = augment.gamma_mc_rowwise(key, res, 1e-6, row0)
    nu, u = augment.draw_ig_noise(key, n, row0)
    got = epilogues.ig_gamma_from_noise(res, nu, u, 1e-6)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_predraw_noise_is_chunk_slice_invariant():
    """The (nu, u) arrays for a chunk are literally slices of the full
    arrays — global-row keying makes chunking invisible, bitwise."""
    key = jax.random.PRNGKey(3)
    nu, u = augment.draw_ig_noise(key, 230, 0)
    for i0, i1 in ((0, 64), (64, 193), (193, 230)):
        nu_c, u_c = augment.draw_ig_noise(key, i1 - i0, i0)
        np.testing.assert_array_equal(np.asarray(nu_c),
                                      np.asarray(nu)[i0:i1])
        np.testing.assert_array_equal(np.asarray(u_c),
                                      np.asarray(u)[i0:i1])


@pytest.mark.parametrize("n,k,n_valid", [(100, 7, 100), (128, 24, 77),
                                         (9, 33, 9)])
def test_fused_mc_cls_draws_bitwise_vs_oracle(n, k, n_valid):
    """The fused chunk-callable's MC gamma (ref backend) equals the
    gamma_mc_rowwise oracle at the same margins, bitwise — including
    padded tails (zero rows draw too, they just contribute nothing)."""
    rng = np.random.default_rng(n * k)
    X = np.zeros((n, k), np.float32)
    y = np.zeros((n,), np.float32)
    X[:n_valid] = rng.normal(size=(n_valid, k)).astype(np.float32)
    y[:n_valid] = rng.choice([-1.0, 1.0], n_valid)
    w = rng.normal(size=k).astype(np.float32)
    key = jax.random.PRNGKey(11)
    row0 = 37
    margin = jnp.asarray(X) @ jnp.asarray(w)
    want = augment.gamma_mc_rowwise(key, jnp.asarray(y) - margin, 1e-6,
                                    row0)
    m, gamma, S, b = accumulate_stats(
        jnp.asarray(X), jnp.asarray(y), jnp.asarray(y), jnp.asarray(w),
        mode="MC", key=key, eps=1e-6, backend="ref", row0=row0)
    np.testing.assert_array_equal(np.asarray(gamma), np.asarray(want))
    # and the statistics are the split computation's, to fp32 tolerance
    g = np.asarray(want)
    S_want = (X * (1.0 / g)[:, None]).T @ X
    b_want = X.T @ (y / g + y)
    np.testing.assert_allclose(np.asarray(S), S_want, rtol=1e-4,
                               atol=1e-4 * max(1.0, np.abs(S_want).max()))
    np.testing.assert_allclose(np.asarray(b), b_want, rtol=1e-4,
                               atol=1e-4 * max(1.0, np.abs(b_want).max()))


def test_fused_svr_draws_bitwise_vs_split_key_oracle():
    """SVR's double mixture: fused gamma/omega (ref backend) equal the
    pre-fusion split-key rowwise oracles bitwise, on a masked odd
    shape; the combined statistics match the split computation."""
    rng = np.random.default_rng(5)
    n, k, eps_ins, row0 = 203, 9, 0.2, 51
    X = rng.normal(size=(n, k)).astype(np.float32)
    X[180:] = 0.0                                   # padded tail
    y = (X @ rng.normal(size=k)).astype(np.float32)
    w = rng.normal(size=k).astype(np.float32)
    key = jax.random.PRNGKey(19)
    k_lo, k_hi = jax.random.split(key)
    res = jnp.asarray(y) - jnp.asarray(X) @ jnp.asarray(w)
    g_want = augment.gamma_mc_rowwise(k_lo, res - eps_ins, 1e-6, row0)
    o_want = augment.gamma_mc_rowwise(k_hi, res + eps_ins, 1e-6, row0)
    pred, gamma, omega, S, b = svr_local_stats(
        jnp.asarray(X), jnp.asarray(y), jnp.asarray(w), mode="MC",
        key=key, eps=1e-6, eps_ins=eps_ins, backend="ref", row0=row0)
    np.testing.assert_array_equal(np.asarray(gamma), np.asarray(g_want))
    np.testing.assert_array_equal(np.asarray(omega), np.asarray(o_want))
    g, o = np.asarray(g_want), np.asarray(o_want)
    S_want = (X * (1.0 / g + 1.0 / o)[:, None]).T @ X
    b_want = X.T @ ((y - eps_ins) / g + (y + eps_ins) / o)
    np.testing.assert_allclose(np.asarray(S), S_want, rtol=1e-4,
                               atol=1e-4 * max(1.0, np.abs(S_want).max()))
    np.testing.assert_allclose(np.asarray(b), b_want, rtol=1e-4,
                               atol=1e-4 * max(1.0, np.abs(b_want).max()))


def test_fused_svr_em_matches_pre_fusion_split():
    """EM-SVR single-stream == the pre-fusion 3-stream computation."""
    rng = np.random.default_rng(7)
    n, k, eps_ins = 150, 11, 0.3
    X = rng.normal(size=(n, k)).astype(np.float32)
    y = (X @ rng.normal(size=k)).astype(np.float32)
    w = rng.normal(size=k).astype(np.float32)
    pred, gamma, omega, S, b = svr_local_stats(
        jnp.asarray(X), jnp.asarray(y), jnp.asarray(w), mode="EM",
        key=None, eps=1e-6, eps_ins=eps_ins, backend="ref", row0=0)
    # residual from the RETURNED margin (a numpy f32 matmul reassociates
    # differently at the lsb — the E-step itself is what's under test)
    res = y - np.asarray(pred)
    g = np.maximum(np.abs(res - eps_ins), 1e-6)
    o = np.maximum(np.abs(res + eps_ins), 1e-6)
    np.testing.assert_array_equal(np.asarray(gamma), g)
    np.testing.assert_array_equal(np.asarray(omega), o)
    S_want = (X * (1.0 / g + 1.0 / o)[:, None]).T @ X
    b_want = X.T @ ((y - eps_ins) / g + (y + eps_ins) / o)
    np.testing.assert_allclose(np.asarray(S), S_want, rtol=1e-5,
                               atol=1e-5 * np.abs(S_want).max())
    np.testing.assert_allclose(np.asarray(b), b_want, rtol=1e-5,
                               atol=1e-5 * max(1.0, np.abs(b_want).max()))


# --------------------------------------------------- 2. kernel parity
@pytest.mark.parametrize("epilogue", ["mc_hinge", "em_svr", "mc_svr"])
@pytest.mark.parametrize("n,k", [(64, 32), (257, 100), (9, 50)])
def test_epilogue_kernel_interpret_matches_ref_at_zero_w(epilogue, n, k):
    """At w = 0 the margin is exactly zero in kernel and oracle alike
    and the (nu, u) noise is shared, so every epilogue output —
    including the MC draws — must agree to FMA-contraction tolerance
    with no accept-reject flips between the interpret-mode Pallas
    kernel and the jnp oracle, odd masked shapes included."""
    rng = np.random.default_rng(n + k)
    X = rng.normal(size=(n, k)).astype(np.float32)
    # Keep residuals off the hinge knee: at |rho| ~ 1e-3 the IG mean
    # mu = 1/|rho| ~ 1e3 and the MSH transform x ~ 1/y cancels
    # catastrophically (relative error ~ mu^2 y^2 eps_f32), swamping
    # the rounding-difference signal this test is after. |rho +-
    # eps_ins| >= 0.15 bounds mu <= ~7 on both SVR mixtures.
    rho = (np.sign(rng.normal(size=n)) *
           (0.3 + np.abs(rng.normal(size=n)))).astype(np.float32)
    beta = rng.choice([-1.0, 1.0], n).astype(np.float32)
    wm = (rng.uniform(size=n) > 0.2).astype(np.float32)
    w0 = np.zeros(k, np.float32)
    key = jax.random.PRNGKey(k)
    n_noise = epilogues.noise_arity(epilogue)
    noise = None
    if n_noise:
        k_lo, k_hi = jax.random.split(key)
        noise = augment.draw_ig_noise(k_lo, n, 3)
        if n_noise == 4:
            noise = (*noise, *augment.draw_ig_noise(k_hi, n, 3))
    kw = dict(epilogue=epilogue, eps=1e-4, eps_ins=0.15)
    got = ops.fused_stats(jnp.asarray(X), jnp.asarray(rho),
                          jnp.asarray(beta), jnp.asarray(w0),
                          jnp.asarray(wm), noise, backend="interpret",
                          block_n=64, **kw)
    want = ref.fused_stats(jnp.asarray(X), jnp.asarray(rho),
                           jnp.asarray(beta), jnp.asarray(w0),
                           jnp.asarray(wm), 1e-4, epilogue=epilogue,
                           noise=noise, eps_ins=0.15)
    names = (("margin", "gamma", "b", "S") if n_noise != 4 and
             epilogue.endswith("hinge") else
             ("margin", "gamma", "omega", "b", "S"))
    for g, w_, name in zip(got, want, names):
        g, w_ = np.asarray(g), np.asarray(w_)
        if name in ("gamma", "omega"):
            # rtol far below any accept-reject flip's O(1) jump but
            # above the transform's cancellation-amplified lsb noise
            # (x = mu(1 + y/2 - sqrt(...)) loses ~mu in relative
            # precision near the hinge knee): draws agree, no flips.
            np.testing.assert_allclose(g, w_, rtol=1e-2, err_msg=name)
        else:
            np.testing.assert_allclose(
                g, w_, rtol=2e-3, atol=2e-3 * max(1.0, np.abs(w_).max()),
                err_msg=name)


@pytest.mark.parametrize("epilogue", ["mc_hinge", "mc_svr"])
def test_epilogue_kernel_self_consistent_at_random_w(epilogue):
    """At random w the kernel margin's lsb noise may flip IG
    accept-reject branches vs the oracle; the kernel must still be
    SELF-consistent: S and b recomputed from its own emitted margins
    and draws match its S and b outputs."""
    rng = np.random.default_rng(23)
    n, k, eps_ins = 200, 17, 0.15
    X = rng.normal(size=(n, k)).astype(np.float32)
    rho = rng.normal(size=n).astype(np.float32)
    beta = rng.choice([-1.0, 1.0], n).astype(np.float32)
    w = rng.normal(size=k).astype(np.float32)
    key = jax.random.PRNGKey(2)
    k_lo, k_hi = jax.random.split(key)
    noise = augment.draw_ig_noise(k_lo, n, 0)
    if epilogue == "mc_svr":
        noise = (*noise, *augment.draw_ig_noise(k_hi, n, 0))
    out = ops.fused_stats(jnp.asarray(X), jnp.asarray(rho),
                          jnp.asarray(beta), jnp.asarray(w), None, noise,
                          epilogue=epilogue, eps=1e-4, eps_ins=eps_ins,
                          backend="interpret", block_n=64)
    if epilogue == "mc_hinge":
        margin, gamma, b, S = (np.asarray(v) for v in out)
        weight = 1.0 / gamma
        coef = rho / gamma + beta
    else:
        margin, gamma, omega, b, S = (np.asarray(v) for v in out)
        weight = 1.0 / gamma + 1.0 / omega
        coef = (rho - eps_ins) / gamma + (rho + eps_ins) / omega
    S_want = (X * weight[:, None]).T @ X
    b_want = X.T @ coef
    np.testing.assert_allclose(S, S_want, rtol=2e-3,
                               atol=2e-3 * np.abs(S_want).max())
    np.testing.assert_allclose(b, b_want, rtol=2e-3,
                               atol=2e-3 * max(1.0, np.abs(b_want).max()))


@pytest.mark.parametrize("epilogue", ["mc_hinge", "em_svr", "mc_svr"])
def test_nystrom_epilogue_kernel_interpret_matches_ref_at_zero_w(epilogue):
    """Phi-space flavor of the zero-w bitwise check: the fused Nystrom
    kernel under the MC/SVR epilogues, masked rows and phi bias on."""
    rng = np.random.default_rng(31)
    n, d, m = 100, 7, 37
    X = rng.normal(size=(n, d)).astype(np.float32)
    L = X[rng.choice(n, m, replace=False)]
    proj = (0.2 * rng.normal(size=(m, m))).astype(np.float32)
    mask = (rng.uniform(size=n) > 0.25).astype(np.float32)
    # off the hinge knee on every row (incl. masked ones, whose draws
    # are compared too even though their statistics are no-ops) — see
    # the X-space test for the mu-amplification rationale
    y = (np.sign(rng.normal(size=n)) *
         (0.3 + np.abs(rng.normal(size=n)))).astype(np.float32)
    w0 = np.zeros(m + 1, np.float32)
    key = jax.random.PRNGKey(5)
    n_noise = epilogues.noise_arity(epilogue)
    noise = None
    if n_noise:
        k_lo, k_hi = jax.random.split(key)
        noise = augment.draw_ig_noise(k_lo, n, 0)
        if n_noise == 4:
            noise = (*noise, *augment.draw_ig_noise(k_hi, n, 0))
    kw = dict(sigma=1.3, kind="rbf", add_bias=True, epilogue=epilogue,
              eps=1e-4, eps_ins=0.1)
    got = ops.nystrom_fused_stats(
        jnp.asarray(X), jnp.asarray(L), jnp.asarray(proj), jnp.asarray(y),
        jnp.asarray(y), jnp.asarray(w0), jnp.asarray(mask), noise,
        backend="interpret", block_n=32, **kw)
    want = ref.nystrom_fused_stats(
        jnp.asarray(X), jnp.asarray(L), jnp.asarray(proj), jnp.asarray(y),
        jnp.asarray(y), jnp.asarray(w0), jnp.asarray(mask), 1.3, "rbf",
        True, 1e-4, epilogue=epilogue, noise=noise, eps_ins=0.1)
    names = (("margin", "gamma", "b", "S") if epilogue == "mc_hinge"
             else ("margin", "gamma", "omega", "b", "S"))
    for g, w_, name in zip(got, want, names):
        g, w_ = np.asarray(g), np.asarray(w_)
        if name in ("gamma", "omega"):
            np.testing.assert_allclose(g, w_, rtol=1e-2, err_msg=name)
        else:
            np.testing.assert_allclose(
                g, w_, rtol=2e-3, atol=2e-3 * max(1.0, np.abs(w_).max()),
                err_msg=name)


def test_mc_epilogue_large_k_falls_back_to_split():
    """K beyond the VMEM cap must route the MC epilogue to the split
    fallback (jnp E-step + K-tiled SYRK) and still match the oracle —
    bitwise on the draws (the fallback margin IS the oracle margin)."""
    n, k = 24, ops.FUSED_STATS_MAX_K + 128
    rng = np.random.default_rng(1)
    X = jnp.asarray(rng.normal(size=(n, k)).astype(np.float32))
    y = jnp.asarray(rng.choice([-1.0, 1.0], n).astype(np.float32))
    wv = jnp.asarray(rng.normal(size=k).astype(np.float32))
    noise = augment.draw_ig_noise(jax.random.PRNGKey(0), n, 0)
    got = ops.fused_stats(X, y, y, wv, None, noise, epilogue="mc_hinge",
                          eps=1e-6, backend="interpret", block_n=32)
    want = ref.fused_stats(X, y, y, wv, None, 1e-6, epilogue="mc_hinge",
                           noise=noise)
    np.testing.assert_array_equal(np.asarray(got[1]), np.asarray(want[1]))
    for g, w_, name in zip(got, want, ("margin", "gamma", "b", "S")):
        g, w_ = np.asarray(g), np.asarray(w_)
        np.testing.assert_allclose(
            g, w_, rtol=2e-3, atol=2e-3 * max(1.0, np.abs(w_).max()),
            err_msg=name)


def test_nystrom_fused_fits_is_epilogue_aware():
    """The VMEM accounting must accept the epilogue and never report a
    LARGER working set for a cheaper epilogue."""
    for m, d in ((256, 784), (1024, 256)):
        em = ops._nystrom_vmem_words(m, d, True, 256, True, "em_hinge")
        svr = ops._nystrom_vmem_words(m, d, True, 256, True, "mc_svr")
        # mc_svr carries 4 noise + 1 extra aug per-row vectors over em
        assert svr == em + 5 * 256, (m, d)
        assert ops.nystrom_fused_fits(m, d, epilogue="em_hinge")
    assert not ops.nystrom_fused_fits(ops.NYSTROM_FUSED_MAX_M + 1, 16,
                                      epilogue="mc_svr")


# ------------------------------------------------------- 3. invariance
def test_mc_cls_svr_chain_is_mesh_layout_invariant():
    """LIN MC fused paths: a mesh fit draws the SAME gamma (and omega)
    chain as the single-device one — rowwise keying + shard row offsets
    make the layout invisible. First iteration: margins are exactly 0
    at w = 0, so the draws are bitwise-identical iff keying is
    layout-invariant (the means differ only by psum ordering)."""
    _run_with_devices("""
import numpy as np, jax
from repro import compat
from repro.core import PEMSVM, SVMConfig
mesh = compat.make_mesh((4, 2), ("data", "model"),
                        axis_types=("auto",) * 2)
rng = np.random.default_rng(0)
N, K = 1024, 16
X = rng.normal(size=(N, K)).astype(np.float32)
w_true = rng.normal(size=K)
y = np.where(X @ w_true + 0.3 * rng.normal(size=N) > 0, 1.0, -1.0)
cfg = SVMConfig(algorithm="MC", burnin=0, max_iters=1, min_iters=1)
r1 = PEMSVM(cfg).fit(X, y)
r8 = PEMSVM(cfg, mesh=mesh).fit(X, y)
np.testing.assert_allclose(r8.aux_history["gamma_mean"][0],
                           r1.aux_history["gamma_mean"][0], rtol=1e-5)
np.testing.assert_allclose(r8.objective[0], r1.objective[0], rtol=1e-4)
ys = (X @ w_true).astype(np.float32)
cfg = SVMConfig(algorithm="MC", task="SVR", eps_ins=0.3, burnin=0,
                max_iters=1, min_iters=1)
s1 = PEMSVM(cfg).fit(X, ys)
s8 = PEMSVM(cfg, mesh=mesh).fit(X, ys)
for kk in ("gamma_mean", "omega_mean"):
    np.testing.assert_allclose(s8.aux_history[kk][0],
                               s1.aux_history[kk][0], rtol=1e-5)
np.testing.assert_allclose(s8.objective[0], s1.objective[0], rtol=1e-4)
print("mesh layout invariance OK")
""")


# -------------------------------------------------------- 4. regression
def test_k_shard_mc_casts_targets_to_f32():
    """Regression: the k_shard MC branch must cast targets before the
    b statistic — with x64 enabled and f64 targets, the pre-fix
    ``y / gamma + y`` upcast b (and then the whole posterior solve and
    the returned weights) to float64."""
    _run_with_devices("""
import jax
jax.config.update("jax_enable_x64", True)
import numpy as np, jax.numpy as jnp
from repro import compat
from jax.sharding import PartitionSpec as P
from repro.compat import shard_map
from repro.core import linear
from repro.core.linear import SVMData
mesh = compat.make_mesh((2,), ("model",), axis_types=("auto",))
rng = np.random.default_rng(0)
N, K = 64, 8
X = jnp.asarray(rng.normal(size=(N, K)).astype(np.float32))
y = jnp.asarray(rng.choice([-1.0, 1.0], N))            # float64 under x64
mask = jnp.ones((N,), jnp.float32)
assert y.dtype == jnp.float64, y.dtype
def step(X, y, mask, w, key):
    return linear.cls_step(SVMData(X, y, mask), w, key, mode="MC",
                           axes=(), k_shard_axis="model", backend="ref")
w0 = jnp.zeros((K,), jnp.float32)
key = jax.random.PRNGKey(0)
rep = (P(None, None), P(None), P(None), P(None), P(None))
w_new, aux = jax.jit(shard_map(
    step, mesh=mesh, in_specs=rep,
    out_specs=(P(None), {k: P() for k in ("objective", "gamma_mean",
                                          "n_sv")}),
    check_vma=False))(X, y, mask, w0, key)
assert w_new.dtype == jnp.float32, w_new.dtype
# and the statistic agrees with the fused (casting) path
w_ref, _ = linear.cls_step(SVMData(X, y.astype(jnp.float32), mask), w0,
                           key, mode="MC", axes=(), backend="ref")
rel = np.abs(np.asarray(w_new) - np.asarray(w_ref)).max() / max(
    1e-9, np.abs(np.asarray(w_ref)).max())
assert rel < 1e-4, rel
print("k_shard f32 cast OK")
""", n_devices=2)
