"""End-to-end behaviour tests for the paper's system."""
import numpy as np
import jax
import jax.numpy as jnp

from conftest import reduce_cfg
from repro.configs import get_config
from repro.core import MaxMarginHead, PEMSVM, SVMConfig, mean_pool
from repro.data import make_blobs, make_mnist8m_like
from repro.models import build_model
from repro.serving import generate


def test_quickstart_path():
    """The README quickstart: fit, predict, score."""
    X, y = make_blobs(2000, 30, seed=1)
    svm = PEMSVM(SVMConfig.from_options("LIN-EM-CLS", lam=0.1))
    res = svm.fit(X, y)
    assert res.converged
    assert svm.score(X, y) > 0.95


def test_composite_max_margin_head_on_backbone():
    """Paper Sec 1: the sampling SVM as the readout of a composite model.
    A tiny frozen SmolLM backbone pools features; PEMSVM fits the head and
    must beat chance convincingly on a token-signal task."""
    cfg = reduce_cfg(get_config("smollm-135m"), n_layers=2, vocab=64)
    model = build_model(cfg, q_chunk=16, kv_chunk=16)
    params = model.init(jax.random.PRNGKey(0))

    rng = np.random.default_rng(0)
    N, S = 400, 16
    toks = np.where(rng.random((N, 1)) > 0.5,
                    rng.integers(0, 24, (N, S)),
                    rng.integers(40, 64, (N, S))).astype(np.int32)
    y = np.where(toks.mean(1) < 32, 1.0, -1.0)

    def feature_fn(tokens):
        h = model.hidden_seq(params, {"tokens": tokens}, remat=False)
        return mean_pool(h.astype(jnp.float32))

    mm = MaxMarginHead(SVMConfig(lam=0.1, max_iters=40), feature_fn)
    mm.fit(toks, y)
    assert mm.score(toks, y) > 0.9


def test_mnist8m_like_pipeline_mlt():
    """Paper Table 8 protocol shrunk: LIN-MC-MLT on mnist8m-shaped data."""
    X, labels = make_mnist8m_like(4000, 64, 10, seed=0)
    svm = PEMSVM(SVMConfig.from_options(
        "LIN-MC-MLT", num_classes=10, lam=2.0 / 0.04, max_iters=25,
        min_iters=20, burnin=5))
    svm.fit(X, labels)
    acc = svm.score(X, labels)
    assert acc > 0.7, acc


def test_generation_is_deterministic_greedy():
    cfg = reduce_cfg(get_config("smollm-135m"), n_layers=2)
    m = build_model(cfg, q_chunk=16, kv_chunk=16)
    params = m.init(jax.random.PRNGKey(0))
    batch = {"tokens": jnp.arange(2 * 16).reshape(2, 16) % cfg.vocab}
    a = generate(m, params, batch, steps=6, cache_len=32)
    b = generate(m, params, batch, steps=6, cache_len=32)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert a.shape == (2, 6)
