"""Shared test fixtures. NOTE: no XLA device-count flags here — smoke
tests and benches must see 1 device; multi-device tests run in
subprocesses (test_distributed.py)."""
import dataclasses
import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def _install_hypothesis_fallback():
    """Property tests use hypothesis when available; on bare images we
    substitute a deterministic sampler with the same tiny API surface
    (given/settings + integers/floats/lists) so the suite still collects
    and exercises each property on seeded random examples."""
    try:
        import hypothesis  # noqa: F401
        return
    except ImportError:
        pass
    import random
    import types

    class _Strategy:
        def __init__(self, gen):
            self._gen = gen

        def sample(self, rng):
            return self._gen(rng)

    def integers(lo=0, hi=2 ** 31 - 1):
        return _Strategy(lambda r: r.randint(lo, hi))

    def floats(lo=0.0, hi=1.0, **_):
        return _Strategy(lambda r: r.uniform(lo, hi))

    def lists(elem, min_size=0, max_size=16, **_):
        return _Strategy(
            lambda r: [elem.sample(r)
                       for _ in range(r.randint(min_size, max_size))])

    def given(*strategies, **kw_strategies):
        def deco(fn):
            # NOTE: zero-arg signature on purpose — pytest must not see
            # the property's parameters and hunt for fixtures.
            def wrapper():
                rng = random.Random(0xC0FFEE)
                for _ in range(getattr(wrapper, "_max_examples", 10)):
                    vals = [s.sample(rng) for s in strategies]
                    kvals = {k: s.sample(rng)
                             for k, s in kw_strategies.items()}
                    fn(*vals, **kvals)
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper
        return deco

    def settings(max_examples=10, **_):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

    hyp = types.ModuleType("hypothesis")
    st = types.ModuleType("hypothesis.strategies")
    st.integers, st.floats, st.lists = integers, floats, lists
    hyp.given, hyp.settings, hyp.strategies = given, settings, st
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st


_install_hypothesis_fallback()


def reduce_cfg(cfg, **extra):
    """Family-aware reduced config for CPU smoke tests."""
    kw = dict(n_layers=cfg.layer_period * 2, d_model=64, vocab=256,
              d_ff=128 if cfg.d_ff else 0)
    if cfg.mla:
        kw.update(n_heads=4, n_kv_heads=4, head_dim=16, kv_lora_rank=32,
                  q_lora_rank=48, qk_rope_dim=8, qk_nope_dim=16,
                  v_head_dim=16)
    else:
        kw.update(n_heads=4, n_kv_heads=2 if cfg.n_kv_heads < cfg.n_heads
                  else 4, head_dim=16)
    if cfg.mrope:
        kw.update(mrope_sections=(2, 3, 3))
    if cfg.n_experts:
        kw.update(n_experts=4, top_k=2, moe_d_ff=32)
    if cfg.enc_dec:
        kw.update(n_enc_layers=2, enc_seq=16, n_kv_heads=4)
    kw.update(extra)
    return dataclasses.replace(cfg, **kw)


@pytest.fixture(scope="session")
def blobs():
    from repro.data import make_blobs
    return make_blobs(1500, 20, seed=0)
