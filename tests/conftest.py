"""Shared test fixtures. NOTE: no XLA device-count flags here — smoke
tests and benches must see 1 device; multi-device tests run in
subprocesses (test_distributed.py)."""
import dataclasses
import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def reduce_cfg(cfg, **extra):
    """Family-aware reduced config for CPU smoke tests."""
    kw = dict(n_layers=cfg.layer_period * 2, d_model=64, vocab=256,
              d_ff=128 if cfg.d_ff else 0)
    if cfg.mla:
        kw.update(n_heads=4, n_kv_heads=4, head_dim=16, kv_lora_rank=32,
                  q_lora_rank=48, qk_rope_dim=8, qk_nope_dim=16,
                  v_head_dim=16)
    else:
        kw.update(n_heads=4, n_kv_heads=2 if cfg.n_kv_heads < cfg.n_heads
                  else 4, head_dim=16)
    if cfg.mrope:
        kw.update(mrope_sections=(2, 3, 3))
    if cfg.n_experts:
        kw.update(n_experts=4, top_k=2, moe_d_ff=32)
    if cfg.enc_dec:
        kw.update(n_enc_layers=2, enc_seq=16, n_kv_heads=4)
    kw.update(extra)
    return dataclasses.replace(cfg, **kw)


@pytest.fixture(scope="session")
def blobs():
    from repro.data import make_blobs
    return make_blobs(1500, 20, seed=0)
