"""hlo_cost analyzer semantics beyond the basic loop-count test."""
import jax
import jax.numpy as jnp

from repro.launch.hlo_cost import analyze


def _compiled(f, *structs):
    return jax.jit(f).lower(*structs).compile()


def test_nested_loops_multiply():
    M = 32

    def f(a, b):
        def outer(x, _):
            def inner(y, _):
                return jnp.tanh(y @ b), None
            y, _ = jax.lax.scan(inner, x, None, length=5)
            return y, None
        y, _ = jax.lax.scan(outer, a, None, length=3)
        return y

    r = analyze(_compiled(
        f, jax.ShapeDtypeStruct((M, M), jnp.float32),
        jax.ShapeDtypeStruct((M, M), jnp.float32)).as_text())
    exp = 15 * 2 * M ** 3
    assert 0.9 < r["flops"] / exp < 1.4, r["flops"] / exp


def test_conditional_counts_max_branch():
    M = 64

    def f(pred, a, b):
        return jax.lax.cond(pred, lambda: a @ b, lambda: a)

    r = analyze(_compiled(
        f, jax.ShapeDtypeStruct((), jnp.bool_),
        jax.ShapeDtypeStruct((M, M), jnp.float32),
        jax.ShapeDtypeStruct((M, M), jnp.float32)).as_text())
    exp = 2 * M ** 3   # max branch = the matmul
    assert 0.9 < r["flops"] / exp < 1.3 or r["flops"] == 0.0, r["flops"]


def test_gather_counts_slice_not_table():
    V, D, B = 50_000, 64, 4

    def f(table, idx):
        return table[idx]

    r = analyze(_compiled(
        f, jax.ShapeDtypeStruct((V, D), jnp.float32),
        jax.ShapeDtypeStruct((B,), jnp.int32)).as_text())
    # XLA fuses the gather; the fusion boundary charges one pass of the
    # table (documented pessimism — EXPERIMENTS.md methodology). Bound:
    # between the slice and ~1.1 table passes, never 2x.
    table_bytes = V * D * 4
    assert r["hbm_bytes"] <= 1.1 * table_bytes, r["hbm_bytes"]


def test_dus_counts_update_not_buffer():
    S, D = 100_000, 64

    def f(buf, upd):
        return jax.lax.dynamic_update_slice(buf, upd, (5, 0))

    r = analyze(_compiled(
        f, jax.ShapeDtypeStruct((S, D), jnp.float32),
        jax.ShapeDtypeStruct((1, D), jnp.float32)).as_text())
    # top-level DUS counts the update; a fused/copy lowering may charge
    # up to ~2 passes of the buffer (in+out), never more
    assert r["hbm_bytes"] <= 2.2 * S * D * 4, r["hbm_bytes"]
