"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracle,
swept over shapes and dtypes, plus hypothesis-generated shapes."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref

RNG = np.random.default_rng(0)


def _data(n, k, dtype):
    X = RNG.normal(size=(n, k)).astype(dtype)
    w = RNG.uniform(0.1, 2.0, size=(n,)).astype(np.float32)
    y = RNG.choice([-1.0, 1.0], size=(n,)).astype(np.float32)
    wv = RNG.normal(size=(k,)).astype(np.float32)
    return X, w, y, wv


@pytest.mark.parametrize("n,k", [(64, 32), (100, 37), (512, 256),
                                 (1000, 130), (9, 513)])
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_weighted_gram_matches_ref(n, k, dtype):
    X, w, _, _ = _data(n, k, np.float32)
    X = jnp.asarray(X, dtype)
    got = ops.weighted_gram(X, jnp.asarray(w), backend="interpret",
                            block_n=128, block_k=128)
    want = ref.weighted_gram(X, jnp.asarray(w))
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-3
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol * np.abs(want).max())


@pytest.mark.parametrize("n,k", [(64, 32), (257, 100), (512, 256)])
def test_fused_estep_matches_ref(n, k):
    X, _, y, wv = _data(n, k, np.float32)
    m_p, g_p, b_p = ops.fused_estep(jnp.asarray(X), jnp.asarray(y),
                                    jnp.asarray(y), jnp.asarray(wv),
                                    eps=1e-6, backend="interpret",
                                    block_n=128)
    m_r, g_r, b_r = ref.fused_estep(jnp.asarray(X), jnp.asarray(y),
                                    jnp.asarray(y), jnp.asarray(wv), 1e-6)
    np.testing.assert_allclose(np.asarray(m_p), np.asarray(m_r), rtol=1e-4,
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(g_p), np.asarray(g_r), rtol=1e-4,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(b_p), np.asarray(b_r), rtol=2e-3,
                               atol=2e-3 * max(1.0, np.abs(b_r).max()))


@pytest.mark.parametrize("n1,n2,k,sigma", [(64, 64, 16, 1.0),
                                           (100, 37, 8, 0.5),
                                           (129, 257, 33, 2.0)])
def test_rbf_gram_matches_ref(n1, n2, k, sigma):
    X1 = RNG.normal(size=(n1, k)).astype(np.float32)
    X2 = RNG.normal(size=(n2, k)).astype(np.float32)
    got = ops.rbf_gram(jnp.asarray(X1), jnp.asarray(X2), sigma=sigma,
                       backend="interpret", block_n=64)
    want = ref.rbf_gram(jnp.asarray(X1), jnp.asarray(X2), sigma)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


def test_rbf_gram_diagonal_is_one():
    X = RNG.normal(size=(50, 7)).astype(np.float32)
    G = np.asarray(ops.rbf_gram(jnp.asarray(X), jnp.asarray(X), sigma=1.3,
                                backend="interpret", block_n=64))
    np.testing.assert_allclose(np.diag(G), 1.0, atol=1e-5)
    np.testing.assert_allclose(G, G.T, atol=1e-5)
    assert G.max() <= 1.0 + 1e-5


@settings(max_examples=12, deadline=None)
@given(st.integers(1, 200), st.integers(1, 70), st.integers(0, 2 ** 20))
def test_weighted_gram_hypothesis_shapes(n, k, seed):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n, k)).astype(np.float32)
    w = rng.uniform(0.01, 5.0, size=(n,)).astype(np.float32)
    got = ops.weighted_gram(jnp.asarray(X), jnp.asarray(w),
                            backend="interpret", block_n=64, block_k=128)
    want = (X * w[:, None]).T @ X
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-3,
                               atol=1e-3 * max(1.0, np.abs(want).max()))


def test_weighted_gram_psd_property():
    """S = X^T diag(w) X with w > 0 must be PSD (solver precondition)."""
    X, w, _, _ = _data(300, 40, np.float32)
    S = np.asarray(ops.weighted_gram(jnp.asarray(X), jnp.asarray(w),
                                     backend="interpret"))
    eig = np.linalg.eigvalsh(S.astype(np.float64))
    assert eig.min() > -1e-3 * max(1.0, eig.max())
